//! Memristive crossbar model (paper Fig. 3d).
//!
//! A crossbar of `dim x dim` RRAM devices stores ternary weights as
//! differential conductance pairs (two devices per weight, so `dim x
//! dim/2` weights). An MVM drives the int8 input vector bit-serially on
//! the rows (`input_bits` pulses), the analog dot products develop on the
//! column lines by Kirchhoff/Ohm, and shared 8-bit ADCs digitize the
//! column outputs (`adc_share` columns multiplexed per ADC).
//!
//! Latency per crossbar MVM:
//!   `input_bits * xbar_read_latency + ceil(cols/adc_share)... ` — the
//!   ADC mux walks the weight columns once per input bit-slice group;
//!   conversions pipeline behind the analog reads, so the slower of the
//!   two streams dominates.
//!
//! Energy: device-pair reads per MAC, driver energy per input bit, and
//! one ADC conversion per digitized column sample.

use crate::config::PimConfig;

/// Geometry of a single crossbar's weight capacity.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct XbarGeometry {
    /// Input rows (one per activation element).
    pub rows: usize,
    /// Weight columns (device columns / devices_per_weight).
    pub weight_cols: usize,
}

impl XbarGeometry {
    pub fn from_config(pim: &PimConfig) -> Self {
        Self {
            rows: pim.crossbar_dim,
            weight_cols: pim.crossbar_dim / pim.devices_per_weight,
        }
    }

    /// Weights stored per crossbar.
    pub fn weights(&self) -> usize {
        self.rows * self.weight_cols
    }
}

/// Latency/energy of one crossbar MVM (all rows x all weight columns).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CrossbarRun {
    /// Analog read + digitization latency, seconds.
    pub latency_s: f64,
    /// Portion of latency attributable to the analog crossbar reads.
    pub xbar_s: f64,
    /// Portion attributable to driver (DAC) setup.
    pub dac_s: f64,
    /// Portion attributable to ADC conversions.
    pub adc_s: f64,
    /// Crossbar device-read energy, joules.
    pub xbar_energy_j: f64,
    /// Driver energy, joules.
    pub dac_energy_j: f64,
    /// ADC energy, joules.
    pub adc_energy_j: f64,
    /// ADC conversions performed.
    pub adc_conversions: u64,
    /// Effective MACs performed.
    pub macs: u64,
}

impl CrossbarRun {
    pub fn total_energy_j(&self) -> f64 {
        self.xbar_energy_j + self.dac_energy_j + self.adc_energy_j
    }
}

/// Simulate one MVM on a single crossbar with `active_rows` driven input
/// rows and `active_cols` weight columns in use (<= geometry).
pub fn run_mvm(pim: &PimConfig, active_rows: usize, active_cols: usize) -> CrossbarRun {
    let geom = XbarGeometry::from_config(pim);
    let rows = active_rows.min(geom.rows);
    let cols = active_cols.min(geom.weight_cols);
    assert!(rows > 0 && cols > 0, "empty crossbar MVM");

    // Bit-serial input streaming: one analog read per input bit plane.
    let xbar_s = pim.input_bits as f64 * pim.xbar_read_latency_s;
    // Drivers piggyback on the read pulses; modeled as one pulse setup.
    let dac_s = pim.xbar_read_latency_s;
    // Each bit plane's column outputs are digitized; `adc_share` columns
    // share one ADC, so a plane needs ceil(cols/ (cols/adc_share ADCs))
    // = adc_share sequential conversions, pipelined across planes.
    let convs_per_plane = pim.adc_share as u64;
    let adc_s = convs_per_plane as f64 * pim.adc_latency_s * pim.input_bits as f64;
    // Analog reads and ADC conversion pipeline; slower stream dominates,
    // the other hides underneath it.
    let latency_s = dac_s + xbar_s.max(adc_s);

    let macs = rows as u64 * cols as u64;
    let adc_conversions = cols as u64 * pim.input_bits as u64;
    CrossbarRun {
        latency_s,
        xbar_s,
        dac_s,
        adc_s: adc_s.min(xbar_s.max(adc_s)), // reported share
        xbar_energy_j: macs as f64 * pim.xbar_mac_energy_j,
        dac_energy_j: rows as f64 * pim.input_bits as f64 * pim.dac_energy_j,
        adc_energy_j: adc_conversions as f64 * pim.adc_energy_j,
        adc_conversions,
        macs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pim() -> PimConfig {
        PimConfig::default()
    }

    #[test]
    fn geometry_differential_pairs() {
        let g = XbarGeometry::from_config(&pim());
        assert_eq!(g.rows, 256);
        assert_eq!(g.weight_cols, 128);
        assert_eq!(g.weights(), 32768);
    }

    #[test]
    fn full_mvm_macs() {
        let r = run_mvm(&pim(), 256, 128);
        assert_eq!(r.macs, 256 * 128);
        assert_eq!(r.adc_conversions, 128 * 8);
    }

    #[test]
    fn partial_mvm_clamps() {
        let r = run_mvm(&pim(), 1000, 1000);
        assert_eq!(r.macs, 256 * 128);
    }

    #[test]
    fn latency_is_sub_microsecond() {
        // Paper: Xbar+DAC+ADC < 1% of latency; single MVM must be ~100ns.
        let r = run_mvm(&pim(), 256, 128);
        assert!(r.latency_s > 0.0 && r.latency_s < 1e-6, "{}", r.latency_s);
    }

    #[test]
    fn energy_components_positive() {
        let r = run_mvm(&pim(), 128, 64);
        assert!(r.xbar_energy_j > 0.0);
        assert!(r.dac_energy_j > 0.0);
        assert!(r.adc_energy_j > 0.0);
        assert!(r.total_energy_j() > r.adc_energy_j);
    }

    #[test]
    #[should_panic(expected = "empty")]
    fn empty_mvm_panics() {
        run_mvm(&pim(), 0, 4);
    }
}
