//! Weight-stationary placement of a model's projection layers onto the
//! crossbar hierarchy (paper: "projection layer weights are preloaded
//! onto the memristive devices in the PIM banks during configuration").
//!
//! A (d_out x d_in) ternary weight matrix tiles into
//! `ceil(d_in/rows) x ceil(d_out/weight_cols)` crossbars; the row-group
//! crossbars of one output column operate in parallel (their partial
//! sums are accumulated digitally after the ADCs), and independent
//! output-column groups are also parallel across PEs/tiles.  An MVM's
//! *latency* is therefore one crossbar MVM (all crossbars fire
//! together) plus the digital partial-sum reduction handled by the NoC
//! model; its *energy* scales with the number of crossbars that fired.

use crate::config::ArchConfig;
use crate::pim::crossbar::{self, CrossbarRun, XbarGeometry};
use crate::workload::MatMulOp;

/// Placement of one projection op (one weight matrix) on the hierarchy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OpMapping {
    /// Crossbars along the input (row) dimension.
    pub row_groups: usize,
    /// Crossbars along the output (column) dimension.
    pub col_groups: usize,
}

impl OpMapping {
    /// Map a projection MVM (stationary matrix is m x k = d_out x d_in).
    pub fn for_op(arch: &ArchConfig, op: &MatMulOp) -> Self {
        let geom = XbarGeometry::from_config(&arch.pim);
        // Input (reduction) dim k spreads over rows; output dim m over
        // weight columns.
        Self {
            row_groups: op.k.div_ceil(geom.rows),
            col_groups: op.m.div_ceil(geom.weight_cols),
        }
    }

    pub fn crossbars(&self) -> u64 {
        self.row_groups as u64 * self.col_groups as u64
    }
}

/// Full-model placement summary.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelMapping {
    pub total_crossbars: u64,
    pub total_pes: u64,
    pub total_tiles: u64,
    /// Devices programmed at configuration time.
    pub programmed_devices: u64,
    /// Weight storage utilization: weights / (crossbars * capacity).
    pub utilization: f64,
}

/// Place every W1A8 op of a decode step onto crossbars.
pub fn map_model(arch: &ArchConfig, ops: &[MatMulOp]) -> ModelMapping {
    let geom = XbarGeometry::from_config(&arch.pim);
    let mut crossbars = 0u64;
    let mut weights = 0u64;
    for op in ops {
        if op.precision == crate::workload::Precision::W1A8 {
            let m = OpMapping::for_op(arch, op);
            crossbars += m.crossbars();
            weights += op.m as u64 * op.k as u64;
        }
    }
    let per_pe = arch.pim.xbars_per_pe as u64;
    let per_tile = per_pe * arch.pim.pes_per_tile as u64;
    let pes = crossbars.div_ceil(per_pe);
    let tiles = crossbars.div_ceil(per_tile);
    ModelMapping {
        total_crossbars: crossbars,
        total_pes: pes,
        total_tiles: tiles,
        programmed_devices: weights * arch.pim.devices_per_weight as u64,
        utilization: weights as f64 / (crossbars as f64 * geom.weights() as f64),
    }
}

/// Latency/energy of one projection MVM executed on its mapped crossbars
/// (all fire in parallel; energy sums, latency is the single-crossbar
/// time — partial-sum reduction is accounted by the NoC model).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PimOpRun {
    pub latency_s: f64,
    pub xbar_s: f64,
    pub dac_s: f64,
    pub adc_s: f64,
    pub energy_j: f64,
    pub crossbars_fired: u64,
    pub macs: u64,
}

/// Execute one W1A8 op on the PIM fabric.
pub fn run_op(arch: &ArchConfig, op: &MatMulOp) -> PimOpRun {
    assert_eq!(
        op.precision,
        crate::workload::Precision::W1A8,
        "attention ops never run on PIM (endurance/accuracy, paper §III)"
    );
    let geom = XbarGeometry::from_config(&arch.pim);
    let mapping = OpMapping::for_op(arch, op);
    // One representative full crossbar; edge crossbars are partially
    // filled but fire in the same analog step.
    let full: CrossbarRun = crossbar::run_mvm(&arch.pim, geom.rows, geom.weight_cols);

    // Energy: each fired crossbar pays drivers+ADC on its active region.
    // Approximate active region by exact weight count (edge tiles fire
    // fewer columns).
    let weights = op.m as u64 * op.k as u64;
    let macs = op.macs();
    let full_cap = geom.weights() as u64;
    let eff_crossbars = weights as f64 / full_cap as f64;
    let energy_j = full.total_energy_j() * eff_crossbars;

    PimOpRun {
        latency_s: full.latency_s,
        xbar_s: full.xbar_s,
        dac_s: full.dac_s,
        adc_s: full.adc_s,
        energy_j,
        crossbars_fired: mapping.crossbars(),
        macs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::by_name;
    use crate::workload::{decode_ops, Precision};

    fn arch() -> ArchConfig {
        ArchConfig::paper_45nm()
    }

    #[test]
    fn opt67b_needs_about_200k_crossbars() {
        let m = by_name("OPT-6.7B").unwrap();
        let ops = decode_ops(&m, 128);
        let map = map_model(&arch(), &ops);
        // 6.44G projection weights / 32768 per crossbar ~= 197k.
        assert!(
            map.total_crossbars > 190_000 && map.total_crossbars < 210_000,
            "{}",
            map.total_crossbars
        );
        assert!(map.utilization > 0.9);
    }

    #[test]
    fn hierarchy_counts_consistent() {
        let m = by_name("GPT2-355M").unwrap();
        let map = map_model(&arch(), &decode_ops(&m, 128));
        assert!(map.total_pes <= map.total_crossbars);
        assert!(map.total_tiles <= map.total_pes);
        assert_eq!(
            map.programmed_devices,
            2 * by_name("GPT2-355M").unwrap().projection_weights()
        );
    }

    #[test]
    fn op_mapping_tiles_exactly() {
        let a = arch();
        let op = MatMulOp {
            layer: 0,
            head: None,
            kind: crate::workload::OpKind::QkvProjection,
            precision: Precision::W1A8,
            m: 4096,
            k: 4096,
            n: 1,
        };
        let m = OpMapping::for_op(&a, &op);
        assert_eq!(m.row_groups, 16); // 4096/256
        assert_eq!(m.col_groups, 32); // 4096/128
        assert_eq!(m.crossbars(), 512);
    }

    #[test]
    fn pim_op_latency_below_microsecond() {
        let a = arch();
        let m = by_name("OPT-6.7B").unwrap();
        let op = decode_ops(&m, 128)
            .into_iter()
            .find(|o| o.precision == Precision::W1A8)
            .unwrap();
        let run = run_op(&a, &op);
        assert!(run.latency_s < 1e-6);
        assert!(run.energy_j > 0.0);
    }

    #[test]
    #[should_panic(expected = "attention")]
    fn attention_on_pim_rejected() {
        let a = arch();
        let op = MatMulOp {
            layer: 0,
            head: Some(0),
            kind: crate::workload::OpKind::AttentionScore,
            precision: Precision::W8A8,
            m: 128,
            k: 64,
            n: 1,
        };
        run_op(&a, &op);
    }
}
