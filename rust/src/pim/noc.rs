//! Mesh network-on-chip model for the PIM tile fabric (paper Fig. 3b:
//! "an array of tiles interconnected through a network-on-chip").
//!
//! The coordinator's top-level latency model uses the calibrated
//! per-crossbar collection constant (`NocConfig::per_xbar_collect_s`);
//! this module provides the *mechanistic* model underneath it: a 2-D
//! mesh of tiles, XY dimension-order routing, per-hop latency, link
//! serialization, and a contention estimate for the partial-sum
//! reduction traffic that flows from every tile toward the reduction
//! root. A test shows the mechanistic model lands within 2x of the
//! calibrated constant for the paper's configuration — the constant is
//! a fitted summary of this mesh, not an arbitrary number.

use crate::config::ArchConfig;

/// A square 2-D mesh of PIM tiles.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Mesh {
    /// Tiles per side (total tiles = side * side).
    pub side: usize,
}

/// Physical link/router parameters (45 nm-class NoC).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NocLink {
    /// Per-hop router+link traversal latency, seconds (2-cycle router
    /// at 1 GHz class).
    pub hop_latency_s: f64,
    /// Link width in bytes per flit.
    pub flit_bytes: usize,
    /// Flit rate, flits/second per link.
    pub flit_rate: f64,
    /// Per-packet service overhead at the reduction root: arbitration,
    /// header decode, ECC, and the digital accumulate of the packet's
    /// partial sums (a handful of cycles in the slower PIM-domain
    /// digital clock, ~200 MHz class).
    pub root_overhead_s: f64,
}

impl Default for NocLink {
    fn default() -> Self {
        Self {
            hop_latency_s: 2e-9,
            flit_bytes: 16,
            flit_rate: 1e9,
            root_overhead_s: 38e-9,
        }
    }
}

impl Mesh {
    /// Smallest square mesh holding `tiles` tiles.
    pub fn for_tiles(tiles: u64) -> Self {
        let side = (tiles as f64).sqrt().ceil() as usize;
        Self { side: side.max(1) }
    }

    /// XY-routing hop count between two tile coordinates.
    pub fn hops(&self, from: (usize, usize), to: (usize, usize)) -> usize {
        from.0.abs_diff(to.0) + from.1.abs_diff(to.1)
    }

    /// Average hop count from all tiles to the mesh centre (the
    /// reduction root where partial sums of one output group meet).
    pub fn mean_hops_to_centre(&self) -> f64 {
        let c = ((self.side - 1) / 2, (self.side - 1) / 2);
        let mut total = 0usize;
        for x in 0..self.side {
            for y in 0..self.side {
                total += self.hops((x, y), c);
            }
        }
        total as f64 / (self.side * self.side) as f64
    }

    /// Bisection links of the mesh (contention bottleneck for
    /// all-to-centre reduction traffic).
    pub fn bisection_links(&self) -> usize {
        self.side.max(1)
    }
}

/// Estimated time to collect `packets` packets of `packet_bytes` each
/// at the reduction root. The root is the serialization point: every
/// packet pays its payload flits plus the fixed per-packet service
/// overhead (arbitration + digital partial-sum accumulate); the routing
/// distance is a one-time pipeline-fill term.
pub fn collect_time_s(mesh: Mesh, link: NocLink, packets: u64, packet_bytes: u64) -> f64 {
    let flits_per_packet = packet_bytes.div_ceil(link.flit_bytes as u64);
    let per_packet = flits_per_packet as f64 / link.flit_rate + link.root_overhead_s;
    let routing_fill = mesh.mean_hops_to_centre() * link.hop_latency_s;
    packets as f64 * per_packet + routing_fill
}

/// Mechanistic per-token communication time for a mapped model: one
/// packet of digitized partial sums per crossbar, collected at the
/// reduction root of the tile mesh.
pub fn model_comm_time_s(arch: &ArchConfig, total_crossbars: u64) -> f64 {
    let xbars_per_tile = (arch.pim.xbars_per_pe * arch.pim.pes_per_tile) as u64;
    let tiles = total_crossbars.div_ceil(xbars_per_tile.max(1));
    let mesh = Mesh::for_tiles(tiles);
    collect_time_s(
        mesh,
        NocLink::default(),
        total_crossbars,
        arch.noc.bytes_per_xbar as u64,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::by_name;
    use crate::pim::mapping::map_model;
    use crate::workload::decode_ops;

    #[test]
    fn hops_are_manhattan() {
        let m = Mesh { side: 8 };
        assert_eq!(m.hops((0, 0), (7, 7)), 14);
        assert_eq!(m.hops((3, 4), (3, 4)), 0);
        assert_eq!(m.hops((2, 5), (5, 1)), 7);
    }

    #[test]
    fn mesh_sizing_covers_tiles() {
        for tiles in [1u64, 2, 16, 17, 100, 6400] {
            let m = Mesh::for_tiles(tiles);
            assert!((m.side * m.side) as u64 >= tiles, "{tiles}");
        }
    }

    #[test]
    fn mean_hops_grows_with_side() {
        let small = Mesh { side: 4 }.mean_hops_to_centre();
        let big = Mesh { side: 16 }.mean_hops_to_centre();
        assert!(big > small);
    }

    #[test]
    fn collect_time_monotone_in_payload_and_packets() {
        let mesh = Mesh { side: 8 };
        let link = NocLink::default();
        let a = collect_time_s(mesh, link, 64, 128);
        let b = collect_time_s(mesh, link, 64, 512);
        let c = collect_time_s(mesh, link, 256, 128);
        assert!(b > a);
        assert!(c > a);
    }

    #[test]
    fn mechanistic_model_within_2x_of_calibrated_constant() {
        // The coordinator uses comm = crossbars * per_xbar_collect_s
        // (46 ns). The mesh model must land in the same regime for the
        // paper's OPT-6.7B mapping — evidence the constant is physical.
        let arch = ArchConfig::paper_45nm();
        let m = by_name("OPT-6.7B").unwrap();
        let mapping = map_model(&arch, &decode_ops(&m, 128));
        let mech = model_comm_time_s(&arch, mapping.total_crossbars);
        let calibrated =
            mapping.total_crossbars as f64 * arch.noc.per_xbar_collect_s;
        let ratio = mech / calibrated;
        assert!(
            (0.5..2.0).contains(&ratio),
            "mechanistic {mech:.6}s vs calibrated {calibrated:.6}s (ratio {ratio:.2})"
        );
    }

    #[test]
    fn comm_time_scales_superlinearly_with_model() {
        // Bigger models -> more tiles -> longer funnel: per-crossbar
        // cost must not shrink with scale.
        let arch = ArchConfig::paper_45nm();
        let small = by_name("GPT2-355M").unwrap();
        let big = by_name("OPT-6.7B").unwrap();
        let ms = map_model(&arch, &decode_ops(&small, 128));
        let mb = map_model(&arch, &decode_ops(&big, 128));
        let per_xbar_small =
            model_comm_time_s(&arch, ms.total_crossbars) / ms.total_crossbars as f64;
        let per_xbar_big =
            model_comm_time_s(&arch, mb.total_crossbars) / mb.total_crossbars as f64;
        assert!(per_xbar_big >= 0.5 * per_xbar_small);
    }
}
