//! MNSIM-equivalent behavioural model of the analog PIM part
//! (paper §III-B, Fig. 3b-d): RRAM crossbar banks that execute the W1A8
//! projection-layer MVMs of 1-bit LLMs.
//!
//! Hierarchy mirrors the paper: **bank -> tile -> PE -> crossbar**, with
//! input/output buffers per tile, a NoC between tiles, and a PIM
//! controller moving data between LPDDR and banks.
//!
//! * [`crossbar`] — mapping ternary weight matrices onto 256x256 device
//!   arrays with differential pairs; per-MVM latency/energy from
//!   bit-serial input streaming + shared 8-bit ADC digitization.
//! * [`mapping`]  — how a model's projection layers tile across
//!   crossbars/PEs/tiles/banks (weight-stationary placement, programmed
//!   once at configuration time).
//! * [`writes`]   — RRAM write cost + endurance model, used by the
//!   attention-on-PIM ablation that justifies the hybrid split.

pub mod crossbar;
pub mod mapping;
pub mod noc;
pub mod writes;

pub use crossbar::{CrossbarRun, XbarGeometry};
pub use mapping::{ModelMapping, OpMapping};
