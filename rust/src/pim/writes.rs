//! RRAM write cost + endurance model — the reason the paper's hybrid
//! split exists.
//!
//! The paper (§III): "The activation-to-activation MatMuls ... necessitate
//! memory writes for each inference, resulting in substantial write
//! energy overheads and potential device failures due to the endurance
//! limitations of memristive devices."  This module quantifies that: the
//! `ablation_attention_on_pim` bench uses it to show what placing the
//! attention K/V matrices in crossbars every token would cost.

use crate::config::PimConfig;

/// Cost of programming a (rows x cols) weight region into RRAM.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WriteCost {
    pub latency_s: f64,
    pub energy_j: f64,
    pub devices_written: u64,
}

/// Program `weights` ternary weights spread over `rows` crossbar rows
/// (row-parallel write: one row per write pulse).
pub fn program_cost(pim: &PimConfig, rows: u64, weights: u64) -> WriteCost {
    let devices = weights * pim.devices_per_weight as u64;
    WriteCost {
        latency_s: rows as f64 * pim.write_latency_per_row_s,
        energy_j: devices as f64 * pim.write_energy_per_device_j,
        devices_written: devices,
    }
}

/// If K/V caches were written to crossbars every token (the design the
/// paper rejects): per-token write cost and device lifetime.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AttentionOnPimCost {
    /// Extra write latency per token, seconds.
    pub write_latency_s: f64,
    /// Extra write energy per token, joules.
    pub write_energy_j: f64,
    /// Tokens until the endurance limit is reached.
    pub tokens_to_failure: f64,
    /// Wall-clock lifetime at `tokens_per_s`, seconds.
    pub lifetime_s: f64,
}

/// Cost model for writing both K and V (l x d per layer, int8 -> one
/// device pair per element... ternary-encoded would need re-quantization;
/// we charge one pair per stored element) each generated token.
pub fn attention_on_pim(
    pim: &PimConfig,
    d: usize,
    n_layers: usize,
    tokens_per_s: f64,
) -> AttentionOnPimCost {
    // Per token: the new K and V rows (2 * d per layer) must be written.
    let elements = 2 * d as u64 * n_layers as u64;
    // Each element occupies one row slot; row-parallel write across
    // crossbar columns: d elements per layer land in ceil(d/cols) rows.
    let rows_per_layer = 2 * d.div_ceil(pim.crossbar_dim / pim.devices_per_weight) as u64;
    let rows = rows_per_layer * n_layers as u64;
    let cost = program_cost(pim, rows, elements);
    // Endurance: every token rewrites the same region (ring buffer over l
    // slots softens it by l, but the paper's argument is order-of-
    // magnitude; we model the worst slot).
    let tokens_to_failure = pim.endurance_cycles;
    AttentionOnPimCost {
        write_latency_s: cost.latency_s,
        write_energy_j: cost.energy_j,
        tokens_to_failure,
        lifetime_s: tokens_to_failure / tokens_per_s.max(1e-12),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pim() -> PimConfig {
        PimConfig::default()
    }

    #[test]
    fn program_cost_scales_with_devices() {
        let a = program_cost(&pim(), 256, 32768);
        let b = program_cost(&pim(), 256, 65536);
        assert_eq!(b.devices_written, 2 * a.devices_written);
        assert!((b.energy_j - 2.0 * a.energy_j).abs() < 1e-18);
        assert_eq!(a.latency_s, b.latency_s); // same rows
    }

    #[test]
    fn attention_on_pim_lifetime_is_short() {
        // OPT-6.7B at ~38 tokens/s: endurance 1e8 -> lifetime ~ a month.
        let c = attention_on_pim(&pim(), 4096, 32, 38.0);
        assert!(c.lifetime_s < 3.2e7, "under a year: {}", c.lifetime_s);
        assert!(c.write_energy_j > 0.0);
        assert!(c.write_latency_s > 0.0);
    }

    #[test]
    fn write_latency_exceeds_read_by_orders() {
        let p = pim();
        let c = attention_on_pim(&p, 1024, 24, 100.0);
        // One token's KV writes vs one crossbar read (~100ns):
        let read = p.input_bits as f64 * p.xbar_read_latency_s;
        assert!(c.write_latency_s > 10.0 * read);
    }
}
