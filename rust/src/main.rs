//! `repro` — the PIM-LLM leader binary.
//!
//! Subcommands:
//! * `simulate`  — one (model, context, arch) point: latency breakdown,
//!   energy ledger, throughput/efficiency metrics.
//! * `sweep`     — regenerate any paper figure/table (fig1b, fig4, fig5,
//!   fig6, fig7, fig8, table3, or `all`).
//! * `serve`     — end-to-end functional serving on the tiny 1-bit
//!   decoder (AOT artifacts when present, else the synthetic offline
//!   model) through the configured runtime backend.
//! * `validate`  — golden-token check: the runtime must reproduce the
//!   recorded golden generation exactly.
//! * `pack`      — lower the model's ternary matrices to bitplanes once
//!   and serialize them as a versioned `.tpk` packed artifact that
//!   `serve`/`validate --artifact` mmap back with no re-packing.
//! * `generate`  — latency/energy of a full autoregressive generation on
//!   the simulated hardware.
//! * `trace-check` — parse a Chrome trace written by `serve --trace`
//!   with the in-crate JSON parser and verify its schema (what ci.sh
//!   runs against every smoke trace).

use pim_llm::analysis::{figures, report};
use pim_llm::config::ArchConfig;
use pim_llm::coordinator::{self, token_loop, Arch};
use pim_llm::models;
use pim_llm::obs::export::{check_trace_doc, write_chrome_trace_tagged};
use pim_llm::quant::{write_tpk, PackedModel};
use pim_llm::runtime::{
    decoder, default_artifacts, ArenaLayout, Artifacts, BackendKind, DraftSpec, Engine,
    ShardedEngine, SpecPlan, DEFAULT_SPEC_K,
};
use pim_llm::serving::{
    serve_sharded_stats_lanes, shard_report, LaneStats, LatencyStats, Policy, Request, Server,
};
use pim_llm::util::cli::Args;
use pim_llm::util::error::{anyhow, ensure, Context, Result};
use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

const USAGE: &str = "\
repro — PIM-LLM: hybrid analog-PIM + systolic accelerator for 1-bit LLMs

USAGE: repro [--config <arch.toml>] <subcommand> [flags]

SUBCOMMANDS
  simulate   --model <name> --context <l> --arch <pim-llm|tpu-llm>
  sweep      --figure <fig1b|fig4|fig5|fig6|fig7|fig8|table3|all>
  serve      --requests N --prompt-len P --new-tokens T [--batch B | --max-active A]
             [--policy fifo|rr|batched|continuous|sharded] [--workers W]
             [--arena-blocks K] [--block-len L] [--kv-quant f32|int8]
             [--prefix-cache] [--prefix-cap E]
             [--backend reference|packed|pjrt]
             (--policy continuous admits/retires sessions every tick
              against the paged KV-cache arena, preempting under
              pressure; batched reserves worst-case blocks per request
              and advances fixed lanes; sharded partitions the arena
              into --workers W Send-able shards, one continuous-batching
              worker thread each (max-active lanes PER worker), with
              deterministic hash placement and cross-shard work
              stealing — same tokens as every other policy, host
              backends only. Without --policy, --batch B > 0
              selects batched, else round-robin. --arena-blocks /
              --block-len size the KV arena (total across shards);
              0 = defaults.
              --kv-quant int8 stores cached K/V as group-scaled int8
              (one f32 scale per block/layer/head row group) — ~4x the
              resident sessions per arena byte; attention gathers the
              int8 rows and accumulates in i32, dequantizing at the
              softmax boundary. Host backends only; f32 (the default)
              stays the bit-exact oracle.
              --prefix-cache shares identical prompt prefixes across
              requests via copy-on-write cache blocks — matched prefill
              positions are skipped with bit-identical outputs;
              --prefix-cap bounds the index, 0 = default. The generated
              workload gives every request a common system prefix over
              the first half of its prompt, and without an explicit
              --block-len the block length defaults to that prefix
              length (the index caches whole blocks only), so hits
              actually occur)
             [--prefill-chunk C] [--spec-draft off|self|tiny|oracle] [--spec-k K]
             (--prefill-chunk C > 0 runs the two-lane scheduler: each
              still-prefilling session ingests up to C prompt positions
              per tick through one span traversal, so long prompts stop
              serializing everyone else's time-to-first-token.
              --spec-draft turns on greedy-exact speculative decoding:
              a draft proposes up to --spec-k tokens per tick and the
              target verifies the whole span in one traversal — output
              is byte-identical by construction. Drafts: `self` (the
              target model itself, the always-accept sanity draft),
              `tiny` (a sized-down synthetic sibling), `oracle` (replay
              a recorded non-speculative run of the same workload — the
              100%-acceptance throughput bound). Both knobs compose with
              every policy, backend, --kv-quant and --prefix-cache)
             [--artifact <file.tpk>] (packed backend only)
             [--trace <path>] [--metrics] [--validate-every N]
             (--trace records every scheduler tick, admission,
              preemption, steal, prefix hit, COW copy, eviction and
              kernel span into per-shard ring buffers and writes a
              Chrome trace-event JSON — load it in Perfetto or
              chrome://tracing, one track per shard worker.
              --metrics prints the counter/gauge/histogram snapshot,
              merged across shards in worker-id order. Both are inert:
              token streams are byte-identical with them on or off.
              --validate-every N runs the arena's full invariant check
              every N ticks and fails the serve on the first violation)
  validate   [--backend reference|packed|pjrt] [--artifact <file.tpk>]
  pack       [--out <file.tpk>] (default packed.tpk)
  generate   --model <name> --prompt-len P --new-tokens T --arch <...>
  trace-check --trace <path>   (validate a serve --trace output file)
  bench-check [--dir <path>]   (parse every checked-in BENCH_*.json with
              the in-crate JSON parser and verify each bench's required
              keys — what ci.sh runs instead of an existence grep)

--backend selects the runtime executor (default: the PIM_LLM_BACKEND
env var, else the pure-Rust reference executor; `packed` runs the same
numerics over 2-bit ternary bitplanes with popcount kernels —
bit-identical outputs, ~16x less weight traffic).

--artifact points serve/validate at a `.tpk` file written by `repro
pack`: the bitplanes are mmap'd zero-copy, so engine start skips the
per-matrix re-pack and concurrent serving processes share one page-cache
copy of the weights. Requires --backend packed; the file is validated
against the current model's manifest before any weight is trusted.

Models (paper Table II): GPT2-355M GPT2-774M GPT2-1.5B OPT-1.3B OPT-2.7B
OPT-6.7B LLaMA-7B (+ OPT-350M, GPT2-Small, GPT2-Medium)";

fn parse_arch(s: &str) -> Result<Arch> {
    match s.to_lowercase().as_str() {
        "pim-llm" | "pim" | "pimllm" => Ok(Arch::PimLlm),
        "tpu-llm" | "tpu" | "tpullm" => Ok(Arch::TpuLlm),
        other => Err(anyhow!("unknown arch '{other}' (pim-llm | tpu-llm)")),
    }
}

fn load_arch(args: &Args) -> Result<ArchConfig> {
    match args.get("config") {
        Some(p) => ArchConfig::from_toml_file(p),
        None => {
            // Prefer the calibrated config if checked in.
            let cal = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
                .join("configs/calibrated_45nm.toml");
            if cal.exists() {
                ArchConfig::from_toml_file(cal)
            } else {
                Ok(ArchConfig::paper_45nm())
            }
        }
    }
}

fn lookup_model(name: &str) -> Result<models::LlmConfig> {
    models::by_name(name).ok_or_else(|| anyhow!("unknown model '{name}'\n\n{USAGE}"))
}

/// The `--artifact <file.tpk>` flag, validated against the chosen
/// backend: a packed artifact only loads on the packed backend.
fn artifact_path(args: &Args, kind: BackendKind) -> Result<Option<std::path::PathBuf>> {
    match args.get("artifact") {
        None => Ok(None),
        Some(p) => {
            if kind != BackendKind::Packed {
                return Err(anyhow!(
                    "--artifact requires --backend packed (a .tpk holds packed \
                     ternary bitplanes, which only that backend executes)"
                ));
            }
            Ok(Some(std::path::PathBuf::from(p)))
        }
    }
}

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let arch_cfg = load_arch(&args)?;

    match args.subcommand.as_deref() {
        Some("simulate") => cmd_simulate(&args, &arch_cfg),
        Some("sweep") => cmd_sweep(&args, &arch_cfg),
        Some("serve") => cmd_serve(&args),
        Some("validate") => cmd_validate(&args),
        Some("pack") => cmd_pack(&args),
        Some("generate") => cmd_generate(&args, &arch_cfg),
        Some("trace-check") => cmd_trace_check(&args),
        Some("bench-check") => cmd_bench_check(&args),
        _ => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

fn cmd_simulate(args: &Args, arch_cfg: &ArchConfig) -> Result<()> {
    args.expect_known(&["config", "model", "context", "arch"])?;
    let m = lookup_model(&args.str_or("model", "OPT-6.7B"))?;
    let context = args.usize_or("context", 128)?;
    let arch = parse_arch(&args.str_or("arch", "pim-llm"))?;
    let r = coordinator::simulate(arch_cfg, &m, context, arch);
    let met = r.metrics();
    println!("{} — {} @ l={}", r.arch.name(), r.model, r.context);
    println!("  token latency : {:.4} ms", 1e3 * r.latency_s());
    println!("  tokens/s      : {:.2}", met.tokens_per_s());
    println!("  energy/token  : {:.4} mJ", 1e3 * r.energy.total_j());
    println!("  tokens/joule  : {:.2}", met.tokens_per_joule());
    println!("  GOPS          : {:.2}", met.gops());
    println!("  GOPS/W        : {:.2}", met.gops_per_w());
    println!("  latency breakdown:");
    for (k, v) in r.breakdown.items() {
        if v > 0.0 {
            println!(
                "    {:<14} {:>10.4} ms ({:>6.2}%)",
                k,
                1e3 * v,
                100.0 * v / r.latency_s()
            );
        }
    }
    println!("  energy breakdown:");
    for (k, v) in r.energy.items() {
        if v > 0.0 {
            println!(
                "    {:<14} {:>10.4} mJ ({:>6.2}%)",
                k,
                1e3 * v,
                100.0 * v / r.energy.total_j()
            );
        }
    }
    Ok(())
}

fn cmd_sweep(args: &Args, arch_cfg: &ArchConfig) -> Result<()> {
    args.expect_known(&["config", "figure"])?;
    let figure = args.str_or("figure", "all");
    let want = |f: &str| figure == "all" || figure == f;
    let mut matched = false;
    if want("fig1b") {
        report::print_fig1b(&figures::fig1b(arch_cfg));
        println!();
        matched = true;
    }
    if want("fig4") {
        report::print_fig4(&figures::fig4(arch_cfg));
        println!();
        matched = true;
    }
    if want("fig5") {
        report::print_fig5(&figures::fig5(arch_cfg));
        println!();
        matched = true;
    }
    if want("fig6") {
        report::print_fig6(&figures::fig6(arch_cfg));
        println!();
        matched = true;
    }
    if want("fig7") {
        report::print_fig7(&figures::fig7(arch_cfg));
        println!();
        matched = true;
    }
    if want("fig8") {
        report::print_fig8(&figures::fig8(arch_cfg));
        println!();
        matched = true;
    }
    if want("table3") {
        report::print_table3(&figures::table3(arch_cfg));
        matched = true;
    }
    if !matched {
        return Err(anyhow!("unknown figure '{figure}'\n\n{USAGE}"));
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    args.expect_known(&[
        "config",
        "requests",
        "prompt-len",
        "new-tokens",
        "max-active",
        "batch",
        "workers",
        "policy",
        "arena-blocks",
        "block-len",
        "kv-quant",
        "prefix-cache",
        "prefix-cap",
        "backend",
        "artifact",
        "trace",
        "metrics",
        "validate-every",
        "prefill-chunk",
        "spec-draft",
        "spec-k",
    ])?;
    let requests = args.usize_or("requests", 16)?;
    let prompt_len = args.usize_or("prompt-len", 8)?;
    let new_tokens = args.usize_or("new-tokens", 16)?;
    let max_active = args.usize_or("max-active", 4)?;
    // Without --policy the historical knobs apply: --batch B > 0 selects
    // the batched scheduler (one decode_batch over all active sessions
    // per tick); 0 keeps round-robin.
    let batch = args.usize_or("batch", 0)?;
    let workers = args.usize_or("workers", 1)?;
    let policy = Policy::from_flags(args.get("policy"), batch, max_active, workers)?;
    // KV-cache arena geometry (0 = defaults); small --arena-blocks is
    // how to see the continuous policy's preemption path live.
    let arena_blocks = args.usize_or("arena-blocks", 0)?;
    // Arena storage layout: f32 (exact, the default) or group-scaled
    // int8 (~4x resident sessions per arena byte, host backends only).
    let kv_quant = ArenaLayout::from_name(&args.str_or("kv-quant", "f32"))?;
    let prefix_cache = args.flag("prefix-cache")?;
    let prefix_cap = args.usize_or("prefix-cap", 0)?;
    // Without an explicit --block-len, --prefix-cache sizes blocks to
    // the workload's shared system prefix (the first half of each
    // prompt): the index only caches FULL blocks, so the default
    // 16-position block would swallow a short prompt whole and the
    // advertised hits could never occur.
    let block_len = match args.get("block-len") {
        Some(_) => args.usize_or("block-len", 0)?,
        None if prefix_cache => (prompt_len / 2).clamp(1, 16),
        None => 0,
    };

    // The first half of every prompt is a COMMON system prefix (id-
    // independent), the second half is per-request — the shape the
    // prefix cache is built for; without --prefix-cache it is simply a
    // fixed workload.
    let reqs: Vec<Request> = (0..requests as u64)
        .map(|id| Request {
            id,
            prompt: (0..prompt_len)
                .map(|i| {
                    if i < prompt_len / 2 {
                        ((i * 7) % 255 + 1) as i32
                    } else {
                        ((id as usize * 31 + i * 7) % 255 + 1) as i32
                    }
                })
                .collect(),
            n_new: new_tokens,
        })
        .collect();
    let kind = BackendKind::resolve(args.backend())?;
    let artifact = artifact_path(args, kind)?;
    // Observability knobs: both are provably inert (byte-identical
    // token streams with them on or off — the determinism suites pin
    // it), so flipping them on for a production-shaped run is safe.
    let trace_path = args.get("trace").map(std::path::PathBuf::from);
    let metrics = args.flag("metrics")?;
    let validate_every = args.usize_or("validate-every", 0)?;
    let obs_on = trace_path.is_some() || metrics;
    // Lane-scheduler knobs: --prefill-chunk 0 (off) keeps the classic
    // single-position tick, --spec-draft off keeps plain decoding.
    let prefill_chunk = args.usize_or("prefill-chunk", 0)?;
    let spec_draft = DraftSpec::from_flag(&args.str_or("spec-draft", "off"))?;
    let spec_k = args.usize_or("spec-k", DEFAULT_SPEC_K)?;

    // Sharded serving partitions ONE arena across worker-owned shards
    // and runs its own multi-threaded front end; everything else drives
    // the classic single-engine server.
    if let Policy::Sharded {
        workers,
        max_active,
    } = policy
    {
        let mut engine = match &artifact {
            Some(p) => ShardedEngine::load_default_packed_artifact_mode(
                p,
                block_len,
                arena_blocks,
                workers,
                kv_quant,
            )?,
            None => ShardedEngine::load_default_mode(
                kind,
                block_len,
                arena_blocks,
                workers,
                kv_quant,
            )?,
        };
        if prefix_cache && !engine.enable_prefix_cache(prefix_cap) {
            println!(
                "note: backend {} keeps contiguous private caches — prefix \
                 sharing unavailable, serving with full prefill",
                engine.backend_name()
            );
        }
        let arena = engine.arena_status();
        println!(
            "engine: backend={} platform={} model=tiny-1bit policy={policy:?} \
             arena={} blocks x {} positions ({} bytes, kv={}) across {} shards \
             prefix_cache={}",
            engine.backend_name(),
            engine.platform(),
            arena.total_blocks,
            arena.block_len,
            arena.total_bytes,
            engine.arena_mode().name(),
            engine.workers(),
            engine.prefix_enabled()
        );
        if obs_on {
            engine.set_obs_enabled(true);
        }
        let plan = build_spec_plan(
            spec_draft,
            spec_k,
            engine.shard(0).artifacts(),
            &reqs,
            block_len,
            kv_quant,
        )?;
        let offsets = vec![0.0; reqs.len()];
        let t0 = Instant::now();
        let (out, shards) = serve_sharded_stats_lanes(
            &mut engine,
            reqs,
            &offsets,
            max_active,
            validate_every,
            prefill_chunk,
            plan.as_ref(),
        )?;
        let wall = t0.elapsed().as_secs_f64();
        let stats = LatencyStats::from_responses(&out, wall);
        println!(
            "served {} requests / {} tokens in {:.2}s (mean latency {:.3}s)",
            stats.n, stats.total_tokens, wall, stats.mean_service_s
        );
        println!("  {}", stats.report());
        if (prefill_chunk > 0 || plan.is_some()) && obs_on {
            let lanes = engine.obs().iter().map(|o| LaneStats::from_obs(o)).fold(
                LaneStats::default(),
                |a, b| LaneStats {
                    prefill_tokens: a.prefill_tokens + b.prefill_tokens,
                    decode_tokens: a.decode_tokens + b.decode_tokens,
                    proposed: a.proposed + b.proposed,
                    accepted: a.accepted + b.accepted,
                },
            );
            println!("  {}", lanes.report());
        }
        for line in shard_report(&shards).lines() {
            println!("  {line}");
        }
        if let Some(ps) = engine.prefix_stats() {
            println!(
                "  {} | {} entries live",
                ps.report(),
                engine.prefix_entries()
            );
        }
        if let Some(path) = &trace_path {
            let tracks = engine.drain_traces();
            let events: usize = tracks.iter().map(|(_, evs)| evs.len()).sum();
            write_chrome_trace_tagged(path, &tracks, Some(engine.arena_mode().name()))?;
            println!(
                "trace: {events} events across {} tracks -> {} (Perfetto-loadable)",
                tracks.len(),
                path.display()
            );
        }
        if metrics {
            print!("{}", engine.metrics_snapshot().render());
        }
        return Ok(());
    }

    let engine = match &artifact {
        Some(p) => {
            Engine::load_default_packed_artifact_mode(p, block_len, arena_blocks, kv_quant)?
        }
        None => Engine::load_default_with_arena_mode(kind, block_len, arena_blocks, kv_quant)?,
    };
    if prefix_cache && !engine.enable_prefix_cache(prefix_cap) {
        println!(
            "note: backend {} keeps contiguous private caches — prefix \
             sharing unavailable, serving with full prefill",
            engine.backend_name()
        );
    }
    let arena = engine.arena_status();
    println!(
        "engine: backend={} platform={} model=tiny-1bit (d={}, {} layers) policy={policy:?} \
         arena={} blocks x {} positions ({} bytes, kv={}) prefix_cache={}",
        engine.backend_name(),
        engine.platform(),
        engine.artifacts.manifest.model.d,
        engine.artifacts.manifest.model.n_layers,
        arena.total_blocks,
        arena.block_len,
        arena.total_bytes,
        engine.arena_mode().name(),
        engine.prefix_enabled()
    );
    if obs_on {
        engine.obs().set_enabled(true);
    }
    let plan = build_spec_plan(
        spec_draft,
        spec_k,
        engine.artifacts(),
        &reqs,
        block_len,
        kv_quant,
    )?;
    let t0 = Instant::now();
    let mut server = Server::new(&engine, policy)
        .with_validate_every(validate_every)
        .with_prefill_chunk(prefill_chunk);
    if let Some(p) = &plan {
        server = server.with_spec(p)?;
    }
    let out = server.serve(reqs)?;
    let wall = t0.elapsed().as_secs_f64();
    let stats = LatencyStats::from_responses(&out, wall);
    println!(
        "served {} requests / {} tokens in {:.2}s (mean latency {:.3}s)",
        stats.n, stats.total_tokens, wall, stats.mean_service_s
    );
    println!("  {}", stats.report());
    if (prefill_chunk > 0 || plan.is_some()) && obs_on {
        println!("  {}", LaneStats::from_obs(engine.obs()).report());
    }
    if let Some(ps) = engine.prefix_stats() {
        println!(
            "  {} | {} entries live",
            ps.report(),
            engine.prefix_entries()
        );
    }
    if let Some(path) = &trace_path {
        let tracks = vec![(engine.obs().shard(), engine.obs().trace.drain())];
        let events = tracks[0].1.len();
        write_chrome_trace_tagged(path, &tracks, Some(engine.arena_mode().name()))?;
        println!(
            "trace: {events} events across 1 track -> {} (Perfetto-loadable)",
            path.display()
        );
    }
    if metrics {
        print!("{}", engine.metrics_snapshot().render());
    }
    Ok(())
}

/// Build the speculative-decoding plan for `serve`. Self/tiny drafts
/// wrap the target's own artifact bundle; the oracle records a
/// non-speculative reference run of the same workload first — the
/// honest 100%-acceptance harness, and the throughput bound the lanes
/// bench reports against. Tokens are policy- and backend-independent,
/// but NOT kv-layout independent (int8 is lossy, and its group scaling
/// follows the block geometry), so the recording run pins the same
/// `--kv-quant` and `--block-len` the serving engine uses.
fn build_spec_plan(
    draft: DraftSpec,
    k: usize,
    bundle: &Arc<Artifacts>,
    reqs: &[Request],
    block_len: usize,
    kv_quant: ArenaLayout,
) -> Result<Option<SpecPlan>> {
    Ok(match draft {
        DraftSpec::Off => None,
        DraftSpec::SelfModel => Some(SpecPlan::self_draft(bundle, k)?),
        DraftSpec::Tiny => Some(SpecPlan::tiny_draft(bundle, k)?),
        DraftSpec::Oracle => {
            let oracle = Engine::load_default_with_arena_mode(
                BackendKind::Reference,
                block_len,
                0,
                kv_quant,
            )?;
            let recorded = Server::new(&oracle, Policy::Fifo).serve(reqs.to_vec())?;
            let book: HashMap<u64, Vec<i32>> =
                recorded.into_iter().map(|r| (r.id, r.tokens)).collect();
            Some(SpecPlan::oracle(book, k)?)
        }
    })
}

/// `repro bench-check [--dir <path>]`: parse every checked-in
/// `BENCH_*.json` with the in-crate JSON parser and verify each
/// bench's required keys — so CI fails a bench artifact an interrupted
/// run left empty or truncated, instead of only checking that the file
/// exists.
fn cmd_bench_check(args: &Args) -> Result<()> {
    args.expect_known(&["config", "dir"])?;
    let dir = std::path::PathBuf::from(
        args.str_or("dir", concat!(env!("CARGO_MANIFEST_DIR"), "/..")),
    );
    let specs: &[(&str, &str, &[&str])] = &[
        (
            "BENCH_obs.json",
            "runtime_obs",
            &[
                "backend",
                "block_len",
                "arena_blocks",
                "requests",
                "target_overhead_pct",
                "worst_overhead_pct",
                "points",
            ],
        ),
        (
            "BENCH_kvq.json",
            "runtime_kvq",
            &[
                "block_len",
                "lanes",
                "requests",
                "sessions_ratio_sized",
                "tiny",
                "sized",
            ],
        ),
        (
            "BENCH_sharded.json",
            "runtime_sharded",
            &[
                "block_len",
                "total_blocks",
                "lanes_per_worker",
                "requests",
                "cores",
                "speedup_4w_over_1w_sized",
                "tiny",
                "sized",
            ],
        ),
        ("BENCH_artifacts.json", "runtime_artifacts", &["models"]),
        (
            "BENCH_lanes.json",
            "runtime_lanes",
            &[
                "block_len",
                "arena_blocks",
                "max_active",
                "requests",
                "prefill_chunk",
                "spec_k",
                "mixed",
                "decode",
            ],
        ),
    ];
    for (file, bench, keys) in specs {
        let path = dir.join(file);
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading bench artifact {}", path.display()))?;
        let doc = pim_llm::util::json::parse(&text)
            .with_context(|| format!("parsing bench artifact {}", path.display()))?;
        (|| -> Result<()> {
            let name = doc.get("bench")?.as_str()?;
            ensure!(name == *bench, "field 'bench' is '{name}', expected '{bench}'");
            for key in *keys {
                doc.get(key)?;
            }
            Ok(())
        })()
        .with_context(|| format!("bench artifact {}", path.display()))?;
        let provisional = doc
            .opt("provisional")
            .map(|b| b.as_bool())
            .transpose()?
            .unwrap_or(false);
        println!(
            "  {file} OK ({bench}{})",
            if provisional { ", provisional" } else { "" }
        );
    }
    println!("bench-check OK: {} artifacts validated", specs.len());
    Ok(())
}

/// `repro trace-check --trace <path>`: parse a `serve --trace` output
/// with the in-crate JSON parser and verify the trace-event schema
/// (nonempty, per-track monotonic timestamps) — the CI round trip.
fn cmd_trace_check(args: &Args) -> Result<()> {
    args.expect_known(&["config", "trace"])?;
    let path = args
        .get("trace")
        .ok_or_else(|| anyhow!("trace-check needs --trace <path>\n\n{USAGE}"))?;
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading trace file {path}"))?;
    let doc = pim_llm::util::json::parse(&text)
        .with_context(|| format!("parsing trace file {path}"))?;
    let (events, tracks) =
        check_trace_doc(&doc).with_context(|| format!("validating trace file {path}"))?;
    println!("trace OK: {events} events, {tracks} tracks, monotonic per track");
    Ok(())
}

fn cmd_validate(args: &Args) -> Result<()> {
    args.expect_known(&["config", "backend", "artifact"])?;
    let kind = BackendKind::resolve(args.backend())?;
    let engine = match artifact_path(args, kind)? {
        Some(p) => Engine::load_default_packed_artifact(&p, 0, 0)?,
        None => Engine::load_default_with(kind)?,
    };
    let timing = decoder::validate_golden(&engine)?;
    println!(
        "golden OK: {} tokens reproduced exactly on {} backend={} (decode {:.1} tok/s, \
         prefill {:.1} tok/s)",
        timing.prompt_len + timing.new_tokens,
        engine.platform(),
        engine.backend_name(),
        timing.decode_tokens_per_s(),
        timing.prefill_tokens_per_s()
    );
    Ok(())
}

fn cmd_pack(args: &Args) -> Result<()> {
    args.expect_known(&["config", "out"])?;
    let out = std::path::PathBuf::from(args.str_or("out", "packed.tpk"));
    let artifacts = default_artifacts(BackendKind::Packed)?;
    let t0 = Instant::now();
    let model = PackedModel::lower(&artifacts)?;
    let lower_s = t0.elapsed().as_secs_f64();
    write_tpk(&out, &model, &artifacts.manifest)?;
    let file_bytes = std::fs::metadata(&out)?.len();
    let m = &artifacts.manifest.model;
    println!(
        "packed {} ternary matrices ({} layers, d={}) in {:.3}s",
        m.n_layers * 6 + 1,
        m.n_layers,
        m.d,
        lower_s
    );
    println!(
        "  planes: {} bytes ({:.1}x smaller than dense f32 {})",
        model.packed_bytes(),
        model.dense_f32_bytes() as f64 / model.packed_bytes() as f64,
        model.dense_f32_bytes()
    );
    println!(
        "  wrote {} ({file_bytes} bytes) — load with \
         `repro serve|validate --backend packed --artifact {}`",
        out.display(),
        out.display()
    );
    Ok(())
}

fn cmd_generate(args: &Args, arch_cfg: &ArchConfig) -> Result<()> {
    args.expect_known(&["config", "model", "prompt-len", "new-tokens", "arch"])?;
    let m = lookup_model(&args.str_or("model", "OPT-6.7B"))?;
    let prompt_len = args.usize_or("prompt-len", 32)?;
    let new_tokens = args.usize_or("new-tokens", 96)?;
    let arch = parse_arch(&args.str_or("arch", "pim-llm"))?;
    let g = token_loop::generate(arch_cfg, &m, arch, prompt_len, new_tokens);
    println!(
        "{} — {}: {} prompt + {} new tokens",
        g.arch.name(),
        g.model,
        g.prompt_len,
        g.n_new
    );
    println!("  total latency : {:.3} s", g.total_latency_s);
    println!("  decode tok/s  : {:.2}", g.decode_tokens_per_s());
    println!("  total energy  : {:.4} J", g.total_energy.total_j());
    Ok(())
}
