//! Popcount MVM kernels over [`TernaryPlanes`] — the packed W1A8
//! projection, bit-for-bit identical to the dense reference kernels.
//!
//! ## How the mask-select accumulation works
//!
//! The dense kernel computes, per output column `j`,
//!
//! ```text
//! y[j] = sum_kk x_q[kk] * w[kk][j]        (w in {-1, 0, +1})
//!      = sum_{kk in PLUS_j} x_q[kk]  -  sum_{kk in MINUS_j} x_q[kk]
//! ```
//!
//! so the matmul is two masked sums of int8 activations. To turn a
//! masked sum into popcounts, the int8 activations are lifted to the
//! unsigned byte `u[kk] = x_q[kk] + 128` (in `[0, 255]`) and sliced
//! into eight activation bitplanes `A_b` (`A_b` bit `kk` = bit `b` of
//! `u[kk]`). Then for a 64-row mask word `M`:
//!
//! ```text
//! sum_{kk in M} u[kk]   = sum_{b=0..8} 2^b * popcount(M & A_b)
//! sum_{kk in M} x_q[kk] = that - 128 * popcount(M)
//! ```
//!
//! — 18 popcounts per 64-row word per column (8 per plane + the bias
//! correction) replace up to 128 scalar FMAs, and the operands are 16x
//! smaller than the dense f32 matrix (2 bits/weight vs 32).
//!
//! ## The hot-path shape: unrolled tiles, blocked stripes, zero alloc
//!
//! * **4-word tiles with independent accumulators.** [`column_dot`]
//!   walks a column's mask words four at a time into four independent
//!   i32 accumulators ([`word_dot`] per word), so the popcount chains
//!   of four 64-row word groups are in flight simultaneously instead
//!   of serialized through one accumulator — the ILP the superscalar
//!   core needs to keep its popcount units busy. A scalar remainder
//!   loop covers `words_per_col % 4`.
//! * **Cache blocking falls out of the layout.** Planes are
//!   column-major, so one column's masks are `2 * words_per_col` u64s
//!   (128 B/plane at d = 512 — two cache lines) and the activation
//!   planes are `8 * words_per_col` u64s per lane (4 KiB at d = 512):
//!   the batch kernel's column-outer/lane-inner loop keeps the column's
//!   masks and every lane's activation planes L1-resident while each
//!   weight word is loaded exactly once per call. Striped threads each
//!   own a contiguous column range, i.e. a contiguous, disjoint slab of
//!   the weight planes and of the accumulator — no sharing, no
//!   false-sharing traffic.
//! * **No per-call heap traffic.** Every buffer the kernels need —
//!   activation bitplanes, quantization scales, the striped accumulator
//!   — lives in a caller-owned [`PackedScratch`] that grows to the
//!   high-water mark once and is reused forever after.
//!   [`bitlinear_packed_into`] (the batch-of-one entry the serving
//!   steady state hits) performs ZERO heap allocations when warm;
//!   [`bitlinear_packed_batch_with`] allocates only its `Vec<Vec<f32>>`
//!   outputs (exactly `1 + B` allocations warm, pinned by the
//!   counting-allocator tests below). The convenience wrappers
//!   [`bitlinear_packed`]/[`bitlinear_packed_batch`] build a local
//!   scratch per call and exist for oracles and tests.
//!
//! ## Why the result is bit-for-bit equal to the f32 reference
//!
//! All accumulation here is i32 and therefore exact — and i32 addition
//! is associative and commutative, so the 4-way tile split, the
//! remainder loop, and column striping cannot change the sum. The dense
//! reference accumulates the same integer terms in f32 carriers; inside
//! the exact window (`k * 127 < 2^24`, enforced by
//! [`super::pack::MAX_EXACT_K`]) every one of its partial sums is an
//! exactly-representable integer, so its f32 additions never round and
//! its final accumulator equals the exact integer sum — the same
//! integer this kernel produces. Both kernels then apply the identical
//! final operation `(sum as f32) * (w_scale / x_scale)` with identical
//! operands — [`quantize_into`] computes the scale with the shared
//! [`act_scale`] and the per-element quantization with the dense
//! kernel's exact formula — so the outputs are identical bit patterns.

use super::planes::TernaryPlanes;
use crate::runtime::kernels::{act_scale, column_stripes, PAR_MAC_THRESHOLD};

/// Reusable scratch for the packed kernels: activation bitplanes,
/// per-lane quantization scales, and the integer accumulator. Grows to
/// the largest shape it has seen and never shrinks, so a warmed-up
/// scratch makes every subsequent kernel call allocation-free (modulo
/// the batch kernel's output vectors). `PackedBackend` threads one of
/// these through its whole decode path.
#[derive(Debug, Default)]
pub struct PackedScratch {
    /// Activation bitplanes, `B * words_per_col * 8` words: lane `bi`
    /// owns `[bi * g, (bi + 1) * g)` with `g = words_per_col * 8`,
    /// word group `wi` of a lane at `[wi * 8 + b]` = plane `b`.
    act: Vec<u64>,
    /// Per-lane activation scales (127 / absmax), `B` entries.
    scales: Vec<f32>,
    /// Integer accumulator for the batch kernel, `n * B` entries,
    /// column-major over lanes: `acc[j * B + bi]` — so a column stripe
    /// `[j0, j1)` owns the contiguous disjoint slab
    /// `[j0 * B, j1 * B)`, handed to its thread via `split_at_mut`.
    acc: Vec<i32>,
}

impl PackedScratch {
    pub fn new() -> Self {
        Self::default()
    }
}

/// Grow-only view: `v[..len]`, resizing (one allocation, then never
/// again for this size) only when the high-water mark rises.
fn ensure_len<T: Copy + Default>(v: &mut Vec<T>, len: usize) -> &mut [T] {
    if v.len() < len {
        v.resize(len, T::default());
    }
    &mut v[..len]
}

/// Quantize one activation vector directly into eight 64-lane bitplanes
/// (`words`, length `words_per_col * 8`, zeroed here), returning the
/// activation scale. Bit-identical to running the shared
/// `act_quant_int8` and then slicing: the scale comes from the shared
/// [`act_scale`] and each element applies the dense kernel's exact
/// `(v * scale).round().clamp(-128.0, 127.0)` before the `u = x_q +
/// 128` lift — no `x_q` vector is ever materialized. Padding lanes
/// beyond `x.len()` stay zero; the weight masks are zero there too, so
/// they never contribute.
///
/// Precondition: finite activations. The `as i32` lift saturates NaN
/// to 0 where the dense kernel would propagate it, so the bit-for-bit
/// contract requires finite inputs — guaranteed for model activations
/// because [`super::model::PackedModel::lower`] rejects any non-finite
/// parameter tensor at load.
fn quantize_into(x: &[f32], words: &mut [u64]) -> f32 {
    words.fill(0);
    let scale = act_scale(x);
    for (kk, &v) in x.iter().enumerate() {
        // Exact integer in [-128, 127], computed with the dense
        // kernel's formula so the rescale operands match bitwise.
        let q = (v * scale).round().clamp(-128.0, 127.0);
        let u = (q as i32 + 128) as u64;
        let (wi, lane) = (kk / 64, kk % 64);
        let group = &mut words[wi * 8..wi * 8 + 8];
        for (b, word) in group.iter_mut().enumerate() {
            *word |= ((u >> b) & 1) << lane;
        }
    }
    scale
}

/// The masked integer dot of ONE 64-row word group: mask words
/// `pw`/`mw` against the eight activation planes of the group.
#[inline(always)]
fn word_dot(pw: u64, mw: u64, group: &[u64]) -> i32 {
    if pw == 0 && mw == 0 {
        return 0; // fully-zero 64-row stretch: nothing to select
    }
    let (mut up, mut um) = (0u32, 0u32);
    for (b, &plane) in group.iter().enumerate() {
        up += (pw & plane).count_ones() << b;
        um += (mw & plane).count_ones() << b;
    }
    // The planes carry u = x_q + 128: subtract the bias once per
    // selected lane. (up/um <= 64 * 255 per word group, so nothing
    // here can overflow.)
    up as i32 - um as i32 - 128 * (pw.count_ones() as i32 - mw.count_ones() as i32)
}

/// The masked integer dot product of one column: walks the column's
/// plus/minus words in 4-word tiles with four independent accumulators
/// (plus a scalar remainder), popcounting against the activation
/// planes. i32 addition is exact and order-free, so the tiling cannot
/// change the result.
#[inline]
fn column_dot(act: &[u64], plus: &[u64], minus: &[u64]) -> i32 {
    let w = plus.len();
    debug_assert_eq!(minus.len(), w);
    debug_assert_eq!(act.len(), w * 8);
    let (mut a0, mut a1, mut a2, mut a3) = (0i32, 0i32, 0i32, 0i32);
    let mut wi = 0usize;
    while wi + 4 <= w {
        a0 += word_dot(plus[wi], minus[wi], &act[wi * 8..wi * 8 + 8]);
        a1 += word_dot(plus[wi + 1], minus[wi + 1], &act[wi * 8 + 8..wi * 8 + 16]);
        a2 += word_dot(plus[wi + 2], minus[wi + 2], &act[wi * 8 + 16..wi * 8 + 24]);
        a3 += word_dot(plus[wi + 3], minus[wi + 3], &act[wi * 8 + 24..wi * 8 + 32]);
        wi += 4;
    }
    while wi < w {
        a0 += word_dot(plus[wi], minus[wi], &act[wi * 8..wi * 8 + 8]);
        wi += 1;
    }
    (a0 + a1) + (a2 + a3)
}

/// Packed W1A8 projection into a caller-provided output slice, with
/// caller-owned scratch: the ZERO-ALLOCATION entry point (when the
/// scratch and `out` are warm) that `PackedBackend::decode_step`'s
/// batch-of-one steady state reaches. Bit for bit the same `n`-vector
/// that [`crate::runtime::kernels::bitlinear`] computes from the dense
/// source (enforced by `tests/packed_equivalence.rs`).
pub fn bitlinear_packed_into(
    x: &[f32],
    planes: &TernaryPlanes,
    scratch: &mut PackedScratch,
    out: &mut [f32],
) {
    // Hard asserts (not debug_assert): a short `x` would leave its
    // missing rows' activation planes zero, which the -128 bias
    // correction then mis-reads as x_q = -128 — silent corruption, so
    // make the misuse loud even in release builds.
    assert_eq!(
        x.len(),
        planes.k,
        "bitlinear_packed: activation length != matrix rows"
    );
    assert_eq!(
        out.len(),
        planes.n,
        "bitlinear_packed: output length != matrix columns"
    );
    let g = planes.words_per_col * 8;
    let act = ensure_len(&mut scratch.act, g);
    let rescale = planes.scale / quantize_into(x, act);
    for (j, o) in out.iter_mut().enumerate() {
        *o = column_dot(act, planes.plus_col(j), planes.minus_col(j)) as f32 * rescale;
    }
}

/// Convenience wrapper over [`bitlinear_packed_into`] with a local
/// scratch and a fresh output vector — the oracle/test entry point.
pub fn bitlinear_packed(x: &[f32], planes: &TernaryPlanes) -> Vec<f32> {
    let mut scratch = PackedScratch::new();
    let mut out = vec![0.0f32; planes.n];
    bitlinear_packed_into(x, planes, &mut scratch, &mut out);
    out
}

/// Batched packed projection with caller-owned scratch: one traversal
/// of the bitplanes per call, every column's mask words applied to all
/// B activation-plane sets while they are hot — the packed analogue of
/// [`crate::runtime::kernels::bitlinear_batch`], and bit-for-bit equal
/// to B [`bitlinear_packed`] calls (integer accumulation is exact, so
/// this is immediate; the tests pin it anyway). With warm scratch the
/// only allocations are the returned output vectors (`1 + B`).
///
/// Above [`PAR_MAC_THRESHOLD`] MACs the output columns are striped
/// across scoped threads via the SAME [`column_stripes`] partition the
/// dense batch kernel uses; each stripe owns a contiguous disjoint slab
/// of the accumulator (`acc[j * B + bi]` layout), handed out with
/// `split_at_mut`. Stripes partition `j` and each column's sum is
/// independent and exact, so thread count cannot change a bit. Below
/// the threshold the walk is inline and serial — no stripe vector, no
/// thread machinery, no allocation.
pub fn bitlinear_packed_batch_with(
    xs: &[Vec<f32>],
    planes: &TernaryPlanes,
    scratch: &mut PackedScratch,
) -> Vec<Vec<f32>> {
    let b = xs.len();
    if b == 0 {
        return Vec::new();
    }
    // Hard assert for the same reason as in `bitlinear_packed_into`.
    assert!(
        xs.iter().all(|x| x.len() == planes.k),
        "bitlinear_packed_batch: activation length != matrix rows"
    );
    let n = planes.n;
    let g = planes.words_per_col * 8;
    let PackedScratch { act, scales, acc } = scratch;
    let act = ensure_len(act, b * g);
    let scales = ensure_len(scales, b);
    for ((bi, x), s) in xs.iter().enumerate().zip(scales.iter_mut()) {
        *s = quantize_into(x, &mut act[bi * g..(bi + 1) * g]);
    }
    let act: &[u64] = act;
    let acc = ensure_len(acc, n * b);

    let macs = b * planes.k * n;
    if macs < PAR_MAC_THRESHOLD {
        for (j, chunk) in acc.chunks_exact_mut(b).enumerate() {
            let plus = planes.plus_col(j);
            let minus = planes.minus_col(j);
            for (bi, a) in chunk.iter_mut().enumerate() {
                *a = column_dot(&act[bi * g..(bi + 1) * g], plus, minus);
            }
        }
    } else {
        let stripes = column_stripes(macs, n);
        std::thread::scope(|s| {
            // column_stripes yields contiguous ascending ranges covering
            // [0, n), so handing out acc slabs in order tiles it exactly.
            let mut rest: &mut [i32] = acc;
            let mut next = 0usize;
            for &(j0, j1) in &stripes {
                debug_assert_eq!(j0, next);
                next = j1;
                let (chunk, tail) = std::mem::take(&mut rest).split_at_mut((j1 - j0) * b);
                rest = tail;
                s.spawn(move || {
                    for (j, row) in (j0..j1).zip(chunk.chunks_exact_mut(b)) {
                        let plus = planes.plus_col(j);
                        let minus = planes.minus_col(j);
                        for (bi, a) in row.iter_mut().enumerate() {
                            *a = column_dot(&act[bi * g..(bi + 1) * g], plus, minus);
                        }
                    }
                });
            }
            debug_assert_eq!(next, n);
        });
    }

    let mut out: Vec<Vec<f32>> = Vec::with_capacity(b);
    for (bi, &s) in scales.iter().enumerate() {
        let rescale = planes.scale / s;
        out.push((0..n).map(|j| acc[j * b + bi] as f32 * rescale).collect());
    }
    out
}

/// Convenience wrapper over [`bitlinear_packed_batch_with`] with a
/// local scratch — the oracle/test entry point.
pub fn bitlinear_packed_batch(xs: &[Vec<f32>], planes: &TernaryPlanes) -> Vec<Vec<f32>> {
    bitlinear_packed_batch_with(xs, planes, &mut PackedScratch::new())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::pack::pack;
    use crate::runtime::kernels::{bitlinear, bitlinear_batch};
    use crate::util::rng::Rng;

    fn random_ternary(rng: &mut Rng, numel: usize) -> Vec<f32> {
        // Rng::range is INCLUSIVE: [0, 2] - 1 = {-1, 0, 1}.
        (0..numel)
            .map(|_| rng.range(0, 2) as f32 - 1.0)
            .collect()
    }

    #[test]
    fn packed_matches_dense_bitwise_across_shapes() {
        // k values chosen to hit every tile shape of the 4-word unroll:
        // words_per_col 1..5 plus 9 (two full tiles + remainder 1) and
        // the exact-tile cases 4 and 8.
        let mut rng = Rng::new(7);
        for (k, n) in [
            (1usize, 1usize),
            (5, 3),
            (63, 9),
            (64, 16),
            (65, 8),
            (130, 31),
            (192, 11), // words_per_col 3: remainder-only path
            (256, 64), // words_per_col 4: exactly one tile
            (320, 5),  // words_per_col 5: one tile + 1 remainder word
            (512, 24), // words_per_col 8: two full tiles
            (520, 10), // words_per_col 9: two tiles + remainder
        ] {
            let w = random_ternary(&mut rng, k * n);
            let scale = 0.25 + rng.f64() as f32;
            let planes = pack(&w, k, n, scale).unwrap();
            for case in 0..3 {
                let x: Vec<f32> = (0..k).map(|_| rng.normal() as f32).collect();
                let dense = bitlinear(&x, &w, n, scale);
                let packed = bitlinear_packed(&x, &planes);
                assert_eq!(dense, packed, "{k}x{n} case {case}");
            }
        }
    }

    #[test]
    fn packed_handles_extreme_activations() {
        // Saturating, all-zero, and single-spike activations: the u =
        // x_q + 128 lift and the eps floor must all agree with dense.
        let k = 70usize;
        let n = 6usize;
        let mut rng = Rng::new(9);
        let w = random_ternary(&mut rng, k * n);
        let planes = pack(&w, k, n, 0.73).unwrap();
        let mut spike = vec![0.0f32; k];
        spike[67] = -4.2;
        for x in [
            vec![0.0f32; k],              // all zeros: eps-floored scale
            vec![1e-7f32; k],             // below the eps floor
            (0..k).map(|i| if i % 2 == 0 { 1e6 } else { -1e6 }).collect(),
            spike,
        ] {
            assert_eq!(bitlinear(&x, &w, n, 0.73), bitlinear_packed(&x, &planes));
        }
    }

    #[test]
    fn packed_batch_matches_dense_batch_and_singles() {
        let mut rng = Rng::new(21);
        for (b_n, k, n) in [(1usize, 8usize, 5usize), (3, 100, 16), (8, 64, 7)] {
            let w = random_ternary(&mut rng, k * n);
            let planes = pack(&w, k, n, 0.37).unwrap();
            let xs: Vec<Vec<f32>> = (0..b_n)
                .map(|_| (0..k).map(|_| rng.normal() as f32).collect())
                .collect();
            let packed = bitlinear_packed_batch(&xs, &planes);
            let dense = bitlinear_batch(&xs, &w, n, 0.37);
            assert_eq!(packed, dense, "b={b_n} {k}x{n} vs dense batch");
            for (x, y) in xs.iter().zip(&packed) {
                assert_eq!(&bitlinear_packed(x, &planes), y, "b={b_n} {k}x{n} single");
            }
        }
    }

    #[test]
    fn packed_batch_striped_path_is_bitwise_equal() {
        // 8 * 64 * 4096 = 2^21 MACs: exactly at the striping threshold,
        // so this exercises the threaded column walk.
        let (b_n, k, n) = (8usize, 64usize, 4096usize);
        let mut rng = Rng::new(33);
        let w = random_ternary(&mut rng, k * n);
        let planes = pack(&w, k, n, 1.5).unwrap();
        let xs: Vec<Vec<f32>> = (0..b_n)
            .map(|_| (0..k).map(|_| rng.normal() as f32).collect())
            .collect();
        let packed = bitlinear_packed_batch(&xs, &planes);
        for (x, y) in xs.iter().zip(&packed) {
            assert_eq!(&bitlinear(x, &w, n, 1.5), y);
        }
    }

    #[test]
    fn empty_batch_is_empty() {
        let planes = pack(&[1.0, -1.0], 2, 1, 1.0).unwrap();
        assert!(bitlinear_packed_batch(&[], &planes).is_empty());
    }

    #[test]
    fn scratch_reuse_across_shapes_stays_bitwise_correct() {
        // One scratch threaded through matrices of different shapes in
        // both directions (grow then shrink then grow): every call must
        // still match the dense kernel bitwise — stale words from a
        // larger predecessor must never leak into a smaller successor.
        let mut rng = Rng::new(55);
        let mut scratch = PackedScratch::new();
        let shapes = [(130usize, 7usize), (40, 12), (520, 3), (64, 9), (5, 2)];
        for &(k, n) in shapes.iter().chain(shapes.iter().rev()) {
            let w = random_ternary(&mut rng, k * n);
            let planes = pack(&w, k, n, 0.91).unwrap();
            let x: Vec<f32> = (0..k).map(|_| rng.normal() as f32).collect();
            let mut out = vec![0.0f32; n];
            bitlinear_packed_into(&x, &planes, &mut scratch, &mut out);
            assert_eq!(bitlinear(&x, &w, n, 0.91), out, "{k}x{n} single");
            let xs: Vec<Vec<f32>> = (0..3)
                .map(|_| (0..k).map(|_| rng.normal() as f32).collect())
                .collect();
            let batch = bitlinear_packed_batch_with(&xs, &planes, &mut scratch);
            assert_eq!(bitlinear_batch(&xs, &w, n, 0.91), batch, "{k}x{n} batch");
        }
    }

    #[test]
    fn warm_single_vector_path_is_allocation_free() {
        // THE zero-alloc invariant of the serving steady state: after
        // one warm-up call, bitlinear_packed_into must touch the heap
        // zero times. Counted by the test-only global allocator
        // (util::testalloc); the counter is thread-local, so parallel
        // test threads cannot perturb it.
        let mut rng = Rng::new(77);
        let (k, n) = (520usize, 33usize); // tiles + remainder, ragged n
        let w = random_ternary(&mut rng, k * n);
        let planes = pack(&w, k, n, 0.43).unwrap();
        let mut scratch = PackedScratch::new();
        let mut out = vec![0.0f32; n];
        let warmup: Vec<f32> = (0..k).map(|_| rng.normal() as f32).collect();
        bitlinear_packed_into(&warmup, &planes, &mut scratch, &mut out);
        let xs: Vec<Vec<f32>> = (0..4)
            .map(|_| (0..k).map(|_| rng.normal() as f32).collect())
            .collect();
        let before = crate::util::testalloc::thread_allocs();
        for x in &xs {
            bitlinear_packed_into(x, &planes, &mut scratch, &mut out);
        }
        assert_eq!(
            crate::util::testalloc::thread_allocs() - before,
            0,
            "warm bitlinear_packed_into must not allocate"
        );
        // And it still computed the right bits while not allocating.
        assert_eq!(bitlinear(&xs[3], &w, n, 0.43), out);
    }

    #[test]
    fn warm_batch_path_allocates_only_its_outputs() {
        // The unstriped batch kernel's only warm heap traffic is the
        // returned Vec<Vec<f32>>: one outer Vec + B inner Vecs.
        let mut rng = Rng::new(78);
        let (b_n, k, n) = (3usize, 130usize, 17usize);
        let w = random_ternary(&mut rng, k * n);
        let planes = pack(&w, k, n, 0.61).unwrap();
        let mut scratch = PackedScratch::new();
        let xs: Vec<Vec<f32>> = (0..b_n)
            .map(|_| (0..k).map(|_| rng.normal() as f32).collect())
            .collect();
        let _ = bitlinear_packed_batch_with(&xs, &planes, &mut scratch); // warm
        let before = crate::util::testalloc::thread_allocs();
        let out = bitlinear_packed_batch_with(&xs, &planes, &mut scratch);
        let allocs = crate::util::testalloc::thread_allocs() - before;
        assert_eq!(
            allocs,
            1 + b_n as u64,
            "warm batch kernel must allocate exactly its output vectors"
        );
        assert_eq!(bitlinear_batch(&xs, &w, n, 0.61), out);
    }
}
