//! Popcount MVM kernels over [`TernaryPlanes`] — the packed W1A8
//! projection, bit-for-bit identical to the dense reference kernels.
//!
//! ## How the mask-select accumulation works
//!
//! The dense kernel computes, per output column `j`,
//!
//! ```text
//! y[j] = sum_kk x_q[kk] * w[kk][j]        (w in {-1, 0, +1})
//!      = sum_{kk in PLUS_j} x_q[kk]  -  sum_{kk in MINUS_j} x_q[kk]
//! ```
//!
//! so the matmul is two masked sums of int8 activations. To turn a
//! masked sum into popcounts, the int8 activations are lifted to the
//! unsigned byte `u[kk] = x_q[kk] + 128` (in `[0, 255]`) and sliced
//! into eight activation bitplanes `A_b` (`A_b` bit `kk` = bit `b` of
//! `u[kk]`). Then for a 64-row mask word `M`:
//!
//! ```text
//! sum_{kk in M} u[kk]   = sum_{b=0..8} 2^b * popcount(M & A_b)
//! sum_{kk in M} x_q[kk] = that - 128 * popcount(M)
//! ```
//!
//! — 18 popcounts per 64-row word per column (8 per plane + the bias
//! correction) replace up to 128 scalar FMAs, and the operands are 16x
//! smaller than the dense f32 matrix (2 bits/weight vs 32).
//!
//! ## Why the result is bit-for-bit equal to the f32 reference
//!
//! All accumulation here is i32 and therefore exact. The dense
//! reference accumulates the same integer terms in f32 carriers; inside
//! the exact window (`k * 127 < 2^24`, enforced by
//! [`super::pack::MAX_EXACT_K`]) every one of its partial sums is an
//! exactly-representable integer, so its f32 additions never round and
//! its final accumulator equals the exact integer sum — the same
//! integer this kernel produces. Both kernels then apply the identical
//! final operation `(sum as f32) * (w_scale / x_scale)` with identical
//! operands, so the outputs are identical bit patterns. (Integer
//! addition is order-independent, which is also why column striping and
//! thread count cannot change a bit.)

use super::planes::TernaryPlanes;
use crate::runtime::kernels::{act_quant_int8, column_stripes};

/// One activation vector quantized and sliced into eight 64-lane
/// bitplanes. Word group `wi` (rows `[wi*64, wi*64+64)`) owns the eight
/// consecutive words `words[wi*8 .. wi*8+8]`, one per bit of
/// `u = x_q + 128` — keeping a word group contiguous means the whole
/// group a column word needs sits in a single cache line.
struct ActPlanes {
    /// `words_per_col * 8` words, `[wi * 8 + b]` = plane `b` of group `wi`.
    words: Vec<u64>,
    /// The activation quantization scale (127 / absmax).
    scale: f32,
}

/// Quantize with the SHARED [`act_quant_int8`] (identical `x_q` and
/// `x_scale` to the dense kernel, which is what makes the final rescale
/// bit-identical), then slice into bitplanes. Padding lanes beyond
/// `x.len()` stay zero; the weight masks are zero there too, so they
/// never contribute.
///
/// Precondition: finite activations. The `xv as i32` lift saturates
/// NaN to 0 where the dense kernel would propagate it, so the
/// bit-for-bit contract requires finite inputs — guaranteed for model
/// activations because [`super::model::PackedModel::lower`] rejects any
/// non-finite parameter tensor at load.
fn quantize_to_planes(x: &[f32], words_per_col: usize) -> ActPlanes {
    let (x_q, scale) = act_quant_int8(x);
    let mut words = vec![0u64; words_per_col * 8];
    for (kk, &xv) in x_q.iter().enumerate() {
        // x_q is an exact integer in [-128, 127] carried in f32.
        let u = (xv as i32 + 128) as u64;
        let (wi, lane) = (kk / 64, kk % 64);
        let group = &mut words[wi * 8..wi * 8 + 8];
        for (b, word) in group.iter_mut().enumerate() {
            *word |= ((u >> b) & 1) << lane;
        }
    }
    ActPlanes { words, scale }
}

/// The masked integer dot product of one column: walks the column's
/// plus/minus words once, popcounting against the activation planes.
#[inline]
fn column_dot(act: &[u64], plus: &[u64], minus: &[u64]) -> i32 {
    let mut acc = 0i32;
    for (wi, (&pw, &mw)) in plus.iter().zip(minus).enumerate() {
        if pw == 0 && mw == 0 {
            continue; // fully-zero 64-row stretch: nothing to select
        }
        let group = &act[wi * 8..wi * 8 + 8];
        let (mut up, mut um) = (0u32, 0u32);
        for (b, &plane) in group.iter().enumerate() {
            up += (pw & plane).count_ones() << b;
            um += (mw & plane).count_ones() << b;
        }
        // The planes carry u = x_q + 128: subtract the bias once per
        // selected lane. (up/um <= 64 * 255 per word group, so nothing
        // here can overflow.)
        acc += up as i32 - um as i32
            - 128 * (pw.count_ones() as i32 - mw.count_ones() as i32);
    }
    acc
}

/// Packed W1A8 projection: `x` (len `planes.k`) through the bitplane
/// matrix, returning bit for bit the same `n`-vector that
/// [`crate::runtime::kernels::bitlinear`] computes from the dense
/// source (enforced by `tests/packed_equivalence.rs`).
pub fn bitlinear_packed(x: &[f32], planes: &TernaryPlanes) -> Vec<f32> {
    // Hard assert (not debug_assert): a short `x` would leave its
    // missing rows' activation planes zero, which the -128 bias
    // correction then mis-reads as x_q = -128 — silent corruption, so
    // make the misuse loud even in release builds.
    assert_eq!(
        x.len(),
        planes.k,
        "bitlinear_packed: activation length != matrix rows"
    );
    let act = quantize_to_planes(x, planes.words_per_col);
    let rescale = planes.scale / act.scale;
    (0..planes.n)
        .map(|j| column_dot(&act.words, planes.plus_col(j), planes.minus_col(j)) as f32 * rescale)
        .collect()
}

/// Batched packed projection: one traversal of the bitplanes per call,
/// every column's mask words applied to all B activation-plane sets
/// while they are hot — the packed analogue of
/// [`crate::runtime::kernels::bitlinear_batch`], and bit-for-bit equal
/// to B [`bitlinear_packed`] calls (integer accumulation is exact, so
/// this is immediate; the tests pin it anyway).
///
/// Above [`crate::runtime::kernels::PAR_MAC_THRESHOLD`] MACs the output
/// columns are striped across threads via the SAME
/// [`column_stripes`] partition the dense batch kernel uses — stripes
/// partition `j` and each column's sum is independent and exact, so
/// thread count cannot change a bit.
pub fn bitlinear_packed_batch(xs: &[Vec<f32>], planes: &TernaryPlanes) -> Vec<Vec<f32>> {
    let b = xs.len();
    if b == 0 {
        return Vec::new();
    }
    // Hard assert for the same reason as in `bitlinear_packed`.
    assert!(
        xs.iter().all(|x| x.len() == planes.k),
        "bitlinear_packed_batch: activation length != matrix rows"
    );
    let acts: Vec<ActPlanes> = xs
        .iter()
        .map(|x| quantize_to_planes(x, planes.words_per_col))
        .collect();
    let n = planes.n;
    let stripes = column_stripes(b * planes.k * n, n);

    let parts = crate::util::par::parallel_map_threads(&stripes, stripes.len(), |&(j0, j1)| {
        let width = j1 - j0;
        let mut acc = vec![0i32; b * width];
        for j in j0..j1 {
            let plus = planes.plus_col(j);
            let minus = planes.minus_col(j);
            for (bi, act) in acts.iter().enumerate() {
                acc[bi * width + (j - j0)] = column_dot(&act.words, plus, minus);
            }
        }
        acc
    });

    let mut out: Vec<Vec<f32>> = Vec::with_capacity(b);
    for (bi, act) in acts.iter().enumerate() {
        let rescale = planes.scale / act.scale;
        let mut o = vec![0.0f32; n];
        for (stripe, part) in stripes.iter().zip(&parts) {
            let (j0, j1) = *stripe;
            let width = j1 - j0;
            for (oj, &sum) in o[j0..j1].iter_mut().zip(&part[bi * width..(bi + 1) * width]) {
                *oj = sum as f32 * rescale;
            }
        }
        out.push(o);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::pack::pack;
    use crate::runtime::kernels::{bitlinear, bitlinear_batch};
    use crate::util::rng::Rng;

    fn random_ternary(rng: &mut Rng, numel: usize) -> Vec<f32> {
        // Rng::range is INCLUSIVE: [0, 2] - 1 = {-1, 0, 1}.
        (0..numel)
            .map(|_| rng.range(0, 2) as f32 - 1.0)
            .collect()
    }

    #[test]
    fn packed_matches_dense_bitwise_across_shapes() {
        let mut rng = Rng::new(7);
        for (k, n) in [
            (1usize, 1usize),
            (5, 3),
            (63, 9),
            (64, 16),
            (65, 8),
            (130, 31),
            (256, 64),
        ] {
            let w = random_ternary(&mut rng, k * n);
            let scale = 0.25 + rng.f64() as f32;
            let planes = pack(&w, k, n, scale).unwrap();
            for case in 0..3 {
                let x: Vec<f32> = (0..k).map(|_| rng.normal() as f32).collect();
                let dense = bitlinear(&x, &w, n, scale);
                let packed = bitlinear_packed(&x, &planes);
                assert_eq!(dense, packed, "{k}x{n} case {case}");
            }
        }
    }

    #[test]
    fn packed_handles_extreme_activations() {
        // Saturating, all-zero, and single-spike activations: the u =
        // x_q + 128 lift and the eps floor must all agree with dense.
        let k = 70usize;
        let n = 6usize;
        let mut rng = Rng::new(9);
        let w = random_ternary(&mut rng, k * n);
        let planes = pack(&w, k, n, 0.73).unwrap();
        let mut spike = vec![0.0f32; k];
        spike[67] = -4.2;
        for x in [
            vec![0.0f32; k],              // all zeros: eps-floored scale
            vec![1e-7f32; k],             // below the eps floor
            (0..k).map(|i| if i % 2 == 0 { 1e6 } else { -1e6 }).collect(),
            spike,
        ] {
            assert_eq!(bitlinear(&x, &w, n, 0.73), bitlinear_packed(&x, &planes));
        }
    }

    #[test]
    fn packed_batch_matches_dense_batch_and_singles() {
        let mut rng = Rng::new(21);
        for (b_n, k, n) in [(1usize, 8usize, 5usize), (3, 100, 16), (8, 64, 7)] {
            let w = random_ternary(&mut rng, k * n);
            let planes = pack(&w, k, n, 0.37).unwrap();
            let xs: Vec<Vec<f32>> = (0..b_n)
                .map(|_| (0..k).map(|_| rng.normal() as f32).collect())
                .collect();
            let packed = bitlinear_packed_batch(&xs, &planes);
            let dense = bitlinear_batch(&xs, &w, n, 0.37);
            assert_eq!(packed, dense, "b={b_n} {k}x{n} vs dense batch");
            for (x, y) in xs.iter().zip(&packed) {
                assert_eq!(&bitlinear_packed(x, &planes), y, "b={b_n} {k}x{n} single");
            }
        }
    }

    #[test]
    fn packed_batch_striped_path_is_bitwise_equal() {
        // 8 * 64 * 4096 = 2^21 MACs: exactly at the striping threshold,
        // so this exercises the threaded column walk.
        let (b_n, k, n) = (8usize, 64usize, 4096usize);
        let mut rng = Rng::new(33);
        let w = random_ternary(&mut rng, k * n);
        let planes = pack(&w, k, n, 1.5).unwrap();
        let xs: Vec<Vec<f32>> = (0..b_n)
            .map(|_| (0..k).map(|_| rng.normal() as f32).collect())
            .collect();
        let packed = bitlinear_packed_batch(&xs, &planes);
        for (x, y) in xs.iter().zip(&packed) {
            assert_eq!(&bitlinear(x, &w, n, 1.5), y);
        }
    }

    #[test]
    fn empty_batch_is_empty() {
        let planes = pack(&[1.0, -1.0], 2, 1, 1.0).unwrap();
        assert!(bitlinear_packed_batch(&[], &planes).is_empty());
    }
}
