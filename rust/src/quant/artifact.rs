//! `.tpk` — the versioned on-disk packed-artifact format: every
//! [`TernaryPlanes`] of a [`PackedModel`] serialized in its exact
//! in-memory layout, so engine start is a header validation plus an
//! mmap instead of an O(weights) re-pack of every matrix, and N serving
//! processes loading the same file share one physical copy of the
//! planes through the kernel page cache.
//!
//! ## File layout (all integers little-endian)
//!
//! ```text
//! offset  size  field
//! ------  ----  -----------------------------------------------------
//!      0     8  magic  "PIMLLTPK"
//!      8     4  format version (u32) = 1
//!     12     4  endian tag (u32) = 0x1B17_C0DE — readable only when
//!               file and host agree on byte order
//!     16    48  model geometry: vocab, d, h, d_ff, n_layers, max_ctx
//!               (six u64s; must match the manifest exactly)
//!     64     8  model eps as f64 bit pattern
//!     72     8  artifact seed (u64)
//!     80     8  n_matrices (u64) = n_layers * 6 + 1
//!     88    88  matrix record 0        ┐  one per matrix, the lowering
//!    176    88  matrix record 1        ┘  order: layer{i}.{wq,wk,wv,
//!    ...            wx,w_in,w_out} ascending, then w_head
//!    ...        zero padding to a 64-byte boundary
//!      P  8*W   plus-plane words of matrix 0 (column-major u64s)
//!    ...        zero padding to a 64-byte boundary
//!     P'  8*W   minus-plane words of matrix 0
//!    ...        ... and so on for every matrix
//! ```
//!
//! Each 88-byte matrix record:
//!
//! ```text
//! offset  size  field
//! ------  ----  -----------------------------------------------------
//!      0    32  parameter name, zero-padded UTF-8 (<= 31 bytes)
//!     32     8  k (rows), u64
//!     40     8  n (columns), u64
//!     48     8  words_per_col = ceil(k / 64), u64
//!     56     4  weight scale as f32 bit pattern
//!     60     4  reserved (0)
//!     64     8  plus-plane byte offset (64-byte aligned)
//!     72     8  minus-plane byte offset (64-byte aligned)
//!     80     8  words per plane = n * words_per_col, u64
//! ```
//!
//! ## Versioning and alignment rules
//!
//! * Any layout change bumps [`TPK_VERSION`]; readers reject other
//!   versions outright (no migration shims — repack with `repro pack`).
//! * Plane sections start on 64-byte boundaries within the file. An
//!   mmap base is page-aligned, so every section is 64-byte aligned in
//!   memory too: `u64` loads are aligned, and sections never straddle
//!   a cache line they don't own.
//! * The payload is exactly the words the kernels consume — the loader
//!   hands out [`PlaneWords::Mapped`] windows into the mapping
//!   (zero-copy) when the host is little-endian and the file mmaps;
//!   otherwise it falls back to byte-swapping reads into owned
//!   vectors. Neither path re-packs: dense weights are never touched.
//!
//! ## What the loader validates vs what `repro validate` covers
//!
//! [`load_tpk`] checks structure exhaustively — magic/version/endian,
//! geometry + eps bits + seed against the manifest, record names and
//! shapes against the manifest parameters, scale bit patterns, word
//! counts, alignment, bounds, and section disjointness — and returns a
//! `util::error` chain on every violation (never a panic, never an
//! out-of-bounds read; pinned by `tests/artifact_roundtrip.rs`). It
//! deliberately does NOT scan plane contents (e.g. plus&minus bit
//! overlap): that would cost the O(weights) walk the format exists to
//! avoid. End-to-end content integrity is what `repro validate
//! --backend packed --artifact <tpk>` establishes by reproducing the
//! golden generation bit-exactly — wired into ci.sh.

use super::model::{PackedLayer, PackedModel};
use super::planes::{PlaneWords, TernaryPlanes};
use crate::runtime::artifacts::{Artifacts, Manifest};
use crate::util::error::{ensure, Context, Result};
use crate::util::mmap::FileBytes;
use std::path::Path;
use std::sync::Arc;

/// File magic, bytes 0..8.
pub const TPK_MAGIC: [u8; 8] = *b"PIMLLTPK";
/// Current format version.
pub const TPK_VERSION: u32 = 1;
/// Endianness canary: written little-endian, so a wrong-endian or
/// corrupted file cannot read back as this value.
pub const TPK_ENDIAN_TAG: u32 = 0x1B17_C0DE;
/// Header size in bytes.
pub const TPK_HEADER_BYTES: usize = 88;
/// Per-matrix record size in bytes.
pub const TPK_RECORD_BYTES: usize = 88;
/// Alignment of every plane section (and of the payload start).
pub const TPK_ALIGN: usize = 64;
/// Longest serializable parameter name (one byte short of the field so
/// the name is always zero-terminated inside it).
pub const TPK_NAME_MAX: usize = 31;

fn align_up(x: usize, a: usize) -> usize {
    x.div_ceil(a) * a
}

/// The matrix serialization order — identical to
/// [`PackedModel::matrices`]: per layer `wq wk wv wx w_in w_out`, then
/// `w_head`.
fn expected_names(n_layers: usize) -> Vec<String> {
    let mut names = Vec::with_capacity(n_layers * 6 + 1);
    for i in 0..n_layers {
        for m in ["wq", "wk", "wv", "wx", "w_in", "w_out"] {
            names.push(format!("layer{i}.{m}"));
        }
    }
    names.push("w_head".to_string());
    names
}

/// Serialize a lowered model to `path` in `.tpk` form. The manifest
/// supplies the geometry/seed header fields that bind the artifact to
/// the model it was packed from.
pub fn write_tpk(path: &Path, model: &PackedModel, manifest: &Manifest) -> Result<()> {
    let matrices = model.matrices();
    let n_matrices = matrices.len();
    ensure!(
        n_matrices == manifest.model.n_layers * 6 + 1,
        "write_tpk: {} matrices for a {}-layer model",
        n_matrices,
        manifest.model.n_layers
    );

    // Lay out the plane sections: 64-byte aligned, in record order,
    // plus then minus per matrix.
    let records_end = TPK_HEADER_BYTES + n_matrices * TPK_RECORD_BYTES;
    let mut cursor = align_up(records_end, TPK_ALIGN);
    let mut sections = Vec::with_capacity(n_matrices);
    for (name, m) in &matrices {
        ensure!(
            name.len() <= TPK_NAME_MAX,
            "write_tpk: name '{name}' exceeds {TPK_NAME_MAX} bytes"
        );
        let words = m.n * m.words_per_col;
        let plus_off = cursor;
        cursor = align_up(plus_off + words * 8, TPK_ALIGN);
        let minus_off = cursor;
        cursor = align_up(minus_off + words * 8, TPK_ALIGN);
        sections.push((plus_off, minus_off, words));
    }

    let mut buf = vec![0u8; cursor];
    let put = |buf: &mut [u8], off: usize, bytes: &[u8]| {
        buf[off..off + bytes.len()].copy_from_slice(bytes);
    };

    put(&mut buf, 0, &TPK_MAGIC);
    put(&mut buf, 8, &TPK_VERSION.to_le_bytes());
    put(&mut buf, 12, &TPK_ENDIAN_TAG.to_le_bytes());
    let g = &manifest.model;
    for (i, v) in [g.vocab, g.d, g.h, g.d_ff, g.n_layers, g.max_ctx]
        .iter()
        .enumerate()
    {
        put(&mut buf, 16 + i * 8, &(*v as u64).to_le_bytes());
    }
    put(&mut buf, 64, &g.eps.to_bits().to_le_bytes());
    put(&mut buf, 72, &manifest.seed.to_le_bytes());
    put(&mut buf, 80, &(n_matrices as u64).to_le_bytes());

    for (i, ((name, m), &(plus_off, minus_off, words))) in
        matrices.iter().zip(&sections).enumerate()
    {
        let r = TPK_HEADER_BYTES + i * TPK_RECORD_BYTES;
        put(&mut buf, r, name.as_bytes());
        put(&mut buf, r + 32, &(m.k as u64).to_le_bytes());
        put(&mut buf, r + 40, &(m.n as u64).to_le_bytes());
        put(&mut buf, r + 48, &(m.words_per_col as u64).to_le_bytes());
        put(&mut buf, r + 56, &m.scale.to_bits().to_le_bytes());
        // r + 60..64 reserved, already zero.
        put(&mut buf, r + 64, &(plus_off as u64).to_le_bytes());
        put(&mut buf, r + 72, &(minus_off as u64).to_le_bytes());
        put(&mut buf, r + 80, &(words as u64).to_le_bytes());
        debug_assert_eq!(m.plus_words().len(), words);
        for (w, (&pw, &mw)) in m.plus_words().iter().zip(m.minus_words()).enumerate() {
            put(&mut buf, plus_off + w * 8, &pw.to_le_bytes());
            put(&mut buf, minus_off + w * 8, &mw.to_le_bytes());
        }
    }

    std::fs::write(path, &buf).with_context(|| format!("writing {}", path.display()))?;
    Ok(())
}

/// Bounds-checked little-endian field reads — every byte the loader
/// touches goes through these, so a truncated or lying file can only
/// produce an error, never a panic or an out-of-bounds read.
fn rd_u64(buf: &[u8], off: usize, what: &str) -> Result<u64> {
    let b = buf
        .get(off..off + 8)
        .ok_or_else(|| crate::anyhow!("tpk truncated reading {what} at byte {off}"))?;
    Ok(u64::from_le_bytes(b.try_into().expect("8-byte slice")))
}

fn rd_u32(buf: &[u8], off: usize, what: &str) -> Result<u32> {
    let b = buf
        .get(off..off + 4)
        .ok_or_else(|| crate::anyhow!("tpk truncated reading {what} at byte {off}"))?;
    Ok(u32::from_le_bytes(b.try_into().expect("4-byte slice")))
}

/// Load a `.tpk` packed artifact, validating it structurally against
/// `artifacts`' manifest (the same model the engine is serving). On a
/// little-endian host with a successful mmap the returned planes are
/// zero-copy windows into the mapping; otherwise they are owned words
/// decoded from the same bytes. Neither path re-packs any matrix.
pub fn load_tpk(path: &Path, artifacts: &Artifacts) -> Result<PackedModel> {
    let fb = FileBytes::open(path)
        .with_context(|| format!("opening packed artifact {}", path.display()))?;
    let buf = fb.bytes();
    let ctx = || format!("loading packed artifact {}", path.display());

    (|| -> Result<PackedModel> {
        ensure!(
            buf.len() >= TPK_HEADER_BYTES,
            "file is {} bytes, smaller than the {TPK_HEADER_BYTES}-byte header",
            buf.len()
        );
        ensure!(
            buf[..8] == TPK_MAGIC,
            "bad magic {:02x?} (expected {:02x?} — not a .tpk file?)",
            &buf[..8.min(buf.len())],
            TPK_MAGIC
        );
        let version = rd_u32(buf, 8, "version")?;
        ensure!(
            version == TPK_VERSION,
            "format version {version}, this build reads only {TPK_VERSION} \
             (repack with `repro pack`)"
        );
        let endian = rd_u32(buf, 12, "endian tag")?;
        ensure!(
            endian == TPK_ENDIAN_TAG,
            "endian tag {endian:#x} != {TPK_ENDIAN_TAG:#x} — corrupt or \
             wrong-endian file"
        );

        let m = &artifacts.manifest.model;
        let geom = [
            ("vocab", m.vocab),
            ("d", m.d),
            ("h", m.h),
            ("d_ff", m.d_ff),
            ("n_layers", m.n_layers),
            ("max_ctx", m.max_ctx),
        ];
        for (i, (field, expect)) in geom.iter().enumerate() {
            let got = rd_u64(buf, 16 + i * 8, field)?;
            ensure!(
                got == *expect as u64,
                "model geometry mismatch: {field} = {got} in file, {expect} in manifest"
            );
        }
        let eps_bits = rd_u64(buf, 64, "eps")?;
        ensure!(
            eps_bits == m.eps.to_bits(),
            "model eps bit pattern mismatch ({:e} in file, {:e} in manifest)",
            f64::from_bits(eps_bits),
            m.eps
        );
        let seed = rd_u64(buf, 72, "seed")?;
        ensure!(
            seed == artifacts.manifest.seed,
            "artifact seed {seed} != manifest seed {} — packed from a \
             different model instance",
            artifacts.manifest.seed
        );
        let n_matrices = rd_u64(buf, 80, "n_matrices")? as usize;
        let expected = m.n_layers * 6 + 1;
        ensure!(
            n_matrices == expected,
            "{n_matrices} matrices in file, {expected} expected for \
             {} layers",
            m.n_layers
        );

        let records_end = TPK_HEADER_BYTES
            .checked_add(
                n_matrices
                    .checked_mul(TPK_RECORD_BYTES)
                    .ok_or_else(|| crate::anyhow!("record table size overflows"))?,
            )
            .ok_or_else(|| crate::anyhow!("record table size overflows"))?;
        ensure!(
            buf.len() >= records_end,
            "file is {} bytes, record table needs {records_end}",
            buf.len()
        );

        let names = expected_names(m.n_layers);
        let file_len = buf.len() as u64;
        let mut planes = Vec::with_capacity(n_matrices);
        let mut spans: Vec<(u64, u64)> = Vec::with_capacity(n_matrices * 2);

        for (i, name) in names.iter().enumerate() {
            let r = TPK_HEADER_BYTES + i * TPK_RECORD_BYTES;
            let name_bytes = &buf[r..r + 32];
            let end = name_bytes
                .iter()
                .position(|&b| b == 0)
                .unwrap_or(name_bytes.len());
            let got_name = std::str::from_utf8(&name_bytes[..end])
                .map_err(|_| crate::anyhow!("record {i}: name is not UTF-8"))?;
            ensure!(
                got_name == name,
                "record {i}: matrix '{got_name}' where '{name}' was expected \
                 (records must follow lowering order)"
            );

            let k = rd_u64(buf, r + 32, "k")? as usize;
            let n = rd_u64(buf, r + 40, "n")? as usize;
            let words_per_col = rd_u64(buf, r + 48, "words_per_col")? as usize;
            let scale_bits = rd_u32(buf, r + 56, "scale")?;
            let plus_off = rd_u64(buf, r + 64, "plus offset")?;
            let minus_off = rd_u64(buf, r + 72, "minus offset")?;
            let words = rd_u64(buf, r + 80, "words")? as usize;

            let p = artifacts
                .manifest
                .params
                .iter()
                .find(|p| p.name == *name)
                .ok_or_else(|| crate::anyhow!("manifest missing parameter '{name}'"))?;
            ensure!(
                p.shape.len() == 2 && p.shape[0] == k && p.shape[1] == n,
                "'{name}': file shape {k}x{n} != manifest shape {:?}",
                p.shape
            );
            ensure!(k > 0 && n > 0, "'{name}': degenerate shape {k}x{n}");
            ensure!(
                k <= super::pack::MAX_EXACT_K,
                "'{name}': k={k} exceeds the f32-exact window"
            );
            ensure!(
                words_per_col == k.div_ceil(64),
                "'{name}': words_per_col {words_per_col} != ceil({k}/64)"
            );
            let expect_words = n
                .checked_mul(words_per_col)
                .ok_or_else(|| crate::anyhow!("'{name}': word count overflows"))?;
            ensure!(
                words == expect_words,
                "'{name}': {words} words per plane, header shape implies {expect_words}"
            );
            let scale = f32::from_bits(scale_bits);
            ensure!(
                scale.is_finite() && scale > 0.0,
                "'{name}': bad weight scale {scale}"
            );
            let scale_param = artifacts
                .manifest
                .params
                .iter()
                .find(|s| s.name == format!("{name}_scale"))
                .ok_or_else(|| crate::anyhow!("manifest missing '{name}_scale'"))?;
            let manifest_scale = artifacts.param_data(scale_param)[0];
            ensure!(
                scale_bits == manifest_scale.to_bits(),
                "'{name}': scale {scale} != manifest scale {manifest_scale}"
            );

            let bytes_per_plane = (words as u64)
                .checked_mul(8)
                .ok_or_else(|| crate::anyhow!("'{name}': plane size overflows"))?;
            for (plane, off) in [("plus", plus_off), ("minus", minus_off)] {
                ensure!(
                    off % TPK_ALIGN as u64 == 0,
                    "'{name}': {plane} section at byte {off} is not \
                     {TPK_ALIGN}-byte aligned"
                );
                ensure!(
                    off >= records_end as u64,
                    "'{name}': {plane} section at byte {off} overlaps the \
                     header/record region (ends at {records_end})"
                );
                let end = off
                    .checked_add(bytes_per_plane)
                    .ok_or_else(|| crate::anyhow!("'{name}': {plane} section end overflows"))?;
                ensure!(
                    end <= file_len,
                    "'{name}': {plane} section [{off}, {end}) runs past the \
                     {file_len}-byte file"
                );
                spans.push((off, end));
            }
            planes.push((k, n, words_per_col, scale, plus_off, minus_off, words));
        }

        // No two plane sections may overlap: a section aliasing another
        // (or a record lying about its extent) must be rejected, not
        // silently served as weights.
        let mut sorted = spans.clone();
        sorted.sort_unstable();
        for pair in sorted.windows(2) {
            ensure!(
                pair[0].1 <= pair[1].0,
                "plane sections [{}, {}) and [{}, {}) overlap",
                pair[0].0,
                pair[0].1,
                pair[1].0,
                pair[1].1
            );
        }

        // Structure is fully validated: materialize the planes.
        // Zero-copy needs both an actual mapping AND a little-endian
        // host (the file stores little-endian words).
        let mapping = if cfg!(target_endian = "little") {
            fb.mapping()
        } else {
            None
        };
        let make_plane = |off: u64, words: usize| -> PlaneWords {
            let off = off as usize;
            match mapping {
                Some(map) => PlaneWords::Mapped {
                    map: Arc::clone(map),
                    word_off: off / 8,
                    words,
                },
                None => PlaneWords::Owned(
                    buf[off..off + words * 8]
                        .chunks_exact(8)
                        .map(|c| u64::from_le_bytes(c.try_into().expect("8-byte chunk")))
                        .collect(),
                ),
            }
        };

        let mut matrices: Vec<TernaryPlanes> = planes
            .into_iter()
            .map(|(k, n, words_per_col, scale, plus_off, minus_off, words)| TernaryPlanes {
                k,
                n,
                scale,
                words_per_col,
                plus: make_plane(plus_off, words),
                minus: make_plane(minus_off, words),
            })
            .collect();

        let w_head = matrices.pop().expect("n_matrices >= 1 checked above");
        let mut layers = Vec::with_capacity(m.n_layers);
        let mut it = matrices.into_iter();
        for _ in 0..m.n_layers {
            layers.push(PackedLayer {
                wq: it.next().expect("record count checked"),
                wk: it.next().expect("record count checked"),
                wv: it.next().expect("record count checked"),
                wx: it.next().expect("record count checked"),
                w_in: it.next().expect("record count checked"),
                w_out: it.next().expect("record count checked"),
            });
        }
        Ok(PackedModel { layers, w_head })
    })()
    .with_context(ctx)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("pimllm-tpk-{}-{name}.tpk", std::process::id()))
    }

    #[test]
    fn round_trip_is_bit_identical_and_zero_copy() {
        let a = Artifacts::synthetic(11).unwrap();
        let lowered = PackedModel::lower(&a).unwrap();
        let p = tmp("roundtrip");
        write_tpk(&p, &lowered, &a.manifest).unwrap();
        let loaded = load_tpk(&p, &a).unwrap();
        assert_eq!(loaded.matrices().len(), lowered.matrices().len());
        for ((ln, lm), (rn, rm)) in lowered.matrices().iter().zip(loaded.matrices().iter()) {
            assert_eq!(ln, rn);
            assert_eq!(lm, rm, "'{ln}' planes must round-trip bit-for-bit");
        }
        #[cfg(all(unix, target_pointer_width = "64"))]
        if cfg!(target_endian = "little") {
            assert!(
                loaded.w_head.is_mapped(),
                "little-endian 64-bit unix load must be zero-copy"
            );
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn sections_are_aligned_and_header_constants_hold() {
        let a = Artifacts::synthetic(12).unwrap();
        let lowered = PackedModel::lower(&a).unwrap();
        let p = tmp("layout");
        write_tpk(&p, &lowered, &a.manifest).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        assert_eq!(&bytes[..8], &TPK_MAGIC);
        let n_matrices = u64::from_le_bytes(bytes[80..88].try_into().unwrap()) as usize;
        assert_eq!(n_matrices, a.manifest.model.n_layers * 6 + 1);
        for i in 0..n_matrices {
            let r = TPK_HEADER_BYTES + i * TPK_RECORD_BYTES;
            for field in [64, 72] {
                let off = u64::from_le_bytes(bytes[r + field..r + field + 8].try_into().unwrap());
                assert_eq!(off % TPK_ALIGN as u64, 0, "record {i} field {field}");
            }
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn wrong_model_rejected() {
        // A .tpk packed from seed 13 must refuse to load against the
        // seed-14 artifacts: same geometry, different weights/scales.
        let a = Artifacts::synthetic(13).unwrap();
        let lowered = PackedModel::lower(&a).unwrap();
        let p = tmp("wrongmodel");
        write_tpk(&p, &lowered, &a.manifest).unwrap();
        let other = Artifacts::synthetic(14).unwrap();
        let err = load_tpk(&p, &other);
        assert!(err.is_err());
        std::fs::remove_file(&p).ok();
    }
}
