//! Packed ternary weight representation + popcount MVM kernels — the
//! codebase's first representation-level subsystem: instead of another
//! consumer of dense f32 weights, this is the compile/pack step that
//! lowers {-1, 0, +1} projection matrices into the 2-bit hardware-shaped
//! storage the paper's PIM banks actually hold, and the integer kernels
//! that execute sign-accumulate MVMs over it.
//!
//! ## Bitplane layout
//!
//! Each k x n ternary matrix becomes two u64 bitplanes (one marking +1
//! weights, one marking -1), stored column-major in 64-row words so one
//! output column's masks are contiguous and the contraction dimension
//! advances 64 rows per word:
//!
//! ```text
//! dense (row-major f32, 4 bytes/weight)      packed (2 bits/weight)
//!
//!         col0 col1 .. coln                  plus plane        minus plane
//! row0  [  +1   0  ..  -1 ]                  col0: w0 w1 ..    col0: w0 w1 ..
//! row1  [   0  -1  ..  +1 ]          =>      col1: w0 w1 ..    col1: w0 w1 ..
//!  ...                                        ...               ...
//! row63 [  -1  +1  ..   0 ]                  (w0 bit i = row i of this col)
//! row64 [  +1   0  ..   0 ]                  (w1 bit i = row 64+i, ...)
//! ```
//!
//! `weight = (plus bit) - (minus bit)`; both bits set is illegal and
//! rejected by [`pack`]. Rows past `k` in the last word are zero in both
//! planes. A 512 x 512 f32 matrix (1 MiB) packs into 64 KiB — 16x — and
//! zero weights (a measured ~31% of ternary entries, see
//! [`crate::workload::EXPECTED_TERNARY_SPARSITY`]) simply have no bit
//! set in either plane, so the kernels skip them for free.
//!
//! ## Why the packed kernels are bit-for-bit exact
//!
//! The dense reference kernel performs integer arithmetic in f32
//! carriers: int8 activations times {-1,0,+1} weights, accumulated in
//! `kk`-ascending order. Inside the f32 exact-integer window (every
//! partial sum below 2^24, i.e. `k * 127 < 2^24` — enforced at pack
//! time via [`pack::MAX_EXACT_K`]) none of those f32 additions can
//! round, so its accumulator IS the exact integer sum. The popcount
//! kernels ([`bitlinear_packed`], [`bitlinear_packed_batch`]) compute
//! the same sum in i32 (exact by construction, in any order), convert
//! it to f32 (exact below 2^24), and apply the identical final rescale
//! `* (w_scale / x_scale)` with identical operands — hence identical
//! output bits, asserted across backends by
//! `tests/packed_equivalence.rs`. Full derivation in
//! [`kernels`]'s module docs.
//!
//! * [`planes`]  — [`TernaryPlanes`] storage format (owned or mmap'd
//!   plane words behind one `&[u64]` view).
//! * [`pack`]    — dense ↔ packed conversion + round-trip validation.
//! * [`kernels`] — popcount MVM kernels (single + batched, striped),
//!   unrolled 4-word tiles over caller-owned [`PackedScratch`].
//! * [`model`]   — [`PackedModel`]: whole-artifacts lowering at load.
//! * [`artifact`]— the versioned `.tpk` on-disk packed format:
//!   serialize a lowered model once, mmap it back zero-copy at every
//!   engine start.

pub mod artifact;
pub mod kernels;
pub mod model;
pub mod pack;
pub mod planes;

pub use artifact::{load_tpk, write_tpk};
pub use kernels::{
    bitlinear_packed, bitlinear_packed_batch, bitlinear_packed_batch_with, bitlinear_packed_into,
    PackedScratch,
};
pub use model::{PackedLayer, PackedModel};
pub use pack::{pack, pack_verified, unpack};
pub use planes::TernaryPlanes;
