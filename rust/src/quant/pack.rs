//! `pack`/`unpack` between dense row-major f32 ternary matrices and
//! [`TernaryPlanes`], with round-trip validation.

use super::planes::{PlaneWords, TernaryPlanes};
use crate::util::error::{ensure, Result};

/// Largest contraction dimension for which the dense f32 reference
/// kernel is still exact-integer arithmetic (every partial sum of k
/// int8*ternary products stays below 2^24, the f32 exact-integer
/// window): `k * 127 < 2^24`. The packed kernels accumulate in i32 and
/// are exact far beyond this, but bit-for-bit identity WITH the f32
/// reference is only guaranteed inside the window, so `pack` enforces
/// it. Every model in this repo (d_ff <= 16384) is orders of magnitude
/// inside the bound.
pub const MAX_EXACT_K: usize = (1 << 24) / 127;

/// Pack a dense row-major ternary matrix `w` (`k` rows x `n` columns,
/// every entry in {-1.0, 0.0, +1.0}) into two column-major u64
/// bitplanes. Fails on non-ternary entries (including NaN) and on
/// degenerate/oversized shapes; padding bits beyond row `k` are zero in
/// both planes.
pub fn pack(w: &[f32], k: usize, n: usize, scale: f32) -> Result<TernaryPlanes> {
    ensure!(k > 0 && n > 0, "pack: degenerate shape {k}x{n}");
    ensure!(
        k <= MAX_EXACT_K,
        "pack: k={k} exceeds the f32-exact window (max {MAX_EXACT_K}); \
         the packed kernel could no longer be bit-identical to the dense \
         reference"
    );
    ensure!(
        w.len() == k * n,
        "pack: {} weights for a {k}x{n} matrix",
        w.len()
    );
    ensure!(
        scale.is_finite() && scale > 0.0,
        "pack: non-positive weight scale {scale}"
    );
    let words_per_col = k.div_ceil(64);
    let mut plus = vec![0u64; n * words_per_col];
    let mut minus = vec![0u64; n * words_per_col];
    for kk in 0..k {
        let (wi, lane) = (kk / 64, kk % 64);
        let row = &w[kk * n..(kk + 1) * n];
        for (j, &wv) in row.iter().enumerate() {
            let word = j * words_per_col + wi;
            if wv == 1.0 {
                plus[word] |= 1u64 << lane;
            } else if wv == -1.0 {
                minus[word] |= 1u64 << lane;
            } else {
                ensure!(
                    wv == 0.0,
                    "pack: non-ternary weight {wv} at row {kk}, col {j}"
                );
            }
        }
    }
    Ok(TernaryPlanes {
        k,
        n,
        scale,
        words_per_col,
        plus: PlaneWords::Owned(plus),
        minus: PlaneWords::Owned(minus),
    })
}

/// Unpack back to the dense row-major f32 matrix (`k * n` entries in
/// {-1.0, 0.0, +1.0}).
pub fn unpack(planes: &TernaryPlanes) -> Vec<f32> {
    let mut w = vec![0.0f32; planes.k * planes.n];
    for j in 0..planes.n {
        let plus = planes.plus_col(j);
        let minus = planes.minus_col(j);
        for (wi, (&pw, &mw)) in plus.iter().zip(minus).enumerate() {
            let mut bits = pw | mw;
            while bits != 0 {
                let lane = bits.trailing_zeros() as usize;
                bits &= bits - 1;
                let kk = wi * 64 + lane;
                w[kk * planes.n + j] = if (pw >> lane) & 1 == 1 { 1.0 } else { -1.0 };
            }
        }
    }
    w
}

/// [`pack`] followed by an [`unpack`] round-trip check against the f32
/// source — the validated entry point the model lowering uses, so a
/// packing bug can never silently corrupt a backend.
pub fn pack_verified(w: &[f32], k: usize, n: usize, scale: f32) -> Result<TernaryPlanes> {
    let planes = pack(w, k, n, scale)?;
    ensure!(
        unpack(&planes) == w,
        "pack round-trip mismatch on a {k}x{n} matrix"
    );
    Ok(planes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_ternary(rng: &mut Rng, numel: usize) -> Vec<f32> {
        // Rng::range is INCLUSIVE: [0, 2] - 1 = {-1, 0, 1}.
        (0..numel).map(|_| rng.range(0, 2) as f32 - 1.0).collect()
    }

    #[test]
    fn round_trips_adversarial_shapes() {
        // k not a multiple of 64, n=1, k=1, word-boundary straddles.
        let mut rng = Rng::new(41);
        for (k, n) in [
            (1usize, 1usize),
            (1, 7),
            (7, 1),
            (63, 3),
            (64, 3),
            (65, 3),
            (130, 5),
            (128, 1),
            (200, 17),
        ] {
            let w = random_ternary(&mut rng, k * n);
            let planes = pack_verified(&w, k, n, 0.5).unwrap();
            assert_eq!(planes.words_per_col, k.div_ceil(64), "{k}x{n}");
            assert_eq!(unpack(&planes), w, "{k}x{n}");
            // Element accessor agrees with the dense source.
            for kk in 0..k {
                for j in 0..n {
                    assert_eq!(planes.weight(kk, j), w[kk * n + j], "{k}x{n} @ ({kk},{j})");
                }
            }
        }
    }

    #[test]
    fn padding_lanes_are_zero_and_masks_disjoint() {
        let mut rng = Rng::new(42);
        for (k, n) in [(1usize, 4usize), (65, 2), (100, 3)] {
            let w = random_ternary(&mut rng, k * n);
            let planes = pack(&w, k, n, 1.0).unwrap();
            let pad_mask = if k % 64 == 0 {
                0u64
            } else {
                !0u64 << (k % 64)
            };
            for j in 0..n {
                let (plus, minus) = (planes.plus_col(j), planes.minus_col(j));
                let last = planes.words_per_col - 1;
                assert_eq!(plus[last] & pad_mask, 0, "{k}x{n} col {j} plus padding");
                assert_eq!(minus[last] & pad_mask, 0, "{k}x{n} col {j} minus padding");
                for (&pw, &mw) in plus.iter().zip(minus) {
                    assert_eq!(pw & mw, 0, "{k}x{n} col {j}: +1 and -1 bits overlap");
                }
            }
        }
    }

    #[test]
    fn nnz_and_sparsity_count_exactly() {
        // 3x2 with a known census: two +1, one -1, three 0.
        let w = vec![1.0, 0.0, -1.0, 0.0, 0.0, 1.0];
        let planes = pack(&w, 3, 2, 1.0).unwrap();
        assert_eq!(planes.nnz(), (2, 1));
        assert!((planes.sparsity() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn packed_bytes_are_16x_smaller_at_word_multiples() {
        let w = vec![0.0f32; 128 * 32];
        let planes = pack(&w, 128, 32, 1.0).unwrap();
        assert_eq!(planes.dense_f32_bytes(), 128 * 32 * 4);
        assert_eq!(planes.packed_bytes(), 2 * 32 * 2 * 8); // 2 words/col/plane
        assert_eq!(planes.dense_f32_bytes() / planes.packed_bytes(), 16);
    }

    #[test]
    fn rejects_bad_inputs() {
        assert!(pack(&[0.5], 1, 1, 1.0).is_err()); // non-ternary
        assert!(pack(&[f32::NAN], 1, 1, 1.0).is_err());
        assert!(pack(&[1.0], 1, 1, 0.0).is_err()); // bad scale
        assert!(pack(&[1.0], 1, 1, f32::NAN).is_err());
        assert!(pack(&[1.0, 0.0], 1, 1, 1.0).is_err()); // wrong numel
        assert!(pack(&[], 0, 1, 1.0).is_err()); // degenerate shape
        assert!(pack(&[], 1, 0, 1.0).is_err());
    }

    #[test]
    fn exact_window_guard_enforced() {
        // The k guard fires before the data-length check, so no
        // >132k-row matrix needs to be materialized to exercise it.
        let k = MAX_EXACT_K + 1;
        let r = pack(&[0.0], k, 1, 1.0);
        assert!(r.is_err());
        assert!(pack(&[0.0], 1, 1, 1.0).is_ok());
    }
}
