//! [`TernaryPlanes`] — the packed storage format for one ternary weight
//! matrix: two u64 bitplanes (plus-mask, minus-mask) in column-major
//! 64-row words, plus the per-matrix dequantization scale.
//!
//! Since the `.tpk` artifact format ([`crate::quant::artifact`]) the
//! plane words can live either in owned `Vec<u64>`s (built by
//! [`crate::quant::pack::pack`]) or directly inside a read-only file
//! mapping ([`PlaneWords::Mapped`]) — zero-copy engine start. Both
//! back the same `&[u64]` view; every kernel and accessor goes through
//! [`PlaneWords`]'s `Deref`, so the two backings are interchangeable
//! and compare equal word-for-word.
//!
//! See the module docs of [`crate::quant`] for the layout diagram and
//! the exactness argument.

use std::sync::Arc;

/// The word storage behind one bitplane: owned heap words, or a window
/// into a shared read-only file mapping (the `.tpk` zero-copy path).
pub(crate) enum PlaneWords {
    /// Heap-allocated words (the `pack` path, and the buffered or
    /// big-endian artifact-load fallback).
    Owned(Vec<u64>),
    /// `words` u64s starting `word_off * 8` bytes into `map`. The
    /// artifact loader only constructs this when the section offset is
    /// 64-byte aligned within a page-aligned mapping (so the `u64`
    /// reads are aligned) and the file is little-endian on a
    /// little-endian host (so the bytes ARE the in-memory words).
    Mapped {
        map: Arc<crate::util::mmap::Mapping>,
        word_off: usize,
        words: usize,
    },
}

impl std::ops::Deref for PlaneWords {
    type Target = [u64];
    #[inline]
    fn deref(&self) -> &[u64] {
        match self {
            PlaneWords::Owned(v) => v,
            PlaneWords::Mapped {
                map,
                word_off,
                words,
            } => {
                // SAFETY: the loader validated `word_off * 8 + words * 8
                // <= map.len()` and 8-byte alignment of both the mapping
                // base (page-aligned by mmap) and the byte offset
                // (64-byte aligned by the format) before constructing
                // this variant; the map is immutable PROT_READ memory
                // kept alive by the Arc.
                unsafe {
                    std::slice::from_raw_parts(map.as_ptr().add(word_off * 8) as *const u64, *words)
                }
            }
        }
    }
}

impl Clone for PlaneWords {
    fn clone(&self) -> Self {
        match self {
            PlaneWords::Owned(v) => PlaneWords::Owned(v.clone()),
            PlaneWords::Mapped {
                map,
                word_off,
                words,
            } => PlaneWords::Mapped {
                map: Arc::clone(map),
                word_off: *word_off,
                words: *words,
            },
        }
    }
}

impl PartialEq for PlaneWords {
    fn eq(&self, other: &Self) -> bool {
        // Content equality regardless of backing: a mapped plane equals
        // the owned plane it was serialized from.
        self[..] == other[..]
    }
}

impl std::fmt::Debug for PlaneWords {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PlaneWords::Owned(v) => write!(f, "PlaneWords::Owned({} words)", v.len()),
            PlaneWords::Mapped { word_off, words, .. } => {
                write!(f, "PlaneWords::Mapped({words} words @ word {word_off})")
            }
        }
    }
}

/// One k x n ternary matrix packed into two bitplanes.
///
/// Layout: column-major over 64-row words. Column `j` owns the word
/// range `[j * words_per_col, (j + 1) * words_per_col)` in each plane;
/// word `wi` of a column covers rows `[wi * 64, wi * 64 + 64)`, row
/// `kk` mapping to bit `kk % 64`. Bits for rows >= `k` (the padding
/// lanes of the last word) are ZERO in both planes — the kernels rely
/// on that, so [`crate::quant::pack::pack`] guarantees it and the
/// round-trip tests pin it.
///
/// Row `kk` of column `j` encodes weight `w[kk][j]`:
///
/// | plus bit | minus bit | weight |
/// |---|---|---|
/// | 0 | 0 |  0 |
/// | 1 | 0 | +1 |
/// | 0 | 1 | -1 |
/// | 1 | 1 |  (illegal — rejected by `pack`) |
#[derive(Debug, Clone, PartialEq)]
pub struct TernaryPlanes {
    /// Rows (the input/contraction dimension: `x.len()`).
    pub k: usize,
    /// Columns (the output dimension).
    pub n: usize,
    /// Dequantization scale of the matrix (the `w_scale` the dense
    /// kernel folds into its final rescale).
    pub scale: f32,
    /// Words per column: `k.div_ceil(64)`.
    pub words_per_col: usize,
    /// +1 mask, `n * words_per_col` words, column-major.
    pub(crate) plus: PlaneWords,
    /// -1 mask, same layout.
    pub(crate) minus: PlaneWords,
}

impl TernaryPlanes {
    /// The +1 mask words of column `j`.
    #[inline]
    pub fn plus_col(&self, j: usize) -> &[u64] {
        &self.plus[j * self.words_per_col..(j + 1) * self.words_per_col]
    }

    /// The -1 mask words of column `j`.
    #[inline]
    pub fn minus_col(&self, j: usize) -> &[u64] {
        &self.minus[j * self.words_per_col..(j + 1) * self.words_per_col]
    }

    /// All +1 mask words (column-major), whichever backing holds them.
    #[inline]
    pub fn plus_words(&self) -> &[u64] {
        &self.plus
    }

    /// All -1 mask words (column-major), whichever backing holds them.
    #[inline]
    pub fn minus_words(&self) -> &[u64] {
        &self.minus
    }

    /// True when the plane words live in a file mapping rather than on
    /// the heap (the `.tpk` zero-copy path) — observable evidence that
    /// artifact load did not re-pack or copy.
    pub fn is_mapped(&self) -> bool {
        matches!(self.plus, PlaneWords::Mapped { .. })
            && matches!(self.minus, PlaneWords::Mapped { .. })
    }

    /// Weight at row `kk`, column `j`, as the ternary f32 it unpacks to.
    pub fn weight(&self, kk: usize, j: usize) -> f32 {
        assert!(kk < self.k && j < self.n, "weight({kk}, {j}) out of range");
        let (wi, lane) = (kk / 64, kk % 64);
        let bit = 1u64 << lane;
        if self.plus_col(j)[wi] & bit != 0 {
            1.0
        } else if self.minus_col(j)[wi] & bit != 0 {
            -1.0
        } else {
            0.0
        }
    }

    /// Non-zero counts: (number of +1 weights, number of -1 weights).
    pub fn nnz(&self) -> (u64, u64) {
        let pop = |words: &[u64]| words.iter().map(|w| w.count_ones() as u64).sum();
        (pop(&self.plus), pop(&self.minus))
    }

    /// Fraction of exactly-zero weights (the measured ternary sparsity
    /// of this matrix).
    pub fn sparsity(&self) -> f64 {
        let (p, m) = self.nnz();
        let total = (self.k * self.n) as u64;
        if total == 0 {
            0.0
        } else {
            1.0 - (p + m) as f64 / total as f64
        }
    }

    /// Bytes this packed representation occupies (both planes; 2 bits
    /// per weight plus last-word padding).
    pub fn packed_bytes(&self) -> usize {
        (self.plus.len() + self.minus.len()) * std::mem::size_of::<u64>()
    }

    /// Bytes the dense f32 source occupies (4 bytes per weight).
    pub fn dense_f32_bytes(&self) -> usize {
        self.k * self.n * std::mem::size_of::<f32>()
    }
}
