//! `Artifacts` → [`PackedModel`] lowering: the compile/pack step that
//! turns every dense ternary projection matrix of a loaded model into
//! [`TernaryPlanes`], once, at engine load — the software analogue of
//! programming the PIM crossbars before serving traffic (HPIM and LEAP
//! structure their simulators around the same pack-then-execute split).

use super::pack::pack_verified;
use super::planes::TernaryPlanes;
use crate::runtime::artifacts::Artifacts;
use crate::util::error::{anyhow, ensure, Context, Result};

/// The six packed projection matrices of one decoder layer.
pub struct PackedLayer {
    pub wq: TernaryPlanes,
    pub wk: TernaryPlanes,
    pub wv: TernaryPlanes,
    pub wx: TernaryPlanes,
    pub w_in: TernaryPlanes,
    pub w_out: TernaryPlanes,
}

/// Every ternary weight matrix of a model in packed bitplane form (the
/// seventh matrix kind, `w_head`, is model-level). Non-ternary
/// parameters (embedding, norm gammas, scales) stay in the artifacts —
/// only projection weights have a 2-bit representation.
pub struct PackedModel {
    pub layers: Vec<PackedLayer>,
    pub w_head: TernaryPlanes,
}

impl PackedModel {
    /// Lower a loaded model. Each matrix is packed with a full
    /// `unpack == source` round-trip check, so a model whose projection
    /// weights are not exactly ternary (or a packing bug) fails loudly
    /// at load time, never as wrong logits.
    pub fn lower(artifacts: &Artifacts) -> Result<Self> {
        let matrix = |name: &str| -> Result<TernaryPlanes> {
            let p = artifacts
                .manifest
                .params
                .iter()
                .find(|p| p.name == name)
                .ok_or_else(|| anyhow!("manifest missing parameter '{name}'"))?;
            ensure!(
                p.shape.len() == 2,
                "parameter '{name}' is not a matrix (shape {:?})",
                p.shape
            );
            let scale_name = format!("{name}_scale");
            let s = artifacts
                .manifest
                .params
                .iter()
                .find(|p| p.name == scale_name)
                .ok_or_else(|| anyhow!("manifest missing parameter '{scale_name}'"))?;
            ensure!(s.numel == 1, "parameter '{scale_name}' is not a scalar");
            let scale = artifacts.param_data(s)[0];
            pack_verified(artifacts.param_data(p), p.shape[0], p.shape[1], scale)
                .with_context(|| format!("packing '{name}'"))
        };
        let mut layers = Vec::with_capacity(artifacts.manifest.model.n_layers);
        for layer in 0..artifacts.manifest.model.n_layers {
            let l = |name: &str| format!("layer{layer}.{name}");
            layers.push(PackedLayer {
                wq: matrix(&l("wq"))?,
                wk: matrix(&l("wk"))?,
                wv: matrix(&l("wv"))?,
                wx: matrix(&l("wx"))?,
                w_in: matrix(&l("w_in"))?,
                w_out: matrix(&l("w_out"))?,
            });
        }
        let w_head = matrix("w_head")?;
        // The popcount kernels' bit-for-bit contract with the dense
        // reference assumes finite activations: the dense path would
        // propagate a NaN loudly, while the `x_q as i32` lift in
        // `quantize_to_planes` saturates NaN to 0 and would diverge
        // silently. Finite parameters guarantee finite activations
        // (every downstream op — rms_norm, gelu, stable softmax, the
        // integer matmuls — is NaN/Inf-free on finite input), so a
        // corrupt tensor ANYWHERE in the model (gammas and embedding
        // included, which the per-matrix round trips above never see)
        // is rejected here, at load.
        for p in &artifacts.manifest.params {
            ensure!(
                artifacts.param_data(p).iter().all(|v| v.is_finite()),
                "parameter '{}' contains non-finite values — the packed backend \
                 requires finite tensors",
                p.name
            );
        }
        Ok(Self { layers, w_head })
    }

    /// Every packed matrix with its manifest name, layer order then head.
    pub fn matrices(&self) -> Vec<(String, &TernaryPlanes)> {
        let mut out = Vec::with_capacity(self.layers.len() * 6 + 1);
        for (i, l) in self.layers.iter().enumerate() {
            for (name, m) in [
                ("wq", &l.wq),
                ("wk", &l.wk),
                ("wv", &l.wv),
                ("wx", &l.wx),
                ("w_in", &l.w_in),
                ("w_out", &l.w_out),
            ] {
                out.push((format!("layer{i}.{name}"), m));
            }
        }
        out.push(("w_head".to_string(), &self.w_head));
        out
    }

    /// Total bytes of the packed representation (all bitplanes).
    pub fn packed_bytes(&self) -> usize {
        self.matrices().iter().map(|(_, m)| m.packed_bytes()).sum()
    }

    /// Total bytes of the dense f32 source matrices.
    pub fn dense_f32_bytes(&self) -> usize {
        self.matrices()
            .iter()
            .map(|(_, m)| m.dense_f32_bytes())
            .sum()
    }

    /// Measured zero fraction over ALL ternary weights of the model —
    /// the plane-popcount census, aggregated through the same
    /// [`crate::workload::SparsityStats`] the dense-side censuses use.
    pub fn sparsity(&self) -> f64 {
        let mut census = crate::workload::SparsityStats { zeros: 0, total: 0 };
        for (_, m) in self.matrices() {
            let (p, mi) = m.nnz();
            let total = (m.k * m.n) as u64;
            census.merge(crate::workload::SparsityStats {
                zeros: total - p - mi,
                total,
            });
        }
        census.fraction()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lowers_synthetic_model_with_expected_geometry() {
        let a = Artifacts::synthetic(5).unwrap();
        let m = PackedModel::lower(&a).unwrap();
        let info = &a.manifest.model;
        assert_eq!(m.layers.len(), info.n_layers);
        for l in &m.layers {
            assert_eq!((l.wq.k, l.wq.n), (info.d, info.d));
            assert_eq!((l.w_in.k, l.w_in.n), (info.d, info.d_ff));
            assert_eq!((l.w_out.k, l.w_out.n), (info.d_ff, info.d));
        }
        assert_eq!((m.w_head.k, m.w_head.n), (info.d, info.vocab));
        assert_eq!(m.matrices().len(), info.n_layers * 6 + 1);
        // Unpacked planes reproduce the dense source exactly.
        let wq = a
            .manifest
            .params
            .iter()
            .find(|p| p.name == "layer0.wq")
            .unwrap();
        assert_eq!(
            crate::quant::pack::unpack(&m.layers[0].wq),
            a.param_data(wq)
        );
    }

    #[test]
    fn size_and_sparsity_accounting() {
        let a = Artifacts::synthetic(6).unwrap();
        let m = PackedModel::lower(&a).unwrap();
        // d=32 < 64 rows: one word per column per plane, so packed is
        // 16 bytes per column-plane-pair vs 128 f32 bytes for 32 rows.
        assert!(m.packed_bytes() > 0);
        assert!(m.dense_f32_bytes() > m.packed_bytes());
        let s = m.sparsity();
        // BitNet-b1.58 ternary quantization of Gaussian weights zeroes
        // ~31% of entries (workload::EXPECTED_TERNARY_SPARSITY); allow
        // a generous band for the tiny model's sample noise.
        assert!(s > 0.15 && s < 0.50, "sparsity {s}");
    }

    #[test]
    fn non_ternary_weights_rejected_at_lowering() {
        let mut a = Artifacts::synthetic(7).unwrap();
        let p = a
            .manifest
            .params
            .iter()
            .find(|p| p.name == "layer0.wv")
            .unwrap()
            .clone();
        a.weights[p.offset + 3] = 0.5;
        assert!(PackedModel::lower(&a).is_err());
    }

    #[test]
    fn non_finite_parameters_rejected_at_lowering() {
        // A NaN in a NON-matrix tensor (gamma) must fail the load: the
        // reference backend would propagate it loudly, the popcount
        // lift would saturate it to 0 and diverge silently.
        let mut a = Artifacts::synthetic(9).unwrap();
        let p = a
            .manifest
            .params
            .iter()
            .find(|p| p.name == "layer0.ln1_gamma")
            .unwrap()
            .clone();
        a.weights[p.offset] = f32::NAN;
        assert!(PackedModel::lower(&a).is_err());
        let mut b = Artifacts::synthetic(9).unwrap();
        let e = b
            .manifest
            .params
            .iter()
            .find(|p| p.name == "embedding")
            .unwrap()
            .clone();
        b.weights[e.offset + 1] = f32::INFINITY;
        assert!(PackedModel::lower(&b).is_err());
    }

    #[test]
    fn missing_parameter_rejected_at_lowering() {
        let mut a = Artifacts::synthetic(8).unwrap();
        let idx = a
            .manifest
            .params
            .iter()
            .position(|p| p.name == "layer1.w_in")
            .unwrap();
        a.manifest.params[idx].name = "layer1.w_in_gone".to_string();
        assert!(PackedModel::lower(&a).is_err());
    }
}
