//! Nonlinear functional units: Softmax (ConSmax-style), LayerNorm/RMSNorm
//! and GELU.
//!
//! The paper (citing Kim et al., "Full stack optimization of transformer
//! inference") argues that with dedicated hardware these ops are
//! negligible next to the MatMuls; the TPU carries a "Nonlinear
//! Functional Unit" (ConSmax) and the PIM PEs carry postprocessing units
//! for LayerNorm/GELU. We still model them — the claim "negligible" is
//! *checked* by a test rather than assumed.

use crate::config::ArchConfig;

/// Which nonlinear op.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NonlinearOp {
    /// ConSmax-style streaming softmax over `n` elements.
    Softmax,
    /// LayerNorm/RMSNorm over `n` elements.
    LayerNorm,
    /// Elementwise GELU over `n` elements.
    Gelu,
}

/// Latency/energy of a nonlinear op over a vector of length `n`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NonlinearRun {
    pub latency_s: f64,
    pub energy_j: f64,
}

/// Vector lanes of the nonlinear functional unit. Matches the systolic
/// array width (one lane per output column), which is what makes these
/// ops negligible next to the MatMuls — the paper's premise, checked in
/// `nonlinear_is_negligible_vs_matmul`.
pub const LANES: usize = 32;

/// Pipelined vector functional units process LANES elements per cycle
/// after a small fixed pipeline depth; energy is a few MAC-equivalents
/// per element.
pub fn run(arch: &ArchConfig, op: NonlinearOp, n: usize) -> NonlinearRun {
    let cycle = arch.tpu_cycle_s();
    let (pipeline_depth, passes, energy_per_elem) = match op {
        // ConSmax: single pass (learnable base removes the max-scan).
        NonlinearOp::Softmax => (8, 1, 3.0 * arch.tpu.mac_energy_j),
        // Norm: two passes (statistics, then normalize).
        NonlinearOp::LayerNorm => (8, 2, 2.0 * arch.tpu.mac_energy_j),
        // GELU: LUT/polynomial, single pass.
        NonlinearOp::Gelu => (4, 1, 2.0 * arch.tpu.mac_energy_j),
    };
    let beats = passes * n.div_ceil(LANES);
    NonlinearRun {
        latency_s: cycle * (pipeline_depth as f64 + beats as f64),
        energy_j: n as f64 * energy_per_elem,
    }
}

/// Total nonlinear cost of one decode step: per layer, h softmaxes over
/// l, two norms over d, one GELU over d_ff; plus the final norm.
pub fn decode_step_total(
    arch: &ArchConfig,
    model: &crate::models::LlmConfig,
    l: usize,
) -> NonlinearRun {
    let mut latency = 0.0;
    let mut energy = 0.0;
    for _ in 0..model.n_layers {
        let sm = run(arch, NonlinearOp::Softmax, l);
        latency += sm.latency_s * model.h as f64;
        energy += sm.energy_j * model.h as f64;
        let ln = run(arch, NonlinearOp::LayerNorm, model.d);
        latency += 2.0 * ln.latency_s;
        energy += 2.0 * ln.energy_j;
        let ge = run(arch, NonlinearOp::Gelu, model.d_ff);
        latency += ge.latency_s;
        energy += ge.energy_j;
    }
    let lnf = run(arch, NonlinearOp::LayerNorm, model.d);
    NonlinearRun {
        latency_s: latency + lnf.latency_s,
        energy_j: energy + lnf.energy_j,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::by_name;
    use crate::systolic::{self, Dataflow};

    #[test]
    fn nonlinear_is_negligible_vs_matmul() {
        // The paper's premise: < a few % of the attention MatMul time.
        let arch = ArchConfig::paper_45nm();
        let m = by_name("OPT-6.7B").unwrap();
        let l = 4096;
        let nl = decode_step_total(&arch, &m, l);
        let att_cycles: u64 = crate::workload::decode_ops(&m, l)
            .iter()
            .filter(|o| o.is_attention())
            .map(|o| systolic::run_op(&arch.tpu, o, Dataflow::OutputStationary).cycles)
            .sum();
        let att_s = att_cycles as f64 * arch.tpu_cycle_s();
        assert!(
            nl.latency_s < 0.15 * att_s,
            "nonlinear {} vs attention {}",
            nl.latency_s,
            att_s
        );
    }

    #[test]
    fn latency_scales_with_n() {
        let arch = ArchConfig::paper_45nm();
        let a = run(&arch, NonlinearOp::Softmax, 128);
        let b = run(&arch, NonlinearOp::Softmax, 4096);
        assert!(b.latency_s > a.latency_s);
        assert!(b.energy_j > a.energy_j);
    }
}
