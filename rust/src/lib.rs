//! # PIM-LLM — hybrid analog-PIM + systolic-array accelerator for 1-bit LLMs
//!
//! Full-system reproduction of *PIM-LLM: A High-Throughput Hybrid PIM
//! Architecture for 1-bit LLMs* (cs.AR 2025).
//!
//! The crate is organised as the paper's system plus every substrate it
//! depends on:
//!
//! * [`config`]     — architecture + calibration parameters (45 nm-class).
//! * [`models`]     — the LLM zoo of paper Table II (+ GPT2-S/M for Table III).
//! * [`workload`]   — per-token MatMul enumeration (paper Table I), op
//!   counting (Fig. 1b) and KV-cache geometry.
//! * [`systolic`]   — SCALE-Sim-equivalent systolic-array simulator:
//!   analytical OS/WS/IS dataflow models cross-validated by a
//!   cycle-accurate wavefront stepper (paper Fig. 4, the TPU side).
//! * [`pim`]        — MNSIM-equivalent behavioural model of the analog PIM:
//!   crossbars, DAC/ADC, PE/tile/bank hierarchy, NoC, buffers.
//! * [`memory`]     — LPDDR + SRAM models.
//! * [`energy`]     — per-component energy ledger, tokens/J, words/battery.
//! * [`nonlinear`]  — softmax/LayerNorm/GELU functional-unit latency models
//!   (shown negligible, as the paper argues).
//! * [`coordinator`]— the paper's contribution: the hybrid scheduler that
//!   puts W1A8 projections on PIM and W8A8 attention on the systolic
//!   array, plus the TPU-LLM baseline scheduler.
//! * [`analysis`]   — figure/table generators (Fig. 1b, 4–8, Table III)
//!   with paper-reference values for shape comparison.
//! * [`quant`]      — packed ternary weight representation: each {-1,0,+1}
//!   matrix lowered to two u64 bitplanes (2 bits/weight) + popcount MVM
//!   kernels, bit-identical to the dense reference kernels.
//! * [`runtime`]    — loader/executor for the AOT-lowered 1-bit decoder
//!   (the functional numerics path) behind a pluggable `Backend`: a
//!   pure-Rust reference executor by default, the `quant`-backed packed
//!   bitplane executor, and the PJRT (xla crate) engine behind the
//!   off-by-default `pjrt` feature. Session KV state lives in a shared
//!   block-paged arena (`runtime::kvcache`) addressed by opaque handles.
//! * [`serving`]    — threaded request queue + schedulers (FIFO,
//!   round-robin, fixed-wave batched, continuous batching with
//!   arena-pressure admission and preemption) for the edge-serving
//!   example.
//! * [`obs`]        — zero-dependency tracing + metrics: per-shard
//!   event ring buffers, counters/gauges/histograms, Chrome
//!   trace-event (Perfetto) and plain-text exporters. Provably inert:
//!   token streams are byte-identical with tracing on or off.
//!
//! Python/JAX/Pallas exists only at build time (`make artifacts`); the
//! binary is self-contained afterwards.

pub mod analysis;
pub mod config;
pub mod coordinator;
pub mod energy;
pub mod memory;
pub mod models;
pub mod nonlinear;
pub mod obs;
pub mod pim;
pub mod quant;
pub mod runtime;
pub mod serving;
pub mod systolic;
pub mod util;
pub mod workload;

pub use config::ArchConfig;
pub use models::LlmConfig;

// Unit tests run under a counting allocator so kernel tests can assert
// zero-allocation invariants (see util::testalloc). Test-only: release
// binaries, benches and integration tests keep the stock allocator.
#[cfg(test)]
#[global_allocator]
static TEST_ALLOC: util::testalloc::CountingAlloc = util::testalloc::CountingAlloc;
