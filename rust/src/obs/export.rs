//! Exporters: trace rings → Chrome trace-event JSON (Perfetto /
//! `chrome://tracing` loadable), metrics → the plain-text snapshot in
//! [`super::MetricsSnapshot::render`].
//!
//! Track model: one thread track per shard worker (`tid` = worker id,
//! `pid` = 0). Kernel spans and ticks are thread-scoped duration
//! events (`ph: "B"/"E"` — strictly nested because each shard records
//! from a single worker thread with a monotonic clock). Request
//! lifetimes and their prefill/decode phases are ASYNC spans
//! (`ph: "b"/"e"`, keyed by `id` = request id) because a request can
//! be preempted and resume later — or finish on a different tick —
//! without nesting inside anything. Scheduling moments (preempt,
//! steal, prefix hit/miss, COW, eviction, reclaim) are instant events
//! (`ph: "i"`).

use super::metrics::MetricsSnapshot;
use super::trace::{Event, EventKind, SpanKind};
use crate::util::error::{Context, Result};
use crate::util::json::Json;
use std::path::Path;

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

fn n(v: u64) -> Json {
    Json::Num(v as f64)
}

/// Microsecond timestamp (trace-event `ts` unit) from event nanos.
fn ts_us(t_ns: u64) -> Json {
    Json::Num(t_ns as f64 / 1000.0)
}

/// One trace-event record for `ev` on track `tid`, or `None` for
/// events that do not export (unknown phases never occur; `Eviction`
/// and `Reclaim` with nothing freed are still exported — dropping them
/// here would make event counts disagree with the metrics counters).
fn trace_event(tid: usize, ev: &Event) -> Json {
    let base = |name: &str, ph: &str, extra: Vec<(&str, Json)>| {
        let mut pairs = vec![
            ("name", s(name)),
            ("ph", s(ph)),
            ("ts", ts_us(ev.t_ns)),
            ("pid", n(0)),
            ("tid", n(tid as u64)),
        ];
        pairs.extend(extra);
        obj(pairs)
    };
    let instant = |name: &str, args: Vec<(&str, Json)>| {
        base(name, "i", vec![("s", s("t")), ("args", obj(args))])
    };
    match ev.kind {
        EventKind::TickStart => base("tick", "B", vec![("args", obj(vec![("active", n(ev.a))]))]),
        EventKind::TickEnd => base("tick", "E", vec![]),
        EventKind::SpanBegin | EventKind::SpanEnd => {
            let ph_sync = if ev.kind == EventKind::SpanBegin { "B" } else { "E" };
            let ph_async = if ev.kind == EventKind::SpanBegin { "b" } else { "e" };
            if ev.span.is_phase() {
                // Request phase: async span keyed by request id.
                base(
                    ev.span.name(),
                    ph_async,
                    vec![("cat", s("phase")), ("id", n(ev.a))],
                )
            } else {
                // Kernel span: thread-scoped, layer in args.
                base(
                    ev.span.name(),
                    ph_sync,
                    vec![("cat", s("kernel")), ("args", obj(vec![("layer", n(ev.a))]))],
                )
            }
        }
        EventKind::Admit if ev.b == 1 => {
            // First admission opens the request-lifetime async span.
            base("request", "b", vec![("cat", s("request")), ("id", n(ev.a))])
        }
        EventKind::Retire => {
            base("request", "e", vec![("cat", s("request")), ("id", n(ev.a))])
        }
        EventKind::Admit => instant("admit", vec![("request", n(ev.a))]),
        EventKind::Preempt => {
            instant("preempt", vec![("request", n(ev.a)), ("pos", n(ev.b))])
        }
        EventKind::Steal => {
            instant("steal", vec![("request", n(ev.a)), ("from", n(ev.b))])
        }
        EventKind::PrefixHit => {
            instant("prefix_hit", vec![("request", n(ev.a)), ("adopted", n(ev.b))])
        }
        EventKind::PrefixMiss => instant("prefix_miss", vec![("request", n(ev.a))]),
        EventKind::Cow => instant("cow", vec![("copies", n(ev.a))]),
        EventKind::Eviction => instant("eviction", vec![("entries", n(ev.a))]),
        EventKind::Reclaim => {
            instant("reclaim", vec![("freed", n(ev.a)), ("wanted", n(ev.b))])
        }
    }
}

/// Build the Chrome trace-event document from per-shard drained rings:
/// `tracks` pairs each worker id with its chronological events. A
/// `thread_name` metadata record labels each track in Perfetto.
pub fn chrome_trace(tracks: &[(usize, Vec<Event>)]) -> Json {
    chrome_trace_tagged(tracks, None)
}

/// [`chrome_trace`] with the serving run's arena layout recorded as a
/// process-scoped metadata record (`process_labels`), so traces from
/// f32 and int8 arenas are distinguishable side by side in Perfetto.
/// Metadata records (`ph: "M"`) carry no timeline position, so taggers
/// never perturb event counts or per-track monotonicity checks.
pub fn chrome_trace_tagged(tracks: &[(usize, Vec<Event>)], arena_layout: Option<&str>) -> Json {
    let mut events = Vec::new();
    if let Some(layout) = arena_layout {
        events.push(obj(vec![
            ("name", s("process_labels")),
            ("ph", s("M")),
            ("pid", n(0)),
            ("tid", n(0)),
            (
                "args",
                obj(vec![("labels", s(&format!("kv_arena={layout}")))]),
            ),
        ]));
    }
    for &(tid, ref evs) in tracks {
        events.push(obj(vec![
            ("name", s("thread_name")),
            ("ph", s("M")),
            ("pid", n(0)),
            ("tid", n(tid as u64)),
            (
                "args",
                obj(vec![("name", s(&format!("shard-{tid}")))]),
            ),
        ]));
        for ev in evs {
            events.push(trace_event(tid, ev));
        }
    }
    Json::Obj(vec![
        ("traceEvents".to_string(), Json::Arr(events)),
        ("displayTimeUnit".to_string(), Json::Str("ms".to_string())),
    ])
}

/// Serialize [`chrome_trace`] to `path`.
pub fn write_chrome_trace(path: &Path, tracks: &[(usize, Vec<Event>)]) -> Result<()> {
    write_chrome_trace_tagged(path, tracks, None)
}

/// Serialize [`chrome_trace_tagged`] to `path`.
pub fn write_chrome_trace_tagged(
    path: &Path,
    tracks: &[(usize, Vec<Event>)],
    arena_layout: Option<&str>,
) -> Result<()> {
    std::fs::write(path, chrome_trace_tagged(tracks, arena_layout).to_string())
        .with_context(|| format!("writing trace to {}", path.display()))
}

/// The plain-text metrics exporter (one stable line per metric).
pub fn metrics_text(snapshot: &MetricsSnapshot) -> String {
    snapshot.render()
}

/// Validate a serialized trace document: `traceEvents` exists and is
/// nonempty, every record carries `ts`/`tid`, and timestamps are
/// monotonically non-decreasing per track (metadata records exempt).
/// Returns (events, tracks) counted. This is what `repro trace-check`
/// (and through it ci.sh) runs against emitted traces.
pub fn check_trace_doc(doc: &Json) -> Result<(usize, usize)> {
    let events = doc.get("traceEvents")?.as_arr()?;
    crate::ensure!(!events.is_empty(), "trace has no events");
    // (tid, last ts) per track; tracks are few, linear scan is fine.
    let mut tracks: Vec<(u64, f64)> = Vec::new();
    let mut counted = 0usize;
    for (i, ev) in events.iter().enumerate() {
        let ph = ev.get("ph")?.as_str()?;
        if ph == "M" {
            continue; // metadata carries no timeline position
        }
        let tid = ev.get("tid")?.as_f64()? as u64;
        let ts = ev
            .get("ts")
            .and_then(|v| v.as_f64())
            .with_context(|| format!("trace event {i} has no numeric ts"))?;
        match tracks.iter_mut().find(|(t, _)| *t == tid) {
            Some((_, last)) => {
                crate::ensure!(
                    ts >= *last,
                    "track {tid}: event {i} ts {ts} went backwards (last {last})"
                );
                *last = ts;
            }
            None => tracks.push((tid, ts)),
        }
        counted += 1;
    }
    crate::ensure!(counted > 0, "trace holds only metadata records");
    Ok((counted, tracks.len()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::TraceSink;
    use crate::util::json;

    fn demo_tracks() -> Vec<(usize, Vec<Event>)> {
        let sink = TraceSink::with_capacity(64);
        sink.set_enabled(true);
        sink.record(EventKind::TickStart, SpanKind::None, 1, 0);
        sink.record(EventKind::Admit, SpanKind::None, 7, 1);
        sink.record(EventKind::SpanBegin, SpanKind::Prefill, 7, 0);
        sink.record(EventKind::SpanBegin, SpanKind::KernelQ, 0, 0);
        sink.record(EventKind::SpanEnd, SpanKind::KernelQ, 0, 0);
        sink.record(EventKind::SpanEnd, SpanKind::Prefill, 7, 0);
        sink.record(EventKind::SpanBegin, SpanKind::Decode, 7, 0);
        sink.record(EventKind::PrefixHit, SpanKind::None, 7, 4);
        sink.record(EventKind::Preempt, SpanKind::None, 7, 9);
        sink.record(EventKind::SpanEnd, SpanKind::Decode, 7, 0);
        sink.record(EventKind::Retire, SpanKind::None, 7, 12);
        sink.record(EventKind::TickEnd, SpanKind::None, 1, 0);
        vec![(0, sink.drain()), (1, Vec::new())]
    }

    #[test]
    fn chrome_trace_round_trips_through_the_in_crate_parser() {
        let doc = chrome_trace(&demo_tracks());
        let text = doc.to_string();
        let parsed = json::parse(&text).unwrap();
        let (events, tracks) = check_trace_doc(&parsed).unwrap();
        assert_eq!(events, 12);
        assert_eq!(tracks, 1); // the empty track contributes metadata only
        // Spot the schema: request lifetime is an async span pair.
        let evs = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        let phs: Vec<&str> = evs
            .iter()
            .filter(|e| e.opt("id").is_some())
            .map(|e| e.get("ph").unwrap().as_str().unwrap())
            .collect();
        assert!(phs.contains(&"b") && phs.contains(&"e"));
    }

    #[test]
    fn layout_tag_is_metadata_only_and_survives_the_round_trip() {
        let doc = chrome_trace_tagged(&demo_tracks(), Some("int8"));
        let parsed = json::parse(&doc.to_string()).unwrap();
        // The tag never changes the counted-event or track totals.
        assert_eq!(check_trace_doc(&parsed).unwrap(), (12, 1));
        let evs = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        let label = evs
            .iter()
            .find(|e| e.get("name").unwrap().as_str().unwrap() == "process_labels")
            .expect("tagged trace carries a process_labels record");
        assert_eq!(label.get("ph").unwrap().as_str().unwrap(), "M");
        assert_eq!(
            label.get("args").unwrap().get("labels").unwrap().as_str().unwrap(),
            "kv_arena=int8"
        );
    }

    #[test]
    fn check_trace_doc_rejects_backwards_time_and_empty_traces() {
        let empty = json::parse(r#"{"traceEvents":[]}"#).unwrap();
        assert!(check_trace_doc(&empty).is_err());
        let backwards = json::parse(
            r#"{"traceEvents":[
                {"name":"a","ph":"i","s":"t","ts":5.0,"pid":0,"tid":0},
                {"name":"b","ph":"i","s":"t","ts":4.0,"pid":0,"tid":0}
            ]}"#,
        )
        .unwrap();
        assert!(check_trace_doc(&backwards).is_err());
        let two_tracks = json::parse(
            r#"{"traceEvents":[
                {"name":"a","ph":"i","s":"t","ts":5.0,"pid":0,"tid":0},
                {"name":"b","ph":"i","s":"t","ts":4.0,"pid":0,"tid":1}
            ]}"#,
        )
        .unwrap();
        // Independent tracks: per-track monotonicity only.
        assert_eq!(check_trace_doc(&two_tracks).unwrap(), (2, 2));
    }
}
