//! The trace ring buffer: fixed-size [`Event`] records with monotonic
//! timestamps, preallocated storage, wraparound-overwrite semantics and
//! a drop counter.
//!
//! Sizing: one [`Event`] is 40 bytes; the default ring holds
//! [`DEFAULT_TRACE_CAPACITY`] = 16384 events (~640 KiB per shard),
//! allocated lazily on first enable so the thousands of engines built
//! by the test suites pay nothing. A tiny model records ~30 kernel-span
//! events per tick plus a handful of scheduling events, so the default
//! ring covers thousands of ticks between drains; longer runs wrap,
//! keeping the NEWEST events and counting the overwritten ones in
//! [`TraceSink::dropped`].
//!
//! The record path is allocation-free by construction — one relaxed
//! atomic load (the enable gate), one monotonic clock read, one
//! uncontended mutex lock, one slot write — which is what lets the
//! serving loop and the kernel layer trace the warm single-vector
//! decode path without breaking its zero-allocation invariant (pinned
//! by the counting-allocator test below).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Default ring capacity in events (per shard).
pub const DEFAULT_TRACE_CAPACITY: usize = 16384;

/// What happened. Payload conventions (`Event::a`, `Event::b`):
///
/// | kind                  | `a`                      | `b`                    |
/// |-----------------------|--------------------------|------------------------|
/// | `TickStart`           | active sessions          | —                      |
/// | `TickEnd`             | tokens decoded this tick | —                      |
/// | `Admit`               | request id               | 1 = first admission    |
/// | `Retire`              | request id               | tokens generated       |
/// | `Preempt`             | request id               | position reached       |
/// | `Steal`               | request id               | victim shard           |
/// | `PrefixHit`           | request id               | positions adopted      |
/// | `PrefixMiss`          | request id               | —                      |
/// | `Cow`                 | block copies this tick   | —                      |
/// | `Eviction`            | prefix entries evicted   | —                      |
/// | `Reclaim`             | blocks freed             | blocks wanted          |
/// | `SpanBegin`/`SpanEnd` | see [`SpanKind`]         | —                      |
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum EventKind {
    TickStart,
    TickEnd,
    Admit,
    Retire,
    Preempt,
    Steal,
    PrefixHit,
    PrefixMiss,
    Cow,
    Eviction,
    Reclaim,
    SpanBegin,
    SpanEnd,
}

impl EventKind {
    /// Stable lowercase name (trace export + text rendering).
    pub fn name(self) -> &'static str {
        match self {
            EventKind::TickStart => "tick_start",
            EventKind::TickEnd => "tick_end",
            EventKind::Admit => "admit",
            EventKind::Retire => "retire",
            EventKind::Preempt => "preempt",
            EventKind::Steal => "steal",
            EventKind::PrefixHit => "prefix_hit",
            EventKind::PrefixMiss => "prefix_miss",
            EventKind::Cow => "cow",
            EventKind::Eviction => "eviction",
            EventKind::Reclaim => "reclaim",
            EventKind::SpanBegin => "span_begin",
            EventKind::SpanEnd => "span_end",
        }
    }

    /// The counter this event kind bumps on record (see
    /// [`crate::obs::Obs::event`]), if any.
    pub fn counter(self) -> Option<super::metrics::Counter> {
        use super::metrics::Counter;
        match self {
            EventKind::TickStart => Some(Counter::TicksRun),
            EventKind::Admit => Some(Counter::Admitted),
            EventKind::Retire => Some(Counter::Retired),
            EventKind::Preempt => Some(Counter::Preemptions),
            EventKind::Steal => Some(Counter::Steals),
            EventKind::PrefixHit => Some(Counter::PrefixHits),
            EventKind::PrefixMiss => Some(Counter::PrefixMisses),
            // `Cow` carries a per-tick DELTA in `a`, not one-event-per-copy;
            // the serving tick bumps `Counter::CowCopies` by that delta
            // itself, so auto-counting here would double-count.
            _ => None,
        }
    }
}

/// Which span a `SpanBegin`/`SpanEnd` event opens or closes: the two
/// request phases (`a` = request id) and the seven projection kernel
/// families + paged attention (`a` = layer index; `Head` uses the layer
/// count). `None` marks non-span events.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum SpanKind {
    None,
    /// Request phase: admission until the prompt is fully fed/adopted.
    Prefill,
    /// Request phase: first generated token until retirement.
    Decode,
    /// Q projection (`wq`).
    KernelQ,
    /// K projection (`wk`).
    KernelK,
    /// V projection (`wv`).
    KernelV,
    /// Attention-output projection (`wx`).
    KernelO,
    /// FFN up projection (`w_in`).
    KernelFf1,
    /// FFN down projection (`w_out`).
    KernelFf2,
    /// LM head (`w_head`).
    KernelHead,
    /// Paged attention over the arena block tables.
    Attention,
    /// Speculative k-token verify traversal (`a` = request id): one
    /// target span checking a draft's proposals.
    SpecVerify,
}

impl SpanKind {
    /// Stable lowercase name (trace export).
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::None => "none",
            SpanKind::Prefill => "prefill",
            SpanKind::Decode => "decode",
            SpanKind::KernelQ => "wq",
            SpanKind::KernelK => "wk",
            SpanKind::KernelV => "wv",
            SpanKind::KernelO => "wx",
            SpanKind::KernelFf1 => "w_in",
            SpanKind::KernelFf2 => "w_out",
            SpanKind::KernelHead => "w_head",
            SpanKind::Attention => "attention",
            SpanKind::SpecVerify => "spec_verify",
        }
    }

    /// Whether this span is a request phase (async-span export) rather
    /// than a thread-scoped kernel span.
    pub fn is_phase(self) -> bool {
        matches!(self, SpanKind::Prefill | SpanKind::Decode)
    }
}

/// One fixed-size trace record. 40 bytes, `Copy`, no heap parts — the
/// ring is a flat `Vec<Event>` and recording is a slot write.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Event {
    /// Nanoseconds since the sink's epoch (monotonic clock).
    pub t_ns: u64,
    pub kind: EventKind,
    /// Span kind for `SpanBegin`/`SpanEnd`; [`SpanKind::None`] otherwise.
    pub span: SpanKind,
    /// Primary payload (see the [`EventKind`] table).
    pub a: u64,
    /// Secondary payload.
    pub b: u64,
}

/// Preallocated ring storage. `buf` grows by `push` only up to
/// `capacity` (reserved exactly once, at enable), after which `head`
/// walks the slots and every overwrite counts one dropped event.
struct Ring {
    buf: Vec<Event>,
    capacity: usize,
    /// Index of the OLDEST event once the ring has wrapped.
    head: usize,
}

impl Ring {
    fn record(&mut self, ev: Event) -> bool {
        if self.buf.len() < self.capacity {
            // Within the reservation made at enable time: no realloc.
            self.buf.push(ev);
            false
        } else if self.capacity > 0 {
            self.buf[self.head] = ev;
            self.head = (self.head + 1) % self.capacity;
            true
        } else {
            true
        }
    }

    /// Copy out chronologically and reset to empty (capacity kept).
    fn drain(&mut self) -> Vec<Event> {
        let mut out = Vec::with_capacity(self.buf.len());
        out.extend_from_slice(&self.buf[self.head..]);
        out.extend_from_slice(&self.buf[..self.head]);
        self.buf.clear();
        self.head = 0;
        out
    }
}

/// The per-shard trace sink: an enable gate, a monotonic epoch, and a
/// mutex-guarded [`Ring`]. The mutex makes drain-while-recording from
/// another thread safe; within a shard the lock is uncontended (one
/// worker thread records, nobody drains until the run ends).
pub struct TraceSink {
    enabled: AtomicBool,
    epoch: Instant,
    dropped: AtomicU64,
    ring: Mutex<Ring>,
}

impl TraceSink {
    /// A disabled sink whose ring will hold `capacity` events once
    /// enabled (storage is reserved on first enable, not here).
    pub fn with_capacity(capacity: usize) -> Self {
        Self {
            enabled: AtomicBool::new(false),
            epoch: Instant::now(),
            dropped: AtomicU64::new(0),
            ring: Mutex::new(Ring {
                buf: Vec::new(),
                capacity,
                head: 0,
            }),
        }
    }

    /// Reserve the ring storage up front (idempotent; called by
    /// [`TraceSink::set_enabled`] via `Obs::set_enabled`) so the first
    /// recorded event never allocates.
    pub fn ensure_allocated(&self) {
        let mut ring = self.ring.lock().unwrap();
        let want = ring.capacity;
        if ring.buf.capacity() < want {
            ring.buf.reserve_exact(want - ring.buf.len());
        }
    }

    pub fn set_enabled(&self, on: bool) {
        if on {
            self.ensure_allocated();
        }
        self.enabled.store(on, Ordering::Release);
    }

    #[inline]
    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Ring capacity in events.
    pub fn capacity(&self) -> usize {
        self.ring.lock().unwrap().capacity
    }

    /// Events overwritten (ring full) or rejected (capacity 0) so far.
    /// Cumulative — drains do not reset it.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Record one event. Allocation-free: gate load, clock read, slot
    /// write under an uncontended lock. No-op while disabled.
    #[inline]
    pub fn record(&self, kind: EventKind, span: SpanKind, a: u64, b: u64) {
        if !self.enabled() {
            return;
        }
        let t_ns = self.epoch.elapsed().as_nanos() as u64;
        let overwrote = self
            .ring
            .lock()
            .unwrap()
            .record(Event { t_ns, kind, span, a, b });
        if overwrote {
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Take every buffered event in chronological order, leaving the
    /// ring empty. Allocates — call outside the serving loop.
    pub fn drain(&self) -> Vec<Event> {
        self.ring.lock().unwrap().drain()
    }

    /// Buffered events right now (for tests / status lines).
    pub fn len(&self) -> usize {
        self.ring.lock().unwrap().buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testalloc::thread_allocs;

    fn sink(cap: usize) -> TraceSink {
        let s = TraceSink::with_capacity(cap);
        s.set_enabled(true);
        s
    }

    #[test]
    fn records_in_order_with_monotonic_timestamps() {
        let s = sink(64);
        for i in 0..10 {
            s.record(EventKind::Admit, SpanKind::None, i, 0);
        }
        let evs = s.drain();
        assert_eq!(evs.len(), 10);
        for (i, e) in evs.iter().enumerate() {
            assert_eq!(e.a, i as u64);
        }
        assert!(evs.windows(2).all(|w| w[0].t_ns <= w[1].t_ns));
        assert_eq!(s.dropped(), 0);
        assert!(s.is_empty());
    }

    #[test]
    fn wraparound_keeps_newest_and_counts_drops() {
        let s = sink(8);
        for i in 0..20u64 {
            s.record(EventKind::TickStart, SpanKind::None, i, 0);
        }
        assert_eq!(s.dropped(), 12);
        let evs = s.drain();
        assert_eq!(evs.len(), 8);
        let got: Vec<u64> = evs.iter().map(|e| e.a).collect();
        assert_eq!(got, (12..20).collect::<Vec<u64>>());
        // Drained: a fresh burst fills the same storage again.
        s.record(EventKind::TickEnd, SpanKind::None, 99, 0);
        assert_eq!(s.drain().len(), 1);
        assert_eq!(s.dropped(), 12);
    }

    #[test]
    fn zero_capacity_drops_everything() {
        let s = sink(0);
        s.record(EventKind::Admit, SpanKind::None, 1, 0);
        s.record(EventKind::Retire, SpanKind::None, 1, 0);
        assert!(s.drain().is_empty());
        assert_eq!(s.dropped(), 2);
    }

    #[test]
    fn disabled_sink_is_inert() {
        let s = TraceSink::with_capacity(16);
        s.record(EventKind::Admit, SpanKind::None, 1, 0);
        assert!(s.drain().is_empty());
        assert_eq!(s.dropped(), 0);
    }

    /// The tentpole's zero-allocation pin: recording into an ENABLED,
    /// preallocated sink does not touch the heap. Together with the
    /// warm-path kernel tests (quant::kernels) and the end-to-end
    /// parity test in runtime::packed, this proves tracing keeps warm
    /// single-vector decode allocation-free.
    #[test]
    fn record_path_is_allocation_free_with_tracing_on() {
        let s = sink(256);
        // Warm: first record exercises any lazy paths.
        s.record(EventKind::TickStart, SpanKind::None, 0, 0);
        let before = thread_allocs();
        for i in 0..200u64 {
            s.record(EventKind::SpanBegin, SpanKind::KernelQ, i, 0);
            s.record(EventKind::SpanEnd, SpanKind::KernelQ, i, 0);
        }
        assert_eq!(
            thread_allocs() - before,
            0,
            "TraceSink::record allocated on the hot path"
        );
        // Wraparound overwrites must be allocation-free too (256-slot
        // ring, 401 records so far: already wrapped above or wraps now).
        let before = thread_allocs();
        for i in 0..300u64 {
            s.record(EventKind::TickStart, SpanKind::None, i, 0);
        }
        assert_eq!(thread_allocs() - before, 0);
    }

    /// Warm packed single-vector decode kernel + tracing ON: the
    /// counting allocator sees zero allocations across the combined
    /// span-record + popcount-MVM sequence — the exact instrumentation
    /// shape the packed backend's decode loop uses.
    #[test]
    fn warm_packed_kernel_with_spans_is_allocation_free() {
        use crate::quant::{bitlinear_packed_into, pack, PackedScratch};
        use crate::util::rng::Rng;

        let (k, n) = (64usize, 16usize);
        let mut rng = Rng::new(0xb0b);
        let w: Vec<f32> = (0..k * n)
            .map(|_| ((rng.next_u64() % 3) as f32) - 1.0)
            .collect();
        let planes = pack(&w, k, n, 1.0).unwrap();
        let x: Vec<f32> = (0..k).map(|_| rng.normal() as f32).collect();
        let mut scratch = PackedScratch::new();
        let mut y = vec![0.0f32; n];
        let s = sink(1024);

        // Warm both the scratch quantization buffers and the sink.
        s.record(EventKind::TickStart, SpanKind::None, 0, 0);
        bitlinear_packed_into(&x, &planes, &mut scratch, &mut y);

        let before = thread_allocs();
        for layer in 0..8u64 {
            s.record(EventKind::SpanBegin, SpanKind::KernelQ, layer, 0);
            bitlinear_packed_into(&x, &planes, &mut scratch, &mut y);
            s.record(EventKind::SpanEnd, SpanKind::KernelQ, layer, 0);
        }
        assert_eq!(
            thread_allocs() - before,
            0,
            "warm packed kernel + tracing ON allocated"
        );
        assert!(s.len() > 0);
    }
}
