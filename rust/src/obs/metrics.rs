//! The metrics registry: named monotonic counters, last-value gauges,
//! and fixed-bucket histograms over relaxed atomics.
//!
//! Everything is enum-indexed into flat atomic arrays — no string
//! hashing, no allocation, no locks on the record path. A snapshot
//! ([`MetricsRegistry::snapshot`]) copies the atomics into plain
//! integers; per-shard snapshots merge with [`MetricsSnapshot::absorb`]
//! in ascending worker-id order (the `PrefixStats::absorb` pattern), so
//! the merged rendering is byte-diffable run-to-run wherever the
//! underlying schedule is deterministic.

use std::sync::atomic::{AtomicU64, Ordering};

/// Monotonic counters. `ALL` fixes the registry layout AND the render
/// order — append new variants at the end to keep snapshots diffable.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Counter {
    /// Serving ticks executed.
    TicksRun,
    /// Tokens fed through decode (one per active session per tick).
    TokensDecoded,
    /// Request admissions (re-admissions after preemption included).
    Admitted,
    /// Requests retired complete.
    Retired,
    /// Sessions preempted under arena pressure.
    Preemptions,
    /// Requests stolen from a sibling shard's queue.
    Steals,
    /// Prefix-cache adoptions (≥1 position skipped).
    PrefixHits,
    /// Prefix lookups that adopted nothing.
    PrefixMisses,
    /// Copy-on-write block copies (adoption tail copies).
    CowCopies,
    /// Prefix index entries evicted under pressure.
    PrefixEvictions,
    /// Arena blocks freed by prefix reclaim.
    BlocksReclaimed,
    /// `debug_validate` passes run by `--validate-every`.
    ValidationsRun,
    /// int8 KV blocks walked (dequantized at the group-scale boundary)
    /// by the q8 attention gather — the traffic the quantized arena
    /// trades the f32 gather for.
    KvDequantBlocks,
    /// Prompt positions fed by the prefill lane (chunked prefill).
    LanePrefillTokens,
    /// Generated tokens absorbed by the decode lane.
    LaneDecodeTokens,
    /// Draft tokens proposed for speculative verification (the free
    /// bonus token of each span is not counted on either side).
    SpecProposed,
    /// Draft proposals the target's own argmax confirmed — acceptance
    /// rate is `spec_accepted / spec_proposed`.
    SpecAccepted,
}

impl Counter {
    pub const ALL: [Counter; 17] = [
        Counter::TicksRun,
        Counter::TokensDecoded,
        Counter::Admitted,
        Counter::Retired,
        Counter::Preemptions,
        Counter::Steals,
        Counter::PrefixHits,
        Counter::PrefixMisses,
        Counter::CowCopies,
        Counter::PrefixEvictions,
        Counter::BlocksReclaimed,
        Counter::ValidationsRun,
        Counter::KvDequantBlocks,
        Counter::LanePrefillTokens,
        Counter::LaneDecodeTokens,
        Counter::SpecProposed,
        Counter::SpecAccepted,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Counter::TicksRun => "ticks_run",
            Counter::TokensDecoded => "tokens_decoded",
            Counter::Admitted => "admitted",
            Counter::Retired => "retired",
            Counter::Preemptions => "preemptions",
            Counter::Steals => "steals",
            Counter::PrefixHits => "prefix_hits",
            Counter::PrefixMisses => "prefix_misses",
            Counter::CowCopies => "cow_copies",
            Counter::PrefixEvictions => "prefix_evictions",
            Counter::BlocksReclaimed => "blocks_reclaimed",
            Counter::ValidationsRun => "validations_run",
            Counter::KvDequantBlocks => "kv_dequant_blocks",
            Counter::LanePrefillTokens => "lane_prefill_tokens",
            Counter::LaneDecodeTokens => "lane_decode_tokens",
            Counter::SpecProposed => "spec_proposed",
            Counter::SpecAccepted => "spec_accepted",
        }
    }

    fn idx(self) -> usize {
        self as usize
    }
}

/// Last-value gauges, sampled once per tick. Merging sums across
/// shards (each shard owns a disjoint arena partition and session set,
/// so sums are the fleet totals).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Gauge {
    ArenaBlocksFree,
    ArenaBlocksUsed,
    /// Live entries pinned in the prefix index.
    PrefixEntries,
    /// Sessions decoding this tick.
    ActiveSessions,
    /// Requests waiting in the visible ready queue.
    QueueDepth,
    /// Bytes backing referenced arena blocks (layout-aware: block
    /// counts are incomparable between the f32 and int8 arenas, bytes
    /// are the common denominator).
    ArenaBytesUsed,
}

impl Gauge {
    pub const ALL: [Gauge; 6] = [
        Gauge::ArenaBlocksFree,
        Gauge::ArenaBlocksUsed,
        Gauge::PrefixEntries,
        Gauge::ActiveSessions,
        Gauge::QueueDepth,
        Gauge::ArenaBytesUsed,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Gauge::ArenaBlocksFree => "arena_blocks_free",
            Gauge::ArenaBlocksUsed => "arena_blocks_used",
            Gauge::PrefixEntries => "prefix_entries",
            Gauge::ActiveSessions => "active_sessions",
            Gauge::QueueDepth => "queue_depth",
            Gauge::ArenaBytesUsed => "arena_bytes_used",
        }
    }

    fn idx(self) -> usize {
        self as usize
    }
}

/// Bucket count per histogram: [`HIST_BOUNDS`] upper bounds plus one
/// overflow slot.
pub const HIST_SLOTS: usize = 7;

/// Inclusive upper bounds of the first six buckets, per histogram.
const HIST_BOUNDS: [[u64; HIST_SLOTS - 1]; 2] = [
    // TickMicros: 10us .. 1s, decades.
    [10, 100, 1_000, 10_000, 100_000, 1_000_000],
    // BatchSize: powers of two.
    [1, 2, 4, 8, 16, 32],
];

/// Fixed-bucket histograms.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Hist {
    /// Wall-clock tick duration, microseconds.
    TickMicros,
    /// Sessions decoded per tick.
    BatchSize,
}

impl Hist {
    pub const ALL: [Hist; 2] = [Hist::TickMicros, Hist::BatchSize];

    pub fn name(self) -> &'static str {
        match self {
            Hist::TickMicros => "tick_micros",
            Hist::BatchSize => "batch_size",
        }
    }

    /// The inclusive upper bounds of this histogram's buckets (the
    /// last slot counts everything above `bounds()[last]`).
    pub fn bounds(self) -> &'static [u64; HIST_SLOTS - 1] {
        &HIST_BOUNDS[self as usize]
    }

    fn idx(self) -> usize {
        self as usize
    }
}

/// The live registry: one relaxed atomic per counter/gauge/bucket.
pub struct MetricsRegistry {
    counters: [AtomicU64; Counter::ALL.len()],
    gauges: [AtomicU64; Gauge::ALL.len()],
    hists: [[AtomicU64; HIST_SLOTS]; Hist::ALL.len()],
}

impl Default for MetricsRegistry {
    fn default() -> Self {
        Self::new()
    }
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self {
            counters: std::array::from_fn(|_| AtomicU64::new(0)),
            gauges: std::array::from_fn(|_| AtomicU64::new(0)),
            hists: std::array::from_fn(|_| std::array::from_fn(|_| AtomicU64::new(0))),
        }
    }

    #[inline]
    pub fn add(&self, c: Counter, n: u64) {
        self.counters[c.idx()].fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn set(&self, g: Gauge, v: u64) {
        self.gauges[g.idx()].store(v, Ordering::Relaxed);
    }

    #[inline]
    pub fn observe(&self, h: Hist, v: u64) {
        let bounds = h.bounds();
        let slot = bounds
            .iter()
            .position(|&b| v <= b)
            .unwrap_or(HIST_SLOTS - 1);
        self.hists[h.idx()][slot].fetch_add(1, Ordering::Relaxed);
    }

    /// Copy every atomic into a plain, mergeable snapshot.
    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            counters: std::array::from_fn(|i| self.counters[i].load(Ordering::Relaxed)),
            gauges: std::array::from_fn(|i| self.gauges[i].load(Ordering::Relaxed)),
            hists: std::array::from_fn(|i| {
                std::array::from_fn(|j| self.hists[i][j].load(Ordering::Relaxed))
            }),
        }
    }
}

/// A point-in-time copy of a [`MetricsRegistry`]. Plain integers:
/// mergeable, comparable, renderable.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct MetricsSnapshot {
    counters: [u64; Counter::ALL.len()],
    gauges: [u64; Gauge::ALL.len()],
    hists: [[u64; HIST_SLOTS]; Hist::ALL.len()],
}

impl MetricsSnapshot {
    pub fn counter(&self, c: Counter) -> u64 {
        self.counters[c.idx()]
    }

    pub fn gauge(&self, g: Gauge) -> u64 {
        self.gauges[g.idx()]
    }

    pub fn hist(&self, h: Hist) -> &[u64; HIST_SLOTS] {
        &self.hists[h.idx()]
    }

    /// Fold another shard's snapshot into this one (sums everywhere —
    /// counters and histogram buckets are additive by definition;
    /// gauges sum because shards partition the arena and the session
    /// set). Call in ascending worker-id order; addition makes the
    /// result order-independent, the convention makes it auditable.
    pub fn absorb(&mut self, other: &MetricsSnapshot) {
        for (a, b) in self.counters.iter_mut().zip(other.counters.iter()) {
            *a += b;
        }
        for (a, b) in self.gauges.iter_mut().zip(other.gauges.iter()) {
            *a += b;
        }
        for (ha, hb) in self.hists.iter_mut().zip(other.hists.iter()) {
            for (a, b) in ha.iter_mut().zip(hb.iter()) {
                *a += b;
            }
        }
    }

    /// Plain-text rendering: one `name value` line per counter and
    /// gauge, one line per histogram with `≤bound:count` cells. Field
    /// order is fixed by the enum `ALL` arrays, so two runs of a
    /// deterministic schedule diff cleanly.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str("# metrics snapshot\n");
        for c in Counter::ALL {
            out.push_str(&format!("counter {} {}\n", c.name(), self.counter(c)));
        }
        for g in Gauge::ALL {
            out.push_str(&format!("gauge {} {}\n", g.name(), self.gauge(g)));
        }
        for h in Hist::ALL {
            out.push_str(&format!("hist {}", h.name()));
            let counts = self.hist(h);
            for (i, &bound) in h.bounds().iter().enumerate() {
                out.push_str(&format!(" le{bound}:{}", counts[i]));
            }
            out.push_str(&format!(" inf:{}\n", counts[HIST_SLOTS - 1]));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges_round_trip() {
        let m = MetricsRegistry::new();
        m.add(Counter::TokensDecoded, 5);
        m.add(Counter::TokensDecoded, 3);
        m.set(Gauge::QueueDepth, 7);
        m.set(Gauge::QueueDepth, 2);
        let s = m.snapshot();
        assert_eq!(s.counter(Counter::TokensDecoded), 8);
        assert_eq!(s.gauge(Gauge::QueueDepth), 2);
        assert_eq!(s.counter(Counter::Admitted), 0);
    }

    #[test]
    fn histogram_buckets_by_inclusive_upper_bound() {
        let m = MetricsRegistry::new();
        for v in [0, 1, 2, 3, 32, 33, 1_000_000] {
            m.observe(Hist::BatchSize, v);
        }
        let s = m.snapshot();
        // bounds [1,2,4,8,16,32]: 0,1→le1; 2→le2; 3→le4; 32→le32; 33,1M→inf
        assert_eq!(s.hist(Hist::BatchSize), &[2, 1, 1, 0, 0, 1, 2]);
    }

    #[test]
    fn absorb_sums_everything_and_commutes() {
        let m1 = MetricsRegistry::new();
        m1.add(Counter::Admitted, 2);
        m1.set(Gauge::ArenaBlocksFree, 4);
        m1.observe(Hist::TickMicros, 50);
        let m2 = MetricsRegistry::new();
        m2.add(Counter::Admitted, 3);
        m2.set(Gauge::ArenaBlocksFree, 6);
        m2.observe(Hist::TickMicros, 5_000_000);

        let (s1, s2) = (m1.snapshot(), m2.snapshot());
        let mut ab = s1;
        ab.absorb(&s2);
        let mut ba = s2;
        ba.absorb(&s1);
        assert_eq!(ab, ba);
        assert_eq!(ab.counter(Counter::Admitted), 5);
        assert_eq!(ab.gauge(Gauge::ArenaBlocksFree), 10);
        assert_eq!(ab.hist(Hist::TickMicros)[1], 1); // 50 ≤ 100
        assert_eq!(ab.hist(Hist::TickMicros)[HIST_SLOTS - 1], 1); // overflow
    }

    #[test]
    fn render_has_one_line_per_metric_in_fixed_order() {
        let s = MetricsRegistry::new().snapshot();
        let text = s.render();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(
            lines.len(),
            1 + Counter::ALL.len() + Gauge::ALL.len() + Hist::ALL.len()
        );
        assert_eq!(lines[1], "counter ticks_run 0");
        assert!(lines.last().unwrap().starts_with("hist batch_size"));
    }
}
