//! Zero-dependency observability: tracing + metrics for the serving
//! stack, the runtime engine, and the kernel layer.
//!
//! The serving loop used to be a black box between request submission
//! and the final `LatencyStats` line: nothing recorded when a tick
//! admitted, preempted, stole, hit the prefix cache, or how long each
//! kernel family ran. This module makes every one of those moments a
//! fixed-size [`Event`] in a preallocated per-shard ring buffer
//! ([`TraceSink`]) and a monotonic counter/gauge/histogram in a
//! [`MetricsRegistry`], with exporters ([`export`]) that turn the ring
//! into Chrome trace-event JSON (loadable in Perfetto) and the registry
//! into a plain-text snapshot.
//!
//! Design invariants, in priority order:
//!
//! 1. **Inert.** Instrumentation NEVER changes a token. Nothing here
//!    feeds back into scheduling or numerics; the determinism suites
//!    run the same workload with tracing on and off and require
//!    byte-identical streams.
//! 2. **Zero-allocation on the hot path.** [`TraceSink::record`]
//!    writes into a buffer preallocated at enable time; counters and
//!    gauges are plain relaxed atomics. The counting-allocator tests
//!    (see `trace.rs` and `runtime/packed.rs`) pin that a warm
//!    single-vector packed decode performs zero heap allocations with
//!    tracing ON. Draining ([`TraceSink::drain`]) allocates, and is
//!    only ever called outside the serving loop.
//! 3. **Near-zero cost when off.** Every recording entry point checks
//!    one relaxed [`AtomicBool`](std::sync::atomic::AtomicBool) first;
//!    a disabled [`Obs`] does no clock reads, takes no locks, and its
//!    default ring buffer is not even allocated until first enabled.
//! 4. **Deterministic reporting.** Per-shard metrics merge in
//!    ascending worker-id order via [`MetricsSnapshot::absorb`] (the
//!    `PrefixStats::absorb` pattern), so the merged snapshot — like
//!    the token streams — is diffable run-to-run.
//!
//! One [`Obs`] instance exists per engine/shard (`Engine::obs()`,
//! `ShardedEngine::obs()`), shared with that shard's backend through
//! `Backend::install_obs` so kernel spans land in the same ring, in
//! the same monotonic timeline, as the serving events around them.

pub mod export;
pub mod metrics;
pub mod trace;

pub use metrics::{Counter, Gauge, Hist, MetricsRegistry, MetricsSnapshot};
pub use trace::{Event, EventKind, SpanKind, TraceSink, DEFAULT_TRACE_CAPACITY};

use std::sync::atomic::{AtomicBool, Ordering};

/// Per-shard observability bundle: one trace ring + one metrics
/// registry behind a single enable gate. Construction is cheap (the
/// ring allocates lazily on first enable), so every engine owns one
/// unconditionally and the disabled cost is a relaxed load per call.
pub struct Obs {
    shard: usize,
    enabled: AtomicBool,
    /// Event ring buffer; drain outside the hot path.
    pub trace: TraceSink,
    /// Counters / gauges / fixed-bucket histograms.
    pub metrics: MetricsRegistry,
}

impl Obs {
    /// A disabled bundle for shard `shard` with the default ring
    /// capacity ([`DEFAULT_TRACE_CAPACITY`] events, allocated lazily).
    pub fn new(shard: usize) -> Self {
        Self::with_capacity(shard, DEFAULT_TRACE_CAPACITY)
    }

    /// A disabled bundle with an explicit ring capacity (events).
    pub fn with_capacity(shard: usize, capacity: usize) -> Self {
        Self {
            shard,
            enabled: AtomicBool::new(false),
            trace: TraceSink::with_capacity(capacity),
            metrics: MetricsRegistry::new(),
        }
    }

    /// The worker id whose timeline this bundle records (trace track).
    pub fn shard(&self) -> usize {
        self.shard
    }

    /// Flip collection on or off. Enabling allocates the ring buffer
    /// if this is the first enable; NEVER call on a decode hot path.
    pub fn set_enabled(&self, on: bool) {
        if on {
            self.trace.ensure_allocated();
        }
        self.trace.set_enabled(on);
        self.enabled.store(on, Ordering::Release);
    }

    /// Whether collection is on (one relaxed load — the gate every
    /// instrumentation site checks first).
    #[inline]
    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Record a scheduling event (and bump its matching counter, so
    /// call sites stay single-line). `a`/`b` payloads are event
    /// specific — see [`EventKind`].
    #[inline]
    pub fn event(&self, kind: EventKind, a: u64, b: u64) {
        if !self.enabled() {
            return;
        }
        self.trace.record(kind, SpanKind::None, a, b);
        if let Some(c) = kind.counter() {
            self.metrics.add(c, 1);
        }
    }

    /// Open a span of kind `span` (phase or kernel family); `a` is the
    /// request id for phases, the layer index for kernels.
    #[inline]
    pub fn span_begin(&self, span: SpanKind, a: u64) {
        if self.enabled() {
            self.trace.record(EventKind::SpanBegin, span, a, 0);
        }
    }

    /// Close the innermost open span of kind `span` (same `a` payload
    /// as the matching [`Obs::span_begin`]).
    #[inline]
    pub fn span_end(&self, span: SpanKind, a: u64) {
        if self.enabled() {
            self.trace.record(EventKind::SpanEnd, span, a, 0);
        }
    }

    /// Add `n` to a monotonic counter.
    #[inline]
    pub fn count(&self, c: Counter, n: u64) {
        if self.enabled() {
            self.metrics.add(c, n);
        }
    }

    /// Set a gauge to its current value.
    #[inline]
    pub fn gauge(&self, g: Gauge, v: u64) {
        if self.enabled() {
            self.metrics.set(g, v);
        }
    }

    /// Record one observation into a fixed-bucket histogram.
    #[inline]
    pub fn observe(&self, h: Hist, v: u64) {
        if self.enabled() {
            self.metrics.observe(h, v);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn obs_is_send_and_sync() {
        fn assert_bounds<T: Send + Sync>() {}
        assert_bounds::<Obs>();
    }

    #[test]
    fn disabled_obs_records_nothing() {
        let o = Obs::new(0);
        o.event(EventKind::Admit, 1, 1);
        o.span_begin(SpanKind::Decode, 1);
        o.count(Counter::TokensDecoded, 5);
        o.gauge(Gauge::QueueDepth, 3);
        o.observe(Hist::BatchSize, 4);
        assert!(o.trace.drain().is_empty());
        assert_eq!(o.trace.dropped(), 0);
        let s = o.metrics.snapshot();
        assert_eq!(s.counter(Counter::Admitted), 0);
        assert_eq!(s.counter(Counter::TokensDecoded), 0);
        assert_eq!(s.gauge(Gauge::QueueDepth), 0);
    }

    #[test]
    fn events_bump_their_matching_counters() {
        let o = Obs::new(0);
        o.set_enabled(true);
        o.event(EventKind::Admit, 7, 1);
        o.event(EventKind::Preempt, 7, 0);
        o.event(EventKind::Admit, 7, 0);
        o.event(EventKind::Retire, 7, 0);
        o.event(EventKind::PrefixHit, 8, 0);
        o.event(EventKind::TickStart, 1, 0);
        let s = o.metrics.snapshot();
        assert_eq!(s.counter(Counter::Admitted), 2);
        assert_eq!(s.counter(Counter::Preemptions), 1);
        assert_eq!(s.counter(Counter::Retired), 1);
        assert_eq!(s.counter(Counter::PrefixHits), 1);
        assert_eq!(s.counter(Counter::TicksRun), 1);
        assert_eq!(o.trace.drain().len(), 6);
    }
}
