//! Figure/table generators: for every evaluation artifact in the paper
//! (Fig. 1b, Fig. 4, Fig. 5, Fig. 6, Fig. 7, Fig. 8, Table III) this
//! module produces the same rows/series from the simulator, alongside
//! the paper's reported values where the text states them, so benches
//! and the CLI can print paper-vs-measured.

pub mod figures;
pub mod report;

pub use figures::*;
