//! Pretty-printing for figure/table rows: fixed-width console tables the
//! benches and CLI share, always showing paper-reference values next to
//! measured ones where the paper states them.

use super::figures::*;

fn fmt_si(v: f64) -> String {
    if v >= 1e6 {
        format!("{:.2}M", v / 1e6)
    } else if v >= 1e3 {
        format!("{:.2}k", v / 1e3)
    } else if v >= 1.0 {
        format!("{v:.2}")
    } else {
        format!("{v:.4}")
    }
}

pub fn print_fig1b(rows: &[Fig1bRow]) {
    println!("Fig. 1b — % low-precision (W1A8) MatMul operations");
    println!("{:<12} {:>8} {:>10}", "model", "context", "low-prec%");
    for r in rows {
        println!(
            "{:<12} {:>8} {:>9.2}%",
            r.model, r.context, r.low_precision_pct
        );
    }
}

pub fn print_fig4(rows: &[Fig4Row]) {
    println!(
        "Fig. 4 — decode-step cycles on 32x32 array (l={FIG4_CONTEXT}), by dataflow"
    );
    println!("{:<12} {:>4} {:>16}", "model", "df", "cycles");
    for r in rows {
        println!("{:<12} {:>4} {:>16}", r.model, r.dataflow, r.cycles);
    }
}

pub fn print_fig5(rows: &[Fig5Row]) {
    println!("Fig. 5 — tokens/s (PIM-LLM vs TPU-LLM)");
    println!(
        "{:<12} {:>7} {:>12} {:>12} {:>9} {:>9}",
        "model", "l", "PIM tok/s", "TPU tok/s", "speedup", "paper"
    );
    for r in rows {
        println!(
            "{:<12} {:>7} {:>12} {:>12} {:>8.2}x {:>9}",
            r.model,
            r.context,
            fmt_si(r.pim_llm_tokens_per_s),
            fmt_si(r.tpu_llm_tokens_per_s),
            r.speedup,
            r.paper_speedup
                .map(|s| format!("{s:.2}x"))
                .unwrap_or_else(|| "-".into()),
        );
    }
}

pub fn print_fig6(rows: &[Fig6Row]) {
    println!("Fig. 6 — latency breakdown (%) of PIM-LLM");
    for r in rows {
        let parts: Vec<String> = r
            .percents
            .iter()
            .filter(|(_, v)| *v > 0.005)
            .map(|(k, v)| format!("{k}={v:.2}%"))
            .collect();
        println!("{:<12} l={:<6} {}", r.model, r.context, parts.join(" "));
    }
    println!("paper reference points:");
    for (m, l, comp, pct) in paper_fig6_reference() {
        println!("  {m} l={l}: {comp} = {pct}%");
    }
}

pub fn print_fig7(rows: &[Fig7Row]) {
    println!("Fig. 7 — tokens/joule (PIM-LLM vs TPU-LLM)");
    println!(
        "{:<12} {:>7} {:>12} {:>12} {:>9} {:>9}",
        "model", "l", "PIM tok/J", "TPU tok/J", "gain%", "paper%"
    );
    for r in rows {
        println!(
            "{:<12} {:>7} {:>12} {:>12} {:>8.2}% {:>9}",
            r.model,
            r.context,
            fmt_si(r.pim_llm_tokens_per_j),
            fmt_si(r.tpu_llm_tokens_per_j),
            r.gain_pct,
            r.paper_gain_pct
                .map(|s| format!("{s:.2}%"))
                .unwrap_or_else(|| "-".into()),
        );
    }
}

pub fn print_fig8(rows: &[Fig8Row]) {
    println!("Fig. 8 — words per battery life (5 Wh, 1.5 tok/word)");
    println!(
        "{:<12} {:>7} {:>12} {:>12} {:>11} {:>11}",
        "model", "l", "PIM words", "TPU words", "paper(PIM)", "paper(TPU)"
    );
    for r in rows {
        println!(
            "{:<12} {:>7} {:>12} {:>12} {:>11} {:>11}",
            r.model,
            r.context,
            fmt_si(r.pim_llm_words),
            fmt_si(r.tpu_llm_words),
            r.paper_pim_words.map(fmt_si).unwrap_or_else(|| "-".into()),
            r.paper_tpu_words.map(fmt_si).unwrap_or_else(|| "-".into()),
        );
    }
}

pub fn print_table3(rows: &[Table3Row]) {
    println!("Table III — comparison with previous PIM accelerators");
    println!(
        "{:<16} {:<12} {:>6} {:>9} {:>9} {:>10} {:>10}",
        "design", "model", "l", "GOPS", "GOPS/W", "paperGOPS", "paperG/W"
    );
    for r in rows {
        let f = |o: Option<f64>| o.map(|v| format!("{v:.2}")).unwrap_or_else(|| "-".into());
        println!(
            "{:<16} {:<12} {:>6} {:>9} {:>9} {:>10} {:>10}",
            r.design,
            r.model,
            r.context,
            f(r.gops),
            f(r.gops_per_w),
            f(r.paper_gops),
            f(r.paper_gops_per_w),
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_si_ranges() {
        assert_eq!(fmt_si(1_600_000.0), "1.60M");
        assert_eq!(fmt_si(1500.0), "1.50k");
        assert_eq!(fmt_si(12.345), "12.35");
        assert_eq!(fmt_si(0.5), "0.5000");
    }

    #[test]
    fn printers_do_not_panic() {
        let arch = crate::config::ArchConfig::paper_45nm();
        print_fig1b(&fig1b(&arch));
        print_fig4(&fig4(&arch));
        print_table3(&table3(&arch));
    }
}
