//! One generator per paper figure/table. Each returns structured rows
//! so benches, the CLI and tests all consume the same data.

use crate::config::ArchConfig;
use crate::coordinator::{self, Arch};
use crate::models::{self, LlmConfig, CONTEXT_LENGTHS};
use crate::systolic::dataflow::{decode_step_cycles, Dataflow};
use crate::util::par::parallel_map;

// ------------------------------------------------------------- Fig. 1b
/// Fig. 1b: percentage of low-precision MatMul operations across OPT
/// models and context lengths.
#[derive(Debug, Clone, PartialEq)]
pub struct Fig1bRow {
    pub model: String,
    pub context: usize,
    pub low_precision_pct: f64,
}

pub fn fig1b(_arch: &ArchConfig) -> Vec<Fig1bRow> {
    let opts = ["OPT-350M", "OPT-1.3B", "OPT-2.7B", "OPT-6.7B"];
    let mut rows = Vec::new();
    for name in opts {
        let m = models::by_name(name).expect("known model");
        for l in CONTEXT_LENGTHS {
            rows.push(Fig1bRow {
                model: m.name.clone(),
                context: l,
                low_precision_pct: 100.0 * m.low_precision_fraction(l),
            });
        }
    }
    rows
}

// -------------------------------------------------------------- Fig. 4
/// Fig. 4: total decode-step cycles on a 32x32 array per dataflow.
#[derive(Debug, Clone, PartialEq)]
pub struct Fig4Row {
    pub model: String,
    pub dataflow: String,
    pub cycles: u64,
}

/// The paper plots per-model totals; we use l = 1024 (mid-range).
pub const FIG4_CONTEXT: usize = 1024;

pub fn fig4(arch: &ArchConfig) -> Vec<Fig4Row> {
    let mut rows = Vec::new();
    for m in models::table2_models() {
        for df in Dataflow::ALL {
            rows.push(Fig4Row {
                model: m.name.clone(),
                dataflow: df.short_name().to_string(),
                cycles: decode_step_cycles(&m, FIG4_CONTEXT, arch.tpu.rows, arch.tpu.cols, df),
            });
        }
    }
    rows
}

// -------------------------------------------------------------- Fig. 5
/// Fig. 5: tokens/s for PIM-LLM and TPU-LLM + the speedup annotation.
#[derive(Debug, Clone, PartialEq)]
pub struct Fig5Row {
    pub model: String,
    pub context: usize,
    pub pim_llm_tokens_per_s: f64,
    pub tpu_llm_tokens_per_s: f64,
    pub speedup: f64,
    /// Speedup the paper states for this point, if stated.
    pub paper_speedup: Option<f64>,
}

/// Speedups the paper calls out in §IV-A.
pub fn paper_fig5_speedup(model: &str, l: usize) -> Option<f64> {
    match (model, l) {
        ("GPT2-355M", 128) => Some(11.6),
        ("OPT-6.7B", 128) => Some(79.2),
        ("GPT2-355M", 4096) => Some(1.5),
        ("OPT-6.7B", 4096) => Some(5.71),
        _ => None,
    }
}

pub fn fig5(arch: &ArchConfig) -> Vec<Fig5Row> {
    let points: Vec<(LlmConfig, usize)> = models::table2_models()
        .into_iter()
        .flat_map(|m| CONTEXT_LENGTHS.into_iter().map(move |l| (m.clone(), l)))
        .collect();
    parallel_map(&points, |(m, l)| {
            let p = coordinator::simulate(arch, m, *l, Arch::PimLlm);
            let t = coordinator::simulate(arch, m, *l, Arch::TpuLlm);
            Fig5Row {
                model: m.name.clone(),
                context: *l,
                pim_llm_tokens_per_s: p.metrics().tokens_per_s(),
                tpu_llm_tokens_per_s: t.metrics().tokens_per_s(),
                speedup: t.latency_s() / p.latency_s(),
                paper_speedup: paper_fig5_speedup(&m.name, *l),
            }
    })
}

// -------------------------------------------------------------- Fig. 6
/// Fig. 6: latency percentage breakdown of the hybrid at l=128 and 4096.
#[derive(Debug, Clone, PartialEq)]
pub struct Fig6Row {
    pub model: String,
    pub context: usize,
    /// (component, percent) in figure legend order.
    pub percents: Vec<(String, f64)>,
}

/// Reference percentages stated in §IV-B.
pub fn paper_fig6_reference() -> Vec<(&'static str, usize, &'static str, f64)> {
    vec![
        ("OPT-6.7B", 128, "systolic", 60.0),
        ("GPT2-355M", 128, "systolic", 73.9),
        ("OPT-6.7B", 128, "communication", 36.3),
        ("GPT2-355M", 128, "communication", 10.7),
        ("GPT2-355M", 128, "buffer", 14.7),
        ("OPT-6.7B", 128, "buffer", 3.5),
        ("OPT-6.7B", 4096, "systolic", 97.0),
        ("GPT2-355M", 4096, "systolic", 97.0),
    ]
}

pub fn fig6(arch: &ArchConfig) -> Vec<Fig6Row> {
    let mut rows = Vec::new();
    for l in [128usize, 4096] {
        for m in models::table2_models() {
            let r = coordinator::simulate(arch, &m, l, Arch::PimLlm);
            let percents = r
                .breakdown
                .fractions()
                .as_vec()
                .into_iter()
                .map(|(k, v)| (k.to_string(), 100.0 * v))
                .collect();
            rows.push(Fig6Row {
                model: m.name.clone(),
                context: l,
                percents,
            });
        }
    }
    rows
}

// -------------------------------------------------------------- Fig. 7
/// Fig. 7: tokens per joule for both architectures.
#[derive(Debug, Clone, PartialEq)]
pub struct Fig7Row {
    pub model: String,
    pub context: usize,
    pub pim_llm_tokens_per_j: f64,
    pub tpu_llm_tokens_per_j: f64,
    /// PIM-LLM efficiency gain over TPU-LLM, percent (negative = TPU
    /// better).
    pub gain_pct: f64,
    pub paper_gain_pct: Option<f64>,
}

/// Gains the paper states in §IV-C (negative: TPU-LLM more efficient).
pub fn paper_fig7_gain(model: &str, l: usize) -> Option<f64> {
    match (model, l) {
        // "TPU delivers 33.7% lower energy consumption" => tokens/J gain
        // of PIM over TPU is 1/1.337 - 1 = -25.2%.
        ("GPT2-355M", 128) => Some(-25.2),
        ("OPT-1.3B", 128) => Some(0.96),
        ("OPT-6.7B", 128) => Some(12.49),
        ("GPT2-355M", 2048) => Some(17.95),
        ("OPT-6.7B", 2048) => Some(22.79),
        ("GPT2-355M", 4096) => Some(70.58),
        ("OPT-6.7B", 4096) => Some(33.7),
        _ => None,
    }
}

pub fn fig7(arch: &ArchConfig) -> Vec<Fig7Row> {
    let points: Vec<(LlmConfig, usize)> = models::table2_models()
        .into_iter()
        .flat_map(|m| CONTEXT_LENGTHS.into_iter().map(move |l| (m.clone(), l)))
        .collect();
    parallel_map(&points, |(m, l)| {
            let p = coordinator::simulate(arch, m, *l, Arch::PimLlm);
            let t = coordinator::simulate(arch, m, *l, Arch::TpuLlm);
            let pj = p.metrics().tokens_per_joule();
            let tj = t.metrics().tokens_per_joule();
            Fig7Row {
                model: m.name.clone(),
                context: *l,
                pim_llm_tokens_per_j: pj,
                tpu_llm_tokens_per_j: tj,
                gain_pct: 100.0 * (pj / tj - 1.0),
                paper_gain_pct: paper_fig7_gain(&m.name, *l),
            }
    })
}

// -------------------------------------------------------------- Fig. 8
/// Fig. 8: Words per Battery Life (5 Wh, 1.5 tok/word).
#[derive(Debug, Clone, PartialEq)]
pub struct Fig8Row {
    pub model: String,
    pub context: usize,
    pub pim_llm_words: f64,
    pub tpu_llm_words: f64,
    pub paper_pim_words: Option<f64>,
    pub paper_tpu_words: Option<f64>,
}

/// Words/battery the paper states in §IV-D.
pub fn paper_fig8_words(model: &str, l: usize) -> (Option<f64>, Option<f64>) {
    match (model, l) {
        ("OPT-6.7B", 128) => (Some(1.6e6), Some(1.4e6)),
        ("GPT2-355M", 4096) => (Some(35.0e6), Some(20.0e6)),
        ("OPT-6.7B", 4096) => (Some(1.6e6), Some(1.2e6)),
        _ => (None, None),
    }
}

pub fn fig8(arch: &ArchConfig) -> Vec<Fig8Row> {
    let points: Vec<(LlmConfig, usize)> = models::table2_models()
        .into_iter()
        .flat_map(|m| CONTEXT_LENGTHS.into_iter().map(move |l| (m.clone(), l)))
        .collect();
    parallel_map(&points, |(m, l)| {
            let p = coordinator::simulate(arch, m, *l, Arch::PimLlm);
            let t = coordinator::simulate(arch, m, *l, Arch::TpuLlm);
            let (pp, pt) = paper_fig8_words(&m.name, *l);
            Fig8Row {
                model: m.name.clone(),
                context: *l,
                pim_llm_words: p.metrics().words_per_battery(),
                tpu_llm_words: t.metrics().words_per_battery(),
                paper_pim_words: pp,
                paper_tpu_words: pt,
            }
    })
}

// ------------------------------------------------------------ Table III
/// Table III: GOPS and GOPS/W of PIM-LLM vs prior PIM accelerators.
#[derive(Debug, Clone, PartialEq)]
pub struct Table3Row {
    pub design: String,
    pub model: String,
    pub context: usize,
    pub gops: Option<f64>,
    pub gops_per_w: Option<f64>,
    pub paper_gops: Option<f64>,
    pub paper_gops_per_w: Option<f64>,
}

pub fn table3(arch: &ArchConfig) -> Vec<Table3Row> {
    // Literature baselines (taken from the papers, as PIM-LLM does).
    let mut rows = vec![
        Table3Row {
            design: "TransPIM [18]".into(),
            model: "GPT2-Medium".into(),
            context: 4096,
            gops: None,
            gops_per_w: Some(200.0), // "< 200"
            paper_gops: None,
            paper_gops_per_w: Some(200.0),
        },
        Table3Row {
            design: "HARDSEA [26]".into(),
            model: "GPT2-Small".into(),
            context: 1024,
            gops: Some(3.2),
            gops_per_w: None,
            paper_gops: Some(3.2),
            paper_gops_per_w: None,
        },
    ];
    let points = [
        ("GPT2-Small", 1024usize, Some(6.47), Some(487.4)),
        ("GPT2-Medium", 4096, Some(3.7), Some(1026.0)),
        ("OPT-6.7B", 1024, Some(58.5), Some(1134.14)),
        ("OPT-6.7B", 4096, Some(17.6), Some(1262.72)),
    ];
    for (name, l, paper_gops, paper_gpw) in points {
        let m = models::by_name(name).expect("known model");
        let r = coordinator::simulate(arch, &m, l, Arch::PimLlm);
        let met = r.metrics();
        rows.push(Table3Row {
            design: "PIM-LLM (ours)".into(),
            model: m.name.clone(),
            context: l,
            gops: Some(met.gops()),
            gops_per_w: Some(met.gops_per_w()),
            paper_gops,
            paper_gops_per_w: paper_gpw,
        });
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    fn arch() -> ArchConfig {
        ArchConfig::paper_45nm()
    }

    #[test]
    fn fig1b_has_all_points_and_valid_range() {
        let rows = fig1b(&arch());
        assert_eq!(rows.len(), 4 * CONTEXT_LENGTHS.len());
        for r in &rows {
            assert!(r.low_precision_pct > 0.0 && r.low_precision_pct < 100.0);
        }
        // The "evenly distributed" point.
        let r = rows
            .iter()
            .find(|r| r.model == "OPT-350M" && r.context == 4096)
            .unwrap();
        assert!(r.low_precision_pct < 70.0);
    }

    #[test]
    fn fig4_os_lowest_everywhere() {
        let rows = fig4(&arch());
        for m in models::table2_models() {
            let get = |df: &str| {
                rows.iter()
                    .find(|r| r.model == m.name && r.dataflow == df)
                    .unwrap()
                    .cycles
            };
            assert!(get("OS") < get("WS"), "{}", m.name);
            assert!(get("OS") < get("IS"), "{}", m.name);
        }
    }

    #[test]
    fn fig5_speedup_matches_paper_within_15pct() {
        for r in fig5(&arch()) {
            if let Some(ps) = r.paper_speedup {
                let rel = (r.speedup - ps).abs() / ps;
                assert!(rel < 0.15, "{} l={}: {} vs paper {}", r.model, r.context, r.speedup, ps);
            }
        }
    }

    #[test]
    fn fig6_percents_sum_to_100() {
        for r in fig6(&arch()) {
            let sum: f64 = r.percents.iter().map(|(_, v)| v).sum();
            assert!((sum - 100.0).abs() < 1e-6, "{} {}", r.model, r.context);
        }
    }

    #[test]
    fn fig8_consistent_with_fig7() {
        // words/battery must equal 18000 * tokens_per_j / 1.5.
        let a = arch();
        let f7 = fig7(&a);
        let f8 = fig8(&a);
        for (r7, r8) in f7.iter().zip(f8.iter()) {
            assert_eq!(r7.model, r8.model);
            let want = 18_000.0 * r7.pim_llm_tokens_per_j / 1.5;
            assert!((r8.pim_llm_words - want).abs() / want < 1e-9);
        }
    }

    #[test]
    fn table3_has_ours_and_baselines() {
        let rows = table3(&arch());
        assert!(rows.iter().any(|r| r.design.contains("TransPIM")));
        assert!(rows.iter().any(|r| r.design.contains("HARDSEA")));
        let ours: Vec<_> = rows.iter().filter(|r| r.design.contains("ours")).collect();
        assert_eq!(ours.len(), 4);
        // GOPS beats HARDSEA's 3.2 on the same workload (paper: 2x).
        let small = ours
            .iter()
            .find(|r| r.model == "GPT2-Small" && r.context == 1024)
            .unwrap();
        assert!(small.gops.unwrap() > 2.0 * 3.2 * 0.8, "{:?}", small.gops);
    }
}
