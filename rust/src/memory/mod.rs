//! Off-chip LPDDR and on-chip SRAM models.
//!
//! The paper preloads all data into LPDDR; the TPU's dataflow generator
//! produces read traces that stream inputs/weights into the input/weight
//! SRAMs, and the PIM controller moves activations between LPDDR and the
//! PIM banks. We model both as bandwidth/energy resources.

use crate::config::{LpddrConfig, TpuConfig};

/// One memory transfer accounted against a channel.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Transfer {
    pub bytes: u64,
    pub latency_s: f64,
    pub energy_j: f64,
}

/// Stream `bytes` over the LPDDR channel.
pub fn lpddr_transfer(cfg: &LpddrConfig, bytes: u64) -> Transfer {
    Transfer {
        bytes,
        latency_s: bytes as f64 / cfg.bandwidth_bytes_per_s,
        energy_j: bytes as f64 * cfg.energy_per_byte_j,
    }
}

/// SRAM access energy for `bytes` (reads + writes symmetric).
pub fn sram_energy(cfg: &TpuConfig, bytes: u64) -> f64 {
    bytes as f64 * cfg.sram_energy_per_byte_j
}

/// Does the working set of a model's weights fit in TPU SRAM? Decides
/// whether the TPU-LLM baseline must re-stream weights per token.
pub fn weights_fit_in_sram(cfg: &TpuConfig, weight_bytes: u64) -> bool {
    weight_bytes <= cfg.sram_bytes as u64
}

/// Double-buffered streaming: compute and memory overlap; effective time
/// is the max of the two plus one buffer fill ramp.
pub fn overlapped_time_s(compute_s: f64, memory_s: f64, ramp_s: f64) -> f64 {
    compute_s.max(memory_s) + ramp_s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ArchConfig;

    #[test]
    fn lpddr_latency_linear() {
        let cfg = ArchConfig::paper_45nm().lpddr;
        let a = lpddr_transfer(&cfg, 1 << 20);
        let b = lpddr_transfer(&cfg, 1 << 21);
        assert!((b.latency_s - 2.0 * a.latency_s).abs() < 1e-12);
        assert!((b.energy_j - 2.0 * a.energy_j).abs() < 1e-15);
    }

    #[test]
    fn tiny_model_fits_sram_large_does_not() {
        let tpu = ArchConfig::paper_45nm().tpu;
        assert!(weights_fit_in_sram(&tpu, 2 * 1024 * 1024));
        // OPT-6.7B int8 weights are ~6.4 GB.
        assert!(!weights_fit_in_sram(&tpu, 6_400_000_000));
    }

    #[test]
    fn overlap_hides_shorter_stream() {
        assert_eq!(overlapped_time_s(10.0, 3.0, 0.5), 10.5);
        assert_eq!(overlapped_time_s(3.0, 10.0, 0.5), 10.5);
    }
}
