//! Per-component energy accounting + the paper's derived efficiency
//! metrics: tokens/joule (Fig. 7) and Words/Battery-Life (Fig. 8: a 5 Wh
//! = 18,000 J edge battery at 1.5 tokens per word).

use std::ops::{Add, AddAssign};

/// Paper §IV-D battery capacity: 5 Wh.
pub const BATTERY_JOULES: f64 = 18_000.0;
/// Paper §IV-D tokenizer ratio: 1.5 tokens per word.
pub const TOKENS_PER_WORD: f64 = 1.5;

/// Energy ledger, itemized by architecture component (joules).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct EnergyLedger {
    /// Systolic-array MACs + its SRAM traffic.
    pub systolic_j: f64,
    /// TPU static/leakage over the step's wall time.
    pub tpu_static_j: f64,
    /// Crossbar analog reads.
    pub xbar_j: f64,
    /// Input drivers (DAC).
    pub dac_j: f64,
    /// ADC conversions.
    pub adc_j: f64,
    /// PIM fixed controller/peripheral energy.
    pub pim_fixed_j: f64,
    /// NoC traffic.
    pub noc_j: f64,
    /// Tile input/output buffers.
    pub buffer_j: f64,
    /// LPDDR traffic (weights for the baseline, KV for both).
    pub lpddr_j: f64,
    /// Nonlinear functional units.
    pub nonlinear_j: f64,
    /// Main controller + dataflow generator / scheduler sequencing (per
    /// decoder layer, both architectures).
    pub controller_j: f64,
}

impl EnergyLedger {
    pub fn total_j(&self) -> f64 {
        self.systolic_j
            + self.tpu_static_j
            + self.xbar_j
            + self.dac_j
            + self.adc_j
            + self.pim_fixed_j
            + self.noc_j
            + self.buffer_j
            + self.lpddr_j
            + self.nonlinear_j
            + self.controller_j
    }

    /// (label, joules) pairs for reporting, in a stable order.
    pub fn items(&self) -> Vec<(&'static str, f64)> {
        vec![
            ("systolic", self.systolic_j),
            ("tpu_static", self.tpu_static_j),
            ("xbar", self.xbar_j),
            ("dac", self.dac_j),
            ("adc", self.adc_j),
            ("pim_fixed", self.pim_fixed_j),
            ("noc", self.noc_j),
            ("buffer", self.buffer_j),
            ("lpddr", self.lpddr_j),
            ("nonlinear", self.nonlinear_j),
            ("controller", self.controller_j),
        ]
    }
}

impl Add for EnergyLedger {
    type Output = EnergyLedger;
    fn add(self, o: EnergyLedger) -> EnergyLedger {
        EnergyLedger {
            systolic_j: self.systolic_j + o.systolic_j,
            tpu_static_j: self.tpu_static_j + o.tpu_static_j,
            xbar_j: self.xbar_j + o.xbar_j,
            dac_j: self.dac_j + o.dac_j,
            adc_j: self.adc_j + o.adc_j,
            pim_fixed_j: self.pim_fixed_j + o.pim_fixed_j,
            noc_j: self.noc_j + o.noc_j,
            buffer_j: self.buffer_j + o.buffer_j,
            lpddr_j: self.lpddr_j + o.lpddr_j,
            nonlinear_j: self.nonlinear_j + o.nonlinear_j,
            controller_j: self.controller_j + o.controller_j,
        }
    }
}

impl AddAssign for EnergyLedger {
    fn add_assign(&mut self, o: EnergyLedger) {
        *self = *self + o;
    }
}

/// Throughput/efficiency metrics for one (model, context, architecture)
/// point — the quantities in Figs. 5, 7, 8 and Table III.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Metrics {
    pub token_latency_s: f64,
    pub token_energy_j: f64,
    pub macs_per_token: u64,
}

impl Metrics {
    pub fn tokens_per_s(&self) -> f64 {
        1.0 / self.token_latency_s
    }

    pub fn tokens_per_joule(&self) -> f64 {
        1.0 / self.token_energy_j
    }

    /// Words generated on one 5 Wh battery (Fig. 8).
    pub fn words_per_battery(&self) -> f64 {
        BATTERY_JOULES * self.tokens_per_joule() / TOKENS_PER_WORD
    }

    /// Giga-ops per second. The paper counts one MAC as one op (verified
    /// against Table III: OPT-6.7B @ l=4096 gives 17.6 GOPS only under
    /// this convention).
    pub fn gops(&self) -> f64 {
        self.macs_per_token as f64 / self.token_latency_s / 1e9
    }

    /// GOPS per watt = (MACs/token) / (J/token) / 1e9.
    pub fn gops_per_w(&self) -> f64 {
        self.macs_per_token as f64 / self.token_energy_j / 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m() -> Metrics {
        Metrics {
            token_latency_s: 0.025,
            token_energy_j: 0.0075,
            macs_per_token: 6_470_000_000,
        }
    }

    #[test]
    fn tokens_per_s_inverse_of_latency() {
        assert!((m().tokens_per_s() - 40.0).abs() < 1e-9);
    }

    #[test]
    fn words_per_battery_formula() {
        // 18000 J * (1/0.0075 tok/J) / 1.5 tok/word = 1.6M words.
        assert!((m().words_per_battery() - 1_600_000.0).abs() < 1.0);
    }

    #[test]
    fn gops_counts_macs_as_ops() {
        let g = m().gops();
        assert!((g - 6.47e9 / 0.025 / 1e9).abs() < 1e-9);
    }

    #[test]
    fn ledger_total_is_sum_of_items() {
        let mut l = EnergyLedger::default();
        l.systolic_j = 1.0;
        l.adc_j = 2.0;
        l.lpddr_j = 0.5;
        let items_sum: f64 = l.items().iter().map(|(_, v)| v).sum();
        assert!((l.total_j() - items_sum).abs() < 1e-12);
        assert!((l.total_j() - 3.5).abs() < 1e-12);
    }

    #[test]
    fn ledger_add_componentwise() {
        let a = EnergyLedger {
            systolic_j: 1.0,
            ..Default::default()
        };
        let b = EnergyLedger {
            systolic_j: 2.0,
            noc_j: 3.0,
            ..Default::default()
        };
        let c = a + b;
        assert_eq!(c.systolic_j, 3.0);
        assert_eq!(c.noc_j, 3.0);
    }
}
