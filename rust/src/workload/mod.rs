//! Workload enumeration: turns an [`LlmConfig`] + context length into the
//! exact list of MatMul (MVM) operations one token-generation step
//! executes, with paper Table I dimensions and the W1A8/W8A8 precision
//! split of Fig. 1a.
//!
//! This is the contract between the model zoo and both schedulers: the
//! hybrid coordinator routes each op by its [`Precision`], the TPU-LLM
//! baseline runs them all on the systolic array.

use crate::models::LlmConfig;

/// Which part of the decoder an op belongs to (paper Fig. 1a / Table I).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpKind {
    /// W_Q / W_K / W_V input projections (d x d).
    QkvProjection,
    /// W_X output projection after head concat (d x d).
    OutProjection,
    /// Score = Q.K^T inside a head: (l x d/h).(d/h x 1).
    AttentionScore,
    /// V.Score inside a head: (d/h x l).(l x 1).
    AttentionValue,
    /// Intermediate FF: (d_FF x d).(d x 1).
    FfIntermediate,
    /// Output FF: (d x d_FF).(d_FF x 1).
    FfOutput,
    /// LM head (vocab projection) — not in Table I; excluded from op
    /// enumeration by default to match the paper's accounting, but kept
    /// for the functional runtime.
    LmHead,
}

/// Numeric precision of an op — decides PIM vs systolic-array placement.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Precision {
    /// 1-bit (ternary) weights, 8-bit activations: projection layers.
    W1A8,
    /// 8-bit activation-to-activation: attention heads.
    W8A8,
}

/// One matrix-vector multiplication, GEMM convention (M x K).(K x N).
/// Decoder inference makes N = 1 everywhere (one token per iteration).
#[derive(Debug, Clone, PartialEq)]
pub struct MatMulOp {
    /// Decoder block index this op belongs to.
    pub layer: usize,
    /// Head index for attention ops (None for projections).
    pub head: Option<usize>,
    pub kind: OpKind,
    pub precision: Precision,
    pub m: usize,
    pub k: usize,
    pub n: usize,
}

impl MatMulOp {
    /// Multiply-accumulate count.
    pub fn macs(&self) -> u64 {
        self.m as u64 * self.k as u64 * self.n as u64
    }

    /// Weight-operand bytes at int8 (for the TPU path) — the stationary
    /// matrix of the op.
    pub fn weight_bytes_int8(&self) -> u64 {
        self.m as u64 * self.k as u64
    }

    pub fn is_attention(&self) -> bool {
        matches!(self.kind, OpKind::AttentionScore | OpKind::AttentionValue)
    }
}

/// The full op list of one decode step (one generated token) at context
/// length `l`, in execution order, paper Table I dimensions.
///
/// Projections are enumerated as (d_out x d_in).(d_in x 1) with
/// M = d_out: the MVM orientation where the weight matrix is stationary.
pub fn decode_ops(model: &LlmConfig, l: usize) -> Vec<MatMulOp> {
    let (d, dff, dh) = (model.d, model.d_ff, model.d_head());
    let mut ops = Vec::with_capacity(model.n_layers * (6 + 2 * model.h));
    for layer in 0..model.n_layers {
        // Q, K, V projections (W1A8, PIM side).
        for _ in 0..3 {
            ops.push(MatMulOp {
                layer,
                head: None,
                kind: OpKind::QkvProjection,
                precision: Precision::W1A8,
                m: d,
                k: d,
                n: 1,
            });
        }
        // Attention heads (W8A8, systolic-array side).
        for head in 0..model.h {
            ops.push(MatMulOp {
                layer,
                head: Some(head),
                kind: OpKind::AttentionScore,
                precision: Precision::W8A8,
                m: l,
                k: dh,
                n: 1,
            });
            ops.push(MatMulOp {
                layer,
                head: Some(head),
                kind: OpKind::AttentionValue,
                precision: Precision::W8A8,
                m: dh,
                k: l,
                n: 1,
            });
        }
        // Output projection.
        ops.push(MatMulOp {
            layer,
            head: None,
            kind: OpKind::OutProjection,
            precision: Precision::W1A8,
            m: d,
            k: d,
            n: 1,
        });
        // Feed-forward projections.
        ops.push(MatMulOp {
            layer,
            head: None,
            kind: OpKind::FfIntermediate,
            precision: Precision::W1A8,
            m: dff,
            k: d,
            n: 1,
        });
        ops.push(MatMulOp {
            layer,
            head: None,
            kind: OpKind::FfOutput,
            precision: Precision::W1A8,
            m: d,
            k: dff,
            n: 1,
        });
    }
    ops
}

/// Summary statistics over an op list.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WorkloadStats {
    pub total_macs: u64,
    pub w1a8_macs: u64,
    pub w8a8_macs: u64,
    pub n_ops: usize,
    pub n_w1a8_ops: usize,
    pub n_w8a8_ops: usize,
}

impl WorkloadStats {
    pub fn low_precision_fraction(&self) -> f64 {
        self.w1a8_macs as f64 / self.total_macs as f64
    }
}

/// Weight-sparsity census of a ternary parameter tensor: how many
/// entries are exactly zero. This is the measured number behind every
/// "ternary weights are sparse" claim in the codebase — the dense
/// `bitlinear` kernel pays a full multiply for each zero, the packed
/// bitplane backend (`crate::quant`) skips them for free, and the
/// `runtime_packed` bench reports it per model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SparsityStats {
    /// Entries that are exactly 0.0.
    pub zeros: u64,
    /// Total entries counted.
    pub total: u64,
}

impl SparsityStats {
    /// Zero fraction in [0, 1] (0 for an empty census).
    pub fn fraction(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.zeros as f64 / self.total as f64
        }
    }

    /// Merge another census into this one.
    pub fn merge(&mut self, other: SparsityStats) {
        self.zeros += other.zeros;
        self.total += other.total;
    }
}

/// Measure the zero fraction of a ternary weight tensor (entries are
/// expected in {-1, 0, +1}, but any exact 0.0 counts).
pub fn ternary_sparsity(weights: &[f32]) -> SparsityStats {
    SparsityStats {
        zeros: weights.iter().filter(|&&w| w == 0.0).count() as u64,
        total: weights.len() as u64,
    }
}

/// Whether a manifest parameter is one of the ternary projection
/// matrices (wq/wk/wv/wx/w_in/w_out/w_head). In this model family the
/// embedding is the only 2-D parameter that is NOT ternary; gammas are
/// 1-D and scales are scalars. Shared by the sparsity censuses here and
/// in the `runtime_packed` bench so the sites cannot drift from each
/// other (the `quant` lowering resolves the same set by explicit name
/// because it needs the paired `*_scale` parameters anyway).
pub fn is_ternary_param(p: &crate::runtime::artifacts::ParamEntry) -> bool {
    p.shape.len() == 2 && p.name != "embedding"
}

/// Expected zero fraction of BitNet-b1.58 ternary quantization applied
/// to Gaussian master weights. With `scale = mean(|W|)` and
/// `W_q = clip(round(W / scale), -1, 1)`, an entry quantizes to zero
/// iff `|W| < scale / 2`; for `W ~ N(0, sigma^2)`,
/// `mean(|W|) = sigma * sqrt(2/pi)`, so
/// `P(zero) = P(|Z| < sqrt(2/pi)/2) = erf(1 / (2 sqrt(pi))) ~= 0.3101`.
/// Measured per model by [`ternary_sparsity`]; the `runtime_packed`
/// bench prints both side by side.
pub const EXPECTED_TERNARY_SPARSITY: f64 = 0.3101;

/// Compute stats for one decode step.
pub fn stats(ops: &[MatMulOp]) -> WorkloadStats {
    let mut s = WorkloadStats {
        total_macs: 0,
        w1a8_macs: 0,
        w8a8_macs: 0,
        n_ops: ops.len(),
        n_w1a8_ops: 0,
        n_w8a8_ops: 0,
    };
    for op in ops {
        let macs = op.macs();
        s.total_macs += macs;
        match op.precision {
            Precision::W1A8 => {
                s.w1a8_macs += macs;
                s.n_w1a8_ops += 1;
            }
            Precision::W8A8 => {
                s.w8a8_macs += macs;
                s.n_w8a8_ops += 1;
            }
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{by_name, extra_models, table2_models, CONTEXT_LENGTHS};

    #[test]
    fn op_count_matches_structure() {
        let m = by_name("GPT2-355M").unwrap();
        let ops = decode_ops(&m, 128);
        // per layer: 3 qkv + 2*h attention + 1 out + 2 ff
        assert_eq!(ops.len(), m.n_layers * (6 + 2 * m.h));
    }

    #[test]
    fn macs_agree_with_closed_form_for_whole_zoo_at_every_context() {
        // The enumerated op list is the contract between the model zoo
        // and both schedulers: its MAC totals must equal the closed-form
        // projection/attention formulas for EVERY model (Table II plus
        // the Fig. 1b / Table III extras) at EVERY paper context point.
        let zoo: Vec<_> = table2_models()
            .into_iter()
            .chain(extra_models())
            .collect();
        assert_eq!(zoo.len(), 10);
        for m in &zoo {
            for l in CONTEXT_LENGTHS {
                let ops = decode_ops(m, l);
                let s = stats(&ops);
                assert_eq!(s.w1a8_macs, m.projection_macs(), "{} proj @ {l}", m.name);
                assert_eq!(s.w8a8_macs, m.attention_macs(l), "{} att @ {l}", m.name);
                assert_eq!(s.total_macs, m.total_macs(l), "{} total @ {l}", m.name);
                assert_eq!(s.n_ops, m.n_layers * (6 + 2 * m.h), "{} ops @ {l}", m.name);
                assert_eq!(s.n_w1a8_ops, m.n_layers * 6, "{} w1a8 ops @ {l}", m.name);
                assert_eq!(s.n_w8a8_ops, m.n_layers * 2 * m.h, "{} w8a8 ops @ {l}", m.name);
            }
        }
    }

    #[test]
    fn execution_order_respects_dependency_chain() {
        // Within every layer the op list must follow the decoder's data
        // dependencies: the three QKV projections (which produce the
        // head inputs), then per-head AttentionScore immediately
        // followed by its AttentionValue (score feeds value), then the
        // output projection over the concatenated heads, then the two
        // feed-forward projections in order; layers strictly ascending.
        for m in table2_models().iter().chain(extra_models().iter()) {
            let ops = decode_ops(m, 512);
            let mut it = ops.iter();
            for layer in 0..m.n_layers {
                for slot in 0..3 {
                    let op = it.next().expect("qkv op");
                    assert_eq!(
                        (op.layer, op.kind, op.head),
                        (layer, OpKind::QkvProjection, None),
                        "{} layer {layer} qkv slot {slot}",
                        m.name
                    );
                }
                for head in 0..m.h {
                    let score = it.next().expect("score op");
                    assert_eq!(
                        (score.layer, score.kind, score.head),
                        (layer, OpKind::AttentionScore, Some(head)),
                        "{} layer {layer} head {head}",
                        m.name
                    );
                    let value = it.next().expect("value op");
                    assert_eq!(
                        (value.layer, value.kind, value.head),
                        (layer, OpKind::AttentionValue, Some(head)),
                        "{} layer {layer} head {head}",
                        m.name
                    );
                }
                for kind in [OpKind::OutProjection, OpKind::FfIntermediate, OpKind::FfOutput] {
                    let op = it.next().expect("tail op");
                    assert_eq!(
                        (op.layer, op.kind, op.head),
                        (layer, kind, None),
                        "{} layer {layer}",
                        m.name
                    );
                }
            }
            assert!(it.next().is_none(), "{}: trailing ops", m.name);
        }
    }

    #[test]
    fn table1_dimensions() {
        let m = by_name("OPT-6.7B").unwrap();
        let ops = decode_ops(&m, 2048);
        let score = ops.iter().find(|o| o.kind == OpKind::AttentionScore).unwrap();
        assert_eq!((score.m, score.k, score.n), (2048, 128, 1));
        let val = ops.iter().find(|o| o.kind == OpKind::AttentionValue).unwrap();
        assert_eq!((val.m, val.k, val.n), (128, 2048, 1));
        let ffi = ops.iter().find(|o| o.kind == OpKind::FfIntermediate).unwrap();
        assert_eq!((ffi.m, ffi.k, ffi.n), (16384, 4096, 1));
        let ffo = ops.iter().find(|o| o.kind == OpKind::FfOutput).unwrap();
        assert_eq!((ffo.m, ffo.k, ffo.n), (4096, 16384, 1));
    }

    #[test]
    fn precision_split_is_exact() {
        let m = by_name("OPT-1.3B").unwrap();
        for op in decode_ops(&m, 512) {
            match op.kind {
                OpKind::AttentionScore | OpKind::AttentionValue => {
                    assert_eq!(op.precision, Precision::W8A8)
                }
                _ => assert_eq!(op.precision, Precision::W1A8),
            }
        }
    }

    #[test]
    fn fraction_matches_model_closed_form() {
        let m = by_name("OPT-2.7B").unwrap();
        let s = stats(&decode_ops(&m, 1024));
        let f1 = s.low_precision_fraction();
        let f2 = m.low_precision_fraction(1024);
        assert!((f1 - f2).abs() < 1e-12);
    }

    #[test]
    fn every_op_is_mvm() {
        let m = by_name("LLaMA-7B").unwrap();
        assert!(decode_ops(&m, 128).iter().all(|o| o.n == 1));
    }

    #[test]
    fn sparsity_census_counts_exact_zeros() {
        let s = ternary_sparsity(&[1.0, 0.0, -1.0, 0.0, 0.0, 1.0]);
        assert_eq!((s.zeros, s.total), (3, 6));
        assert!((s.fraction() - 0.5).abs() < 1e-12);
        let empty = ternary_sparsity(&[]);
        assert_eq!(empty.fraction(), 0.0);
        let mut merged = s;
        merged.merge(ternary_sparsity(&[0.0, 1.0]));
        assert_eq!((merged.zeros, merged.total), (4, 8));
    }

    #[test]
    fn measured_sparsity_of_synthetic_ternary_weights_matches_expectation() {
        // The synthetic artifact generator quantizes Gaussian masters
        // with the BitNet-b1.58 rule, so the measured zero fraction over
        // all its projection matrices should land near the closed-form
        // EXPECTED_TERNARY_SPARSITY (~0.31). Aggregate over every
        // ternary matrix of a model to keep sample noise small.
        let a = crate::runtime::Artifacts::synthetic(19).unwrap();
        let mut census = SparsityStats { zeros: 0, total: 0 };
        for p in &a.manifest.params {
            if is_ternary_param(p) {
                census.merge(ternary_sparsity(a.param_data(p)));
            }
        }
        assert!(census.total > 10_000, "census too small: {census:?}");
        let err = (census.fraction() - EXPECTED_TERNARY_SPARSITY).abs();
        assert!(
            err < 0.05,
            "measured {:.4} vs expected {EXPECTED_TERNARY_SPARSITY}",
            census.fraction()
        );
    }
}
