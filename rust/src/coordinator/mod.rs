//! The PIM-LLM coordinator — the paper's system contribution.
//!
//! Routes every MatMul of a decode step by precision: **W1A8 projections
//! go to the analog PIM banks** (weight-stationary, programmed once),
//! **W8A8 attention goes to the digital systolic array**; orchestrates
//! the per-layer pipeline (buffers, NoC transfers, nonlinear units) and
//! produces the per-component latency breakdown of paper Fig. 6 and the
//! energy ledger behind Figs. 7/8.
//!
//! The **TPU-LLM baseline** (the paper's comparison point throughout
//! §IV) runs the identical op list entirely on the systolic array, with
//! weights streamed from LPDDR each token.
//!
//! Submodules:
//! * [`breakdown`]  — Fig. 6 latency categories and percentage math.
//! * [`token_loop`] — autoregressive generation latency (context grows
//!   per position) and request-level accounting.

pub mod breakdown;
pub mod token_loop;

pub use breakdown::LatencyBreakdown;

use crate::config::ArchConfig;
use crate::energy::{EnergyLedger, Metrics};
use crate::memory;
use crate::models::LlmConfig;
use crate::nonlinear;
use crate::pim::mapping;
use crate::systolic::{self, Dataflow};
use crate::workload::{self, MatMulOp, Precision};

/// Which architecture to simulate.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Arch {
    /// The proposed hybrid: PIM projections + systolic attention.
    PimLlm,
    /// Baseline LLM-specific TPU: everything on the systolic array.
    TpuLlm,
}

impl Arch {
    pub fn name(&self) -> &'static str {
        match self {
            Arch::PimLlm => "PIM-LLM",
            Arch::TpuLlm => "TPU-LLM",
        }
    }
}

/// Complete report for one decode step (one generated token).
#[derive(Debug, Clone, PartialEq)]
pub struct StepReport {
    pub arch: Arch,
    pub model: String,
    pub context: usize,
    pub breakdown: LatencyBreakdown,
    pub energy: EnergyLedger,
    pub stats: workload::WorkloadStats,
}

impl StepReport {
    pub fn latency_s(&self) -> f64 {
        self.breakdown.total_s()
    }

    pub fn metrics(&self) -> Metrics {
        Metrics {
            token_latency_s: self.latency_s(),
            token_energy_j: self.energy.total_j(),
            macs_per_token: self.stats.total_macs,
        }
    }
}

/// Simulate one decode step on the chosen architecture.
pub fn simulate(arch_cfg: &ArchConfig, model: &LlmConfig, l: usize, arch: Arch) -> StepReport {
    match arch {
        Arch::PimLlm => simulate_hybrid(arch_cfg, model, l),
        Arch::TpuLlm => simulate_tpu_baseline(arch_cfg, model, l),
    }
}

/// Attention ops of the step executed on the systolic array (shared by
/// both architectures). Returns (cycles, macs, sram bytes).
fn attention_on_systolic(arch: &ArchConfig, ops: &[MatMulOp]) -> (u64, u64, u64) {
    let mut cycles = 0u64;
    let mut macs = 0u64;
    let mut sram = 0u64;
    for op in ops.iter().filter(|o| o.precision == Precision::W8A8) {
        let run = systolic::run_op(&arch.tpu, op, Dataflow::OutputStationary);
        cycles += run.cycles;
        macs += run.macs;
        sram += run.sram_read_bytes + run.sram_write_bytes;
    }
    (cycles, macs, sram)
}

/// The hybrid PIM-LLM step.
///
/// Dependency structure per decoder block: QKV projections (PIM, the
/// three fire in parallel on disjoint banks) -> attention (systolic) ->
/// W_X (PIM) -> FF in -> GELU -> FF out (PIM). Projection latency is one
/// crossbar MVM per *stage* (all crossbars of a matrix fire together);
/// partial-sum collection rides the NoC and is the communication term.
pub fn simulate_hybrid(arch: &ArchConfig, model: &LlmConfig, l: usize) -> StepReport {
    let ops = workload::decode_ops(model, l);
    let stats = workload::stats(&ops);
    let mut bd = LatencyBreakdown::default();
    let mut en = EnergyLedger::default();

    // --- attention on the dedicated systolic array --------------------
    let (att_cycles, att_macs, att_sram) = attention_on_systolic(arch, &ops);
    bd.systolic_s = att_cycles as f64 * arch.tpu_cycle_s();
    en.systolic_j =
        att_macs as f64 * arch.tpu.mac_energy_j + memory::sram_energy(&arch.tpu, att_sram);

    // --- projections on PIM -------------------------------------------
    // Latency: per layer the dependency chain is 4 PIM stages
    // (QKV in parallel on disjoint banks, then W_X, FF-in, FF-out); all
    // crossbars of one stage fire simultaneously, so a stage costs one
    // crossbar MVM. Itemize analog time as DAC setup + the slower of
    // (analog read stream | ADC conversion stream).
    let geom = crate::pim::crossbar::XbarGeometry::from_config(&arch.pim);
    let full = crate::pim::crossbar::run_mvm(&arch.pim, geom.rows, geom.weight_cols);
    let stages = 4.0 * model.n_layers as f64;
    bd.dac_s = stages * full.dac_s;
    if full.xbar_s >= full.adc_s {
        bd.xbar_s = stages * full.xbar_s;
        bd.adc_s = 0.0; // fully pipelined behind the analog reads
    } else {
        bd.xbar_s = 0.0;
        bd.adc_s = stages * full.adc_s;
    }

    // Energy + crossbar census over all projection ops.
    let full_cap = geom.weights() as f64;
    let mut total_crossbars = 0u64;
    for op in ops.iter().filter(|o| o.precision == Precision::W1A8) {
        let m = mapping::OpMapping::for_op(arch, op);
        total_crossbars += m.crossbars();
        let eff = (op.m as u64 * op.k as u64) as f64 / full_cap;
        en.xbar_j += full.xbar_energy_j * eff;
        en.dac_j += full.dac_energy_j * eff;
        en.adc_j += full.adc_energy_j * eff;
    }
    en.pim_fixed_j = arch.pim.fixed_token_energy_j;

    // --- communication: NoC collection of digitized partial sums ------
    bd.communication_s = total_crossbars as f64 * arch.noc.per_xbar_collect_s;
    let noc_bytes = total_crossbars * arch.noc.bytes_per_xbar as u64;
    en.noc_j = noc_bytes as f64 * arch.noc.energy_per_byte_j;

    // --- buffers -------------------------------------------------------
    bd.buffer_s = model.n_layers as f64 * arch.buffer.per_layer_s;
    // Activations in/out of tile buffers: ~4 d-vectors + 2 dff-vectors
    // per layer at int8.
    let buf_bytes = model.n_layers as u64 * (4 * model.d as u64 + 2 * model.d_ff as u64);
    en.buffer_j = buf_bytes as f64 * arch.buffer.energy_per_byte_j;

    // --- digital peripheral (paper: < 0.01%) ---------------------------
    bd.peripheral_s = model.n_layers as f64 * arch.peripheral.per_layer_s;
    en.controller_j = model.n_layers as f64 * arch.peripheral.energy_per_layer_j;

    // --- nonlinear functional units ------------------------------------
    let nl = nonlinear::decode_step_total(arch, model, l);
    bd.nonlinear_s = nl.latency_s;
    en.nonlinear_j = nl.energy_j;

    // --- KV-cache traffic on LPDDR (K and V read once per token; the
    // new token's K/V written back) -------------------------------------
    let kv = memory::lpddr_transfer(&arch.lpddr, model.kv_bytes(l));
    // Streaming overlaps attention compute (double-buffered weight
    // memory); only exposed if bandwidth-bound.
    bd.lpddr_exposed_s = (kv.latency_s - bd.systolic_s).max(0.0);
    en.lpddr_j = kv.energy_j;

    // --- statics --------------------------------------------------------
    en.tpu_static_j = arch.tpu.static_power_w * bd.total_s();

    StepReport {
        arch: Arch::PimLlm,
        model: model.name.clone(),
        context: l,
        breakdown: bd,
        energy: en,
        stats,
    }
}

/// The TPU-LLM baseline step: every op on the systolic array (OS
/// dataflow), weights streamed from LPDDR each token (they cannot fit in
/// the 8 MB SRAM for any Table II model).
pub fn simulate_tpu_baseline(arch: &ArchConfig, model: &LlmConfig, l: usize) -> StepReport {
    let ops = workload::decode_ops(model, l);
    let stats = workload::stats(&ops);
    let mut bd = LatencyBreakdown::default();
    let mut en = EnergyLedger::default();

    let mut cycles = 0u64;
    let mut sram = 0u64;
    for op in &ops {
        let run = systolic::run_op(&arch.tpu, op, Dataflow::OutputStationary);
        cycles += run.cycles;
        sram += run.sram_read_bytes + run.sram_write_bytes;
    }
    bd.systolic_s = cycles as f64 * arch.tpu_cycle_s();
    en.systolic_j =
        stats.total_macs as f64 * arch.tpu.mac_energy_j + memory::sram_energy(&arch.tpu, sram);

    // Weight + KV streaming from LPDDR, overlapped with compute.
    let weight_bytes = if arch.lpddr.charge_weight_streaming
        && !memory::weights_fit_in_sram(&arch.tpu, model.weight_bytes_w8())
    {
        model.weight_bytes_w8()
    } else {
        0
    };
    let stream = memory::lpddr_transfer(&arch.lpddr, weight_bytes + model.kv_bytes(l));
    bd.lpddr_exposed_s = (stream.latency_s - bd.systolic_s).max(0.0);
    en.lpddr_j = stream.energy_j;

    let nl = nonlinear::decode_step_total(arch, model, l);
    bd.nonlinear_s = nl.latency_s;
    en.nonlinear_j = nl.energy_j;

    // Main controller / dataflow generator sequencing, same per-layer
    // cost as the hybrid (it schedules the same decoder structure).
    en.controller_j = model.n_layers as f64 * arch.peripheral.energy_per_layer_j;

    en.tpu_static_j = arch.tpu.static_power_w * bd.total_s();

    StepReport {
        arch: Arch::TpuLlm,
        model: model.name.clone(),
        context: l,
        breakdown: bd,
        energy: en,
        stats,
    }
}

/// Speedup of PIM-LLM over TPU-LLM at one evaluation point (Fig. 5
/// annotation values).
pub fn speedup(arch_cfg: &ArchConfig, model: &LlmConfig, l: usize) -> f64 {
    let p = simulate_hybrid(arch_cfg, model, l);
    let t = simulate_tpu_baseline(arch_cfg, model, l);
    t.latency_s() / p.latency_s()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::by_name;

    fn arch() -> ArchConfig {
        ArchConfig::paper_45nm()
    }

    /// Fig. 5 headline: GPT2-355M @128 ~ 11.6x, OPT-6.7B @128 ~ 79.2x.
    #[test]
    fn fig5_speedups_short_context() {
        let a = arch();
        let s_gpt = speedup(&a, &by_name("GPT2-355M").unwrap(), 128);
        assert!((s_gpt - 11.6).abs() / 11.6 < 0.15, "GPT2-355M: {s_gpt}");
        let s_opt = speedup(&a, &by_name("OPT-6.7B").unwrap(), 128);
        assert!((s_opt - 79.2).abs() / 79.2 < 0.15, "OPT-6.7B: {s_opt}");
    }

    /// Fig. 5: GPT2-355M @4096 ~ 1.5x, OPT-6.7B @4096 ~ 5.71x.
    #[test]
    fn fig5_speedups_long_context() {
        let a = arch();
        let s_gpt = speedup(&a, &by_name("GPT2-355M").unwrap(), 4096);
        assert!((s_gpt - 1.5).abs() / 1.5 < 0.15, "GPT2-355M: {s_gpt}");
        let s_opt = speedup(&a, &by_name("OPT-6.7B").unwrap(), 4096);
        assert!((s_opt - 5.71).abs() / 5.71 < 0.15, "OPT-6.7B: {s_opt}");
    }

    /// Speedup decreases with context length (paper §IV-A).
    #[test]
    fn speedup_monotone_decreasing_in_context() {
        let a = arch();
        let m = by_name("OPT-2.7B").unwrap();
        let mut prev = f64::INFINITY;
        for l in crate::models::CONTEXT_LENGTHS {
            let s = speedup(&a, &m, l);
            assert!(s < prev, "l={l}: {s} !< {prev}");
            assert!(s > 1.0, "PIM-LLM must win at every point");
            prev = s;
        }
    }

    /// Fig. 6: systolic dominates; at l=4096 it exceeds 97%.
    #[test]
    fn fig6_breakdown_shape() {
        let a = arch();
        let r128 = simulate_hybrid(&a, &by_name("OPT-6.7B").unwrap(), 128);
        let f = r128.breakdown.fractions();
        assert!(f.systolic > 0.5 && f.systolic < 0.75, "{f:?}");
        assert!(f.communication > 0.2, "{f:?}");
        let r4096 = simulate_hybrid(&a, &by_name("OPT-6.7B").unwrap(), 4096);
        assert!(r4096.breakdown.fractions().systolic > 0.9);
    }

    /// Energy ledger is positive and itemization sums to the total.
    #[test]
    fn energy_itemization_consistent() {
        let a = arch();
        for arch_kind in [Arch::PimLlm, Arch::TpuLlm] {
            let r = simulate(&a, &by_name("OPT-1.3B").unwrap(), 512, arch_kind);
            let sum: f64 = r.energy.items().iter().map(|(_, v)| v).sum();
            assert!((sum - r.energy.total_j()).abs() < 1e-12 * sum.max(1.0));
            assert!(r.energy.total_j() > 0.0);
        }
    }

    /// Larger models -> larger speedups at fixed context (paper §IV-A).
    #[test]
    fn speedup_grows_with_model_size() {
        let a = arch();
        let small = speedup(&a, &by_name("GPT2-355M").unwrap(), 128);
        let big = speedup(&a, &by_name("OPT-6.7B").unwrap(), 128);
        assert!(big > small);
    }

    /// The W8A8/W1A8 partition is exhaustive and exclusive.
    #[test]
    fn partition_covers_all_macs() {
        let a = arch();
        let m = by_name("LLaMA-7B").unwrap();
        let r = simulate_hybrid(&a, &m, 1024);
        assert_eq!(r.stats.w1a8_macs + r.stats.w8a8_macs, r.stats.total_macs);
        assert_eq!(r.stats.w1a8_macs, m.projection_macs());
    }
}
