//! Latency breakdown in the categories of paper Fig. 6: systolic array,
//! communication (NoC), buffers, crossbar, DAC, ADC, digital peripheral
//! — plus the two categories the figure folds away (nonlinear units and
//! exposed LPDDR time) which we keep explicit for honesty.


/// Per-component latency of one decode step, seconds.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LatencyBreakdown {
    /// W8A8 MatMuls on the systolic array.
    pub systolic_s: f64,
    /// NoC collection/routing of PIM partial sums & activations.
    pub communication_s: f64,
    /// Tile input/output buffer fill/drain.
    pub buffer_s: f64,
    /// Analog crossbar read time.
    pub xbar_s: f64,
    /// Input driver (DAC) time.
    pub dac_s: f64,
    /// ADC conversion time not hidden behind the analog reads.
    pub adc_s: f64,
    /// Digital peripheral circuitry.
    pub peripheral_s: f64,
    /// Nonlinear functional units (softmax/norm/GELU).
    pub nonlinear_s: f64,
    /// LPDDR streaming time not hidden under compute.
    pub lpddr_exposed_s: f64,
}

/// The same breakdown as fractions of the total (sums to 1).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Fractions {
    pub systolic: f64,
    pub communication: f64,
    pub buffer: f64,
    pub xbar: f64,
    pub dac: f64,
    pub adc: f64,
    pub peripheral: f64,
    pub nonlinear: f64,
    pub lpddr_exposed: f64,
}

impl LatencyBreakdown {
    pub fn total_s(&self) -> f64 {
        self.systolic_s
            + self.communication_s
            + self.buffer_s
            + self.xbar_s
            + self.dac_s
            + self.adc_s
            + self.peripheral_s
            + self.nonlinear_s
            + self.lpddr_exposed_s
    }

    /// Combined PIM analog time (the "PIM" sliver in Fig. 6's zoom).
    pub fn pim_analog_s(&self) -> f64 {
        self.xbar_s + self.dac_s + self.adc_s
    }

    pub fn fractions(&self) -> Fractions {
        let t = self.total_s().max(f64::MIN_POSITIVE);
        Fractions {
            systolic: self.systolic_s / t,
            communication: self.communication_s / t,
            buffer: self.buffer_s / t,
            xbar: self.xbar_s / t,
            dac: self.dac_s / t,
            adc: self.adc_s / t,
            peripheral: self.peripheral_s / t,
            nonlinear: self.nonlinear_s / t,
            lpddr_exposed: self.lpddr_exposed_s / t,
        }
    }

    /// (label, seconds) pairs in Fig. 6's legend order.
    pub fn items(&self) -> Vec<(&'static str, f64)> {
        vec![
            ("systolic", self.systolic_s),
            ("communication", self.communication_s),
            ("buffer", self.buffer_s),
            ("xbar", self.xbar_s),
            ("dac", self.dac_s),
            ("adc", self.adc_s),
            ("peripheral", self.peripheral_s),
            ("nonlinear", self.nonlinear_s),
            ("lpddr_exposed", self.lpddr_exposed_s),
        ]
    }
}

impl Fractions {
    pub fn as_vec(&self) -> Vec<(&'static str, f64)> {
        vec![
            ("systolic", self.systolic),
            ("communication", self.communication),
            ("buffer", self.buffer),
            ("xbar", self.xbar),
            ("dac", self.dac),
            ("adc", self.adc),
            ("peripheral", self.peripheral),
            ("nonlinear", self.nonlinear),
            ("lpddr_exposed", self.lpddr_exposed),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fractions_sum_to_one() {
        let bd = LatencyBreakdown {
            systolic_s: 1.0,
            communication_s: 0.5,
            buffer_s: 0.25,
            xbar_s: 0.1,
            dac_s: 0.05,
            adc_s: 0.05,
            peripheral_s: 0.02,
            nonlinear_s: 0.02,
            lpddr_exposed_s: 0.01,
        };
        let sum: f64 = bd.fractions().as_vec().iter().map(|(_, v)| v).sum();
        assert!((sum - 1.0).abs() < 1e-12);
    }

    #[test]
    fn total_is_item_sum() {
        let bd = LatencyBreakdown {
            systolic_s: 2.0,
            buffer_s: 1.0,
            ..Default::default()
        };
        let item_sum: f64 = bd.items().iter().map(|(_, v)| v).sum();
        assert!((bd.total_s() - item_sum).abs() < 1e-12);
        assert!((bd.total_s() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn empty_breakdown_fractions_finite() {
        let bd = LatencyBreakdown::default();
        let f = bd.fractions();
        assert!(f.systolic.is_finite());
    }
}
