//! Autoregressive token-loop accounting: a generation's latency/energy
//! integrates the per-step cost as the context grows one token at a
//! time (the per-figure sweeps evaluate fixed l; real requests do not).

use super::{simulate, Arch, StepReport};
use crate::config::ArchConfig;
use crate::energy::EnergyLedger;
use crate::models::LlmConfig;

/// Aggregate cost of generating `n_new` tokens starting from a prompt of
/// `prompt_len` tokens (prefill is modeled as sequential decode steps —
/// the paper's architecture processes one token per iteration).
#[derive(Debug, Clone, PartialEq)]
pub struct GenerationReport {
    pub arch: Arch,
    pub model: String,
    pub prompt_len: usize,
    pub n_new: usize,
    pub total_latency_s: f64,
    pub total_energy: EnergyLedger,
    /// Latency of each generated token (position-dependent).
    pub per_token_latency_s: Vec<f64>,
}

impl GenerationReport {
    pub fn tokens_per_s(&self) -> f64 {
        (self.prompt_len + self.n_new) as f64 / self.total_latency_s
    }

    /// Decode-only throughput (excludes prompt ingestion), the number
    /// comparable to Fig. 5's fixed-l points.
    pub fn decode_tokens_per_s(&self) -> f64 {
        let decode_s: f64 = self.per_token_latency_s[self.prompt_len..].iter().sum();
        self.n_new as f64 / decode_s
    }
}

/// Simulate a full generation. Context length for the step at position
/// `p` (0-based) is `p + 1` (the KV cache holds p+1 entries after the
/// update), so step cost grows as generation proceeds.
pub fn generate(
    arch_cfg: &ArchConfig,
    model: &LlmConfig,
    arch: Arch,
    prompt_len: usize,
    n_new: usize,
) -> GenerationReport {
    assert!(prompt_len > 0, "empty prompt");
    let mut total_latency = 0.0;
    let mut energy = EnergyLedger::default();
    let mut per_token = Vec::with_capacity(prompt_len + n_new);
    for p in 0..(prompt_len + n_new) {
        let step: StepReport = simulate(arch_cfg, model, p + 1, arch);
        total_latency += step.latency_s();
        energy += step.energy;
        per_token.push(step.latency_s());
    }
    GenerationReport {
        arch,
        model: model.name.clone(),
        prompt_len,
        n_new,
        total_latency_s: total_latency,
        total_energy: energy,
        per_token_latency_s: per_token,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::by_name;

    #[test]
    fn per_token_latency_grows_with_position() {
        let a = ArchConfig::paper_45nm();
        let m = by_name("GPT2-355M").unwrap();
        let g = generate(&a, &m, Arch::PimLlm, 4, 16);
        assert_eq!(g.per_token_latency_s.len(), 20);
        // Later tokens attend over longer context.
        assert!(g.per_token_latency_s[19] > g.per_token_latency_s[0]);
    }

    #[test]
    fn totals_are_sums() {
        let a = ArchConfig::paper_45nm();
        let m = by_name("GPT2-355M").unwrap();
        let g = generate(&a, &m, Arch::TpuLlm, 2, 6);
        let s: f64 = g.per_token_latency_s.iter().sum();
        assert!((g.total_latency_s - s).abs() < 1e-12);
        assert!(g.tokens_per_s() > 0.0);
        assert!(g.decode_tokens_per_s() > 0.0);
    }

    #[test]
    fn hybrid_faster_than_baseline_end_to_end() {
        let a = ArchConfig::paper_45nm();
        let m = by_name("OPT-1.3B").unwrap();
        let p = generate(&a, &m, Arch::PimLlm, 8, 8);
        let t = generate(&a, &m, Arch::TpuLlm, 8, 8);
        assert!(p.total_latency_s < t.total_latency_s);
    }

    #[test]
    #[should_panic(expected = "empty prompt")]
    fn empty_prompt_panics() {
        let a = ArchConfig::paper_45nm();
        let m = by_name("GPT2-355M").unwrap();
        generate(&a, &m, Arch::PimLlm, 0, 1);
    }
}
