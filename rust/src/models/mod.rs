//! The LLM zoo: hyper-parameters of every model the paper evaluates
//! (Table II) plus GPT2-Small/Medium used in the Table III comparison
//! against HARDSEA and TransPIM.
//!
//! Note the paper's Table II convention: for the GPT2 family it sets
//! `d_FF = d` (not the usual 4*d). We follow the table exactly — the
//! Table III GOPS numbers only reproduce under this convention (verified
//! in `analysis::table3`).


/// Decoder-only LLM hyper-parameters (paper Table II row).
#[derive(Debug, Clone, PartialEq)]
pub struct LlmConfig {
    /// Human-readable name, e.g. "OPT-6.7B".
    pub name: String,
    /// Approximate parameter count (reported, used for labels only).
    pub params: u64,
    /// Embedding dimension d.
    pub d: usize,
    /// Attention heads h.
    pub h: usize,
    /// Feed-forward intermediate dimension d_FF.
    pub d_ff: usize,
    /// Decoder blocks N.
    pub n_layers: usize,
}

impl LlmConfig {
    pub fn new(
        name: &str,
        params: u64,
        d: usize,
        h: usize,
        d_ff: usize,
        n_layers: usize,
    ) -> Self {
        Self {
            name: name.to_string(),
            params,
            d,
            h,
            d_ff,
            n_layers,
        }
    }

    /// Head dimension d/h.
    pub fn d_head(&self) -> usize {
        self.d / self.h
    }

    /// Weight count of the projection layers (the part that lives in the
    /// PIM crossbars): per layer W_Q, W_K, W_V, W_X (d x d each) plus the
    /// two FF projections (d x d_FF and d_FF x d).
    pub fn projection_weights(&self) -> u64 {
        let per_layer = 4 * (self.d as u64) * (self.d as u64)
            + 2 * (self.d as u64) * (self.d_ff as u64);
        per_layer * self.n_layers as u64
    }

    /// MACs per generated token in projection layers (1 MVM per matrix).
    pub fn projection_macs(&self) -> u64 {
        self.projection_weights()
    }

    /// MACs per generated token in the attention heads at context length
    /// `l`: per layer, per head, Score = Q.K^T is (l x d/h).(d/h x 1) and
    /// V.Score is (d/h x l).(l x 1) — i.e. 2 * l * d/h MACs per head,
    /// 2 * l * d per layer (paper Table I).
    pub fn attention_macs(&self, l: usize) -> u64 {
        2 * (l as u64) * (self.d as u64) * self.n_layers as u64
    }

    /// Total MACs per generated token.
    pub fn total_macs(&self, l: usize) -> u64 {
        self.projection_macs() + self.attention_macs(l)
    }

    /// Fraction of per-token MACs that are low-precision (W1A8) — the
    /// quantity plotted in paper Fig. 1b.
    pub fn low_precision_fraction(&self, l: usize) -> f64 {
        self.projection_macs() as f64 / self.total_macs(l) as f64
    }

    /// KV-cache bytes read per token at context length `l` (both K and V,
    /// int8 storage).
    pub fn kv_bytes(&self, l: usize) -> u64 {
        2 * (l as u64) * (self.d as u64) * self.n_layers as u64
    }

    /// Weight bytes streamed by the TPU-LLM baseline per token (int8).
    pub fn weight_bytes_w8(&self) -> u64 {
        self.projection_weights()
    }
}

/// Paper Table II: the seven evaluated models.
pub fn table2_models() -> Vec<LlmConfig> {
    vec![
        LlmConfig::new("GPT2-355M", 355_000_000, 1024, 16, 1024, 24),
        LlmConfig::new("GPT2-774M", 774_000_000, 1280, 20, 1280, 36),
        LlmConfig::new("GPT2-1.5B", 1_500_000_000, 1600, 25, 1600, 48),
        LlmConfig::new("OPT-1.3B", 1_300_000_000, 2048, 32, 8192, 24),
        LlmConfig::new("OPT-2.7B", 2_700_000_000, 2560, 32, 10240, 32),
        LlmConfig::new("OPT-6.7B", 6_700_000_000, 4096, 32, 16384, 32),
        LlmConfig::new("LLaMA-7B", 7_000_000_000, 4096, 32, 11008, 32),
    ]
}

/// Extra models referenced by Fig. 1b (OPT-350M) and Table III
/// (GPT2-Small/Medium; TransPIM and HARDSEA workloads). GPT2 family uses
/// the paper's d_FF = d convention.
pub fn extra_models() -> Vec<LlmConfig> {
    vec![
        LlmConfig::new("OPT-350M", 350_000_000, 1024, 16, 4096, 24),
        LlmConfig::new("GPT2-Small", 124_000_000, 768, 12, 768, 12),
        // "GPT2-Medium" in Table III is the same 355M model as Table II.
        LlmConfig::new("GPT2-Medium", 355_000_000, 1024, 16, 1024, 24),
    ]
}

/// Look up any known model by (case-insensitive) name.
pub fn by_name(name: &str) -> Option<LlmConfig> {
    let lname = name.to_lowercase();
    table2_models()
        .into_iter()
        .chain(extra_models())
        .find(|m| m.name.to_lowercase() == lname)
}

/// Context lengths swept in the paper's figures.
pub const CONTEXT_LENGTHS: [usize; 6] = [128, 256, 512, 1024, 2048, 4096];

/// The tiny functional model compiled by the AOT path (must match
/// `python/compile/model.py::TINY`).
pub fn tiny_functional() -> LlmConfig {
    LlmConfig::new("tiny-1bit", 1_700_000, 256, 4, 1024, 2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_has_seven_models() {
        let models = table2_models();
        assert_eq!(models.len(), 7);
        let opt67 = by_name("OPT-6.7B").unwrap();
        assert_eq!(opt67.d, 4096);
        assert_eq!(opt67.h, 32);
        assert_eq!(opt67.d_ff, 16384);
        assert_eq!(opt67.n_layers, 32);
    }

    #[test]
    fn gpt2_uses_dff_equals_d() {
        for name in ["GPT2-355M", "GPT2-774M", "GPT2-1.5B", "GPT2-Small"] {
            let m = by_name(name).unwrap();
            assert_eq!(m.d_ff, m.d, "{name}");
        }
    }

    #[test]
    fn head_dim_divides() {
        for m in table2_models().iter().chain(extra_models().iter()) {
            assert_eq!(m.d % m.h, 0, "{}", m.name);
        }
    }

    #[test]
    fn projection_macs_match_hand_count() {
        // OPT-6.7B: per layer 4*4096^2 + 2*4096*16384 = 201.3M; x32.
        let m = by_name("OPT-6.7B").unwrap();
        let per_layer = 4 * 4096u64 * 4096 + 2 * 4096 * 16384;
        assert_eq!(m.projection_macs(), per_layer * 32);
    }

    #[test]
    fn attention_macs_scale_linearly_in_l() {
        let m = by_name("GPT2-355M").unwrap();
        assert_eq!(m.attention_macs(256), 2 * m.attention_macs(128));
    }

    #[test]
    fn fig1b_fraction_shape() {
        // OPT-350M @ 4096 is the "evenly distributed" case (~60%);
        // larger models at short context exceed 99%.
        let m350 = by_name("OPT-350M").unwrap();
        let f = m350.low_precision_fraction(4096);
        assert!(f > 0.55 && f < 0.70, "got {f}");
        let m67 = by_name("OPT-6.7B").unwrap();
        assert!(m67.low_precision_fraction(128) > 0.99);
    }

    #[test]
    fn fraction_monotonically_decreases_with_context() {
        let m = by_name("OPT-1.3B").unwrap();
        let mut prev = 1.0;
        for l in CONTEXT_LENGTHS {
            let f = m.low_precision_fraction(l);
            assert!(f < prev);
            prev = f;
        }
    }

    #[test]
    fn by_name_is_case_insensitive_and_total() {
        assert!(by_name("opt-6.7b").is_some());
        assert!(by_name("gpt2-small").is_some());
        assert!(by_name("nonexistent").is_none());
    }
}
