//! Latency statistics over served requests: mean / percentiles /
//! throughput, the numbers the edge-serving example reports.

use super::Response;

/// Summary statistics of a serving run.
#[derive(Debug, Clone, PartialEq)]
pub struct LatencyStats {
    pub n: usize,
    pub total_tokens: usize,
    pub mean_service_s: f64,
    pub p50_service_s: f64,
    pub p95_service_s: f64,
    pub p99_service_s: f64,
    pub mean_ttft_s: f64,
    pub tokens_per_s: f64,
}

fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let idx = ((p / 100.0) * (sorted.len() - 1) as f64).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

impl LatencyStats {
    /// Compute stats. `wall_s` is the whole batch's wall-clock time.
    pub fn from_responses(responses: &[Response], wall_s: f64) -> Self {
        let mut service: Vec<f64> = responses.iter().map(|r| r.service_s).collect();
        service.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let total_tokens: usize = responses.iter().map(|r| r.tokens.len()).sum();
        let n = responses.len();
        LatencyStats {
            n,
            total_tokens,
            mean_service_s: service.iter().sum::<f64>() / n.max(1) as f64,
            p50_service_s: percentile(&service, 50.0),
            p95_service_s: percentile(&service, 95.0),
            p99_service_s: percentile(&service, 99.0),
            mean_ttft_s: responses.iter().map(|r| r.ttft_s).sum::<f64>() / n.max(1) as f64,
            tokens_per_s: total_tokens as f64 / wall_s.max(f64::MIN_POSITIVE),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn resp(id: u64, service: f64) -> Response {
        Response {
            id,
            tokens: vec![0; 10],
            queue_s: 0.0,
            service_s: service,
            ttft_s: service / 2.0,
        }
    }

    #[test]
    fn stats_basic() {
        let rs: Vec<Response> = (0..100).map(|i| resp(i, (i + 1) as f64 / 100.0)).collect();
        let s = LatencyStats::from_responses(&rs, 1.0);
        assert_eq!(s.n, 100);
        assert_eq!(s.total_tokens, 1000);
        assert!((s.p50_service_s - 0.50).abs() < 0.02);
        assert!((s.p95_service_s - 0.95).abs() < 0.02);
        assert!(s.p99_service_s >= s.p95_service_s);
        assert!((s.tokens_per_s - 1000.0).abs() < 1e-9);
    }

    #[test]
    fn percentile_handles_singleton() {
        let s = LatencyStats::from_responses(&[resp(0, 2.0)], 2.0);
        assert_eq!(s.p50_service_s, 2.0);
        assert_eq!(s.p99_service_s, 2.0);
    }
}
