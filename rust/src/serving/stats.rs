//! Latency statistics over served requests: queue wait, time to first
//! token, end-to-end percentiles, throughput, preemption counts — the
//! numbers `repro serve` and the edge-serving example report.

use super::Response;
use crate::obs::{Counter, Obs};

/// Summary statistics of a serving run.
#[derive(Debug, Clone, PartialEq)]
pub struct LatencyStats {
    pub n: usize,
    pub total_tokens: usize,
    /// End-to-end (arrival -> completion) latency.
    pub mean_service_s: f64,
    pub p50_service_s: f64,
    pub p95_service_s: f64,
    pub p99_service_s: f64,
    /// Time to first generated token.
    pub mean_ttft_s: f64,
    pub p50_ttft_s: f64,
    pub p95_ttft_s: f64,
    /// Queue wait before first admission.
    pub mean_queue_s: f64,
    pub p50_queue_s: f64,
    pub p95_queue_s: f64,
    /// Total continuous-scheduler preemptions across all requests.
    pub evictions: usize,
    /// Prompt positions served from the copy-on-write prefix cache
    /// instead of prefill decode, across all requests (0 with the
    /// cache off).
    pub cached_tokens: usize,
    pub tokens_per_s: f64,
}

/// Percentile over a `total_cmp`-sorted sample. NaN entries (a clock
/// that went backwards, a field a custom front end never filled) sit
/// grouped at the ends of the total order (-NaN first, +NaN last), so
/// the percentile is taken over the contiguous run of real numbers
/// between them — one poisoned response no longer poisons (or panics)
/// the whole report. All-NaN or empty samples report NaN.
fn percentile(sorted: &[f64], p: f64) -> f64 {
    let lo = sorted.iter().position(|v| !v.is_nan());
    let Some(lo) = lo else {
        return f64::NAN;
    };
    let hi = sorted.iter().rposition(|v| !v.is_nan()).expect("lo exists");
    let finite = &sorted[lo..=hi];
    let idx = ((p / 100.0) * (finite.len() - 1) as f64).round() as usize;
    finite[idx.min(finite.len() - 1)]
}

/// Sorted copy of one latency field across responses. `f64::total_cmp`
/// rather than `partial_cmp(..).unwrap()`: a single NaN latency must
/// not panic the stats pass at the end of an otherwise-successful
/// serving run.
fn sorted_field(responses: &[Response], f: impl Fn(&Response) -> f64) -> Vec<f64> {
    let mut v: Vec<f64> = responses.iter().map(f).collect();
    v.sort_by(f64::total_cmp);
    v
}

impl LatencyStats {
    /// Compute stats. `wall_s` is the whole batch's wall-clock time.
    pub fn from_responses(responses: &[Response], wall_s: f64) -> Self {
        let service = sorted_field(responses, |r| r.service_s);
        let ttft = sorted_field(responses, |r| r.ttft_s);
        let queue = sorted_field(responses, |r| r.queue_s);
        let total_tokens: usize = responses.iter().map(|r| r.tokens.len()).sum();
        let n = responses.len();
        let mean = |v: &[f64]| v.iter().sum::<f64>() / n.max(1) as f64;
        LatencyStats {
            n,
            total_tokens,
            mean_service_s: mean(&service),
            p50_service_s: percentile(&service, 50.0),
            p95_service_s: percentile(&service, 95.0),
            p99_service_s: percentile(&service, 99.0),
            mean_ttft_s: mean(&ttft),
            p50_ttft_s: percentile(&ttft, 50.0),
            p95_ttft_s: percentile(&ttft, 95.0),
            mean_queue_s: mean(&queue),
            p50_queue_s: percentile(&queue, 50.0),
            p95_queue_s: percentile(&queue, 95.0),
            evictions: responses.iter().map(|r| r.evictions as usize).sum(),
            cached_tokens: responses.iter().map(|r| r.cached_tokens).sum(),
            tokens_per_s: total_tokens as f64 / wall_s.max(f64::MIN_POSITIVE),
        }
    }

    /// One-line report of the headline numbers — `repro serve` prints
    /// this as its summary line.
    pub fn report(&self) -> String {
        format!(
            "throughput {:.1} tok/s | service p50/p95/p99 {:.3}/{:.3}/{:.3}s \
             | ttft mean/p50/p95 {:.3}/{:.3}/{:.3}s | queue mean/p95 {:.3}/{:.3}s \
             | {} preemptions | {} prefix-cached tokens",
            self.tokens_per_s,
            self.p50_service_s,
            self.p95_service_s,
            self.p99_service_s,
            self.mean_ttft_s,
            self.p50_ttft_s,
            self.p95_ttft_s,
            self.mean_queue_s,
            self.p95_queue_s,
            self.evictions,
            self.cached_tokens
        )
    }
}

/// Lane-scheduler + speculative-decoding counters for one serving run,
/// read from the engine's [`Obs`] metrics registry. Counters only
/// record while observability is enabled (`Obs::set_enabled`), so a
/// run without `--trace`-style instrumentation reports zeros. `repro
/// serve` prints this under the latency summary whenever chunked
/// prefill or speculative decoding is on.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct LaneStats {
    /// Prompt positions ingested through the chunked prefill lane.
    pub prefill_tokens: u64,
    /// Tokens committed by the decode lane (speculative or classic).
    pub decode_tokens: u64,
    /// Draft proposals fed into verify spans. The bonus token `f0` is
    /// counted on neither side of the acceptance ratio — it is correct
    /// without any draft help.
    pub proposed: u64,
    /// Draft proposals the target's own argmax confirmed.
    pub accepted: u64,
}

impl LaneStats {
    /// Read the current lane counters from one observability bundle.
    pub fn from_obs(obs: &Obs) -> Self {
        Self {
            prefill_tokens: obs.metrics.counter(Counter::LanePrefillTokens),
            decode_tokens: obs.metrics.counter(Counter::LaneDecodeTokens),
            proposed: obs.metrics.counter(Counter::SpecProposed),
            accepted: obs.metrics.counter(Counter::SpecAccepted),
        }
    }

    /// Fraction of draft proposals accepted, in `[0, 1]`. Zero
    /// proposals reports 0.0, never NaN (the summary line is diffed by
    /// CI, so its shape must not depend on whether a draft ran).
    pub fn acceptance(&self) -> f64 {
        if self.proposed == 0 {
            0.0
        } else {
            self.accepted as f64 / self.proposed as f64
        }
    }

    /// One-line summary, e.g.
    /// `lanes: 96 prefill + 80 decode tokens | spec: 45/60 proposals accepted (75.0%)`.
    pub fn report(&self) -> String {
        format!(
            "lanes: {} prefill + {} decode tokens | spec: {}/{} proposals \
             accepted ({:.1}%)",
            self.prefill_tokens,
            self.decode_tokens,
            self.accepted,
            self.proposed,
            100.0 * self.acceptance()
        )
    }
}

/// Per-shard counters from one sharded serving run
/// ([`super::serve_sharded_stats`]): where the placement hash landed
/// each request, how much work stealing rebalanced them, and how hard
/// the shard's arena slice worked. `served` can differ from `placed` in
/// both directions — by `stolen` on the thief's side and by the
/// requests stolen AWAY on the victim's — but the totals balance:
/// summed over shards, `served == placed`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ShardStats {
    /// Shard / worker index.
    pub shard: usize,
    /// Requests the deterministic placement hash routed here.
    pub placed: usize,
    /// Requests this worker stole from other shards' queues.
    pub stolen: usize,
    /// Responses this worker completed.
    pub served: usize,
    /// Sessions preempted under arena pressure on this shard.
    pub evictions: usize,
    /// Peak concurrently-active sessions on this shard.
    pub peak_active: usize,
}

impl ShardStats {
    pub fn new(shard: usize) -> Self {
        Self {
            shard,
            ..Self::default()
        }
    }

    /// One-line per-shard summary, e.g.
    /// `shard 2: placed 5 | stole 1 | served 6 | 0 preemptions | peak 4 active`.
    pub fn report(&self) -> String {
        format!(
            "shard {}: placed {} | stole {} | served {} | {} preemptions | peak {} active",
            self.shard, self.placed, self.stolen, self.served, self.evictions, self.peak_active
        )
    }
}

/// Multi-line report over a whole worker set, one shard per line plus a
/// steal/served totals line — `repro serve --policy sharded` prints
/// this under the latency summary. Shards are always emitted in
/// ascending worker-id order, whatever order the caller collected them
/// in — the report is a determinism surface (CI diffs it run-to-run),
/// so line order must not depend on thread join order.
pub fn shard_report(stats: &[ShardStats]) -> String {
    let mut ordered: Vec<&ShardStats> = stats.iter().collect();
    ordered.sort_by_key(|s| s.shard);
    let mut lines: Vec<String> = ordered.into_iter().map(ShardStats::report).collect();
    let stolen: usize = stats.iter().map(|s| s.stolen).sum();
    let served: usize = stats.iter().map(|s| s.served).sum();
    lines.push(format!(
        "{} workers | {} served | {} stolen",
        stats.len(),
        served,
        stolen
    ));
    lines.join("\n")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn resp(id: u64, service: f64) -> Response {
        Response {
            id,
            tokens: vec![0; 10],
            queue_s: service / 4.0,
            service_s: service,
            ttft_s: service / 2.0,
            evictions: (id % 3 == 0) as u32,
            cached_tokens: (id % 2) as usize * 3,
        }
    }

    #[test]
    fn stats_basic() {
        let rs: Vec<Response> = (0..100).map(|i| resp(i, (i + 1) as f64 / 100.0)).collect();
        let s = LatencyStats::from_responses(&rs, 1.0);
        assert_eq!(s.n, 100);
        assert_eq!(s.total_tokens, 1000);
        assert!((s.p50_service_s - 0.50).abs() < 0.02);
        assert!((s.p95_service_s - 0.95).abs() < 0.02);
        assert!(s.p99_service_s >= s.p95_service_s);
        assert!((s.tokens_per_s - 1000.0).abs() < 1e-9);
        // The new per-request dimensions track their fields.
        assert!((s.p50_ttft_s - 0.25).abs() < 0.02);
        assert!((s.p95_ttft_s - 0.475).abs() < 0.02);
        assert!((s.p50_queue_s - 0.125).abs() < 0.01);
        assert!((s.mean_queue_s - s.mean_service_s / 4.0).abs() < 1e-9);
        assert_eq!(s.evictions, 34); // ids 0, 3, 6, ..., 99
        assert_eq!(s.cached_tokens, 150); // 50 odd ids x 3
        assert!(s.report().contains("34 preemptions"));
        assert!(s.report().contains("150 prefix-cached tokens"));
    }

    #[test]
    fn shard_stats_report_and_totals() {
        let a = ShardStats {
            shard: 0,
            placed: 5,
            stolen: 0,
            served: 4,
            evictions: 1,
            peak_active: 3,
        };
        let b = ShardStats {
            stolen: 1,
            served: 2,
            ..ShardStats::new(1)
        };
        assert_eq!(b.shard, 1);
        assert_eq!(
            a.report(),
            "shard 0: placed 5 | stole 0 | served 4 | 1 preemptions | peak 3 active"
        );
        let merged = shard_report(&[a, b]);
        assert!(merged.contains("shard 1: placed 0 | stole 1 | served 2"));
        assert!(merged.ends_with("2 workers | 6 served | 1 stolen"));
    }

    #[test]
    fn shard_report_orders_by_worker_id_regardless_of_input_order() {
        // Threaded collectors can hand the stats over in join order;
        // the report must come out in ascending worker-id order anyway.
        let shards: Vec<ShardStats> = [3usize, 0, 2, 1]
            .into_iter()
            .map(|w| ShardStats {
                served: w + 1,
                ..ShardStats::new(w)
            })
            .collect();
        let merged = shard_report(&shards);
        let lines: Vec<&str> = merged.lines().collect();
        assert_eq!(lines.len(), 5);
        for (i, line) in lines[..4].iter().enumerate() {
            assert!(
                line.starts_with(&format!("shard {i}:")),
                "line {i} out of order: {line}"
            );
        }
        assert!(lines[4].starts_with("4 workers | 10 served"));
    }

    #[test]
    fn report_includes_zero_valued_counters() {
        // The summary line is grepped by CI and diffed across runs: the
        // eviction / cached-token fields must appear even when zero, not
        // vanish and shift the line's shape.
        let rs = vec![Response {
            evictions: 0,
            cached_tokens: 0,
            ..resp(1, 1.0) // id 1: resp() gives nonzero cached otherwise
        }];
        let s = LatencyStats::from_responses(&rs, 1.0);
        assert_eq!(s.evictions, 0);
        assert_eq!(s.cached_tokens, 0);
        let line = s.report();
        assert!(line.contains("0 preemptions"), "{line}");
        assert!(line.contains("0 prefix-cached tokens"), "{line}");
    }

    #[test]
    fn nan_latency_does_not_panic_or_poison_percentiles() {
        // Regression: sorted_field used partial_cmp(..).unwrap(), so a
        // single NaN ttft panicked the stats pass after an otherwise
        // successful run. NaNs must be tolerated and excluded from the
        // percentile sample.
        let mut rs: Vec<Response> = (0..9).map(|i| resp(i, (i + 1) as f64)).collect();
        rs.push(Response {
            ttft_s: f64::NAN,
            queue_s: -f64::NAN,
            ..resp(9, 10.0)
        });
        let s = LatencyStats::from_responses(&rs, 1.0);
        // service_s is NaN-free: percentiles as usual over 1..=10.
        assert_eq!(s.p50_service_s, 6.0);
        assert_eq!(s.p99_service_s, 10.0);
        // ttft (+NaN sorts last) and queue (-NaN sorts first) both
        // report percentiles over the 9 real samples.
        assert!(!s.p50_ttft_s.is_nan() && !s.p95_ttft_s.is_nan());
        assert_eq!(s.p95_ttft_s, 4.5); // max real ttft: 9.0 / 2
        assert!(!s.p50_queue_s.is_nan() && !s.p95_queue_s.is_nan());
        assert_eq!(s.p95_queue_s, 2.25); // max real queue: 9.0 / 4
        // Empty and all-NaN samples degrade to NaN, never panic.
        let empty = LatencyStats::from_responses(&[], 1.0);
        assert!(empty.p50_service_s.is_nan());
        let all_nan = LatencyStats::from_responses(
            &[Response {
                service_s: f64::NAN,
                ..resp(0, 1.0)
            }],
            1.0,
        );
        assert!(all_nan.p50_service_s.is_nan());
    }

    #[test]
    fn lane_stats_read_counters_and_report_without_nan() {
        let obs = Obs::new(0);
        // Disabled: counts are dropped, stats stay zero, report stays
        // well-formed (0.0%, not NaN).
        obs.count(Counter::SpecProposed, 5);
        let off = LaneStats::from_obs(&obs);
        assert_eq!(off, LaneStats::default());
        assert_eq!(off.acceptance(), 0.0);
        assert!(off.report().contains("(0.0%)"), "{}", off.report());
        // Enabled: the four lane counters flow through.
        obs.set_enabled(true);
        obs.count(Counter::LanePrefillTokens, 96);
        obs.count(Counter::LaneDecodeTokens, 80);
        obs.count(Counter::SpecProposed, 60);
        obs.count(Counter::SpecAccepted, 45);
        let on = LaneStats::from_obs(&obs);
        assert_eq!(
            on,
            LaneStats {
                prefill_tokens: 96,
                decode_tokens: 80,
                proposed: 60,
                accepted: 45,
            }
        );
        assert!((on.acceptance() - 0.75).abs() < 1e-12);
        assert_eq!(
            on.report(),
            "lanes: 96 prefill + 80 decode tokens | spec: 45/60 proposals \
             accepted (75.0%)"
        );
    }

    #[test]
    fn percentile_handles_singleton() {
        let s = LatencyStats::from_responses(&[resp(0, 2.0)], 2.0);
        assert_eq!(s.p50_service_s, 2.0);
        assert_eq!(s.p99_service_s, 2.0);
        assert_eq!(s.p95_ttft_s, 1.0);
        assert_eq!(s.p95_queue_s, 0.5);
    }
}
