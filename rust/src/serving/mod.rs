//! Edge-serving front end: a request queue feeding the runtime engine,
//! with FIFO admission, round-robin continuous batching across active
//! sessions (the engine decodes one token per call, so "batching"
//! interleaves sessions token-wise — exactly the one-token-per-iteration
//! regime the paper's architecture is built for), and latency
//! statistics. A threaded front end (`serve_threaded_with`) drives
//! multiple engine replicas; the offline build has no tokio, so
//! concurrency is std::thread-based (documented substitution — see
//! Cargo.toml).

pub mod stats;

pub use stats::LatencyStats;

use crate::runtime::{Engine, TinyDecoder};
use crate::util::error::Result;
use std::collections::VecDeque;
use std::time::Instant;

/// One inference request.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub n_new: usize,
}

/// A finished request.
#[derive(Debug, Clone, PartialEq)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<i32>,
    /// Queueing delay before the first decode step.
    pub queue_s: f64,
    /// Time from admission to completion.
    pub service_s: f64,
    /// Time to first generated token (prompt ingestion included).
    pub ttft_s: f64,
}

/// Scheduler policy for the serving loop.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// Run each request to completion before admitting the next.
    Fifo,
    /// Interleave decode steps across up to `max_active` sessions.
    RoundRobin { max_active: usize },
}

struct Active<'e> {
    req: Request,
    dec: TinyDecoder<'e>,
    fed: usize,
    admitted: Instant,
    arrived: Instant,
    first_token_at: Option<f64>,
}

impl<'e> Active<'e> {
    /// Advance by one token step. Returns true when finished.
    fn step(&mut self) -> Result<bool> {
        if self.fed < self.req.prompt.len() {
            let t = self.req.prompt[self.fed];
            self.dec.feed(t)?;
        } else {
            let next = self.dec.greedy_next();
            self.dec.feed(next)?;
            if self.first_token_at.is_none() {
                self.first_token_at = Some(self.arrived.elapsed().as_secs_f64());
            }
        }
        self.fed += 1;
        Ok(self.fed >= self.req.prompt.len() + self.req.n_new)
    }
}

/// Synchronous serving engine (the async front end in `serve_async`
/// drives this from a tokio task; the PJRT call itself is blocking).
pub struct Server<'e> {
    engine: &'e Engine,
    policy: Policy,
}

impl<'e> Server<'e> {
    pub fn new(engine: &'e Engine, policy: Policy) -> Self {
        Self { engine, policy }
    }

    /// Serve a batch of requests to completion, returning responses in
    /// completion order.
    pub fn serve(&self, requests: Vec<Request>) -> Result<Vec<Response>> {
        let t0 = Instant::now();
        let mut queue: VecDeque<(Request, Instant)> =
            requests.into_iter().map(|r| (r, t0)).collect();
        let mut active: Vec<Active<'e>> = Vec::new();
        let mut done = Vec::new();
        let max_active = match self.policy {
            Policy::Fifo => 1,
            Policy::RoundRobin { max_active } => max_active.max(1),
        };

        while !queue.is_empty() || !active.is_empty() {
            // Admit.
            while active.len() < max_active {
                let Some((req, arrived)) = queue.pop_front() else {
                    break;
                };
                let dec = TinyDecoder::new(self.engine)?;
                active.push(Active {
                    req,
                    dec,
                    fed: 0,
                    admitted: Instant::now(),
                    arrived,
                    first_token_at: None,
                });
            }
            // One round-robin pass: each active session advances a token.
            let mut i = 0;
            while i < active.len() {
                let finished = active[i].step()?;
                if finished {
                    let a = active.swap_remove(i);
                    done.push(Response {
                        id: a.req.id,
                        tokens: a.dec.tokens.clone(),
                        queue_s: (a.admitted - a.arrived).as_secs_f64(),
                        service_s: a.arrived.elapsed().as_secs_f64(),
                        ttft_s: a
                            .first_token_at
                            .unwrap_or_else(|| a.arrived.elapsed().as_secs_f64()),
                    });
                } else {
                    i += 1;
                }
            }
        }
        Ok(done)
    }
}

/// Threaded front end: shard the request list across `workers` threads,
/// each driving its **own engine replica** built by `make_engine`
/// (engine backends are not `Sync` — the pjrt feature's PJRT handles in
/// particular — so replication, one engine per worker, is the sound
/// multi-worker topology; it also mirrors a real deployment where each
/// accelerator instance holds its own programmed crossbars).
pub fn serve_threaded_with<F>(
    make_engine: F,
    requests: Vec<Request>,
    workers: usize,
    max_active: usize,
) -> Result<Vec<Response>>
where
    F: Fn() -> Result<Engine> + Sync,
{
    let workers = workers.clamp(1, requests.len().max(1));
    // Shard round-robin so load is balanced even with mixed lengths.
    let mut shards: Vec<Vec<Request>> = (0..workers).map(|_| Vec::new()).collect();
    for (i, r) in requests.into_iter().enumerate() {
        shards[i % workers].push(r);
    }
    let results: Vec<Result<Vec<Response>>> = std::thread::scope(|scope| {
        let make_engine = &make_engine;
        let handles: Vec<_> = shards
            .into_iter()
            .map(|shard| {
                scope.spawn(move || {
                    let engine = make_engine()?;
                    Server::new(&engine, Policy::RoundRobin { max_active }).serve(shard)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("worker panicked"))
            .collect()
    });
    let mut out = Vec::new();
    for r in results {
        out.extend(r?);
    }
    out.sort_by_key(|r| r.id);
    Ok(out)
}

/// Threaded front end loading each replica from an artifact directory.
pub fn serve_threaded(
    artifacts_dir: &std::path::Path,
    requests: Vec<Request>,
    workers: usize,
    max_active: usize,
) -> Result<Vec<Response>> {
    serve_threaded_with(
        || Engine::load(crate::runtime::Artifacts::load(artifacts_dir)?),
        requests,
        workers,
        max_active,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Artifacts;

    const SEED: u64 = 11;

    fn engine() -> Engine {
        Engine::load(Artifacts::synthetic(SEED).unwrap()).unwrap()
    }

    fn reqs(n: u64) -> Vec<Request> {
        (0..n)
            .map(|id| Request {
                id,
                prompt: vec![(id % 7) as i32 + 1, 2, 3],
                n_new: 4,
            })
            .collect()
    }

    #[test]
    fn fifo_serves_all_and_preserves_order() {
        let e = engine();
        let server = Server::new(&e, Policy::Fifo);
        let out = server.serve(reqs(3)).unwrap();
        assert_eq!(out.len(), 3);
        let ids: Vec<u64> = out.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2]);
        for r in &out {
            assert_eq!(r.tokens.len(), 3 + 4);
        }
    }

    #[test]
    fn round_robin_matches_fifo_outputs() {
        let e = engine();
        let fifo = Server::new(&e, Policy::Fifo).serve(reqs(3)).unwrap();
        let rr = Server::new(&e, Policy::RoundRobin { max_active: 3 })
            .serve(reqs(3))
            .unwrap();
        // Same generated tokens regardless of interleaving (isolation).
        for f in &fifo {
            let r = rr.iter().find(|r| r.id == f.id).unwrap();
            assert_eq!(f.tokens, r.tokens, "request {}", f.id);
        }
    }

    #[test]
    fn responses_have_sane_timing() {
        let e = engine();
        let out = Server::new(&e, Policy::RoundRobin { max_active: 2 })
            .serve(reqs(2))
            .unwrap();
        for r in out {
            assert!(r.service_s > 0.0);
            assert!(r.ttft_s <= r.service_s + 1e-9);
        }
    }

    #[test]
    fn threaded_front_end_serves_and_sorts() {
        let out = serve_threaded_with(
            || Engine::load(Artifacts::synthetic(SEED)?),
            reqs(4),
            2,
            2,
        )
        .unwrap();
        assert_eq!(out.len(), 4);
        let ids: Vec<u64> = out.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3]);
    }

    #[test]
    fn threaded_replicas_match_single_engine() {
        // Worker replicas are deterministic copies: the sharded threaded
        // path must produce exactly the tokens the single-engine server
        // produces.
        let single = Server::new(&engine(), Policy::RoundRobin { max_active: 2 })
            .serve(reqs(4))
            .unwrap();
        let threaded = serve_threaded_with(
            || Engine::load(Artifacts::synthetic(SEED)?),
            reqs(4),
            2,
            2,
        )
        .unwrap();
        for t in &threaded {
            let s = single.iter().find(|s| s.id == t.id).unwrap();
            assert_eq!(s.tokens, t.tokens, "request {}", t.id);
        }
    }
}
