//! Edge-serving front end: a request queue feeding the runtime engine,
//! with FIFO admission, latency statistics, and three schedulers:
//!
//! * [`Policy::Fifo`] — each request runs to completion alone.
//! * [`Policy::RoundRobin`] — token-wise interleaving across up to
//!   `max_active` sessions, one `decode_step` per session per tick.
//! * [`Policy::Batched`] — the paper's regime: every scheduler tick
//!   issues ONE `decode_batch` over all active sessions (sessions still
//!   prefilling and sessions generating advance together), so each
//!   layer's weights are traversed once per tick for the whole batch
//!   instead of once per session. The `batch` knob is the admission cap.
//!
//! All three produce identical tokens for identical requests (enforced
//! by `tests/batch_equivalence.rs`); they differ only in throughput and
//! latency shape. A threaded front end (`serve_threaded_with`) drives
//! multiple engine replicas; the offline build has no tokio, so
//! concurrency is std::thread-based (documented substitution — see
//! Cargo.toml).

pub mod stats;

pub use stats::LatencyStats;

use crate::runtime::decoder::greedy_argmax;
use crate::runtime::{Caches, Engine, StepOutput};
use crate::util::error::{ensure, Result};
use std::collections::VecDeque;
use std::time::Instant;

/// One inference request.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub n_new: usize,
}

/// A finished request.
#[derive(Debug, Clone, PartialEq)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<i32>,
    /// Queueing delay before the first decode step.
    pub queue_s: f64,
    /// Time from arrival to completion.
    pub service_s: f64,
    /// Time to first generated token (prompt ingestion included).
    pub ttft_s: f64,
}

/// Scheduler policy for the serving loop.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// Run each request to completion before admitting the next.
    Fifo,
    /// Interleave decode steps across up to `max_active` sessions, one
    /// engine call per session per tick.
    RoundRobin { max_active: usize },
    /// Admit up to `batch` sessions and advance ALL of them with a
    /// single `decode_batch` per tick — one weight traversal per tick
    /// regardless of how many users are active.
    Batched { batch: usize },
}

/// One admitted session: its decode state plus bookkeeping for the
/// latency stats. Prefill and generation are both driven through
/// [`Active::next_token`]/[`Active::absorb`], so a tick can mix sessions
/// in either phase.
struct Active {
    req: Request,
    caches: Option<Caches>,
    pos: i32,
    tokens: Vec<i32>,
    last_logits: Vec<f32>,
    fed: usize,
    admitted: Instant,
    arrived: Instant,
    first_token_at: Option<f64>,
}

impl Active {
    fn admit(req: Request, engine: &Engine, arrived: Instant) -> Result<Self> {
        Ok(Self {
            caches: Some(engine.empty_caches()?),
            req,
            pos: 0,
            tokens: Vec::new(),
            last_logits: Vec::new(),
            fed: 0,
            admitted: Instant::now(),
            arrived,
            first_token_at: None,
        })
    }

    fn done(&self) -> bool {
        self.fed >= self.req.prompt.len() + self.req.n_new
    }

    /// Token this session feeds next: its next prompt token while
    /// prefilling, else its greedy continuation via the shared
    /// [`greedy_argmax`] convention (token 0 before any logits exist).
    fn next_token(&self) -> i32 {
        if self.fed < self.req.prompt.len() {
            self.req.prompt[self.fed]
        } else {
            greedy_argmax(&self.last_logits)
        }
    }

    /// Account one fed token + its engine output.
    fn absorb(&mut self, token: i32, out: StepOutput) {
        let generated = self.fed >= self.req.prompt.len();
        self.caches = Some(out.caches);
        self.last_logits = out.logits;
        self.tokens.push(token);
        self.fed += 1;
        self.pos += 1;
        if generated && self.first_token_at.is_none() {
            self.first_token_at = Some(self.arrived.elapsed().as_secs_f64());
        }
    }

    fn finish(self) -> Response {
        let service_s = self.arrived.elapsed().as_secs_f64();
        Response {
            id: self.req.id,
            tokens: self.tokens,
            queue_s: (self.admitted - self.arrived).as_secs_f64(),
            service_s,
            ttft_s: self.first_token_at.unwrap_or(service_s),
        }
    }
}

/// Synchronous serving engine (the threaded front end drives one of
/// these per worker; the engine call itself is blocking).
pub struct Server<'e> {
    engine: &'e Engine,
    policy: Policy,
}

impl<'e> Server<'e> {
    pub fn new(engine: &'e Engine, policy: Policy) -> Self {
        Self { engine, policy }
    }

    /// Serve a batch of requests to completion, returning responses in
    /// completion order.
    pub fn serve(&self, requests: Vec<Request>) -> Result<Vec<Response>> {
        let t0 = Instant::now();
        let mut queue: VecDeque<(Request, Instant)> =
            requests.into_iter().map(|r| (r, t0)).collect();
        let mut active: Vec<Active> = Vec::new();
        let mut done = Vec::new();
        let max_active = match self.policy {
            Policy::Fifo => 1,
            Policy::RoundRobin { max_active } => max_active.max(1),
            Policy::Batched { batch } => batch.max(1),
        };
        let max_ctx = self.engine.max_ctx();

        while !queue.is_empty() || !active.is_empty() {
            // Admission: top the active set up to the cap. Requests that
            // cannot fit the context window are rejected here, not
            // mid-decode; zero-work requests (empty prompt, n_new == 0)
            // complete immediately without occupying a batch lane.
            while active.len() < max_active {
                let Some((req, arrived)) = queue.pop_front() else {
                    break;
                };
                ensure!(
                    req.prompt.len() + req.n_new <= max_ctx,
                    "request {} needs {} tokens > max_ctx {max_ctx}",
                    req.id,
                    req.prompt.len() + req.n_new
                );
                let a = Active::admit(req, self.engine, arrived)?;
                if a.done() {
                    done.push(a.finish());
                } else {
                    active.push(a);
                }
            }
            if active.is_empty() {
                continue;
            }

            // One scheduler tick: every active session advances exactly
            // one token (prefill or generate, mixed freely).
            match self.policy {
                Policy::Batched { .. } => {
                    let tokens: Vec<i32> = active.iter().map(Active::next_token).collect();
                    let positions: Vec<i32> = active.iter().map(|a| a.pos).collect();
                    let caches: Vec<Caches> = active
                        .iter_mut()
                        .map(|a| a.caches.take().expect("caches present"))
                        .collect();
                    let outs = self.engine.decode_batch(caches, &tokens, &positions)?;
                    for ((a, out), &t) in active.iter_mut().zip(outs).zip(&tokens) {
                        a.absorb(t, out);
                    }
                }
                Policy::Fifo | Policy::RoundRobin { .. } => {
                    for a in active.iter_mut() {
                        let t = a.next_token();
                        let caches = a.caches.take().expect("caches present");
                        let out = self.engine.decode_step(caches, t, a.pos)?;
                        a.absorb(t, out);
                    }
                }
            }

            // Sweep finished sessions (completion order).
            let mut i = 0;
            while i < active.len() {
                if active[i].done() {
                    done.push(active.swap_remove(i).finish());
                } else {
                    i += 1;
                }
            }
        }
        Ok(done)
    }
}

/// Threaded front end: shard the request list across `workers` threads,
/// each driving its **own engine replica** built by `make_engine`
/// (engine backends are not `Sync` — the pjrt feature's PJRT handles in
/// particular — so replication, one engine per worker, is the sound
/// multi-worker topology; it also mirrors a real deployment where each
/// accelerator instance holds its own programmed crossbars). Each worker
/// runs the given scheduling `policy` over its shard.
pub fn serve_threaded_policy<F>(
    make_engine: F,
    requests: Vec<Request>,
    workers: usize,
    policy: Policy,
) -> Result<Vec<Response>>
where
    F: Fn() -> Result<Engine> + Sync,
{
    let workers = workers.clamp(1, requests.len().max(1));
    // Shard round-robin so load is balanced even with mixed lengths.
    let mut shards: Vec<Vec<Request>> = (0..workers).map(|_| Vec::new()).collect();
    for (i, r) in requests.into_iter().enumerate() {
        shards[i % workers].push(r);
    }
    let results: Vec<Result<Vec<Response>>> = std::thread::scope(|scope| {
        let make_engine = &make_engine;
        let handles: Vec<_> = shards
            .into_iter()
            .map(|shard| {
                scope.spawn(move || {
                    let engine = make_engine()?;
                    Server::new(&engine, policy).serve(shard)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("worker panicked"))
            .collect()
    });
    let mut out = Vec::new();
    for r in results {
        out.extend(r?);
    }
    out.sort_by_key(|r| r.id);
    Ok(out)
}

/// [`serve_threaded_policy`] with the historical round-robin policy.
pub fn serve_threaded_with<F>(
    make_engine: F,
    requests: Vec<Request>,
    workers: usize,
    max_active: usize,
) -> Result<Vec<Response>>
where
    F: Fn() -> Result<Engine> + Sync,
{
    serve_threaded_policy(
        make_engine,
        requests,
        workers,
        Policy::RoundRobin { max_active },
    )
}

/// Threaded front end loading each replica from an artifact directory.
pub fn serve_threaded(
    artifacts_dir: &std::path::Path,
    requests: Vec<Request>,
    workers: usize,
    max_active: usize,
) -> Result<Vec<Response>> {
    serve_threaded_with(
        || Engine::load(crate::runtime::Artifacts::load(artifacts_dir)?),
        requests,
        workers,
        max_active,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Artifacts;

    const SEED: u64 = 11;

    fn engine() -> Engine {
        Engine::load(Artifacts::synthetic(SEED).unwrap()).unwrap()
    }

    fn reqs(n: u64) -> Vec<Request> {
        (0..n)
            .map(|id| Request {
                id,
                prompt: vec![(id % 7) as i32 + 1, 2, 3],
                n_new: 4,
            })
            .collect()
    }

    #[test]
    fn fifo_serves_all_and_preserves_order() {
        let e = engine();
        let server = Server::new(&e, Policy::Fifo);
        let out = server.serve(reqs(3)).unwrap();
        assert_eq!(out.len(), 3);
        let ids: Vec<u64> = out.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2]);
        for r in &out {
            assert_eq!(r.tokens.len(), 3 + 4);
        }
    }

    #[test]
    fn round_robin_matches_fifo_outputs() {
        let e = engine();
        let fifo = Server::new(&e, Policy::Fifo).serve(reqs(3)).unwrap();
        let rr = Server::new(&e, Policy::RoundRobin { max_active: 3 })
            .serve(reqs(3))
            .unwrap();
        // Same generated tokens regardless of interleaving (isolation).
        for f in &fifo {
            let r = rr.iter().find(|r| r.id == f.id).unwrap();
            assert_eq!(f.tokens, r.tokens, "request {}", f.id);
        }
    }

    #[test]
    fn batched_matches_fifo_outputs() {
        // The batched scheduler (one decode_batch per tick) must be
        // token-for-token identical to per-session decoding.
        let e = engine();
        let fifo = Server::new(&e, Policy::Fifo).serve(reqs(5)).unwrap();
        let batched = Server::new(&e, Policy::Batched { batch: 3 })
            .serve(reqs(5))
            .unwrap();
        assert_eq!(batched.len(), 5);
        for f in &fifo {
            let b = batched.iter().find(|b| b.id == f.id).unwrap();
            assert_eq!(f.tokens, b.tokens, "request {}", f.id);
        }
    }

    #[test]
    fn batched_handles_ragged_and_degenerate_requests() {
        // Mixed prompt lengths, empty prompts, and zero-work requests in
        // one batch: everything completes, empty-prompt generation
        // starts from token 0, zero-work requests return no tokens.
        let e = engine();
        let requests = vec![
            Request { id: 0, prompt: vec![1, 2, 3, 4, 5], n_new: 2 },
            Request { id: 1, prompt: vec![], n_new: 3 },
            Request { id: 2, prompt: vec![9], n_new: 0 },
            Request { id: 3, prompt: vec![], n_new: 0 },
        ];
        let out = Server::new(&e, Policy::Batched { batch: 4 })
            .serve(requests.clone())
            .unwrap();
        assert_eq!(out.len(), 4);
        let by_id = |id: u64| out.iter().find(|r| r.id == id).unwrap();
        assert_eq!(by_id(0).tokens.len(), 7);
        assert_eq!(by_id(1).tokens.len(), 3);
        assert_eq!(by_id(1).tokens[0], 0);
        assert_eq!(by_id(2).tokens, vec![9]);
        assert!(by_id(3).tokens.is_empty());
        // And identically under the sequential schedulers.
        for policy in [Policy::Fifo, Policy::RoundRobin { max_active: 2 }] {
            let seq = Server::new(&e, policy).serve(requests.clone()).unwrap();
            for r in &out {
                let s = seq.iter().find(|s| s.id == r.id).unwrap();
                assert_eq!(r.tokens, s.tokens, "request {} under {policy:?}", r.id);
            }
        }
    }

    #[test]
    fn oversized_request_rejected_at_admission() {
        let e = engine();
        let max_ctx = e.max_ctx();
        let out = Server::new(&e, Policy::Batched { batch: 2 }).serve(vec![Request {
            id: 0,
            prompt: vec![1; max_ctx],
            n_new: 1,
        }]);
        assert!(out.is_err());
    }

    #[test]
    fn responses_have_sane_timing() {
        let e = engine();
        let out = Server::new(&e, Policy::Batched { batch: 2 })
            .serve(reqs(2))
            .unwrap();
        for r in out {
            assert!(r.service_s > 0.0);
            assert!(r.ttft_s <= r.service_s + 1e-9);
        }
    }

    #[test]
    fn threaded_front_end_serves_and_sorts() {
        let out = serve_threaded_with(
            || Engine::load(Artifacts::synthetic(SEED)?),
            reqs(4),
            2,
            2,
        )
        .unwrap();
        assert_eq!(out.len(), 4);
        let ids: Vec<u64> = out.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3]);
    }

    #[test]
    fn threaded_replicas_match_single_engine() {
        // Worker replicas are deterministic copies: the sharded threaded
        // path must produce exactly the tokens the single-engine server
        // produces — under both the round-robin and batched policies.
        let single = Server::new(&engine(), Policy::RoundRobin { max_active: 2 })
            .serve(reqs(4))
            .unwrap();
        for policy in [
            Policy::RoundRobin { max_active: 2 },
            Policy::Batched { batch: 2 },
        ] {
            let threaded = serve_threaded_policy(
                || Engine::load(Artifacts::synthetic(SEED)?),
                reqs(4),
                2,
                policy,
            )
            .unwrap();
            for t in &threaded {
                let s = single.iter().find(|s| s.id == t.id).unwrap();
                assert_eq!(s.tokens, t.tokens, "request {} under {policy:?}", t.id);
            }
        }
    }
}
