//! Edge-serving front end: a request queue feeding the runtime engine,
//! with latency statistics and four schedulers:
//!
//! * [`Policy::Fifo`] — each request runs to completion alone.
//! * [`Policy::RoundRobin`] — token-wise interleaving across up to
//!   `max_active` sessions, one `decode_step` per session per tick.
//! * [`Policy::Batched`] — fixed-wave batching: every scheduler tick
//!   issues ONE `decode_batch` over all active sessions (sessions still
//!   prefilling and sessions generating advance together), so each
//!   layer's weights are traversed once per tick for the whole batch.
//!   The `batch` knob is the admission cap, and — like `Fifo` and
//!   `RoundRobin` — admission RESERVES the request's worst-case KV-cache
//!   blocks up front, so concurrency is bounded by worst-case context.
//! * [`Policy::Continuous`] — continuous batching over the paged arena
//!   (the HPIM/PIM-AI serving regime): sessions are admitted and
//!   retired every tick against ACTUAL block usage, cache blocks are
//!   claimed on demand as positions advance, and under arena pressure
//!   the youngest session is preempted — its blocks freed, its request
//!   requeued at the front for a deterministic re-prefill. Same one
//!   `decode_batch` per tick as `Batched`, but more sessions fit the
//!   same arena because nothing idles on a worst-case reservation.
//!
//! * [`Policy::Sharded`] — N worker threads, each owning one
//!   [`EngineShard`] (a private slice of the total arena capacity) and
//!   running its own continuous-batching tick over its resident
//!   sessions. Requests are placed deterministically
//!   (`shard_for(id) % workers`), idle workers steal whole
//!   not-yet-prefilled requests from backlogged shards, and each shard
//!   keeps a private prefix index — no block, refcount, or lock is ever
//!   shared between threads. Driven by [`serve_sharded`] over a
//!   [`ShardedEngine`]; the single-thread policies above are its
//!   `workers = 1` oracle.
//!
//! All five produce identical tokens for identical requests (sessions
//! are isolated and re-prefill is deterministic — enforced by
//! `tests/batch_equivalence.rs` and `tests/paged_equivalence.rs`); they
//! differ only in throughput and latency shape. That purity is also the
//! sharded determinism proof: a request's tokens depend on nothing but
//! the request, and stealing only moves requests that have not started
//! (or have been preempted back to nothing), so worker count, placement
//! and steal timing can change WHO decodes a request but never WHAT it
//! decodes — `tests/shard_determinism.rs` pins byte-identical responses
//! across `workers ∈ {1, 2, 4, 8}`.
//!
//! Lane scheduling: [`Server::with_prefill_chunk`] splits the tick into
//! a PREFILL lane (each still-ingesting session advances up to `chunk`
//! prompt positions through one `decode_span` traversal, so long
//! prompts stop serializing everyone else's time-to-first-token) and a
//! DECODE lane (sessions generating at tick start advance one token
//! each). [`Server::with_spec`] upgrades the decode lane to greedy-exact
//! speculative decoding ([`crate::runtime::spec`]): a draft proposes up
//! to `k - 1` tokens, the target verifies the whole span in ONE
//! traversal, and rejected positions are rolled back through the arena
//! block tables. Both are scheduling-only: a session's fed sequence
//! never changes, so served tokens are byte-identical to the classic
//! single-position tick (`tests/chunked_prefill.rs`,
//! `tests/spec_equivalence.rs`).
//!
//! Prefix sharing: with the engine's copy-on-write prefix cache enabled
//! ([`crate::runtime::Engine::enable_prefix_cache`], the
//! `--prefix-cache` knob), admission consults the token-keyed index
//! before reserving or claiming blocks — matched prompt positions are
//! adopted as shared read-only blocks and their prefill decode is
//! skipped entirely; completed prefills are recorded back into the
//! index. Under every policy the cache changes no token (adopted state
//! is bitwise cold-prefill state — `tests/prefix_equivalence.rs`), and
//! under block pressure index pins are reclaimed LRU-first, before any
//! session is preempted. Requests can arrive
//! over time ([`Server::serve_arrivals`]) — with all offsets zero the
//! schedule is a pure function of the request list, which is what the
//! determinism suite pins. Two threaded front ends exist: the
//! [`ThreadedServe`] builder replicates one full engine per worker (the
//! only sound topology for non-`Send` backends like PJRT), and
//! [`serve_sharded`] partitions ONE arena across worker-owned shards.
//! The offline build has no tokio, so concurrency is std::thread-based
//! (documented substitution — see Cargo.toml).

pub mod stats;

pub use stats::{shard_report, LaneStats, LatencyStats, ShardStats};

use crate::obs::{Counter, EventKind, Gauge, Hist, SpanKind};
use crate::runtime::decoder::greedy_argmax;
use crate::runtime::engine::{shard_for, EngineImpl, EngineShard, ShardedEngine};
use crate::runtime::spec::{SpecPlan, SpecState};
use crate::runtime::{ArenaLayout, Backend, CacheHandle, Engine};
use crate::util::error::{ensure, Context, Result};
use std::cell::{Cell, RefCell};
use std::collections::VecDeque;
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// One inference request.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub n_new: usize,
}

impl Request {
    /// Total tokens this request will feed (prompt + generated).
    fn total_tokens(&self) -> usize {
        self.prompt.len() + self.n_new
    }
}

/// A finished request.
#[derive(Debug, Clone, PartialEq)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<i32>,
    /// Queueing delay before the FIRST admission (re-admissions after a
    /// preemption do not reset it).
    pub queue_s: f64,
    /// Time from arrival to completion (end-to-end latency).
    pub service_s: f64,
    /// Time from arrival to the first generated token (prompt ingestion
    /// included; preserved across preemptions).
    pub ttft_s: f64,
    /// How many times the continuous scheduler preempted this request
    /// (0 under the fixed-wave policies).
    pub evictions: u32,
    /// Prompt positions served from the copy-on-write prefix cache
    /// instead of prefill decode — summed across re-admissions (a
    /// preempted request that re-shares its prefix saves the work
    /// again). 0 with the cache off.
    pub cached_tokens: usize,
}

/// Scheduler policy for the serving loop.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// Run each request to completion before admitting the next.
    Fifo,
    /// Interleave decode steps across up to `max_active` sessions, one
    /// engine call per session per tick.
    RoundRobin { max_active: usize },
    /// Admit up to `batch` sessions (each with a worst-case block
    /// reservation) and advance ALL of them with a single `decode_batch`
    /// per tick — one weight traversal per tick regardless of how many
    /// users are active.
    Batched { batch: usize },
    /// Continuous batching: up to `max_active` sessions advanced by one
    /// `decode_batch` per tick, blocks claimed on demand,
    /// pressure-aware admission and youngest-first preemption.
    Continuous { max_active: usize },
    /// `workers` threads, each running the continuous tick over its own
    /// [`EngineShard`] with up to `max_active` resident sessions PER
    /// shard. Only meaningful through [`serve_sharded`] on a
    /// [`ShardedEngine`]; handing it to a single-engine [`Server`] or
    /// the replica front end is an error, not a silent fallback.
    Sharded { workers: usize, max_active: usize },
}

impl Policy {
    /// Resolve an explicit `--policy` NAME. `batch`/`max_active` size
    /// the admission lanes exactly as [`Policy::from_flags`] does, and
    /// `workers` only matters for `sharded`. Unrecognized names get an
    /// error that lists every valid spelling — the CLI shows it
    /// verbatim, so a typo is a one-glance fix.
    pub fn from_name(
        name: &str,
        batch: usize,
        max_active: usize,
        workers: usize,
    ) -> Result<Policy> {
        let lanes = if batch > 0 { batch } else { max_active.max(1) };
        match name {
            "fifo" => Ok(Policy::Fifo),
            "rr" | "round-robin" => Ok(Policy::RoundRobin { max_active }),
            "batched" => Ok(Policy::Batched { batch: lanes }),
            "continuous" => Ok(Policy::Continuous { max_active: lanes }),
            "sharded" => Ok(Policy::Sharded {
                workers: workers.max(1),
                max_active: lanes,
            }),
            other => {
                crate::bail!(
                    "unknown policy '{other}' — valid policies are: fifo | rr | \
                     batched | continuous | sharded"
                )
            }
        }
    }

    /// Resolve the CLI surface (`--policy` plus the `--batch` /
    /// `--max-active` / `--workers` knobs). With no `--policy`, the
    /// historical behavior is kept: `--batch B > 0` selects the batched
    /// scheduler, otherwise round-robin.
    pub fn from_flags(
        name: Option<&str>,
        batch: usize,
        max_active: usize,
        workers: usize,
    ) -> Result<Policy> {
        match name {
            None => Ok(if batch > 0 {
                Policy::Batched { batch }
            } else {
                Policy::RoundRobin { max_active }
            }),
            Some(name) => Self::from_name(name, batch, max_active, workers),
        }
    }

    /// Admission lane cap (per worker under [`Policy::Sharded`]).
    fn max_active(self) -> usize {
        match self {
            Policy::Fifo => 1,
            Policy::RoundRobin { max_active }
            | Policy::Continuous { max_active }
            | Policy::Sharded { max_active, .. } => max_active.max(1),
            Policy::Batched { batch } => batch.max(1),
        }
    }

    /// Whether admission pre-reserves the request's worst-case block
    /// count (the fixed-wave policies) instead of claiming on demand.
    /// A shard's tick is the continuous tick, so `Sharded` claims on
    /// demand too.
    fn reserves_worst_case(self) -> bool {
        !matches!(self, Policy::Continuous { .. } | Policy::Sharded { .. })
    }
}

/// A request waiting for (re-)admission, with the latency bookkeeping
/// that must survive preemption.
struct Pending {
    req: Request,
    arrived: Instant,
    first_admitted: Option<Instant>,
    /// Seconds from arrival to the first generated token, if it was
    /// produced before a preemption.
    first_token_at: Option<f64>,
    evictions: u32,
    /// Prefix-cache positions adopted so far (kept across preemptions).
    cached: usize,
}

impl Pending {
    fn new(req: Request, arrived: Instant) -> Self {
        Self {
            req,
            arrived,
            first_admitted: None,
            first_token_at: None,
            evictions: 0,
            cached: 0,
        }
    }

    /// Complete without ever occupying a lane (zero-work requests).
    fn finish_empty(self) -> Response {
        let now = Instant::now();
        let service_s = now.saturating_duration_since(self.arrived).as_secs_f64();
        Response {
            id: self.req.id,
            tokens: Vec::new(),
            queue_s: self
                .first_admitted
                .unwrap_or(now)
                .saturating_duration_since(self.arrived)
                .as_secs_f64(),
            service_s,
            ttft_s: self.first_token_at.unwrap_or(service_s),
            evictions: self.evictions,
            cached_tokens: self.cached,
        }
    }
}

/// One admitted session: its decode state plus bookkeeping for the
/// latency stats. Prefill and generation are both driven through
/// [`Active::next_token`]/[`Active::absorb`], so a tick can mix sessions
/// in either phase.
struct Active {
    req: Request,
    handle: CacheHandle,
    /// Admission order; the continuous scheduler preempts the HIGHEST
    /// seq (youngest) first, so the oldest session always progresses.
    seq: u64,
    pos: i32,
    tokens: Vec<i32>,
    last_logits: Vec<f32>,
    fed: usize,
    arrived: Instant,
    first_admitted: Instant,
    first_token_at: Option<f64>,
    evictions: u32,
    /// Prefix-cache positions adopted (across all admissions).
    cached: usize,
    /// Whether this session's prompt blocks have been recorded in the
    /// prefix index (once, at prefill completion).
    indexed: bool,
    /// Whether prefill has completed for THIS admission — which request
    /// lifetime span (prefill or decode) is currently open in the trace.
    /// Purely observational; never consulted by scheduling.
    prefill_done: bool,
}

impl Active {
    fn done(&self) -> bool {
        self.fed >= self.req.total_tokens()
    }

    /// Token this session feeds next: its next prompt token while
    /// prefilling, else its greedy continuation via the shared
    /// [`greedy_argmax`] convention (token 0 before any logits exist).
    fn next_token(&self) -> i32 {
        if self.fed < self.req.prompt.len() {
            self.req.prompt[self.fed]
        } else {
            greedy_argmax(&self.last_logits)
        }
    }

    /// Account one fed token + its engine output.
    fn absorb(&mut self, token: i32, logits: Vec<f32>) {
        let generated = self.fed >= self.req.prompt.len();
        self.last_logits = logits;
        self.tokens.push(token);
        self.fed += 1;
        self.pos += 1;
        if generated && self.first_token_at.is_none() {
            self.first_token_at = Some(
                Instant::now()
                    .saturating_duration_since(self.arrived)
                    .as_secs_f64(),
            );
        }
    }

    /// Preempt: discard decode progress (the re-prefill regenerates it
    /// deterministically) but keep the latency bookkeeping.
    fn into_pending(self) -> Pending {
        Pending {
            req: self.req,
            arrived: self.arrived,
            first_admitted: Some(self.first_admitted),
            first_token_at: self.first_token_at,
            evictions: self.evictions + 1,
            cached: self.cached,
        }
    }

    fn finish(self) -> Response {
        let service_s = Instant::now()
            .saturating_duration_since(self.arrived)
            .as_secs_f64();
        Response {
            id: self.req.id,
            tokens: self.tokens,
            queue_s: self
                .first_admitted
                .saturating_duration_since(self.arrived)
                .as_secs_f64(),
            service_s,
            ttft_s: self.first_token_at.unwrap_or(service_s),
            evictions: self.evictions,
            cached_tokens: self.cached,
        }
    }
}

/// Synchronous serving engine (the threaded front ends drive one of
/// these per worker; the engine call itself is blocking). Generic over
/// the engine's backend-box type for the same reason
/// [`EngineImpl`] is: `Server<'e>` (the default, `B = dyn Backend`)
/// is the classic single-engine server, while the sharded worker loop
/// instantiates `Server<'_, dyn Backend + Send>` over its
/// [`EngineShard`] and reuses the exact admission / pressure / tick /
/// sweep stages below — one battle-tested scheduler, two topologies.
pub struct Server<'e, B: ?Sized + Backend = dyn Backend> {
    engine: &'e EngineImpl<B>,
    policy: Policy,
    /// Run the arena's full invariant check every N ticks (0 = never) —
    /// the `--validate-every` debug knob. A failure aborts the serve
    /// with a structured error naming the tick.
    validate_every: usize,
    /// Scheduler ticks executed by this server (drives validate_every).
    ticks: Cell<u64>,
    /// Arena copy-on-write count at the last tick — the baseline the
    /// tick subtracts to attribute per-tick COW deltas to the trace.
    last_cow: Cell<u64>,
    /// Prefill-lane chunk: max prompt positions a prefilling session
    /// advances per tick. `0` keeps the classic one-position path;
    /// `>= 1` routes the tick through the two-lane scheduler (chunk 1
    /// feeds the same spans one position at a time — the boundary the
    /// chunked-prefill differential tests pin).
    prefill_chunk: usize,
    /// Greedy-exact speculative decoding state (`None` = off). Behind a
    /// `RefCell` because the tick advances draft sessions through
    /// `&self`, exactly like the tick counters above.
    spec: Option<RefCell<SpecState>>,
}

impl<'e, B: ?Sized + Backend> Server<'e, B> {
    pub fn new(engine: &'e EngineImpl<B>, policy: Policy) -> Self {
        Self {
            engine,
            policy,
            validate_every: 0,
            ticks: Cell::new(0),
            last_cow: Cell::new(engine.cow_copies()),
            prefill_chunk: 0,
            spec: None,
        }
    }

    /// Run [`EngineImpl::debug_validate`] every `n` ticks (0 disables,
    /// the default). Failures surface as structured errors naming the
    /// failing tick, instead of silent corruption compounding.
    pub fn with_validate_every(mut self, n: usize) -> Self {
        self.validate_every = n;
        self
    }

    /// Cap prompt positions per prefilling session per tick (the
    /// `--prefill-chunk` knob; 0 = classic single-position prefill).
    /// Scheduling only: every session still feeds its own tokens at its
    /// own positions, so served tokens are bitwise those of the
    /// unchunked path (`tests/chunked_prefill.rs`) — chunking changes
    /// WHEN prompt positions are fed, never WHAT any session decodes.
    pub fn with_prefill_chunk(mut self, chunk: usize) -> Self {
        self.prefill_chunk = chunk;
        self
    }

    /// Enable greedy-exact speculative decoding from a shared plan (the
    /// `--spec-draft`/`--spec-k` knobs): builds this server's private
    /// draft state — a model draft gets its own f32 reference engine
    /// sized to the policy's lane cap. Output bytes are unchanged by
    /// construction; see [`crate::runtime::spec`].
    pub fn with_spec(mut self, plan: &SpecPlan) -> Result<Self> {
        let state = SpecState::build(plan, self.policy.max_active())
            .context("enabling speculative decoding")?;
        self.spec = Some(RefCell::new(state));
        Ok(self)
    }

    /// Serve a batch of requests (all arriving at once) to completion,
    /// returning responses in completion order.
    pub fn serve(&self, requests: Vec<Request>) -> Result<Vec<Response>> {
        let offsets = vec![0.0; requests.len()];
        self.serve_arrivals(requests, &offsets)
    }

    /// Serve requests arriving over time: request `i` becomes visible to
    /// the scheduler `offsets[i]` seconds after the call (0 = at once).
    /// With all offsets zero this is exactly [`Server::serve`] and the
    /// schedule is wall-clock independent; staggered offsets are the
    /// open-loop arrival benches' surface. Per-request tokens are
    /// arrival-independent either way (sessions are isolated).
    pub fn serve_arrivals(
        &self,
        requests: Vec<Request>,
        offsets: &[f64],
    ) -> Result<Vec<Response>> {
        ensure!(
            !matches!(self.policy, Policy::Sharded { .. }),
            "Policy::Sharded partitions a ShardedEngine across worker threads — \
             drive it through serving::serve_sharded, not a single-engine Server"
        );
        validate_arrivals(&requests, offsets)?;
        // A reused server restarts session seq numbering — stale draft
        // sessions from an earlier run must not alias the new ones.
        if let Some(spec) = &self.spec {
            spec.borrow_mut().reset();
        }
        let mut future: VecDeque<(Request, f64)> = {
            let mut v: Vec<(Request, f64)> =
                requests.into_iter().zip(offsets.iter().copied()).collect();
            // Stable by arrival time, so same-time requests keep list order.
            v.sort_by(|a, b| a.1.partial_cmp(&b.1).expect("finite offsets"));
            v.into_iter().collect()
        };
        let mut active: Vec<Active> = Vec::new();
        let mut done: Vec<Response> = Vec::new();
        let result = self.run_loop(&mut future, &mut active, &mut done);
        // Never leak arena blocks, even on an admission error: retire
        // whatever was still active so the engine stays usable.
        for a in active.drain(..) {
            let _ = self.engine.free_session(a.handle);
            if let Some(spec) = &self.spec {
                spec.borrow_mut().forget(a.seq);
            }
        }
        result.map(|()| done)
    }

    /// Whether the session lacks the block backing its NEXT position
    /// (backend-aware: PJRT sessions report no arena pressure).
    fn needs_block(&self, a: &Active) -> Result<bool> {
        self.engine.session_needs_block(a.handle, a.pos as usize)
    }

    /// How many active sessions lack the block for their NEXT position.
    /// The two consumers gate differently on purpose: admission
    /// requires strictly MORE free blocks than this (headroom for the
    /// newcomer), the preemption loop exactly `free >= needed` (enough
    /// to tick) — an intentional pair, not drift.
    fn pressure(&self, active: &[Active]) -> Result<usize> {
        let mut needed = 0usize;
        for a in active {
            if self.needs_block(a)? {
                needed += 1;
            }
        }
        Ok(needed)
    }

    /// Blocks this serving loop could EVER obtain: the free list plus
    /// blocks held only by its own sessions and/or reclaimable prefix
    /// pins. Shared blocks are counted once (summing per-session table
    /// lengths would double-count a shared prefix); blocks held outside
    /// the loop — a live decoder on the same engine — are excluded, as
    /// they are never coming back.
    fn obtainable(&self, active: &[Active]) -> usize {
        let handles: Vec<CacheHandle> = active.iter().map(|a| a.handle).collect();
        self.engine.obtainable_blocks(&handles)
    }

    fn run_loop(
        &self,
        future: &mut VecDeque<(Request, f64)>,
        active: &mut Vec<Active>,
        done: &mut Vec<Response>,
    ) -> Result<()> {
        let t0 = Instant::now();
        let mut ready: VecDeque<Pending> = VecDeque::new();
        let mut next_seq = 0u64;

        while !future.is_empty() || !ready.is_empty() || !active.is_empty() {
            // ---- arrivals: surface requests whose offset has passed. ----
            // The arrival timestamp is the NOMINAL instant `t0 + offset`,
            // not the surfacing time — a request that arrives mid-tick
            // must be charged the queueing it actually experienced while
            // the tick ran (avoiding coordinated omission in the
            // queue/TTFT/service latency stats).
            let now_s = t0.elapsed().as_secs_f64();
            while future.front().is_some_and(|&(_, off)| off <= now_s) {
                let (req, off) = future.pop_front().expect("front checked");
                ready.push_back(Pending::new(req, t0 + Duration::from_secs_f64(off)));
            }

            self.admit(&mut ready, active, done, &mut next_seq)?;

            if active.is_empty() {
                // Nothing runnable. With this server's sessions all
                // retired, a request the admission loop still could not
                // place means its blocks are held OUTSIDE this serving
                // loop (e.g. a live decoder on the same engine) — error
                // out rather than busy-spin waiting on blocks nobody
                // here will free.
                let total_blocks = self.engine.arena_status().total_blocks;
                ensure!(
                    ready.is_empty(),
                    "request {} cannot be admitted: {} of {} arena blocks are held \
                     outside this serving loop",
                    ready.front().expect("non-empty").req.id,
                    total_blocks - self.engine.arena_status().free_blocks,
                    total_blocks
                );
                // Everything left is a future arrival. Nothing can
                // change state before it (single-threaded loop, empty
                // active set), so sleep the whole gap in one go.
                if let Some(&(_, off)) = future.front() {
                    let wait = off - t0.elapsed().as_secs_f64();
                    if wait > 0.0 {
                        std::thread::sleep(Duration::from_secs_f64(wait));
                    }
                }
                continue;
            }

            self.engine
                .obs()
                .gauge(Gauge::QueueDepth, ready.len() as u64);
            self.relieve_pressure(&mut ready, active)?;
            self.tick(active, done)?;
        }
        Ok(())
    }

    /// Admission stage: top the active set up to the lane cap, subject
    /// to arena capacity. Oversized requests (context window or arena)
    /// are rejected here, not mid-decode; zero-work requests complete
    /// immediately without occupying a lane or a block. Shared verbatim
    /// by [`Server::run_loop`] and the sharded worker loop — a stolen
    /// request enters here exactly like a placed one, so where a request
    /// runs can never change what it decodes.
    fn admit(
        &self,
        ready: &mut VecDeque<Pending>,
        active: &mut Vec<Active>,
        done: &mut Vec<Response>,
        next_seq: &mut u64,
    ) -> Result<()> {
        let max_active = self.policy.max_active();
        let max_ctx = self.engine.max_ctx();
        let total_blocks = self.engine.arena_status().total_blocks;
        while active.len() < max_active {
            let Some(front) = ready.front() else { break };
            let total = front.req.total_tokens();
            ensure!(
                total <= max_ctx,
                "request {} needs {} tokens > max_ctx {max_ctx}",
                front.req.id,
                total
            );
            if total == 0 {
                let p = ready.pop_front().expect("front checked");
                done.push(p.finish_empty());
                continue;
            }
            let need = self.engine.blocks_for_positions(total);
            // Fixed-wave sessions hold their worst-case reservation,
            // so the per-session next-block scan always reports 0 —
            // only the continuous gates read it. Skip the O(active)
            // walk on the reserving policies' admission path.
            let needed_now = if self.policy.reserves_worst_case() {
                0
            } else {
                self.pressure(active)?
            };
            // Full index blocks this request would adopt SHARED —
            // they consume no free blocks, so the reservation's
            // free-block need shrinks by them. Peeking also
            // LRU-touches the matched chain, so the reclaim below
            // evicts everything else first instead of the very
            // chain the request is about to hit. 0 with the cache
            // off.
            let peeked = self.engine.prefix_peek_blocks(&front.req.prompt);
            // Under block shortage, reclaim prefix-index pins
            // (LRU): cached prefixes are pure opportunity, running
            // sessions and admissions are work. No-op without the
            // prefix cache.
            let want = if self.policy.reserves_worst_case() {
                need.saturating_sub(peeked)
            } else {
                needed_now + 1
            };
            if self.engine.arena_status().free_blocks < want {
                self.engine.prefix_reclaim(want)?;
            }
            let free = self.engine.arena_status().free_blocks;
            // Blocks this serving loop can EVER obtain for the
            // request: the free list plus blocks held only by its
            // own sessions and reclaimable prefix pins (shared
            // blocks counted once). Blocks held outside the loop (a
            // live decoder on the same engine) are never coming
            // back, so a request needing them must be rejected up
            // front — not aborted mid-decode with a misleading
            // pressure error.
            let obtainable = self.obtainable(active);
            ensure!(
                need <= obtainable,
                "request {} needs {need} cache blocks but only {obtainable} of \
                 {total_blocks} are obtainable by this serving loop ({} held \
                 outside it)",
                front.req.id,
                total_blocks - obtainable
            );
            let admit = if self.policy.reserves_worst_case() {
                // Fixed-wave: everything BEYOND the shared prefix
                // blocks must fit as a worst-case reservation, so
                // an admitted session can never stall (shared
                // blocks are already materialized; the partial
                // tail's copy-on-write block is part of the
                // non-peeked remainder). A post-adoption re-check
                // below keeps this exact even if the match changes
                // between peek and adoption.
                free >= need.saturating_sub(peeked)
            } else {
                // Continuous: claim on demand, but leave headroom
                // for every running session's next block plus one
                // for the newcomer, so admission itself does not
                // force an immediate preemption.
                free > needed_now
            };
            if !admit {
                break;
            }
            let mut p = ready.pop_front().expect("front checked");
            let handle = self.engine.new_session()?;
            // Consult the prefix index BEFORE reserving/claiming:
            // matched positions arrive as shared (copy-on-write)
            // blocks and their prefill decode is skipped outright —
            // the cache state is bitwise what cold prefill would
            // produce, so tokens cannot change. Returns 0 with the
            // cache off or on backends without block-table reads.
            let cached_now = match self.engine.prefix_adopt(handle, &p.req.prompt) {
                Ok(c) => c,
                Err(e) => {
                    // Never leak the half-admitted session's blocks.
                    let _ = self.engine.free_session(handle);
                    return Err(e);
                }
            };
            if self.policy.reserves_worst_case() {
                // Exact no-stall re-check: the blocks NOT already in
                // the session's table must come from the free list.
                // If the actual match came up shorter than the peek
                // (only possible if the reclaim above was forced
                // through the touched chain), defer the admission
                // rather than letting the reservation hard-error —
                // active sessions will free blocks as they finish.
                let held = self.engine.session_blocks(handle)?;
                let short = self.engine.arena_status().free_blocks
                    < need.saturating_sub(held);
                if short && !active.is_empty() {
                    // Roll back the adoption's hit/saved counters —
                    // the retry will adopt and count again, and the
                    // engine stats must keep matching the sum of
                    // response-level cached_tokens.
                    self.engine.prefix_unrecord(cached_now);
                    self.engine.free_session(handle)?;
                    ready.push_front(p);
                    break;
                }
                // With no active session to wait on, fall through:
                // reserve_session's out-of-blocks error carries the
                // accurate diagnosis.
                if let Err(e) = self.engine.reserve_session(handle, total) {
                    let _ = self.engine.free_session(handle);
                    return Err(e);
                }
            }
            let first_admission = p.first_admitted.is_none();
            if first_admission {
                p.first_admitted = Some(Instant::now());
            }
            let prefill_done = cached_now >= p.req.prompt.len();
            let obs = self.engine.obs();
            if obs.enabled() {
                let rid = p.req.id;
                obs.event(EventKind::Admit, rid, u64::from(first_admission));
                if self.engine.prefix_enabled() {
                    if cached_now > 0 {
                        obs.event(EventKind::PrefixHit, rid, cached_now as u64);
                    } else {
                        obs.event(EventKind::PrefixMiss, rid, 0);
                    }
                }
                // The request-lifetime spans: prefill opens at every
                // (re-)admission; a fully adopted prompt skips straight
                // to decode.
                obs.span_begin(SpanKind::Prefill, rid);
                if prefill_done {
                    obs.span_end(SpanKind::Prefill, rid);
                    obs.span_begin(SpanKind::Decode, rid);
                }
            }
            active.push(Active {
                handle,
                seq: *next_seq,
                pos: cached_now as i32,
                tokens: p.req.prompt[..cached_now].to_vec(),
                last_logits: Vec::new(),
                fed: cached_now,
                arrived: p.arrived,
                first_admitted: p.first_admitted.expect("just set"),
                first_token_at: p.first_token_at,
                evictions: p.evictions,
                cached: p.cached + cached_now,
                indexed: false,
                prefill_done,
                req: p.req,
            });
            *next_seq += 1;
        }
        Ok(())
    }

    /// Pressure stage (on-demand policies only): make sure every active
    /// session's next position is backable, preempting the youngest
    /// until it is. Preemption frees the victim's blocks and requeues
    /// its request at the FRONT of the ready queue; the re-prefill is
    /// deterministic, so its tokens are unchanged. The oldest session is
    /// never evicted (victims are max-seq, and the single-session case
    /// always fits by the admission capacity check), so progress is
    /// guaranteed. Returns the number of sessions preempted (the sharded
    /// stats report surfaces the sum per shard). No-op on the
    /// worst-case-reserving policies.
    fn relieve_pressure(
        &self,
        ready: &mut VecDeque<Pending>,
        active: &mut Vec<Active>,
    ) -> Result<usize> {
        if self.policy.reserves_worst_case() {
            return Ok(0);
        }
        let total_blocks = self.engine.arena_status().total_blocks;
        let mut preempted = 0usize;
        loop {
            let needed = self.pressure(active)?;
            if self.engine.arena_status().free_blocks >= needed {
                break;
            }
            // Reclaim prefix-index pins before touching running
            // sessions: evicting a cached prefix costs future
            // hits, preempting a session costs a re-prefill.
            self.engine.prefix_reclaim(needed)?;
            let free = self.engine.arena_status().free_blocks;
            if free >= needed {
                break;
            }
            // A lone session always fits by the admission
            // obtainable check — unless blocks are held outside
            // this loop, which no amount of preemption can fix.
            ensure!(
                active.len() > 1,
                "request {} cannot claim its next cache block: {} of \
                 {total_blocks} arena blocks are held outside this serving \
                 loop",
                active[0].req.id,
                total_blocks.saturating_sub(self.obtainable(active))
            );
            let victim = active
                .iter()
                .enumerate()
                .max_by_key(|(_, a)| a.seq)
                .map(|(i, _)| i)
                .expect("active non-empty");
            let a = active.remove(victim);
            // Freeing releases only the victim's EXCLUSIVE
            // blocks — blocks shared with the prefix index or
            // another session keep their remaining references
            // (the refcount invariant tests/kvcache_properties
            // pins), so no still-referenced block can reach the
            // free list here.
            self.engine.free_session(a.handle)?;
            // The draft mirror dies with its target; re-admission
            // rebuilds it by catch-up feeding the re-prefilled tokens.
            if let Some(spec) = &self.spec {
                spec.borrow_mut().forget(a.seq);
            }
            let obs = self.engine.obs();
            if obs.enabled() {
                obs.event(EventKind::Preempt, a.req.id, a.pos as u64);
                // Close whichever lifetime span this admission had
                // open; re-admission reopens prefill from scratch.
                let span = if a.prefill_done {
                    SpanKind::Decode
                } else {
                    SpanKind::Prefill
                };
                obs.span_end(span, a.req.id);
            }
            ready.push_front(a.into_pending());
            preempted += 1;
        }
        Ok(preempted)
    }

    /// One scheduler tick: every active session advances exactly one
    /// token (prefill or generate, mixed freely), completed prefills are
    /// recorded into the prefix index, and finished sessions are swept
    /// out (completion order), freeing their blocks for the next
    /// admission round.
    fn tick(&self, active: &mut Vec<Active>, done: &mut Vec<Response>) -> Result<()> {
        let obs = self.engine.obs();
        let batch = active.len();
        obs.event(EventKind::TickStart, batch as u64, 0);
        // Clock reads only with tracing on — a disabled Obs keeps the
        // tick at exactly one relaxed load per instrumentation site.
        let t_start = if obs.enabled() {
            Some(Instant::now())
        } else {
            None
        };
        self.ticks.set(self.ticks.get() + 1);
        if self.validate_every > 0 && self.ticks.get() % self.validate_every as u64 == 0 {
            let n = self.ticks.get();
            let shard = self.engine.obs().shard();
            self.engine.debug_validate().with_context(|| {
                format!(
                    "--validate-every: arena invariant check failed at shard {shard} \
                     tick {n}"
                )
            })?;
            obs.count(Counter::ValidationsRun, 1);
        }
        let fed = if self.prefill_chunk == 0 && self.spec.is_none() {
            // Classic single-position tick, byte-for-byte the pre-lane
            // scheduler: every active session advances exactly one token.
            match self.policy {
                Policy::Batched { .. } | Policy::Continuous { .. } | Policy::Sharded { .. } => {
                    let tokens: Vec<i32> = active.iter().map(Active::next_token).collect();
                    let positions: Vec<i32> = active.iter().map(|a| a.pos).collect();
                    let handles: Vec<CacheHandle> =
                        active.iter().map(|a| a.handle).collect();
                    let outs = self.engine.decode_batch(&handles, &tokens, &positions)?;
                    for ((a, logits), &t) in active.iter_mut().zip(outs).zip(&tokens) {
                        a.absorb(t, logits);
                    }
                }
                Policy::Fifo | Policy::RoundRobin { .. } => {
                    for a in active.iter_mut() {
                        let t = a.next_token();
                        let logits = self.engine.decode_step(a.handle, t, a.pos)?;
                        a.absorb(t, logits);
                    }
                }
            }
            batch as u64
        } else {
            self.tick_lanes(active)?
        };

        // Every active session fed at least one token this tick (the
        // lane scheduler may feed several), and the prefill -> decode
        // transition is observable right after.
        obs.count(Counter::TokensDecoded, fed);
        for a in active.iter_mut() {
            if !a.prefill_done && a.fed >= a.req.prompt.len() {
                a.prefill_done = true;
                obs.span_end(SpanKind::Prefill, a.req.id);
                obs.span_begin(SpanKind::Decode, a.req.id);
            }
        }

        // ---- prefix index: record each completed prefill (once ----
        // per admission, before the sweep can retire it) so later
        // requests with the same system prompt share these blocks.
        // No-op with the cache off.
        if self.engine.prefix_enabled() {
            for a in active.iter_mut() {
                if !a.indexed && a.fed >= a.req.prompt.len() {
                    a.indexed = true;
                    self.engine.prefix_insert(a.handle, &a.req.prompt)?;
                }
            }
        }

        // ---- sweep finished sessions (completion order), freeing ----
        // their blocks for the next admission round.
        let mut i = 0;
        while i < active.len() {
            if active[i].done() {
                let a = active.swap_remove(i);
                self.engine.free_session(a.handle)?;
                if let Some(spec) = &self.spec {
                    spec.borrow_mut().forget(a.seq);
                }
                if obs.enabled() {
                    obs.event(EventKind::Retire, a.req.id, a.tokens.len() as u64);
                    obs.span_end(SpanKind::Decode, a.req.id);
                }
                done.push(a.finish());
            } else {
                i += 1;
            }
        }

        if obs.enabled() {
            let st = self.engine.arena_status();
            obs.gauge(Gauge::ArenaBlocksFree, st.free_blocks as u64);
            obs.gauge(Gauge::ArenaBlocksUsed, st.used_blocks as u64);
            obs.gauge(Gauge::ArenaBytesUsed, st.used_bytes as u64);
            obs.gauge(Gauge::ActiveSessions, active.len() as u64);
            obs.gauge(Gauge::PrefixEntries, self.engine.prefix_entries() as u64);
            obs.observe(Hist::BatchSize, batch as u64);
            // Copy-on-write copies since the last tick (adoption tail
            // copies in admit plus decode-time shared-block writes):
            // the arena counts them where they happen, the tick
            // attributes the delta to its timeline.
            let cow = self.engine.cow_copies();
            let delta = cow - self.last_cow.get();
            self.last_cow.set(cow);
            if delta > 0 {
                obs.event(EventKind::Cow, delta, 0);
                obs.count(Counter::CowCopies, delta);
            }
            if let Some(t) = t_start {
                obs.observe(Hist::TickMicros, t.elapsed().as_micros() as u64);
            }
            obs.event(EventKind::TickEnd, batch as u64, 0);
        }
        Ok(())
    }

    /// The two-lane tick: prompt ingestion and token generation are
    /// scheduled separately, with per-lane token accounting
    /// ([`Counter::LanePrefillTokens`] / [`Counter::LaneDecodeTokens`]).
    ///
    /// * PREFILL lane — every session still ingesting its prompt
    ///   advances up to `prefill_chunk` positions through ONE
    ///   `decode_span` traversal, so a long prompt reaches its first
    ///   token in `len / chunk` ticks instead of `len` without adding
    ///   per-tick weight traversals for everyone else.
    /// * DECODE lane — every session generating at tick start advances
    ///   one token (or up to `k` with speculative decoding on). A
    ///   session that finishes its prefill above starts generating next
    ///   tick, exactly like the classic single-position path.
    ///
    /// Lane membership and block reservations are fixed at tick start:
    /// `relieve_pressure` guaranteed one free block per session whose
    /// next position is unbacked, and every span here is capped so its
    /// EXTRA positions never eat a block reserved for another session's
    /// guaranteed advance — the floor of one position per session is
    /// precisely the classic tick's claim. Scheduling only, so served
    /// tokens are bitwise the classic path's (`tests/chunked_prefill.rs`,
    /// `tests/spec_equivalence.rs`).
    fn tick_lanes(&self, active: &mut Vec<Active>) -> Result<u64> {
        let obs = self.engine.obs();
        let reserving = self.policy.reserves_worst_case() || !self.engine.arena_backed();
        let needs: Vec<bool> = if reserving {
            vec![false; active.len()]
        } else {
            active
                .iter()
                .map(|a| self.needs_block(a))
                .collect::<Result<_>>()?
        };
        let mut reserved: usize = needs.iter().filter(|&&n| n).count();
        let in_prefill: Vec<bool> = active
            .iter()
            .map(|a| a.fed < a.req.prompt.len())
            .collect();
        // One spare block held back per capped span: claiming a span
        // position inside a shared (prefix-adopted) boundary block
        // copy-on-writes it, costing a block the table-growth count
        // below does not see.
        let cow_spare = usize::from(self.engine.prefix_enabled());
        let mut fed = 0u64;

        // ---- prefill lane -------------------------------------------
        let chunk = self.prefill_chunk.max(1);
        for i in 0..active.len() {
            if !in_prefill[i] {
                continue;
            }
            reserved -= usize::from(needs[i]);
            let a = &mut active[i];
            let want = chunk.min(a.req.prompt.len() - a.fed);
            let span = if reserving {
                want
            } else {
                self.cap_span(a, want, reserved + cow_spare)?
            };
            let toks = a.req.prompt[a.fed..a.fed + span].to_vec();
            let outs = self.engine.decode_span(a.handle, &toks, a.pos)?;
            for (&t, logits) in toks.iter().zip(outs) {
                a.absorb(t, logits);
            }
            obs.count(Counter::LanePrefillTokens, span as u64);
            fed += span as u64;
        }

        // ---- decode lane --------------------------------------------
        if let Some(spec) = &self.spec {
            let mut spec = spec.borrow_mut();
            for i in 0..active.len() {
                if in_prefill[i] {
                    continue;
                }
                reserved -= usize::from(needs[i]);
                fed += self.spec_step(
                    &mut active[i],
                    &mut spec,
                    reserving,
                    reserved + cow_spare,
                )?;
            }
        } else {
            let lane: Vec<usize> = (0..active.len()).filter(|&i| !in_prefill[i]).collect();
            match self.policy {
                Policy::Batched { .. } | Policy::Continuous { .. } | Policy::Sharded { .. } => {
                    if !lane.is_empty() {
                        let tokens: Vec<i32> =
                            lane.iter().map(|&i| active[i].next_token()).collect();
                        let positions: Vec<i32> = lane.iter().map(|&i| active[i].pos).collect();
                        let handles: Vec<CacheHandle> =
                            lane.iter().map(|&i| active[i].handle).collect();
                        let outs = self.engine.decode_batch(&handles, &tokens, &positions)?;
                        for ((&i, logits), &t) in lane.iter().zip(outs).zip(&tokens) {
                            active[i].absorb(t, logits);
                        }
                    }
                }
                Policy::Fifo | Policy::RoundRobin { .. } => {
                    for &i in &lane {
                        let a = &mut active[i];
                        let t = a.next_token();
                        let logits = self.engine.decode_step(a.handle, t, a.pos)?;
                        a.absorb(t, logits);
                    }
                }
            }
            obs.count(Counter::LaneDecodeTokens, lane.len() as u64);
            fed += lane.len() as u64;
        }
        Ok(fed)
    }

    /// Longest span length `1..=want` whose cache-block growth fits the
    /// CURRENT free list while leaving `hold_back` blocks untouched
    /// (other sessions' reserved advances plus the copy-on-write
    /// spare). Floor 1: a single position is exactly the claim
    /// `relieve_pressure` guaranteed this session.
    fn cap_span(&self, a: &Active, want: usize, hold_back: usize) -> Result<usize> {
        if want <= 1 {
            return Ok(want.max(1));
        }
        let held = self.engine.session_blocks(a.handle)?;
        let budget = self
            .engine
            .arena_status()
            .free_blocks
            .saturating_sub(hold_back);
        let mut n = want;
        while n > 1 {
            let needed = self
                .engine
                .blocks_for_positions(a.fed + n)
                .saturating_sub(held);
            if needed <= budget {
                break;
            }
            n -= 1;
        }
        Ok(n)
    }

    /// One speculative advance for a generating session: draft proposes,
    /// the target verifies the whole span, matching proposals are
    /// absorbed and rejected cache rows are rolled back. Returns tokens
    /// fed (`1..=k`); output bytes equal the non-speculative path by
    /// construction — `f0` IS the classic next token, and proposal
    /// `d_i` is only kept when it equals the target's own argmax of the
    /// span logits, which `decode_span` guarantees bitwise-equal to the
    /// sequential logits.
    fn spec_step(
        &self,
        a: &mut Active,
        spec: &mut SpecState,
        reserving: bool,
        hold_back: usize,
    ) -> Result<u64> {
        let obs = self.engine.obs();
        let want = a.req.total_tokens() - a.fed;
        let mut k = spec.k().min(want);
        if !reserving {
            k = self.cap_span(a, k, hold_back)?;
        }
        let f0 = greedy_argmax(&a.last_logits);
        let proposals = if k > 1 {
            spec.propose(a.seq, a.req.id, &a.tokens, f0, k - 1)?
        } else {
            Vec::new()
        };
        obs.count(Counter::SpecProposed, proposals.len() as u64);
        let mut span = Vec::with_capacity(1 + proposals.len());
        span.push(f0);
        span.extend_from_slice(&proposals);

        let accepted = if span.len() > 1
            && self.engine.arena_mode() == ArenaLayout::F32
            && self.engine.arena_backed()
        {
            // Batched verify: ONE weight traversal for the whole span,
            // then roll the rejected tail's cache rows back through the
            // block table. F32-arena-only — int8 writes requantize
            // earlier group rows in place, which truncation cannot
            // recover.
            obs.span_begin(SpanKind::SpecVerify, a.req.id);
            let outs = self.engine.decode_span(a.handle, &span, a.pos)?;
            obs.span_end(SpanKind::SpecVerify, a.req.id);
            let mut m = 0;
            while m + 1 < span.len() && span[m + 1] == greedy_argmax(&outs[m]) {
                m += 1;
            }
            for (&t, logits) in span.iter().take(m + 1).zip(outs) {
                a.absorb(t, logits);
            }
            if m + 1 < span.len() {
                self.engine.truncate_session(a.handle, a.fed)?;
            }
            m + 1
        } else {
            // Sequential verify-then-commit (int8 arenas, private-cache
            // backends): feed a token only after the previous logits
            // confirmed it, so nothing unverified ever lands in the
            // cache and there is nothing to roll back.
            let mut n = 0;
            loop {
                let t = span[n];
                let logits = self.engine.decode_step(a.handle, t, a.pos)?;
                let more = n + 1 < span.len() && span[n + 1] == greedy_argmax(&logits);
                a.absorb(t, logits);
                n += 1;
                if !more {
                    break;
                }
            }
            n
        };
        obs.count(Counter::SpecAccepted, (accepted - 1) as u64);
        obs.count(Counter::LaneDecodeTokens, accepted as u64);
        spec.commit(a.seq, a.tokens.len())?;
        Ok(accepted as u64)
    }
}

/// Offset-list validation shared by [`Server::serve_arrivals`] and the
/// sharded front end: one offset per request, each finite and >= 0.
fn validate_arrivals(requests: &[Request], offsets: &[f64]) -> Result<()> {
    ensure!(
        requests.len() == offsets.len(),
        "serve_arrivals arity mismatch: {} requests, {} offsets",
        requests.len(),
        offsets.len()
    );
    for (r, &o) in requests.iter().zip(offsets) {
        ensure!(
            o.is_finite() && o >= 0.0,
            "request {}: arrival offset {o} must be finite and >= 0",
            r.id
        );
    }
    Ok(())
}

/// Replicated threaded front end, builder-style: shard the request list
/// across `workers` threads, each driving its **own engine replica**
/// built by `make_engine` (engine backends are not `Sync` — the pjrt
/// feature's PJRT handles in particular — so replication, one engine
/// per worker, is the sound multi-worker topology for an arbitrary
/// backend; it also mirrors a real deployment where each accelerator
/// instance holds its own programmed crossbars). Each worker runs the
/// configured scheduling policy over its shard of the request list;
/// responses come back sorted by request id.
///
/// ```ignore
/// let out = ThreadedServe::new(|| Engine::load(artifacts()?))
///     .workers(4)
///     .policy(Policy::Continuous { max_active: 8 })
///     .run(requests)?;
/// ```
///
/// For partitioning ONE arena across worker-owned shards instead of
/// replicating the whole engine, see [`serve_sharded`].
pub struct ThreadedServe<F> {
    make_engine: F,
    workers: usize,
    policy: Policy,
}

impl<F> ThreadedServe<F>
where
    F: Fn() -> Result<Engine> + Sync,
{
    /// Front end over engine replicas built by `make_engine`, with the
    /// historical defaults: one worker, round-robin over 2 lanes.
    pub fn new(make_engine: F) -> Self {
        Self {
            make_engine,
            workers: 1,
            policy: Policy::RoundRobin { max_active: 2 },
        }
    }

    /// Number of worker threads (each builds its own engine replica).
    /// Clamped at run time to the request count; 0 means 1.
    pub fn workers(mut self, workers: usize) -> Self {
        self.workers = workers;
        self
    }

    /// Scheduling policy each worker runs over its shard of the request
    /// list. [`Policy::Sharded`] is rejected at run time — it partitions
    /// ONE engine's arena and is driven by [`serve_sharded`], not by
    /// replicas.
    pub fn policy(mut self, policy: Policy) -> Self {
        self.policy = policy;
        self
    }

    /// Run the request list to completion across the replicas.
    pub fn run(self, requests: Vec<Request>) -> Result<Vec<Response>> {
        ensure!(
            !matches!(self.policy, Policy::Sharded { .. }),
            "Policy::Sharded partitions one ShardedEngine — drive it through \
             serving::serve_sharded, not through engine replicas"
        );
        let workers = self.workers.clamp(1, requests.len().max(1));
        // Shard round-robin so load is balanced even with mixed lengths.
        let mut shards: Vec<Vec<Request>> = (0..workers).map(|_| Vec::new()).collect();
        for (i, r) in requests.into_iter().enumerate() {
            shards[i % workers].push(r);
        }
        let policy = self.policy;
        let results: Vec<Result<Vec<Response>>> = std::thread::scope(|scope| {
            let make_engine = &self.make_engine;
            let handles: Vec<_> = shards
                .into_iter()
                .map(|shard| {
                    scope.spawn(move || {
                        let engine = make_engine()?;
                        Server::new(&engine, policy).serve(shard)
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("worker panicked"))
                .collect()
        });
        let mut out = Vec::new();
        for r in results {
            out.extend(r?);
        }
        out.sort_by_key(|r| r.id);
        Ok(out)
    }
}

/// [`ThreadedServe`] with the historical round-robin policy.
pub fn serve_threaded_with<F>(
    make_engine: F,
    requests: Vec<Request>,
    workers: usize,
    max_active: usize,
) -> Result<Vec<Response>>
where
    F: Fn() -> Result<Engine> + Sync,
{
    ThreadedServe::new(make_engine)
        .workers(workers)
        .policy(Policy::RoundRobin { max_active })
        .run(requests)
}

/// [`ThreadedServe`] loading each replica from an artifact directory.
pub fn serve_threaded(
    artifacts_dir: &std::path::Path,
    requests: Vec<Request>,
    workers: usize,
    max_active: usize,
) -> Result<Vec<Response>> {
    serve_threaded_with(
        || Engine::load(crate::runtime::Artifacts::load(artifacts_dir)?),
        requests,
        workers,
        max_active,
    )
}

// ---------------------------------------------------------------------
// Sharded serving: N worker threads over ONE partitioned arena.
// ---------------------------------------------------------------------

/// The shared admission queues of a sharded run: one FIFO per shard,
/// holding that shard's not-yet-admitted `(request, offset)` entries in
/// arrival order. An entry is popped under its queue's mutex exactly
/// once — by its home worker, or by an idle worker stealing it — and
/// never returns to a shared queue (a preempted session requeues into
/// its worker's PRIVATE ready queue), so every request is served
/// exactly once. The mutexes guard only these `VecDeque`s: no cache
/// block, refcount, or engine state is ever behind a lock.
struct ShardQueues {
    queues: Vec<Mutex<VecDeque<(Request, f64)>>>,
}

impl ShardQueues {
    /// Partition offset-sorted `(request, offset)` pairs by the
    /// deterministic placement rule ([`shard_for`]`(id) % workers`),
    /// preserving order within each shard — so each queue is itself
    /// offset-sorted. Returns the queue set plus per-shard placement
    /// counts.
    fn place(sorted: Vec<(Request, f64)>, workers: usize) -> (Self, Vec<usize>) {
        let mut queues: Vec<VecDeque<(Request, f64)>> =
            (0..workers).map(|_| VecDeque::new()).collect();
        let mut placed = vec![0usize; workers];
        for (req, off) in sorted {
            let s = shard_for(req.id, workers);
            placed[s] += 1;
            queues[s].push_back((req, off));
        }
        let queues = queues.into_iter().map(Mutex::new).collect();
        (Self { queues }, placed)
    }

    /// Pop the front entry of shard `s`'s queue if it has ARRIVED
    /// (offset elapsed). Both home-queue draining and stealing go
    /// through this, so a steal respects arrival order and arrival time
    /// exactly like home admission does.
    fn pop_visible(&self, s: usize, now_s: f64) -> Option<(Request, f64)> {
        let mut q = self.queues[s].lock().expect("shard queue poisoned");
        if q.front().is_some_and(|&(_, off)| off <= now_s) {
            q.pop_front()
        } else {
            None
        }
    }

    /// Earliest pending arrival offset across ALL queues (`None` = every
    /// queue drained). The idle worker's sleep target: a future arrival
    /// may land on its own shard or need stealing, so nobody exits while
    /// any queue is non-empty.
    fn earliest(&self) -> Option<f64> {
        self.queues
            .iter()
            .filter_map(|q| {
                let q = q.lock().expect("shard queue poisoned");
                q.front().map(|&(_, off)| off)
            })
            .min_by(|a, b| a.partial_cmp(b).expect("finite offsets"))
    }
}

/// One sharded worker: continuous batching over its own [`EngineShard`]
/// — admission, decode, retirement, preemption, and prefix adoption are
/// the very same [`Server`] stages the single-thread policies run, just
/// instantiated over the shard's `dyn Backend + Send` box. Drains its
/// home queue first; when it would otherwise idle a lane, it steals the
/// front-most ARRIVED entry from the other shards (scanning `w+1, w+2,
/// …` wrapping — a deterministic victim order). A stolen request has by
/// construction not started (stealing moves whole queued requests
/// only), so it prefills from nothing on the thief's shard — its tokens
/// cannot differ from a home run.
fn shard_worker(
    shard: &EngineShard,
    w: usize,
    shared: &ShardQueues,
    t0: Instant,
    max_active: usize,
    validate_every: usize,
    prefill_chunk: usize,
    spec: Option<&SpecPlan>,
) -> Result<(Vec<Response>, ShardStats)> {
    let workers = shared.queues.len();
    let mut server = Server::new(shard, Policy::Continuous { max_active })
        .with_validate_every(validate_every)
        .with_prefill_chunk(prefill_chunk);
    if let Some(plan) = spec {
        // Each worker builds its own draft state (a draft session
        // mirrors a target session, and targets live per shard).
        server = server.with_spec(plan)?;
    }
    let server = server;
    let mut ready: VecDeque<Pending> = VecDeque::new();
    let mut active: Vec<Active> = Vec::new();
    let mut done: Vec<Response> = Vec::new();
    let mut next_seq = 0u64;
    let mut stats = ShardStats::new(w);

    let result = (|| -> Result<()> {
        loop {
            // ---- arrivals: drain every ARRIVED entry of the home ----
            // queue into the private ready queue, in arrival order.
            let now_s = t0.elapsed().as_secs_f64();
            while let Some((req, off)) = shared.pop_visible(w, now_s) {
                ready.push_back(Pending::new(req, t0 + Duration::from_secs_f64(off)));
            }

            // ---- steal: only when this worker would otherwise idle ----
            // a lane — no arrived home work and lanes free. One whole
            // request per round, from the first backlogged victim in
            // scan order; it prefills here, on this shard's blocks
            // (copy-on-write refcounts never cross a shard boundary).
            if ready.is_empty() && active.len() < max_active {
                for victim in (1..workers).map(|d| (w + d) % workers) {
                    if let Some((req, off)) = shared.pop_visible(victim, now_s) {
                        stats.stolen += 1;
                        shard.obs().event(EventKind::Steal, req.id, victim as u64);
                        ready.push_back(Pending::new(req, t0 + Duration::from_secs_f64(off)));
                        break;
                    }
                }
            }

            if ready.is_empty() && active.is_empty() {
                // Nothing runnable here. The run is over for this worker
                // only when EVERY shared queue is drained; otherwise
                // sleep until the earliest future arrival and rescan.
                match shared.earliest() {
                    None => break,
                    Some(off) => {
                        let wait = off - t0.elapsed().as_secs_f64();
                        if wait > 0.0 {
                            std::thread::sleep(Duration::from_secs_f64(wait));
                        }
                        continue;
                    }
                }
            }

            server.admit(&mut ready, &mut active, &mut done, &mut next_seq)?;

            if active.is_empty() {
                // With no session running, every shard block should be
                // free (modulo reclaimable prefix pins, which admission
                // reclaims) — a request that still cannot be placed
                // needs blocks held OUTSIDE this serving loop, e.g. a
                // live decoder driving the shard directly. Error out
                // rather than busy-spin. An empty ready queue here just
                // means the round's work was zero-token requests.
                let st = shard.arena_status();
                ensure!(
                    ready.is_empty(),
                    "request {} cannot be admitted on shard {w}: {} of {} arena \
                     blocks are held outside this serving loop",
                    ready.front().expect("non-empty").req.id,
                    st.total_blocks - st.free_blocks,
                    st.total_blocks
                );
                continue;
            }

            stats.peak_active = stats.peak_active.max(active.len());
            shard.obs().gauge(Gauge::QueueDepth, ready.len() as u64);
            stats.evictions += server.relieve_pressure(&mut ready, &mut active)?;
            server.tick(&mut active, &mut done)?;
        }
        Ok(())
    })();

    // Never leak shard blocks, even on an admission error: retire
    // whatever was still active so the engine stays usable. Entries
    // left in the shared queues stay stealable by healthy workers.
    if result.is_err() {
        for a in active.drain(..) {
            let _ = shard.free_session(a.handle);
        }
    }
    result?;
    stats.served = done.len();
    Ok((done, stats))
}

/// Serve a batch of requests (all arriving at once) across the shards
/// of a [`ShardedEngine`]: each worker thread owns one shard and runs
/// continuous batching over it with up to `max_active` lanes PER
/// WORKER. Placement is the deterministic [`shard_for`] hash; idle
/// workers steal whole queued requests from backlogged shards. The
/// responses are byte-identical to a single-worker run of the same
/// requests (`tests/shard_determinism.rs`), sorted by request id.
pub fn serve_sharded(
    engine: &mut ShardedEngine,
    requests: Vec<Request>,
    max_active: usize,
) -> Result<Vec<Response>> {
    let offsets = vec![0.0; requests.len()];
    serve_sharded_arrivals(engine, requests, &offsets, max_active)
}

/// [`serve_sharded`] with per-request arrival offsets (seconds after
/// the call; 0 = at once), the open-loop bench surface.
pub fn serve_sharded_arrivals(
    engine: &mut ShardedEngine,
    requests: Vec<Request>,
    offsets: &[f64],
    max_active: usize,
) -> Result<Vec<Response>> {
    serve_sharded_stats(engine, requests, offsets, max_active).map(|(out, _)| out)
}

/// [`serve_sharded_arrivals`] additionally returning the per-shard
/// counters (placement, steals, completions, preemptions, peak
/// occupancy) — one [`ShardStats`] per worker, in shard order.
pub fn serve_sharded_stats(
    engine: &mut ShardedEngine,
    requests: Vec<Request>,
    offsets: &[f64],
    max_active: usize,
) -> Result<(Vec<Response>, Vec<ShardStats>)> {
    serve_sharded_stats_opts(engine, requests, offsets, max_active, 0)
}

/// [`serve_sharded_stats`] with the debug knobs: `validate_every > 0`
/// runs every shard's full arena invariant check every N of its own
/// ticks (the `--validate-every` CLI flag), failing the serve with a
/// structured error naming the shard and tick.
pub fn serve_sharded_stats_opts(
    engine: &mut ShardedEngine,
    requests: Vec<Request>,
    offsets: &[f64],
    max_active: usize,
    validate_every: usize,
) -> Result<(Vec<Response>, Vec<ShardStats>)> {
    serve_sharded_stats_lanes(engine, requests, offsets, max_active, validate_every, 0, None)
}

/// [`serve_sharded_stats_opts`] with the lane-scheduler knobs:
/// `prefill_chunk > 0` ingests prompts through the chunked prefill lane
/// and `spec` turns on speculative decoding (every worker builds its
/// own draft state over the shared plan). Both are scheduling-only —
/// responses stay byte-identical to the classic sharded run.
pub fn serve_sharded_stats_lanes(
    engine: &mut ShardedEngine,
    requests: Vec<Request>,
    offsets: &[f64],
    max_active: usize,
    validate_every: usize,
    prefill_chunk: usize,
    spec: Option<&SpecPlan>,
) -> Result<(Vec<Response>, Vec<ShardStats>)> {
    validate_arrivals(&requests, offsets)?;
    ensure!(max_active >= 1, "sharded serving needs max_active >= 1");
    let workers = engine.workers();
    let sorted: Vec<(Request, f64)> = {
        let mut v: Vec<(Request, f64)> =
            requests.into_iter().zip(offsets.iter().copied()).collect();
        // Stable by arrival time, so same-time requests keep list order.
        v.sort_by(|a, b| a.1.partial_cmp(&b.1).expect("finite offsets"));
        v
    };
    let (shared, placed) = ShardQueues::place(sorted, workers);
    let t0 = Instant::now();
    // `&mut EngineShard` is `Send` (the shard owns its backend, arena
    // and prefix index outright), so each worker thread gets exclusive
    // access to exactly one shard — the only shared state is the queue
    // set above and the `Arc`'d weights inside the shards.
    let results: Vec<Result<(Vec<Response>, ShardStats)>> = std::thread::scope(|scope| {
        let shared = &shared;
        let handles: Vec<_> = engine
            .shards_mut()
            .iter_mut()
            .enumerate()
            .map(|(w, shard)| {
                scope.spawn(move || {
                    shard_worker(
                        &*shard,
                        w,
                        shared,
                        t0,
                        max_active,
                        validate_every,
                        prefill_chunk,
                        spec,
                    )
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("sharded worker panicked"))
            .collect()
    });
    let mut out = Vec::new();
    let mut stats = Vec::with_capacity(workers);
    for r in results {
        let (responses, st) = r?;
        out.extend(responses);
        stats.push(st);
    }
    for (st, &p) in stats.iter_mut().zip(&placed) {
        st.placed = p;
    }
    out.sort_by_key(|r| r.id);
    Ok((out, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{Artifacts, BackendKind};

    const SEED: u64 = 11;

    fn engine() -> Engine {
        Engine::load(Artifacts::synthetic(SEED).unwrap()).unwrap()
    }

    fn reqs(n: u64) -> Vec<Request> {
        (0..n)
            .map(|id| Request {
                id,
                prompt: vec![(id % 7) as i32 + 1, 2, 3],
                n_new: 4,
            })
            .collect()
    }

    #[test]
    fn fifo_serves_all_and_preserves_order() {
        let e = engine();
        let server = Server::new(&e, Policy::Fifo);
        let out = server.serve(reqs(3)).unwrap();
        assert_eq!(out.len(), 3);
        let ids: Vec<u64> = out.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2]);
        for r in &out {
            assert_eq!(r.tokens.len(), 3 + 4);
            assert_eq!(r.evictions, 0);
        }
    }

    #[test]
    fn round_robin_matches_fifo_outputs() {
        let e = engine();
        let fifo = Server::new(&e, Policy::Fifo).serve(reqs(3)).unwrap();
        let rr = Server::new(&e, Policy::RoundRobin { max_active: 3 })
            .serve(reqs(3))
            .unwrap();
        // Same generated tokens regardless of interleaving (isolation).
        for f in &fifo {
            let r = rr.iter().find(|r| r.id == f.id).unwrap();
            assert_eq!(f.tokens, r.tokens, "request {}", f.id);
        }
    }

    #[test]
    fn batched_and_continuous_match_fifo_outputs() {
        // Both decode_batch-per-tick schedulers must be token-for-token
        // identical to per-session decoding.
        let e = engine();
        let fifo = Server::new(&e, Policy::Fifo).serve(reqs(5)).unwrap();
        for policy in [
            Policy::Batched { batch: 3 },
            Policy::Continuous { max_active: 3 },
        ] {
            let out = Server::new(&e, policy).serve(reqs(5)).unwrap();
            assert_eq!(out.len(), 5, "{policy:?}");
            for f in &fifo {
                let b = out.iter().find(|b| b.id == f.id).unwrap();
                assert_eq!(f.tokens, b.tokens, "request {} under {policy:?}", f.id);
            }
        }
    }

    #[test]
    fn schedulers_handle_ragged_and_degenerate_requests() {
        // Mixed prompt lengths, empty prompts, and zero-work requests in
        // one batch: everything completes, empty-prompt generation
        // starts from token 0, zero-work requests return no tokens.
        let e = engine();
        let requests = vec![
            Request { id: 0, prompt: vec![1, 2, 3, 4, 5], n_new: 2 },
            Request { id: 1, prompt: vec![], n_new: 3 },
            Request { id: 2, prompt: vec![9], n_new: 0 },
            Request { id: 3, prompt: vec![], n_new: 0 },
        ];
        let out = Server::new(&e, Policy::Batched { batch: 4 })
            .serve(requests.clone())
            .unwrap();
        assert_eq!(out.len(), 4);
        let by_id = |id: u64| out.iter().find(|r| r.id == id).unwrap();
        assert_eq!(by_id(0).tokens.len(), 7);
        assert_eq!(by_id(1).tokens.len(), 3);
        assert_eq!(by_id(1).tokens[0], 0);
        assert_eq!(by_id(2).tokens, vec![9]);
        assert!(by_id(3).tokens.is_empty());
        // And identically under the other schedulers.
        for policy in [
            Policy::Fifo,
            Policy::RoundRobin { max_active: 2 },
            Policy::Continuous { max_active: 4 },
        ] {
            let seq = Server::new(&e, policy).serve(requests.clone()).unwrap();
            for r in &out {
                let s = seq.iter().find(|s| s.id == r.id).unwrap();
                assert_eq!(r.tokens, s.tokens, "request {} under {policy:?}", r.id);
            }
        }
    }

    #[test]
    fn continuous_under_pressure_preempts_and_still_matches() {
        // An arena too small for every session's worst case: the
        // continuous scheduler must preempt (youngest first), requeue,
        // re-prefill, and still produce exactly the isolated tokens.
        // 6 requests x 12 tokens = 3 blocks each (block_len 4) against a
        // 10-block arena with 6 lanes forces evictions.
        let tight = Engine::load_with_arena(
            Artifacts::synthetic(SEED).unwrap(),
            BackendKind::Reference,
            4,
            10,
        )
        .unwrap();
        let requests: Vec<Request> = (0..6u64)
            .map(|id| Request {
                id,
                prompt: vec![(id % 5) as i32 + 1, 7, 2, 4],
                n_new: 8,
            })
            .collect();
        let out = Server::new(&tight, Policy::Continuous { max_active: 6 })
            .serve(requests.clone())
            .unwrap();
        assert_eq!(out.len(), 6);
        let total_evictions: u32 = out.iter().map(|r| r.evictions).sum();
        assert!(
            total_evictions > 0,
            "10 blocks cannot hold 6 x 3-block sessions without preemption"
        );
        // All blocks returned after the run.
        let st = tight.arena_status();
        assert_eq!(st.free_blocks, st.total_blocks);
        // Tokens identical to the isolated run on a roomy engine.
        let fifo = Server::new(&engine(), Policy::Fifo).serve(requests).unwrap();
        for f in &fifo {
            let c = out.iter().find(|c| c.id == f.id).unwrap();
            assert_eq!(f.tokens, c.tokens, "request {}", f.id);
        }
    }

    #[test]
    fn chunked_prefill_matches_classic_outputs() {
        // Chunk sizes spanning "one position per tick" (classic
        // pacing), "mid prompt", and "whole prompt in one tick" —
        // scheduling only, so tokens must be bitwise the unchunked
        // run's under both lane-capable policy families.
        let e = engine();
        let requests: Vec<Request> = (0..4u64)
            .map(|id| Request {
                id,
                prompt: (0..9).map(|p| ((id + p) % 6) as i32 + 1).collect(),
                n_new: 5,
            })
            .collect();
        let classic = Server::new(&e, Policy::Continuous { max_active: 4 })
            .serve(requests.clone())
            .unwrap();
        for chunk in [1usize, 3, 64] {
            for policy in [
                Policy::Continuous { max_active: 4 },
                Policy::Batched { batch: 4 },
            ] {
                let out = Server::new(&e, policy)
                    .with_prefill_chunk(chunk)
                    .serve(requests.clone())
                    .unwrap();
                for c in &classic {
                    let r = out.iter().find(|r| r.id == c.id).unwrap();
                    assert_eq!(
                        c.tokens, r.tokens,
                        "request {} chunk {chunk} under {policy:?}",
                        c.id
                    );
                }
            }
        }
    }

    #[test]
    fn speculative_decoding_matches_classic_bitwise() {
        // Every draft source — perfect (self), heuristic (tiny), and
        // replayed (oracle) — must leave served tokens byte-identical:
        // the verify step only keeps proposals the target itself argmaxes.
        use std::collections::HashMap;
        let e = engine();
        let requests: Vec<Request> = (0..4u64)
            .map(|id| Request {
                id,
                prompt: vec![(id % 7) as i32 + 1, 2, 3],
                n_new: 7,
            })
            .collect();
        let classic = Server::new(&e, Policy::Continuous { max_active: 4 })
            .serve(requests.clone())
            .unwrap();
        let book: HashMap<u64, Vec<i32>> =
            classic.iter().map(|r| (r.id, r.tokens.clone())).collect();
        let plans = [
            SpecPlan::self_draft(e.artifacts(), 3).unwrap(),
            SpecPlan::tiny_draft(e.artifacts(), 4).unwrap(),
            SpecPlan::oracle(book, 4).unwrap(),
        ];
        for plan in &plans {
            let out = Server::new(&e, Policy::Continuous { max_active: 4 })
                .with_spec(plan)
                .unwrap()
                .serve(requests.clone())
                .unwrap();
            for c in &classic {
                let r = out.iter().find(|r| r.id == c.id).unwrap();
                assert_eq!(c.tokens, r.tokens, "request {}", c.id);
            }
        }
    }

    #[test]
    fn lanes_under_pressure_preempt_and_still_match() {
        // Chunked prefill + speculative decode against the same tight
        // arena as the preemption test above: spans must be capped so
        // one session's EXTRA positions never eat another session's
        // reserved block, and a preemption's rollback + draft forget
        // must leave tokens untouched.
        let requests: Vec<Request> = (0..6u64)
            .map(|id| Request {
                id,
                prompt: vec![(id % 5) as i32 + 1, 7, 2, 4],
                n_new: 8,
            })
            .collect();
        let fifo = Server::new(&engine(), Policy::Fifo)
            .serve(requests.clone())
            .unwrap();
        let tight = Engine::load_with_arena(
            Artifacts::synthetic(SEED).unwrap(),
            BackendKind::Reference,
            4,
            10,
        )
        .unwrap();
        let plan = SpecPlan::self_draft(tight.artifacts(), 3).unwrap();
        let out = Server::new(&tight, Policy::Continuous { max_active: 6 })
            .with_prefill_chunk(3)
            .with_spec(&plan)
            .unwrap()
            .serve(requests)
            .unwrap();
        assert!(
            out.iter().map(|r| r.evictions).sum::<u32>() > 0,
            "10 blocks cannot hold 6 x 3-block sessions without preemption"
        );
        let st = tight.arena_status();
        assert_eq!(st.free_blocks, st.total_blocks);
        for f in &fifo {
            let r = out.iter().find(|r| r.id == f.id).unwrap();
            assert_eq!(f.tokens, r.tokens, "request {}", f.id);
        }
    }

    #[test]
    fn fixed_wave_reservation_defers_admission_but_completes() {
        // 4 blocks, block_len 4, requests of 8 tokens = 2 blocks each:
        // the batched policy can hold at most 2 reservations at a time
        // but must still complete all 5 requests with correct tokens.
        let tight = Engine::load_with_arena(
            Artifacts::synthetic(SEED).unwrap(),
            BackendKind::Reference,
            4,
            4,
        )
        .unwrap();
        let requests: Vec<Request> = (0..5u64)
            .map(|id| Request {
                id,
                prompt: vec![(id % 3) as i32 + 1, 2],
                n_new: 6,
            })
            .collect();
        let out = Server::new(&tight, Policy::Batched { batch: 4 })
            .serve(requests.clone())
            .unwrap();
        assert_eq!(out.len(), 5);
        for r in &out {
            assert_eq!(r.tokens.len(), 8);
            assert_eq!(r.evictions, 0, "fixed-wave policies never preempt");
        }
        let st = tight.arena_status();
        assert_eq!(st.free_blocks, st.total_blocks);
    }

    #[test]
    fn oversized_requests_rejected_and_leak_free() {
        let e = engine();
        let max_ctx = e.max_ctx();
        // Context-window overflow.
        let out = Server::new(&e, Policy::Batched { batch: 2 }).serve(vec![Request {
            id: 0,
            prompt: vec![1; max_ctx],
            n_new: 1,
        }]);
        assert!(out.is_err());
        // Arena-capacity overflow (request larger than the whole pool).
        let tiny = Engine::load_with_arena(
            Artifacts::synthetic(SEED).unwrap(),
            BackendKind::Reference,
            4,
            2,
        )
        .unwrap();
        for policy in [Policy::Batched { batch: 2 }, Policy::Continuous { max_active: 2 }] {
            let out = Server::new(&tiny, policy).serve(vec![Request {
                id: 0,
                prompt: vec![1, 2, 3, 4, 5],
                n_new: 5,
            }]);
            assert!(out.is_err(), "{policy:?}");
            // The failed serve returned every block it touched.
            let st = tiny.arena_status();
            assert_eq!(st.free_blocks, st.total_blocks, "{policy:?}");
        }
    }

    #[test]
    fn blocks_held_outside_the_server_error_instead_of_spinning() {
        // A live decoder on the same engine owns every arena block: the
        // serving loop must surface that as an admission error, not
        // busy-wait for blocks nobody in the loop will free.
        let tight = Engine::load_with_arena(
            Artifacts::synthetic(SEED).unwrap(),
            BackendKind::Reference,
            4,
            2,
        )
        .unwrap();
        let mut outside = crate::runtime::TinyDecoder::new(&tight).unwrap();
        outside.generate(&[1, 2, 3, 4, 5], 3).unwrap(); // 8 tokens = both blocks
        assert_eq!(tight.arena_status().free_blocks, 0);
        for policy in [Policy::Batched { batch: 2 }, Policy::Continuous { max_active: 2 }] {
            let out = Server::new(&tight, policy).serve(vec![Request {
                id: 0,
                prompt: vec![1],
                n_new: 3,
            }]);
            assert!(out.is_err(), "{policy:?} must error, not spin");
        }
        // Dropping the outside decoder frees the blocks; serving works.
        drop(outside);
        let out = Server::new(&tight, Policy::Continuous { max_active: 2 })
            .serve(vec![Request { id: 0, prompt: vec![1], n_new: 3 }])
            .unwrap();
        assert_eq!(out[0].tokens.len(), 4);
    }

    #[test]
    fn staggered_arrivals_complete_with_identical_tokens() {
        let e = engine();
        let requests = reqs(4);
        let instant = Server::new(&e, Policy::Continuous { max_active: 2 })
            .serve(requests.clone())
            .unwrap();
        let staggered = Server::new(&e, Policy::Continuous { max_active: 2 })
            .serve_arrivals(requests, &[0.0, 0.002, 0.004, 0.006])
            .unwrap();
        assert_eq!(staggered.len(), 4);
        for s in &staggered {
            let i = instant.iter().find(|i| i.id == s.id).unwrap();
            assert_eq!(i.tokens, s.tokens, "request {}", s.id);
        }
        // Bad offsets are rejected.
        assert!(Server::new(&e, Policy::Fifo)
            .serve_arrivals(reqs(1), &[-1.0])
            .is_err());
        assert!(Server::new(&e, Policy::Fifo)
            .serve_arrivals(reqs(2), &[0.0])
            .is_err());
    }

    /// Requests with a heavily shared system prompt (few distinct
    /// prefixes, many requests) — the prefix cache's target workload.
    fn shared_prefix_reqs(n: u64) -> Vec<Request> {
        (0..n)
            .map(|id| {
                let mut prompt = vec![11, 7, 3, 9, 2, 8, 4, 6, 1, 12, 10, 5];
                prompt[0] = (id % 2) as i32 + 11; // 2 distinct system prompts
                prompt.push(id as i32 + 1); // per-request suffix
                Request { id, prompt, n_new: 4 }
            })
            .collect()
    }

    #[test]
    fn prefix_cache_changes_no_token_and_saves_prefill() {
        let e = engine();
        let requests = shared_prefix_reqs(8);
        let cold = Server::new(&e, Policy::Fifo).serve(requests.clone()).unwrap();
        for policy in [
            Policy::Fifo,
            Policy::Batched { batch: 3 },
            Policy::Continuous { max_active: 3 },
        ] {
            // Block length 4 so the 12-token shared prefix spans whole
            // blocks (the default 16-position block would swallow it).
            let warm_engine = Engine::load_with_arena(
                Artifacts::synthetic(SEED).unwrap(),
                BackendKind::Reference,
                4,
                64,
            )
            .unwrap();
            assert!(warm_engine.enable_prefix_cache(0));
            let out = Server::new(&warm_engine, policy)
                .serve(requests.clone())
                .unwrap();
            for c in &cold {
                let w = out.iter().find(|w| w.id == c.id).unwrap();
                assert_eq!(c.tokens, w.tokens, "request {} under {policy:?}", c.id);
            }
            // Some request after the first must have reused the shared
            // prefix (FIFO serializes, so later requests always hit;
            // the wave policies hit across waves or not at all — but
            // saved_tokens is response-visible either way).
            let saved: usize = out.iter().map(|r| r.cached_tokens).sum();
            if policy == Policy::Fifo {
                assert!(saved > 0, "FIFO must hit on the shared prefix");
            }
            let stats = warm_engine.prefix_stats().unwrap();
            assert_eq!(
                saved, stats.saved_tokens,
                "response accounting must match engine counters ({policy:?})"
            );
            // All arena invariants hold; only index pins remain.
            warm_engine.debug_validate().unwrap();
            let st = warm_engine.arena_status();
            assert_eq!(st.free_blocks + st.pinned_blocks, st.total_blocks);
        }
    }

    #[test]
    fn tight_arena_reclaim_spares_the_chain_about_to_be_adopted() {
        // Fixed-wave admission in an arena sized near one worst-case
        // request: the free-block want is computed AFTER peeking the
        // index (shared blocks need no free blocks), so admitting a
        // request whose prefix is cached must NOT reclaim — and
        // certainly not evict — the very chain it is about to adopt.
        let sys: Vec<i32> = vec![9, 3, 7, 1, 5, 2, 8, 4, 6, 11, 13, 10]; // 12 = 3 blocks
        let reqs: Vec<Request> = (0..2u64)
            .map(|id| {
                let mut prompt = sys.clone();
                prompt.push(90 + id as i32);
                Request { id, prompt, n_new: 3 } // 16 tokens = 4 blocks
            })
            .collect();
        // 5 blocks: request 0 runs cold (4 blocks), retires leaving its
        // 3-block chain pinned + 2 free; request 1 needs only 1 fresh
        // block thanks to the shared chain.
        let tight = Engine::load_with_arena(
            Artifacts::synthetic(SEED).unwrap(),
            BackendKind::Reference,
            4,
            5,
        )
        .unwrap();
        assert!(tight.enable_prefix_cache(0));
        let out = Server::new(&tight, Policy::Fifo).serve(reqs.clone()).unwrap();
        let r1 = out.iter().find(|r| r.id == 1).unwrap();
        assert_eq!(
            r1.cached_tokens, 12,
            "request 1 must adopt the full cached system prompt — a \
             need-sized reclaim would have evicted it"
        );
        let stats = tight.prefix_stats().unwrap();
        assert_eq!(stats.evictions, 0, "no reclaim should have been needed");
        // And tokens still equal the cold run.
        let cold = Server::new(&engine(), Policy::Fifo).serve(reqs).unwrap();
        for c in &cold {
            let w = out.iter().find(|w| w.id == c.id).unwrap();
            assert_eq!(c.tokens, w.tokens, "request {}", c.id);
        }
        tight.debug_validate().unwrap();
    }

    #[test]
    fn preempted_prefix_sharer_returns_no_still_referenced_block() {
        // The free-list regression test: a tight arena forces the
        // continuous scheduler to preempt sessions that ADOPTED shared
        // prefix blocks; freeing them must only release exclusive
        // blocks (refcount invariant), tokens must still match the
        // isolated run, and the arena must validate after every serve.
        let tight = Engine::load_with_arena(
            Artifacts::synthetic(SEED).unwrap(),
            BackendKind::Reference,
            4,
            10,
        )
        .unwrap();
        assert!(tight.enable_prefix_cache(0));
        let requests = shared_prefix_reqs(6); // 13 prompt + 4 new = 5 blocks each
        let out = Server::new(&tight, Policy::Continuous { max_active: 6 })
            .serve(requests.clone())
            .unwrap();
        assert_eq!(out.len(), 6);
        assert!(
            out.iter().map(|r| r.evictions).sum::<u32>() > 0,
            "10 blocks cannot hold 6 x 5-block sessions without preemption"
        );
        tight.debug_validate().unwrap();
        let st = tight.arena_status();
        assert_eq!(
            st.free_blocks + st.pinned_blocks,
            st.total_blocks,
            "every non-pinned block must be back in the free list"
        );
        let fifo = Server::new(&engine(), Policy::Fifo).serve(requests).unwrap();
        for f in &fifo {
            let c = out.iter().find(|c| c.id == f.id).unwrap();
            assert_eq!(f.tokens, c.tokens, "request {}", f.id);
        }
    }

    #[test]
    fn responses_have_sane_timing() {
        let e = engine();
        for policy in [
            Policy::Batched { batch: 2 },
            Policy::Continuous { max_active: 2 },
        ] {
            let out = Server::new(&e, policy).serve(reqs(2)).unwrap();
            for r in out {
                assert!(r.service_s > 0.0, "{policy:?}");
                assert!(r.ttft_s <= r.service_s + 1e-9, "{policy:?}");
                assert!(r.queue_s >= 0.0 && r.queue_s <= r.service_s + 1e-9, "{policy:?}");
            }
        }
    }

    #[test]
    fn policy_flag_resolution() {
        // Historical default: --batch > 0 selects batched, else rr.
        assert_eq!(
            Policy::from_flags(None, 0, 4, 1).unwrap(),
            Policy::RoundRobin { max_active: 4 }
        );
        assert_eq!(
            Policy::from_flags(None, 8, 4, 1).unwrap(),
            Policy::Batched { batch: 8 }
        );
        // Explicit names; lane count comes from --batch, else --max-active.
        assert_eq!(
            Policy::from_flags(Some("fifo"), 8, 4, 1).unwrap(),
            Policy::Fifo
        );
        assert_eq!(
            Policy::from_flags(Some("rr"), 8, 4, 1).unwrap(),
            Policy::RoundRobin { max_active: 4 }
        );
        assert_eq!(
            Policy::from_flags(Some("batched"), 0, 4, 1).unwrap(),
            Policy::Batched { batch: 4 }
        );
        assert_eq!(
            Policy::from_flags(Some("continuous"), 8, 4, 1).unwrap(),
            Policy::Continuous { max_active: 8 }
        );
        assert_eq!(
            Policy::from_flags(Some("sharded"), 0, 3, 4).unwrap(),
            Policy::Sharded {
                workers: 4,
                max_active: 3
            }
        );
        // --workers 0 is clamped, not an error.
        assert_eq!(
            Policy::from_name("sharded", 2, 0, 0).unwrap(),
            Policy::Sharded {
                workers: 1,
                max_active: 2
            }
        );
    }

    #[test]
    fn unknown_policy_error_lists_the_valid_names() {
        let err = Policy::from_flags(Some("nope"), 0, 4, 1).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("unknown policy 'nope'"), "got: {msg}");
        for name in ["fifo", "rr", "batched", "continuous", "sharded"] {
            assert!(msg.contains(name), "error must list '{name}', got: {msg}");
        }
    }

    #[test]
    fn threaded_front_end_serves_and_sorts() {
        let out = serve_threaded_with(
            || Engine::load(Artifacts::synthetic(SEED)?),
            reqs(4),
            2,
            2,
        )
        .unwrap();
        assert_eq!(out.len(), 4);
        let ids: Vec<u64> = out.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3]);
    }

    #[test]
    fn threaded_replicas_match_single_engine() {
        // Worker replicas are deterministic copies: the sharded threaded
        // path must produce exactly the tokens the single-engine server
        // produces — under the round-robin, batched, and continuous
        // policies.
        let single = Server::new(&engine(), Policy::RoundRobin { max_active: 2 })
            .serve(reqs(4))
            .unwrap();
        for policy in [
            Policy::RoundRobin { max_active: 2 },
            Policy::Batched { batch: 2 },
            Policy::Continuous { max_active: 2 },
        ] {
            let threaded = ThreadedServe::new(|| Engine::load(Artifacts::synthetic(SEED)?))
                .workers(2)
                .policy(policy)
                .run(reqs(4))
                .unwrap();
            for t in &threaded {
                let s = single.iter().find(|s| s.id == t.id).unwrap();
                assert_eq!(s.tokens, t.tokens, "request {} under {policy:?}", t.id);
            }
        }
    }

    #[test]
    fn replica_front_end_rejects_the_sharded_policy() {
        let err = ThreadedServe::new(|| Engine::load(Artifacts::synthetic(SEED)?))
            .policy(Policy::Sharded {
                workers: 2,
                max_active: 2,
            })
            .run(reqs(2))
            .unwrap_err();
        assert!(err.to_string().contains("serve_sharded"), "got: {err}");
        let e = engine();
        let err = Server::new(&e, Policy::Sharded {
            workers: 2,
            max_active: 2,
        })
        .serve(reqs(2))
        .unwrap_err();
        assert!(err.to_string().contains("serve_sharded"), "got: {err}");
    }

    #[test]
    fn sharded_serving_matches_single_engine() {
        use crate::runtime::ShardedEngine;

        let single = Server::new(&engine(), Policy::Continuous { max_active: 4 })
            .serve(reqs(6))
            .unwrap();
        for workers in [1, 2, 3] {
            let mut se = ShardedEngine::load(
                Artifacts::synthetic(SEED).unwrap(),
                BackendKind::Reference,
                4,
                6 * workers,
                workers,
            )
            .unwrap();
            let (out, stats) = serve_sharded_stats(&mut se, reqs(6), &[0.0; 6], 2).unwrap();
            // Sorted by id, tokens byte-identical to the single engine.
            let ids: Vec<u64> = out.iter().map(|r| r.id).collect();
            assert_eq!(ids, vec![0, 1, 2, 3, 4, 5]);
            for o in &out {
                let s = single.iter().find(|s| s.id == o.id).unwrap();
                assert_eq!(o.tokens, s.tokens, "request {} x{workers}", o.id);
            }
            // Counters balance: every request placed once, served once.
            assert_eq!(stats.len(), workers);
            assert_eq!(stats.iter().map(|s| s.placed).sum::<usize>(), 6);
            assert_eq!(stats.iter().map(|s| s.served).sum::<usize>(), 6);
            // Nothing leaks: all shard blocks return to the free lists.
            let st = se.arena_status();
            assert_eq!(st.free_blocks, st.total_blocks);
            se.debug_validate().unwrap();
        }
    }
}
