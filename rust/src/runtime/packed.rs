//! Packed-bitplane runtime backend: the third execution engine, running
//! every W1A8 projection as a popcount MVM over [`crate::quant`]
//! bitplanes instead of a dense f32 matmul.
//!
//! Structure: at load, [`crate::quant::PackedModel::lower`] packs all
//! seven ternary matrix kinds (per layer wq/wk/wv/wx/w_in/w_out, plus
//! the model-level w_head) into two-u64-bitplane form — once, the way
//! the paper programs its PIM crossbars once before serving. The decode
//! step then routes every projection through
//! [`bitlinear_packed`]/[`bitlinear_packed_batch`] while reusing the
//! reference backend's attention/nonlinear path (shared
//! [`super::kernels`]) and its resolved parameter table for everything
//! that is not a ternary matrix (embedding, norm gammas).
//!
//! Outputs — logits AND KV caches — are bit-for-bit identical to the
//! reference backend on every path (single step, full generation,
//! ragged batches, batched serving); `tests/packed_equivalence.rs`
//! enforces it. See [`crate::quant`] for why exactness holds.

use super::artifacts::Artifacts;
use super::backend::{Backend, Caches, StepOutput};
use super::kernels::{attention, gelu, rms_norm};
use super::reference::ReferenceBackend;
use crate::quant::{bitlinear_packed, bitlinear_packed_batch, PackedModel};
use crate::util::error::{ensure, Context, Result};
use std::sync::Arc;

/// The packed backend: bitplane weights + popcount projection kernels.
///
/// Memory note: the 16x shrink is in weight TRAFFIC (what the decode
/// step streams per token), not residency — the embedded reference
/// backend keeps the full `Arc<Artifacts>` alive (embedding and gammas
/// live there), so the dense f32 projection tensors stay resident
/// alongside the bitplanes. Dropping them would need `Artifacts` to
/// give up per-parameter storage; not worth the churn while the dense
/// copy also serves the engine's `artifacts` accessor.
pub struct PackedBackend {
    /// The reference backend supplies the resolved parameter table
    /// (embedding, gammas) and the non-projection numerics; it holds no
    /// decode state, so reusing it costs a few indices.
    reference: ReferenceBackend,
    /// Every ternary matrix in packed form, lowered once at load.
    model: PackedModel,
}

impl PackedBackend {
    pub fn new(artifacts: Arc<Artifacts>) -> Result<Self> {
        let model =
            PackedModel::lower(&artifacts).context("lowering artifacts to bitplanes")?;
        let reference = ReferenceBackend::new(artifacts)?;
        Ok(Self { reference, model })
    }
}

impl Backend for PackedBackend {
    fn name(&self) -> &'static str {
        "packed"
    }

    fn platform(&self) -> String {
        "cpu".to_string()
    }

    fn empty_caches(&self) -> Result<Caches> {
        self.reference.empty_caches()
    }

    fn decode_step(&self, caches: Caches, token_id: i32, pos: i32) -> Result<StepOutput> {
        let (mut kc, mut vc) = match caches {
            Caches::Host { k, v } => (k, v),
            #[cfg(feature = "pjrt")]
            Caches::Device { .. } => {
                crate::bail!("packed backend received device-resident caches")
            }
        };
        let r = &self.reference;
        let m = r.artifacts.manifest.model.clone();
        let (d, h, max_ctx) = (m.d, m.h, m.max_ctx);
        let dh = d / h;
        ensure!(pos >= 0, "negative position {pos}");
        let pos = pos as usize;
        ensure!(pos < max_ctx, "position {pos} >= max_ctx {max_ctx}");
        let eps = m.eps as f32;

        // Embed (XLA clamps out-of-range gather indices; mirror that).
        let tok = (token_id.max(0) as usize).min(m.vocab - 1);
        let embedding = r.data(r.embedding);
        let mut x: Vec<f32> = embedding[tok * d..(tok + 1) * d].to_vec();

        for (layer, (lp, pl)) in r.layers.iter().zip(&self.model.layers).enumerate() {
            // --- attention sub-block (projections over bitplanes) -----
            let xn = rms_norm(&x, r.data(lp.ln1_gamma), eps);
            let q = bitlinear_packed(&xn, &pl.wq);
            let k = bitlinear_packed(&xn, &pl.wk);
            let v = bitlinear_packed(&xn, &pl.wv);

            // Write this token's K/V into the caches at `pos` (same
            // LPDDR-side concat as the reference backend).
            for head in 0..h {
                let base = ((layer * h + head) * max_ctx + pos) * dh;
                kc[base..base + dh].copy_from_slice(&k[head * dh..(head + 1) * dh]);
                vc[base..base + dh].copy_from_slice(&v[head * dh..(head + 1) * dh]);
            }

            let att = attention(&q, &kc, &vc, layer, pos, h, max_ctx, dh);
            let att = bitlinear_packed(&att, &pl.wx);
            for (xi, ai) in x.iter_mut().zip(&att) {
                *xi += ai;
            }

            // --- feed-forward sub-block -------------------------------
            let xn = rms_norm(&x, r.data(lp.ln2_gamma), eps);
            let ff = bitlinear_packed(&xn, &pl.w_in);
            let ff: Vec<f32> = ff.into_iter().map(gelu).collect();
            let ff = bitlinear_packed(&ff, &pl.w_out);
            for (xi, fi) in x.iter_mut().zip(&ff) {
                *xi += fi;
            }
        }

        let x = rms_norm(&x, r.data(r.lnf_gamma), eps);
        let logits = bitlinear_packed(&x, &self.model.w_head);

        Ok(StepOutput {
            logits,
            caches: Caches::Host { k: kc, v: vc },
        })
    }

    /// Batched decode over the bitplanes: every matrix's mask words are
    /// traversed ONCE per call and applied to all B activation-plane
    /// sets ([`bitlinear_packed_batch`]); attention runs per sequence,
    /// exactly like the reference backend's batched path. Ragged
    /// positions allowed; bit-identical to B sequential
    /// [`Backend::decode_step`] calls.
    fn decode_batch(
        &self,
        caches: Vec<Caches>,
        tokens: &[i32],
        positions: &[i32],
    ) -> Result<Vec<StepOutput>> {
        ensure!(
            caches.len() == tokens.len() && caches.len() == positions.len(),
            "decode_batch arity mismatch: {} caches, {} tokens, {} positions",
            caches.len(),
            tokens.len(),
            positions.len()
        );
        if caches.is_empty() {
            return Ok(Vec::new());
        }
        let r = &self.reference;
        let m = r.artifacts.manifest.model.clone();
        let (d, h, max_ctx) = (m.d, m.h, m.max_ctx);
        let dh = d / h;
        let eps = m.eps as f32;

        let mut kcs = Vec::with_capacity(caches.len());
        let mut vcs = Vec::with_capacity(caches.len());
        for c in caches {
            match c {
                Caches::Host { k, v } => {
                    kcs.push(k);
                    vcs.push(v);
                }
                #[cfg(feature = "pjrt")]
                Caches::Device { .. } => {
                    crate::bail!("packed backend received device-resident caches")
                }
            }
        }
        let mut poss = Vec::with_capacity(positions.len());
        for &p in positions {
            ensure!(p >= 0, "negative position {p}");
            let p = p as usize;
            ensure!(p < max_ctx, "position {p} >= max_ctx {max_ctx}");
            poss.push(p);
        }

        // Embed every sequence's token (XLA-style clamped gather).
        let embedding = r.data(r.embedding);
        let mut xs: Vec<Vec<f32>> = tokens
            .iter()
            .map(|&t| {
                let tok = (t.max(0) as usize).min(m.vocab - 1);
                embedding[tok * d..(tok + 1) * d].to_vec()
            })
            .collect();

        for (layer, (lp, pl)) in r.layers.iter().zip(&self.model.layers).enumerate() {
            // --- attention sub-block (projections over bitplanes) -----
            let xn: Vec<Vec<f32>> = xs
                .iter()
                .map(|x| rms_norm(x, r.data(lp.ln1_gamma), eps))
                .collect();
            let q = bitlinear_packed_batch(&xn, &pl.wq);
            let k = bitlinear_packed_batch(&xn, &pl.wk);
            let v = bitlinear_packed_batch(&xn, &pl.wv);

            // Scatter each sequence's new K/V into its own cache at its
            // own (ragged) position.
            for (((kc, vc), &pos), (k_i, v_i)) in kcs
                .iter_mut()
                .zip(vcs.iter_mut())
                .zip(&poss)
                .zip(k.iter().zip(&v))
            {
                for head in 0..h {
                    let base = ((layer * h + head) * max_ctx + pos) * dh;
                    kc[base..base + dh].copy_from_slice(&k_i[head * dh..(head + 1) * dh]);
                    vc[base..base + dh].copy_from_slice(&v_i[head * dh..(head + 1) * dh]);
                }
            }

            // Attention reads per-sequence KV state, not weights — there
            // is nothing to amortize, so it runs per sequence.
            let att: Vec<Vec<f32>> = q
                .iter()
                .zip(kcs.iter().zip(&vcs))
                .zip(&poss)
                .map(|((q_i, (kc, vc)), &pos)| attention(q_i, kc, vc, layer, pos, h, max_ctx, dh))
                .collect();
            let att = bitlinear_packed_batch(&att, &pl.wx);
            for (x, a) in xs.iter_mut().zip(&att) {
                for (xi, ai) in x.iter_mut().zip(a) {
                    *xi += ai;
                }
            }

            // --- feed-forward sub-block -------------------------------
            let xn: Vec<Vec<f32>> = xs
                .iter()
                .map(|x| rms_norm(x, r.data(lp.ln2_gamma), eps))
                .collect();
            let ff = bitlinear_packed_batch(&xn, &pl.w_in);
            let ff: Vec<Vec<f32>> = ff
                .into_iter()
                .map(|f| f.into_iter().map(gelu).collect())
                .collect();
            let ff = bitlinear_packed_batch(&ff, &pl.w_out);
            for (x, f) in xs.iter_mut().zip(&ff) {
                for (xi, fi) in x.iter_mut().zip(f) {
                    *xi += fi;
                }
            }
        }

        let xs: Vec<Vec<f32>> = xs
            .iter()
            .map(|x| rms_norm(x, r.data(r.lnf_gamma), eps))
            .collect();
        let logits = bitlinear_packed_batch(&xs, &self.model.w_head);

        Ok(logits
            .into_iter()
            .zip(kcs.into_iter().zip(vcs))
            .map(|(lg, (kc, vc))| StepOutput {
                logits: lg,
                caches: Caches::Host { k: kc, v: vc },
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn backends() -> (ReferenceBackend, PackedBackend) {
        let a = Arc::new(Artifacts::synthetic(13).unwrap());
        (
            ReferenceBackend::new(Arc::clone(&a)).unwrap(),
            PackedBackend::new(a).unwrap(),
        )
    }

    fn host(c: &Caches) -> (&[f32], &[f32]) {
        match c {
            Caches::Host { k, v } => (k, v),
            #[cfg(feature = "pjrt")]
            Caches::Device { .. } => panic!("expected host caches"),
        }
    }

    #[test]
    fn single_step_matches_reference_bitwise_including_caches() {
        let (r, p) = backends();
        let ro = r.decode_step(r.empty_caches().unwrap(), 9, 0).unwrap();
        let po = p.decode_step(p.empty_caches().unwrap(), 9, 0).unwrap();
        assert_eq!(ro.logits, po.logits);
        let (rk, rv) = host(&ro.caches);
        let (pk, pv) = host(&po.caches);
        assert_eq!(rk, pk);
        assert_eq!(rv, pv);
    }

    #[test]
    fn decode_batch_matches_reference_bitwise() {
        let (r, p) = backends();
        let tokens = [3i32, 17, 60];
        let positions = [0i32, 0, 0];
        let rc = tokens.iter().map(|_| r.empty_caches().unwrap()).collect();
        let pc = tokens.iter().map(|_| p.empty_caches().unwrap()).collect();
        let ro = r.decode_batch(rc, &tokens, &positions).unwrap();
        let po = p.decode_batch(pc, &tokens, &positions).unwrap();
        for (a, b) in ro.iter().zip(&po) {
            assert_eq!(a.logits, b.logits);
            assert_eq!(host(&a.caches), host(&b.caches));
        }
    }

    #[test]
    fn bounds_enforced_like_reference() {
        let (_, p) = backends();
        let max_ctx = p.reference.artifacts.manifest.model.max_ctx as i32;
        assert!(p.decode_step(p.empty_caches().unwrap(), 0, -1).is_err());
        assert!(p.decode_step(p.empty_caches().unwrap(), 0, max_ctx).is_err());
        assert!(p
            .decode_batch(vec![p.empty_caches().unwrap()], &[1, 2], &[0, 0])
            .is_err());
        assert!(p.decode_batch(Vec::new(), &[], &[]).unwrap().is_empty());
    }

    #[test]
    fn name_and_platform() {
        let (_, p) = backends();
        assert_eq!(p.name(), "packed");
        assert_eq!(p.platform(), "cpu");
        assert!(p.model.packed_bytes() > 0);
    }
}
