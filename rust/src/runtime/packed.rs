//! Packed-bitplane runtime backend: the third execution engine, running
//! every W1A8 projection as a popcount MVM over [`crate::quant`]
//! bitplanes instead of a dense f32 matmul.
//!
//! Structure: at load, [`crate::quant::PackedModel::lower`] packs all
//! seven ternary matrix kinds (per layer wq/wk/wv/wx/w_in/w_out, plus
//! the model-level w_head) into two-u64-bitplane form — once, the way
//! the paper programs its PIM crossbars once before serving — or, on
//! the `.tpk` path ([`PackedBackend::with_model`]), adopts planes
//! already materialized from a packed artifact with no re-pack at all.
//! The decode step then routes every projection through
//! [`bitlinear_packed_batch_with`] over the backend's own
//! [`PackedScratch`] (so the warm steady state does no kernel-side heap
//! allocation) while reusing the reference backend's
//! attention/nonlinear path (shared [`super::kernels`], including the
//! paged-arena attention gather) and its resolved parameter table for
//! everything that is not a ternary matrix (embedding, norm gammas).
//! Like the reference backend, a single decode step IS a batch of one
//! (the batch kernel at B=1 is bit-for-bit [`bitlinear_packed`],
//! pinned by the quant kernel tests), so one orchestration serves both
//! entry points.
//!
//! Outputs — logits AND KV caches — are bit-for-bit identical to the
//! reference backend on every path (single step, full generation,
//! ragged batches, batched and continuous serving, and decode over
//! prefix-cache-adopted shared blocks — `tests/prefix_equivalence.rs`
//! holds this backend to cold-prefill equality too);
//! `tests/packed_equivalence.rs` enforces it, and
//! `tests/paged_equivalence.rs` additionally holds this backend's paged
//! path to its own contiguous oracle
//! ([`PackedBackend::decode_step_contiguous`]). See [`crate::quant`]
//! for why exactness holds.

use super::artifacts::Artifacts;
use super::backend::Backend;
use super::kernels::{attention, gelu, rms_norm};
use super::kvcache::{ensure_distinct, ArenaLayout, CacheArena, CacheHandle};
use super::reference::ReferenceBackend;
use crate::obs::{Obs, SpanKind};
use crate::quant::{
    bitlinear_packed, bitlinear_packed_batch_with, PackedModel, PackedScratch,
};
use crate::util::error::{ensure, Context, Result};
use std::cell::RefCell;
use std::sync::Arc;

/// The packed backend: bitplane weights + popcount projection kernels.
///
/// Memory note: the 16x shrink is in weight TRAFFIC (what the decode
/// step streams per token), not residency — the embedded reference
/// backend keeps the full `Arc<Artifacts>` alive (embedding and gammas
/// live there), so the dense f32 projection tensors stay resident
/// alongside the bitplanes. Dropping them would need `Artifacts` to
/// give up per-parameter storage; not worth the churn while the dense
/// copy also serves the engine's `artifacts` accessor. (When the model
/// comes from a `.tpk` artifact via [`PackedBackend::with_model`], the
/// bitplanes themselves are usually not even resident — they are
/// mmap'd pages shared with every other process serving the same file.)
pub struct PackedBackend {
    /// The reference backend supplies the resolved parameter table
    /// (embedding, gammas) and the non-projection numerics; it holds no
    /// decode state, so reusing it costs a few indices.
    reference: ReferenceBackend,
    /// Every ternary matrix in packed form — lowered once at load, or
    /// shared (`Arc`) across every shard of a sharded engine when
    /// loaded from a `.tpk` artifact.
    model: Arc<PackedModel>,
    /// Reusable kernel scratch (activation bitplanes, scales, integer
    /// accumulator), grown to the model's high-water shape on the first
    /// step and allocation-free from then on. `RefCell`: `Backend`
    /// methods take `&self`, and a backend is owned by exactly one
    /// engine/worker thread (`Send`, not `Sync`), so the borrow is
    /// never contended.
    scratch: RefCell<PackedScratch>,
}

impl PackedBackend {
    pub fn new(artifacts: Arc<Artifacts>) -> Result<Self> {
        let model =
            PackedModel::lower(&artifacts).context("lowering artifacts to bitplanes")?;
        Self::with_model(artifacts, Arc::new(model))
    }

    /// Build the backend around an already-materialized packed model —
    /// the `.tpk` path: the engine (or the sharded engine, ONCE for all
    /// workers) loads the artifact and every backend shares the same
    /// `Arc`'d planes, so no per-worker re-pack and no per-worker copy.
    pub fn with_model(artifacts: Arc<Artifacts>, model: Arc<PackedModel>) -> Result<Self> {
        let m = &artifacts.manifest.model;
        ensure!(
            model.layers.len() == m.n_layers,
            "packed model has {} layers, manifest {}",
            model.layers.len(),
            m.n_layers
        );
        ensure!(
            model.w_head.k == m.d && model.w_head.n == m.vocab,
            "packed w_head is {}x{}, manifest model wants {}x{}",
            model.w_head.k,
            model.w_head.n,
            m.d,
            m.vocab
        );
        let reference = ReferenceBackend::new(artifacts)?;
        Ok(Self {
            reference,
            model,
            scratch: RefCell::new(PackedScratch::new()),
        })
    }

    /// The packed planes this backend executes (shared when loaded from
    /// a `.tpk`).
    pub fn model(&self) -> &Arc<PackedModel> {
        &self.model
    }

    /// The pre-paging contiguous decode step over the bitplane kernels,
    /// kept as this backend's bitwise ORACLE (see
    /// `ReferenceBackend::decode_step_contiguous` for the contract).
    pub fn decode_step_contiguous(
        &self,
        kc: &mut [f32],
        vc: &mut [f32],
        token_id: i32,
        pos: i32,
    ) -> Result<Vec<f32>> {
        let r = &self.reference;
        let m = r.artifacts.manifest.model.clone();
        let (d, h, max_ctx) = (m.d, m.h, m.max_ctx);
        let dh = d / h;
        ensure!(pos >= 0, "negative position {pos}");
        let pos = pos as usize;
        ensure!(pos < max_ctx, "position {pos} >= max_ctx {max_ctx}");
        let eps = m.eps as f32;

        // Embed (XLA clamps out-of-range gather indices; mirror that).
        let tok = (token_id.max(0) as usize).min(m.vocab - 1);
        let embedding = r.data(r.embedding);
        let mut x: Vec<f32> = embedding[tok * d..(tok + 1) * d].to_vec();

        for (layer, (lp, pl)) in r.layers.iter().zip(&self.model.layers).enumerate() {
            // --- attention sub-block (projections over bitplanes) -----
            let xn = rms_norm(&x, r.data(lp.ln1_gamma), eps);
            let q = bitlinear_packed(&xn, &pl.wq);
            let k = bitlinear_packed(&xn, &pl.wk);
            let v = bitlinear_packed(&xn, &pl.wv);

            for head in 0..h {
                let base = ((layer * h + head) * max_ctx + pos) * dh;
                kc[base..base + dh].copy_from_slice(&k[head * dh..(head + 1) * dh]);
                vc[base..base + dh].copy_from_slice(&v[head * dh..(head + 1) * dh]);
            }

            let att = attention(&q, kc, vc, layer, pos, h, max_ctx, dh);
            let att = bitlinear_packed(&att, &pl.wx);
            for (xi, ai) in x.iter_mut().zip(&att) {
                *xi += ai;
            }

            // --- feed-forward sub-block -------------------------------
            let xn = rms_norm(&x, r.data(lp.ln2_gamma), eps);
            let ff = bitlinear_packed(&xn, &pl.w_in);
            let ff: Vec<f32> = ff.into_iter().map(gelu).collect();
            let ff = bitlinear_packed(&ff, &pl.w_out);
            for (xi, fi) in x.iter_mut().zip(&ff) {
                *xi += fi;
            }
        }

        let x = rms_norm(&x, r.data(r.lnf_gamma), eps);
        Ok(bitlinear_packed(&x, &self.model.w_head))
    }
}

impl Backend for PackedBackend {
    fn name(&self) -> &'static str {
        "packed"
    }

    fn platform(&self) -> String {
        "cpu".to_string()
    }

    /// Kernel spans live on the embedded reference backend's obs slot —
    /// one shared bundle per engine, whichever backend records.
    fn install_obs(&self, obs: Arc<Obs>) {
        *self.reference.obs.borrow_mut() = obs;
    }

    fn decode_step(
        &self,
        arena: &mut CacheArena,
        handle: CacheHandle,
        token_id: i32,
        pos: i32,
    ) -> Result<Vec<f32>> {
        let mut out = self.decode_batch(arena, &[handle], &[token_id], &[pos])?;
        Ok(out.pop().expect("one lane in, one lane out"))
    }

    /// Batched decode over the bitplanes: every matrix's mask words are
    /// traversed ONCE per call and applied to all B activation-plane
    /// sets ([`bitlinear_packed_batch`]); attention runs per session
    /// through its block table, exactly like the reference backend's
    /// batched path. Ragged positions allowed; bit-identical to B
    /// sequential [`Backend::decode_step`] calls.
    fn decode_batch(
        &self,
        arena: &mut CacheArena,
        handles: &[CacheHandle],
        tokens: &[i32],
        positions: &[i32],
    ) -> Result<Vec<Vec<f32>>> {
        ensure!(
            handles.len() == tokens.len() && handles.len() == positions.len(),
            "decode_batch arity mismatch: {} handles, {} tokens, {} positions",
            handles.len(),
            tokens.len(),
            positions.len()
        );
        if handles.is_empty() {
            return Ok(Vec::new());
        }
        ensure_distinct(handles)?;
        self.step_many(arena, handles, tokens, positions)
    }

    /// One-session consecutive-position span through the same
    /// one-traversal-per-bitplane orchestration as
    /// [`Backend::decode_batch`]; same soundness argument and same f32
    /// gate as the reference backend's span (see
    /// `ReferenceBackend::decode_span`) — on the int8 layout a row write
    /// requantizes earlier rows of its group in place, so the span falls
    /// back to the sequential default there.
    fn decode_span(
        &self,
        arena: &mut CacheArena,
        handle: CacheHandle,
        tokens: &[i32],
        start_pos: i32,
    ) -> Result<Vec<Vec<f32>>> {
        if tokens.is_empty() {
            return Ok(Vec::new());
        }
        if arena.mode() != ArenaLayout::F32 {
            return tokens
                .iter()
                .enumerate()
                .map(|(i, &t)| self.decode_step(arena, handle, t, start_pos + i as i32))
                .collect();
        }
        let handles = vec![handle; tokens.len()];
        let positions: Vec<i32> = (0..tokens.len() as i32).map(|i| start_pos + i).collect();
        self.step_many(arena, &handles, tokens, &positions)
    }
}

impl PackedBackend {
    /// The shared batched orchestration behind [`Backend::decode_batch`]
    /// and [`Backend::decode_span`]; callers have validated arity — and
    /// distinctness where it matters (span entries alias one handle).
    fn step_many(
        &self,
        arena: &mut CacheArena,
        handles: &[CacheHandle],
        tokens: &[i32],
        positions: &[i32],
    ) -> Result<Vec<Vec<f32>>> {
        let r = &self.reference;
        let m = r.artifacts.manifest.model.clone();
        let (d, h, max_ctx) = (m.d, m.h, m.max_ctx);
        let dh = d / h;
        let eps = m.eps as f32;
        let poss = ReferenceBackend::prepare_step(arena, handles, positions, max_ctx)?;
        // One scratch borrow for the whole step: every projection below
        // reuses the same activation-plane/accumulator buffers, so the
        // warm steady state does no kernel-side heap allocation. The
        // obs borrow likewise lives for the step; span records stay
        // allocation-free with tracing on (pinned by the test below).
        let scratch = &mut *self.scratch.borrow_mut();
        let obs_guard = self.reference.obs.borrow();
        let obs: &Obs = &obs_guard;

        // Embed every session's token (XLA-style clamped gather).
        let embedding = r.data(r.embedding);
        let mut xs: Vec<Vec<f32>> = tokens
            .iter()
            .map(|&t| {
                let tok = (t.max(0) as usize).min(m.vocab - 1);
                embedding[tok * d..(tok + 1) * d].to_vec()
            })
            .collect();

        for (layer, (lp, pl)) in r.layers.iter().zip(&self.model.layers).enumerate() {
            // --- attention sub-block (projections over bitplanes) -----
            let xn: Vec<Vec<f32>> = xs
                .iter()
                .map(|x| rms_norm(x, r.data(lp.ln1_gamma), eps))
                .collect();
            let lid = layer as u64;
            obs.span_begin(SpanKind::KernelQ, lid);
            let q = bitlinear_packed_batch_with(&xn, &pl.wq, scratch);
            obs.span_end(SpanKind::KernelQ, lid);
            obs.span_begin(SpanKind::KernelK, lid);
            let k = bitlinear_packed_batch_with(&xn, &pl.wk, scratch);
            obs.span_end(SpanKind::KernelK, lid);
            obs.span_begin(SpanKind::KernelV, lid);
            let v = bitlinear_packed_batch_with(&xn, &pl.wv, scratch);
            obs.span_end(SpanKind::KernelV, lid);

            // Scatter each session's new K/V through its block table at
            // its own (ragged) position.
            for (i, (&hd, &pos)) in handles.iter().zip(&poss).enumerate() {
                arena.write_kv(hd, layer, pos, &k[i], &v[i])?;
            }

            // Attention reads per-session KV state, not weights — there
            // is nothing to amortize, so it runs per session.
            obs.span_begin(SpanKind::Attention, lid);
            let att = q
                .iter()
                .zip(handles.iter().zip(&poss))
                .map(|(q_i, (&hd, &pos))| {
                    Ok(ReferenceBackend::attention_dispatch(
                        q_i,
                        &arena.view(hd)?,
                        layer,
                        pos,
                        obs,
                    ))
                })
                .collect::<Result<Vec<_>>>()?;
            obs.span_end(SpanKind::Attention, lid);
            obs.span_begin(SpanKind::KernelO, lid);
            let att = bitlinear_packed_batch_with(&att, &pl.wx, scratch);
            obs.span_end(SpanKind::KernelO, lid);
            for (x, a) in xs.iter_mut().zip(&att) {
                for (xi, ai) in x.iter_mut().zip(a) {
                    *xi += ai;
                }
            }

            // --- feed-forward sub-block -------------------------------
            let xn: Vec<Vec<f32>> = xs
                .iter()
                .map(|x| rms_norm(x, r.data(lp.ln2_gamma), eps))
                .collect();
            obs.span_begin(SpanKind::KernelFf1, lid);
            let ff = bitlinear_packed_batch_with(&xn, &pl.w_in, scratch);
            obs.span_end(SpanKind::KernelFf1, lid);
            let ff: Vec<Vec<f32>> = ff
                .into_iter()
                .map(|f| f.into_iter().map(gelu).collect())
                .collect();
            obs.span_begin(SpanKind::KernelFf2, lid);
            let ff = bitlinear_packed_batch_with(&ff, &pl.w_out, scratch);
            obs.span_end(SpanKind::KernelFf2, lid);
            for (x, f) in xs.iter_mut().zip(&ff) {
                for (xi, fi) in x.iter_mut().zip(f) {
                    *xi += fi;
                }
            }
        }

        let xs: Vec<Vec<f32>> = xs
            .iter()
            .map(|x| rms_norm(x, r.data(r.lnf_gamma), eps))
            .collect();
        let hid = r.layers.len() as u64;
        obs.span_begin(SpanKind::KernelHead, hid);
        let logits = bitlinear_packed_batch_with(&xs, &self.model.w_head, scratch);
        obs.span_end(SpanKind::KernelHead, hid);
        Ok(logits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::kvcache::CacheLayout;

    fn backends() -> (ReferenceBackend, PackedBackend) {
        let a = Arc::new(Artifacts::synthetic(13).unwrap());
        (
            ReferenceBackend::new(Arc::clone(&a)).unwrap(),
            PackedBackend::new(a).unwrap(),
        )
    }

    fn arena_for(p: &PackedBackend) -> CacheArena {
        CacheArena::with_sessions(
            CacheLayout::from_model(&p.reference.artifacts.manifest.model),
            8,
        )
        .unwrap()
    }

    #[test]
    fn single_step_matches_reference_bitwise_including_caches() {
        let (r, p) = backends();
        let mut ra = arena_for(&p);
        let mut pa = arena_for(&p);
        let rs = r.new_session(&mut ra).unwrap();
        let ps = p.new_session(&mut pa).unwrap();
        let ro = r.decode_step(&mut ra, rs, 9, 0).unwrap();
        let po = p.decode_step(&mut pa, ps, 9, 0).unwrap();
        assert_eq!(ro, po);
        assert_eq!(
            ra.gather_contiguous(rs).unwrap(),
            pa.gather_contiguous(ps).unwrap()
        );
    }

    #[test]
    fn decode_batch_matches_reference_bitwise() {
        let (r, p) = backends();
        let mut ra = arena_for(&p);
        let mut pa = arena_for(&p);
        let tokens = [3i32, 17, 60];
        let positions = [0i32, 0, 0];
        let rh: Vec<_> = tokens.iter().map(|_| r.new_session(&mut ra).unwrap()).collect();
        let ph: Vec<_> = tokens.iter().map(|_| p.new_session(&mut pa).unwrap()).collect();
        let ro = r.decode_batch(&mut ra, &rh, &tokens, &positions).unwrap();
        let po = p.decode_batch(&mut pa, &ph, &tokens, &positions).unwrap();
        assert_eq!(ro, po);
        for (a, b) in rh.iter().zip(&ph) {
            assert_eq!(
                ra.gather_contiguous(*a).unwrap(),
                pa.gather_contiguous(*b).unwrap()
            );
        }
    }

    #[test]
    fn contiguous_oracle_matches_paged_path() {
        let (_, p) = backends();
        let m = p.reference.artifacts.manifest.model.clone();
        let mut arena =
            CacheArena::new(CacheLayout::with_block_len(&m, 5), 16).unwrap();
        let s = p.new_session(&mut arena).unwrap();
        let numel = m.n_layers * m.h * m.max_ctx * (m.d / m.h);
        let (mut kc, mut vc) = (vec![0.0f32; numel], vec![0.0f32; numel]);
        for (pos, tok) in [8i32, 3, 3, 11, 0, 6].into_iter().enumerate() {
            let paged = p.decode_step(&mut arena, s, tok, pos as i32).unwrap();
            let oracle = p
                .decode_step_contiguous(&mut kc, &mut vc, tok, pos as i32)
                .unwrap();
            assert_eq!(paged, oracle, "pos {pos}");
        }
        assert_eq!(arena.gather_contiguous(s).unwrap(), (kc, vc));
    }

    #[test]
    fn bounds_enforced_like_reference() {
        let (_, p) = backends();
        let mut arena = arena_for(&p);
        let max_ctx = p.reference.artifacts.manifest.model.max_ctx as i32;
        let s = p.new_session(&mut arena).unwrap();
        assert!(p.decode_step(&mut arena, s, 0, -1).is_err());
        assert!(p.decode_step(&mut arena, s, 0, max_ctx).is_err());
        assert!(p
            .decode_batch(&mut arena, &[s], &[1, 2], &[0, 0])
            .is_err());
        assert!(p.decode_batch(&mut arena, &[], &[], &[]).unwrap().is_empty());
    }

    #[test]
    fn name_and_platform() {
        let (_, p) = backends();
        assert_eq!(p.name(), "packed");
        assert_eq!(p.platform(), "cpu");
        assert!(p.model.packed_bytes() > 0);
    }

    #[test]
    fn packed_backend_is_send() {
        // Sharded serving constructs one packed backend per worker (the
        // bitplane re-pack is a load-time cost) and moves it into the
        // worker thread; that requires the struct to stay `Send`.
        fn assert_send<T: Send>() {}
        assert_send::<PackedBackend>();
    }

    #[test]
    fn warm_decode_with_tracing_on_adds_zero_allocations() {
        // The tentpole's inertness pin at the decode level: a warm
        // single-vector packed decode step allocates exactly as much
        // with tracing ON as with tracing OFF (its unavoidable output
        // vectors — logits, embeddings, per-layer activations — and
        // nothing from the instrumentation). The span-record path
        // itself writes into a ring preallocated at enable time.
        fn warm_step_allocs(trace: bool) -> u64 {
            let a = Arc::new(Artifacts::synthetic(13).unwrap());
            let p = PackedBackend::new(a).unwrap();
            if trace {
                let obs = Arc::new(Obs::new(0));
                obs.set_enabled(true);
                p.install_obs(Arc::clone(&obs));
                assert!(p.reference.obs.borrow().enabled());
            }
            let mut arena = CacheArena::with_sessions(
                CacheLayout::from_model(&p.reference.artifacts.manifest.model),
                8,
            )
            .unwrap();
            let s = p.new_session(&mut arena).unwrap();
            // Warm: scratch growth, block claims, ring warm-up.
            p.decode_step(&mut arena, s, 5, 0).unwrap();
            p.decode_step(&mut arena, s, 7, 1).unwrap();
            let before = crate::util::testalloc::thread_allocs();
            p.decode_step(&mut arena, s, 3, 2).unwrap();
            crate::util::testalloc::thread_allocs() - before
        }
        let off = warm_step_allocs(false);
        let on = warm_step_allocs(true);
        assert_eq!(
            on, off,
            "tracing ON changed warm decode allocation count ({on} vs {off})"
        );
    }

    #[test]
    fn tracing_on_does_not_change_logits() {
        // Inertness at the numerics level, backend-local: same session
        // history with tracing on vs off produces byte-identical logits
        // and records kernel spans for every layer family.
        let a = Arc::new(Artifacts::synthetic(13).unwrap());
        let p1 = PackedBackend::new(Arc::clone(&a)).unwrap();
        let p2 = PackedBackend::new(a).unwrap();
        let obs = Arc::new(Obs::new(0));
        obs.set_enabled(true);
        p2.install_obs(Arc::clone(&obs));
        let mut a1 = arena_for(&p1);
        let mut a2 = arena_for(&p2);
        let s1 = p1.new_session(&mut a1).unwrap();
        let s2 = p2.new_session(&mut a2).unwrap();
        for (pos, tok) in [4i32, 9, 2].into_iter().enumerate() {
            let o1 = p1.decode_step(&mut a1, s1, tok, pos as i32).unwrap();
            let o2 = p2.decode_step(&mut a2, s2, tok, pos as i32).unwrap();
            assert_eq!(o1, o2, "pos {pos}");
        }
        let events = obs.trace.drain();
        // 3 steps x n_layers x (7 kernels + attention) x 2 + head pair.
        let n_layers = p2.reference.layers.len();
        assert_eq!(events.len(), 3 * (n_layers * 7 * 2 + 2));
    }
}
