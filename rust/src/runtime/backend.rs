//! The execution-backend abstraction of the functional runtime.
//!
//! The decode-step numerics can be executed by more than one engine
//! (HPIM and LEAP structure their simulators the same way):
//!
//! * [`crate::runtime::reference`] — pure-Rust reference executor
//!   mirroring `python/compile/kernels/ref.py`; the DEFAULT, builds and
//!   runs offline with zero dependencies.
//! * [`crate::runtime::packed`] — bitplane popcount executor over
//!   [`crate::quant`] packed ternary weights; bit-identical outputs to
//!   the reference backend at a fraction of the weight traffic.
//! * [`crate::runtime::pjrt`] — the XLA/PJRT engine executing the
//!   AOT-lowered HLO; behind the off-by-default `pjrt` Cargo feature
//!   because the `xla` crate needs network access to build.
//!
//! Callers (decoder, serving, CLI) talk to [`crate::runtime::Engine`],
//! which owns a `Box<dyn Backend>`; KV caches are opaque [`Caches`]
//! values threaded between steps, so backends can keep state wherever
//! it lives naturally (host vectors vs device buffers).

use crate::util::error::{ensure, Result};

/// KV-cache state threaded between decode steps. Opaque to callers:
/// obtain from [`Backend::empty_caches`], pass to
/// [`Backend::decode_step`], which consumes it and returns the successor.
pub enum Caches {
    /// Host-resident caches of the reference backend; each of `k`/`v` is
    /// the flattened `(n_layers, h, max_ctx, d_head)` tensor, row-major.
    Host { k: Vec<f32>, v: Vec<f32> },
    /// Device-resident PJRT buffers (never copied to the host on the
    /// request path).
    #[cfg(feature = "pjrt")]
    Device {
        k: xla::PjRtBuffer,
        v: xla::PjRtBuffer,
    },
}

/// Outputs of one decode step.
pub struct StepOutput {
    pub logits: Vec<f32>,
    pub caches: Caches,
}

/// One execution engine for the decode step.
pub trait Backend {
    /// Short identifier: "reference", "packed" or "pjrt".
    fn name(&self) -> &'static str;

    /// Platform string (mirrors PJRT's platform_name, e.g. "cpu").
    fn platform(&self) -> String;

    /// Fresh zeroed KV caches in this backend's native representation.
    fn empty_caches(&self) -> Result<Caches>;

    /// Execute one decode step: feed token `token_id` at position `pos`
    /// with the given caches; returns logits + updated caches. Consumes
    /// the caches (they are superseded by the returned ones).
    fn decode_step(&self, caches: Caches, token_id: i32, pos: i32) -> Result<StepOutput>;

    /// Execute one decode step for B independent sequences at once:
    /// sequence `i` feeds `tokens[i]` at `positions[i]` into `caches[i]`
    /// (ragged positions allowed — sequences need not be in lock-step).
    /// Returns one [`StepOutput`] per sequence, in input order.
    ///
    /// Contract: the result MUST be exactly (bit-for-bit) what B separate
    /// [`Backend::decode_step`] calls would produce — batching is a
    /// throughput optimization, never a numerics change. The default
    /// implementation simply loops `decode_step`; backends that can
    /// amortize the per-step weight traversal across sequences (the PIM
    /// weight-stationary regime the paper's throughput claim rests on)
    /// override it.
    fn decode_batch(
        &self,
        caches: Vec<Caches>,
        tokens: &[i32],
        positions: &[i32],
    ) -> Result<Vec<StepOutput>> {
        ensure!(
            caches.len() == tokens.len() && caches.len() == positions.len(),
            "decode_batch arity mismatch: {} caches, {} tokens, {} positions",
            caches.len(),
            tokens.len(),
            positions.len()
        );
        caches
            .into_iter()
            .zip(tokens.iter().zip(positions))
            .map(|(c, (&t, &p))| self.decode_step(c, t, p))
            .collect()
    }
}
