//! The execution-backend abstraction of the functional runtime.
//!
//! The decode-step numerics can be executed by more than one engine
//! (HPIM and LEAP structure their simulators the same way):
//!
//! * [`crate::runtime::reference`] — pure-Rust reference executor
//!   mirroring `python/compile/kernels/ref.py`; the DEFAULT, builds and
//!   runs offline with zero dependencies.
//! * [`crate::runtime::packed`] — bitplane popcount executor over
//!   [`crate::quant`] packed ternary weights; bit-identical outputs to
//!   the reference backend at a fraction of the weight traffic.
//! * [`crate::runtime::pjrt`] — the XLA/PJRT engine executing the
//!   AOT-lowered HLO; behind the off-by-default `pjrt` Cargo feature
//!   because the `xla` crate needs network access to build.
//!
//! KV-cache state no longer moves through these calls: it lives in the
//! shared block-paged [`CacheArena`] ([`crate::runtime::kvcache`]), and
//! callers hold opaque generation-checked [`CacheHandle`]s. A decode
//! step reads and writes the session's cache in place through the
//! arena and returns only the logits — which is what lets the serving
//! layer admit, retire, and preempt sessions against real block usage
//! instead of worst-case context reservations. The host backends keep
//! all session state in the arena; the PJRT backend keeps its
//! device-resident contiguous buffers in a private side table keyed by
//! [`CacheHandle::key`] (the contiguous compatibility shim) while still
//! registering handles with the arena so handle lifecycle and
//! validation stay uniform.
//!
//! Thread topology: the trait deliberately has NO `Send` supertrait —
//! PJRT's device handles need not be movable. Both host backends are
//! plain data over an immutable `Arc<Artifacts>` (the reference
//! executor resolves parameter indices; the packed executor additionally
//! re-packs its bitplanes at construction), so they are `Send` by
//! structure, and the sharded serving engine boxes them as
//! `dyn Backend + Send` to move one instance into each worker thread
//! (see `runtime::engine::ShardedEngine`). Each worker gets its OWN
//! backend instance; only the `Arc`'d weights are shared.

use super::kvcache::{ensure_distinct, CacheArena, CacheHandle};
use crate::util::error::{ensure, Result};
use std::sync::Arc;

/// One execution engine for the decode step.
pub trait Backend {
    /// Short identifier: "reference", "packed" or "pjrt".
    fn name(&self) -> &'static str;

    /// Platform string (mirrors PJRT's platform_name, e.g. "cpu").
    fn platform(&self) -> String;

    /// Hand the backend its engine's observability bundle so kernel
    /// spans (the seven projection families + attention) land in the
    /// same per-shard trace ring as the serving events around them.
    /// Called once at engine assembly, never on a decode path. Default
    /// no-op: backends without kernel instrumentation (PJRT executes
    /// one fused program) simply stay silent.
    fn install_obs(&self, obs: Arc<crate::obs::Obs>) {
        let _ = obs;
    }

    /// Open a fresh decode session (zeroed cache state, no blocks held
    /// yet). Backends with private per-session state (PJRT's device
    /// buffers) override this to set it up alongside the arena slot.
    fn new_session(&self, arena: &mut CacheArena) -> Result<CacheHandle> {
        arena.alloc_session()
    }

    /// Retire a session: release its arena blocks (and any private
    /// backend state) and invalidate the handle.
    fn drop_session(&self, arena: &mut CacheArena, handle: CacheHandle) -> Result<()> {
        arena.free_session(handle)
    }

    /// Reserve cache capacity for a session that will feed `positions`
    /// tokens in total — the worst-case up-front reservation the
    /// fixed-wave schedulers use. Backends whose caches are not arena
    /// blocks (PJRT's contiguous device buffers already hold the full
    /// window) override this to a no-op.
    fn reserve_session(
        &self,
        arena: &mut CacheArena,
        handle: CacheHandle,
        positions: usize,
    ) -> Result<()> {
        if positions > 0 {
            arena.ensure_capacity(handle, positions - 1)
        } else {
            Ok(())
        }
    }

    /// Whether this backend's decode path reads K/V through the arena's
    /// block tables, so a session can adopt shared (copy-on-write)
    /// prefix blocks and skip the matched prefill positions. The host
    /// backends do; backends with private contiguous caches (PJRT's
    /// device buffers) override this to `false`, and the engine then
    /// never offers them prefix sharing — they fall back to full
    /// prefill, which is always correct.
    fn supports_prefix_sharing(&self) -> bool {
        true
    }

    /// Whether this backend's attention path can read an int8-layout
    /// arena ([`crate::runtime::kvcache::ArenaLayout::KvInt8`]) through
    /// [`crate::runtime::kernels::attention_paged_q8`]. The host
    /// backends dispatch on the arena layout per step, so they support
    /// it; backends with private contiguous f32 caches (PJRT's device
    /// buffers) override this to `false` and engine assembly rejects
    /// the combination up front instead of mis-decoding.
    fn supports_kv_int8(&self) -> bool {
        true
    }

    /// Whether decoding the session at position `pos` would claim a
    /// cache block it does not yet hold — the serving layer's arena
    /// pressure signal. Backends whose caches are not arena blocks
    /// (PJRT's device buffers) override this to report no pressure.
    fn session_needs_block(
        &self,
        arena: &CacheArena,
        handle: CacheHandle,
        pos: usize,
    ) -> Result<bool> {
        Ok(arena.layout().blocks_for_positions(pos + 1) > arena.session_blocks(handle)?)
    }

    /// Execute one decode step: feed token `token_id` at position `pos`
    /// into the session's cache state (updated in place through the
    /// arena); returns the logits. Claims the position's cache block on
    /// demand if the session does not hold it yet.
    fn decode_step(
        &self,
        arena: &mut CacheArena,
        handle: CacheHandle,
        token_id: i32,
        pos: i32,
    ) -> Result<Vec<f32>>;

    /// Execute one decode step for B independent sessions at once:
    /// session `handles[i]` feeds `tokens[i]` at `positions[i]` (ragged
    /// positions allowed — sessions need not be in lock-step). Returns
    /// one logits vector per session, in input order. A session may
    /// appear at most once per call.
    ///
    /// Contract: the result MUST be exactly (bit-for-bit) what B
    /// separate [`Backend::decode_step`] calls would produce — batching
    /// is a throughput optimization, never a numerics change. The
    /// default implementation simply loops `decode_step`; backends that
    /// can amortize the per-step weight traversal across sequences (the
    /// PIM weight-stationary regime the paper's throughput claim rests
    /// on) override it.
    fn decode_batch(
        &self,
        arena: &mut CacheArena,
        handles: &[CacheHandle],
        tokens: &[i32],
        positions: &[i32],
    ) -> Result<Vec<Vec<f32>>> {
        ensure!(
            handles.len() == tokens.len() && handles.len() == positions.len(),
            "decode_batch arity mismatch: {} handles, {} tokens, {} positions",
            handles.len(),
            tokens.len(),
            positions.len()
        );
        ensure_distinct(handles)?;
        handles
            .iter()
            .zip(tokens.iter().zip(positions))
            .map(|(&h, (&t, &p))| self.decode_step(arena, h, t, p))
            .collect()
    }

    /// Feed `tokens` into ONE session at consecutive positions
    /// `start_pos..start_pos + tokens.len()`, returning the logits
    /// after every fed position — the k-token verify traversal of
    /// greedy-exact speculative decoding and the chunked-prefill span.
    ///
    /// Contract: the result MUST be exactly (bit-for-bit) what
    /// `tokens.len()` sequential [`Backend::decode_step`] calls would
    /// produce. The default simply loops `decode_step`, which is
    /// correct on every backend. The host backends override it to
    /// traverse each weight matrix ONCE for the whole span (position
    /// `p + 1`'s layer-`l` input depends only on its own layer-`l-1`
    /// output, and its attention reads K/V rows `0..=p + 1`, which the
    /// per-layer scatter has already written — the same dataflow
    /// argument batched decode rests on); they fall back to this
    /// sequential loop on the int8 arena layout, where writing a row
    /// can rescale earlier rows of its quantization group in place and
    /// break the sequential bit-equivalence.
    fn decode_span(
        &self,
        arena: &mut CacheArena,
        handle: CacheHandle,
        tokens: &[i32],
        start_pos: i32,
    ) -> Result<Vec<Vec<f32>>> {
        tokens
            .iter()
            .enumerate()
            .map(|(i, &t)| self.decode_step(arena, handle, t, start_pos + i as i32))
            .collect()
    }
}
