//! Shared dense f32 kernels of the runtime backends.
//!
//! Extracted from the reference executor so the packed-bitplane backend
//! ([`crate::runtime::packed`]) can reuse the exact same
//! quantization/normalization/attention numerics while replacing only
//! the projection MVMs. Every function here mirrors
//! `python/compile/kernels/ref.py` + `model.py` bit for bit; the
//! cross-backend equivalence guarantee (`tests/packed_equivalence.rs`)
//! depends on both backends calling into this one module rather than
//! carrying private copies.
//!
//! Quantized integer values are carried in f32; every partial sum stays
//! inside the f32 exact-integer window (|v| < 2^24) for the shapes this
//! runtime sees: [`bitlinear`]'s accumulator is bounded by `k * 127`
//! (exact for k < 132,104 — [`crate::quant::pack::MAX_EXACT_K`] pins
//! the packed backend to the same window; the largest contraction in
//! this repo's models is d_ff <= 16384), and [`attention`]'s W8A8
//! products are bounded by `max(dh, max_ctx) * 127 * 127` with both
//! dims <= 128 here. See ref.py's module docstring for the original
//! derivation.

/// The activation-quantization scale alone (ref.py::act_quant_int8):
/// `127 / max(absmax(x), 1e-5)`. Split out of [`act_quant_int8`] so the
/// packed backend's zero-allocation kernel can quantize straight into
/// bitplane words — element `v` maps to `(v * scale).round().clamp(
/// -128.0, 127.0)`, and any caller applying exactly that formula is
/// bit-identical to [`act_quant_int8`] by construction.
pub fn act_scale(x: &[f32]) -> f32 {
    let absmax = x.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
    127.0 / absmax.max(1e-5)
}

/// Absmax per-tensor symmetric int8 quantization (ref.py::act_quant_int8):
/// scale = 127 / max(|x|, eps); x_q = clip(round(x * scale), -128, 127).
pub fn act_quant_int8(x: &[f32]) -> (Vec<f32>, f32) {
    let scale = act_scale(x);
    let q = x
        .iter()
        .map(|&v| (v * scale).round().clamp(-128.0, 127.0))
        .collect();
    (q, scale)
}

/// RMSNorm (model.py::rms_norm): x * rsqrt(mean(x^2) + eps) * gamma.
pub fn rms_norm(x: &[f32], gamma: &[f32], eps: f32) -> Vec<f32> {
    let var = x.iter().map(|&v| v * v).sum::<f32>() / x.len() as f32;
    let r = 1.0 / (var + eps).sqrt();
    x.iter().zip(gamma).map(|(&v, &g)| v * r * g).collect()
}

/// Tanh-approximate GELU (jax.nn.gelu approximate=True).
pub fn gelu(x: f32) -> f32 {
    const SQRT_2_OVER_PI: f32 = 0.797_884_6;
    0.5 * x * (1.0 + (SQRT_2_OVER_PI * (x + 0.044_715 * x * x * x)).tanh())
}

/// Numerically-stable softmax in place over `x`.
pub fn softmax(x: &mut [f32]) {
    let max = x.iter().fold(f32::NEG_INFINITY, |a, &v| a.max(v));
    let mut sum = 0.0f32;
    for v in x.iter_mut() {
        *v = (*v - max).exp();
        sum += *v;
    }
    for v in x.iter_mut() {
        *v /= sum;
    }
}

/// W1A8 projection (ref.py::bitlinear_ref): `x` (len k) through the
/// ternary matrix `w` (k x n_out, row-major) with combined dequant
/// rescale. One PIM-bank MVM.
pub fn bitlinear(x: &[f32], w: &[f32], n_out: usize, w_scale: f32) -> Vec<f32> {
    let k = x.len();
    debug_assert_eq!(w.len(), k * n_out);
    let (x_q, x_scale) = act_quant_int8(x);
    let mut acc = vec![0.0f32; n_out];
    for (kk, &xv) in x_q.iter().enumerate() {
        if xv == 0.0 {
            // Zero activations contribute nothing, so skip the row.
            // (The weight-side analogue — zero TERNARY WEIGHTS, a
            // measured ~31% of entries per `workload::ternary_sparsity`
            // / `workload::EXPECTED_TERNARY_SPARSITY` — costs this
            // dense kernel a full multiply per entry; the packed
            // backend's bitplanes skip those for free.)
            continue;
        }
        let row = &w[kk * n_out..(kk + 1) * n_out];
        for (a, &wv) in acc.iter_mut().zip(row) {
            *a += xv * wv;
        }
    }
    let rescale = w_scale / x_scale;
    for a in &mut acc {
        *a *= rescale;
    }
    acc
}

/// Batched W1A8 projection: the same numerics as [`bitlinear`] for each
/// of the B activation vectors in `xs`, but with ONE traversal of the
/// weight matrix `w` per call — each weight row is read once and applied
/// to every sequence while it is hot, instead of being re-streamed B
/// times. This is the software analogue of the paper's weight-stationary
/// PIM banks serving many users per programmed crossbar, and the whole
/// source of the batched path's throughput win.
///
/// Exactness: for every sequence `b` and output `j`, the accumulator
/// receives `x_q[b][kk] * w[kk][j]` for `kk` ascending — the identical
/// f32 operation sequence [`bitlinear`] performs — so the result is
/// bit-for-bit equal to B sequential calls. Column striping (below)
/// partitions `j`, never reorders `kk`, so thread count and stripe
/// boundaries cannot change a single bit of the output.
pub fn bitlinear_batch(xs: &[Vec<f32>], w: &[f32], n_out: usize, w_scale: f32) -> Vec<Vec<f32>> {
    let b = xs.len();
    if b == 0 {
        return Vec::new();
    }
    let k = xs[0].len();
    debug_assert!(xs.iter().all(|x| x.len() == k));
    debug_assert_eq!(w.len(), k * n_out);
    let quant: Vec<(Vec<f32>, f32)> = xs.iter().map(|x| act_quant_int8(x)).collect();

    // Column stripes: split the output dimension across threads once the
    // MAC count is large enough to amortize thread spawn. Each stripe
    // reads only its own columns of every row, so the weight matrix is
    // still traversed exactly once per call in aggregate.
    let stripes = column_stripes(b * k * n_out, n_out);

    let parts = crate::util::par::parallel_map_threads(&stripes, stripes.len(), |&(j0, j1)| {
        let width = j1 - j0;
        let mut acc = vec![0.0f32; b * width];
        for kk in 0..k {
            let row = &w[kk * n_out + j0..kk * n_out + j1];
            for (bi, (x_q, _)) in quant.iter().enumerate() {
                let xv = x_q[kk];
                if xv == 0.0 {
                    continue; // zero activation: nothing to accumulate
                }
                let a = &mut acc[bi * width..(bi + 1) * width];
                for (aj, &wv) in a.iter_mut().zip(row) {
                    *aj += xv * wv;
                }
            }
        }
        acc
    });

    let mut out: Vec<Vec<f32>> = vec![vec![0.0f32; n_out]; b];
    for (stripe, part) in stripes.iter().zip(&parts) {
        let (j0, j1) = *stripe;
        let width = j1 - j0;
        for (bi, o) in out.iter_mut().enumerate() {
            o[j0..j1].copy_from_slice(&part[bi * width..(bi + 1) * width]);
        }
    }
    for (o, (_, x_scale)) in out.iter_mut().zip(&quant) {
        let rescale = w_scale / x_scale;
        for a in o.iter_mut() {
            *a *= rescale;
        }
    }
    out
}

/// MAC-count threshold above which the batched projection kernels
/// (dense [`bitlinear_batch`] and the packed-bitplane batch kernel in
/// [`crate::quant`]) stripe output columns across threads. Striping
/// partitions columns and never reorders accumulation, so crossing the
/// threshold cannot change a bit of any output.
pub const PAR_MAC_THRESHOLD: usize = 1 << 21;

/// The shared column-stripe partition of both batched projection
/// kernels: one `[j0, j1)` range per worker thread over `n_out` output
/// columns, serial (a single full-width stripe) below
/// [`PAR_MAC_THRESHOLD`] MACs. One definition so the dense and packed
/// backends can never drift in how they parallelize.
pub fn column_stripes(macs: usize, n_out: usize) -> Vec<(usize, usize)> {
    let threads = if macs >= PAR_MAC_THRESHOLD {
        crate::util::par::default_threads().min(n_out)
    } else {
        1
    };
    let chunk = n_out.div_ceil(threads);
    (0..threads)
        .map(|t| (t * chunk, ((t + 1) * chunk).min(n_out)))
        .filter(|&(j0, j1)| j0 < j1)
        .collect()
}

/// One attention head over contiguous K/V rows — the single shared
/// definition of the W8A8 attention numerics (mirrors
/// model.py::_attention per head). `k_head`/`v_head` hold the `valid`
/// attended rows back to back; `o` (len `dh`) must arrive zeroed.
///
/// Both entry points funnel here: [`attention`] hands it slices of the
/// contiguous `(n_layers, h, max_ctx, d_head)` tensor, and
/// [`attention_paged`] hands it scratch gathered from the block-paged
/// arena. Because the gathered scratch holds byte-for-byte the same
/// rows in the same order, the two paths are bit-for-bit identical by
/// construction (and by `tests/paged_equivalence.rs`).
fn attention_head(q_head: &[f32], k_head: &[f32], v_head: &[f32], dh: usize, o: &mut [f32]) {
    let valid = k_head.len() / dh;
    debug_assert_eq!(k_head.len(), valid * dh);
    debug_assert_eq!(v_head.len(), valid * dh);

    // Score = q . K^T, both operands int8-quantized (W8A8).
    let (q_q, q_s) = act_quant_int8(q_head);
    let (k_q, k_s) = act_quant_int8(k_head);
    let inv_scale = 1.0 / (q_s * k_s);
    let inv_sqrt_dh = 1.0 / (dh as f32).sqrt();
    let mut scores = vec![0.0f32; valid];
    for (t, s) in scores.iter_mut().enumerate() {
        let row = &k_q[t * dh..(t + 1) * dh];
        let mut acc = 0.0f32;
        for (a, b) in q_q.iter().zip(row) {
            acc += a * b;
        }
        *s = acc * inv_scale * inv_sqrt_dh;
    }
    softmax(&mut scores);

    // Out = probs . V (W8A8 again).
    let (p_q, p_s) = act_quant_int8(&scores);
    let (v_q, v_s) = act_quant_int8(v_head);
    let inv_scale = 1.0 / (p_s * v_s);
    for (t, &pv) in p_q.iter().enumerate() {
        if pv == 0.0 {
            continue;
        }
        let row = &v_q[t * dh..(t + 1) * dh];
        for (oj, &vj) in o.iter_mut().zip(row) {
            *oj += pv * vj;
        }
    }
    for oj in o.iter_mut() {
        *oj *= inv_scale;
    }
}

/// Multi-head attention over contiguous KV tensors of one layer —
/// `k_cache`/`v_cache` are the flattened `(n_layers, h, max_ctx,
/// d_head)` host tensors; `q` is this token's query vector (len
/// `h * dh`); slots `[0, pos]` are attended (causal).
///
/// Since the paged-arena refactor the decode path reads K/V through
/// [`attention_paged`]; this contiguous entry point remains THE numeric
/// oracle — the `decode_step_contiguous` oracles in the reference and
/// packed backends run it, and `tests/paged_equivalence.rs` holds the
/// paged path to bitwise equality against it.
pub fn attention(
    q: &[f32],
    k_cache: &[f32],
    v_cache: &[f32],
    layer: usize,
    pos: usize,
    h: usize,
    max_ctx: usize,
    dh: usize,
) -> Vec<f32> {
    let valid = pos + 1; // causal: slots [0, pos]
    let mut out = vec![0.0f32; h * dh];
    for head in 0..h {
        let base = (layer * h + head) * max_ctx * dh;
        attention_head(
            &q[head * dh..(head + 1) * dh],
            &k_cache[base..base + valid * dh],
            &v_cache[base..base + valid * dh],
            dh,
            &mut out[head * dh..(head + 1) * dh],
        );
    }
    out
}

/// Multi-head attention reading K/V through a session's block table in
/// the paged arena ([`crate::runtime::kvcache::CacheArena`]). Per
/// `(layer, head)` the valid rows are gathered block by block into
/// contiguous scratch — one copy per block, in position order, exactly
/// the bytes the contiguous tensor would hold — and then run through
/// the identical [`attention_head`] accumulation. Gather order never
/// reorders rows, so the output is bit-for-bit equal to [`attention`]
/// on the equivalent contiguous caches.
pub fn attention_paged(
    q: &[f32],
    kv: &crate::runtime::kvcache::PagedKv<'_>,
    layer: usize,
    pos: usize,
) -> Vec<f32> {
    let (h, dh) = (kv.heads(), kv.head_dim());
    let valid = pos + 1; // causal: slots [0, pos]
    let mut out = vec![0.0f32; h * dh];
    let mut k_scratch = Vec::with_capacity(valid * dh);
    let mut v_scratch = Vec::with_capacity(valid * dh);
    for head in 0..h {
        kv.gather_head(layer, head, valid, &mut k_scratch, &mut v_scratch);
        attention_head(
            &q[head * dh..(head + 1) * dh],
            &k_scratch,
            &v_scratch,
            dh,
            &mut out[head * dh..(head + 1) * dh],
        );
    }
    out
}

/// Multi-head attention over an int8-layout paged arena
/// ([`crate::runtime::kvcache::ArenaLayout::KvInt8`]): the decode-side
/// kernel of the quantized KV cache. Instead of gathering f32 rows, it
/// walks the int8 code blocks IN PLACE
/// ([`crate::runtime::kvcache::PagedKv::for_each_block_q8`]) and
/// accumulates both W8A8 matmuls in i32 against the int8-quantized
/// query / probability vectors, dequantizing per (block, layer, head)
/// row-group only at the softmax boundary and at the PV epilogue — so
/// the memory-bound gather moves one byte per cached element instead of
/// four, and no f32 copy of the window is ever materialized.
///
/// Numerics vs the f32 oracle ([`attention_paged`]): the query and
/// probability vectors quantize under the identical `act_scale` rule,
/// and the K/V codes were stored under the same rule per row-group — so
/// when the window spans ONE block whose group absmax equals the
/// window absmax and every stored value already sits on the int8 grid,
/// the score and output arithmetic is the same integer sequence and the
/// result is bit-for-bit equal. Otherwise divergence is bounded by the
/// K/V quantization step (at most ~1.5 steps per element after a
/// requantize-on-grow), which `tests/kvq_equivalence.rs` pins.
///
/// i32 accumulator safety: QK^T is bounded by `dh * 127^2` and each
/// per-block PV partial by `block_len * 127^2` — both far inside i32
/// for every shape this runtime sees.
pub fn attention_paged_q8(
    q: &[f32],
    kv: &crate::runtime::kvcache::PagedKv<'_>,
    layer: usize,
    pos: usize,
) -> Vec<f32> {
    let (h, dh) = (kv.heads(), kv.head_dim());
    let valid = pos + 1; // causal: slots [0, pos]
    let inv_sqrt_dh = 1.0 / (dh as f32).sqrt();
    let mut out = vec![0.0f32; h * dh];
    let mut q_q = vec![0i32; dh];
    let mut p_q = vec![0i32; valid];
    let mut scores = vec![0.0f32; valid];
    let mut acc = vec![0i32; dh];
    for head in 0..h {
        let q_head = &q[head * dh..(head + 1) * dh];
        let q_s = act_scale(q_head);
        for (qq, &x) in q_q.iter_mut().zip(q_head) {
            *qq = (x * q_s).round().clamp(-128.0, 127.0) as i32;
        }

        // Score = q . K^T in i32, dequantized per block row-group.
        let mut t = 0usize;
        kv.for_each_block_q8(layer, head, valid, |k8, _v8, k_amax, _v_amax, rows| {
            let k_s = 127.0 / k_amax.max(1e-5);
            let inv_scale = 1.0 / (q_s * k_s);
            for r in 0..rows {
                let row = &k8[r * dh..(r + 1) * dh];
                let mut a = 0i32;
                for (&qq, &kk) in q_q.iter().zip(row) {
                    a += qq * i32::from(kk);
                }
                scores[t] = a as f32 * inv_scale * inv_sqrt_dh;
                t += 1;
            }
        });
        softmax(&mut scores);

        // Out = probs . V, probs int8-quantized under the shared rule,
        // accumulated in i32 per block and dequantized per row-group.
        let p_s = act_scale(&scores);
        for (pq, &p) in p_q.iter_mut().zip(scores.iter()) {
            *pq = (p * p_s).round().clamp(-128.0, 127.0) as i32;
        }
        let o = &mut out[head * dh..(head + 1) * dh];
        let mut t = 0usize;
        kv.for_each_block_q8(layer, head, valid, |_k8, v8, _k_amax, v_amax, rows| {
            let v_s = 127.0 / v_amax.max(1e-5);
            let inv_scale = 1.0 / (p_s * v_s);
            acc.fill(0);
            for r in 0..rows {
                let pv = p_q[t];
                t += 1;
                if pv == 0 {
                    continue;
                }
                let row = &v8[r * dh..(r + 1) * dh];
                for (aj, &vj) in acc.iter_mut().zip(row) {
                    *aj += pv * i32::from(vj);
                }
            }
            for (oj, &aj) in o.iter_mut().zip(acc.iter()) {
                *oj += aj as f32 * inv_scale;
            }
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn act_quant_matches_ref_py_semantics() {
        let (q, s) = act_quant_int8(&[0.5, -1.0, 0.25]);
        assert_eq!(s, 127.0);
        assert_eq!(q, vec![64.0, -127.0, 32.0]);
        // All-zero input: eps floor keeps the scale finite.
        let (q0, s0) = act_quant_int8(&[0.0, 0.0]);
        assert!(s0.is_finite() && s0 > 0.0);
        assert_eq!(q0, vec![0.0, 0.0]);
    }

    #[test]
    fn softmax_normalizes() {
        let mut x = vec![1.0, 2.0, 3.0];
        softmax(&mut x);
        assert!((x.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(x[2] > x[1] && x[1] > x[0]);
    }

    #[test]
    fn bitlinear_identity_on_identity_matrix() {
        // w = I (ternary-legal), scale chosen so rescale undoes x's
        // quantization: y ~= x.
        let n = 4;
        let mut w = vec![0.0f32; n * n];
        for i in 0..n {
            w[i * n + i] = 1.0;
        }
        let x = vec![0.5, -0.25, 0.125, 1.0];
        let y = bitlinear(&x, &w, n, 1.0);
        for (a, b) in x.iter().zip(&y) {
            assert!((a - b).abs() < 0.01, "{a} vs {b}");
        }
    }

    #[test]
    fn bitlinear_batch_bitwise_matches_sequential() {
        // Random-ish inputs across shapes that exercise both the serial
        // stripe path and ragged widths; the batched kernel must agree
        // bit-for-bit with per-vector bitlinear.
        let mut rng = crate::util::rng::Rng::new(99);
        for (b_n, k, n_out) in [(1usize, 8usize, 5usize), (3, 16, 16), (8, 32, 7)] {
            // Rng::range is INCLUSIVE: [0, 2] - 1 = {-1, 0, 1}, the
            // ternary domain the W1A8 contract is about.
            let w: Vec<f32> = (0..k * n_out)
                .map(|_| rng.range(0, 2) as f32 - 1.0)
                .collect();
            let xs: Vec<Vec<f32>> = (0..b_n)
                .map(|_| (0..k).map(|_| rng.normal() as f32).collect())
                .collect();
            let batched = bitlinear_batch(&xs, &w, n_out, 0.37);
            for (x, y) in xs.iter().zip(&batched) {
                assert_eq!(&bitlinear(x, &w, n_out, 0.37), y);
            }
        }
    }

    #[test]
    fn attention_is_causal_and_finite() {
        // One layer, one head, dh=2, max_ctx=4: slots beyond `pos` must
        // not influence the output.
        let (h, max_ctx, dh) = (1usize, 4usize, 2usize);
        let q = vec![0.3, -0.7];
        let mut k_cache = vec![0.0f32; h * max_ctx * dh];
        let mut v_cache = vec![0.0f32; h * max_ctx * dh];
        for (i, (kv, vv)) in k_cache.iter_mut().zip(v_cache.iter_mut()).enumerate() {
            *kv = (i as f32 * 0.31).sin();
            *vv = (i as f32 * 0.17).cos();
        }
        let at_pos1 = attention(&q, &k_cache, &v_cache, 0, 1, h, max_ctx, dh);
        // Scribble over the not-yet-valid slots: output must not change.
        for i in 2 * dh..max_ctx * dh {
            k_cache[i] = 1e6;
            v_cache[i] = -1e6;
        }
        let again = attention(&q, &k_cache, &v_cache, 0, 1, h, max_ctx, dh);
        assert_eq!(at_pos1, again);
        assert!(at_pos1.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn paged_attention_matches_contiguous_bitwise() {
        // Same K/V contents written both contiguously and through the
        // paged arena (awkward block length 3, so positions straddle
        // block boundaries): attention outputs must be identical bits.
        use crate::runtime::artifacts::ModelInfo;
        use crate::runtime::kvcache::{CacheArena, CacheLayout};
        let m = ModelInfo {
            vocab: 8,
            d: 8,
            h: 2,
            d_ff: 8,
            n_layers: 2,
            max_ctx: 11,
            eps: 1e-5,
        };
        let (h, dh, max_ctx) = (m.h, m.d / m.h, m.max_ctx);
        let mut arena = CacheArena::new(CacheLayout::with_block_len(&m, 3), 16).unwrap();
        let s = arena.alloc_session().unwrap();
        let numel = m.n_layers * h * max_ctx * dh;
        let (mut kc, mut vc) = (vec![0.0f32; numel], vec![0.0f32; numel]);
        let mut rng = crate::util::rng::Rng::new(5);
        for pos in 0..max_ctx {
            arena.ensure_capacity(s, pos).unwrap();
            for layer in 0..m.n_layers {
                let k_row: Vec<f32> = (0..h * dh).map(|_| rng.normal() as f32).collect();
                let v_row: Vec<f32> = (0..h * dh).map(|_| rng.normal() as f32).collect();
                arena.write_kv(s, layer, pos, &k_row, &v_row).unwrap();
                for head in 0..h {
                    let base = ((layer * h + head) * max_ctx + pos) * dh;
                    kc[base..base + dh].copy_from_slice(&k_row[head * dh..(head + 1) * dh]);
                    vc[base..base + dh].copy_from_slice(&v_row[head * dh..(head + 1) * dh]);
                }
            }
            let q: Vec<f32> = (0..h * dh).map(|_| rng.normal() as f32).collect();
            for layer in 0..m.n_layers {
                let contiguous = attention(&q, &kc, &vc, layer, pos, h, max_ctx, dh);
                let paged = attention_paged(&q, &arena.view(s).unwrap(), layer, pos);
                assert_eq!(contiguous, paged, "layer {layer} pos {pos}");
            }
        }
    }

    fn tiny_model(max_ctx: usize) -> crate::runtime::artifacts::ModelInfo {
        crate::runtime::artifacts::ModelInfo {
            vocab: 8,
            d: 8,
            h: 2,
            d_ff: 8,
            n_layers: 2,
            max_ctx,
            eps: 1e-5,
        }
    }

    #[test]
    fn q8_attention_is_exact_on_grid_aligned_single_block_windows() {
        // K/V values in {-1, 0, 1} quantize losslessly (group absmax 1,
        // scale 127) and — inside one block, where the f32 oracle's
        // whole-window scale equals the group scale — the q8 kernel runs
        // the identical integer sequence, so outputs match bit for bit.
        use crate::runtime::kvcache::{ArenaLayout, CacheArena, CacheLayout};
        let m = tiny_model(8);
        let (h, dh) = (m.h, m.d / m.h);
        let layout = CacheLayout::with_block_len(&m, 8); // one block covers all
        let mut fa = CacheArena::new_with_mode(layout.clone(), 4, ArenaLayout::F32).unwrap();
        let mut qa = CacheArena::new_with_mode(layout, 4, ArenaLayout::KvInt8).unwrap();
        let fs = fa.alloc_session().unwrap();
        let qs = qa.alloc_session().unwrap();
        let mut rng = crate::util::rng::Rng::new(21);
        for pos in 0..8usize {
            fa.ensure_capacity(fs, pos).unwrap();
            qa.ensure_capacity(qs, pos).unwrap();
            for layer in 0..m.n_layers {
                let k_row: Vec<f32> =
                    (0..h * dh).map(|_| rng.range(0, 2) as f32 - 1.0).collect();
                let v_row: Vec<f32> =
                    (0..h * dh).map(|_| rng.range(0, 2) as f32 - 1.0).collect();
                fa.write_kv(fs, layer, pos, &k_row, &v_row).unwrap();
                qa.write_kv(qs, layer, pos, &k_row, &v_row).unwrap();
            }
            let q: Vec<f32> = (0..h * dh).map(|_| rng.normal() as f32).collect();
            for layer in 0..m.n_layers {
                let oracle = attention_paged(&q, &fa.view(fs).unwrap(), layer, pos);
                let q8 = attention_paged_q8(&q, &qa.view(qs).unwrap(), layer, pos);
                assert_eq!(oracle, q8, "layer {layer} pos {pos}");
            }
        }
    }

    #[test]
    fn q8_attention_tracks_the_f32_oracle_within_quantization_error() {
        // Random normal K/V across an awkward block length (windows
        // straddle blocks, group scales grow as rows arrive): the q8
        // output must stay within a small absolute band of the f32
        // paged oracle on the same written rows.
        use crate::runtime::kvcache::{ArenaLayout, CacheArena, CacheLayout};
        let m = tiny_model(11);
        let (h, dh) = (m.h, m.d / m.h);
        let layout = CacheLayout::with_block_len(&m, 3);
        let mut fa = CacheArena::new_with_mode(layout.clone(), 16, ArenaLayout::F32).unwrap();
        let mut qa = CacheArena::new_with_mode(layout, 16, ArenaLayout::KvInt8).unwrap();
        let fs = fa.alloc_session().unwrap();
        let qs = qa.alloc_session().unwrap();
        let mut rng = crate::util::rng::Rng::new(7);
        for pos in 0..m.max_ctx {
            fa.ensure_capacity(fs, pos).unwrap();
            qa.ensure_capacity(qs, pos).unwrap();
            for layer in 0..m.n_layers {
                let k_row: Vec<f32> = (0..h * dh).map(|_| rng.normal() as f32).collect();
                let v_row: Vec<f32> = (0..h * dh).map(|_| rng.normal() as f32).collect();
                fa.write_kv(fs, layer, pos, &k_row, &v_row).unwrap();
                qa.write_kv(qs, layer, pos, &k_row, &v_row).unwrap();
            }
            let q: Vec<f32> = (0..h * dh).map(|_| rng.normal() as f32).collect();
            for layer in 0..m.n_layers {
                let oracle = attention_paged(&q, &fa.view(fs).unwrap(), layer, pos);
                let q8 = attention_paged_q8(&q, &qa.view(qs).unwrap(), layer, pos);
                for (a, b) in oracle.iter().zip(&q8) {
                    assert!(
                        (a - b).abs() < 0.05,
                        "layer {layer} pos {pos}: {a} vs {b}"
                    );
                    assert!(b.is_finite());
                }
            }
        }
    }
}
