//! The PJRT execution backend (behind the `pjrt` Cargo feature; needs
//! the `xla` crate — see the dependency-policy note in Cargo.toml).
//!
//! Compiles the decode-step HLO once, stages the weights **on device
//! once** (`buffer_from_host_buffer`, whose kImmutableOnlyDuringCall
//! semantics copy synchronously), and runs each generated token through
//! `execute_b` with device-resident buffers.
//!
//! Cache model — the contiguous compatibility shim: the AOT-lowered HLO
//! takes full contiguous `(n_layers, h, max_ctx, d_head)` cache
//! operands, so this backend cannot read through the host arena's block
//! tables. Instead it registers plain sessions with the arena (handle
//! lifecycle and validation stay uniform with the host backends; the
//! sessions never claim arena blocks) and keeps its device-resident
//! K/V buffer pairs in a private side table keyed by
//! [`CacheHandle::key`]. `reserve_session` is a no-op — the device
//! buffers already hold the full window — so the serving layer's
//! arena-pressure admission sees zero pressure from PJRT sessions,
//! which is correct: their memory is device-managed.
//!
//! Perf note (EXPERIMENTS.md §Perf): the naive path executed with host
//! literals, which re-uploads all ~6.8 MB of weights every decode step.
//! Staging weights as PjRtBuffers at load time and keeping the KV
//! caches device-resident removes that copy from the request path —
//! only the two scalars (token, pos) are uploaded per step and only the
//! logits are downloaded.
//!
//! Interchange is HLO *text* — see aot.py and /opt/xla-example/README.md
//! for why serialized protos from jax >= 0.5 are rejected by
//! xla_extension 0.5.1.

use super::artifacts::Artifacts;
use super::backend::Backend;
use super::kvcache::{CacheArena, CacheHandle};
use crate::util::error::{anyhow, bail, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::sync::Arc;
use xla::{HloModuleProto, PjRtBuffer, PjRtClient, PjRtLoadedExecutable, XlaComputation};

/// Compiled decode-step executable plus everything static across tokens.
pub struct PjrtBackend {
    client: PjRtClient,
    exe: PjRtLoadedExecutable,
    /// Device-resident parameter buffers in manifest order (staged once).
    param_buffers: Vec<PjRtBuffer>,
    artifacts: Arc<Artifacts>,
    /// The contiguous shim: device-resident (k, v) cache buffers per
    /// live session, keyed by the handle's (slot, generation) key.
    sessions: RefCell<HashMap<u64, (PjRtBuffer, PjRtBuffer)>>,
}

impl PjrtBackend {
    /// Compile the HLO on the CPU PJRT client, stage the weights on
    /// device. Requires real AOT artifacts (`make artifacts`) — the
    /// synthetic set has no HLO text.
    pub fn new(artifacts: Arc<Artifacts>) -> Result<Self> {
        let client = PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e}"))?;
        let proto = HloModuleProto::from_text_file(artifacts.hlo_path())
            .map_err(|e| anyhow!("parsing HLO text: {e}"))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling decode_step: {e}"))?;

        // buffer_from_host_buffer uses kImmutableOnlyDuringCall semantics:
        // the copy completes during the call, so the host slices may be
        // dropped afterwards (BufferFromHostLiteral, by contrast, copies
        // asynchronously and would require keeping the literals alive).
        let mut param_buffers = Vec::with_capacity(artifacts.manifest.params.len());
        for p in &artifacts.manifest.params {
            let data = artifacts.param_data(p);
            let dims: Vec<usize> = p.shape.clone();
            let buf = client
                .buffer_from_host_buffer(data, &dims, None)
                .map_err(|e| anyhow!("staging {}: {e}", p.name))?;
            param_buffers.push(buf);
        }

        Ok(Self {
            client,
            exe,
            param_buffers,
            artifacts,
            sessions: RefCell::new(HashMap::new()),
        })
    }

    /// Fresh zeroed device-resident cache buffers.
    fn empty_device_caches(&self) -> Result<(PjRtBuffer, PjRtBuffer)> {
        let shape = self.artifacts.cache_shape();
        let numel: usize = shape.iter().product();
        let zeros = vec![0f32; numel];
        let k = self
            .client
            .buffer_from_host_buffer(&zeros, &shape, None)
            .map_err(|e| anyhow!("cache upload: {e}"))?;
        let v = self
            .client
            .buffer_from_host_buffer(&zeros, &shape, None)
            .map_err(|e| anyhow!("cache upload: {e}"))?;
        Ok((k, v))
    }

    /// Upload a scalar i32 as a device buffer (synchronous copy).
    fn scalar_buffer(&self, v: i32) -> Result<PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(&[v], &[], None)
            .map_err(|e| anyhow!("scalar upload: {e}"))
    }

    /// PJRT may flatten the (logits, k, v) output tuple into three
    /// buffers or hand back a single tuple buffer depending on the
    /// client; handle both. Returns (logits, k, v).
    fn unpack_outputs(
        &self,
        mut outputs: Vec<PjRtBuffer>,
    ) -> Result<(Vec<f32>, PjRtBuffer, PjRtBuffer)> {
        match outputs.len() {
            3 => {
                let v = outputs.pop().unwrap();
                let k = outputs.pop().unwrap();
                let logits_buf = outputs.pop().unwrap();
                let logits = logits_buf
                    .to_literal_sync()
                    .map_err(|e| anyhow!("logits fetch: {e}"))?
                    .to_vec::<f32>()
                    .map_err(|e| anyhow!("logits to_vec: {e}"))?;
                Ok((logits, k, v))
            }
            1 => {
                // Tuple buffer: download, split, re-upload the caches.
                let out = outputs.pop().unwrap();
                let lit = out
                    .to_literal_sync()
                    .map_err(|e| anyhow!("tuple fetch: {e}"))?;
                let (logits_lit, k_lit, v_lit) = lit
                    .to_tuple3()
                    .map_err(|e| anyhow!("output tuple: {e}"))?;
                let logits = logits_lit
                    .to_vec::<f32>()
                    .map_err(|e| anyhow!("logits to_vec: {e}"))?;
                let shape = self.artifacts.cache_shape();
                let k_host = k_lit
                    .to_vec::<f32>()
                    .map_err(|e| anyhow!("cache download: {e}"))?;
                let v_host = v_lit
                    .to_vec::<f32>()
                    .map_err(|e| anyhow!("cache download: {e}"))?;
                let k = self
                    .client
                    .buffer_from_host_buffer(&k_host, &shape, None)
                    .map_err(|e| anyhow!("cache re-upload: {e}"))?;
                let v = self
                    .client
                    .buffer_from_host_buffer(&v_host, &shape, None)
                    .map_err(|e| anyhow!("cache re-upload: {e}"))?;
                Ok((logits, k, v))
            }
            n => bail!("unexpected output arity {n}"),
        }
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn new_session(&self, arena: &mut CacheArena) -> Result<CacheHandle> {
        let handle = arena.alloc_session()?;
        let caches = self.empty_device_caches()?;
        self.sessions.borrow_mut().insert(handle.key(), caches);
        Ok(handle)
    }

    fn drop_session(&self, arena: &mut CacheArena, handle: CacheHandle) -> Result<()> {
        arena.free_session(handle)?;
        self.sessions.borrow_mut().remove(&handle.key());
        Ok(())
    }

    fn reserve_session(
        &self,
        _arena: &mut CacheArena,
        _handle: CacheHandle,
        _positions: usize,
    ) -> Result<()> {
        // Device caches are contiguous and already hold the full
        // context window; there is nothing to reserve in the host arena.
        Ok(())
    }

    fn supports_prefix_sharing(&self) -> bool {
        // The contiguous device-buffer shim cannot read through arena
        // block tables, so adopted prefix blocks would never reach the
        // device caches. Report no support; the engine then skips
        // adoption and this backend always runs the full prefill.
        false
    }

    fn supports_kv_int8(&self) -> bool {
        // The AOT-lowered HLO attends over contiguous f32 device caches;
        // it has no int8 gather/dequant path, so engine assembly must
        // refuse an int8-layout arena rather than mis-decode.
        false
    }

    fn session_needs_block(
        &self,
        arena: &CacheArena,
        handle: CacheHandle,
        _pos: usize,
    ) -> Result<bool> {
        // Validate the handle, but report no pressure: device caches
        // never claim host arena blocks.
        arena.session_blocks(handle)?;
        Ok(false)
    }

    fn decode_step(
        &self,
        arena: &mut CacheArena,
        handle: CacheHandle,
        token_id: i32,
        pos: i32,
    ) -> Result<Vec<f32>> {
        // Validate the handle against the arena first so stale handles
        // fail with the uniform error message.
        arena.session_blocks(handle)?;
        let (cache_k, cache_v) = self
            .sessions
            .borrow_mut()
            .remove(&handle.key())
            .ok_or_else(|| anyhow!("pjrt session {handle:?} has no device caches"))?;
        let tok = self.scalar_buffer(token_id)?;
        let p = self.scalar_buffer(pos)?;
        let mut args: Vec<&PjRtBuffer> = Vec::with_capacity(self.param_buffers.len() + 4);
        args.extend(self.param_buffers.iter());
        args.push(&cache_k);
        args.push(&cache_v);
        args.push(&tok);
        args.push(&p);

        // An execute/unpack failure loses the in-flight device buffers:
        // the session's next step will report the missing caches rather
        // than silently restarting from zeros.
        let mut result = self
            .exe
            .execute_b::<&PjRtBuffer>(&args)
            .map_err(|e| anyhow!("decode_step execute: {e}"))?;
        let outputs = result.swap_remove(0);
        let (logits, k, v) = self.unpack_outputs(outputs)?;
        self.sessions.borrow_mut().insert(handle.key(), (k, v));
        Ok(logits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifacts::default_dir;
    use crate::runtime::kvcache::CacheLayout;

    fn backend() -> Option<PjrtBackend> {
        if !default_dir().join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return None;
        }
        let artifacts = Arc::new(Artifacts::load(default_dir()).expect("artifacts"));
        Some(PjrtBackend::new(artifacts).expect("pjrt backend"))
    }

    fn arena_for(b: &PjrtBackend) -> CacheArena {
        CacheArena::with_sessions(
            CacheLayout::from_model(&b.artifacts.manifest.model),
            4,
        )
        .unwrap()
    }

    #[test]
    fn engine_compiles_and_steps() {
        let Some(b) = backend() else { return };
        assert_eq!(b.platform(), "cpu");
        let mut arena = arena_for(&b);
        let s = b.new_session(&mut arena).unwrap();
        let logits = b.decode_step(&mut arena, s, 1, 0).unwrap();
        assert_eq!(logits.len(), b.artifacts.manifest.model.vocab);
        assert!(logits.iter().all(|x| x.is_finite()));
        // The shim registers and retires device state with the handle.
        b.drop_session(&mut arena, s).unwrap();
        assert!(b.decode_step(&mut arena, s, 1, 1).is_err());
    }

    #[test]
    fn decode_step_matches_golden_first_logits() {
        let Some(b) = backend() else { return };
        let mut arena = arena_for(&b);
        let s = b.new_session(&mut arena).unwrap();
        let g = b.artifacts.golden.clone();
        let logits = b.decode_step(&mut arena, s, g.prompt[0], 0).unwrap();
        for (got, want) in logits.iter().zip(g.first_logits_prefix.iter()) {
            assert!(
                (got - want).abs() <= 1e-4 * want.abs().max(1.0),
                "{got} vs {want}"
            );
        }
        let l2: f64 = logits
            .iter()
            .map(|&x| (x as f64) * (x as f64))
            .sum::<f64>()
            .sqrt();
        assert!((l2 - g.first_logits_l2).abs() / g.first_logits_l2 < 1e-4);
    }

    #[test]
    fn corrupt_hlo_rejected_at_load() {
        // Failure injection: valid manifest/weights/golden but truncated
        // HLO text must fail at PjrtBackend::new (the parse step).
        let dir = default_dir();
        if !dir.join("manifest.json").exists() {
            return;
        }
        let tmp = std::env::temp_dir().join(format!("pimllm-hlo-{}", std::process::id()));
        std::fs::create_dir_all(&tmp).unwrap();
        for f in ["manifest.json", "golden.json", "weights.bin"] {
            std::fs::copy(dir.join(f), tmp.join(f)).unwrap();
        }
        let hlo = std::fs::read_to_string(dir.join("decode_step.hlo.txt")).unwrap();
        std::fs::write(tmp.join("decode_step.hlo.txt"), &hlo[..hlo.len() / 3]).unwrap();
        let arts = Artifacts::load(&tmp).expect("artifacts themselves are valid");
        let result = PjrtBackend::new(Arc::new(arts));
        std::fs::remove_dir_all(&tmp).ok();
        assert!(result.is_err(), "truncated HLO must not compile");
    }
}
