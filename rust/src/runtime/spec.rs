//! Greedy-exact speculative decoding: a cheap DRAFT proposes the next
//! few tokens, the target backend verifies them all in ONE
//! [`super::Backend::decode_span`] traversal, and only proposals that
//! match the target's own greedy argmax are kept — so the served token
//! stream is byte-identical to non-speculative decoding *by
//! construction*, at any acceptance rate.
//!
//! Protocol, for a session with `L` committed tokens and `last_logits`
//! from position `L - 1`:
//!
//! 1. `f0 = greedy_argmax(last_logits)` — exactly the token the
//!    non-speculative path would feed next, correct with no draft help.
//! 2. The draft proposes `d_1..d_n` by feeding `f0, d_1, …` into its own
//!    session (`n <= k - 1`).
//! 3. The target feeds the whole span `[f0, d_1..d_n]` at positions
//!    `L..L + n`, yielding logits `O_0..O_n` — one weight traversal for
//!    up to `k` tokens instead of `k` traversals.
//! 4. Accept the longest prefix with `d_i == greedy_argmax(O_{i-1})`;
//!    absorb `f0, d_1..d_m` with their logits (`m + 1` tokens this
//!    tick, `O_m` becoming the next tick's `last_logits`).
//! 5. Roll back: cache rows were written for every span position, so on
//!    a rejection the target's block table is truncated to `L + m + 1`
//!    positions ([`super::kvcache::CacheArena::truncate_session`]); the
//!    draft is truncated to the same committed length. On the int8
//!    arena layout — where truncation cannot recover requantized rows —
//!    the serving layer verifies sequentially instead and never feeds
//!    an unverified token, so no target rollback is ever needed there.
//!
//! Every accepted token equals what non-speculative greedy decoding
//! would have produced at that position, and the logits carried forward
//! are the span logits — bitwise those of the sequential steps
//! ([`super::Backend::decode_span`]'s contract). A wrong draft can only
//! cost speed, never change output; `tests/spec_equivalence.rs` pins
//! spec-on == spec-off bytewise across backends, policies and drafts.
//!
//! Three draft sources, picked by `--spec-draft`:
//!
//! * `self` — the target's own artifact bundle (`Arc`-shared, no weight
//!   copy). 100% acceptance by construction but a full-cost draft; the
//!   verify-path demonstrator.
//! * `tiny` — a sized-down synthetic sibling (same vocab and context
//!   window, fraction of the width/depth). The realistic cost
//!   asymmetry; acceptance depends on how well it tracks the target.
//! * `oracle` — replays pre-recorded non-speculative streams keyed by
//!   request id: near-zero draft cost at 100% acceptance, the honest
//!   upper-bound harness for the speculative throughput benches (the
//!   bench records a spec-off run first).

use super::artifacts::{Artifacts, ModelInfo};
use super::decoder::greedy_argmax;
use super::engine::{BackendKind, Engine};
use super::kvcache::{CacheHandle, CacheLayout};
use crate::util::error::{bail, ensure, Context, Result};
use std::collections::HashMap;
use std::sync::Arc;

/// Proposals per verify when `--spec-draft` is given without `--spec-k`.
pub const DEFAULT_SPEC_K: usize = 4;

/// The `--spec-draft` flag, parsed. `Off` keeps serving exactly on the
/// non-speculative path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DraftSpec {
    Off,
    SelfModel,
    Tiny,
    Oracle,
}

impl DraftSpec {
    /// Parse `--spec-draft` (absent/empty/"off" disables).
    pub fn from_flag(s: &str) -> Result<Self> {
        match s {
            "" | "off" => Ok(DraftSpec::Off),
            "self" => Ok(DraftSpec::SelfModel),
            "tiny" => Ok(DraftSpec::Tiny),
            "oracle" => Ok(DraftSpec::Oracle),
            other => bail!("unknown --spec-draft '{other}' (off | self | tiny | oracle)"),
        }
    }
}

/// Where draft proposals come from. `Send + Sync`: the sharded front
/// end hands one plan to every worker thread, and each builds its own
/// private [`SpecState`] from it.
#[derive(Clone)]
pub enum DraftSource {
    /// Run this artifact bundle as a draft engine (self or tiny).
    Model(Arc<Artifacts>),
    /// Replay recorded greedy streams: request id -> the full token
    /// sequence (prompt + generated) of a non-speculative run.
    Oracle(Arc<HashMap<u64, Vec<i32>>>),
}

/// A speculative-decoding setup: the draft source plus the span width.
/// Cheap to clone (everything behind `Arc`); thread-safe by structure.
#[derive(Clone)]
pub struct SpecPlan {
    /// Max tokens fed per verify span: 1 bonus token + up to `k - 1`
    /// draft proposals.
    pub k: usize,
    pub source: DraftSource,
}

impl SpecPlan {
    fn with_k(k: usize, source: DraftSource) -> Result<Self> {
        ensure!(k >= 1, "--spec-k must be >= 1 (got {k})");
        Ok(Self { k, source })
    }

    /// Draft with the target's own bundle: every proposal matches, the
    /// draft costs as much as the target. Demonstrates the verify path.
    pub fn self_draft(target: &Arc<Artifacts>, k: usize) -> Result<Self> {
        Self::with_k(k, DraftSource::Model(Arc::clone(target)))
    }

    /// Draft with a sized-down synthetic sibling of `target`: same
    /// vocab and context window (proposals must be valid target tokens
    /// at valid positions), roughly quarter width and half depth — the
    /// cost asymmetry a real speculative deployment relies on.
    pub fn tiny_draft(target: &Arc<Artifacts>, k: usize) -> Result<Self> {
        let m = &target.manifest.model;
        let h = m.h.max(1);
        // Quarter the width, rounded up to a multiple of the head count
        // (and at least one lane per head).
        let d = m.d.div_ceil(4).div_ceil(h) * h;
        let tiny = ModelInfo {
            vocab: m.vocab,
            d,
            h,
            d_ff: 2 * d,
            n_layers: m.n_layers.div_ceil(2),
            max_ctx: m.max_ctx,
            eps: m.eps,
        };
        let bundle = Artifacts::synthetic_with(0x0D12AF7, tiny)
            .context("building the tiny draft bundle")?;
        Self::with_k(k, DraftSource::Model(Arc::new(bundle)))
    }

    /// Draft by replaying recorded streams (request id -> full token
    /// sequence from a non-speculative run of the same requests):
    /// near-zero cost, 100% acceptance on a faithful recording — and a
    /// stale or wrong recording only lowers acceptance, never output
    /// fidelity, because every proposal is still verified.
    pub fn oracle(book: HashMap<u64, Vec<i32>>, k: usize) -> Result<Self> {
        Self::with_k(k, DraftSource::Oracle(Arc::new(book)))
    }
}

/// One serving loop's live speculative state: the draft driver plus a
/// per-session map. NOT `Send` (a model draft owns an [`Engine`]);
/// every server or sharded worker builds its own from the shared plan.
pub struct SpecState {
    k: usize,
    driver: Driver,
}

enum Driver {
    Model(DraftEngine),
    Oracle(Arc<HashMap<u64, Vec<i32>>>),
}

/// A draft model mirrored beside the target: one reference-backend f32
/// engine plus one draft session per live target session.
struct DraftEngine {
    engine: Engine,
    /// Target session seq -> draft session. `fed` counts committed +
    /// proposed tokens fed into the draft; after a rejection the
    /// session is truncated back to the committed length.
    sessions: HashMap<u64, DraftSession>,
}

#[derive(Clone, Copy)]
struct DraftSession {
    handle: CacheHandle,
    fed: usize,
}

impl SpecState {
    /// Build from a plan. `lanes` bounds concurrent target sessions
    /// (the scheduler's `max_active`): a model draft sizes its private
    /// f32 arena to hold that many full-context draft sessions, so the
    /// draft can never hit block pressure of its own.
    pub fn build(plan: &SpecPlan, lanes: usize) -> Result<Self> {
        let driver = match &plan.source {
            DraftSource::Oracle(book) => Driver::Oracle(Arc::clone(book)),
            DraftSource::Model(bundle) => {
                let m = &bundle.manifest.model;
                let per_session =
                    CacheLayout::with_block_len(m, 0).blocks_for_positions(m.max_ctx);
                let blocks = per_session * (lanes.max(1) + 1);
                let engine = Engine::load_shared_with_arena(
                    Arc::clone(bundle),
                    BackendKind::Reference,
                    0,
                    blocks,
                )
                .context("building the speculative draft engine")?;
                Driver::Model(DraftEngine {
                    engine,
                    sessions: HashMap::new(),
                })
            }
        };
        Ok(Self { k: plan.k, driver })
    }

    /// Max tokens per verify span (bonus token included).
    pub fn k(&self) -> usize {
        self.k
    }

    /// Propose up to `n` tokens continuing `tokens + [f0]` for the
    /// session `seq` serving request `id`. May return fewer (draft
    /// context exhausted, oracle stream ended) — the verify span just
    /// shrinks. Proposals are suggestions only; the caller verifies
    /// every one against the target's own argmax.
    pub fn propose(
        &mut self,
        seq: u64,
        id: u64,
        tokens: &[i32],
        f0: i32,
        n: usize,
    ) -> Result<Vec<i32>> {
        match &mut self.driver {
            Driver::Oracle(book) => {
                let start = tokens.len() + 1; // skip the recorded f0 slot
                Ok(book
                    .get(&id)
                    .map(|stream| {
                        let end = stream.len().min(start + n);
                        stream.get(start..end).unwrap_or(&[]).to_vec()
                    })
                    .unwrap_or_default())
            }
            Driver::Model(draft) => draft.propose(seq, tokens, f0, n),
        }
    }

    /// The target committed `keep` tokens: roll the draft session back
    /// to them, dropping any rejected proposals it had fed.
    pub fn commit(&mut self, seq: u64, keep: usize) -> Result<()> {
        if let Driver::Model(draft) = &mut self.driver {
            if let Some(s) = draft.sessions.get_mut(&seq) {
                if keep < s.fed {
                    draft.engine.truncate_session(s.handle, keep)?;
                    s.fed = keep;
                }
            }
        }
        Ok(())
    }

    /// Drop EVERY draft session — a reused server starts its next serve
    /// run with fresh session seq numbers, which must not alias stale
    /// draft state from the previous run.
    pub fn reset(&mut self) {
        if let Driver::Model(draft) = &mut self.driver {
            for (_, s) in draft.sessions.drain() {
                let _ = draft.engine.free_session(s.handle);
            }
        }
    }

    /// The target session retired or was preempted: drop its draft
    /// state. A preempted request re-prefills from nothing, and its
    /// next speculative tick rebuilds the draft by catch-up feeding.
    pub fn forget(&mut self, seq: u64) {
        if let Driver::Model(draft) = &mut self.driver {
            if let Some(s) = draft.sessions.remove(&seq) {
                let _ = draft.engine.free_session(s.handle);
            }
        }
    }
}

impl DraftEngine {
    fn propose(&mut self, seq: u64, tokens: &[i32], f0: i32, n: usize) -> Result<Vec<i32>> {
        let (handle, mut fed) = match self.sessions.get(&seq) {
            Some(s) => (s.handle, s.fed),
            None => (self.engine.new_session()?, 0),
        };
        // Catch up on committed tokens the draft has not seen — the
        // whole gap in one span (a fresh or just-preempted session
        // re-prefills here).
        if fed < tokens.len() {
            self.engine
                .decode_span(handle, &tokens[fed..], fed as i32)
                .context("draft catch-up")?;
            fed = tokens.len();
        }
        let max_ctx = self.engine.max_ctx();
        let mut out = Vec::with_capacity(n);
        let mut t = f0;
        for _ in 0..n {
            if fed >= max_ctx {
                break; // draft window exhausted; shorter span, still exact
            }
            let logits = self.engine.decode_step(handle, t, fed as i32)?;
            fed += 1;
            t = greedy_argmax(&logits);
            out.push(t);
        }
        self.sessions.insert(seq, DraftSession { handle, fed });
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bundle() -> Arc<Artifacts> {
        Arc::new(Artifacts::synthetic(5).unwrap())
    }

    #[test]
    fn draft_spec_parses_every_kind_and_rejects_typos() {
        assert_eq!(DraftSpec::from_flag("").unwrap(), DraftSpec::Off);
        assert_eq!(DraftSpec::from_flag("off").unwrap(), DraftSpec::Off);
        assert_eq!(DraftSpec::from_flag("self").unwrap(), DraftSpec::SelfModel);
        assert_eq!(DraftSpec::from_flag("tiny").unwrap(), DraftSpec::Tiny);
        assert_eq!(DraftSpec::from_flag("oracle").unwrap(), DraftSpec::Oracle);
        let err = DraftSpec::from_flag("tinny").unwrap_err().to_string();
        assert!(err.contains("tinny"), "names the bad value: {err}");
        assert!(err.contains("oracle"), "lists the valid ones: {err}");
    }

    #[test]
    fn spec_k_zero_is_rejected() {
        let err = SpecPlan::self_draft(&bundle(), 0).unwrap_err().to_string();
        assert!(err.contains("--spec-k"), "{err}");
    }

    #[test]
    fn tiny_draft_keeps_vocab_and_context_and_shrinks_width() {
        let target = bundle();
        let plan = SpecPlan::tiny_draft(&target, 4).unwrap();
        let DraftSource::Model(draft) = &plan.source else {
            panic!("tiny draft must carry a model bundle");
        };
        let (t, d) = (&target.manifest.model, &draft.manifest.model);
        assert_eq!(d.vocab, t.vocab);
        assert_eq!(d.max_ctx, t.max_ctx);
        assert!(d.d < t.d, "narrower: {} < {}", d.d, t.d);
        assert!(d.n_layers <= t.n_layers);
        assert_eq!(d.d % d.h, 0);
    }

    #[test]
    fn oracle_proposes_the_recorded_continuation_and_nothing_past_it() {
        let mut book = HashMap::new();
        book.insert(7u64, vec![10, 11, 12, 13, 14]);
        let plan = SpecPlan::oracle(book, 4).unwrap();
        let mut st = SpecState::build(&plan, 2).unwrap();
        // 2 committed tokens: slot 2 is f0's, proposals start at slot 3.
        assert_eq!(st.propose(0, 7, &[10, 11], 12, 3).unwrap(), vec![13, 14]);
        // Unknown request: no proposals, the span degrades to 1 token.
        assert!(st.propose(0, 8, &[10, 11], 12, 3).unwrap().is_empty());
        // End of stream: nothing left to propose.
        assert!(st.propose(0, 7, &[10, 11, 12, 13], 14, 3).unwrap().is_empty());
        // Oracle commit/forget are stateless no-ops.
        st.commit(0, 1).unwrap();
        st.forget(0);
    }

    #[test]
    fn self_draft_proposes_the_greedy_continuation_and_rolls_back() {
        let a = bundle();
        let plan = SpecPlan::self_draft(&a, 4).unwrap();
        let mut st = SpecState::build(&plan, 2).unwrap();
        let props = st.propose(0, 99, &[], 7, 3).unwrap();
        assert_eq!(props.len(), 3);

        // Oracle check: the same greedy chain on an independent engine.
        let e =
            Engine::load_shared_with_arena(Arc::clone(&a), BackendKind::Reference, 0, 0)
                .unwrap();
        let h = e.new_session().unwrap();
        let mut t = 7;
        let mut expect = Vec::new();
        for pos in 0..3 {
            let l = e.decode_step(h, t, pos).unwrap();
            t = greedy_argmax(&l);
            expect.push(t);
        }
        assert_eq!(props, expect);

        // Reject everything past the first committed token, then
        // repropose: the truncated draft must regrow the same chain.
        st.commit(0, 1).unwrap();
        let again = st.propose(0, 99, &[7], expect[0], 2).unwrap();
        assert_eq!(again, expect[1..3].to_vec());

        // Forget frees the draft session; a later propose starts clean.
        st.forget(0);
        let fresh = st.propose(0, 99, &[7], expect[0], 2).unwrap();
        assert_eq!(fresh, expect[1..3].to_vec());
    }
}
