//! Token-keyed prefix index over the paged KV-cache arena — the lookup
//! side of copy-on-write prefix sharing.
//!
//! Motivation (the ROADMAP's "millions of users" serving story, and the
//! system-level-reuse point HPIM and PIM-AI both make): in high-traffic
//! serving, many requests share a system prompt or few-shot prefix, and
//! re-running prefill MACs over the shared part is pure waste. This
//! index maps prompt-token prefixes to chains of FULL, immutable cache
//! blocks already computed by an earlier session. An admitted request
//! adopts the matched chain read-only ([`crate::runtime::kvcache::
//! CacheArena::share_blocks`]) plus — when the match ends mid-block — a
//! copy-on-write adoption of the partially matched tail block, and its
//! prefill starts AFTER the matched positions. Because the decode step
//! is bit-deterministic, the adopted K/V bytes are exactly what cold
//! prefill would have written, so shared-prefix decode is bit-for-bit
//! identical to cold decode (`tests/prefix_equivalence.rs` enforces
//! this on both host backends).
//!
//! Structure: a radix trie whose edges are `block_len`-token groups —
//! one node per cached block, child lists kept in insertion order so
//! lookup is deterministic. Nodes pin their block in the arena
//! ([`crate::runtime::kvcache::CacheArena::pin_block`]), which keeps
//! the chain alive after the producing session retires; eviction (LRU,
//! leaf-first, driven by the [`PrefixCache::cap`] entry bound or by
//! [`PrefixCache::reclaim`] under arena pressure) unpins, returning the
//! block to the free pool once no session shares it. All bookkeeping is
//! logical (a monotonic clock, no wall time), so serving runs stay
//! reproducible.

use super::kvcache::CacheArena;
use crate::util::error::{ensure, Result};

/// Default bound on index entries (cached blocks) when the caller does
/// not size the index explicitly (`--prefix-cap 0`).
pub const DEFAULT_PREFIX_CAP: usize = 256;

/// Counters of the prefix cache's effectiveness, reported by
/// `repro serve --prefix-cache` and the edge-serving example.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PrefixStats {
    /// Adoptions that reused at least one cached position.
    pub hits: usize,
    /// Adoptions that found nothing reusable.
    pub misses: usize,
    /// Prompt positions whose prefill decode was skipped entirely.
    pub saved_tokens: usize,
    /// Blocks inserted into the index over its lifetime.
    pub insertions: usize,
    /// Entries evicted (LRU cap or arena-pressure reclaim).
    pub evictions: usize,
}

impl PrefixStats {
    /// Fold another counter set into this one — how the sharded engine
    /// merges its per-shard indices into one report. Each shard owns a
    /// private index (blocks never cross shards, so neither do pins or
    /// hits); the fleet-wide picture is the plain sum. This is the
    /// pattern [`crate::obs::MetricsSnapshot::absorb`] generalizes to
    /// the full metrics registry: sum everything, merge in ascending
    /// worker-id order so the report is byte-diffable run-to-run.
    pub fn absorb(&mut self, other: PrefixStats) {
        self.hits += other.hits;
        self.misses += other.misses;
        self.saved_tokens += other.saved_tokens;
        self.insertions += other.insertions;
        self.evictions += other.evictions;
    }

    /// One-line report for the serving CLIs.
    pub fn report(&self) -> String {
        format!(
            "prefix cache: {} hits / {} misses | {} prefill tokens saved \
             | {} blocks inserted | {} evicted",
            self.hits, self.misses, self.saved_tokens, self.insertions, self.evictions
        )
    }
}

/// Result of a prefix lookup: the chain of fully matched immutable
/// blocks, plus (optionally) a partially matched tail block and how many
/// of its leading positions matched.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PrefixMatch {
    /// Fully matched blocks, in position order — adopt via
    /// `share_blocks`, never written again.
    pub full_blocks: Vec<u32>,
    /// A block whose first `rows` positions match the prompt — adopt
    /// shared, then `cow_block(.., rows)` before the first write.
    pub tail: Option<(u32, usize)>,
    /// Total matched positions: `full_blocks.len() * block_len + rows`.
    pub positions: usize,
}

impl PrefixMatch {
    fn empty() -> Self {
        PrefixMatch {
            full_blocks: Vec::new(),
            tail: None,
            positions: 0,
        }
    }
}

/// One trie node: a cached block and the `block_len` tokens it covers.
struct Node {
    tokens: Vec<i32>,
    block: u32,
    /// Logical LRU stamp (monotonic clock, not wall time).
    last_used: u64,
    parent: usize,
    children: Vec<usize>,
}

/// The trie. Node storage is a slab with a free list; index 0 is the
/// root sentinel (no block, empty tokens).
pub struct PrefixCache {
    block_len: usize,
    /// Maximum non-root nodes (= pinned blocks) the index may hold.
    cap: usize,
    clock: u64,
    nodes: Vec<Option<Node>>,
    free_nodes: Vec<usize>,
    len: usize,
    pub stats: PrefixStats,
}

impl PrefixCache {
    /// Index over blocks of `block_len` positions, bounded at `cap`
    /// entries (`0` selects [`DEFAULT_PREFIX_CAP`]).
    pub fn new(block_len: usize, cap: usize) -> Self {
        let cap = if cap == 0 { DEFAULT_PREFIX_CAP } else { cap };
        PrefixCache {
            block_len,
            cap,
            clock: 0,
            nodes: vec![Some(Node {
                tokens: Vec::new(),
                block: u32::MAX,
                last_used: 0,
                parent: usize::MAX,
                children: Vec::new(),
            })],
            free_nodes: Vec::new(),
            len: 0,
            stats: PrefixStats::default(),
        }
    }

    /// Live entries (cached blocks) in the index.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Entry bound the index enforces.
    pub fn cap(&self) -> usize {
        self.cap
    }

    fn node(&self, i: usize) -> &Node {
        self.nodes[i].as_ref().expect("live node")
    }

    fn touch(&mut self, i: usize) {
        self.clock += 1;
        self.nodes[i].as_mut().expect("live node").last_used = self.clock;
    }

    /// Longest cached match for `prompt`, capped at `prompt.len() - 1`
    /// positions: at least the last prompt token is always decoded so
    /// the session has logits to generate from. Touches every node on
    /// the matched path (LRU). Deterministic: children are scanned in
    /// insertion order and full matches win over partial ones.
    pub fn lookup(&mut self, prompt: &[i32]) -> PrefixMatch {
        let usable = prompt.len().saturating_sub(1);
        let mut m = PrefixMatch::empty();
        let mut at = 0usize; // root
        loop {
            let covered = m.full_blocks.len() * self.block_len;
            let remaining = &prompt[covered..usable];
            // A full-block match requires a whole group inside the
            // usable window.
            let mut next = None;
            if remaining.len() >= self.block_len {
                let group = &prompt[covered..covered + self.block_len];
                next = self
                    .node(at)
                    .children
                    .iter()
                    .copied()
                    .find(|&c| self.node(c).tokens == group);
            }
            match next {
                Some(c) => {
                    self.touch(c);
                    m.full_blocks.push(self.node(c).block);
                    at = c;
                }
                None => {
                    // No full match: the best PARTIAL child match (>= 1
                    // leading token) becomes the copy-on-write tail.
                    let limit = remaining.len().min(self.block_len);
                    let mut best: Option<(usize, usize)> = None; // (node, rows)
                    for &c in &self.node(at).children {
                        let rows = self
                            .node(c)
                            .tokens
                            .iter()
                            .zip(remaining)
                            .take(limit)
                            .take_while(|(a, b)| a == b)
                            .count();
                        // Strictly-greater keeps the first (oldest
                        // insertion) on ties — deterministic.
                        if rows >= 1 && best.map_or(true, |(_, r)| rows > r) {
                            best = Some((c, rows));
                        }
                    }
                    if let Some((c, rows)) = best {
                        self.touch(c);
                        m.tail = Some((self.node(c).block, rows));
                        m.positions = m.full_blocks.len() * self.block_len + rows;
                    } else {
                        m.positions = m.full_blocks.len() * self.block_len;
                    }
                    return m;
                }
            }
        }
    }

    /// Record a finished prefill: `tokens` must cover whole blocks
    /// (`blocks.len() * block_len` tokens) that are FULLY WRITTEN in the
    /// arena — the caller (the serving loop, once a session's prefill
    /// completes) guarantees this. Existing nodes are reused (their
    /// pinned block has bitwise-identical content, decode being
    /// deterministic); new nodes pin their block. Enforces the LRU cap
    /// afterwards.
    pub fn insert(
        &mut self,
        arena: &mut CacheArena,
        tokens: &[i32],
        blocks: &[u32],
    ) -> Result<()> {
        ensure!(
            tokens.len() == blocks.len() * self.block_len,
            "prefix insert: {} tokens does not cover {} blocks of {} positions",
            tokens.len(),
            blocks.len(),
            self.block_len
        );
        let mut at = 0usize;
        for (group, &block) in tokens.chunks(self.block_len).zip(blocks) {
            let existing = self
                .node(at)
                .children
                .iter()
                .copied()
                .find(|&c| self.node(c).tokens == group);
            at = match existing {
                Some(c) => c,
                None => {
                    arena.pin_block(block)?;
                    let node = Node {
                        tokens: group.to_vec(),
                        block,
                        last_used: 0,
                        parent: at,
                        children: Vec::new(),
                    };
                    let idx = match self.free_nodes.pop() {
                        Some(i) => {
                            self.nodes[i] = Some(node);
                            i
                        }
                        None => {
                            self.nodes.push(Some(node));
                            self.nodes.len() - 1
                        }
                    };
                    self.nodes[at].as_mut().expect("live node").children.push(idx);
                    self.len += 1;
                    self.stats.insertions += 1;
                    idx
                }
            };
            self.touch(at);
        }
        self.enforce_cap(arena)
    }

    /// Evict the least-recently-used LEAF node (leaf-first keeps chains
    /// adoptable: an inner node without its children is still a valid,
    /// shorter chain, but a child without its parent would be
    /// unreachable). Returns whether anything was evicted.
    fn evict_lru_leaf(&mut self, arena: &mut CacheArena) -> Result<bool> {
        let mut victim: Option<(usize, u64)> = None;
        for (i, n) in self.nodes.iter().enumerate().skip(1) {
            if let Some(n) = n {
                if n.children.is_empty()
                    && victim.map_or(true, |(_, t)| n.last_used < t)
                {
                    victim = Some((i, n.last_used));
                }
            }
        }
        let Some((i, _)) = victim else { return Ok(false) };
        let node = self.nodes[i].take().expect("victim is live");
        let parent = self.nodes[node.parent].as_mut().expect("parent is live");
        parent.children.retain(|&c| c != i);
        self.free_nodes.push(i);
        self.len -= 1;
        self.stats.evictions += 1;
        arena.unpin_block(node.block)?;
        Ok(true)
    }

    fn enforce_cap(&mut self, arena: &mut CacheArena) -> Result<()> {
        while self.len > self.cap {
            ensure!(self.evict_lru_leaf(arena)?, "cap eviction found no leaf");
        }
        Ok(())
    }

    /// Arena-pressure reclaim: evict LRU entries (unpinning their
    /// blocks) until the arena has at least `want_free` free blocks or
    /// the index is empty. Unpinning a block still shared with a live
    /// session frees nothing immediately — the loop keeps evicting, so
    /// whatever CAN be reclaimed is. Returns blocks actually freed.
    pub fn reclaim(&mut self, arena: &mut CacheArena, want_free: usize) -> Result<usize> {
        let before = arena.status().free_blocks;
        while arena.status().free_blocks < want_free && self.len > 0 {
            self.evict_lru_leaf(arena)?;
        }
        Ok(arena.status().free_blocks - before)
    }

    /// Drop every entry, unpinning all blocks.
    pub fn clear(&mut self, arena: &mut CacheArena) -> Result<()> {
        while self.len > 0 {
            ensure!(self.evict_lru_leaf(arena)?, "clear found no leaf");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifacts::ModelInfo;
    use crate::runtime::kvcache::CacheLayout;

    const BL: usize = 4;

    fn arena(blocks: usize) -> CacheArena {
        let m = ModelInfo {
            vocab: 16,
            d: 4,
            h: 2,
            d_ff: 16,
            n_layers: 1,
            max_ctx: 32,
            eps: 1e-5,
        };
        CacheArena::new(CacheLayout::with_block_len(&m, BL), blocks).unwrap()
    }

    /// A session holding `n` fully-claimed blocks, as insertion fodder.
    fn donor(a: &mut CacheArena, n: usize) -> Vec<u32> {
        let s = a.alloc_session().unwrap();
        a.ensure_capacity(s, n * BL - 1).unwrap();
        a.session_table(s).unwrap()
    }

    #[test]
    fn lookup_matches_full_blocks_then_partial_tail() {
        let mut a = arena(8);
        let chain = donor(&mut a, 3);
        let mut pc = PrefixCache::new(BL, 0);
        let tokens: Vec<i32> = (1..=12).collect(); // 3 full groups
        pc.insert(&mut a, &tokens, &chain).unwrap();
        assert_eq!(pc.len(), 3);

        // Identical prompt, longer than the chain: all 3 blocks match.
        let mut p: Vec<i32> = (1..=14).collect();
        let m = pc.lookup(&p);
        assert_eq!(m.full_blocks, chain);
        assert_eq!(m.tail, None);
        assert_eq!(m.positions, 12);

        // Prompt diverging mid-second-block: 1 full + 2-row tail.
        p = vec![1, 2, 3, 4, 5, 6, 99, 99, 99];
        let m = pc.lookup(&p);
        assert_eq!(m.full_blocks, chain[..1]);
        assert_eq!(m.tail, Some((chain[1], 2)));
        assert_eq!(m.positions, 6);

        // No overlap at all.
        let m = pc.lookup(&[7, 7, 7, 7]);
        assert_eq!(m.positions, 0);
        assert!(m.full_blocks.is_empty() && m.tail.is_none());
    }

    #[test]
    fn lookup_always_leaves_one_token_to_decode() {
        let mut a = arena(8);
        let chain = donor(&mut a, 2);
        let mut pc = PrefixCache::new(BL, 0);
        let tokens: Vec<i32> = (1..=8).collect();
        pc.insert(&mut a, &tokens, &chain).unwrap();

        // Prompt exactly equal to the cached chain: the last position
        // must stay undecoded, so the match is 1 full block + 3 rows.
        let m = pc.lookup(&tokens);
        assert_eq!(m.full_blocks, chain[..1]);
        assert_eq!(m.tail, Some((chain[1], 3)));
        assert_eq!(m.positions, 7);

        // Prompt one past a block boundary: full block + nothing (the
        // only remaining usable token is position 4, matched... and
        // capped). prompt len 5 -> usable 4 -> exactly one full block.
        let m = pc.lookup(&tokens[..5]);
        assert_eq!(m.full_blocks, chain[..1]);
        assert_eq!(m.tail, None);
        assert_eq!(m.positions, 4);

        // Single-token and empty prompts never match.
        assert_eq!(pc.lookup(&tokens[..1]).positions, 0);
        assert_eq!(pc.lookup(&[]).positions, 0);
    }

    #[test]
    fn insert_reuses_existing_nodes_and_branches() {
        let mut a = arena(12);
        let c1 = donor(&mut a, 2);
        let mut pc = PrefixCache::new(BL, 0);
        pc.insert(&mut a, &[1, 2, 3, 4, 5, 6, 7, 8], &c1).unwrap();
        // Same first group from a different session: node reused, the
        // second group branches.
        let c2 = donor(&mut a, 2);
        pc.insert(&mut a, &[1, 2, 3, 4, 9, 9, 9, 9], &c2).unwrap();
        assert_eq!(pc.len(), 3, "shared first group must not duplicate");
        // The shared node kept the FIRST block; c2's first block is
        // unpinned (refcount back to its donor session only).
        assert_eq!(a.block_refs(c1[0]), 2); // donor + pin
        assert_eq!(a.block_refs(c2[0]), 1); // donor only
        let m = pc.lookup(&[1, 2, 3, 4, 9, 9, 9, 9, 0]);
        assert_eq!(m.full_blocks, vec![c1[0], c2[1]]);
        a.debug_validate().unwrap();
    }

    #[test]
    fn lru_cap_evicts_leaf_first_and_unpins() {
        let mut a = arena(16);
        let mut pc = PrefixCache::new(BL, 2);
        let c1 = donor(&mut a, 2);
        pc.insert(&mut a, &[1, 2, 3, 4, 5, 6, 7, 8], &c1).unwrap();
        assert_eq!(pc.len(), 2);
        // A third entry overflows the cap: the LRU LEAF goes (c1[1] — a
        // leaf and older than the new chain), never the inner node.
        let c2 = donor(&mut a, 1);
        pc.insert(&mut a, &[9, 9, 9, 9], &c2).unwrap();
        assert_eq!(pc.len(), 2);
        assert_eq!(pc.stats.evictions, 1);
        assert_eq!(a.block_refs(c1[1]), 1, "evicted entry must unpin");
        // The surviving prefix still matches.
        assert_eq!(pc.lookup(&[1, 2, 3, 4, 0]).full_blocks, vec![c1[0]]);
        assert_eq!(pc.lookup(&[9, 9, 9, 9, 0]).full_blocks, vec![c2[0]]);
        a.debug_validate().unwrap();
    }

    #[test]
    fn reclaim_frees_pinned_blocks_under_pressure() {
        let mut a = arena(4);
        let s = a.alloc_session().unwrap();
        a.ensure_capacity(s, 2 * BL - 1).unwrap();
        let chain = a.session_table(s).unwrap();
        let mut pc = PrefixCache::new(BL, 0);
        pc.insert(&mut a, &[1, 2, 3, 4, 5, 6, 7, 8], &chain).unwrap();
        // Retire the producer: blocks survive on index pins alone.
        a.free_session(s).unwrap();
        assert_eq!(a.status().free_blocks, 2);
        assert_eq!(a.status().pinned_blocks, 2);
        // Pressure for 3 free blocks: one eviction suffices.
        let freed = pc.reclaim(&mut a, 3).unwrap();
        assert_eq!(freed, 1);
        assert_eq!(pc.len(), 1);
        // Pressure for everything: the index empties.
        let freed = pc.reclaim(&mut a, 4).unwrap();
        assert_eq!(freed, 1);
        assert!(pc.is_empty());
        assert_eq!(a.status().free_blocks, 4);
        a.debug_validate().unwrap();
    }

    #[test]
    fn clear_unpins_everything() {
        let mut a = arena(8);
        let chain = donor(&mut a, 3);
        let mut pc = PrefixCache::new(BL, 0);
        pc.insert(&mut a, &(1..=12).collect::<Vec<i32>>(), &chain).unwrap();
        pc.clear(&mut a).unwrap();
        assert!(pc.is_empty());
        assert_eq!(a.status().pinned_blocks, 0);
        a.debug_validate().unwrap();
    }

    #[test]
    fn insert_arity_is_validated() {
        let mut a = arena(4);
        let chain = donor(&mut a, 1);
        let mut pc = PrefixCache::new(BL, 0);
        assert!(pc.insert(&mut a, &[1, 2, 3], &chain).is_err());
        assert!(pc.insert(&mut a, &[1, 2, 3, 4, 5], &chain).is_err());
        assert_eq!(pc.len(), 0);
    }

    #[test]
    fn stats_absorb_sums_per_shard_counters() {
        let mut merged = PrefixStats::default();
        merged.absorb(PrefixStats {
            hits: 2,
            misses: 1,
            saved_tokens: 16,
            insertions: 4,
            evictions: 0,
        });
        merged.absorb(PrefixStats {
            hits: 1,
            misses: 3,
            saved_tokens: 8,
            insertions: 2,
            evictions: 5,
        });
        assert_eq!(
            merged,
            PrefixStats {
                hits: 3,
                misses: 4,
                saved_tokens: 24,
                insertions: 6,
                evictions: 5,
            }
        );
        // A shard-local index is plain data: safe to move to a worker.
        fn assert_send<T: Send>() {}
        assert_send::<PrefixCache>();
    }
}
