//! Block-paged KV-cache arena — shared cache storage for every decode
//! session, replacing the per-session contiguous `Caches` values that
//! were moved in and out of the backends before this refactor.
//!
//! Motivation (HPIM arxiv 2509.12993, PIM-AI arxiv 2411.17309, and the
//! vLLM lineage they cite): when each session owns a private
//! `(n_layers, h, max_ctx, d_head)` tensor, concurrency is capped by the
//! WORST-CASE context length — a request that will generate 10 tokens
//! reserves the same memory as one that fills the window. Paging the
//! cache into fixed-size blocks lets the serving layer admit sessions
//! against actual usage, preempt under pressure, and reuse freed
//! capacity immediately, which is what the continuous-batching policy
//! ([`crate::serving::Policy::Continuous`]) is built on.
//!
//! Layout: one block backs [`CacheLayout::block_len`] consecutive
//! positions of ONE session across ALL layers and heads, stored
//! `(n_layers, h, block_len, d_head)` row-major — the contiguous layout
//! with `max_ctx` replaced by `block_len`. A session is a block table
//! (`Vec<u32>` of block ids, position `p` lives in table entry
//! `p / block_len` at in-block offset `p % block_len`). Within a block,
//! the rows of one `(layer, head)` pair are contiguous, so the paged
//! attention gather ([`crate::runtime::kernels::attention_paged`]) copies
//! one contiguous run per block per head — and because the gathered
//! scratch holds exactly the bytes the contiguous tensor would, the
//! attention numerics are bit-for-bit identical to the pre-paging path
//! (enforced by `tests/paged_equivalence.rs`).
//!
//! Handles ([`CacheHandle`]) are generation-checked indices: freeing a
//! session bumps its slot's generation, so stale handles (use after
//! free, double free) are rejected with an error instead of silently
//! touching another session's cache. `tests/kvcache_properties.rs`
//! churns the allocator to pin the no-leak / no-double-free / full-reuse
//! invariants.
//!
//! Sharing (the copy-on-write prefix cache): every block carries a
//! reference count — the number of live block tables it appears in plus
//! the number of prefix-index pins ([`CacheArena::pin_block`]) holding
//! it. A block returns to the free list only when its count reaches
//! zero, so [`CacheArena::free_session`] on a session that adopted
//! shared prefix blocks never hands a still-referenced block back.
//! Sessions adopt matched prefix blocks read-only via
//! [`CacheArena::share_blocks`]; before the first write into a shared
//! block it must be made exclusive with [`CacheArena::cow_block`]
//! (copy-on-write: the matched rows are copied, the rest zeroed so the
//! block is bitwise what cold prefill would have produced).
//! [`CacheArena::ensure_capacity`] performs that COW automatically for
//! the position about to be written, and [`CacheArena::write_kv`]
//! rejects writes into still-shared blocks, so a backend can never
//! corrupt another session's (or the prefix index's) cached prefix.

use crate::util::error::{anyhow, ensure, Result};

/// Storage precision of the arena's K/V pools.
///
/// * [`ArenaLayout::F32`] — the original layout, one f32 per element.
///   Bit-exact: every equivalence suite holds it to the contiguous
///   oracle, and it stays the default everywhere.
/// * [`ArenaLayout::KvInt8`] — W8 KV storage: each (block, layer, head)
///   row-group holds `block_len * d_head` int8 codes plus ONE f32
///   absmax per pool (K and V separately), quantized with the same
///   symmetric absmax rule as the activation path
///   (`kernels::act_scale` / `act_quant_int8`). ~4x more cached
///   positions per arena byte; attention over it runs through
///   [`crate::runtime::kernels::attention_paged_q8`], which accumulates
///   QK^T and PV in i32 and dequantizes only at the softmax boundary.
///   Divergence from the f32 oracle is bounded by the quantization step
///   (exact when the stored values already sit on the int8 grid).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ArenaLayout {
    F32,
    KvInt8,
}

impl ArenaLayout {
    /// CLI / report name of the layout.
    pub fn name(&self) -> &'static str {
        match self {
            ArenaLayout::F32 => "f32",
            ArenaLayout::KvInt8 => "int8",
        }
    }

    /// Parse a `--kv-quant` flag value.
    pub fn from_name(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(ArenaLayout::F32),
            "int8" => Ok(ArenaLayout::KvInt8),
            other => Err(anyhow!(
                "unknown KV quantization '{other}' (expected 'f32' or 'int8')"
            )),
        }
    }
}

/// Default number of positions per cache block (vLLM-style granularity;
/// clamped to `max_ctx` for tiny models).
pub const DEFAULT_BLOCK_LEN: usize = 16;

/// Default arena capacity, expressed in worst-case (full `max_ctx`)
/// sessions, used when the caller does not size the arena explicitly.
pub const DEFAULT_ARENA_SESSIONS: usize = 64;

/// Geometry of the paged cache: model shape plus the block granularity.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CacheLayout {
    pub n_layers: usize,
    pub h: usize,
    pub dh: usize,
    pub max_ctx: usize,
    pub block_len: usize,
}

impl CacheLayout {
    /// Layout for a model with the default block length.
    pub fn from_model(m: &super::artifacts::ModelInfo) -> Self {
        Self::with_block_len(m, DEFAULT_BLOCK_LEN)
    }

    /// Layout with an explicit block length (`0` selects the default);
    /// clamped to `[1, max_ctx]` — a block longer than the context
    /// window would only waste its tail.
    pub fn with_block_len(m: &super::artifacts::ModelInfo, block_len: usize) -> Self {
        let block_len = if block_len == 0 {
            DEFAULT_BLOCK_LEN
        } else {
            block_len
        };
        CacheLayout {
            n_layers: m.n_layers,
            h: m.h,
            dh: m.d / m.h,
            max_ctx: m.max_ctx,
            block_len: block_len.clamp(1, m.max_ctx.max(1)),
        }
    }

    /// Floats per block in EACH of the K and V pools.
    pub fn block_floats(&self) -> usize {
        self.block_len * self.n_layers * self.h * self.dh
    }

    /// Scale row-groups per block — one per (layer, head) pair, in each
    /// of the K and V pools (int8 layout only).
    pub fn block_groups(&self) -> usize {
        self.n_layers * self.h
    }

    /// Bytes one block occupies across BOTH pools in the given layout,
    /// including the int8 layout's per-group f32 scale metadata — the
    /// denominator for equal-bytes arena sizing across layouts.
    pub fn block_bytes(&self, mode: ArenaLayout) -> usize {
        match mode {
            ArenaLayout::F32 => 2 * self.block_floats() * 4,
            ArenaLayout::KvInt8 => 2 * (self.block_floats() + self.block_groups() * 4),
        }
    }

    /// Blocks a byte budget buys in the given layout (floor; >= 1 only
    /// if the budget covers a block).
    pub fn blocks_for_bytes(&self, bytes: usize, mode: ArenaLayout) -> usize {
        bytes / self.block_bytes(mode)
    }

    /// Blocks needed to back `n` positions (0 positions -> 0 blocks).
    pub fn blocks_for_positions(&self, n: usize) -> usize {
        n.div_ceil(self.block_len)
    }

    /// Blocks of one worst-case (full `max_ctx`) session.
    pub fn blocks_per_session(&self) -> usize {
        self.blocks_for_positions(self.max_ctx)
    }
}

/// Opaque, generation-checked reference to one session's cache state.
/// Obtained from [`CacheArena::alloc_session`] (via
/// `Backend::new_session` / `Engine::new_session`); every arena
/// operation validates it, so stale handles error instead of aliasing.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct CacheHandle {
    index: u32,
    generation: u32,
}

impl CacheHandle {
    /// Stable unique key of this (slot, generation) pair — used by
    /// backends that keep private per-session side state (the PJRT
    /// contiguous shim keys its device buffers by this).
    pub fn key(self) -> u64 {
        (self.index as u64) << 32 | self.generation as u64
    }
}

/// One session slot: its block table plus the generation counter that
/// invalidates outstanding handles when the slot is freed and reused.
#[derive(Debug)]
struct Slot {
    generation: u32,
    live: bool,
    table: Vec<u32>,
}

/// Point-in-time arena occupancy, for pressure-aware admission and
/// reporting.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ArenaStatus {
    pub total_blocks: usize,
    pub free_blocks: usize,
    /// Blocks referenced by at least one table or pin. A block shared by
    /// several sessions (or a session and the prefix index) counts ONCE —
    /// used + free always sums to total.
    pub used_blocks: usize,
    pub block_len: usize,
    pub live_sessions: usize,
    /// Blocks currently pinned by the prefix index (each counted once,
    /// however many pins it holds).
    pub pinned_blocks: usize,
    /// Bytes of one block in the active layout (K + V pools plus any
    /// scale metadata) — block counts are incomparable across layouts,
    /// bytes are the common denominator.
    pub block_bytes: usize,
    /// Total arena storage bytes (`total_blocks * block_bytes`).
    pub total_bytes: usize,
    /// Bytes backing referenced blocks (`used_blocks * block_bytes`).
    pub used_bytes: usize,
}

/// The shared block-paged KV-cache pool. K and V live in two flat f32
/// pools of `capacity_blocks * block_floats` each; a free list hands
/// out block ids LIFO (deterministic given a deterministic operation
/// sequence, which keeps serving runs reproducible).
pub struct CacheArena {
    layout: CacheLayout,
    /// Storage precision of the pools below (fixed at construction).
    mode: ArenaLayout,
    capacity_blocks: usize,
    /// f32-layout pools (empty in int8 mode).
    k: Vec<f32>,
    v: Vec<f32>,
    /// int8-layout pools (empty in f32 mode): `capacity * block_floats`
    /// codes each, plus one f32 absmax per (block, layer, head)
    /// row-group per pool. The scale of a group is derived from its
    /// absmax exactly like the activation path
    /// (`127.0 / absmax.max(1e-5)`), so K/V rows quantize under the
    /// same rule as every int8 activation in the decode step.
    k8: Vec<i8>,
    v8: Vec<i8>,
    k_amax: Vec<f32>,
    v_amax: Vec<f32>,
    /// Free block ids, popped from the back.
    free: Vec<u32>,
    /// Per-block reference count: table occurrences across live slots
    /// plus prefix-index pins. 0 == the block is in the free list.
    refs: Vec<u32>,
    /// Per-block prefix-index pin count (a subset of `refs`, tracked
    /// separately so `debug_validate` can balance the refcount equation
    /// and `obtainable_with` can treat pins as reclaimable).
    pins: Vec<u32>,
    slots: Vec<Slot>,
    /// Indices of dead slots available for reuse.
    free_slots: Vec<u32>,
    /// Lifetime count of copy-on-write block copies ([`Self::cow_block`]
    /// returning true) — the observability layer reads per-tick deltas
    /// off this to attribute COW traffic without hooking the write path.
    cow_copies: u64,
}

impl CacheArena {
    /// Arena with an explicit block capacity (`>= 1`) in the default
    /// (f32, bit-exact) layout.
    pub fn new(layout: CacheLayout, capacity_blocks: usize) -> Result<Self> {
        Self::new_with_mode(layout, capacity_blocks, ArenaLayout::F32)
    }

    /// Arena with an explicit block capacity and storage layout.
    pub fn new_with_mode(
        layout: CacheLayout,
        capacity_blocks: usize,
        mode: ArenaLayout,
    ) -> Result<Self> {
        ensure!(capacity_blocks >= 1, "arena needs at least one block");
        ensure!(
            layout.block_floats() > 0,
            "degenerate cache layout {layout:?}"
        );
        let bf = layout.block_floats();
        let bg = layout.block_groups();
        let (fpool, qpool, spool) = match mode {
            ArenaLayout::F32 => (capacity_blocks * bf, 0, 0),
            ArenaLayout::KvInt8 => (0, capacity_blocks * bf, capacity_blocks * bg),
        };
        Ok(Self {
            k: vec![0.0; fpool],
            v: vec![0.0; fpool],
            k8: vec![0; qpool],
            v8: vec![0; qpool],
            k_amax: vec![0.0; spool],
            v_amax: vec![0.0; spool],
            // Reversed so blocks are first handed out in 0, 1, 2... order.
            free: (0..capacity_blocks as u32).rev().collect(),
            refs: vec![0; capacity_blocks],
            pins: vec![0; capacity_blocks],
            layout,
            mode,
            capacity_blocks,
            slots: Vec::new(),
            free_slots: Vec::new(),
            cow_copies: 0,
        })
    }

    /// Arena sized for `sessions` worst-case (full-context) sessions
    /// (`0` selects [`DEFAULT_ARENA_SESSIONS`]).
    pub fn with_sessions(layout: CacheLayout, sessions: usize) -> Result<Self> {
        Self::with_sessions_mode(layout, sessions, ArenaLayout::F32)
    }

    /// [`Self::with_sessions`] with an explicit storage layout.
    pub fn with_sessions_mode(
        layout: CacheLayout,
        sessions: usize,
        mode: ArenaLayout,
    ) -> Result<Self> {
        let sessions = if sessions == 0 {
            DEFAULT_ARENA_SESSIONS
        } else {
            sessions
        };
        let blocks = layout.blocks_per_session().max(1) * sessions;
        Self::new_with_mode(layout, blocks, mode)
    }

    /// Partition `total_blocks` of capacity into `shards` independent
    /// arenas — the storage layer of the sharded serving engine. Each
    /// shard is a self-contained [`CacheArena`] (own K/V storage, free
    /// list, refcounts, slots), so a shard is `Send` and can be owned
    /// exclusively by one worker thread with no locking; block indices
    /// are shard-local and COW refcounts never cross a shard boundary.
    ///
    /// The split is deterministic: every shard gets
    /// `total_blocks / shards` blocks and the remainder goes to the
    /// lowest shard ids, so equal `total_blocks` always produces the
    /// same partition. Per-shard accounting is checked by calling
    /// [`CacheArena::debug_validate`] on each returned arena.
    pub fn split(layout: CacheLayout, total_blocks: usize, shards: usize) -> Result<Vec<Self>> {
        Self::split_mode(layout, total_blocks, shards, ArenaLayout::F32)
    }

    /// [`Self::split`] with an explicit storage layout — every shard
    /// inherits the same mode (a fleet never mixes precisions).
    pub fn split_mode(
        layout: CacheLayout,
        total_blocks: usize,
        shards: usize,
        mode: ArenaLayout,
    ) -> Result<Vec<Self>> {
        ensure!(shards >= 1, "need at least one shard");
        ensure!(
            total_blocks >= shards,
            "cannot split {total_blocks} blocks into {shards} shards (each shard needs >= 1 block)"
        );
        let base = total_blocks / shards;
        let rem = total_blocks % shards;
        (0..shards)
            .map(|i| Self::new_with_mode(layout.clone(), base + usize::from(i < rem), mode))
            .collect()
    }

    pub fn layout(&self) -> &CacheLayout {
        &self.layout
    }

    /// Storage precision of this arena's pools.
    pub fn mode(&self) -> ArenaLayout {
        self.mode
    }

    /// Lifetime copy-on-write block copies (monotonic; never reset).
    pub fn cow_copies(&self) -> u64 {
        self.cow_copies
    }

    pub fn status(&self) -> ArenaStatus {
        let total = self.capacity_blocks;
        let used = total - self.free.len();
        let bb = self.layout.block_bytes(self.mode);
        ArenaStatus {
            total_blocks: total,
            free_blocks: self.free.len(),
            used_blocks: used,
            block_len: self.layout.block_len,
            live_sessions: self.slots.iter().filter(|s| s.live).count(),
            pinned_blocks: self.pins.iter().filter(|&&p| p > 0).count(),
            block_bytes: bb,
            total_bytes: total * bb,
            used_bytes: used * bb,
        }
    }

    fn slot(&self, h: CacheHandle) -> Result<&Slot> {
        let s = self
            .slots
            .get(h.index as usize)
            .ok_or_else(|| anyhow!("unknown cache handle {h:?}"))?;
        ensure!(
            s.live && s.generation == h.generation,
            "stale cache handle {h:?} (session freed)"
        );
        Ok(s)
    }

    fn slot_mut(&mut self, h: CacheHandle) -> Result<&mut Slot> {
        let s = self
            .slots
            .get_mut(h.index as usize)
            .ok_or_else(|| anyhow!("unknown cache handle {h:?}"))?;
        ensure!(
            s.live && s.generation == h.generation,
            "stale cache handle {h:?} (session freed)"
        );
        Ok(s)
    }

    /// Whether `h` refers to a live session.
    pub fn is_live(&self, h: CacheHandle) -> bool {
        self.slot(h).is_ok()
    }

    /// Open a session with an empty block table. Never fails for lack
    /// of blocks — blocks are claimed lazily by [`Self::ensure_capacity`].
    pub fn alloc_session(&mut self) -> Result<CacheHandle> {
        if let Some(i) = self.free_slots.pop() {
            let s = &mut self.slots[i as usize];
            debug_assert!(!s.live && s.table.is_empty());
            s.live = true;
            Ok(CacheHandle {
                index: i,
                generation: s.generation,
            })
        } else {
            ensure!(
                self.slots.len() < u32::MAX as usize,
                "session slot space exhausted"
            );
            self.slots.push(Slot {
                generation: 0,
                live: true,
                table: Vec::new(),
            });
            Ok(CacheHandle {
                index: (self.slots.len() - 1) as u32,
                generation: 0,
            })
        }
    }

    /// Free a session: release its references and invalidate the handle
    /// (the slot's generation is bumped, so a retained copy of `h`
    /// errors from now on). A block returns to the free pool only when
    /// this was its LAST reference — blocks shared with another session
    /// or pinned by the prefix index stay allocated, which is what makes
    /// preempting a prefix-sharing session safe. Eviction and normal
    /// retirement are the same operation — an evicted session is simply
    /// re-prefilled into a fresh session later, which is deterministic.
    pub fn free_session(&mut self, h: CacheHandle) -> Result<()> {
        self.slot(h)?; // validate first so `free` is untouched on error
        let s = &mut self.slots[h.index as usize];
        let table = std::mem::take(&mut s.table);
        s.live = false;
        s.generation = s.generation.wrapping_add(1);
        self.free_slots.push(h.index);
        for b in table {
            self.release_ref(b);
        }
        Ok(())
    }

    /// Truncate a session's block table to what `keep_positions` fed
    /// positions need, releasing every trailing block reference — the
    /// rollback primitive speculative decoding uses to drop the cache
    /// blocks claimed for rejected draft tokens. Only whole trailing
    /// blocks are released; rows past `keep_positions` inside the kept
    /// boundary block stay in storage, which is safe on the f32 layout
    /// because attention at position `p` reads rows `0..=p` only and a
    /// later feed at those positions overwrites the full row before it
    /// is ever read. (The int8 layout has no such guarantee — writing a
    /// row can rescale earlier codes in its group in place — so the
    /// speculative verify path never writes rejected rows there in the
    /// first place.) A shared trailing block merely loses this
    /// session's reference; `keep_positions` covering the whole table
    /// is a no-op.
    pub fn truncate_session(&mut self, h: CacheHandle, keep_positions: usize) -> Result<()> {
        self.slot(h)?; // validate first so the table is untouched on error
        let keep_blocks = self.layout.blocks_for_positions(keep_positions);
        let s = &mut self.slots[h.index as usize];
        if keep_blocks >= s.table.len() {
            return Ok(());
        }
        let trailing = s.table.split_off(keep_blocks);
        for b in trailing {
            self.release_ref(b);
        }
        Ok(())
    }

    /// Drop one reference to `b`, returning it to the free list at zero.
    fn release_ref(&mut self, b: u32) {
        debug_assert!(self.refs[b as usize] > 0, "releasing unowned block {b}");
        self.refs[b as usize] -= 1;
        if self.refs[b as usize] == 0 {
            self.free.push(b);
        }
    }

    /// Pop a free block, zero its storage, and give it one reference.
    /// Returns `None` when the pool is dry (callers report their own
    /// context-rich errors).
    fn claim_zeroed(&mut self) -> Option<u32> {
        let b = self.free.pop()?;
        let bf = self.layout.block_floats();
        let base = b as usize * bf;
        match self.mode {
            ArenaLayout::F32 => {
                self.k[base..base + bf].fill(0.0);
                self.v[base..base + bf].fill(0.0);
            }
            ArenaLayout::KvInt8 => {
                self.k8[base..base + bf].fill(0);
                self.v8[base..base + bf].fill(0);
                let bg = self.layout.block_groups();
                let gbase = b as usize * bg;
                self.k_amax[gbase..gbase + bg].fill(0.0);
                self.v_amax[gbase..gbase + bg].fill(0.0);
            }
        }
        debug_assert_eq!(self.refs[b as usize], 0);
        self.refs[b as usize] = 1;
        Some(b)
    }

    /// Ensure the session can WRITE position `pos` (with everything
    /// before it backed): claims zeroed blocks from the free list as
    /// needed, and — if the block containing `pos` is shared (adopted
    /// from the prefix cache) — copies it on write
    /// ([`Self::cow_block`] with the rows before `pos` kept), so the
    /// caller's subsequent [`Self::write_kv`] lands in an exclusive
    /// block. All-or-nothing: if the pool cannot cover the full need
    /// (new blocks plus a possible COW copy), an error is returned and
    /// NOTHING is claimed — the session's table and the free list are
    /// untouched, so the serving layer can turn the pressure into
    /// preemption and simply retry.
    pub fn ensure_capacity(&mut self, h: CacheHandle, pos: usize) -> Result<()> {
        ensure!(
            pos < self.layout.max_ctx,
            "position {pos} >= max_ctx {}",
            self.layout.max_ctx
        );
        let block_len = self.layout.block_len;
        let target = pos / block_len + 1;
        let held = self.slot(h)?.table.len();
        if target <= held {
            // Block exists; make it exclusive if a prefix share still
            // holds it (the COW consumes one free block, checked inside).
            self.cow_block(h, pos / block_len, pos % block_len)?;
            return Ok(());
        }
        let needed = target - held;
        if self.free.len() < needed {
            let st = self.status();
            crate::bail!(
                "KV arena out of blocks (need {needed}, {} free of {} total, \
                 {} sessions live) — raise the arena capacity or use the \
                 continuous policy's preemption",
                st.free_blocks,
                st.total_blocks,
                st.live_sessions
            );
        }
        for _ in 0..needed {
            let b = self.claim_zeroed().expect("count checked above");
            self.slots[h.index as usize].table.push(b);
        }
        Ok(())
    }

    /// Adopt already-populated blocks into the session's table, read
    /// only: each block's reference count is incremented and it is
    /// appended to the table in order (backing the positions after the
    /// session's current end). The blocks keep their contents — this is
    /// how a session inherits a matched prompt prefix without re-running
    /// a single MAC. Writing into a shared block requires
    /// [`Self::cow_block`] first ([`Self::ensure_capacity`] does it
    /// automatically; [`Self::write_kv`] rejects the write otherwise).
    /// All-or-nothing: validation happens before any refcount changes.
    pub fn share_blocks(&mut self, h: CacheHandle, blocks: &[u32]) -> Result<()> {
        let total = self.refs.len();
        let slot = self.slot(h)?;
        for (n, &b) in blocks.iter().enumerate() {
            ensure!((b as usize) < total, "shared block {b} out of range");
            ensure!(
                self.refs[b as usize] > 0,
                "cannot share free block {b} (no live content)"
            );
            ensure!(
                !slot.table.contains(&b) && !blocks[..n].contains(&b),
                "block {b} already in the session's table"
            );
        }
        for &b in blocks {
            self.refs[b as usize] += 1;
            self.slots[h.index as usize].table.push(b);
        }
        Ok(())
    }

    /// Make table entry `block_idx` exclusive to the session via copy on
    /// write: if the block is shared (refcount > 1), a fresh block is
    /// claimed, the first `keep_rows` positions of every (layer, head)
    /// pair are copied, the remaining rows are zeroed (bitwise what cold
    /// prefill would hold there), and the table entry is repointed —
    /// the donor keeps its copy untouched. Exclusive blocks are left
    /// alone. Returns whether a copy happened.
    pub fn cow_block(
        &mut self,
        h: CacheHandle,
        block_idx: usize,
        keep_rows: usize,
    ) -> Result<bool> {
        let l = self.layout.clone();
        ensure!(
            keep_rows <= l.block_len,
            "keep_rows {keep_rows} > block_len {}",
            l.block_len
        );
        let slot = self.slot(h)?;
        let Some(&old) = slot.table.get(block_idx) else {
            crate::bail!(
                "cow_block: table entry {block_idx} out of range (len {})",
                slot.table.len()
            );
        };
        if self.refs[old as usize] == 1 {
            return Ok(false); // already exclusive
        }
        let Some(fresh) = self.claim_zeroed() else {
            let st = self.status();
            crate::bail!(
                "KV arena out of blocks for a prefix copy-on-write \
                 ({} free of {} total) — raise the arena capacity or use \
                 the continuous policy's preemption",
                st.free_blocks,
                st.total_blocks
            );
        };
        let bf = l.block_floats();
        let (ob, nb) = (old as usize * bf, fresh as usize * bf);
        for lh in 0..l.block_groups() {
            let off = lh * l.block_len * l.dh;
            let n = keep_rows * l.dh;
            match self.mode {
                ArenaLayout::F32 => {
                    self.k.copy_within(ob + off..ob + off + n, nb + off);
                    self.v.copy_within(ob + off..ob + off + n, nb + off);
                }
                // int8: copy the codes AND the group scales verbatim, so
                // the adopter dequantizes the kept rows to exactly the
                // donor's values (the zeroed tail dequantizes to 0 under
                // any scale).
                ArenaLayout::KvInt8 => {
                    self.k8.copy_within(ob + off..ob + off + n, nb + off);
                    self.v8.copy_within(ob + off..ob + off + n, nb + off);
                }
            }
        }
        if self.mode == ArenaLayout::KvInt8 {
            let bg = l.block_groups();
            let (og, ng) = (old as usize * bg, fresh as usize * bg);
            self.k_amax.copy_within(og..og + bg, ng);
            self.v_amax.copy_within(og..og + bg, ng);
        }
        self.slots[h.index as usize].table[block_idx] = fresh;
        self.release_ref(old);
        self.cow_copies += 1;
        Ok(true)
    }

    /// Add a prefix-index pin to `b`, keeping it alive independent of
    /// any session table. The block must currently be live (referenced).
    pub fn pin_block(&mut self, b: u32) -> Result<()> {
        ensure!((b as usize) < self.refs.len(), "pin: block {b} out of range");
        ensure!(
            self.refs[b as usize] > 0,
            "cannot pin free block {b} (no live content)"
        );
        self.refs[b as usize] += 1;
        self.pins[b as usize] += 1;
        Ok(())
    }

    /// Drop one prefix-index pin from `b`; the block returns to the
    /// free pool if this was its last reference.
    pub fn unpin_block(&mut self, b: u32) -> Result<()> {
        ensure!((b as usize) < self.refs.len(), "unpin: block {b} out of range");
        ensure!(self.pins[b as usize] > 0, "block {b} is not pinned");
        self.pins[b as usize] -= 1;
        self.release_ref(b);
        Ok(())
    }

    /// Reference count of one block (0 = free). Test/diagnostic surface.
    pub fn block_refs(&self, b: u32) -> u32 {
        self.refs.get(b as usize).copied().unwrap_or(0)
    }

    /// The session's block table (ids in position order) — what the
    /// prefix index records for a finished prefill.
    pub fn session_table(&self, h: CacheHandle) -> Result<Vec<u32>> {
        Ok(self.slot(h)?.table.clone())
    }

    /// Blocks a serving loop could EVER obtain for a new request: the
    /// free list plus every block whose references are entirely held by
    /// the given sessions and/or prefix-index pins (freeing those
    /// sessions and reclaiming the index would release it). Blocks also
    /// referenced by a session OUTSIDE `handles` are not counted — they
    /// are never coming back to this loop. Shared blocks are counted
    /// once, so this never overstates capacity the way summing
    /// per-session table lengths would.
    pub fn obtainable_with(&self, handles: &[CacheHandle]) -> usize {
        let mut counted = vec![0u32; self.refs.len()];
        for &h in handles {
            if let Ok(slot) = self.slot(h) {
                for &b in &slot.table {
                    counted[b as usize] += 1;
                }
            }
        }
        let reclaimable = self
            .refs
            .iter()
            .zip(counted.iter().zip(&self.pins))
            .filter(|(&r, (&c, &p))| r > 0 && r == c + p)
            .count();
        self.free.len() + reclaimable
    }

    /// Blocks currently held by the session.
    pub fn session_blocks(&self, h: CacheHandle) -> Result<usize> {
        Ok(self.slot(h)?.table.len())
    }

    /// Write one token's K/V rows (all heads of one layer, `h * dh`
    /// floats each) at `pos`. The backing block must already exist
    /// ([`Self::ensure_capacity`]); positions are written in place, so
    /// re-running a step overwrites deterministically.
    pub fn write_kv(
        &mut self,
        h: CacheHandle,
        layer: usize,
        pos: usize,
        k_row: &[f32],
        v_row: &[f32],
    ) -> Result<()> {
        let l = self.layout.clone();
        ensure!(layer < l.n_layers, "layer {layer} out of range");
        ensure!(pos < l.max_ctx, "position {pos} >= max_ctx {}", l.max_ctx);
        ensure!(
            k_row.len() == l.h * l.dh && v_row.len() == l.h * l.dh,
            "K/V row length {} != h*dh {}",
            k_row.len(),
            l.h * l.dh
        );
        let slot = self.slot_mut(h)?;
        let bi = pos / l.block_len;
        let Some(&block) = slot.table.get(bi) else {
            crate::bail!("position {pos} not backed by a block (table len {})", slot.table.len());
        };
        ensure!(
            self.refs[block as usize] == 1,
            "write at position {pos} targets shared block {block} \
             (refcount {}) — copy-on-write required first (ensure_capacity \
             does this); writing would corrupt another session's prefix",
            self.refs[block as usize]
        );
        let pib = pos % l.block_len;
        let bf = l.block_floats();
        for head in 0..l.h {
            let dst = block as usize * bf + ((layer * l.h + head) * l.block_len + pib) * l.dh;
            let ks = &k_row[head * l.dh..(head + 1) * l.dh];
            let vs = &v_row[head * l.dh..(head + 1) * l.dh];
            match self.mode {
                ArenaLayout::F32 => {
                    self.k[dst..dst + l.dh].copy_from_slice(ks);
                    self.v[dst..dst + l.dh].copy_from_slice(vs);
                }
                ArenaLayout::KvInt8 => {
                    let g = block as usize * l.block_groups() + layer * l.h + head;
                    let gbase = block as usize * bf + (layer * l.h + head) * l.block_len * l.dh;
                    let rows = l.block_len * l.dh;
                    quantize_row_into_group(
                        ks,
                        &mut self.k8[gbase..gbase + rows],
                        &mut self.k_amax[g],
                        pib * l.dh,
                    );
                    quantize_row_into_group(
                        vs,
                        &mut self.v8[gbase..gbase + rows],
                        &mut self.v_amax[g],
                        pib * l.dh,
                    );
                }
            }
        }
        Ok(())
    }

    /// Read-only paged view of one session, for the attention gather.
    pub fn view(&self, h: CacheHandle) -> Result<PagedKv<'_>> {
        let slot = self.slot(h)?;
        Ok(PagedKv {
            k: &self.k,
            v: &self.v,
            k8: &self.k8,
            v8: &self.v8,
            k_amax: &self.k_amax,
            v_amax: &self.v_amax,
            mode: self.mode,
            table: &slot.table,
            layout: &self.layout,
        })
    }

    /// Reassemble the session's cache as the contiguous
    /// `(n_layers, h, max_ctx, d_head)` tensors the pre-paging backends
    /// produced (unbacked positions read as zero — exactly what fresh
    /// contiguous caches held). Used by the equivalence tests to compare
    /// paged state against the contiguous oracle bit for bit.
    pub fn gather_contiguous(&self, h: CacheHandle) -> Result<(Vec<f32>, Vec<f32>)> {
        let slot = self.slot(h)?;
        let l = &self.layout;
        let numel = l.n_layers * l.h * l.max_ctx * l.dh;
        let (mut kc, mut vc) = (vec![0.0f32; numel], vec![0.0f32; numel]);
        let bf = l.block_floats();
        for (bi, &block) in slot.table.iter().enumerate() {
            let pos0 = bi * l.block_len;
            let rows = l.block_len.min(l.max_ctx - pos0);
            for layer in 0..l.n_layers {
                for head in 0..l.h {
                    let src = block as usize * bf + ((layer * l.h + head) * l.block_len) * l.dh;
                    let dst = ((layer * l.h + head) * l.max_ctx + pos0) * l.dh;
                    match self.mode {
                        ArenaLayout::F32 => {
                            kc[dst..dst + rows * l.dh]
                                .copy_from_slice(&self.k[src..src + rows * l.dh]);
                            vc[dst..dst + rows * l.dh]
                                .copy_from_slice(&self.v[src..src + rows * l.dh]);
                        }
                        // int8: dequantize through the group scale — the
                        // contiguous reconstruction is the cache "as the
                        // attention kernel sees it".
                        ArenaLayout::KvInt8 => {
                            let g = block as usize * l.block_groups() + layer * l.h + head;
                            dequant_into(
                                &self.k8[src..src + rows * l.dh],
                                self.k_amax[g],
                                &mut kc[dst..dst + rows * l.dh],
                            );
                            dequant_into(
                                &self.v8[src..src + rows * l.dh],
                                self.v_amax[g],
                                &mut vc[dst..dst + rows * l.dh],
                            );
                        }
                    }
                }
            }
        }
        Ok((kc, vc))
    }

    /// Full-arena invariant check, for the property tests: refcount
    /// accounting must balance — every block's reference count equals
    /// its table occurrences across live slots plus its prefix-index
    /// pins, blocks with zero references sit in the free list exactly
    /// once, referenced blocks are never in the free list, dead slots
    /// hold nothing, and every table entry is a valid block id.
    pub fn debug_validate(&self) -> Result<()> {
        let total = self.capacity_blocks;
        let mut in_free = vec![0u32; total];
        for &b in &self.free {
            ensure!((b as usize) < total, "free list holds bogus block {b}");
            in_free[b as usize] += 1;
        }
        let mut occurrences = vec![0u32; total];
        for (i, s) in self.slots.iter().enumerate() {
            ensure!(
                s.live || s.table.is_empty(),
                "dead slot {i} still owns blocks"
            );
            for &b in &s.table {
                ensure!((b as usize) < total, "slot {i} holds bogus block {b}");
                occurrences[b as usize] += 1;
            }
        }
        for b in 0..total {
            let (r, t, p, f) = (self.refs[b], occurrences[b], self.pins[b], in_free[b]);
            ensure!(
                r == t + p,
                "block {b}: refcount {r} != {t} table occurrences + {p} pins"
            );
            if r == 0 {
                ensure!(f == 1, "free block {b} in free list {f} times (expect 1)");
            } else {
                ensure!(f == 0, "referenced block {b} (refcount {r}) in free list");
            }
        }
        Ok(())
    }
}

/// Scale of a K/V row-group with the given absmax — the same symmetric
/// absmax rule the activation path uses (`kernels::act_scale`).
#[inline]
fn group_scale(amax: f32) -> f32 {
    127.0 / amax.max(1e-5)
}

/// Quantize one `dh`-float row into its (block, layer, head) group at
/// code offset `at`. If the row's absmax exceeds the group's, the codes
/// already stored are requantized under the grown scale first
/// (`q' = round(q * s_new / s_old)`) so the whole group keeps ONE
/// scale; the rescale costs at most ~1.5 quantization steps of the new
/// (coarser) grid per element, on top of the step the original
/// quantization already paid.
fn quantize_row_into_group(row: &[f32], codes: &mut [i8], amax: &mut f32, at: usize) {
    let row_amax = row.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
    if row_amax > *amax {
        let ratio = amax.max(1e-5) / row_amax.max(1e-5);
        for c in codes.iter_mut() {
            *c = (f32::from(*c) * ratio).round().clamp(-128.0, 127.0) as i8;
        }
        *amax = row_amax;
    }
    let s = group_scale(*amax);
    for (dst, &x) in codes[at..at + row.len()].iter_mut().zip(row) {
        *dst = (x * s).round().clamp(-128.0, 127.0) as i8;
    }
}

/// Dequantize a run of group codes through the group's absmax.
fn dequant_into(codes: &[i8], amax: f32, out: &mut [f32]) {
    let inv = 1.0 / group_scale(amax);
    for (o, &c) in out.iter_mut().zip(codes) {
        *o = f32::from(c) * inv;
    }
}

/// Borrowed paged view of one session's K/V state: the block table plus
/// the shared pools. [`crate::runtime::kernels::attention_paged`] reads
/// through this in the f32 layout;
/// [`crate::runtime::kernels::attention_paged_q8`] walks the int8
/// blocks in place via [`PagedKv::for_each_block_q8`].
pub struct PagedKv<'a> {
    k: &'a [f32],
    v: &'a [f32],
    k8: &'a [i8],
    v8: &'a [i8],
    k_amax: &'a [f32],
    v_amax: &'a [f32],
    mode: ArenaLayout,
    table: &'a [u32],
    layout: &'a CacheLayout,
}

impl PagedKv<'_> {
    pub fn heads(&self) -> usize {
        self.layout.h
    }

    pub fn head_dim(&self) -> usize {
        self.layout.dh
    }

    /// Storage precision of the pools behind this view — the attention
    /// dispatch point in both host backends branches on this.
    pub fn mode(&self) -> ArenaLayout {
        self.mode
    }

    /// Positions-per-block granularity of the backing arena.
    pub fn block_len(&self) -> usize {
        self.layout.block_len
    }

    /// Visit the int8 codes of one `(layer, head)` pair block by block,
    /// in position order, WITHOUT copying: the callback receives the
    /// K and V code rows of each block (`rows * d_head` codes, `rows <=
    /// block_len`) plus the block's K and V group absmax. This is the
    /// zero-copy gather of the q8 attention path — the kernel
    /// accumulates straight out of the pool and dequantizes per group.
    /// Panics (like [`Self::gather_head`]) if the table backs fewer
    /// than `valid` positions.
    pub fn for_each_block_q8(
        &self,
        layer: usize,
        head: usize,
        valid: usize,
        mut f: impl FnMut(&[i8], &[i8], f32, f32, usize),
    ) {
        debug_assert_eq!(self.mode, ArenaLayout::KvInt8);
        let l = self.layout;
        let bf = l.block_floats();
        let bg = l.block_groups();
        let mut row = 0usize;
        for &block in self.table {
            if row >= valid {
                break;
            }
            let rows = (valid - row).min(l.block_len);
            let base = block as usize * bf + ((layer * l.h + head) * l.block_len) * l.dh;
            let g = block as usize * bg + layer * l.h + head;
            f(
                &self.k8[base..base + rows * l.dh],
                &self.v8[base..base + rows * l.dh],
                self.k_amax[g],
                self.v_amax[g],
                rows,
            );
            row += rows;
        }
        assert_eq!(
            row, valid,
            "paged q8 gather: table backs {row} of {valid} positions"
        );
    }

    /// Gather the first `valid` positions of one `(layer, head)` pair
    /// into contiguous scratch — exactly the bytes the contiguous
    /// `(n_layers, h, max_ctx, d_head)` tensor holds at
    /// `[layer, head, 0..valid, :]`, so running the attention math on
    /// the gathered scratch is bit-for-bit the contiguous computation.
    /// One contiguous copy per block (the per-`(layer, head)` rows of a
    /// block are adjacent by layout).
    pub fn gather_head(
        &self,
        layer: usize,
        head: usize,
        valid: usize,
        out_k: &mut Vec<f32>,
        out_v: &mut Vec<f32>,
    ) {
        let l = self.layout;
        out_k.clear();
        out_v.clear();
        let bf = l.block_floats();
        let bg = l.block_groups();
        let mut row = 0usize;
        for &block in self.table {
            if row >= valid {
                break;
            }
            let rows = (valid - row).min(l.block_len);
            let base = block as usize * bf + ((layer * l.h + head) * l.block_len) * l.dh;
            match self.mode {
                ArenaLayout::F32 => {
                    out_k.extend_from_slice(&self.k[base..base + rows * l.dh]);
                    out_v.extend_from_slice(&self.v[base..base + rows * l.dh]);
                }
                // int8: dequantize through the group scales — callers of
                // the f32 gather see the cache as the q8 kernel values it.
                ArenaLayout::KvInt8 => {
                    let g = block as usize * bg + layer * l.h + head;
                    let n = rows * l.dh;
                    out_k.resize(row * l.dh + n, 0.0);
                    out_v.resize(row * l.dh + n, 0.0);
                    dequant_into(
                        &self.k8[base..base + n],
                        self.k_amax[g],
                        &mut out_k[row * l.dh..],
                    );
                    dequant_into(
                        &self.v8[base..base + n],
                        self.v_amax[g],
                        &mut out_v[row * l.dh..],
                    );
                }
            }
            row += rows;
        }
        // A short gather means a caller skipped ensure_capacity — that
        // is a backend bug, and silently attending over fewer positions
        // would corrupt outputs, so fail loudly even in release builds.
        assert_eq!(
            row, valid,
            "paged gather: table backs {row} of {valid} positions"
        );
    }
}

/// Reject duplicate handles in one batched call: two lanes advancing
/// the same session in a single step would alias its cache writes.
pub fn ensure_distinct(handles: &[CacheHandle]) -> Result<()> {
    for (n, h) in handles.iter().enumerate() {
        ensure!(
            !handles[..n].contains(h),
            "cache handle {h:?} listed twice in one batch"
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifacts::ModelInfo;

    // d = 4 with h = 2 heads -> dh = 2, so K/V rows are 4 floats.
    fn model() -> ModelInfo {
        ModelInfo {
            vocab: 16,
            d: 4,
            h: 2,
            d_ff: 16,
            n_layers: 2,
            max_ctx: 10,
            eps: 1e-5,
        }
    }

    fn layout(block_len: usize) -> CacheLayout {
        CacheLayout::with_block_len(&model(), block_len)
    }

    #[test]
    fn layout_math() {
        let l = layout(4);
        // block_len * n_layers * h * dh
        assert_eq!(l.block_floats(), 4 * 2 * 2 * 2);
        assert_eq!(l.blocks_for_positions(0), 0);
        assert_eq!(l.blocks_for_positions(1), 1);
        assert_eq!(l.blocks_for_positions(4), 1);
        assert_eq!(l.blocks_for_positions(5), 2);
        assert_eq!(l.blocks_per_session(), 3); // ceil(10 / 4)
        // Block length is clamped to the context window; 0 = default.
        assert_eq!(layout(64).block_len, 10);
        assert_eq!(layout(0).block_len, DEFAULT_BLOCK_LEN.min(10));
    }

    #[test]
    fn alloc_write_gather_round_trip() {
        let mut a = CacheArena::new(layout(4), 6).unwrap();
        let h = a.alloc_session().unwrap();
        for pos in 0..7usize {
            a.ensure_capacity(h, pos).unwrap();
            let k: Vec<f32> = (0..4).map(|i| (pos * 10 + i) as f32).collect();
            let v: Vec<f32> = k.iter().map(|x| -x).collect();
            a.write_kv(h, 1, pos, &k, &v).unwrap();
        }
        assert_eq!(a.session_blocks(h).unwrap(), 2);
        // The paged view gathers exactly the contiguous bytes.
        let view = a.view(h).unwrap();
        let (mut gk, mut gv) = (Vec::new(), Vec::new());
        view.gather_head(1, 1, 7, &mut gk, &mut gv);
        let expect: Vec<f32> = (0..7)
            .flat_map(|p| [(p * 10 + 2) as f32, (p * 10 + 3) as f32])
            .collect();
        assert_eq!(gk, expect);
        assert_eq!(gv, expect.iter().map(|x| -x).collect::<Vec<_>>());
        // Layer 0 was never written: all zero.
        view.gather_head(0, 0, 7, &mut gk, &mut gv);
        assert!(gk.iter().all(|&x| x == 0.0));
        a.debug_validate().unwrap();
    }

    #[test]
    fn gather_contiguous_matches_dense_indexing() {
        let l = layout(3);
        let mut a = CacheArena::new(l.clone(), 8).unwrap();
        let h = a.alloc_session().unwrap();
        let mut dense_k = vec![0.0f32; l.n_layers * l.h * l.max_ctx * l.dh];
        let mut dense_v = dense_k.clone();
        for pos in 0..l.max_ctx {
            a.ensure_capacity(h, pos).unwrap();
            for layer in 0..l.n_layers {
                let row: Vec<f32> = (0..l.h * l.dh)
                    .map(|i| (layer * 1000 + pos * 10 + i) as f32)
                    .collect();
                let neg: Vec<f32> = row.iter().map(|x| -x).collect();
                a.write_kv(h, layer, pos, &row, &neg).unwrap();
                for head in 0..l.h {
                    let dst = ((layer * l.h + head) * l.max_ctx + pos) * l.dh;
                    dense_k[dst..dst + l.dh]
                        .copy_from_slice(&row[head * l.dh..(head + 1) * l.dh]);
                    dense_v[dst..dst + l.dh]
                        .copy_from_slice(&neg[head * l.dh..(head + 1) * l.dh]);
                }
            }
        }
        assert_eq!(a.gather_contiguous(h).unwrap(), (dense_k, dense_v));
    }

    #[test]
    fn handles_are_generation_checked() {
        let mut a = CacheArena::new(layout(4), 4).unwrap();
        let h = a.alloc_session().unwrap();
        a.ensure_capacity(h, 0).unwrap();
        a.free_session(h).unwrap();
        // Double free and every other op on a stale handle must error.
        assert!(a.free_session(h).is_err());
        assert!(a.ensure_capacity(h, 0).is_err());
        assert!(a.view(h).is_err());
        assert!(a.session_blocks(h).is_err());
        assert!(!a.is_live(h));
        // The freed slot's reuse yields a DIFFERENT handle.
        let h2 = a.alloc_session().unwrap();
        assert_ne!(h.key(), h2.key());
        assert!(a.is_live(h2));
        a.debug_validate().unwrap();
    }

    #[test]
    fn exhaustion_and_reuse() {
        let mut a = CacheArena::new(layout(4), 2).unwrap();
        let h1 = a.alloc_session().unwrap();
        let h2 = a.alloc_session().unwrap();
        a.ensure_capacity(h1, 3).unwrap(); // block 0
        a.ensure_capacity(h2, 3).unwrap(); // block 1
        assert_eq!(a.status().free_blocks, 0);
        // Pool dry: growing either session fails...
        assert!(a.ensure_capacity(h1, 4).is_err());
        // ...but freeing returns capacity that is immediately reusable.
        a.free_session(h2).unwrap();
        assert_eq!(a.status().free_blocks, 1);
        a.ensure_capacity(h1, 4).unwrap();
        assert_eq!(a.session_blocks(h1).unwrap(), 2);
        a.debug_validate().unwrap();
    }

    #[test]
    fn blocks_are_zeroed_on_reuse() {
        let mut a = CacheArena::new(layout(4), 1).unwrap();
        let h = a.alloc_session().unwrap();
        a.ensure_capacity(h, 0).unwrap();
        a.write_kv(h, 0, 0, &[7.0; 4], &[9.0; 4]).unwrap();
        a.free_session(h).unwrap();
        let h = a.alloc_session().unwrap();
        a.ensure_capacity(h, 0).unwrap();
        let (k, v) = a.gather_contiguous(h).unwrap();
        assert!(k.iter().all(|&x| x == 0.0) && v.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn duplicate_handles_rejected() {
        let mut a = CacheArena::new(layout(4), 4).unwrap();
        let h1 = a.alloc_session().unwrap();
        let h2 = a.alloc_session().unwrap();
        assert!(ensure_distinct(&[h1, h2]).is_ok());
        assert!(ensure_distinct(&[h1, h2, h1]).is_err());
    }

    #[test]
    fn shared_blocks_return_to_free_only_at_refcount_zero() {
        // The preemption regression: a session that adopted shared
        // prefix blocks is freed — the still-referenced blocks must NOT
        // land in the free list.
        let mut a = CacheArena::new(layout(4), 4).unwrap();
        let donor = a.alloc_session().unwrap();
        a.ensure_capacity(donor, 7).unwrap(); // blocks 0, 1
        let chain = a.session_table(donor).unwrap();
        a.pin_block(chain[0]).unwrap(); // prefix index pins block 0

        let s = a.alloc_session().unwrap();
        a.share_blocks(s, &chain).unwrap();
        assert_eq!(a.block_refs(chain[0]), 3); // donor + pin + s
        assert_eq!(a.block_refs(chain[1]), 2); // donor + s
        let free_before = a.status().free_blocks;
        a.free_session(s).unwrap(); // preempt the sharer
        assert_eq!(
            a.status().free_blocks,
            free_before,
            "freeing a sharer must not release still-referenced blocks"
        );
        a.debug_validate().unwrap();

        a.free_session(donor).unwrap();
        // Block 1's last ref was the donor; block 0 is still pinned.
        assert_eq!(a.status().free_blocks, free_before + 1);
        assert_eq!(a.block_refs(chain[0]), 1);
        a.unpin_block(chain[0]).unwrap();
        assert_eq!(a.status().free_blocks, free_before + 2);
        assert!(a.unpin_block(chain[0]).is_err(), "double unpin must error");
        a.debug_validate().unwrap();
    }

    #[test]
    fn cow_copies_kept_rows_and_zeroes_the_rest() {
        let mut a = CacheArena::new(layout(4), 4).unwrap();
        let donor = a.alloc_session().unwrap();
        for pos in 0..4usize {
            a.ensure_capacity(donor, pos).unwrap();
            for layer in 0..2 {
                let row: Vec<f32> =
                    (0..4).map(|i| (layer * 100 + pos * 10 + i) as f32).collect();
                let neg: Vec<f32> = row.iter().map(|x| -x).collect();
                a.write_kv(donor, layer, pos, &row, &neg).unwrap();
            }
        }
        let chain = a.session_table(donor).unwrap();
        let s = a.alloc_session().unwrap();
        a.share_blocks(s, &chain).unwrap();
        // Copy keeping 2 of 4 rows: rows 0-1 must be the donor's bytes,
        // rows 2-3 must be zero (cold-prefill state), donor untouched.
        assert!(a.cow_block(s, 0, 2).unwrap());
        let (dk, dv) = a.gather_contiguous(donor).unwrap();
        let (sk, sv) = a.gather_contiguous(s).unwrap();
        let l = a.layout().clone();
        for layer in 0..l.n_layers {
            for head in 0..l.h {
                for pos in 0..4usize {
                    let at = ((layer * l.h + head) * l.max_ctx + pos) * l.dh;
                    if pos < 2 {
                        assert_eq!(sk[at..at + l.dh], dk[at..at + l.dh]);
                        assert_eq!(sv[at..at + l.dh], dv[at..at + l.dh]);
                    } else {
                        assert!(sk[at..at + l.dh].iter().all(|&x| x == 0.0));
                        assert!(sv[at..at + l.dh].iter().all(|&x| x == 0.0));
                    }
                }
            }
        }
        // The copy made the entry exclusive: a second cow is a no-op.
        assert!(!a.cow_block(s, 0, 2).unwrap());
        assert_eq!(a.block_refs(chain[0]), 1); // donor only again
        a.debug_validate().unwrap();
    }

    #[test]
    fn writes_into_shared_blocks_are_rejected_until_cow() {
        let mut a = CacheArena::new(layout(4), 4).unwrap();
        let donor = a.alloc_session().unwrap();
        a.ensure_capacity(donor, 3).unwrap();
        let chain = a.session_table(donor).unwrap();
        let s = a.alloc_session().unwrap();
        a.share_blocks(s, &chain).unwrap();
        // Direct write into the shared block: rejected.
        assert!(a.write_kv(s, 0, 1, &[1.0; 4], &[1.0; 4]).is_err());
        // ensure_capacity for a position INSIDE the shared block
        // performs the COW (keeping the rows before it), unblocking it.
        a.ensure_capacity(s, 1).unwrap();
        a.write_kv(s, 0, 1, &[1.0; 4], &[1.0; 4]).unwrap();
        // The donor still owns the original, unmodified block.
        let (dk, _) = a.gather_contiguous(donor).unwrap();
        assert!(dk.iter().all(|&x| x == 0.0));
        a.debug_validate().unwrap();
    }

    #[test]
    fn cow_failure_is_all_or_nothing() {
        // 2-block arena: donor owns both; sharer adopts both; a COW has
        // no free block to copy into — the error must leave the table,
        // refcounts and free list untouched.
        let mut a = CacheArena::new(layout(4), 2).unwrap();
        let donor = a.alloc_session().unwrap();
        a.ensure_capacity(donor, 7).unwrap();
        let chain = a.session_table(donor).unwrap();
        let s = a.alloc_session().unwrap();
        a.share_blocks(s, &chain).unwrap();
        assert!(a.cow_block(s, 0, 2).is_err());
        assert!(a.ensure_capacity(s, 1).is_err()); // same via the write path
        assert_eq!(a.session_table(s).unwrap(), chain);
        assert_eq!(a.block_refs(chain[0]), 2);
        a.debug_validate().unwrap();
    }

    #[test]
    fn share_rejects_free_duplicate_and_bogus_blocks() {
        let mut a = CacheArena::new(layout(4), 4).unwrap();
        let donor = a.alloc_session().unwrap();
        a.ensure_capacity(donor, 3).unwrap();
        let chain = a.session_table(donor).unwrap();
        let s = a.alloc_session().unwrap();
        assert!(a.share_blocks(s, &[99]).is_err(), "bogus id");
        assert!(a.share_blocks(s, &[3]).is_err(), "free block");
        assert!(
            a.share_blocks(s, &[chain[0], chain[0]]).is_err(),
            "duplicate in one call"
        );
        a.share_blocks(s, &chain).unwrap();
        assert!(
            a.share_blocks(s, &chain).is_err(),
            "already in the session's table"
        );
        // Failed shares left the accounting clean.
        a.debug_validate().unwrap();
        // Pinning a free block is rejected too.
        assert!(a.pin_block(3).is_err());
    }

    #[test]
    fn obtainable_counts_shared_blocks_once() {
        let mut a = CacheArena::new(layout(4), 6).unwrap();
        let s1 = a.alloc_session().unwrap();
        a.ensure_capacity(s1, 7).unwrap(); // 2 exclusive blocks
        let chain = a.session_table(s1).unwrap();
        let s2 = a.alloc_session().unwrap();
        a.share_blocks(s2, &chain).unwrap();
        a.pin_block(chain[0]).unwrap();
        // 4 free + 2 shared-but-fully-held-by-{s1, s2, pins} = 6.
        assert_eq!(a.obtainable_with(&[s1, s2]), 6);
        // With only s2 in the loop, s1's references make both blocks
        // unobtainable (a naive free + table-len sum would say 6).
        assert_eq!(a.obtainable_with(&[s2]), 4);
        assert_eq!(a.obtainable_with(&[]), 4);
    }

    #[test]
    fn split_partitions_deterministically() {
        // 14 blocks over 4 shards: base 3, remainder to the lowest ids.
        let shards = CacheArena::split(layout(4), 14, 4).unwrap();
        let caps: Vec<usize> = shards.iter().map(|a| a.status().total_blocks).collect();
        assert_eq!(caps, vec![4, 4, 3, 3]);
        assert_eq!(caps.iter().sum::<usize>(), 14);
        // Even split stays even; a second split of the same inputs is
        // byte-for-byte the same partition.
        let again: Vec<usize> = CacheArena::split(layout(4), 14, 4)
            .unwrap()
            .iter()
            .map(|a| a.status().total_blocks)
            .collect();
        assert_eq!(caps, again);
        assert_eq!(
            CacheArena::split(layout(4), 8, 2)
                .unwrap()
                .iter()
                .map(|a| a.status().total_blocks)
                .collect::<Vec<_>>(),
            vec![4, 4]
        );
        // Degenerate splits are rejected up front.
        assert!(CacheArena::split(layout(4), 3, 4).is_err());
        assert!(CacheArena::split(layout(4), 4, 0).is_err());
    }

    #[test]
    fn split_shards_are_independent_arenas() {
        // Blocks allocated on one shard never appear in another shard's
        // accounting: each shard's free list, refcounts and sessions are
        // self-contained, which is what makes a shard safe to move to a
        // worker thread without any locking.
        let mut shards = CacheArena::split(layout(4), 8, 2).unwrap();
        let h0 = shards[0].alloc_session().unwrap();
        shards[0].ensure_capacity(h0, 7).unwrap(); // 2 blocks on shard 0
        assert_eq!(shards[0].status().used_blocks, 2);
        assert_eq!(shards[1].status().used_blocks, 0);
        // Shard-local block ids start at 0 on every shard.
        let h1 = shards[1].alloc_session().unwrap();
        shards[1].ensure_capacity(h1, 0).unwrap();
        assert_eq!(shards[1].session_table(h1).unwrap(), vec![0]);
        for s in &shards {
            s.debug_validate().unwrap();
        }
        // A shard is Send by construction (plain Vec storage).
        fn assert_send<T: Send>() {}
        assert_send::<CacheArena>();
    }

    #[test]
    fn layout_names_round_trip_and_bytes_account_for_scales() {
        assert_eq!(ArenaLayout::from_name("f32").unwrap(), ArenaLayout::F32);
        assert_eq!(ArenaLayout::from_name("int8").unwrap(), ArenaLayout::KvInt8);
        assert!(ArenaLayout::from_name("fp16").is_err());
        assert_eq!(ArenaLayout::F32.name(), "f32");
        assert_eq!(ArenaLayout::KvInt8.name(), "int8");
        let l = layout(4);
        // f32: 2 pools of block_floats f32s. int8: 2 pools of
        // block_floats codes + one f32 absmax per (layer, head) group.
        assert_eq!(l.block_floats(), 64);
        assert_eq!(l.block_bytes(ArenaLayout::F32), 2 * 64 * 4);
        assert_eq!(l.block_bytes(ArenaLayout::KvInt8), 2 * (64 + 4 * 4));
        // ~4x density: equal bytes buy ~3.5-4x the int8 blocks.
        let budget = 10 * l.block_bytes(ArenaLayout::F32);
        assert_eq!(l.blocks_for_bytes(budget, ArenaLayout::F32), 10);
        assert!(l.blocks_for_bytes(budget, ArenaLayout::KvInt8) >= 3 * 10);
        // Status reports the same accounting in bytes.
        let a = CacheArena::new_with_mode(l.clone(), 6, ArenaLayout::KvInt8).unwrap();
        let st = a.status();
        assert_eq!(st.block_bytes, l.block_bytes(ArenaLayout::KvInt8));
        assert_eq!(st.total_bytes, 6 * st.block_bytes);
        assert_eq!(st.used_bytes, 0);
        assert_eq!(a.mode(), ArenaLayout::KvInt8);
    }

    #[test]
    fn int8_round_trip_error_is_bounded_by_the_quantization_step() {
        let mut a = CacheArena::new_with_mode(layout(4), 6, ArenaLayout::KvInt8).unwrap();
        let h = a.alloc_session().unwrap();
        let mut rng = crate::util::rng::Rng::new(3);
        let mut written: Vec<(usize, usize, Vec<f32>, Vec<f32>)> = Vec::new();
        for pos in 0..7usize {
            a.ensure_capacity(h, pos).unwrap();
            for layer in 0..2 {
                let k: Vec<f32> = (0..4).map(|_| rng.normal() as f32).collect();
                let v: Vec<f32> = (0..4).map(|_| rng.normal() as f32).collect();
                a.write_kv(h, layer, pos, &k, &v).unwrap();
                written.push((layer, pos, k, v));
            }
        }
        let (kc, vc) = a.gather_contiguous(h).unwrap();
        let l = a.layout().clone();
        // Group absmax <= the largest |value| seen; a requantize-on-grow
        // costs at most ~1.5 steps of the final grid, so 2 steps of the
        // global absmax bounds every element comfortably.
        let gmax = written
            .iter()
            .flat_map(|(_, _, k, v)| k.iter().chain(v))
            .fold(0.0f32, |m, &x| m.max(x.abs()));
        let step = gmax / 127.0;
        for (layer, pos, k, v) in &written {
            for head in 0..l.h {
                let at = ((layer * l.h + head) * l.max_ctx + pos) * l.dh;
                for i in 0..l.dh {
                    let (wk, wv) = (k[head * l.dh + i], v[head * l.dh + i]);
                    assert!(
                        (kc[at + i] - wk).abs() <= 2.0 * step,
                        "K layer {layer} pos {pos}: {} vs {wk}",
                        kc[at + i]
                    );
                    assert!(
                        (vc[at + i] - wv).abs() <= 2.0 * step,
                        "V layer {layer} pos {pos}: {} vs {wv}",
                        vc[at + i]
                    );
                }
            }
        }
        a.debug_validate().unwrap();
    }

    #[test]
    fn int8_requantize_on_grow_keeps_earlier_rows_consistent() {
        // A small row then a 100x larger one in the same group: the
        // group's single scale must grow, and the EARLIER row must still
        // dequantize near its written value on the coarser grid.
        let mut a = CacheArena::new_with_mode(layout(4), 2, ArenaLayout::KvInt8).unwrap();
        let h = a.alloc_session().unwrap();
        a.ensure_capacity(h, 0).unwrap();
        a.ensure_capacity(h, 1).unwrap();
        a.write_kv(h, 0, 0, &[0.5, -0.5, 0.25, 0.5], &[0.5; 4]).unwrap();
        a.write_kv(h, 0, 1, &[50.0, -50.0, 25.0, 50.0], &[50.0; 4]).unwrap();
        let (kc, _) = a.gather_contiguous(h).unwrap();
        let l = a.layout().clone();
        let step = 50.0 / 127.0; // the grown grid
        // Row 0 (head 0): within 1.5 steps of the written values.
        let at0 = 0; // layer 0, head 0, pos 0
        for (i, want) in [0.5f32, -0.5].iter().enumerate() {
            assert!(
                (kc[at0 + i] - want).abs() <= 1.5 * step,
                "requantized row drifted: {} vs {want}",
                kc[at0 + i]
            );
        }
        // Row 1 is freshly quantized on the new grid: within 0.5 step.
        let at1 = l.dh; // pos 1 of the same (layer 0, head 0)
        assert!((kc[at1] - 50.0).abs() <= 0.5 * step);
        assert!((kc[at1 + 1] + 50.0).abs() <= 0.5 * step);
    }

    #[test]
    fn int8_grid_aligned_values_round_trip_exactly() {
        // Values already on the int8 grid of their group absmax (here
        // {-1, 0, 1} with absmax 1) dequantize bit-exactly: q = +/-127
        // codes, and 127 * (1 / (127/1)) == 1.0 in f32.
        let mut a = CacheArena::new_with_mode(layout(4), 2, ArenaLayout::KvInt8).unwrap();
        let h = a.alloc_session().unwrap();
        a.ensure_capacity(h, 0).unwrap();
        let k = [1.0f32, -1.0, 0.0, 1.0];
        let v = [-1.0f32, 0.0, 1.0, -1.0];
        a.write_kv(h, 0, 0, &k, &v).unwrap();
        let (kc, vc) = a.gather_contiguous(h).unwrap();
        let l = a.layout().clone();
        for head in 0..l.h {
            let at = (head * l.max_ctx) * l.dh; // layer 0, pos 0
            assert_eq!(&kc[at..at + l.dh], &k[head * l.dh..(head + 1) * l.dh]);
            assert_eq!(&vc[at..at + l.dh], &v[head * l.dh..(head + 1) * l.dh]);
        }
    }

    #[test]
    fn int8_cow_preserves_dequantized_values_and_scales() {
        let mut a = CacheArena::new_with_mode(layout(4), 4, ArenaLayout::KvInt8).unwrap();
        let donor = a.alloc_session().unwrap();
        let mut rng = crate::util::rng::Rng::new(9);
        for pos in 0..4usize {
            a.ensure_capacity(donor, pos).unwrap();
            for layer in 0..2 {
                let k: Vec<f32> = (0..4).map(|_| rng.normal() as f32).collect();
                let v: Vec<f32> = (0..4).map(|_| rng.normal() as f32).collect();
                a.write_kv(donor, layer, pos, &k, &v).unwrap();
            }
        }
        let chain = a.session_table(donor).unwrap();
        let s = a.alloc_session().unwrap();
        a.share_blocks(s, &chain).unwrap();
        assert!(a.cow_block(s, 0, 2).unwrap());
        // Codes AND group scales were copied: the kept rows dequantize
        // to exactly the donor's values; the tail reads zero.
        let (dk, dv) = a.gather_contiguous(donor).unwrap();
        let (sk, sv) = a.gather_contiguous(s).unwrap();
        let l = a.layout().clone();
        for layer in 0..l.n_layers {
            for head in 0..l.h {
                for pos in 0..4usize {
                    let at = ((layer * l.h + head) * l.max_ctx + pos) * l.dh;
                    if pos < 2 {
                        assert_eq!(sk[at..at + l.dh], dk[at..at + l.dh]);
                        assert_eq!(sv[at..at + l.dh], dv[at..at + l.dh]);
                    } else {
                        assert!(sk[at..at + l.dh].iter().all(|&x| x == 0.0));
                        assert!(sv[at..at + l.dh].iter().all(|&x| x == 0.0));
                    }
                }
            }
        }
        // The adopter's first write after the COW must not perturb the
        // donor (fresh group, donor's scale evolves independently).
        a.write_kv(s, 0, 2, &[99.0; 4], &[99.0; 4]).unwrap();
        assert_eq!(a.gather_contiguous(donor).unwrap(), (dk, dv));
        a.debug_validate().unwrap();
    }

    #[test]
    fn int8_blocks_and_scales_are_zeroed_on_reuse() {
        let mut a = CacheArena::new_with_mode(layout(4), 1, ArenaLayout::KvInt8).unwrap();
        let h = a.alloc_session().unwrap();
        a.ensure_capacity(h, 0).unwrap();
        a.write_kv(h, 0, 0, &[7.0; 4], &[9.0; 4]).unwrap();
        a.free_session(h).unwrap();
        let h = a.alloc_session().unwrap();
        a.ensure_capacity(h, 0).unwrap();
        let (k, v) = a.gather_contiguous(h).unwrap();
        assert!(k.iter().all(|&x| x == 0.0) && v.iter().all(|&x| x == 0.0));
        // A fresh small-magnitude write quantizes on ITS OWN absmax —
        // stale scale metadata from the previous tenant would wreck it.
        a.write_kv(h, 0, 0, &[0.01, -0.01, 0.0, 0.01], &[0.01; 4]).unwrap();
        let (k, _) = a.gather_contiguous(h).unwrap();
        assert!((k[0] - 0.01).abs() < 0.001, "stale group scale: {}", k[0]);
    }

    #[test]
    fn split_mode_propagates_the_layout_to_every_shard() {
        let shards = CacheArena::split_mode(layout(4), 8, 2, ArenaLayout::KvInt8).unwrap();
        assert_eq!(shards.len(), 2);
        for s in &shards {
            assert_eq!(s.mode(), ArenaLayout::KvInt8);
            let st = s.status();
            assert_eq!(st.block_bytes, s.layout().block_bytes(ArenaLayout::KvInt8));
            assert_eq!(st.total_bytes, st.total_blocks * st.block_bytes);
        }
    }

    #[test]
    fn write_requires_backing_block() {
        let mut a = CacheArena::new(layout(4), 4).unwrap();
        let h = a.alloc_session().unwrap();
        assert!(a.write_kv(h, 0, 0, &[0.0; 4], &[0.0; 4]).is_err());
        a.ensure_capacity(h, 0).unwrap();
        a.write_kv(h, 0, 0, &[0.0; 4], &[0.0; 4]).unwrap();
        // Position 4 lives in block 1, not yet claimed.
        assert!(a.write_kv(h, 0, 4, &[0.0; 4], &[0.0; 4]).is_err());
        // Bounds.
        assert!(a.ensure_capacity(h, 10).is_err());
        assert!(a.write_kv(h, 2, 0, &[0.0; 4], &[0.0; 4]).is_err());
        assert!(a.write_kv(h, 0, 0, &[0.0; 3], &[0.0; 3]).is_err());
    }

    #[test]
    fn truncate_session_releases_trailing_blocks_and_keeps_prefix_rows() {
        // 9 positions over block_len 4 = 3 blocks; roll back to 5 = 2
        // blocks: the trailing block returns to the free list, the kept
        // rows read back bitwise, and a subsequent regrow works.
        let mut a = CacheArena::new(layout(4), 6).unwrap();
        let h = a.alloc_session().unwrap();
        for pos in 0..9usize {
            a.ensure_capacity(h, pos).unwrap();
            let k: Vec<f32> = (0..4).map(|i| (pos * 10 + i) as f32).collect();
            let v: Vec<f32> = k.iter().map(|x| -x).collect();
            a.write_kv(h, 0, pos, &k, &v).unwrap();
        }
        assert_eq!(a.session_blocks(h).unwrap(), 3);
        let free_before = a.status().free_blocks;
        a.truncate_session(h, 5).unwrap();
        assert_eq!(a.session_blocks(h).unwrap(), 2);
        assert_eq!(a.status().free_blocks, free_before + 1);
        a.debug_validate().unwrap();
        // Rows 0..5 are untouched by the rollback.
        let view = a.view(h).unwrap();
        let (mut gk, mut gv) = (Vec::new(), Vec::new());
        view.gather_head(0, 0, 5, &mut gk, &mut gv);
        let expect: Vec<f32> = (0..5)
            .flat_map(|p| [(p * 10) as f32, (p * 10 + 1) as f32])
            .collect();
        assert_eq!(gk, expect);
        // Regrow over the rolled-back positions: ensure + write works
        // and the rewritten rows win over any stale storage.
        for pos in 5..7usize {
            a.ensure_capacity(h, pos).unwrap();
            a.write_kv(h, 0, pos, &[1.0; 4], &[2.0; 4]).unwrap();
        }
        assert_eq!(a.session_blocks(h).unwrap(), 2);
        a.debug_validate().unwrap();
        // Truncating to at or beyond the held table is a no-op; a dead
        // handle errors.
        a.truncate_session(h, 9).unwrap();
        assert_eq!(a.session_blocks(h).unwrap(), 2);
        a.free_session(h).unwrap();
        assert!(a.truncate_session(h, 0).is_err());
    }

    #[test]
    fn truncate_session_on_shared_blocks_drops_only_this_reference() {
        // Donor shares its 2-block chain with an adopter; truncating the
        // adopter to 0 positions must release the adopter's references
        // without freeing the donor's blocks.
        let mut a = CacheArena::new(layout(4), 4).unwrap();
        let donor = a.alloc_session().unwrap();
        for pos in 0..8usize {
            a.ensure_capacity(donor, pos).unwrap();
            a.write_kv(donor, 0, pos, &[3.0; 4], &[4.0; 4]).unwrap();
        }
        let chain = a.session_table(donor).unwrap();
        let adopter = a.alloc_session().unwrap();
        a.share_blocks(adopter, &chain).unwrap();
        for &b in &chain {
            assert_eq!(a.block_refs(b), 2);
        }
        a.truncate_session(adopter, 0).unwrap();
        assert_eq!(a.session_blocks(adopter).unwrap(), 0);
        for &b in &chain {
            assert_eq!(a.block_refs(b), 1, "donor must keep block {b}");
        }
        a.debug_validate().unwrap();
        // The donor's rows are untouched.
        let (k, _) = a.gather_contiguous(donor).unwrap();
        assert!(k.iter().take(8).any(|&x| x != 0.0));
    }
}
