//! Block-paged KV-cache arena — shared cache storage for every decode
//! session, replacing the per-session contiguous `Caches` values that
//! were moved in and out of the backends before this refactor.
//!
//! Motivation (HPIM arxiv 2509.12993, PIM-AI arxiv 2411.17309, and the
//! vLLM lineage they cite): when each session owns a private
//! `(n_layers, h, max_ctx, d_head)` tensor, concurrency is capped by the
//! WORST-CASE context length — a request that will generate 10 tokens
//! reserves the same memory as one that fills the window. Paging the
//! cache into fixed-size blocks lets the serving layer admit sessions
//! against actual usage, preempt under pressure, and reuse freed
//! capacity immediately, which is what the continuous-batching policy
//! ([`crate::serving::Policy::Continuous`]) is built on.
//!
//! Layout: one block backs [`CacheLayout::block_len`] consecutive
//! positions of ONE session across ALL layers and heads, stored
//! `(n_layers, h, block_len, d_head)` row-major — the contiguous layout
//! with `max_ctx` replaced by `block_len`. A session is a block table
//! (`Vec<u32>` of block ids, position `p` lives in table entry
//! `p / block_len` at in-block offset `p % block_len`). Within a block,
//! the rows of one `(layer, head)` pair are contiguous, so the paged
//! attention gather ([`crate::runtime::kernels::attention_paged`]) copies
//! one contiguous run per block per head — and because the gathered
//! scratch holds exactly the bytes the contiguous tensor would, the
//! attention numerics are bit-for-bit identical to the pre-paging path
//! (enforced by `tests/paged_equivalence.rs`).
//!
//! Handles ([`CacheHandle`]) are generation-checked indices: freeing a
//! session bumps its slot's generation, so stale handles (use after
//! free, double free) are rejected with an error instead of silently
//! touching another session's cache. `tests/kvcache_properties.rs`
//! churns the allocator to pin the no-leak / no-double-free / full-reuse
//! invariants.
//!
//! Sharing (the copy-on-write prefix cache): every block carries a
//! reference count — the number of live block tables it appears in plus
//! the number of prefix-index pins ([`CacheArena::pin_block`]) holding
//! it. A block returns to the free list only when its count reaches
//! zero, so [`CacheArena::free_session`] on a session that adopted
//! shared prefix blocks never hands a still-referenced block back.
//! Sessions adopt matched prefix blocks read-only via
//! [`CacheArena::share_blocks`]; before the first write into a shared
//! block it must be made exclusive with [`CacheArena::cow_block`]
//! (copy-on-write: the matched rows are copied, the rest zeroed so the
//! block is bitwise what cold prefill would have produced).
//! [`CacheArena::ensure_capacity`] performs that COW automatically for
//! the position about to be written, and [`CacheArena::write_kv`]
//! rejects writes into still-shared blocks, so a backend can never
//! corrupt another session's (or the prefix index's) cached prefix.

use crate::util::error::{anyhow, ensure, Result};

/// Default number of positions per cache block (vLLM-style granularity;
/// clamped to `max_ctx` for tiny models).
pub const DEFAULT_BLOCK_LEN: usize = 16;

/// Default arena capacity, expressed in worst-case (full `max_ctx`)
/// sessions, used when the caller does not size the arena explicitly.
pub const DEFAULT_ARENA_SESSIONS: usize = 64;

/// Geometry of the paged cache: model shape plus the block granularity.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CacheLayout {
    pub n_layers: usize,
    pub h: usize,
    pub dh: usize,
    pub max_ctx: usize,
    pub block_len: usize,
}

impl CacheLayout {
    /// Layout for a model with the default block length.
    pub fn from_model(m: &super::artifacts::ModelInfo) -> Self {
        Self::with_block_len(m, DEFAULT_BLOCK_LEN)
    }

    /// Layout with an explicit block length (`0` selects the default);
    /// clamped to `[1, max_ctx]` — a block longer than the context
    /// window would only waste its tail.
    pub fn with_block_len(m: &super::artifacts::ModelInfo, block_len: usize) -> Self {
        let block_len = if block_len == 0 {
            DEFAULT_BLOCK_LEN
        } else {
            block_len
        };
        CacheLayout {
            n_layers: m.n_layers,
            h: m.h,
            dh: m.d / m.h,
            max_ctx: m.max_ctx,
            block_len: block_len.clamp(1, m.max_ctx.max(1)),
        }
    }

    /// Floats per block in EACH of the K and V pools.
    pub fn block_floats(&self) -> usize {
        self.block_len * self.n_layers * self.h * self.dh
    }

    /// Blocks needed to back `n` positions (0 positions -> 0 blocks).
    pub fn blocks_for_positions(&self, n: usize) -> usize {
        n.div_ceil(self.block_len)
    }

    /// Blocks of one worst-case (full `max_ctx`) session.
    pub fn blocks_per_session(&self) -> usize {
        self.blocks_for_positions(self.max_ctx)
    }
}

/// Opaque, generation-checked reference to one session's cache state.
/// Obtained from [`CacheArena::alloc_session`] (via
/// `Backend::new_session` / `Engine::new_session`); every arena
/// operation validates it, so stale handles error instead of aliasing.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct CacheHandle {
    index: u32,
    generation: u32,
}

impl CacheHandle {
    /// Stable unique key of this (slot, generation) pair — used by
    /// backends that keep private per-session side state (the PJRT
    /// contiguous shim keys its device buffers by this).
    pub fn key(self) -> u64 {
        (self.index as u64) << 32 | self.generation as u64
    }
}

/// One session slot: its block table plus the generation counter that
/// invalidates outstanding handles when the slot is freed and reused.
#[derive(Debug)]
struct Slot {
    generation: u32,
    live: bool,
    table: Vec<u32>,
}

/// Point-in-time arena occupancy, for pressure-aware admission and
/// reporting.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ArenaStatus {
    pub total_blocks: usize,
    pub free_blocks: usize,
    /// Blocks referenced by at least one table or pin. A block shared by
    /// several sessions (or a session and the prefix index) counts ONCE —
    /// used + free always sums to total.
    pub used_blocks: usize,
    pub block_len: usize,
    pub live_sessions: usize,
    /// Blocks currently pinned by the prefix index (each counted once,
    /// however many pins it holds).
    pub pinned_blocks: usize,
}

/// The shared block-paged KV-cache pool. K and V live in two flat f32
/// pools of `capacity_blocks * block_floats` each; a free list hands
/// out block ids LIFO (deterministic given a deterministic operation
/// sequence, which keeps serving runs reproducible).
pub struct CacheArena {
    layout: CacheLayout,
    k: Vec<f32>,
    v: Vec<f32>,
    /// Free block ids, popped from the back.
    free: Vec<u32>,
    /// Per-block reference count: table occurrences across live slots
    /// plus prefix-index pins. 0 == the block is in the free list.
    refs: Vec<u32>,
    /// Per-block prefix-index pin count (a subset of `refs`, tracked
    /// separately so `debug_validate` can balance the refcount equation
    /// and `obtainable_with` can treat pins as reclaimable).
    pins: Vec<u32>,
    slots: Vec<Slot>,
    /// Indices of dead slots available for reuse.
    free_slots: Vec<u32>,
    /// Lifetime count of copy-on-write block copies ([`Self::cow_block`]
    /// returning true) — the observability layer reads per-tick deltas
    /// off this to attribute COW traffic without hooking the write path.
    cow_copies: u64,
}

impl CacheArena {
    /// Arena with an explicit block capacity (`>= 1`).
    pub fn new(layout: CacheLayout, capacity_blocks: usize) -> Result<Self> {
        ensure!(capacity_blocks >= 1, "arena needs at least one block");
        ensure!(
            layout.block_floats() > 0,
            "degenerate cache layout {layout:?}"
        );
        let bf = layout.block_floats();
        Ok(Self {
            k: vec![0.0; capacity_blocks * bf],
            v: vec![0.0; capacity_blocks * bf],
            // Reversed so blocks are first handed out in 0, 1, 2... order.
            free: (0..capacity_blocks as u32).rev().collect(),
            refs: vec![0; capacity_blocks],
            pins: vec![0; capacity_blocks],
            layout,
            slots: Vec::new(),
            free_slots: Vec::new(),
            cow_copies: 0,
        })
    }

    /// Arena sized for `sessions` worst-case (full-context) sessions
    /// (`0` selects [`DEFAULT_ARENA_SESSIONS`]).
    pub fn with_sessions(layout: CacheLayout, sessions: usize) -> Result<Self> {
        let sessions = if sessions == 0 {
            DEFAULT_ARENA_SESSIONS
        } else {
            sessions
        };
        let blocks = layout.blocks_per_session().max(1) * sessions;
        Self::new(layout, blocks)
    }

    /// Partition `total_blocks` of capacity into `shards` independent
    /// arenas — the storage layer of the sharded serving engine. Each
    /// shard is a self-contained [`CacheArena`] (own K/V storage, free
    /// list, refcounts, slots), so a shard is `Send` and can be owned
    /// exclusively by one worker thread with no locking; block indices
    /// are shard-local and COW refcounts never cross a shard boundary.
    ///
    /// The split is deterministic: every shard gets
    /// `total_blocks / shards` blocks and the remainder goes to the
    /// lowest shard ids, so equal `total_blocks` always produces the
    /// same partition. Per-shard accounting is checked by calling
    /// [`CacheArena::debug_validate`] on each returned arena.
    pub fn split(layout: CacheLayout, total_blocks: usize, shards: usize) -> Result<Vec<Self>> {
        ensure!(shards >= 1, "need at least one shard");
        ensure!(
            total_blocks >= shards,
            "cannot split {total_blocks} blocks into {shards} shards (each shard needs >= 1 block)"
        );
        let base = total_blocks / shards;
        let rem = total_blocks % shards;
        (0..shards)
            .map(|i| Self::new(layout.clone(), base + usize::from(i < rem)))
            .collect()
    }

    pub fn layout(&self) -> &CacheLayout {
        &self.layout
    }

    /// Lifetime copy-on-write block copies (monotonic; never reset).
    pub fn cow_copies(&self) -> u64 {
        self.cow_copies
    }

    pub fn status(&self) -> ArenaStatus {
        ArenaStatus {
            total_blocks: self.k.len() / self.layout.block_floats(),
            free_blocks: self.free.len(),
            used_blocks: self.k.len() / self.layout.block_floats() - self.free.len(),
            block_len: self.layout.block_len,
            live_sessions: self.slots.iter().filter(|s| s.live).count(),
            pinned_blocks: self.pins.iter().filter(|&&p| p > 0).count(),
        }
    }

    fn slot(&self, h: CacheHandle) -> Result<&Slot> {
        let s = self
            .slots
            .get(h.index as usize)
            .ok_or_else(|| anyhow!("unknown cache handle {h:?}"))?;
        ensure!(
            s.live && s.generation == h.generation,
            "stale cache handle {h:?} (session freed)"
        );
        Ok(s)
    }

    fn slot_mut(&mut self, h: CacheHandle) -> Result<&mut Slot> {
        let s = self
            .slots
            .get_mut(h.index as usize)
            .ok_or_else(|| anyhow!("unknown cache handle {h:?}"))?;
        ensure!(
            s.live && s.generation == h.generation,
            "stale cache handle {h:?} (session freed)"
        );
        Ok(s)
    }

    /// Whether `h` refers to a live session.
    pub fn is_live(&self, h: CacheHandle) -> bool {
        self.slot(h).is_ok()
    }

    /// Open a session with an empty block table. Never fails for lack
    /// of blocks — blocks are claimed lazily by [`Self::ensure_capacity`].
    pub fn alloc_session(&mut self) -> Result<CacheHandle> {
        if let Some(i) = self.free_slots.pop() {
            let s = &mut self.slots[i as usize];
            debug_assert!(!s.live && s.table.is_empty());
            s.live = true;
            Ok(CacheHandle {
                index: i,
                generation: s.generation,
            })
        } else {
            ensure!(
                self.slots.len() < u32::MAX as usize,
                "session slot space exhausted"
            );
            self.slots.push(Slot {
                generation: 0,
                live: true,
                table: Vec::new(),
            });
            Ok(CacheHandle {
                index: (self.slots.len() - 1) as u32,
                generation: 0,
            })
        }
    }

    /// Free a session: release its references and invalidate the handle
    /// (the slot's generation is bumped, so a retained copy of `h`
    /// errors from now on). A block returns to the free pool only when
    /// this was its LAST reference — blocks shared with another session
    /// or pinned by the prefix index stay allocated, which is what makes
    /// preempting a prefix-sharing session safe. Eviction and normal
    /// retirement are the same operation — an evicted session is simply
    /// re-prefilled into a fresh session later, which is deterministic.
    pub fn free_session(&mut self, h: CacheHandle) -> Result<()> {
        self.slot(h)?; // validate first so `free` is untouched on error
        let s = &mut self.slots[h.index as usize];
        let table = std::mem::take(&mut s.table);
        s.live = false;
        s.generation = s.generation.wrapping_add(1);
        self.free_slots.push(h.index);
        for b in table {
            self.release_ref(b);
        }
        Ok(())
    }

    /// Drop one reference to `b`, returning it to the free list at zero.
    fn release_ref(&mut self, b: u32) {
        debug_assert!(self.refs[b as usize] > 0, "releasing unowned block {b}");
        self.refs[b as usize] -= 1;
        if self.refs[b as usize] == 0 {
            self.free.push(b);
        }
    }

    /// Pop a free block, zero its storage, and give it one reference.
    /// Returns `None` when the pool is dry (callers report their own
    /// context-rich errors).
    fn claim_zeroed(&mut self) -> Option<u32> {
        let b = self.free.pop()?;
        let bf = self.layout.block_floats();
        let base = b as usize * bf;
        self.k[base..base + bf].fill(0.0);
        self.v[base..base + bf].fill(0.0);
        debug_assert_eq!(self.refs[b as usize], 0);
        self.refs[b as usize] = 1;
        Some(b)
    }

    /// Ensure the session can WRITE position `pos` (with everything
    /// before it backed): claims zeroed blocks from the free list as
    /// needed, and — if the block containing `pos` is shared (adopted
    /// from the prefix cache) — copies it on write
    /// ([`Self::cow_block`] with the rows before `pos` kept), so the
    /// caller's subsequent [`Self::write_kv`] lands in an exclusive
    /// block. All-or-nothing: if the pool cannot cover the full need
    /// (new blocks plus a possible COW copy), an error is returned and
    /// NOTHING is claimed — the session's table and the free list are
    /// untouched, so the serving layer can turn the pressure into
    /// preemption and simply retry.
    pub fn ensure_capacity(&mut self, h: CacheHandle, pos: usize) -> Result<()> {
        ensure!(
            pos < self.layout.max_ctx,
            "position {pos} >= max_ctx {}",
            self.layout.max_ctx
        );
        let block_len = self.layout.block_len;
        let target = pos / block_len + 1;
        let held = self.slot(h)?.table.len();
        if target <= held {
            // Block exists; make it exclusive if a prefix share still
            // holds it (the COW consumes one free block, checked inside).
            self.cow_block(h, pos / block_len, pos % block_len)?;
            return Ok(());
        }
        let needed = target - held;
        if self.free.len() < needed {
            let st = self.status();
            crate::bail!(
                "KV arena out of blocks (need {needed}, {} free of {} total, \
                 {} sessions live) — raise the arena capacity or use the \
                 continuous policy's preemption",
                st.free_blocks,
                st.total_blocks,
                st.live_sessions
            );
        }
        for _ in 0..needed {
            let b = self.claim_zeroed().expect("count checked above");
            self.slots[h.index as usize].table.push(b);
        }
        Ok(())
    }

    /// Adopt already-populated blocks into the session's table, read
    /// only: each block's reference count is incremented and it is
    /// appended to the table in order (backing the positions after the
    /// session's current end). The blocks keep their contents — this is
    /// how a session inherits a matched prompt prefix without re-running
    /// a single MAC. Writing into a shared block requires
    /// [`Self::cow_block`] first ([`Self::ensure_capacity`] does it
    /// automatically; [`Self::write_kv`] rejects the write otherwise).
    /// All-or-nothing: validation happens before any refcount changes.
    pub fn share_blocks(&mut self, h: CacheHandle, blocks: &[u32]) -> Result<()> {
        let total = self.refs.len();
        let slot = self.slot(h)?;
        for (n, &b) in blocks.iter().enumerate() {
            ensure!((b as usize) < total, "shared block {b} out of range");
            ensure!(
                self.refs[b as usize] > 0,
                "cannot share free block {b} (no live content)"
            );
            ensure!(
                !slot.table.contains(&b) && !blocks[..n].contains(&b),
                "block {b} already in the session's table"
            );
        }
        for &b in blocks {
            self.refs[b as usize] += 1;
            self.slots[h.index as usize].table.push(b);
        }
        Ok(())
    }

    /// Make table entry `block_idx` exclusive to the session via copy on
    /// write: if the block is shared (refcount > 1), a fresh block is
    /// claimed, the first `keep_rows` positions of every (layer, head)
    /// pair are copied, the remaining rows are zeroed (bitwise what cold
    /// prefill would hold there), and the table entry is repointed —
    /// the donor keeps its copy untouched. Exclusive blocks are left
    /// alone. Returns whether a copy happened.
    pub fn cow_block(
        &mut self,
        h: CacheHandle,
        block_idx: usize,
        keep_rows: usize,
    ) -> Result<bool> {
        let l = self.layout.clone();
        ensure!(
            keep_rows <= l.block_len,
            "keep_rows {keep_rows} > block_len {}",
            l.block_len
        );
        let slot = self.slot(h)?;
        let Some(&old) = slot.table.get(block_idx) else {
            crate::bail!(
                "cow_block: table entry {block_idx} out of range (len {})",
                slot.table.len()
            );
        };
        if self.refs[old as usize] == 1 {
            return Ok(false); // already exclusive
        }
        let Some(fresh) = self.claim_zeroed() else {
            let st = self.status();
            crate::bail!(
                "KV arena out of blocks for a prefix copy-on-write \
                 ({} free of {} total) — raise the arena capacity or use \
                 the continuous policy's preemption",
                st.free_blocks,
                st.total_blocks
            );
        };
        let bf = l.block_floats();
        let (ob, nb) = (old as usize * bf, fresh as usize * bf);
        for lh in 0..l.n_layers * l.h {
            let off = lh * l.block_len * l.dh;
            let n = keep_rows * l.dh;
            self.k.copy_within(ob + off..ob + off + n, nb + off);
            self.v.copy_within(ob + off..ob + off + n, nb + off);
        }
        self.slots[h.index as usize].table[block_idx] = fresh;
        self.release_ref(old);
        self.cow_copies += 1;
        Ok(true)
    }

    /// Add a prefix-index pin to `b`, keeping it alive independent of
    /// any session table. The block must currently be live (referenced).
    pub fn pin_block(&mut self, b: u32) -> Result<()> {
        ensure!((b as usize) < self.refs.len(), "pin: block {b} out of range");
        ensure!(
            self.refs[b as usize] > 0,
            "cannot pin free block {b} (no live content)"
        );
        self.refs[b as usize] += 1;
        self.pins[b as usize] += 1;
        Ok(())
    }

    /// Drop one prefix-index pin from `b`; the block returns to the
    /// free pool if this was its last reference.
    pub fn unpin_block(&mut self, b: u32) -> Result<()> {
        ensure!((b as usize) < self.refs.len(), "unpin: block {b} out of range");
        ensure!(self.pins[b as usize] > 0, "block {b} is not pinned");
        self.pins[b as usize] -= 1;
        self.release_ref(b);
        Ok(())
    }

    /// Reference count of one block (0 = free). Test/diagnostic surface.
    pub fn block_refs(&self, b: u32) -> u32 {
        self.refs.get(b as usize).copied().unwrap_or(0)
    }

    /// The session's block table (ids in position order) — what the
    /// prefix index records for a finished prefill.
    pub fn session_table(&self, h: CacheHandle) -> Result<Vec<u32>> {
        Ok(self.slot(h)?.table.clone())
    }

    /// Blocks a serving loop could EVER obtain for a new request: the
    /// free list plus every block whose references are entirely held by
    /// the given sessions and/or prefix-index pins (freeing those
    /// sessions and reclaiming the index would release it). Blocks also
    /// referenced by a session OUTSIDE `handles` are not counted — they
    /// are never coming back to this loop. Shared blocks are counted
    /// once, so this never overstates capacity the way summing
    /// per-session table lengths would.
    pub fn obtainable_with(&self, handles: &[CacheHandle]) -> usize {
        let mut counted = vec![0u32; self.refs.len()];
        for &h in handles {
            if let Ok(slot) = self.slot(h) {
                for &b in &slot.table {
                    counted[b as usize] += 1;
                }
            }
        }
        let reclaimable = self
            .refs
            .iter()
            .zip(counted.iter().zip(&self.pins))
            .filter(|(&r, (&c, &p))| r > 0 && r == c + p)
            .count();
        self.free.len() + reclaimable
    }

    /// Blocks currently held by the session.
    pub fn session_blocks(&self, h: CacheHandle) -> Result<usize> {
        Ok(self.slot(h)?.table.len())
    }

    /// Write one token's K/V rows (all heads of one layer, `h * dh`
    /// floats each) at `pos`. The backing block must already exist
    /// ([`Self::ensure_capacity`]); positions are written in place, so
    /// re-running a step overwrites deterministically.
    pub fn write_kv(
        &mut self,
        h: CacheHandle,
        layer: usize,
        pos: usize,
        k_row: &[f32],
        v_row: &[f32],
    ) -> Result<()> {
        let l = self.layout.clone();
        ensure!(layer < l.n_layers, "layer {layer} out of range");
        ensure!(pos < l.max_ctx, "position {pos} >= max_ctx {}", l.max_ctx);
        ensure!(
            k_row.len() == l.h * l.dh && v_row.len() == l.h * l.dh,
            "K/V row length {} != h*dh {}",
            k_row.len(),
            l.h * l.dh
        );
        let slot = self.slot_mut(h)?;
        let bi = pos / l.block_len;
        let Some(&block) = slot.table.get(bi) else {
            crate::bail!("position {pos} not backed by a block (table len {})", slot.table.len());
        };
        ensure!(
            self.refs[block as usize] == 1,
            "write at position {pos} targets shared block {block} \
             (refcount {}) — copy-on-write required first (ensure_capacity \
             does this); writing would corrupt another session's prefix",
            self.refs[block as usize]
        );
        let pib = pos % l.block_len;
        let bf = l.block_floats();
        for head in 0..l.h {
            let dst = block as usize * bf + ((layer * l.h + head) * l.block_len + pib) * l.dh;
            self.k[dst..dst + l.dh].copy_from_slice(&k_row[head * l.dh..(head + 1) * l.dh]);
            self.v[dst..dst + l.dh].copy_from_slice(&v_row[head * l.dh..(head + 1) * l.dh]);
        }
        Ok(())
    }

    /// Read-only paged view of one session, for the attention gather.
    pub fn view(&self, h: CacheHandle) -> Result<PagedKv<'_>> {
        let slot = self.slot(h)?;
        Ok(PagedKv {
            k: &self.k,
            v: &self.v,
            table: &slot.table,
            layout: &self.layout,
        })
    }

    /// Reassemble the session's cache as the contiguous
    /// `(n_layers, h, max_ctx, d_head)` tensors the pre-paging backends
    /// produced (unbacked positions read as zero — exactly what fresh
    /// contiguous caches held). Used by the equivalence tests to compare
    /// paged state against the contiguous oracle bit for bit.
    pub fn gather_contiguous(&self, h: CacheHandle) -> Result<(Vec<f32>, Vec<f32>)> {
        let slot = self.slot(h)?;
        let l = &self.layout;
        let numel = l.n_layers * l.h * l.max_ctx * l.dh;
        let (mut kc, mut vc) = (vec![0.0f32; numel], vec![0.0f32; numel]);
        let bf = l.block_floats();
        for (bi, &block) in slot.table.iter().enumerate() {
            let pos0 = bi * l.block_len;
            let rows = l.block_len.min(l.max_ctx - pos0);
            for layer in 0..l.n_layers {
                for head in 0..l.h {
                    let src = block as usize * bf + ((layer * l.h + head) * l.block_len) * l.dh;
                    let dst = ((layer * l.h + head) * l.max_ctx + pos0) * l.dh;
                    kc[dst..dst + rows * l.dh]
                        .copy_from_slice(&self.k[src..src + rows * l.dh]);
                    vc[dst..dst + rows * l.dh]
                        .copy_from_slice(&self.v[src..src + rows * l.dh]);
                }
            }
        }
        Ok((kc, vc))
    }

    /// Full-arena invariant check, for the property tests: refcount
    /// accounting must balance — every block's reference count equals
    /// its table occurrences across live slots plus its prefix-index
    /// pins, blocks with zero references sit in the free list exactly
    /// once, referenced blocks are never in the free list, dead slots
    /// hold nothing, and every table entry is a valid block id.
    pub fn debug_validate(&self) -> Result<()> {
        let total = self.k.len() / self.layout.block_floats();
        let mut in_free = vec![0u32; total];
        for &b in &self.free {
            ensure!((b as usize) < total, "free list holds bogus block {b}");
            in_free[b as usize] += 1;
        }
        let mut occurrences = vec![0u32; total];
        for (i, s) in self.slots.iter().enumerate() {
            ensure!(
                s.live || s.table.is_empty(),
                "dead slot {i} still owns blocks"
            );
            for &b in &s.table {
                ensure!((b as usize) < total, "slot {i} holds bogus block {b}");
                occurrences[b as usize] += 1;
            }
        }
        for b in 0..total {
            let (r, t, p, f) = (self.refs[b], occurrences[b], self.pins[b], in_free[b]);
            ensure!(
                r == t + p,
                "block {b}: refcount {r} != {t} table occurrences + {p} pins"
            );
            if r == 0 {
                ensure!(f == 1, "free block {b} in free list {f} times (expect 1)");
            } else {
                ensure!(f == 0, "referenced block {b} (refcount {r}) in free list");
            }
        }
        Ok(())
    }
}

/// Borrowed paged view of one session's K/V state: the block table plus
/// the shared pools. [`crate::runtime::kernels::attention_paged`] reads
/// through this.
pub struct PagedKv<'a> {
    k: &'a [f32],
    v: &'a [f32],
    table: &'a [u32],
    layout: &'a CacheLayout,
}

impl PagedKv<'_> {
    pub fn heads(&self) -> usize {
        self.layout.h
    }

    pub fn head_dim(&self) -> usize {
        self.layout.dh
    }

    /// Gather the first `valid` positions of one `(layer, head)` pair
    /// into contiguous scratch — exactly the bytes the contiguous
    /// `(n_layers, h, max_ctx, d_head)` tensor holds at
    /// `[layer, head, 0..valid, :]`, so running the attention math on
    /// the gathered scratch is bit-for-bit the contiguous computation.
    /// One contiguous copy per block (the per-`(layer, head)` rows of a
    /// block are adjacent by layout).
    pub fn gather_head(
        &self,
        layer: usize,
        head: usize,
        valid: usize,
        out_k: &mut Vec<f32>,
        out_v: &mut Vec<f32>,
    ) {
        let l = self.layout;
        out_k.clear();
        out_v.clear();
        let bf = l.block_floats();
        let mut row = 0usize;
        for &block in self.table {
            if row >= valid {
                break;
            }
            let rows = (valid - row).min(l.block_len);
            let base = block as usize * bf + ((layer * l.h + head) * l.block_len) * l.dh;
            out_k.extend_from_slice(&self.k[base..base + rows * l.dh]);
            out_v.extend_from_slice(&self.v[base..base + rows * l.dh]);
            row += rows;
        }
        // A short gather means a caller skipped ensure_capacity — that
        // is a backend bug, and silently attending over fewer positions
        // would corrupt outputs, so fail loudly even in release builds.
        assert_eq!(
            row, valid,
            "paged gather: table backs {row} of {valid} positions"
        );
    }
}

/// Reject duplicate handles in one batched call: two lanes advancing
/// the same session in a single step would alias its cache writes.
pub fn ensure_distinct(handles: &[CacheHandle]) -> Result<()> {
    for (n, h) in handles.iter().enumerate() {
        ensure!(
            !handles[..n].contains(h),
            "cache handle {h:?} listed twice in one batch"
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifacts::ModelInfo;

    // d = 4 with h = 2 heads -> dh = 2, so K/V rows are 4 floats.
    fn model() -> ModelInfo {
        ModelInfo {
            vocab: 16,
            d: 4,
            h: 2,
            d_ff: 16,
            n_layers: 2,
            max_ctx: 10,
            eps: 1e-5,
        }
    }

    fn layout(block_len: usize) -> CacheLayout {
        CacheLayout::with_block_len(&model(), block_len)
    }

    #[test]
    fn layout_math() {
        let l = layout(4);
        // block_len * n_layers * h * dh
        assert_eq!(l.block_floats(), 4 * 2 * 2 * 2);
        assert_eq!(l.blocks_for_positions(0), 0);
        assert_eq!(l.blocks_for_positions(1), 1);
        assert_eq!(l.blocks_for_positions(4), 1);
        assert_eq!(l.blocks_for_positions(5), 2);
        assert_eq!(l.blocks_per_session(), 3); // ceil(10 / 4)
        // Block length is clamped to the context window; 0 = default.
        assert_eq!(layout(64).block_len, 10);
        assert_eq!(layout(0).block_len, DEFAULT_BLOCK_LEN.min(10));
    }

    #[test]
    fn alloc_write_gather_round_trip() {
        let mut a = CacheArena::new(layout(4), 6).unwrap();
        let h = a.alloc_session().unwrap();
        for pos in 0..7usize {
            a.ensure_capacity(h, pos).unwrap();
            let k: Vec<f32> = (0..4).map(|i| (pos * 10 + i) as f32).collect();
            let v: Vec<f32> = k.iter().map(|x| -x).collect();
            a.write_kv(h, 1, pos, &k, &v).unwrap();
        }
        assert_eq!(a.session_blocks(h).unwrap(), 2);
        // The paged view gathers exactly the contiguous bytes.
        let view = a.view(h).unwrap();
        let (mut gk, mut gv) = (Vec::new(), Vec::new());
        view.gather_head(1, 1, 7, &mut gk, &mut gv);
        let expect: Vec<f32> = (0..7).flat_map(|p| [(p * 10 + 2) as f32, (p * 10 + 3) as f32]).collect();
        assert_eq!(gk, expect);
        assert_eq!(gv, expect.iter().map(|x| -x).collect::<Vec<_>>());
        // Layer 0 was never written: all zero.
        view.gather_head(0, 0, 7, &mut gk, &mut gv);
        assert!(gk.iter().all(|&x| x == 0.0));
        a.debug_validate().unwrap();
    }

    #[test]
    fn gather_contiguous_matches_dense_indexing() {
        let l = layout(3);
        let mut a = CacheArena::new(l.clone(), 8).unwrap();
        let h = a.alloc_session().unwrap();
        let mut dense_k = vec![0.0f32; l.n_layers * l.h * l.max_ctx * l.dh];
        let mut dense_v = dense_k.clone();
        for pos in 0..l.max_ctx {
            a.ensure_capacity(h, pos).unwrap();
            for layer in 0..l.n_layers {
                let row: Vec<f32> = (0..l.h * l.dh)
                    .map(|i| (layer * 1000 + pos * 10 + i) as f32)
                    .collect();
                let neg: Vec<f32> = row.iter().map(|x| -x).collect();
                a.write_kv(h, layer, pos, &row, &neg).unwrap();
                for head in 0..l.h {
                    let dst = ((layer * l.h + head) * l.max_ctx + pos) * l.dh;
                    dense_k[dst..dst + l.dh]
                        .copy_from_slice(&row[head * l.dh..(head + 1) * l.dh]);
                    dense_v[dst..dst + l.dh]
                        .copy_from_slice(&neg[head * l.dh..(head + 1) * l.dh]);
                }
            }
        }
        assert_eq!(a.gather_contiguous(h).unwrap(), (dense_k, dense_v));
    }

    #[test]
    fn handles_are_generation_checked() {
        let mut a = CacheArena::new(layout(4), 4).unwrap();
        let h = a.alloc_session().unwrap();
        a.ensure_capacity(h, 0).unwrap();
        a.free_session(h).unwrap();
        // Double free and every other op on a stale handle must error.
        assert!(a.free_session(h).is_err());
        assert!(a.ensure_capacity(h, 0).is_err());
        assert!(a.view(h).is_err());
        assert!(a.session_blocks(h).is_err());
        assert!(!a.is_live(h));
        // The freed slot's reuse yields a DIFFERENT handle.
        let h2 = a.alloc_session().unwrap();
        assert_ne!(h.key(), h2.key());
        assert!(a.is_live(h2));
        a.debug_validate().unwrap();
    }

    #[test]
    fn exhaustion_and_reuse() {
        let mut a = CacheArena::new(layout(4), 2).unwrap();
        let h1 = a.alloc_session().unwrap();
        let h2 = a.alloc_session().unwrap();
        a.ensure_capacity(h1, 3).unwrap(); // block 0
        a.ensure_capacity(h2, 3).unwrap(); // block 1
        assert_eq!(a.status().free_blocks, 0);
        // Pool dry: growing either session fails...
        assert!(a.ensure_capacity(h1, 4).is_err());
        // ...but freeing returns capacity that is immediately reusable.
        a.free_session(h2).unwrap();
        assert_eq!(a.status().free_blocks, 1);
        a.ensure_capacity(h1, 4).unwrap();
        assert_eq!(a.session_blocks(h1).unwrap(), 2);
        a.debug_validate().unwrap();
    }

    #[test]
    fn blocks_are_zeroed_on_reuse() {
        let mut a = CacheArena::new(layout(4), 1).unwrap();
        let h = a.alloc_session().unwrap();
        a.ensure_capacity(h, 0).unwrap();
        a.write_kv(h, 0, 0, &[7.0; 4], &[9.0; 4]).unwrap();
        a.free_session(h).unwrap();
        let h = a.alloc_session().unwrap();
        a.ensure_capacity(h, 0).unwrap();
        let (k, v) = a.gather_contiguous(h).unwrap();
        assert!(k.iter().all(|&x| x == 0.0) && v.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn duplicate_handles_rejected() {
        let mut a = CacheArena::new(layout(4), 4).unwrap();
        let h1 = a.alloc_session().unwrap();
        let h2 = a.alloc_session().unwrap();
        assert!(ensure_distinct(&[h1, h2]).is_ok());
        assert!(ensure_distinct(&[h1, h2, h1]).is_err());
    }

    #[test]
    fn shared_blocks_return_to_free_only_at_refcount_zero() {
        // The preemption regression: a session that adopted shared
        // prefix blocks is freed — the still-referenced blocks must NOT
        // land in the free list.
        let mut a = CacheArena::new(layout(4), 4).unwrap();
        let donor = a.alloc_session().unwrap();
        a.ensure_capacity(donor, 7).unwrap(); // blocks 0, 1
        let chain = a.session_table(donor).unwrap();
        a.pin_block(chain[0]).unwrap(); // prefix index pins block 0

        let s = a.alloc_session().unwrap();
        a.share_blocks(s, &chain).unwrap();
        assert_eq!(a.block_refs(chain[0]), 3); // donor + pin + s
        assert_eq!(a.block_refs(chain[1]), 2); // donor + s
        let free_before = a.status().free_blocks;
        a.free_session(s).unwrap(); // preempt the sharer
        assert_eq!(
            a.status().free_blocks,
            free_before,
            "freeing a sharer must not release still-referenced blocks"
        );
        a.debug_validate().unwrap();

        a.free_session(donor).unwrap();
        // Block 1's last ref was the donor; block 0 is still pinned.
        assert_eq!(a.status().free_blocks, free_before + 1);
        assert_eq!(a.block_refs(chain[0]), 1);
        a.unpin_block(chain[0]).unwrap();
        assert_eq!(a.status().free_blocks, free_before + 2);
        assert!(a.unpin_block(chain[0]).is_err(), "double unpin must error");
        a.debug_validate().unwrap();
    }

    #[test]
    fn cow_copies_kept_rows_and_zeroes_the_rest() {
        let mut a = CacheArena::new(layout(4), 4).unwrap();
        let donor = a.alloc_session().unwrap();
        for pos in 0..4usize {
            a.ensure_capacity(donor, pos).unwrap();
            for layer in 0..2 {
                let row: Vec<f32> =
                    (0..4).map(|i| (layer * 100 + pos * 10 + i) as f32).collect();
                let neg: Vec<f32> = row.iter().map(|x| -x).collect();
                a.write_kv(donor, layer, pos, &row, &neg).unwrap();
            }
        }
        let chain = a.session_table(donor).unwrap();
        let s = a.alloc_session().unwrap();
        a.share_blocks(s, &chain).unwrap();
        // Copy keeping 2 of 4 rows: rows 0-1 must be the donor's bytes,
        // rows 2-3 must be zero (cold-prefill state), donor untouched.
        assert!(a.cow_block(s, 0, 2).unwrap());
        let (dk, dv) = a.gather_contiguous(donor).unwrap();
        let (sk, sv) = a.gather_contiguous(s).unwrap();
        let l = a.layout().clone();
        for layer in 0..l.n_layers {
            for head in 0..l.h {
                for pos in 0..4usize {
                    let at = ((layer * l.h + head) * l.max_ctx + pos) * l.dh;
                    if pos < 2 {
                        assert_eq!(sk[at..at + l.dh], dk[at..at + l.dh]);
                        assert_eq!(sv[at..at + l.dh], dv[at..at + l.dh]);
                    } else {
                        assert!(sk[at..at + l.dh].iter().all(|&x| x == 0.0));
                        assert!(sv[at..at + l.dh].iter().all(|&x| x == 0.0));
                    }
                }
            }
        }
        // The copy made the entry exclusive: a second cow is a no-op.
        assert!(!a.cow_block(s, 0, 2).unwrap());
        assert_eq!(a.block_refs(chain[0]), 1); // donor only again
        a.debug_validate().unwrap();
    }

    #[test]
    fn writes_into_shared_blocks_are_rejected_until_cow() {
        let mut a = CacheArena::new(layout(4), 4).unwrap();
        let donor = a.alloc_session().unwrap();
        a.ensure_capacity(donor, 3).unwrap();
        let chain = a.session_table(donor).unwrap();
        let s = a.alloc_session().unwrap();
        a.share_blocks(s, &chain).unwrap();
        // Direct write into the shared block: rejected.
        assert!(a.write_kv(s, 0, 1, &[1.0; 4], &[1.0; 4]).is_err());
        // ensure_capacity for a position INSIDE the shared block
        // performs the COW (keeping the rows before it), unblocking it.
        a.ensure_capacity(s, 1).unwrap();
        a.write_kv(s, 0, 1, &[1.0; 4], &[1.0; 4]).unwrap();
        // The donor still owns the original, unmodified block.
        let (dk, _) = a.gather_contiguous(donor).unwrap();
        assert!(dk.iter().all(|&x| x == 0.0));
        a.debug_validate().unwrap();
    }

    #[test]
    fn cow_failure_is_all_or_nothing() {
        // 2-block arena: donor owns both; sharer adopts both; a COW has
        // no free block to copy into — the error must leave the table,
        // refcounts and free list untouched.
        let mut a = CacheArena::new(layout(4), 2).unwrap();
        let donor = a.alloc_session().unwrap();
        a.ensure_capacity(donor, 7).unwrap();
        let chain = a.session_table(donor).unwrap();
        let s = a.alloc_session().unwrap();
        a.share_blocks(s, &chain).unwrap();
        assert!(a.cow_block(s, 0, 2).is_err());
        assert!(a.ensure_capacity(s, 1).is_err()); // same via the write path
        assert_eq!(a.session_table(s).unwrap(), chain);
        assert_eq!(a.block_refs(chain[0]), 2);
        a.debug_validate().unwrap();
    }

    #[test]
    fn share_rejects_free_duplicate_and_bogus_blocks() {
        let mut a = CacheArena::new(layout(4), 4).unwrap();
        let donor = a.alloc_session().unwrap();
        a.ensure_capacity(donor, 3).unwrap();
        let chain = a.session_table(donor).unwrap();
        let s = a.alloc_session().unwrap();
        assert!(a.share_blocks(s, &[99]).is_err(), "bogus id");
        assert!(a.share_blocks(s, &[3]).is_err(), "free block");
        assert!(
            a.share_blocks(s, &[chain[0], chain[0]]).is_err(),
            "duplicate in one call"
        );
        a.share_blocks(s, &chain).unwrap();
        assert!(
            a.share_blocks(s, &chain).is_err(),
            "already in the session's table"
        );
        // Failed shares left the accounting clean.
        a.debug_validate().unwrap();
        // Pinning a free block is rejected too.
        assert!(a.pin_block(3).is_err());
    }

    #[test]
    fn obtainable_counts_shared_blocks_once() {
        let mut a = CacheArena::new(layout(4), 6).unwrap();
        let s1 = a.alloc_session().unwrap();
        a.ensure_capacity(s1, 7).unwrap(); // 2 exclusive blocks
        let chain = a.session_table(s1).unwrap();
        let s2 = a.alloc_session().unwrap();
        a.share_blocks(s2, &chain).unwrap();
        a.pin_block(chain[0]).unwrap();
        // 4 free + 2 shared-but-fully-held-by-{s1, s2, pins} = 6.
        assert_eq!(a.obtainable_with(&[s1, s2]), 6);
        // With only s2 in the loop, s1's references make both blocks
        // unobtainable (a naive free + table-len sum would say 6).
        assert_eq!(a.obtainable_with(&[s2]), 4);
        assert_eq!(a.obtainable_with(&[]), 4);
    }

    #[test]
    fn split_partitions_deterministically() {
        // 14 blocks over 4 shards: base 3, remainder to the lowest ids.
        let shards = CacheArena::split(layout(4), 14, 4).unwrap();
        let caps: Vec<usize> = shards.iter().map(|a| a.status().total_blocks).collect();
        assert_eq!(caps, vec![4, 4, 3, 3]);
        assert_eq!(caps.iter().sum::<usize>(), 14);
        // Even split stays even; a second split of the same inputs is
        // byte-for-byte the same partition.
        let again: Vec<usize> = CacheArena::split(layout(4), 14, 4)
            .unwrap()
            .iter()
            .map(|a| a.status().total_blocks)
            .collect();
        assert_eq!(caps, again);
        assert_eq!(
            CacheArena::split(layout(4), 8, 2)
                .unwrap()
                .iter()
                .map(|a| a.status().total_blocks)
                .collect::<Vec<_>>(),
            vec![4, 4]
        );
        // Degenerate splits are rejected up front.
        assert!(CacheArena::split(layout(4), 3, 4).is_err());
        assert!(CacheArena::split(layout(4), 4, 0).is_err());
    }

    #[test]
    fn split_shards_are_independent_arenas() {
        // Blocks allocated on one shard never appear in another shard's
        // accounting: each shard's free list, refcounts and sessions are
        // self-contained, which is what makes a shard safe to move to a
        // worker thread without any locking.
        let mut shards = CacheArena::split(layout(4), 8, 2).unwrap();
        let h0 = shards[0].alloc_session().unwrap();
        shards[0].ensure_capacity(h0, 7).unwrap(); // 2 blocks on shard 0
        assert_eq!(shards[0].status().used_blocks, 2);
        assert_eq!(shards[1].status().used_blocks, 0);
        // Shard-local block ids start at 0 on every shard.
        let h1 = shards[1].alloc_session().unwrap();
        shards[1].ensure_capacity(h1, 0).unwrap();
        assert_eq!(shards[1].session_table(h1).unwrap(), vec![0]);
        for s in &shards {
            s.debug_validate().unwrap();
        }
        // A shard is Send by construction (plain Vec storage).
        fn assert_send<T: Send>() {}
        assert_send::<CacheArena>();
    }

    #[test]
    fn write_requires_backing_block() {
        let mut a = CacheArena::new(layout(4), 4).unwrap();
        let h = a.alloc_session().unwrap();
        assert!(a.write_kv(h, 0, 0, &[0.0; 4], &[0.0; 4]).is_err());
        a.ensure_capacity(h, 0).unwrap();
        a.write_kv(h, 0, 0, &[0.0; 4], &[0.0; 4]).unwrap();
        // Position 4 lives in block 1, not yet claimed.
        assert!(a.write_kv(h, 0, 4, &[0.0; 4], &[0.0; 4]).is_err());
        // Bounds.
        assert!(a.ensure_capacity(h, 10).is_err());
        assert!(a.write_kv(h, 2, 0, &[0.0; 4], &[0.0; 4]).is_err());
        assert!(a.write_kv(h, 0, 0, &[0.0; 3], &[0.0; 3]).is_err());
    }
}
