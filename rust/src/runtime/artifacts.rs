//! AOT artifact loading: `manifest.json` (model config + parameter
//! layout), `weights.bin` (flat f32 LE), `golden.json` (reference
//! generation the runtime must reproduce), `decode_step.hlo.txt`.
//!
//! The manifest is self-describing: argument order of the HLO entry is
//! `params... , k_caches, v_caches, token_id, pos`, exactly as
//! `python/compile/aot.py` lowered it. Parsed with the in-crate JSON
//! parser (`util::json`).

use crate::util::error::{bail, ensure, Context, Result};
use crate::util::json::{self, Json};
use std::path::{Path, PathBuf};

/// Model hyper-parameters recorded by the AOT step (mirror of
/// `python/compile/model.py::ModelConfig`).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelInfo {
    pub vocab: usize,
    pub d: usize,
    pub h: usize,
    pub d_ff: usize,
    pub n_layers: usize,
    pub max_ctx: usize,
    pub eps: f64,
}

impl ModelInfo {
    fn from_json(v: &Json) -> Result<Self> {
        Ok(Self {
            vocab: v.get("vocab")?.as_usize()?,
            d: v.get("d")?.as_usize()?,
            h: v.get("h")?.as_usize()?,
            d_ff: v.get("d_ff")?.as_usize()?,
            n_layers: v.get("n_layers")?.as_usize()?,
            max_ctx: v.get("max_ctx")?.as_usize()?,
            eps: v.get("eps")?.as_f64()?,
        })
    }
}

/// One parameter's placement in weights.bin.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub numel: usize,
}

impl ParamEntry {
    fn from_json(v: &Json) -> Result<Self> {
        Ok(Self {
            name: v.get("name")?.as_str()?.to_string(),
            shape: v
                .get("shape")?
                .as_arr()?
                .iter()
                .map(|d| d.as_usize())
                .collect::<Result<_>>()?,
            offset: v.get("offset")?.as_usize()?,
            numel: v.get("numel")?.as_usize()?,
        })
    }
}

/// Parsed manifest.json.
#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    pub model: ModelInfo,
    pub seed: u64,
    pub entry: String,
    pub arg_order: Vec<String>,
    pub outputs: Vec<String>,
    pub params: Vec<ParamEntry>,
    pub total_floats: usize,
}

impl Manifest {
    fn from_json(v: &Json) -> Result<Self> {
        let strings = |key: &str| -> Result<Vec<String>> {
            v.get(key)?
                .as_arr()?
                .iter()
                .map(|s| Ok(s.as_str()?.to_string()))
                .collect()
        };
        Ok(Self {
            model: ModelInfo::from_json(v.get("model")?)?,
            seed: v.get("seed")?.as_i64()? as u64,
            entry: v.get("entry")?.as_str()?.to_string(),
            arg_order: strings("arg_order")?,
            outputs: strings("outputs")?,
            params: v
                .get("params")?
                .as_arr()?
                .iter()
                .map(ParamEntry::from_json)
                .collect::<Result<_>>()?,
            total_floats: v.get("total_floats")?.as_usize()?,
        })
    }
}

/// Parsed golden.json.
#[derive(Debug, Clone, PartialEq)]
pub struct Golden {
    pub prompt: Vec<i32>,
    pub n_new: usize,
    pub tokens: Vec<i32>,
    pub first_logits_prefix: Vec<f32>,
    pub first_logits_l2: f64,
}

impl Golden {
    fn from_json(v: &Json) -> Result<Self> {
        let i32s = |key: &str| -> Result<Vec<i32>> {
            Ok(v.get(key)?
                .as_i64_vec()?
                .into_iter()
                .map(|x| x as i32)
                .collect())
        };
        Ok(Self {
            prompt: i32s("prompt")?,
            n_new: v.get("n_new")?.as_usize()?,
            tokens: i32s("tokens")?,
            first_logits_prefix: v
                .get("first_logits_prefix")?
                .as_f64_vec()?
                .into_iter()
                .map(|x| x as f32)
                .collect(),
            first_logits_l2: v.get("first_logits_l2")?.as_f64()?,
        })
    }
}

/// All artifacts of one compiled model variant.
#[derive(Debug, Clone)]
pub struct Artifacts {
    pub dir: PathBuf,
    pub manifest: Manifest,
    pub golden: Golden,
    /// Flat little-endian f32 weights in manifest order.
    pub weights: Vec<f32>,
}

impl Artifacts {
    /// Load and validate a full artifact directory.
    pub fn load<P: AsRef<Path>>(dir: P) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json", dir.display()))?;
        let manifest = Manifest::from_json(&json::parse(&manifest_text)?)
            .context("parsing manifest.json")?;
        let golden_text = std::fs::read_to_string(dir.join("golden.json"))
            .context("reading golden.json")?;
        let golden =
            Golden::from_json(&json::parse(&golden_text)?).context("parsing golden.json")?;
        let raw = std::fs::read(dir.join("weights.bin")).context("reading weights.bin")?;
        if raw.len() != manifest.total_floats * 4 {
            bail!(
                "weights.bin is {} bytes, manifest expects {}",
                raw.len(),
                manifest.total_floats * 4
            );
        }
        let weights: Vec<f32> = raw
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();
        let a = Self {
            dir,
            manifest,
            golden,
            weights,
        };
        a.validate()?;
        Ok(a)
    }

    /// Internal consistency checks (offsets contiguous, arg order sane).
    pub fn validate(&self) -> Result<()> {
        let mut end = 0usize;
        for p in &self.manifest.params {
            if p.offset != end {
                bail!("param {} offset {} != expected {}", p.name, p.offset, end);
            }
            let numel: usize = p.shape.iter().product::<usize>().max(1);
            if numel != p.numel {
                bail!("param {} numel mismatch", p.name);
            }
            end = p.offset + p.numel;
        }
        if end != self.manifest.total_floats {
            bail!(
                "params cover {} floats, manifest says {}",
                end,
                self.manifest.total_floats
            );
        }
        let tail: Vec<&str> = self
            .manifest
            .arg_order
            .iter()
            .rev()
            .take(4)
            .map(String::as_str)
            .collect();
        if tail != ["pos", "token_id", "v_caches", "k_caches"] {
            bail!("unexpected arg tail: {tail:?}");
        }
        if self.manifest.arg_order.len() != self.manifest.params.len() + 4 {
            bail!("arg_order/params length mismatch");
        }
        if self.golden.tokens.len() != self.golden.prompt.len() + self.golden.n_new {
            bail!("golden token count mismatch");
        }
        Ok(())
    }

    /// Slice of one parameter's data.
    pub fn param_data(&self, p: &ParamEntry) -> &[f32] {
        &self.weights[p.offset..p.offset + p.numel]
    }

    /// Path to the decode-step HLO text.
    pub fn hlo_path(&self) -> PathBuf {
        self.dir.join("decode_step.hlo.txt")
    }

    /// KV cache shape: (n_layers, h, max_ctx, d_head).
    pub fn cache_shape(&self) -> [usize; 4] {
        let m = &self.manifest.model;
        [m.n_layers, m.h, m.max_ctx, m.d / m.h]
    }

    /// Build a fully in-memory synthetic artifact set: a tiny random
    /// 1-bit decoder in the exact manifest layout `python/compile/aot.py`
    /// emits (same parameter order and naming as `model.py`), with the
    /// golden generation produced by the in-crate reference executor.
    ///
    /// This is what makes the functional path (decoder, serving, CLI
    /// `serve`/`validate`, runtime benches) exercisable OFFLINE with no
    /// `make artifacts` step. There is no HLO text, so the PJRT backend
    /// cannot load synthetic artifacts — use the real AOT output for
    /// that.
    pub fn synthetic(seed: u64) -> Result<Self> {
        // Tiny-but-real decoder shape (small enough for debug-mode test
        // runs; same structure as model.py's TINY config).
        Self::synthetic_with(
            seed,
            ModelInfo {
                vocab: 64,
                d: 32,
                h: 4,
                d_ff: 64,
                n_layers: 2,
                max_ctx: 32,
                eps: 1e-5,
            },
        )
    }

    /// [`Artifacts::synthetic`] with an explicit model shape — lets the
    /// batching tests and the `runtime_batching` bench synthesize models
    /// large enough that the per-step weight traversal dominates (the
    /// regime the paper's batched-throughput argument is about).
    pub fn synthetic_with(seed: u64, model: ModelInfo) -> Result<Self> {
        use crate::util::rng::Rng;

        ensure!(model.d % model.h == 0, "d must be divisible by h");
        ensure!(model.vocab >= 8, "synthetic golden needs vocab >= 8");
        ensure!(model.max_ctx >= 8, "synthetic golden needs max_ctx >= 8");
        let mut rng = Rng::new(seed ^ 0x5EED_1B17_C0DE_CAFE);

        struct Builder {
            params: Vec<ParamEntry>,
            weights: Vec<f32>,
        }
        impl Builder {
            fn push(&mut self, name: &str, shape: Vec<usize>, data: Vec<f32>) {
                let numel = shape.iter().product::<usize>().max(1);
                assert_eq!(numel, data.len(), "{name}");
                self.params.push(ParamEntry {
                    name: name.to_string(),
                    shape,
                    offset: self.weights.len(),
                    numel,
                });
                self.weights.extend_from_slice(&data);
            }
        }

        // BitNet-b1.58 ternary quantization of a random master weight
        // (ref.py::weight_quant_ternary): scale = mean(|W|),
        // W_q = clip(round(W/scale), -1, 1).
        let ternary = |rng: &mut Rng, fan_in: usize, numel: usize| -> (Vec<f32>, f32) {
            let master: Vec<f32> = (0..numel)
                .map(|_| (rng.normal() / (fan_in as f64).sqrt()) as f32)
                .collect();
            let scale = (master.iter().map(|w| w.abs()).sum::<f32>()
                / numel as f32)
                .max(1e-5);
            let q: Vec<f32> = master
                .iter()
                .map(|w| (w / scale).round().clamp(-1.0, 1.0))
                .collect();
            (q, scale)
        };

        let (d, dff, v) = (model.d, model.d_ff, model.vocab);
        let mut b = Builder {
            params: Vec::new(),
            weights: Vec::new(),
        };
        for layer in 0..model.n_layers {
            let l = format!("layer{layer}.");
            b.push(&format!("{l}ln1_gamma"), vec![d], vec![1.0; d]);
            for name in ["wq", "wk", "wv", "wx"] {
                let (q, s) = ternary(&mut rng, d, d * d);
                b.push(&format!("{l}{name}"), vec![d, d], q);
                b.push(&format!("{l}{name}_scale"), vec![], vec![s]);
            }
            b.push(&format!("{l}ln2_gamma"), vec![d], vec![1.0; d]);
            let (q, s) = ternary(&mut rng, d, d * dff);
            b.push(&format!("{l}w_in"), vec![d, dff], q);
            b.push(&format!("{l}w_in_scale"), vec![], vec![s]);
            let (q, s) = ternary(&mut rng, dff, dff * d);
            b.push(&format!("{l}w_out"), vec![dff, d], q);
            b.push(&format!("{l}w_out_scale"), vec![], vec![s]);
        }
        let emb: Vec<f32> = (0..v * d).map(|_| 0.02 * rng.normal() as f32).collect();
        b.push("embedding", vec![v, d], emb);
        b.push("lnf_gamma", vec![d], vec![1.0; d]);
        let (q, s) = ternary(&mut rng, d, d * v);
        b.push("w_head", vec![d, v], q);
        b.push("w_head_scale", vec![], vec![s]);

        let mut arg_order: Vec<String> = b.params.iter().map(|p| p.name.clone()).collect();
        arg_order.extend(
            ["k_caches", "v_caches", "token_id", "pos"]
                .iter()
                .map(|s| s.to_string()),
        );
        let total_floats = b.weights.len();

        let prompt: Vec<i32> = vec![1, 2, 3];
        let n_new = 4usize;
        let mut a = Artifacts {
            dir: PathBuf::from("<synthetic>"),
            manifest: Manifest {
                model,
                seed,
                entry: "decode_step".to_string(),
                arg_order,
                outputs: vec![
                    "logits".to_string(),
                    "k_caches".to_string(),
                    "v_caches".to_string(),
                ],
                params: b.params,
                total_floats,
            },
            golden: Golden {
                prompt: prompt.clone(),
                n_new: 0,
                tokens: prompt.clone(),
                first_logits_prefix: Vec::new(),
                first_logits_l2: 1.0,
            },
            weights: b.weights,
        };
        a.validate().context("synthetic manifest inconsistent")?;

        // Produce the golden generation through the real decode loop
        // (TinyDecoder on the reference backend) — one source of truth
        // for greedy decoding incl. argmax tie-breaking, and the same
        // numerics the default backend runs, so `validate` closes the
        // loop end to end.
        let engine = crate::runtime::Engine::load_with(
            a.clone(),
            crate::runtime::BackendKind::Reference,
        )?;
        let mut dec = crate::runtime::TinyDecoder::new(&engine)?;
        let mut first_logits: Vec<f32> = Vec::new();
        for (pos, &t) in prompt.iter().enumerate() {
            dec.feed(t)?;
            if pos == 0 {
                first_logits = dec.last_logits.clone();
            }
        }
        for _ in 0..n_new {
            let next = dec.greedy_next();
            dec.feed(next)?;
        }
        let tokens = dec.tokens.clone();
        let l2: f64 = first_logits
            .iter()
            .map(|&x| (x as f64) * (x as f64))
            .sum::<f64>()
            .sqrt();
        a.golden = Golden {
            prompt,
            n_new,
            tokens,
            first_logits_prefix: first_logits.into_iter().take(8).collect(),
            first_logits_l2: l2,
        };
        a.validate()?;
        Ok(a)
    }
}

/// Default artifact directory relative to the repo root.
pub fn default_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_available() -> bool {
        default_dir().join("manifest.json").exists()
    }

    #[test]
    fn load_and_validate_real_artifacts() {
        if !artifacts_available() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let a = Artifacts::load(default_dir()).unwrap();
        assert_eq!(a.manifest.entry, "decode_step");
        assert_eq!(a.manifest.model.d, 256);
        assert_eq!(a.cache_shape(), [2, 4, 128, 64]);
        assert_eq!(a.weights.len(), a.manifest.total_floats);
        // Ternary projection weights are in {-1, 0, 1}.
        let wq = a
            .manifest
            .params
            .iter()
            .find(|p| p.name == "layer0.wq")
            .unwrap();
        for &v in a.param_data(wq) {
            assert!(v == -1.0 || v == 0.0 || v == 1.0);
        }
    }

    #[test]
    fn corrupt_weights_rejected() {
        if !artifacts_available() {
            return;
        }
        let tmp = std::env::temp_dir().join(format!("pimllm-art-{}", std::process::id()));
        std::fs::create_dir_all(&tmp).unwrap();
        for f in ["manifest.json", "golden.json"] {
            std::fs::copy(default_dir().join(f), tmp.join(f)).unwrap();
        }
        std::fs::write(tmp.join("weights.bin"), [0u8; 16]).unwrap();
        let result = Artifacts::load(&tmp);
        std::fs::remove_dir_all(&tmp).ok();
        assert!(result.is_err());
    }

    #[test]
    fn synthetic_artifacts_validate_and_are_deterministic() {
        let a = Artifacts::synthetic(7).unwrap();
        assert_eq!(a.manifest.entry, "decode_step");
        assert_eq!(
            a.golden.tokens.len(),
            a.golden.prompt.len() + a.golden.n_new
        );
        assert_eq!(a.weights.len(), a.manifest.total_floats);
        // Ternary projection weights are in {-1, 0, 1}.
        let wq = a
            .manifest
            .params
            .iter()
            .find(|p| p.name == "layer0.wq")
            .unwrap();
        for &w in a.param_data(wq) {
            assert!(w == -1.0 || w == 0.0 || w == 1.0);
        }
        // Same seed -> bit-identical artifacts; different seed differs.
        let b = Artifacts::synthetic(7).unwrap();
        assert_eq!(a.weights, b.weights);
        assert_eq!(a.golden.tokens, b.golden.tokens);
        let c = Artifacts::synthetic(8).unwrap();
        assert_ne!(a.weights, c.weights);
    }

    #[test]
    fn sized_synthetic_artifacts_validate() {
        let a = Artifacts::synthetic_with(
            3,
            ModelInfo {
                vocab: 32,
                d: 16,
                h: 2,
                d_ff: 32,
                n_layers: 1,
                max_ctx: 16,
                eps: 1e-5,
            },
        )
        .unwrap();
        assert_eq!(a.manifest.model.d, 16);
        assert_eq!(a.cache_shape(), [1, 2, 16, 8]);
        assert_eq!(
            a.golden.tokens.len(),
            a.golden.prompt.len() + a.golden.n_new
        );
        // Bad shapes are rejected up front.
        let bad = ModelInfo {
            vocab: 32,
            d: 10,
            h: 4,
            d_ff: 16,
            n_layers: 1,
            max_ctx: 16,
            eps: 1e-5,
        };
        assert!(Artifacts::synthetic_with(3, bad).is_err());
    }

    #[test]
    fn golden_token_count_checked() {
        if !artifacts_available() {
            return;
        }
        let mut a = Artifacts::load(default_dir()).unwrap();
        a.golden.tokens.pop();
        assert!(a.validate().is_err());
    }
}
