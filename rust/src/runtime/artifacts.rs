//! AOT artifact loading: `manifest.json` (model config + parameter
//! layout), `weights.bin` (flat f32 LE), `golden.json` (reference
//! generation the runtime must reproduce), `decode_step.hlo.txt`.
//!
//! The manifest is self-describing: argument order of the HLO entry is
//! `params... , k_caches, v_caches, token_id, pos`, exactly as
//! `python/compile/aot.py` lowered it. Parsed with the in-crate JSON
//! parser (`util::json`).

use crate::util::json::{self, Json};
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// Model hyper-parameters recorded by the AOT step (mirror of
/// `python/compile/model.py::ModelConfig`).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelInfo {
    pub vocab: usize,
    pub d: usize,
    pub h: usize,
    pub d_ff: usize,
    pub n_layers: usize,
    pub max_ctx: usize,
    pub eps: f64,
}

impl ModelInfo {
    fn from_json(v: &Json) -> Result<Self> {
        Ok(Self {
            vocab: v.get("vocab")?.as_usize()?,
            d: v.get("d")?.as_usize()?,
            h: v.get("h")?.as_usize()?,
            d_ff: v.get("d_ff")?.as_usize()?,
            n_layers: v.get("n_layers")?.as_usize()?,
            max_ctx: v.get("max_ctx")?.as_usize()?,
            eps: v.get("eps")?.as_f64()?,
        })
    }
}

/// One parameter's placement in weights.bin.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub numel: usize,
}

impl ParamEntry {
    fn from_json(v: &Json) -> Result<Self> {
        Ok(Self {
            name: v.get("name")?.as_str()?.to_string(),
            shape: v
                .get("shape")?
                .as_arr()?
                .iter()
                .map(|d| d.as_usize())
                .collect::<Result<_>>()?,
            offset: v.get("offset")?.as_usize()?,
            numel: v.get("numel")?.as_usize()?,
        })
    }
}

/// Parsed manifest.json.
#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    pub model: ModelInfo,
    pub seed: u64,
    pub entry: String,
    pub arg_order: Vec<String>,
    pub outputs: Vec<String>,
    pub params: Vec<ParamEntry>,
    pub total_floats: usize,
}

impl Manifest {
    fn from_json(v: &Json) -> Result<Self> {
        let strings = |key: &str| -> Result<Vec<String>> {
            v.get(key)?
                .as_arr()?
                .iter()
                .map(|s| Ok(s.as_str()?.to_string()))
                .collect()
        };
        Ok(Self {
            model: ModelInfo::from_json(v.get("model")?)?,
            seed: v.get("seed")?.as_i64()? as u64,
            entry: v.get("entry")?.as_str()?.to_string(),
            arg_order: strings("arg_order")?,
            outputs: strings("outputs")?,
            params: v
                .get("params")?
                .as_arr()?
                .iter()
                .map(ParamEntry::from_json)
                .collect::<Result<_>>()?,
            total_floats: v.get("total_floats")?.as_usize()?,
        })
    }
}

/// Parsed golden.json.
#[derive(Debug, Clone, PartialEq)]
pub struct Golden {
    pub prompt: Vec<i32>,
    pub n_new: usize,
    pub tokens: Vec<i32>,
    pub first_logits_prefix: Vec<f32>,
    pub first_logits_l2: f64,
}

impl Golden {
    fn from_json(v: &Json) -> Result<Self> {
        let i32s = |key: &str| -> Result<Vec<i32>> {
            Ok(v.get(key)?
                .as_i64_vec()?
                .into_iter()
                .map(|x| x as i32)
                .collect())
        };
        Ok(Self {
            prompt: i32s("prompt")?,
            n_new: v.get("n_new")?.as_usize()?,
            tokens: i32s("tokens")?,
            first_logits_prefix: v
                .get("first_logits_prefix")?
                .as_f64_vec()?
                .into_iter()
                .map(|x| x as f32)
                .collect(),
            first_logits_l2: v.get("first_logits_l2")?.as_f64()?,
        })
    }
}

/// All artifacts of one compiled model variant.
#[derive(Debug, Clone)]
pub struct Artifacts {
    pub dir: PathBuf,
    pub manifest: Manifest,
    pub golden: Golden,
    /// Flat little-endian f32 weights in manifest order.
    pub weights: Vec<f32>,
}

impl Artifacts {
    /// Load and validate a full artifact directory.
    pub fn load<P: AsRef<Path>>(dir: P) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json", dir.display()))?;
        let manifest = Manifest::from_json(&json::parse(&manifest_text)?)
            .context("parsing manifest.json")?;
        let golden_text = std::fs::read_to_string(dir.join("golden.json"))
            .context("reading golden.json")?;
        let golden =
            Golden::from_json(&json::parse(&golden_text)?).context("parsing golden.json")?;
        let raw = std::fs::read(dir.join("weights.bin")).context("reading weights.bin")?;
        if raw.len() != manifest.total_floats * 4 {
            bail!(
                "weights.bin is {} bytes, manifest expects {}",
                raw.len(),
                manifest.total_floats * 4
            );
        }
        let weights: Vec<f32> = raw
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();
        let a = Self {
            dir,
            manifest,
            golden,
            weights,
        };
        a.validate()?;
        Ok(a)
    }

    /// Internal consistency checks (offsets contiguous, arg order sane).
    pub fn validate(&self) -> Result<()> {
        let mut end = 0usize;
        for p in &self.manifest.params {
            if p.offset != end {
                bail!("param {} offset {} != expected {}", p.name, p.offset, end);
            }
            let numel: usize = p.shape.iter().product::<usize>().max(1);
            if numel != p.numel {
                bail!("param {} numel mismatch", p.name);
            }
            end = p.offset + p.numel;
        }
        if end != self.manifest.total_floats {
            bail!(
                "params cover {} floats, manifest says {}",
                end,
                self.manifest.total_floats
            );
        }
        let tail: Vec<&str> = self
            .manifest
            .arg_order
            .iter()
            .rev()
            .take(4)
            .map(String::as_str)
            .collect();
        if tail != ["pos", "token_id", "v_caches", "k_caches"] {
            bail!("unexpected arg tail: {tail:?}");
        }
        if self.manifest.arg_order.len() != self.manifest.params.len() + 4 {
            bail!("arg_order/params length mismatch");
        }
        if self.golden.tokens.len() != self.golden.prompt.len() + self.golden.n_new {
            bail!("golden token count mismatch");
        }
        Ok(())
    }

    /// Slice of one parameter's data.
    pub fn param_data(&self, p: &ParamEntry) -> &[f32] {
        &self.weights[p.offset..p.offset + p.numel]
    }

    /// Path to the decode-step HLO text.
    pub fn hlo_path(&self) -> PathBuf {
        self.dir.join("decode_step.hlo.txt")
    }

    /// KV cache shape: (n_layers, h, max_ctx, d_head).
    pub fn cache_shape(&self) -> [usize; 4] {
        let m = &self.manifest.model;
        [m.n_layers, m.h, m.max_ctx, m.d / m.h]
    }
}

/// Default artifact directory relative to the repo root.
pub fn default_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_available() -> bool {
        default_dir().join("manifest.json").exists()
    }

    #[test]
    fn load_and_validate_real_artifacts() {
        if !artifacts_available() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let a = Artifacts::load(default_dir()).unwrap();
        assert_eq!(a.manifest.entry, "decode_step");
        assert_eq!(a.manifest.model.d, 256);
        assert_eq!(a.cache_shape(), [2, 4, 128, 64]);
        assert_eq!(a.weights.len(), a.manifest.total_floats);
        // Ternary projection weights are in {-1, 0, 1}.
        let wq = a
            .manifest
            .params
            .iter()
            .find(|p| p.name == "layer0.wq")
            .unwrap();
        for &v in a.param_data(wq) {
            assert!(v == -1.0 || v == 0.0 || v == 1.0);
        }
    }

    #[test]
    fn corrupt_weights_rejected() {
        if !artifacts_available() {
            return;
        }
        let tmp = std::env::temp_dir().join(format!("pimllm-art-{}", std::process::id()));
        std::fs::create_dir_all(&tmp).unwrap();
        for f in ["manifest.json", "golden.json"] {
            std::fs::copy(default_dir().join(f), tmp.join(f)).unwrap();
        }
        std::fs::write(tmp.join("weights.bin"), [0u8; 16]).unwrap();
        let result = Artifacts::load(&tmp);
        std::fs::remove_dir_all(&tmp).ok();
        assert!(result.is_err());
    }

    #[test]
    fn golden_token_count_checked() {
        if !artifacts_available() {
            return;
        }
        let mut a = Artifacts::load(default_dir()).unwrap();
        a.golden.tokens.pop();
        assert!(a.validate().is_err());
    }
}
