//! PJRT runtime: loads the AOT-lowered 1-bit decoder (HLO text) and
//! executes it on the `xla` crate's CPU PJRT client — the functional
//! numerics path of the system. Python never runs here.
//!
//! * [`artifacts`] — manifest/weights/golden parsing + validation.
//! * [`engine`]    — compiled executable + device-resident weights; one
//!   `decode_step` call per generated token.
//! * [`decoder`]   — greedy generation loop + golden validation.

pub mod artifacts;
pub mod decoder;
pub mod engine;

pub use artifacts::Artifacts;
pub use decoder::TinyDecoder;
pub use engine::Engine;
