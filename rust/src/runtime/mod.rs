//! Functional runtime: loads the AOT artifacts of the 1-bit decoder and
//! executes decode steps through a pluggable [`Backend`]. Python never
//! runs here.
//!
//! * [`artifacts`] — manifest/weights/golden parsing + validation, plus
//!   an offline synthetic artifact generator.
//! * [`backend`]   — the `Backend` trait: decode sessions addressed by
//!   opaque [`CacheHandle`]s, state updated in place through the arena.
//! * [`kvcache`]   — the block-paged KV-cache arena shared by all
//!   sessions: fixed-size blocks, per-session block tables,
//!   alloc/free/evict with generation-checked handles, and refcounted
//!   copy-on-write block sharing.
//! * [`prefixcache`] — token-keyed radix index mapping prompt prefixes
//!   to chains of cached blocks; sessions adopt matched prefixes
//!   read-only and skip their prefill decode entirely (bit-identical
//!   to cold prefill — `tests/prefix_equivalence.rs`).
//! * [`kernels`]   — the shared dense f32 kernels (quantization,
//!   RMSNorm/GELU/softmax, `bitlinear`, attention — contiguous oracle
//!   and paged block-table variants) both host backends execute.
//! * [`reference`] — pure-Rust reference executor (ref.py semantics);
//!   the DEFAULT backend, zero dependencies, runs offline.
//! * [`packed`]    — bitplane popcount executor: ternary weights lowered
//!   to [`crate::quant`] planes at load, projections as integer
//!   mask-select MVMs; bit-identical outputs to `reference`.
//! * [`pjrt`]      — XLA/PJRT engine for the AOT-lowered HLO, behind
//!   the off-by-default `pjrt` Cargo feature (the `xla` crate needs
//!   network access to build — see Cargo.toml); keeps contiguous
//!   device-resident caches behind the same handle API.
//! * [`engine`]    — the facades callers use; picks a backend and sizes
//!   the arena at load. [`Engine`] is the single-threaded facade;
//!   [`ShardedEngine`] partitions the same total arena capacity into N
//!   `Send`-able [`EngineShard`]s (own backend instance, own arena
//!   slice, own prefix index — nothing shared but the `Arc`'d weights),
//!   each owned exclusively by one serving worker thread, with
//!   deterministic request→shard placement ([`engine::shard_for`]).
//! * [`decoder`]   — greedy generation loops (single-session
//!   `TinyDecoder`, batched `BatchDecoder`) + golden validation.
//! * [`spec`]      — greedy-exact speculative decoding: draft sources
//!   (`self` / `tiny` / `oracle`) proposing k-token spans the target
//!   verifies in one traversal, byte-identical output by construction.

pub mod artifacts;
pub mod backend;
pub mod decoder;
pub mod engine;
pub mod kernels;
pub mod kvcache;
pub mod packed;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod prefixcache;
pub mod reference;
pub mod spec;

pub use artifacts::Artifacts;
pub use backend::Backend;
pub use decoder::{BatchDecoder, TinyDecoder};
pub use engine::{
    default_artifacts, shard_for, BackendKind, Engine, EngineImpl, EngineShard, ShardHandle,
    ShardedEngine,
};
pub use kvcache::{ArenaLayout, ArenaStatus, CacheArena, CacheHandle, CacheLayout};
pub use prefixcache::{PrefixCache, PrefixMatch, PrefixStats};
pub use spec::{DraftSource, DraftSpec, SpecPlan, SpecState, DEFAULT_SPEC_K};
