//! Greedy autoregressive decoding on the PJRT engine + golden
//! validation: the Rust runtime must reproduce, token for token, the
//! generation the JAX graph produced at AOT time (`golden.json`).

use super::engine::Engine;
use crate::util::error::{bail, Result};
use std::time::Instant;

/// Stateful decoder session over a loaded engine. KV caches live in the
/// backend's native representation (host tensors for the reference
/// executor, device-resident PJRT buffers for the `pjrt` feature) and
/// are threaded between steps as opaque values.
pub struct TinyDecoder<'e> {
    engine: &'e Engine,
    caches: Option<crate::runtime::backend::Caches>,
    pos: i32,
    pub tokens: Vec<i32>,
    pub last_logits: Vec<f32>,
}

/// Timing of one generation.
#[derive(Debug, Clone, PartialEq)]
pub struct GenTiming {
    pub prompt_len: usize,
    pub new_tokens: usize,
    pub total_s: f64,
    pub per_step_s: Vec<f64>,
}

impl GenTiming {
    pub fn tokens_per_s(&self) -> f64 {
        (self.prompt_len + self.new_tokens) as f64 / self.total_s
    }
}

impl<'e> TinyDecoder<'e> {
    pub fn new(engine: &'e Engine) -> Result<Self> {
        let caches = engine.empty_caches()?;
        Ok(Self {
            engine,
            caches: Some(caches),
            pos: 0,
            tokens: Vec::new(),
            last_logits: Vec::new(),
        })
    }

    /// Feed one token; updates caches and logits.
    pub fn feed(&mut self, token: i32) -> Result<()> {
        if self.pos as usize >= self.engine.max_ctx() {
            bail!("context overflow: pos {} >= {}", self.pos, self.engine.max_ctx());
        }
        let caches = self.caches.take().expect("caches present");
        let out = self.engine.decode_step(caches, token, self.pos)?;
        self.caches = Some(out.caches);
        self.last_logits = out.logits;
        self.tokens.push(token);
        self.pos += 1;
        Ok(())
    }

    /// Greedy argmax over the last logits.
    pub fn greedy_next(&self) -> i32 {
        self.last_logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i as i32)
            .expect("non-empty logits")
    }

    /// Feed a prompt then greedily generate `n_new` tokens.
    pub fn generate(&mut self, prompt: &[i32], n_new: usize) -> Result<GenTiming> {
        let start = Instant::now();
        let mut per_step = Vec::with_capacity(prompt.len() + n_new);
        for &t in prompt {
            let s = Instant::now();
            self.feed(t)?;
            per_step.push(s.elapsed().as_secs_f64());
        }
        for _ in 0..n_new {
            let next = self.greedy_next();
            let s = Instant::now();
            self.feed(next)?;
            per_step.push(s.elapsed().as_secs_f64());
        }
        Ok(GenTiming {
            prompt_len: prompt.len(),
            new_tokens: n_new,
            total_s: start.elapsed().as_secs_f64(),
            per_step_s: per_step,
        })
    }
}

/// Run the golden generation and check the produced tokens exactly.
pub fn validate_golden(engine: &Engine) -> Result<GenTiming> {
    let g = engine.artifacts.golden.clone();
    let mut dec = TinyDecoder::new(engine)?;
    let timing = dec.generate(&g.prompt, g.n_new)?;
    if dec.tokens != g.tokens {
        bail!(
            "golden mismatch:\n  rust: {:?}\n  jax:  {:?}",
            dec.tokens,
            g.tokens
        );
    }
    Ok(timing)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Artifacts;

    fn engine() -> Engine {
        Engine::load(Artifacts::synthetic(2).expect("synthetic artifacts"))
            .expect("engine")
    }

    /// THE end-to-end check: the runtime reproduces the recorded golden
    /// generation token-for-token (on synthetic artifacts the golden was
    /// produced by the reference executor at synthesis time; on real AOT
    /// artifacts it is the JAX generation).
    #[test]
    fn golden_generation_reproduces() {
        let e = engine();
        let timing = validate_golden(&e).expect("golden validation");
        assert!(timing.tokens_per_s() > 0.0);
    }

    #[test]
    fn context_overflow_rejected() {
        let e = engine();
        let mut dec = TinyDecoder::new(&e).unwrap();
        dec.pos = e.max_ctx() as i32;
        assert!(dec.feed(0).is_err());
    }

    #[test]
    fn different_prompts_diverge() {
        let e = engine();
        let mut a = TinyDecoder::new(&e).unwrap();
        a.generate(&[1, 2], 4).unwrap();
        let mut b = TinyDecoder::new(&e).unwrap();
        b.generate(&[3, 4], 4).unwrap();
        assert_ne!(a.tokens, b.tokens);
    }

    #[test]
    fn timing_accounts_every_step() {
        let e = engine();
        let mut dec = TinyDecoder::new(&e).unwrap();
        let t = dec.generate(&[1, 2, 3], 5).unwrap();
        assert_eq!(t.prompt_len, 3);
        assert_eq!(t.new_tokens, 5);
        assert_eq!(t.per_step_s.len(), 8);
        assert_eq!(dec.tokens.len(), 8);
    }
}
