//! Greedy autoregressive decoding + golden validation: the Rust runtime
//! must reproduce, token for token, the generation the JAX graph
//! produced at AOT time (`golden.json`).
//!
//! Two decoders share the engine:
//! * [`TinyDecoder`] — one session, one `decode_step` per token.
//! * [`BatchDecoder`] — B concurrent sessions advanced one token each
//!   per `decode_batch` call, so every layer's weights are traversed
//!   once per step for the whole batch (bit-identical outputs to B
//!   `TinyDecoder`s — enforced by `tests/batch_equivalence.rs`).
//!
//! Sessions are arena-backed [`CacheHandle`]s since the paging refactor
//! (see [`crate::runtime::kvcache`]): cache blocks are claimed on
//! demand as positions advance, and both decoders retire their sessions
//! on drop so a decoder's capacity is reusable the moment it goes out
//! of scope.

use super::engine::Engine;
use super::kvcache::CacheHandle;
use crate::util::error::{anyhow, bail, ensure, Result};
use std::time::Instant;

/// THE greedy-decoding convention, shared by [`TinyDecoder`],
/// [`BatchDecoder`] and the serving loop — the cross-scheduler
/// token-equivalence guarantee depends on every path using exactly this
/// function: last-maximal-index argmax (`Iterator::max_by` semantics),
/// and token 0 (the tiny model's BOS) when no logits exist yet (empty
/// prompt, nothing fed).
pub fn greedy_argmax(logits: &[f32]) -> i32 {
    logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map_or(0, |(i, _)| i as i32)
}

/// Stateful decoder session over a loaded engine. KV-cache state lives
/// in the engine's paged arena behind the session handle; the decoder
/// only tracks its position and token history.
pub struct TinyDecoder<'e> {
    engine: &'e Engine,
    session: CacheHandle,
    pos: i32,
    pub tokens: Vec<i32>,
    pub last_logits: Vec<f32>,
}

/// Timing of one generation, with the prefill (prompt ingestion) and
/// decode (token generation) phases accounted separately.
#[derive(Debug, Clone, PartialEq)]
pub struct GenTiming {
    pub prompt_len: usize,
    pub new_tokens: usize,
    pub total_s: f64,
    /// Time spent ingesting the prompt.
    pub prefill_s: f64,
    /// Time spent generating new tokens.
    pub decode_s: f64,
    pub per_step_s: Vec<f64>,
}

impl GenTiming {
    /// Decode-only throughput: generated tokens over the time spent
    /// generating them. Prompt tokens are deliberately excluded — they
    /// are prefill work, and counting them inflated the reported
    /// generation rate. Returns 0.0 when nothing was generated.
    pub fn decode_tokens_per_s(&self) -> f64 {
        if self.new_tokens == 0 || self.decode_s <= 0.0 {
            0.0
        } else {
            self.new_tokens as f64 / self.decode_s
        }
    }

    /// Prefill rate: prompt tokens over the prompt-ingestion time.
    /// Returns 0.0 for an empty prompt.
    pub fn prefill_tokens_per_s(&self) -> f64 {
        if self.prompt_len == 0 || self.prefill_s <= 0.0 {
            0.0
        } else {
            self.prompt_len as f64 / self.prefill_s
        }
    }
}

impl<'e> TinyDecoder<'e> {
    pub fn new(engine: &'e Engine) -> Result<Self> {
        let session = engine.new_session()?;
        Ok(Self {
            engine,
            session,
            pos: 0,
            tokens: Vec::new(),
            last_logits: Vec::new(),
        })
    }

    /// Feed one token; updates caches and logits.
    pub fn feed(&mut self, token: i32) -> Result<()> {
        if self.pos as usize >= self.engine.max_ctx() {
            bail!("context overflow: pos {} >= {}", self.pos, self.engine.max_ctx());
        }
        self.last_logits = self.engine.decode_step(self.session, token, self.pos)?;
        self.tokens.push(token);
        self.pos += 1;
        Ok(())
    }

    /// Greedy argmax over the last logits (see [`greedy_argmax`] for the
    /// shared convention, including the empty-prompt BOS start).
    pub fn greedy_next(&self) -> i32 {
        greedy_argmax(&self.last_logits)
    }

    /// Feed a prompt then greedily generate `n_new` tokens.
    pub fn generate(&mut self, prompt: &[i32], n_new: usize) -> Result<GenTiming> {
        let start = Instant::now();
        let mut per_step = Vec::with_capacity(prompt.len() + n_new);
        let mut prefill_s = 0.0;
        let mut decode_s = 0.0;
        for &t in prompt {
            let s = Instant::now();
            self.feed(t)?;
            let dt = s.elapsed().as_secs_f64();
            prefill_s += dt;
            per_step.push(dt);
        }
        for _ in 0..n_new {
            let next = self.greedy_next();
            let s = Instant::now();
            self.feed(next)?;
            let dt = s.elapsed().as_secs_f64();
            decode_s += dt;
            per_step.push(dt);
        }
        Ok(GenTiming {
            prompt_len: prompt.len(),
            new_tokens: n_new,
            total_s: start.elapsed().as_secs_f64(),
            prefill_s,
            decode_s,
            per_step_s: per_step,
        })
    }
}

impl Drop for TinyDecoder<'_> {
    fn drop(&mut self) {
        self.engine.release_session(self.session);
    }
}

/// One decoding session inside a [`BatchDecoder`]: its cache handle,
/// position, token history and last logits — exactly the state a
/// [`TinyDecoder`] holds, minus the engine handle.
pub struct BatchSession {
    session: CacheHandle,
    pos: i32,
    pub tokens: Vec<i32>,
    pub last_logits: Vec<f32>,
}

impl BatchSession {
    /// Next decode position (= number of tokens fed so far).
    pub fn pos(&self) -> i32 {
        self.pos
    }
}

/// Timing of one batched generation run.
#[derive(Debug, Clone, PartialEq)]
pub struct BatchTiming {
    /// Number of sequences decoded together.
    pub batch: usize,
    /// Number of `decode_batch` calls (= weight traversals) issued.
    pub steps: usize,
    pub prompt_tokens: usize,
    pub new_tokens: usize,
    pub total_s: f64,
}

impl BatchTiming {
    /// Aggregate throughput in fed tokens (prompt + generated) per
    /// second: every fed token occupies one lane of one `decode_batch`
    /// call, so this is the engine-level token rate of the batched loop.
    pub fn fed_tokens_per_s(&self) -> f64 {
        if self.total_s <= 0.0 {
            0.0
        } else {
            (self.prompt_tokens + self.new_tokens) as f64 / self.total_s
        }
    }
}

/// Batched decoder: B independent greedy sessions advanced one token
/// each per engine call. Each [`BatchDecoder::feed`] issues a single
/// [`Engine::decode_batch`], so on the reference backend every layer's
/// weights are walked once for the whole batch instead of once per
/// session — the amortization the paper's throughput claim rests on.
/// Sessions may be at ragged positions (mixed prompt lengths, mixed
/// progress); outputs are bit-identical to per-session [`TinyDecoder`]s.
pub struct BatchDecoder<'e> {
    engine: &'e Engine,
    sessions: Vec<BatchSession>,
}

impl<'e> BatchDecoder<'e> {
    pub fn new(engine: &'e Engine) -> Self {
        Self {
            engine,
            sessions: Vec::new(),
        }
    }

    /// Open a fresh session (no cache blocks yet, position 0); returns
    /// its id.
    pub fn add_session(&mut self) -> Result<usize> {
        let session = self.engine.new_session()?;
        self.sessions.push(BatchSession {
            session,
            pos: 0,
            tokens: Vec::new(),
            last_logits: Vec::new(),
        });
        Ok(self.sessions.len() - 1)
    }

    pub fn len(&self) -> usize {
        self.sessions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sessions.is_empty()
    }

    pub fn session(&self, id: usize) -> &BatchSession {
        &self.sessions[id]
    }

    /// Greedy argmax over session `id`'s last logits (see
    /// [`greedy_argmax`] for the shared convention).
    pub fn greedy_next(&self, id: usize) -> i32 {
        greedy_argmax(&self.sessions[id].last_logits)
    }

    /// Feed one token into each listed `(session, token)` pair through a
    /// SINGLE `decode_batch` call. A session may appear at most once per
    /// call (it advances by exactly one position).
    ///
    /// Error semantics: argument problems (unknown/duplicate session,
    /// context overflow) are rejected up front and consume nothing, and
    /// since cache state lives in the arena (nothing is moved), an
    /// engine-level error consumes nothing either — positions only
    /// advance on success, and a retried step deterministically
    /// overwrites the same cache rows.
    pub fn feed(&mut self, steps: &[(usize, i32)]) -> Result<()> {
        if steps.is_empty() {
            return Ok(());
        }
        // Validate up front: a session may appear at most once (it
        // advances by exactly one position), must exist, and must have
        // context room.
        let max_ctx = self.engine.max_ctx() as i32;
        for (n, &(id, _)) in steps.iter().enumerate() {
            ensure!(
                !steps[..n].iter().any(|&(seen, _)| seen == id),
                "session {id} listed twice in one batch"
            );
            let s = self
                .sessions
                .get(id)
                .ok_or_else(|| anyhow!("no session {id}"))?;
            ensure!(
                s.pos < max_ctx,
                "context overflow: session {id} pos {} >= {max_ctx}",
                s.pos
            );
        }
        let mut handles = Vec::with_capacity(steps.len());
        let mut tokens = Vec::with_capacity(steps.len());
        let mut positions = Vec::with_capacity(steps.len());
        for &(id, token) in steps {
            let s = &self.sessions[id];
            handles.push(s.session);
            tokens.push(token);
            positions.push(s.pos);
        }
        let outs = self.engine.decode_batch(&handles, &tokens, &positions)?;
        for (&(id, token), logits) in steps.iter().zip(outs) {
            let s = &mut self.sessions[id];
            s.last_logits = logits;
            s.tokens.push(token);
            s.pos += 1;
        }
        Ok(())
    }

    /// Open one session per prompt and run the whole ragged workload to
    /// completion: each step feeds every unfinished session (its next
    /// prompt token while prefilling, its greedy continuation after) in
    /// one `decode_batch`. Returns aggregate timing; per-session tokens
    /// are in [`BatchDecoder::session`].
    pub fn generate(&mut self, prompts: &[Vec<i32>], n_new: &[usize]) -> Result<BatchTiming> {
        ensure!(
            prompts.len() == n_new.len(),
            "generate arity mismatch: {} prompts, {} n_new",
            prompts.len(),
            n_new.len()
        );
        let start = Instant::now();
        let base = self.sessions.len();
        for _ in prompts {
            self.add_session()?;
        }
        let total: Vec<usize> = prompts.iter().zip(n_new).map(|(p, &n)| p.len() + n).collect();
        let mut fed = vec![0usize; prompts.len()];
        let mut steps = 0usize;
        loop {
            let mut batch: Vec<(usize, i32)> = Vec::new();
            for (i, (p, &tot)) in prompts.iter().zip(&total).enumerate() {
                if fed[i] >= tot {
                    continue;
                }
                let token = if fed[i] < p.len() {
                    p[fed[i]]
                } else {
                    self.greedy_next(base + i)
                };
                batch.push((base + i, token));
            }
            if batch.is_empty() {
                break;
            }
            self.feed(&batch)?;
            for &(id, _) in &batch {
                fed[id - base] += 1;
            }
            steps += 1;
        }
        Ok(BatchTiming {
            batch: prompts.len(),
            steps,
            prompt_tokens: prompts.iter().map(Vec::len).sum(),
            new_tokens: n_new.iter().sum(),
            total_s: start.elapsed().as_secs_f64(),
        })
    }
}

impl Drop for BatchDecoder<'_> {
    fn drop(&mut self) {
        for s in &self.sessions {
            self.engine.release_session(s.session);
        }
    }
}

/// Run the golden generation and check the produced tokens exactly.
pub fn validate_golden(engine: &Engine) -> Result<GenTiming> {
    let g = engine.artifacts.golden.clone();
    let mut dec = TinyDecoder::new(engine)?;
    let timing = dec.generate(&g.prompt, g.n_new)?;
    if dec.tokens != g.tokens {
        bail!(
            "golden mismatch:\n  rust: {:?}\n  jax:  {:?}",
            dec.tokens,
            g.tokens
        );
    }
    Ok(timing)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Artifacts;

    fn engine() -> Engine {
        Engine::load(Artifacts::synthetic(2).expect("synthetic artifacts"))
            .expect("engine")
    }

    /// THE end-to-end check: the runtime reproduces the recorded golden
    /// generation token-for-token (on synthetic artifacts the golden was
    /// produced by the reference executor at synthesis time; on real AOT
    /// artifacts it is the JAX generation).
    #[test]
    fn golden_generation_reproduces() {
        let e = engine();
        let timing = validate_golden(&e).expect("golden validation");
        assert!(timing.decode_tokens_per_s() > 0.0);
        assert!(timing.prefill_tokens_per_s() > 0.0);
    }

    #[test]
    fn greedy_argmax_convention_is_pinned() {
        // Empty logits -> BOS token 0; ties resolve to the LAST maximal
        // index (Iterator::max_by semantics). Every decode path shares
        // this function, so pin the convention here.
        assert_eq!(greedy_argmax(&[]), 0);
        assert_eq!(greedy_argmax(&[1.0, 3.0, 2.0]), 1);
        assert_eq!(greedy_argmax(&[5.0, 5.0, 1.0]), 1);
    }

    #[test]
    fn context_overflow_rejected() {
        let e = engine();
        let mut dec = TinyDecoder::new(&e).unwrap();
        dec.pos = e.max_ctx() as i32;
        assert!(dec.feed(0).is_err());
    }

    #[test]
    fn different_prompts_diverge() {
        let e = engine();
        let mut a = TinyDecoder::new(&e).unwrap();
        a.generate(&[1, 2], 4).unwrap();
        let mut b = TinyDecoder::new(&e).unwrap();
        b.generate(&[3, 4], 4).unwrap();
        assert_ne!(a.tokens, b.tokens);
    }

    #[test]
    fn dropped_decoders_release_their_arena_blocks() {
        let e = engine();
        let full = e.arena_status().free_blocks;
        {
            let mut tiny = TinyDecoder::new(&e).unwrap();
            tiny.generate(&[1, 2, 3], 4).unwrap();
            let mut batch = BatchDecoder::new(&e);
            batch.generate(&[vec![1], vec![2, 3]], &[2, 2]).unwrap();
            assert!(e.arena_status().free_blocks < full);
        }
        assert_eq!(e.arena_status().free_blocks, full);
    }

    #[test]
    fn timing_accounts_every_step() {
        let e = engine();
        let mut dec = TinyDecoder::new(&e).unwrap();
        let t = dec.generate(&[1, 2, 3], 5).unwrap();
        assert_eq!(t.prompt_len, 3);
        assert_eq!(t.new_tokens, 5);
        assert_eq!(t.per_step_s.len(), 8);
        assert_eq!(dec.tokens.len(), 8);
        // The phase split covers exactly the per-step samples.
        let prefill: f64 = t.per_step_s[..3].iter().sum();
        let decode: f64 = t.per_step_s[3..].iter().sum();
        assert!((t.prefill_s - prefill).abs() < 1e-12);
        assert!((t.decode_s - decode).abs() < 1e-12);
    }

    #[test]
    fn throughput_rates_are_phase_scoped() {
        // decode tokens/s must come from the decode phase only — the
        // old all-tokens-over-total number counted prompt ingestion as
        // generation throughput.
        let t = GenTiming {
            prompt_len: 90,
            new_tokens: 10,
            total_s: 2.0,
            prefill_s: 1.0,
            decode_s: 1.0,
            per_step_s: Vec::new(),
        };
        assert!((t.decode_tokens_per_s() - 10.0).abs() < 1e-12);
        assert!((t.prefill_tokens_per_s() - 90.0).abs() < 1e-12);
        // Degenerate cases report 0, not NaN/inf.
        let none = GenTiming {
            prompt_len: 0,
            new_tokens: 0,
            total_s: 0.0,
            prefill_s: 0.0,
            decode_s: 0.0,
            per_step_s: Vec::new(),
        };
        assert_eq!(none.decode_tokens_per_s(), 0.0);
        assert_eq!(none.prefill_tokens_per_s(), 0.0);
    }

    #[test]
    fn batch_decoder_matches_tiny_decoder_ragged() {
        // Three sessions with different prompt lengths and generation
        // budgets, advanced together: token streams must be identical to
        // three independent TinyDecoders.
        let e = engine();
        let prompts: Vec<Vec<i32>> = vec![vec![1, 2, 3], vec![9], vec![4, 5, 6, 7, 8]];
        let n_new = [5usize, 7, 2];
        let mut batch = BatchDecoder::new(&e);
        let timing = batch.generate(&prompts, &n_new).unwrap();
        assert_eq!(timing.batch, 3);
        // Longest lane: 5 prompt + 2 new = 7; lane 1: 1 + 7 = 8 steps.
        assert_eq!(timing.steps, 8);
        for (i, (p, &n)) in prompts.iter().zip(&n_new).enumerate() {
            let mut tiny = TinyDecoder::new(&e).unwrap();
            tiny.generate(p, n).unwrap();
            assert_eq!(batch.session(i).tokens, tiny.tokens, "session {i}");
            assert_eq!(
                batch.session(i).last_logits,
                tiny.last_logits,
                "session {i} logits"
            );
        }
    }

    #[test]
    fn empty_prompt_decodes_identically_everywhere() {
        let e = engine();
        let mut tiny = TinyDecoder::new(&e).unwrap();
        tiny.generate(&[], 4).unwrap();
        assert_eq!(tiny.tokens.len(), 4);
        assert_eq!(tiny.tokens[0], 0); // BOS convention
        let mut batch = BatchDecoder::new(&e);
        batch.generate(&[vec![]], &[4]).unwrap();
        assert_eq!(batch.session(0).tokens, tiny.tokens);
    }

    #[test]
    fn batch_feed_rejects_duplicate_session_and_overflow() {
        let e = engine();
        let mut batch = BatchDecoder::new(&e);
        let s = batch.add_session().unwrap();
        assert!(batch.feed(&[(s, 1), (s, 2)]).is_err());
        // The rejected call consumed nothing: the same session still works.
        batch.feed(&[(s, 1)]).unwrap();
        assert_eq!(batch.session(s).tokens, vec![1]);
        // Context overflow is rejected up front.
        let mut batch = BatchDecoder::new(&e);
        let s = batch.add_session().unwrap();
        for i in 0..e.max_ctx() {
            batch.feed(&[(s, i as i32 % 7)]).unwrap();
        }
        assert!(batch.feed(&[(s, 0)]).is_err());
    }

    #[test]
    fn zero_token_generate_is_a_noop() {
        let e = engine();
        let mut batch = BatchDecoder::new(&e);
        let t = batch.generate(&[vec![]], &[0]).unwrap();
        assert_eq!(t.steps, 0);
        assert_eq!(batch.session(0).tokens.len(), 0);
        assert_eq!(t.fed_tokens_per_s(), 0.0);
    }
}
