//! The runtime engine facade: artifacts + a boxed [`Backend`] + the
//! shared block-paged KV-cache arena, chosen and sized at load time.
//!
//! Three backends: the pure-Rust [`super::reference`] executor (the
//! offline default), the [`super::packed`] bitplane popcount executor
//! (also offline; bit-identical outputs, packed ternary weights), and —
//! with the `pjrt` Cargo feature plus the `xla` dependency (see
//! Cargo.toml) — the XLA/PJRT engine behind [`BackendKind::Pjrt`].
//!
//! Selection: the `--backend reference|packed|pjrt` CLI flag resolves
//! through [`BackendKind::resolve`]; without the flag the
//! `PIM_LLM_BACKEND` env var applies, and with neither the reference
//! backend is used.
//!
//! Callers (decoder, serving, CLI, benches) only see `Engine`: sessions
//! are opened with [`Engine::new_session`], advanced with
//! [`Engine::decode_step`] / [`Engine::decode_batch`] against opaque
//! [`CacheHandle`]s, and retired with [`Engine::free_session`]. Cache
//! state never moves through these calls — it lives in the arena
//! ([`super::kvcache`]), whose occupancy ([`Engine::arena_status`])
//! drives the serving layer's pressure-aware admission and preemption.

use super::artifacts::Artifacts;
use super::backend::Backend;
use super::kvcache::{ArenaLayout, ArenaStatus, CacheArena, CacheHandle, CacheLayout};
use super::prefixcache::{PrefixCache, PrefixStats};
use crate::obs::{Counter, EventKind, MetricsSnapshot, Obs};
use crate::quant::PackedModel;
use crate::util::error::{Context, Result};
use std::cell::RefCell;
use std::path::Path;
use std::sync::Arc;

/// Which execution backend to load.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// Pure-Rust reference executor (the offline default).
    Reference,
    /// Bitplane popcount executor over packed ternary weights
    /// ([`crate::quant`]); bit-identical to `Reference`.
    Packed,
    /// XLA/PJRT engine executing the AOT-lowered HLO.
    #[cfg(feature = "pjrt")]
    Pjrt,
}

impl BackendKind {
    /// Resolve a backend name ("" / "reference" -> Reference; "packed"
    /// -> Packed; "pjrt" -> Pjrt when the feature is compiled in, a
    /// clear error otherwise).
    pub fn from_name(name: &str) -> Result<Self> {
        match name {
            "" | "reference" => Ok(BackendKind::Reference),
            "packed" => Ok(BackendKind::Packed),
            #[cfg(feature = "pjrt")]
            "pjrt" => Ok(BackendKind::Pjrt),
            other => {
                // With the feature on, "pjrt" is matched above, so this
                // branch only fires for it on feature-less builds.
                if other == "pjrt" {
                    crate::bail!(
                        "backend 'pjrt' needs a build with --features pjrt \
                         (see rust/README.md for the build matrix)"
                    );
                }
                crate::bail!("unknown backend '{other}' (reference | packed | pjrt)")
            }
        }
    }

    /// Resolve from `PIM_LLM_BACKEND` (unset -> Reference).
    pub fn from_env() -> Result<Self> {
        let name = std::env::var("PIM_LLM_BACKEND").unwrap_or_default();
        Self::from_name(&name).context("resolving PIM_LLM_BACKEND")
    }

    /// Resolve the CLI `--backend` flag, falling back to the env var
    /// (then the reference default) when the flag was not given.
    pub fn resolve(flag: Option<&str>) -> Result<Self> {
        match flag {
            Some(name) => Self::from_name(name).context("resolving --backend"),
            None => Self::from_env(),
        }
    }

    /// Whether this backend can only run from real AOT artifacts.
    /// Synthetic artifacts carry weights but no HLO text, so only the
    /// PJRT engine needs the real thing — the host executors (reference,
    /// packed) both run from the synthetic fallback.
    pub fn requires_aot_artifacts(self) -> bool {
        match self {
            BackendKind::Reference | BackendKind::Packed => false,
            #[cfg(feature = "pjrt")]
            BackendKind::Pjrt => true,
        }
    }
}

/// Loaded model + execution backend + a block-paged KV-cache arena; one
/// `decode_step`/`decode_batch` per generated token. Generic over the
/// boxed backend's trait-object type so the same implementation serves
/// two concrete facades:
///
/// * [`Engine`] (`B = dyn Backend`) — the single-threaded engine every
///   caller has always seen, able to hold any backend including PJRT;
/// * [`EngineShard`] (`B = dyn Backend + Send`) — one shard of a
///   [`ShardedEngine`], movable into a worker thread because every
///   field is `Send` (host backends are plain data; the arena and
///   prefix index are plain `Vec` storage).
///
/// The arena sits behind a `RefCell`: engine calls are already
/// single-threaded per engine/shard (backends are not `Sync`; the
/// threaded serving front ends give each worker its own engine or
/// shard), and interior mutability is what lets many sessions share one
/// `&Engine` the way they shared it before the paging refactor.
pub struct EngineImpl<B: ?Sized + Backend = dyn Backend> {
    pub artifacts: Arc<Artifacts>,
    backend: Box<B>,
    arena: RefCell<CacheArena>,
    /// Copy-on-write prefix index over the arena, off until
    /// [`Engine::enable_prefix_cache`] (the `--prefix-cache` knob).
    prefix: RefCell<Option<PrefixCache>>,
    /// Observability bundle (trace ring + metrics), shared with the
    /// backend so kernel spans land in the same per-shard timeline.
    /// Disabled by default — [`crate::obs::Obs::set_enabled`] is the
    /// `--trace` / `--metrics` switch. `Arc`: the backend and any
    /// exporter hold it alongside the engine.
    obs: Arc<Obs>,
}

/// The classic single-threaded engine facade (any backend).
pub type Engine = EngineImpl;

/// One worker-owned shard of a [`ShardedEngine`]: a host backend plus a
/// private slice of the total arena capacity. `Send` by construction —
/// no locks anywhere on its decode path, because no other thread can
/// reach its blocks.
pub type EngineShard = EngineImpl<dyn Backend + Send>;

impl Engine {
    /// Load with the backend selected by `PIM_LLM_BACKEND` (reference by
    /// default).
    pub fn load(artifacts: Artifacts) -> Result<Self> {
        Self::load_with(artifacts, BackendKind::from_env()?)
    }

    /// Load with an explicit backend and the default arena geometry
    /// (default block length, [`super::kvcache::DEFAULT_ARENA_SESSIONS`]
    /// worst-case sessions of capacity).
    pub fn load_with(artifacts: Artifacts, kind: BackendKind) -> Result<Self> {
        Self::load_with_arena(artifacts, kind, 0, 0)
    }

    /// Load with an explicit backend AND arena geometry: `block_len`
    /// positions per cache block and `capacity_blocks` total blocks
    /// (either `0` selects its default). Small capacities are how the
    /// continuous-batching tests and benches create arena pressure.
    pub fn load_with_arena(
        artifacts: Artifacts,
        kind: BackendKind,
        block_len: usize,
        capacity_blocks: usize,
    ) -> Result<Self> {
        Self::load_with_arena_mode(artifacts, kind, block_len, capacity_blocks, ArenaLayout::F32)
    }

    /// [`Engine::load_with_arena`] with an explicit arena storage layout
    /// ([`ArenaLayout::KvInt8`] stores K/V as group-scaled int8, ~4x the
    /// resident sessions per arena byte) — what `--kv-quant` maps to.
    pub fn load_with_arena_mode(
        artifacts: Artifacts,
        kind: BackendKind,
        block_len: usize,
        capacity_blocks: usize,
        mode: ArenaLayout,
    ) -> Result<Self> {
        Self::load_shared_with_arena_mode(
            Arc::new(artifacts),
            kind,
            block_len,
            capacity_blocks,
            mode,
        )
    }

    /// Assemble an engine over an ALREADY-`Arc`'d artifact bundle — no
    /// weight copy. This is how speculative decoding stands a draft
    /// engine beside its target: the same `Arc` for a self-draft, a
    /// sibling bundle for a sized-down one.
    pub fn load_shared_with_arena(
        artifacts: Arc<Artifacts>,
        kind: BackendKind,
        block_len: usize,
        capacity_blocks: usize,
    ) -> Result<Self> {
        Self::load_shared_with_arena_mode(
            artifacts,
            kind,
            block_len,
            capacity_blocks,
            ArenaLayout::F32,
        )
    }

    /// [`Engine::load_shared_with_arena`] with an explicit arena layout.
    pub fn load_shared_with_arena_mode(
        artifacts: Arc<Artifacts>,
        kind: BackendKind,
        block_len: usize,
        capacity_blocks: usize,
        mode: ArenaLayout,
    ) -> Result<Self> {
        let backend: Box<dyn Backend> = match kind {
            BackendKind::Reference => Box::new(
                super::reference::ReferenceBackend::new(Arc::clone(&artifacts))?,
            ),
            BackendKind::Packed => {
                Box::new(super::packed::PackedBackend::new(Arc::clone(&artifacts))?)
            }
            #[cfg(feature = "pjrt")]
            BackendKind::Pjrt => {
                Box::new(super::pjrt::PjrtBackend::new(Arc::clone(&artifacts))?)
            }
        };
        Self::assemble(artifacts, backend, block_len, capacity_blocks, mode)
    }

    /// Load the packed backend straight from a `.tpk` artifact
    /// ([`crate::quant::load_tpk`]): the bitplanes are mmap'd zero-copy
    /// where the platform allows, so engine start does no per-matrix
    /// re-packing and N processes opening the same file share one page
    /// cache copy. `artifacts` still supplies the manifest (validated
    /// against the artifact header) and the golden transcript.
    pub fn load_packed_artifact(
        artifacts: Artifacts,
        tpk_path: &Path,
        block_len: usize,
        capacity_blocks: usize,
    ) -> Result<Self> {
        Self::load_packed_artifact_mode(
            artifacts,
            tpk_path,
            block_len,
            capacity_blocks,
            ArenaLayout::F32,
        )
    }

    /// [`Engine::load_packed_artifact`] with an explicit arena layout.
    pub fn load_packed_artifact_mode(
        artifacts: Artifacts,
        tpk_path: &Path,
        block_len: usize,
        capacity_blocks: usize,
        mode: ArenaLayout,
    ) -> Result<Self> {
        let artifacts = Arc::new(artifacts);
        let model = Arc::new(crate::quant::load_tpk(tpk_path, &artifacts)?);
        let backend: Box<dyn Backend> = Box::new(super::packed::PackedBackend::with_model(
            Arc::clone(&artifacts),
            model,
        )?);
        Self::assemble(artifacts, backend, block_len, capacity_blocks, mode)
    }

    /// [`Engine::load_packed_artifact`] over the default artifacts
    /// directory (synthetic fallback) — what `repro serve/validate
    /// --backend packed --artifact P` map to.
    pub fn load_default_packed_artifact(
        tpk_path: &Path,
        block_len: usize,
        capacity_blocks: usize,
    ) -> Result<Self> {
        Self::load_default_packed_artifact_mode(
            tpk_path,
            block_len,
            capacity_blocks,
            ArenaLayout::F32,
        )
    }

    /// [`Engine::load_default_packed_artifact`] with an explicit arena
    /// layout.
    pub fn load_default_packed_artifact_mode(
        tpk_path: &Path,
        block_len: usize,
        capacity_blocks: usize,
        mode: ArenaLayout,
    ) -> Result<Self> {
        Self::load_packed_artifact_mode(
            default_artifacts(BackendKind::Packed)?,
            tpk_path,
            block_len,
            capacity_blocks,
            mode,
        )
    }

    /// Shared tail of every loader: size the arena and box the parts.
    /// Rejects an int8 arena on backends whose attention path cannot
    /// read it ([`Backend::supports_kv_int8`]) — a load-time error beats
    /// a silent mis-decode.
    fn assemble(
        artifacts: Arc<Artifacts>,
        backend: Box<dyn Backend>,
        block_len: usize,
        capacity_blocks: usize,
        mode: ArenaLayout,
    ) -> Result<Self> {
        crate::ensure!(
            mode == ArenaLayout::F32 || backend.supports_kv_int8(),
            "backend '{}' cannot read an int8 KV arena (--kv-quant int8 needs a \
             host backend)",
            backend.name()
        );
        let layout = CacheLayout::with_block_len(&artifacts.manifest.model, block_len);
        let arena = if capacity_blocks == 0 {
            CacheArena::with_sessions_mode(layout, 0, mode)?
        } else {
            CacheArena::new_with_mode(layout, capacity_blocks, mode)?
        };
        let obs = Arc::new(Obs::new(0));
        backend.install_obs(Arc::clone(&obs));
        Ok(Self {
            artifacts,
            backend,
            arena: RefCell::new(arena),
            prefix: RefCell::new(None),
            obs,
        })
    }

    /// Load from the default `artifacts/` directory with the env-var
    /// backend; see [`Engine::load_default_with`].
    pub fn load_default() -> Result<Self> {
        Self::load_default_with(BackendKind::from_env()?)
    }

    /// [`Engine::load_default_with`] with explicit arena geometry (both
    /// `0` = defaults); what the CLI's `--arena-blocks` flag maps to.
    pub fn load_default_with_arena(
        kind: BackendKind,
        block_len: usize,
        capacity_blocks: usize,
    ) -> Result<Self> {
        Self::load_with_arena(default_artifacts(kind)?, kind, block_len, capacity_blocks)
    }

    /// [`Engine::load_default_with_arena`] with an explicit arena layout.
    pub fn load_default_with_arena_mode(
        kind: BackendKind,
        block_len: usize,
        capacity_blocks: usize,
        mode: ArenaLayout,
    ) -> Result<Self> {
        Self::load_with_arena_mode(
            default_artifacts(kind)?,
            kind,
            block_len,
            capacity_blocks,
            mode,
        )
    }

    /// Load from the default `artifacts/` directory; if no AOT artifacts
    /// exist there, fall back to the in-memory synthetic tiny model so
    /// the functional path still runs offline. The fallback applies to
    /// both host executors (reference and packed) — PJRT needs the real
    /// HLO text, so selecting it without artifacts is a clear error
    /// rather than a confusing HLO-parse failure later.
    pub fn load_default_with(kind: BackendKind) -> Result<Self> {
        Self::load_default_with_arena(kind, 0, 0)
    }
}

/// Artifacts from the default `artifacts/` directory, with the
/// synthetic tiny-model fallback for host backends — the shared loading
/// rule behind [`Engine::load_default_with_arena`] and
/// [`ShardedEngine::load_default`].
pub fn default_artifacts(kind: BackendKind) -> Result<Artifacts> {
    let dir = super::artifacts::default_dir();
    if dir.join("manifest.json").exists() {
        Artifacts::load(dir).context("loading artifacts (run `make artifacts`)")
    } else if kind.requires_aot_artifacts() {
        crate::bail!(
            "backend {kind:?} requires real AOT artifacts at {} — run `make \
             artifacts` first (only the host backends have a synthetic \
             fallback)",
            dir.display()
        )
    } else {
        eprintln!(
            "note: no AOT artifacts at {} — using the built-in synthetic tiny \
             model on the {kind:?} backend (run `make artifacts` for the real \
             AOT decoder)",
            dir.display()
        );
        Artifacts::synthetic(0)
    }
}

impl<B: ?Sized + Backend> EngineImpl<B> {
    /// Open a fresh decode session; retire it with
    /// [`Engine::free_session`] (the decoders do this on drop).
    pub fn new_session(&self) -> Result<CacheHandle> {
        self.backend.new_session(&mut self.arena.borrow_mut())
    }

    /// Retire a session, returning its cache blocks to the arena.
    pub fn free_session(&self, handle: CacheHandle) -> Result<()> {
        self.backend.drop_session(&mut self.arena.borrow_mut(), handle)
    }

    /// Non-panicking session release for `Drop` impls: skips (leaving
    /// the blocks to the arena's owner) if the arena is mid-borrow,
    /// which can only happen while unwinding out of an engine call.
    pub(crate) fn release_session(&self, handle: CacheHandle) {
        if let Ok(mut arena) = self.arena.try_borrow_mut() {
            let _ = self.backend.drop_session(&mut arena, handle);
        }
    }

    /// Reserve worst-case cache capacity (`positions` total fed tokens)
    /// for a session up front — what the fixed-wave serving policies do
    /// at admission so an admitted session can never stall mid-decode.
    pub fn reserve_session(&self, handle: CacheHandle, positions: usize) -> Result<()> {
        self.backend
            .reserve_session(&mut self.arena.borrow_mut(), handle, positions)
    }

    /// Execute one decode step: feed token `token_id` at position `pos`
    /// into the session's cache state (updated in place); returns the
    /// logits.
    pub fn decode_step(
        &self,
        handle: CacheHandle,
        token_id: i32,
        pos: i32,
    ) -> Result<Vec<f32>> {
        self.backend
            .decode_step(&mut self.arena.borrow_mut(), handle, token_id, pos)
    }

    /// Execute one decode step for B independent sessions in a single
    /// backend call (session `handles[i]` feeds `tokens[i]` at
    /// `positions[i]`; ragged positions allowed). Guaranteed
    /// bit-identical to B separate [`Engine::decode_step`] calls — on
    /// the host backends each weight matrix is traversed once per call
    /// instead of once per session.
    pub fn decode_batch(
        &self,
        handles: &[CacheHandle],
        tokens: &[i32],
        positions: &[i32],
    ) -> Result<Vec<Vec<f32>>> {
        self.backend
            .decode_batch(&mut self.arena.borrow_mut(), handles, tokens, positions)
    }

    /// Feed `tokens` into ONE session at consecutive positions
    /// `start_pos..start_pos + tokens.len()`, returning the logits after
    /// every fed position. Guaranteed bit-identical to the equivalent
    /// sequential [`Engine::decode_step`] loop — on the host backends
    /// over an f32 arena each weight matrix is traversed once per call
    /// instead of once per position, which is what chunked prefill and
    /// the speculative k-token verify amortize.
    pub fn decode_span(
        &self,
        handle: CacheHandle,
        tokens: &[i32],
        start_pos: i32,
    ) -> Result<Vec<Vec<f32>>> {
        self.backend
            .decode_span(&mut self.arena.borrow_mut(), handle, tokens, start_pos)
    }

    /// Roll a session's cache back to `keep_positions` fed positions,
    /// releasing whole trailing blocks through the arena block table —
    /// how speculative decoding drops the cache rows claimed for
    /// rejected draft tokens. Only meaningful on backends whose session
    /// state IS the arena (the host backends); see
    /// `CacheArena::truncate_session` for the row-level safety argument.
    pub fn truncate_session(&self, handle: CacheHandle, keep_positions: usize) -> Result<()> {
        self.arena.borrow_mut().truncate_session(handle, keep_positions)
    }

    /// Current arena occupancy (total/free/used blocks), the signal the
    /// continuous-batching scheduler admits and preempts on.
    pub fn arena_status(&self) -> ArenaStatus {
        self.arena.borrow().status()
    }

    /// Cache blocks needed to back `positions` fed tokens.
    pub fn blocks_for_positions(&self, positions: usize) -> usize {
        self.arena.borrow().layout().blocks_for_positions(positions)
    }

    /// Cache blocks the session currently holds.
    pub fn session_blocks(&self, handle: CacheHandle) -> Result<usize> {
        self.arena.borrow().session_blocks(handle)
    }

    /// Whether decoding the session at `pos` would claim a cache block
    /// it does not yet hold (always false on backends whose caches are
    /// not arena blocks, e.g. PJRT) — the continuous scheduler's
    /// pressure signal.
    pub fn session_needs_block(&self, handle: CacheHandle, pos: usize) -> Result<bool> {
        self.backend
            .session_needs_block(&self.arena.borrow(), handle, pos)
    }

    /// Reassemble a session's cache as the contiguous
    /// `(n_layers, h, max_ctx, d_head)` K/V tensors — test/diagnostic
    /// surface for the paged-vs-contiguous equivalence suites.
    pub fn gather_session(&self, handle: CacheHandle) -> Result<(Vec<f32>, Vec<f32>)> {
        self.arena.borrow().gather_contiguous(handle)
    }

    /// Cache positions per arena block.
    pub fn block_len(&self) -> usize {
        self.arena.borrow().layout().block_len
    }

    /// The arena's storage layout (f32 or group-scaled int8).
    pub fn arena_mode(&self) -> ArenaLayout {
        self.arena.borrow().mode()
    }

    /// Run the arena's full invariant check (refcount accounting, free
    /// list, pins) — test/diagnostic surface.
    pub fn debug_validate(&self) -> Result<()> {
        self.arena.borrow().debug_validate()
    }

    // ---- copy-on-write prefix cache --------------------------------

    /// Switch on the prefix cache, bounded at `cap_entries` cached
    /// blocks (`0` = [`super::prefixcache::DEFAULT_PREFIX_CAP`]).
    /// Returns whether it is actually active: backends whose decode
    /// path cannot read adopted arena blocks (PJRT's contiguous device
    /// shim) report no support and the engine stays cache-less — every
    /// request simply runs its full prefill, which is always correct.
    /// Re-enabling replaces the index: the old one is cleared first
    /// (every pin released), so its blocks return to the pool instead
    /// of leaking behind an unreachable index.
    pub fn enable_prefix_cache(&self, cap_entries: usize) -> bool {
        if !self.backend.supports_prefix_sharing() {
            return false;
        }
        let block_len = self.block_len();
        let mut prefix = self.prefix.borrow_mut();
        if let Some(old) = prefix.as_mut() {
            old.clear(&mut self.arena.borrow_mut())
                .expect("clearing prefix index: pin accounting corrupt");
        }
        *prefix = Some(PrefixCache::new(block_len, cap_entries));
        true
    }

    /// Whether the prefix cache is active.
    pub fn prefix_enabled(&self) -> bool {
        self.prefix.borrow().is_some()
    }

    /// FULL index blocks the current index would let `prompt` adopt —
    /// shared references that consume no free blocks, so admission can
    /// subtract them from a request's worst-case free-block need
    /// before reclaiming or gating. Touches the matched chain's LRU
    /// stamps, so a reclaim that immediately follows evicts everything
    /// ELSE first — the chain about to be adopted survives. Returns 0
    /// with the cache off.
    pub fn prefix_peek_blocks(&self, prompt: &[i32]) -> usize {
        self.prefix
            .borrow_mut()
            .as_mut()
            .map_or(0, |pc| pc.lookup(prompt).full_blocks.len())
    }

    /// Consult the prefix index for `prompt` and adopt the matched
    /// blocks into the (freshly opened, still block-less) session: full
    /// blocks are shared read-only; a partially matched tail block is
    /// shared and immediately copied ([`CacheArena::cow_block`], the
    /// matched rows kept) so the session's first write cannot touch the
    /// donor. Returns the number of positions whose prefill decode the
    /// caller may skip — the session's cache state at that point is
    /// bitwise what cold prefill would have produced. Always `0` when
    /// the cache is disabled. The eager tail copy consumes one free
    /// block; if none is available the tail is skipped (the full-block
    /// match still stands), so adoption never fails for lack of
    /// capacity.
    pub fn prefix_adopt(&self, handle: CacheHandle, prompt: &[i32]) -> Result<usize> {
        let mut prefix = self.prefix.borrow_mut();
        let Some(pc) = prefix.as_mut() else {
            return Ok(0);
        };
        let mut arena = self.arena.borrow_mut();
        crate::ensure!(
            arena.session_blocks(handle)? == 0,
            "prefix adoption requires a fresh session (it holds blocks)"
        );
        let m = pc.lookup(prompt);
        let mut adopted = 0usize;
        if !m.full_blocks.is_empty() {
            arena.share_blocks(handle, &m.full_blocks)?;
            adopted = m.full_blocks.len() * arena.layout().block_len;
        }
        if let Some((tail, rows)) = m.tail {
            if arena.status().free_blocks > 0 {
                arena.share_blocks(handle, &[tail])?;
                arena.cow_block(handle, m.full_blocks.len(), rows)?;
                adopted += rows;
            }
        }
        if adopted > 0 {
            pc.stats.hits += 1;
            pc.stats.saved_tokens += adopted;
        } else {
            pc.stats.misses += 1;
        }
        Ok(adopted)
    }

    /// Record a finished prefill in the prefix index: the session's
    /// blocks covering whole groups of `prompt` are pinned and keyed by
    /// their tokens (existing entries are reused — contents are bitwise
    /// identical by decode determinism). CONTRACT: the session must
    /// have decoded (or adopted) at least all of `prompt`, so those
    /// blocks are fully written. No-op while the cache is disabled.
    pub fn prefix_insert(&self, handle: CacheHandle, prompt: &[i32]) -> Result<()> {
        let mut prefix = self.prefix.borrow_mut();
        let Some(pc) = prefix.as_mut() else {
            return Ok(());
        };
        let mut arena = self.arena.borrow_mut();
        let block_len = arena.layout().block_len;
        let full = prompt.len() / block_len;
        if full == 0 {
            return Ok(());
        }
        let table = arena.session_table(handle)?;
        crate::ensure!(
            table.len() >= full,
            "prefix insert: session holds {} blocks, prompt needs {full}",
            table.len()
        );
        pc.insert(&mut arena, &prompt[..full * block_len], &table[..full])
    }

    /// Roll back the hit/miss/saved counters of an adoption whose
    /// admission was abandoned before any decode happened (the serving
    /// loop's deferred-admission path frees the session and requeues
    /// the request, which will adopt — and count — again on retry).
    /// Keeps engine-level [`PrefixStats`] equal to the sum of
    /// response-level `cached_tokens`. `adopted` is what the rolled-back
    /// `prefix_adopt` returned. No-op with the cache off.
    pub fn prefix_unrecord(&self, adopted: usize) {
        if let Some(pc) = self.prefix.borrow_mut().as_mut() {
            if adopted > 0 {
                pc.stats.hits = pc.stats.hits.saturating_sub(1);
                pc.stats.saved_tokens = pc.stats.saved_tokens.saturating_sub(adopted);
            } else {
                pc.stats.misses = pc.stats.misses.saturating_sub(1);
            }
        }
    }

    /// Evict least-recently-used prefix entries (unpinning their
    /// blocks) until at least `want_free` arena blocks are free or the
    /// index is empty — how the serving layer turns index pins back
    /// into schedulable capacity under pressure. Returns blocks freed.
    pub fn prefix_reclaim(&self, want_free: usize) -> Result<usize> {
        let mut prefix = self.prefix.borrow_mut();
        let Some(pc) = prefix.as_mut() else {
            return Ok(0);
        };
        let evicted_before = pc.stats.evictions;
        let freed = pc.reclaim(&mut self.arena.borrow_mut(), want_free)?;
        if self.obs.enabled() {
            let evicted = (pc.stats.evictions - evicted_before) as u64;
            if evicted > 0 {
                self.obs.event(EventKind::Eviction, evicted, 0);
                self.obs.count(Counter::PrefixEvictions, evicted);
            }
            self.obs
                .event(EventKind::Reclaim, freed as u64, want_free as u64);
            self.obs.count(Counter::BlocksReclaimed, freed as u64);
        }
        Ok(freed)
    }

    /// Effectiveness counters of the prefix cache (None when disabled).
    pub fn prefix_stats(&self) -> Option<PrefixStats> {
        self.prefix.borrow().as_ref().map(|pc| pc.stats)
    }

    /// Live entries (pinned blocks) in the prefix index.
    pub fn prefix_entries(&self) -> usize {
        self.prefix.borrow().as_ref().map_or(0, |pc| pc.len())
    }

    /// Blocks a serving loop restricted to `handles` could ever obtain:
    /// free blocks plus blocks held only by those sessions and/or
    /// reclaimable prefix pins — shared blocks counted once. See
    /// [`CacheArena::obtainable_with`].
    pub fn obtainable_blocks(&self, handles: &[CacheHandle]) -> usize {
        self.arena.borrow().obtainable_with(handles)
    }

    pub fn vocab(&self) -> usize {
        self.artifacts.manifest.model.vocab
    }

    pub fn max_ctx(&self) -> usize {
        self.artifacts.manifest.model.max_ctx
    }

    /// The loaded artifact bundle (manifest + weights) — what a
    /// speculative-decoding setup clones to run the SAME model as its
    /// own draft, and reads shapes from to size a smaller one.
    pub fn artifacts(&self) -> &Arc<Artifacts> {
        &self.artifacts
    }

    pub fn platform(&self) -> String {
        self.backend.platform()
    }

    /// Short backend identifier: "reference", "packed" or "pjrt".
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Whether the backend's session state lives in the arena's block
    /// tables (the host backends) rather than in private buffers (PJRT's
    /// contiguous device caches). The precondition for everything that
    /// manipulates a session through its table — prefix-block adoption,
    /// span capacity capping, and the speculative-verify rollback
    /// ([`Engine::truncate_session`]).
    pub fn arena_backed(&self) -> bool {
        self.backend.supports_prefix_sharing()
    }

    // ---- observability ---------------------------------------------

    /// This engine's observability bundle (trace ring + metrics). The
    /// same instance is installed in the backend at assembly, so kernel
    /// spans share the serving events' timeline. Disabled by default;
    /// flip with [`Obs::set_enabled`] outside any decode loop.
    pub fn obs(&self) -> &Arc<Obs> {
        &self.obs
    }

    /// Point-in-time copy of this engine's metrics registry.
    pub fn metrics_snapshot(&self) -> MetricsSnapshot {
        self.obs.metrics.snapshot()
    }

    /// Lifetime copy-on-write block copies in this engine's arena
    /// (adoption tail copies plus decode-time shared-block writes) —
    /// the serving tick reads per-tick deltas off this monotonic count.
    pub fn cow_copies(&self) -> u64 {
        self.arena.borrow().cow_copies()
    }
}

// ---- sharded engine ------------------------------------------------

/// A host backend boxed as `dyn Backend + Send`, one per worker. Both
/// host executors are plain data over `Arc<Artifacts>` (the weights are
/// shared immutably), so the compiler derives `Send` structurally.
/// When `packed` carries a pre-lowered [`PackedModel`] (loaded once
/// from a `.tpk` artifact, or lowered once in memory) every worker
/// shares that one copy — N workers no longer re-pack N times. PJRT
/// keeps device-resident session state and cannot be sharded.
fn host_backend(
    artifacts: &Arc<Artifacts>,
    kind: BackendKind,
    packed: Option<&Arc<PackedModel>>,
) -> Result<Box<dyn Backend + Send>> {
    match kind {
        BackendKind::Reference => {
            crate::ensure!(
                packed.is_none(),
                "a packed model artifact only loads on the packed backend"
            );
            Ok(Box::new(super::reference::ReferenceBackend::new(
                Arc::clone(artifacts),
            )?))
        }
        BackendKind::Packed => Ok(match packed {
            Some(model) => Box::new(super::packed::PackedBackend::with_model(
                Arc::clone(artifacts),
                Arc::clone(model),
            )?),
            None => Box::new(super::packed::PackedBackend::new(Arc::clone(artifacts))?),
        }),
        #[cfg(feature = "pjrt")]
        BackendKind::Pjrt => crate::bail!(
            "sharded serving needs a host backend (reference | packed); the PJRT \
             backend keeps device-resident session state and cannot move to a \
             worker thread"
        ),
    }
}

/// Deterministic request→shard placement: a SplitMix64 hash of the
/// request id modulo the shard count. Never use `std`'s `DefaultHasher`
/// here — `RandomState` is seeded per process, which would break the
/// headline guarantee that placement (and therefore every shard-local
/// schedule) is reproducible across runs.
pub fn shard_for(request_id: u64, shards: usize) -> usize {
    debug_assert!(shards >= 1);
    (crate::util::rng::Rng::new(request_id).next_u64() % shards.max(1) as u64) as usize
}

/// A session handle carrying the shard that owns it. Block indices and
/// COW refcounts are shard-local, so a `CacheHandle` alone no longer
/// names a session once the arena is partitioned — the pair does.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ShardHandle {
    pub shard: usize,
    pub handle: CacheHandle,
}

/// N worker-owned [`EngineShard`]s behind one facade: the total arena
/// capacity is partitioned deterministically across shards
/// ([`CacheArena::split`]), each shard gets its own backend instance and
/// its own prefix-cache index, and nothing is shared between shards but
/// the immutable `Arc<Artifacts>`. The sharded serving loop
/// ([`crate::serving::serve_sharded`]) moves `&mut` shard references
/// into scoped worker threads; single-threaded callers can instead
/// drive sessions through the [`ShardHandle`] API below, which routes
/// each call to the owning shard.
pub struct ShardedEngine {
    shards: Vec<EngineShard>,
}

impl ShardedEngine {
    /// Build `workers` shards over `total_blocks` of arena capacity
    /// (`0` selects the same default total as [`Engine::load_with_arena`];
    /// either way the TOTAL is fixed and then split, so comparing worker
    /// counts compares schedulers, not memory budgets).
    pub fn load(
        artifacts: Artifacts,
        kind: BackendKind,
        block_len: usize,
        total_blocks: usize,
        workers: usize,
    ) -> Result<Self> {
        Self::load_mode(artifacts, kind, block_len, total_blocks, workers, ArenaLayout::F32)
    }

    /// [`ShardedEngine::load`] with an explicit arena storage layout —
    /// every shard's partition shares the one layout.
    pub fn load_mode(
        artifacts: Artifacts,
        kind: BackendKind,
        block_len: usize,
        total_blocks: usize,
        workers: usize,
        mode: ArenaLayout,
    ) -> Result<Self> {
        Self::build(Arc::new(artifacts), kind, None, block_len, total_blocks, workers, mode)
    }

    /// Sharded serving from a `.tpk` packed artifact: the model is
    /// loaded (mmap'd where possible) ONCE and the single
    /// [`PackedModel`] is shared by every worker's backend, so startup
    /// cost is independent of the worker count and no worker re-packs
    /// anything.
    pub fn load_packed_artifact(
        artifacts: Artifacts,
        tpk_path: &Path,
        block_len: usize,
        total_blocks: usize,
        workers: usize,
    ) -> Result<Self> {
        Self::load_packed_artifact_mode(
            artifacts,
            tpk_path,
            block_len,
            total_blocks,
            workers,
            ArenaLayout::F32,
        )
    }

    /// [`ShardedEngine::load_packed_artifact`] with an explicit arena
    /// layout.
    pub fn load_packed_artifact_mode(
        artifacts: Artifacts,
        tpk_path: &Path,
        block_len: usize,
        total_blocks: usize,
        workers: usize,
        mode: ArenaLayout,
    ) -> Result<Self> {
        let artifacts = Arc::new(artifacts);
        let model = Arc::new(crate::quant::load_tpk(tpk_path, &artifacts)?);
        Self::build(
            artifacts,
            BackendKind::Packed,
            Some(&model),
            block_len,
            total_blocks,
            workers,
            mode,
        )
    }

    /// [`ShardedEngine::load_packed_artifact`] over the default
    /// artifacts directory (synthetic fallback).
    pub fn load_default_packed_artifact(
        tpk_path: &Path,
        block_len: usize,
        total_blocks: usize,
        workers: usize,
    ) -> Result<Self> {
        Self::load_default_packed_artifact_mode(
            tpk_path,
            block_len,
            total_blocks,
            workers,
            ArenaLayout::F32,
        )
    }

    /// [`ShardedEngine::load_default_packed_artifact`] with an explicit
    /// arena layout.
    pub fn load_default_packed_artifact_mode(
        tpk_path: &Path,
        block_len: usize,
        total_blocks: usize,
        workers: usize,
        mode: ArenaLayout,
    ) -> Result<Self> {
        Self::load_packed_artifact_mode(
            default_artifacts(BackendKind::Packed)?,
            tpk_path,
            block_len,
            total_blocks,
            workers,
            mode,
        )
    }

    #[allow(clippy::too_many_arguments)]
    fn build(
        artifacts: Arc<Artifacts>,
        kind: BackendKind,
        packed: Option<&Arc<PackedModel>>,
        block_len: usize,
        total_blocks: usize,
        workers: usize,
        mode: ArenaLayout,
    ) -> Result<Self> {
        crate::ensure!(workers >= 1, "sharded engine needs at least one worker");
        let layout = CacheLayout::with_block_len(&artifacts.manifest.model, block_len);
        let total = if total_blocks == 0 {
            layout.blocks_per_session().max(1) * super::kvcache::DEFAULT_ARENA_SESSIONS
        } else {
            total_blocks
        };
        let shards = CacheArena::split_mode(layout, total, workers, mode)?
            .into_iter()
            .enumerate()
            .map(|(w, arena)| {
                let backend = host_backend(&artifacts, kind, packed)?;
                crate::ensure!(
                    mode == ArenaLayout::F32 || backend.supports_kv_int8(),
                    "backend '{}' cannot read an int8 KV arena",
                    backend.name()
                );
                // One bundle per shard: worker id names the trace track.
                let obs = Arc::new(Obs::new(w));
                backend.install_obs(Arc::clone(&obs));
                Ok(EngineImpl {
                    artifacts: Arc::clone(&artifacts),
                    backend,
                    arena: RefCell::new(arena),
                    prefix: RefCell::new(None),
                    obs,
                })
            })
            .collect::<Result<Vec<EngineShard>>>()?;
        Ok(Self { shards })
    }

    /// [`ShardedEngine::load`] over the default artifacts directory
    /// (synthetic fallback for host backends) — what `repro serve
    /// --policy sharded` maps to.
    pub fn load_default(
        kind: BackendKind,
        block_len: usize,
        total_blocks: usize,
        workers: usize,
    ) -> Result<Self> {
        Self::load(default_artifacts(kind)?, kind, block_len, total_blocks, workers)
    }

    /// [`ShardedEngine::load_default`] with an explicit arena layout.
    pub fn load_default_mode(
        kind: BackendKind,
        block_len: usize,
        total_blocks: usize,
        workers: usize,
        mode: ArenaLayout,
    ) -> Result<Self> {
        Self::load_mode(
            default_artifacts(kind)?,
            kind,
            block_len,
            total_blocks,
            workers,
            mode,
        )
    }

    pub fn workers(&self) -> usize {
        self.shards.len()
    }

    pub fn shard(&self, shard: usize) -> &EngineShard {
        &self.shards[shard]
    }

    /// Exclusive shard access — the sharded serving loop `iter_mut`s
    /// this to move one `&mut EngineShard` into each worker thread.
    pub fn shards_mut(&mut self) -> &mut [EngineShard] {
        &mut self.shards
    }

    /// The shard this request id is placed on ([`shard_for`]).
    pub fn placement(&self, request_id: u64) -> usize {
        shard_for(request_id, self.shards.len())
    }

    /// Open a session on the shard that owns `request_id`.
    pub fn new_session(&self, request_id: u64) -> Result<ShardHandle> {
        self.new_session_on(self.placement(request_id))
    }

    /// Open a session on an explicit shard.
    pub fn new_session_on(&self, shard: usize) -> Result<ShardHandle> {
        crate::ensure!(shard < self.shards.len(), "no shard {shard}");
        Ok(ShardHandle {
            shard,
            handle: self.shards[shard].new_session()?,
        })
    }

    pub fn free_session(&self, h: ShardHandle) -> Result<()> {
        self.shards[h.shard].free_session(h.handle)
    }

    pub fn decode_step(&self, h: ShardHandle, token_id: i32, pos: i32) -> Result<Vec<f32>> {
        self.shards[h.shard].decode_step(h.handle, token_id, pos)
    }

    /// Enable every shard's private prefix index, each bounded at
    /// `cap_entries` (the per-shard cap; indices never share blocks
    /// because blocks never cross shards). Returns whether the backend
    /// supports prefix sharing at all.
    pub fn enable_prefix_cache(&self, cap_entries: usize) -> bool {
        self.shards.iter().all(|s| s.enable_prefix_cache(cap_entries))
    }

    pub fn prefix_enabled(&self) -> bool {
        self.shards.iter().any(|s| s.prefix_enabled())
    }

    /// Prefix-cache counters summed across shards (None when disabled).
    pub fn prefix_stats(&self) -> Option<PrefixStats> {
        let mut merged: Option<PrefixStats> = None;
        for s in &self.shards {
            if let Some(st) = s.prefix_stats() {
                merged.get_or_insert_with(PrefixStats::default).absorb(st);
            }
        }
        merged
    }

    /// Live prefix-index entries summed across shards.
    pub fn prefix_entries(&self) -> usize {
        self.shards.iter().map(|s| s.prefix_entries()).sum()
    }

    /// Arena occupancy merged across shards (block counts and byte
    /// totals summed; the block length and per-block byte cost are
    /// uniform by construction, so they carry over from shard 0).
    pub fn arena_status(&self) -> ArenaStatus {
        let mut merged = self.shards[0].arena_status();
        for s in &self.shards[1..] {
            let st = s.arena_status();
            merged.total_blocks += st.total_blocks;
            merged.free_blocks += st.free_blocks;
            merged.used_blocks += st.used_blocks;
            merged.live_sessions += st.live_sessions;
            merged.pinned_blocks += st.pinned_blocks;
            merged.total_bytes += st.total_bytes;
            merged.used_bytes += st.used_bytes;
        }
        merged
    }

    /// Run every shard's full arena invariant check.
    pub fn debug_validate(&self) -> Result<()> {
        for (i, s) in self.shards.iter().enumerate() {
            s.debug_validate()
                .with_context(|| format!("shard {i} accounting"))?;
        }
        Ok(())
    }

    pub fn block_len(&self) -> usize {
        self.shards[0].block_len()
    }

    /// The arena storage layout (uniform across shards).
    pub fn arena_mode(&self) -> ArenaLayout {
        self.shards[0].arena_mode()
    }

    pub fn vocab(&self) -> usize {
        self.shards[0].vocab()
    }

    pub fn max_ctx(&self) -> usize {
        self.shards[0].max_ctx()
    }

    pub fn backend_name(&self) -> &'static str {
        self.shards[0].backend_name()
    }

    pub fn platform(&self) -> String {
        self.shards[0].platform()
    }

    // ---- observability ---------------------------------------------

    /// Every shard's observability bundle, in ascending worker-id
    /// order — one trace track per worker.
    pub fn obs(&self) -> Vec<Arc<Obs>> {
        self.shards.iter().map(|s| Arc::clone(s.obs())).collect()
    }

    /// Flip collection on every shard (outside the serving loop only:
    /// the first enable allocates each shard's trace ring).
    pub fn set_obs_enabled(&self, on: bool) {
        for s in &self.shards {
            s.obs().set_enabled(on);
        }
    }

    /// Metrics merged across shards in ascending worker-id order (the
    /// [`PrefixStats::absorb`] pattern): counters and histogram buckets
    /// sum, gauges sum because shards partition the arena and sessions.
    pub fn metrics_snapshot(&self) -> MetricsSnapshot {
        let mut merged = self.shards[0].metrics_snapshot();
        for s in &self.shards[1..] {
            merged.absorb(&s.metrics_snapshot());
        }
        merged
    }

    /// Drain every shard's trace ring, chronological within each shard,
    /// tagged with the worker id in ascending order — the shape
    /// [`crate::obs::export::chrome_trace`] takes as tracks.
    pub fn drain_traces(&self) -> Vec<(usize, Vec<crate::obs::Event>)> {
        self.shards
            .iter()
            .map(|s| (s.obs().shard(), s.obs().trace.drain()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine() -> Engine {
        Engine::load_with(Artifacts::synthetic(1).unwrap(), BackendKind::Reference)
            .expect("engine")
    }

    #[test]
    fn engine_loads_and_steps_offline() {
        let e = engine();
        assert_eq!(e.backend_name(), "reference");
        assert_eq!(e.platform(), "cpu");
        let s = e.new_session().unwrap();
        let logits = e.decode_step(s, 1, 0).unwrap();
        assert_eq!(logits.len(), e.vocab());
        assert!(logits.iter().all(|x| x.is_finite()));
        e.free_session(s).unwrap();
    }

    #[test]
    fn packed_engine_loads_and_matches_reference() {
        let reference = engine();
        let packed =
            Engine::load_with(Artifacts::synthetic(1).unwrap(), BackendKind::Packed)
                .expect("packed engine");
        assert_eq!(packed.backend_name(), "packed");
        let rs = reference.new_session().unwrap();
        let ps = packed.new_session().unwrap();
        assert_eq!(
            reference.decode_step(rs, 7, 0).unwrap(),
            packed.decode_step(ps, 7, 0).unwrap()
        );
    }

    #[test]
    fn backend_names_resolve() {
        assert_eq!(BackendKind::from_name("").unwrap(), BackendKind::Reference);
        assert_eq!(
            BackendKind::from_name("reference").unwrap(),
            BackendKind::Reference
        );
        assert_eq!(
            BackendKind::from_name("packed").unwrap(),
            BackendKind::Packed
        );
        assert!(BackendKind::from_name("tpu").is_err());
        #[cfg(not(feature = "pjrt"))]
        assert!(BackendKind::from_name("pjrt").is_err());
        // The flag wins over the env var; no flag falls through.
        assert_eq!(
            BackendKind::resolve(Some("packed")).unwrap(),
            BackendKind::Packed
        );
        assert!(BackendKind::resolve(Some("nope")).is_err());
        // AOT requirement: only PJRT insists on real artifacts.
        assert!(!BackendKind::Reference.requires_aot_artifacts());
        assert!(!BackendKind::Packed.requires_aot_artifacts());
    }

    #[test]
    fn decode_step_deterministic() {
        let e = engine();
        let s1 = e.new_session().unwrap();
        let s2 = e.new_session().unwrap();
        assert_eq!(
            e.decode_step(s1, 5, 0).unwrap(),
            e.decode_step(s2, 5, 0).unwrap()
        );
    }

    #[test]
    fn sessions_thread_state_and_free_releases_blocks() {
        // Feeding [1] then [2] must differ from feeding [2] fresh, and
        // retiring sessions must return their blocks to the pool.
        let e = engine();
        let full = e.arena_status().free_blocks;
        let s = e.new_session().unwrap();
        e.decode_step(s, 1, 0).unwrap();
        let continued = e.decode_step(s, 2, 1).unwrap();
        let fresh_s = e.new_session().unwrap();
        let fresh = e.decode_step(fresh_s, 2, 0).unwrap();
        assert_ne!(continued, fresh);
        assert!(e.arena_status().free_blocks < full);
        e.free_session(s).unwrap();
        e.free_session(fresh_s).unwrap();
        assert_eq!(e.arena_status().free_blocks, full);
        // Stale handle rejected.
        assert!(e.decode_step(s, 0, 0).is_err());
    }

    #[test]
    fn decode_batch_matches_individual_steps() {
        let e = engine();
        let sa = e.new_session().unwrap();
        let sb = e.new_session().unwrap();
        let a = e.decode_step(sa, 3, 0).unwrap();
        let b = e.decode_step(sb, 9, 0).unwrap();
        let ba = e.new_session().unwrap();
        let bb = e.new_session().unwrap();
        let out = e.decode_batch(&[ba, bb], &[3, 9], &[0, 0]).unwrap();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0], a);
        assert_eq!(out[1], b);
    }

    #[test]
    fn explicit_arena_geometry_is_respected() {
        let e = Engine::load_with_arena(
            Artifacts::synthetic(1).unwrap(),
            BackendKind::Reference,
            4,
            6,
        )
        .unwrap();
        let st = e.arena_status();
        assert_eq!(st.block_len, 4);
        assert_eq!(st.total_blocks, 6);
        assert_eq!(e.blocks_for_positions(0), 0);
        assert_eq!(e.blocks_for_positions(4), 1);
        assert_eq!(e.blocks_for_positions(5), 2);
        // Reservation claims worst-case blocks up front.
        let s = e.new_session().unwrap();
        e.reserve_session(s, 9).unwrap();
        assert_eq!(e.session_blocks(s).unwrap(), 3);
        assert_eq!(e.arena_status().free_blocks, 3);
    }

    #[test]
    fn decode_step_matches_golden_first_logits() {
        let e = engine();
        let g = e.artifacts.golden.clone();
        let s = e.new_session().unwrap();
        let logits = e.decode_step(s, g.prompt[0], 0).unwrap();
        for (got, want) in logits.iter().zip(g.first_logits_prefix.iter()) {
            assert!(
                (got - want).abs() <= 1e-4 * want.abs().max(1.0),
                "{got} vs {want}"
            );
        }
        let l2: f64 = logits
            .iter()
            .map(|&x| (x as f64) * (x as f64))
            .sum::<f64>()
            .sqrt();
        assert!((l2 - g.first_logits_l2).abs() / g.first_logits_l2 < 1e-4);
    }

    #[test]
    fn prefix_adoption_skips_prefill_bitwise() {
        // Engine-level smoke of the COW prefix cache (the full sweep is
        // tests/prefix_equivalence.rs): a donor prefills and indexes a
        // prompt; an adopter skips the matched positions and must land
        // on bitwise-identical logits and caches.
        let e = Engine::load_with_arena(
            Artifacts::synthetic(1).unwrap(),
            BackendKind::Reference,
            4,
            32,
        )
        .unwrap();
        assert!(!e.prefix_enabled());
        assert!(e.enable_prefix_cache(0));
        assert!(e.prefix_enabled());

        let prompt = [3i32, 1, 4, 1, 5, 9, 2, 6, 5, 3];
        let donor = e.new_session().unwrap();
        let mut donor_logits = Vec::new();
        for (pos, &t) in prompt.iter().enumerate() {
            donor_logits.push(e.decode_step(donor, t, pos as i32).unwrap());
        }
        e.prefix_insert(donor, &prompt).unwrap();
        assert_eq!(e.prefix_entries(), 2); // 8 of 10 tokens = 2 full blocks

        // Adoption matches the two cached full blocks (the index holds
        // only full blocks, so the partial 3rd block is re-decoded).
        let s = e.new_session().unwrap();
        let skipped = e.prefix_adopt(s, &prompt).unwrap();
        assert_eq!(skipped, 8);
        for (pos, &t) in prompt.iter().enumerate().skip(skipped) {
            assert_eq!(
                e.decode_step(s, t, pos as i32).unwrap(),
                donor_logits[pos],
                "adopted decode diverged at pos {pos}"
            );
        }
        assert_eq!(
            e.gather_session(s).unwrap(),
            e.gather_session(donor).unwrap(),
            "adopted caches must be bitwise the cold-prefill caches"
        );
        let stats = e.prefix_stats().unwrap();
        assert_eq!((stats.hits, stats.misses, stats.saved_tokens), (1, 0, 8));

        // Freeing the donor keeps the indexed blocks alive (pins).
        e.free_session(donor).unwrap();
        e.debug_validate().unwrap();
        let s2 = e.new_session().unwrap();
        assert_eq!(e.prefix_adopt(s2, &prompt).unwrap(), 8);
        e.free_session(s).unwrap();
        e.free_session(s2).unwrap();
        // Reclaim empties the index and returns the pinned blocks.
        e.prefix_reclaim(usize::MAX).unwrap();
        assert_eq!(e.prefix_entries(), 0);
        let st = e.arena_status();
        assert_eq!(st.free_blocks, st.total_blocks);
        e.debug_validate().unwrap();
    }

    #[test]
    fn re_enabling_prefix_cache_releases_old_pins() {
        // Swapping in a new index (resize/reset) must clear the old
        // one: its pins would otherwise be orphaned — unreachable by
        // reclaim, permanently stealing arena blocks.
        let e = Engine::load_with_arena(
            Artifacts::synthetic(2).unwrap(),
            BackendKind::Reference,
            4,
            16,
        )
        .unwrap();
        assert!(e.enable_prefix_cache(0));
        let prompt: Vec<i32> = (1..=8).collect();
        let s = e.new_session().unwrap();
        for (pos, &t) in prompt.iter().enumerate() {
            e.decode_step(s, t, pos as i32).unwrap();
        }
        e.prefix_insert(s, &prompt).unwrap();
        e.free_session(s).unwrap();
        assert_eq!(e.arena_status().pinned_blocks, 2);
        assert!(e.enable_prefix_cache(8)); // resize: old index cleared
        assert_eq!(e.arena_status().pinned_blocks, 0);
        let st = e.arena_status();
        assert_eq!(st.free_blocks, st.total_blocks, "old pins must be released");
        assert_eq!(e.prefix_entries(), 0);
        e.debug_validate().unwrap();
    }

    #[test]
    fn prefix_cache_disabled_is_inert() {
        let e = engine();
        let s = e.new_session().unwrap();
        assert_eq!(e.prefix_adopt(s, &[1, 2, 3]).unwrap(), 0);
        e.decode_step(s, 1, 0).unwrap();
        e.prefix_insert(s, &[1]).unwrap();
        assert_eq!(e.prefix_reclaim(4).unwrap(), 0);
        assert!(e.prefix_stats().is_none());
    }

    #[test]
    fn prefix_adoption_requires_a_fresh_session() {
        let e = engine();
        e.enable_prefix_cache(0);
        let donor = e.new_session().unwrap();
        for (pos, t) in (0..20).enumerate() {
            e.decode_step(donor, t, pos as i32).unwrap();
        }
        let toks: Vec<i32> = (0..20).collect();
        e.prefix_insert(donor, &toks).unwrap();
        let s = e.new_session().unwrap();
        e.decode_step(s, 0, 0).unwrap(); // session already has a block
        assert!(e.prefix_adopt(s, &toks).is_err());
    }

    #[test]
    fn engines_agree_across_instances() {
        // Two engines from the same artifacts must agree bitwise.
        let e1 = engine();
        let e2 = engine();
        let s1 = e1.new_session().unwrap();
        let s2 = e2.new_session().unwrap();
        assert_eq!(
            e1.decode_step(s1, 42, 0).unwrap(),
            e2.decode_step(s2, 42, 0).unwrap()
        );
    }

    #[test]
    fn packed_artifact_engines_match_lowered_engines() {
        // Engine + ShardedEngine loaded from a .tpk must be bitwise the
        // engines that lower the packed model in memory (the full
        // corruption matrix lives in tests/artifact_roundtrip.rs).
        let dir = std::env::temp_dir().join(format!("pim-llm-engine-tpk-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("engine.tpk");
        let artifacts = Artifacts::synthetic(1).unwrap();
        let lowered = crate::quant::PackedModel::lower(&artifacts).unwrap();
        crate::quant::write_tpk(&path, &lowered, &artifacts.manifest).unwrap();

        let from_tpk =
            Engine::load_packed_artifact(Artifacts::synthetic(1).unwrap(), &path, 0, 0)
                .expect("engine from .tpk");
        let packed =
            Engine::load_with(Artifacts::synthetic(1).unwrap(), BackendKind::Packed).unwrap();
        assert_eq!(from_tpk.backend_name(), "packed");
        let s1 = from_tpk.new_session().unwrap();
        let s2 = packed.new_session().unwrap();
        for (pos, tok) in [3i32, 1, 4, 1, 5].into_iter().enumerate() {
            assert_eq!(
                from_tpk.decode_step(s1, tok, pos as i32).unwrap(),
                packed.decode_step(s2, tok, pos as i32).unwrap(),
                "tpk-loaded engine diverged at pos {pos}"
            );
        }

        let se = ShardedEngine::load_packed_artifact(
            Artifacts::synthetic(1).unwrap(),
            &path,
            4,
            16,
            2,
        )
        .expect("sharded engine from .tpk");
        // Every shard shares the single loaded model (same allocation).
        let h = se.new_session_on(1).unwrap();
        let s3 = packed.new_session().unwrap();
        assert_eq!(
            se.decode_step(h, 7, 0).unwrap(),
            packed.decode_step(s3, 7, 0).unwrap()
        );
        // A .tpk cannot sneak onto the reference backend.
        assert!(host_backend(
            &Arc::new(Artifacts::synthetic(1).unwrap()),
            BackendKind::Reference,
            Some(&Arc::new(lowered)),
        )
        .is_err());
        std::fs::remove_file(&path).ok();
        std::fs::remove_dir(&dir).ok();
    }

    fn sharded(workers: usize) -> ShardedEngine {
        ShardedEngine::load(
            Artifacts::synthetic(1).unwrap(),
            BackendKind::Reference,
            4,
            16,
            workers,
        )
        .expect("sharded engine")
    }

    #[test]
    fn shards_are_send_and_split_the_total_capacity() {
        fn assert_send<T: Send>() {}
        assert_send::<EngineShard>();
        assert_send::<&mut EngineShard>();

        let se = sharded(4);
        assert_eq!(se.workers(), 4);
        // 16 blocks over 4 shards: equal total capacity, split evenly.
        assert_eq!(se.arena_status().total_blocks, 16);
        for i in 0..4 {
            assert_eq!(se.shard(i).arena_status().total_blocks, 4);
        }
        // Worker count changes the partition, never the total.
        assert_eq!(sharded(3).arena_status().total_blocks, 16);
        assert!(ShardedEngine::load(
            Artifacts::synthetic(1).unwrap(),
            BackendKind::Reference,
            4,
            16,
            0
        )
        .is_err());
    }

    #[test]
    fn int8_engines_decode_and_report_byte_accounting() {
        let e = Engine::load_with_arena_mode(
            Artifacts::synthetic(1).unwrap(),
            BackendKind::Reference,
            4,
            8,
            ArenaLayout::KvInt8,
        )
        .unwrap();
        assert_eq!(e.arena_mode(), ArenaLayout::KvInt8);
        let st = e.arena_status();
        assert_eq!(st.total_blocks, 8);
        assert_eq!(st.total_bytes, 8 * st.block_bytes);
        assert_eq!(st.used_bytes, 0);
        // An int8 block costs roughly a quarter of the f32 block.
        let f32e = Engine::load_with_arena(
            Artifacts::synthetic(1).unwrap(),
            BackendKind::Reference,
            4,
            8,
        )
        .unwrap();
        assert_eq!(f32e.arena_mode(), ArenaLayout::F32);
        assert!(st.block_bytes * 3 < f32e.arena_status().block_bytes);
        // Decode runs and produces finite logits; bytes track blocks.
        let s = e.new_session().unwrap();
        let logits = e.decode_step(s, 1, 0).unwrap();
        assert!(logits.iter().all(|x| x.is_finite()));
        assert_eq!(e.arena_status().used_bytes, st.block_bytes);
        e.free_session(s).unwrap();
        e.debug_validate().unwrap();
        // Sharded facade: split keeps the layout, bytes merge by sum.
        let se = ShardedEngine::load_mode(
            Artifacts::synthetic(1).unwrap(),
            BackendKind::Reference,
            4,
            16,
            2,
            ArenaLayout::KvInt8,
        )
        .unwrap();
        assert_eq!(se.arena_mode(), ArenaLayout::KvInt8);
        assert_eq!(se.arena_status().total_bytes, 16 * st.block_bytes);
    }

    #[test]
    fn placement_is_deterministic_and_uses_every_shard() {
        let se = sharded(4);
        let mut hit = [false; 4];
        for id in 0..64u64 {
            let p = se.placement(id);
            assert_eq!(p, shard_for(id, 4), "placement must be the pure hash");
            assert_eq!(p, se.placement(id), "repeated placement must agree");
            hit[p] = true;
        }
        assert!(hit.iter().all(|&h| h), "64 ids should touch all 4 shards");
        // Single shard: everything lands on shard 0.
        assert!((0..16u64).all(|id| shard_for(id, 1) == 0));
    }

    #[test]
    fn shard_handles_route_to_their_owning_shard() {
        let se = sharded(2);
        let e = engine();
        // A session decoded through the facade must agree bitwise with
        // the monolithic engine, on whichever shard placement picks.
        let h = se.new_session(7).unwrap();
        let s = e.new_session().unwrap();
        assert_eq!(
            se.decode_step(h, 5, 0).unwrap(),
            e.decode_step(s, 5, 0).unwrap()
        );
        // The blocks live on the owning shard only.
        assert_eq!(se.shard(h.shard).arena_status().used_blocks, 1);
        assert_eq!(se.shard(1 - h.shard).arena_status().used_blocks, 0);
        se.free_session(h).unwrap();
        assert_eq!(se.arena_status().used_blocks, 0);
        se.debug_validate().unwrap();
        assert!(se.new_session_on(2).is_err());
    }

    #[test]
    fn sharded_prefix_indices_stay_shard_local() {
        let se = sharded(2);
        assert!(se.enable_prefix_cache(0));
        assert!(se.prefix_enabled());
        let prompt: Vec<i32> = (1..=8).collect();
        let h = se.new_session_on(0).unwrap();
        for (pos, &t) in prompt.iter().enumerate() {
            se.decode_step(h, t, pos as i32).unwrap();
        }
        se.shard(0).prefix_insert(h.handle, &prompt).unwrap();
        se.free_session(h).unwrap();
        // The index pinned blocks on shard 0 only; merged stats see it.
        assert_eq!(se.prefix_entries(), 2);
        assert_eq!(se.shard(1).prefix_entries(), 0);
        assert_eq!(se.shard(0).arena_status().pinned_blocks, 2);
        assert_eq!(se.shard(1).arena_status().pinned_blocks, 0);
        // Adoption on shard 0 hits; the same prompt on shard 1 misses —
        // shard-local indices never answer for another shard's blocks.
        let a0 = se.new_session_on(0).unwrap();
        assert_eq!(se.shard(0).prefix_adopt(a0.handle, &prompt).unwrap(), 8);
        let a1 = se.new_session_on(1).unwrap();
        assert_eq!(se.shard(1).prefix_adopt(a1.handle, &prompt).unwrap(), 0);
        let merged = se.prefix_stats().unwrap();
        assert_eq!((merged.hits, merged.misses, merged.saved_tokens), (1, 1, 8));
        se.free_session(a0).unwrap();
        se.free_session(a1).unwrap();
        se.debug_validate().unwrap();
    }
}
