//! The runtime engine facade: artifacts + a boxed [`Backend`] chosen at
//! load time.
//!
//! Three backends: the pure-Rust [`super::reference`] executor (the
//! offline default), the [`super::packed`] bitplane popcount executor
//! (also offline; bit-identical outputs, packed ternary weights), and —
//! with the `pjrt` Cargo feature plus the `xla` dependency (see
//! Cargo.toml) — the XLA/PJRT engine behind [`BackendKind::Pjrt`].
//!
//! Selection: the `--backend reference|packed|pjrt` CLI flag resolves
//! through [`BackendKind::resolve`]; without the flag the
//! `PIM_LLM_BACKEND` env var applies, and with neither the reference
//! backend is used.
//!
//! Callers (decoder, serving, CLI, benches) only see `Engine`; the KV
//! caches they thread between steps are the opaque [`Caches`] values of
//! whichever backend is active.

use super::artifacts::Artifacts;
use super::backend::{Backend, Caches, StepOutput};
use crate::util::error::{Context, Result};
use std::sync::Arc;

/// Which execution backend to load.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// Pure-Rust reference executor (the offline default).
    Reference,
    /// Bitplane popcount executor over packed ternary weights
    /// ([`crate::quant`]); bit-identical to `Reference`.
    Packed,
    /// XLA/PJRT engine executing the AOT-lowered HLO.
    #[cfg(feature = "pjrt")]
    Pjrt,
}

impl BackendKind {
    /// Resolve a backend name ("" / "reference" -> Reference; "packed"
    /// -> Packed; "pjrt" -> Pjrt when the feature is compiled in, a
    /// clear error otherwise).
    pub fn from_name(name: &str) -> Result<Self> {
        match name {
            "" | "reference" => Ok(BackendKind::Reference),
            "packed" => Ok(BackendKind::Packed),
            #[cfg(feature = "pjrt")]
            "pjrt" => Ok(BackendKind::Pjrt),
            other => {
                // With the feature on, "pjrt" is matched above, so this
                // branch only fires for it on feature-less builds.
                if other == "pjrt" {
                    crate::bail!(
                        "backend 'pjrt' needs a build with --features pjrt \
                         (see rust/README.md for the build matrix)"
                    );
                }
                crate::bail!("unknown backend '{other}' (reference | packed | pjrt)")
            }
        }
    }

    /// Resolve from `PIM_LLM_BACKEND` (unset -> Reference).
    pub fn from_env() -> Result<Self> {
        let name = std::env::var("PIM_LLM_BACKEND").unwrap_or_default();
        Self::from_name(&name).context("resolving PIM_LLM_BACKEND")
    }

    /// Resolve the CLI `--backend` flag, falling back to the env var
    /// (then the reference default) when the flag was not given.
    pub fn resolve(flag: Option<&str>) -> Result<Self> {
        match flag {
            Some(name) => Self::from_name(name).context("resolving --backend"),
            None => Self::from_env(),
        }
    }

    /// Whether this backend can only run from real AOT artifacts.
    /// Synthetic artifacts carry weights but no HLO text, so only the
    /// PJRT engine needs the real thing — the host executors (reference,
    /// packed) both run from the synthetic fallback.
    pub fn requires_aot_artifacts(self) -> bool {
        match self {
            BackendKind::Reference | BackendKind::Packed => false,
            #[cfg(feature = "pjrt")]
            BackendKind::Pjrt => true,
        }
    }
}

/// Loaded model + execution backend; one `decode_step` per generated
/// token.
pub struct Engine {
    pub artifacts: Arc<Artifacts>,
    backend: Box<dyn Backend>,
}

impl Engine {
    /// Load with the backend selected by `PIM_LLM_BACKEND` (reference by
    /// default).
    pub fn load(artifacts: Artifacts) -> Result<Self> {
        Self::load_with(artifacts, BackendKind::from_env()?)
    }

    /// Load with an explicit backend.
    pub fn load_with(artifacts: Artifacts, kind: BackendKind) -> Result<Self> {
        let artifacts = Arc::new(artifacts);
        let backend: Box<dyn Backend> = match kind {
            BackendKind::Reference => Box::new(
                super::reference::ReferenceBackend::new(Arc::clone(&artifacts))?,
            ),
            BackendKind::Packed => {
                Box::new(super::packed::PackedBackend::new(Arc::clone(&artifacts))?)
            }
            #[cfg(feature = "pjrt")]
            BackendKind::Pjrt => {
                Box::new(super::pjrt::PjrtBackend::new(Arc::clone(&artifacts))?)
            }
        };
        Ok(Self { artifacts, backend })
    }

    /// Load from the default `artifacts/` directory with the env-var
    /// backend; see [`Engine::load_default_with`].
    pub fn load_default() -> Result<Self> {
        Self::load_default_with(BackendKind::from_env()?)
    }

    /// Load from the default `artifacts/` directory; if no AOT artifacts
    /// exist there, fall back to the in-memory synthetic tiny model so
    /// the functional path still runs offline. The fallback applies to
    /// both host executors (reference and packed) — PJRT needs the real
    /// HLO text, so selecting it without artifacts is a clear error
    /// rather than a confusing HLO-parse failure later.
    pub fn load_default_with(kind: BackendKind) -> Result<Self> {
        let dir = super::artifacts::default_dir();
        if dir.join("manifest.json").exists() {
            let artifacts = Artifacts::load(dir)
                .context("loading artifacts (run `make artifacts`)")?;
            Self::load_with(artifacts, kind)
        } else if kind.requires_aot_artifacts() {
            crate::bail!(
                "backend {kind:?} requires real AOT artifacts at {} — run `make \
                 artifacts` first (only the host backends have a synthetic \
                 fallback)",
                dir.display()
            )
        } else {
            eprintln!(
                "note: no AOT artifacts at {} — using the built-in synthetic tiny \
                 model on the {kind:?} backend (run `make artifacts` for the real \
                 AOT decoder)",
                dir.display()
            );
            Self::load_with(Artifacts::synthetic(0)?, kind)
        }
    }

    /// Fresh zeroed KV caches in the backend's native representation.
    pub fn empty_caches(&self) -> Result<Caches> {
        self.backend.empty_caches()
    }

    /// Execute one decode step: feed token `token_id` at position `pos`
    /// with the given caches; returns logits + updated caches. Consumes
    /// the caches (they are superseded by the returned ones).
    pub fn decode_step(&self, caches: Caches, token_id: i32, pos: i32) -> Result<StepOutput> {
        self.backend.decode_step(caches, token_id, pos)
    }

    /// Execute one decode step for B independent sequences in a single
    /// backend call (sequence `i` feeds `tokens[i]` at `positions[i]`
    /// into `caches[i]`; ragged positions allowed). Guaranteed
    /// bit-identical to B separate [`Engine::decode_step`] calls — on
    /// the host backends each weight matrix is traversed once per call
    /// instead of once per sequence.
    pub fn decode_batch(
        &self,
        caches: Vec<Caches>,
        tokens: &[i32],
        positions: &[i32],
    ) -> Result<Vec<StepOutput>> {
        self.backend.decode_batch(caches, tokens, positions)
    }

    pub fn vocab(&self) -> usize {
        self.artifacts.manifest.model.vocab
    }

    pub fn max_ctx(&self) -> usize {
        self.artifacts.manifest.model.max_ctx
    }

    pub fn platform(&self) -> String {
        self.backend.platform()
    }

    /// Short backend identifier: "reference", "packed" or "pjrt".
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine() -> Engine {
        Engine::load_with(Artifacts::synthetic(1).unwrap(), BackendKind::Reference)
            .expect("engine")
    }

    #[test]
    fn engine_loads_and_steps_offline() {
        let e = engine();
        assert_eq!(e.backend_name(), "reference");
        assert_eq!(e.platform(), "cpu");
        let caches = e.empty_caches().unwrap();
        let out = e.decode_step(caches, 1, 0).unwrap();
        assert_eq!(out.logits.len(), e.vocab());
        assert!(out.logits.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn packed_engine_loads_and_matches_reference() {
        let reference = engine();
        let packed =
            Engine::load_with(Artifacts::synthetic(1).unwrap(), BackendKind::Packed)
                .expect("packed engine");
        assert_eq!(packed.backend_name(), "packed");
        let a = reference
            .decode_step(reference.empty_caches().unwrap(), 7, 0)
            .unwrap();
        let b = packed
            .decode_step(packed.empty_caches().unwrap(), 7, 0)
            .unwrap();
        assert_eq!(a.logits, b.logits);
    }

    #[test]
    fn backend_names_resolve() {
        assert_eq!(BackendKind::from_name("").unwrap(), BackendKind::Reference);
        assert_eq!(
            BackendKind::from_name("reference").unwrap(),
            BackendKind::Reference
        );
        assert_eq!(
            BackendKind::from_name("packed").unwrap(),
            BackendKind::Packed
        );
        assert!(BackendKind::from_name("tpu").is_err());
        #[cfg(not(feature = "pjrt"))]
        assert!(BackendKind::from_name("pjrt").is_err());
        // The flag wins over the env var; no flag falls through.
        assert_eq!(
            BackendKind::resolve(Some("packed")).unwrap(),
            BackendKind::Packed
        );
        assert!(BackendKind::resolve(Some("nope")).is_err());
        // AOT requirement: only PJRT insists on real artifacts.
        assert!(!BackendKind::Reference.requires_aot_artifacts());
        assert!(!BackendKind::Packed.requires_aot_artifacts());
    }

    #[test]
    fn decode_step_deterministic() {
        let e = engine();
        let a = e.decode_step(e.empty_caches().unwrap(), 5, 0).unwrap();
        let b = e.decode_step(e.empty_caches().unwrap(), 5, 0).unwrap();
        assert_eq!(a.logits, b.logits);
    }

    #[test]
    fn cache_buffers_thread_state() {
        // Feeding [1] then [2] must differ from feeding [2] fresh.
        let e = engine();
        let s1 = e.decode_step(e.empty_caches().unwrap(), 1, 0).unwrap();
        let s2 = e.decode_step(s1.caches, 2, 1).unwrap();
        let fresh = e.decode_step(e.empty_caches().unwrap(), 2, 0).unwrap();
        assert_ne!(s2.logits, fresh.logits);
    }

    #[test]
    fn decode_batch_matches_individual_steps() {
        let e = engine();
        let a = e.decode_step(e.empty_caches().unwrap(), 3, 0).unwrap();
        let b = e.decode_step(e.empty_caches().unwrap(), 9, 0).unwrap();
        let out = e
            .decode_batch(
                vec![e.empty_caches().unwrap(), e.empty_caches().unwrap()],
                &[3, 9],
                &[0, 0],
            )
            .unwrap();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].logits, a.logits);
        assert_eq!(out[1].logits, b.logits);
    }

    #[test]
    fn decode_step_matches_golden_first_logits() {
        let e = engine();
        let g = e.artifacts.golden.clone();
        let out = e
            .decode_step(e.empty_caches().unwrap(), g.prompt[0], 0)
            .unwrap();
        for (got, want) in out.logits.iter().zip(g.first_logits_prefix.iter()) {
            assert!(
                (got - want).abs() <= 1e-4 * want.abs().max(1.0),
                "{got} vs {want}"
            );
        }
        let l2: f64 = out
            .logits
            .iter()
            .map(|&x| (x as f64) * (x as f64))
            .sum::<f64>()
            .sqrt();
        assert!((l2 - g.first_logits_l2).abs() / g.first_logits_l2 < 1e-4);
    }

    #[test]
    fn engines_agree_across_instances() {
        // Two engines from the same artifacts must agree bitwise.
        let e1 = engine();
        let e2 = engine();
        let o1 = e1.decode_step(e1.empty_caches().unwrap(), 42, 0).unwrap();
        let o2 = e2.decode_step(e2.empty_caches().unwrap(), 42, 0).unwrap();
        assert_eq!(o1.logits, o2.logits);
    }
}
