//! The PJRT execution engine: compile the decode-step HLO once, stage
//! the weights **on device once** (`buffer_from_host_buffer`, whose
//! kImmutableOnlyDuringCall semantics copy synchronously), and run each
//! generated token through `execute_b` with device-resident buffers.
//!
//! Perf note (EXPERIMENTS.md §Perf): the naive path executed with host
//! literals, which re-uploads all ~6.8 MB of weights every decode step.
//! Staging weights as PjRtBuffers at load time and threading the KV
//! caches through as buffers removes that copy from the request path —
//! only the two scalars (token, pos) are uploaded per step and only the
//! logits are downloaded.
//!
//! Interchange is HLO *text* — see aot.py and /opt/xla-example/README.md
//! for why serialized protos from jax >= 0.5 are rejected by
//! xla_extension 0.5.1.

use super::artifacts::Artifacts;
use anyhow::{anyhow, bail, Context, Result};
use xla::{HloModuleProto, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable, XlaComputation};

/// Compiled decode-step executable plus everything static across tokens.
pub struct Engine {
    client: PjRtClient,
    exe: PjRtLoadedExecutable,
    /// Device-resident parameter buffers in manifest order (staged once).
    param_buffers: Vec<PjRtBuffer>,
    pub artifacts: Artifacts,
}

/// Device-side KV caches threaded between steps (opaque to callers).
pub struct Caches {
    pub k: PjRtBuffer,
    pub v: PjRtBuffer,
}

/// Outputs of one decode step.
pub struct StepOutput {
    pub logits: Vec<f32>,
    pub caches: Caches,
}

impl Engine {
    /// Load artifacts, compile the HLO on the CPU PJRT client, stage the
    /// weights on device.
    pub fn load(artifacts: Artifacts) -> Result<Self> {
        let client = PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e}"))?;
        let proto = HloModuleProto::from_text_file(artifacts.hlo_path())
            .map_err(|e| anyhow!("parsing HLO text: {e}"))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling decode_step: {e}"))?;

        // buffer_from_host_buffer uses kImmutableOnlyDuringCall semantics:
        // the copy completes during the call, so the host slices may be
        // dropped afterwards (BufferFromHostLiteral, by contrast, copies
        // asynchronously and would require keeping the literals alive).
        let mut param_buffers = Vec::with_capacity(artifacts.manifest.params.len());
        for p in &artifacts.manifest.params {
            let data = artifacts.param_data(p);
            let dims: Vec<usize> = p.shape.clone();
            let buf = client
                .buffer_from_host_buffer(data, &dims, None)
                .map_err(|e| anyhow!("staging {}: {e}", p.name))?;
            param_buffers.push(buf);
        }

        Ok(Self {
            client,
            exe,
            param_buffers,
            artifacts,
        })
    }

    /// Load from the default `artifacts/` directory.
    pub fn load_default() -> Result<Self> {
        let artifacts = Artifacts::load(super::artifacts::default_dir())
            .context("loading artifacts (run `make artifacts`)")?;
        Self::load(artifacts)
    }

    /// Fresh zeroed device-side KV caches.
    pub fn empty_caches(&self) -> Result<Caches> {
        let shape = self.artifacts.cache_shape();
        let numel: usize = shape.iter().product();
        let zeros = vec![0f32; numel];
        let k = self
            .client
            .buffer_from_host_buffer(&zeros, &shape, None)
            .map_err(|e| anyhow!("cache upload: {e}"))?;
        let v = self
            .client
            .buffer_from_host_buffer(&zeros, &shape, None)
            .map_err(|e| anyhow!("cache upload: {e}"))?;
        Ok(Caches { k, v })
    }

    /// Upload a scalar i32 as a device buffer (synchronous copy).
    fn scalar_buffer(&self, v: i32) -> Result<PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(&[v], &[], None)
            .map_err(|e| anyhow!("scalar upload: {e}"))
    }

    /// Execute one decode step: feed token `token_id` at position `pos`
    /// with the given caches; returns logits + updated caches. Consumes
    /// the caches (they are superseded by the returned ones).
    pub fn decode_step(&self, caches: Caches, token_id: i32, pos: i32) -> Result<StepOutput> {
        let tok = self.scalar_buffer(token_id)?;
        let p = self.scalar_buffer(pos)?;
        let mut args: Vec<&PjRtBuffer> = Vec::with_capacity(self.param_buffers.len() + 4);
        args.extend(self.param_buffers.iter());
        args.push(&caches.k);
        args.push(&caches.v);
        args.push(&tok);
        args.push(&p);

        let mut result = self
            .exe
            .execute_b::<&PjRtBuffer>(&args)
            .map_err(|e| anyhow!("decode_step execute: {e}"))?;
        let outputs = result.swap_remove(0);
        self.unpack_outputs(outputs)
    }

    /// PJRT may flatten the (logits, k, v) output tuple into three
    /// buffers or hand back a single tuple buffer depending on the
    /// client; handle both.
    fn unpack_outputs(&self, mut outputs: Vec<PjRtBuffer>) -> Result<StepOutput> {
        match outputs.len() {
            3 => {
                let v = outputs.pop().unwrap();
                let k = outputs.pop().unwrap();
                let logits_buf = outputs.pop().unwrap();
                let logits = logits_buf
                    .to_literal_sync()
                    .map_err(|e| anyhow!("logits fetch: {e}"))?
                    .to_vec::<f32>()
                    .map_err(|e| anyhow!("logits to_vec: {e}"))?;
                Ok(StepOutput {
                    logits,
                    caches: Caches { k, v },
                })
            }
            1 => {
                // Tuple buffer: download, split, re-upload the caches.
                let out = outputs.pop().unwrap();
                let lit = out
                    .to_literal_sync()
                    .map_err(|e| anyhow!("tuple fetch: {e}"))?;
                let (logits_lit, k_lit, v_lit) = lit
                    .to_tuple3()
                    .map_err(|e| anyhow!("output tuple: {e}"))?;
                let logits = logits_lit
                    .to_vec::<f32>()
                    .map_err(|e| anyhow!("logits to_vec: {e}"))?;
                let shape = self.artifacts.cache_shape();
                let k_host = k_lit
                    .to_vec::<f32>()
                    .map_err(|e| anyhow!("cache download: {e}"))?;
                let v_host = v_lit
                    .to_vec::<f32>()
                    .map_err(|e| anyhow!("cache download: {e}"))?;
                let k = self
                    .client
                    .buffer_from_host_buffer(&k_host, &shape, None)
                    .map_err(|e| anyhow!("cache re-upload: {e}"))?;
                let v = self
                    .client
                    .buffer_from_host_buffer(&v_host, &shape, None)
                    .map_err(|e| anyhow!("cache re-upload: {e}"))?;
                Ok(StepOutput {
                    logits,
                    caches: Caches { k, v },
                })
            }
            n => bail!("unexpected output arity {n}"),
        }
    }

    pub fn vocab(&self) -> usize {
        self.artifacts.manifest.model.vocab
    }

    pub fn max_ctx(&self) -> usize {
        self.artifacts.manifest.model.max_ctx
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifacts::default_dir;

    fn engine() -> Option<Engine> {
        if !default_dir().join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return None;
        }
        Some(Engine::load_default().expect("engine"))
    }

    #[test]
    fn engine_compiles_and_steps() {
        let Some(e) = engine() else { return };
        assert_eq!(e.platform(), "cpu");
        let caches = e.empty_caches().unwrap();
        let out = e.decode_step(caches, 1, 0).unwrap();
        assert_eq!(out.logits.len(), e.vocab());
        assert!(out.logits.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn decode_step_matches_golden_first_logits() {
        let Some(e) = engine() else { return };
        let caches = e.empty_caches().unwrap();
        let g = e.artifacts.golden.clone();
        let out = e.decode_step(caches, g.prompt[0], 0).unwrap();
        for (got, want) in out.logits.iter().zip(g.first_logits_prefix.iter()) {
            assert!(
                (got - want).abs() <= 1e-4 * want.abs().max(1.0),
                "{got} vs {want}"
            );
        }
        let l2: f64 = out
            .logits
            .iter()
            .map(|&x| (x as f64) * (x as f64))
            .sum::<f64>()
            .sqrt();
        assert!((l2 - g.first_logits_l2).abs() / g.first_logits_l2 < 1e-4);
    }

    #[test]
    fn decode_step_deterministic() {
        let Some(e) = engine() else { return };
        let a = e.decode_step(e.empty_caches().unwrap(), 5, 0).unwrap();
        let b = e.decode_step(e.empty_caches().unwrap(), 5, 0).unwrap();
        assert_eq!(a.logits, b.logits);
    }

    #[test]
    fn cache_buffers_thread_state() {
        // Feeding [1] then [2] must differ from feeding [2] fresh.
        let Some(e) = engine() else { return };
        let s1 = e.decode_step(e.empty_caches().unwrap(), 1, 0).unwrap();
        let s2 = e.decode_step(s1.caches, 2, 1).unwrap();
        let fresh = e.decode_step(e.empty_caches().unwrap(), 2, 0).unwrap();
        assert_ne!(s2.logits, fresh.logits);
    }
}
