//! The runtime engine facade: artifacts + a boxed [`Backend`] chosen at
//! load time.
//!
//! The default backend is the pure-Rust [`super::reference`] executor,
//! which builds and runs offline. With the `pjrt` Cargo feature enabled
//! (plus the `xla` dependency — see Cargo.toml), the XLA/PJRT engine is
//! available behind [`BackendKind::Pjrt`] or `PIM_LLM_BACKEND=pjrt`.
//!
//! Callers (decoder, serving, CLI, benches) only see `Engine`; the KV
//! caches they thread between steps are the opaque [`Caches`] values of
//! whichever backend is active.

use super::artifacts::Artifacts;
use super::backend::{Backend, Caches, StepOutput};
use crate::util::error::{Context, Result};
use std::sync::Arc;

/// Which execution backend to load.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// Pure-Rust reference executor (the offline default).
    Reference,
    /// XLA/PJRT engine executing the AOT-lowered HLO.
    #[cfg(feature = "pjrt")]
    Pjrt,
}

impl BackendKind {
    /// Resolve from `PIM_LLM_BACKEND` (unset/"reference" -> Reference;
    /// "pjrt" -> Pjrt when the feature is compiled in, error otherwise).
    pub fn from_env() -> Result<Self> {
        match std::env::var("PIM_LLM_BACKEND").ok().as_deref() {
            None | Some("") | Some("reference") => Ok(BackendKind::Reference),
            #[cfg(feature = "pjrt")]
            Some("pjrt") => Ok(BackendKind::Pjrt),
            Some(other) => {
                // With the feature on, "pjrt" is matched above, so this
                // branch only fires for it on feature-less builds.
                if other == "pjrt" {
                    crate::bail!(
                        "PIM_LLM_BACKEND=pjrt needs a build with --features pjrt \
                         (see rust/README.md for the build matrix)"
                    );
                }
                crate::bail!("unknown PIM_LLM_BACKEND '{other}' (reference | pjrt)")
            }
        }
    }
}

/// Loaded model + execution backend; one `decode_step` per generated
/// token.
pub struct Engine {
    pub artifacts: Arc<Artifacts>,
    backend: Box<dyn Backend>,
}

impl Engine {
    /// Load with the backend selected by `PIM_LLM_BACKEND` (reference by
    /// default).
    pub fn load(artifacts: Artifacts) -> Result<Self> {
        Self::load_with(artifacts, BackendKind::from_env()?)
    }

    /// Load with an explicit backend.
    pub fn load_with(artifacts: Artifacts, kind: BackendKind) -> Result<Self> {
        let artifacts = Arc::new(artifacts);
        let backend: Box<dyn Backend> = match kind {
            BackendKind::Reference => Box::new(
                super::reference::ReferenceBackend::new(Arc::clone(&artifacts))?,
            ),
            #[cfg(feature = "pjrt")]
            BackendKind::Pjrt => {
                Box::new(super::pjrt::PjrtBackend::new(Arc::clone(&artifacts))?)
            }
        };
        Ok(Self { artifacts, backend })
    }

    /// Load from the default `artifacts/` directory; if no AOT artifacts
    /// exist there, fall back to the in-memory synthetic tiny model so
    /// the functional path still runs offline. The fallback only applies
    /// to the reference backend — PJRT needs the real HLO text, so a
    /// non-reference selection without artifacts is a clear error rather
    /// than a confusing HLO-parse failure later.
    pub fn load_default() -> Result<Self> {
        let kind = BackendKind::from_env()?;
        let dir = super::artifacts::default_dir();
        if dir.join("manifest.json").exists() {
            let artifacts = Artifacts::load(dir)
                .context("loading artifacts (run `make artifacts`)")?;
            Self::load_with(artifacts, kind)
        } else if kind != BackendKind::Reference {
            crate::bail!(
                "backend {kind:?} requires real AOT artifacts at {} — run `make \
                 artifacts` first (only the reference backend has a synthetic \
                 fallback)",
                dir.display()
            )
        } else {
            eprintln!(
                "note: no AOT artifacts at {} — using the built-in synthetic tiny \
                 model on the reference backend (run `make artifacts` for the real \
                 AOT decoder)",
                dir.display()
            );
            Self::load_with(Artifacts::synthetic(0)?, kind)
        }
    }

    /// Fresh zeroed KV caches in the backend's native representation.
    pub fn empty_caches(&self) -> Result<Caches> {
        self.backend.empty_caches()
    }

    /// Execute one decode step: feed token `token_id` at position `pos`
    /// with the given caches; returns logits + updated caches. Consumes
    /// the caches (they are superseded by the returned ones).
    pub fn decode_step(&self, caches: Caches, token_id: i32, pos: i32) -> Result<StepOutput> {
        self.backend.decode_step(caches, token_id, pos)
    }

    /// Execute one decode step for B independent sequences in a single
    /// backend call (sequence `i` feeds `tokens[i]` at `positions[i]`
    /// into `caches[i]`; ragged positions allowed). Guaranteed
    /// bit-identical to B separate [`Engine::decode_step`] calls — on
    /// the reference backend each weight matrix is traversed once per
    /// call instead of once per sequence.
    pub fn decode_batch(
        &self,
        caches: Vec<Caches>,
        tokens: &[i32],
        positions: &[i32],
    ) -> Result<Vec<StepOutput>> {
        self.backend.decode_batch(caches, tokens, positions)
    }

    pub fn vocab(&self) -> usize {
        self.artifacts.manifest.model.vocab
    }

    pub fn max_ctx(&self) -> usize {
        self.artifacts.manifest.model.max_ctx
    }

    pub fn platform(&self) -> String {
        self.backend.platform()
    }

    /// Short backend identifier: "reference" or "pjrt".
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine() -> Engine {
        Engine::load_with(Artifacts::synthetic(1).unwrap(), BackendKind::Reference)
            .expect("engine")
    }

    #[test]
    fn engine_loads_and_steps_offline() {
        let e = engine();
        assert_eq!(e.backend_name(), "reference");
        assert_eq!(e.platform(), "cpu");
        let caches = e.empty_caches().unwrap();
        let out = e.decode_step(caches, 1, 0).unwrap();
        assert_eq!(out.logits.len(), e.vocab());
        assert!(out.logits.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn decode_step_deterministic() {
        let e = engine();
        let a = e.decode_step(e.empty_caches().unwrap(), 5, 0).unwrap();
        let b = e.decode_step(e.empty_caches().unwrap(), 5, 0).unwrap();
        assert_eq!(a.logits, b.logits);
    }

    #[test]
    fn cache_buffers_thread_state() {
        // Feeding [1] then [2] must differ from feeding [2] fresh.
        let e = engine();
        let s1 = e.decode_step(e.empty_caches().unwrap(), 1, 0).unwrap();
        let s2 = e.decode_step(s1.caches, 2, 1).unwrap();
        let fresh = e.decode_step(e.empty_caches().unwrap(), 2, 0).unwrap();
        assert_ne!(s2.logits, fresh.logits);
    }

    #[test]
    fn decode_batch_matches_individual_steps() {
        let e = engine();
        let a = e.decode_step(e.empty_caches().unwrap(), 3, 0).unwrap();
        let b = e.decode_step(e.empty_caches().unwrap(), 9, 0).unwrap();
        let out = e
            .decode_batch(
                vec![e.empty_caches().unwrap(), e.empty_caches().unwrap()],
                &[3, 9],
                &[0, 0],
            )
            .unwrap();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].logits, a.logits);
        assert_eq!(out[1].logits, b.logits);
    }

    #[test]
    fn decode_step_matches_golden_first_logits() {
        let e = engine();
        let g = e.artifacts.golden.clone();
        let out = e
            .decode_step(e.empty_caches().unwrap(), g.prompt[0], 0)
            .unwrap();
        for (got, want) in out.logits.iter().zip(g.first_logits_prefix.iter()) {
            assert!(
                (got - want).abs() <= 1e-4 * want.abs().max(1.0),
                "{got} vs {want}"
            );
        }
        let l2: f64 = out
            .logits
            .iter()
            .map(|&x| (x as f64) * (x as f64))
            .sum::<f64>()
            .sqrt();
        assert!((l2 - g.first_logits_l2).abs() / g.first_logits_l2 < 1e-4);
    }

    #[test]
    fn engines_agree_across_instances() {
        // Two engines from the same artifacts must agree bitwise.
        let e1 = engine();
        let e2 = engine();
        let o1 = e1.decode_step(e1.empty_caches().unwrap(), 42, 0).unwrap();
        let o2 = e2.decode_step(e2.empty_caches().unwrap(), 42, 0).unwrap();
        assert_eq!(o1.logits, o2.logits);
    }
}
