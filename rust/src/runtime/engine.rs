//! The runtime engine facade: artifacts + a boxed [`Backend`] + the
//! shared block-paged KV-cache arena, chosen and sized at load time.
//!
//! Three backends: the pure-Rust [`super::reference`] executor (the
//! offline default), the [`super::packed`] bitplane popcount executor
//! (also offline; bit-identical outputs, packed ternary weights), and —
//! with the `pjrt` Cargo feature plus the `xla` dependency (see
//! Cargo.toml) — the XLA/PJRT engine behind [`BackendKind::Pjrt`].
//!
//! Selection: the `--backend reference|packed|pjrt` CLI flag resolves
//! through [`BackendKind::resolve`]; without the flag the
//! `PIM_LLM_BACKEND` env var applies, and with neither the reference
//! backend is used.
//!
//! Callers (decoder, serving, CLI, benches) only see `Engine`: sessions
//! are opened with [`Engine::new_session`], advanced with
//! [`Engine::decode_step`] / [`Engine::decode_batch`] against opaque
//! [`CacheHandle`]s, and retired with [`Engine::free_session`]. Cache
//! state never moves through these calls — it lives in the arena
//! ([`super::kvcache`]), whose occupancy ([`Engine::arena_status`])
//! drives the serving layer's pressure-aware admission and preemption.

use super::artifacts::Artifacts;
use super::backend::Backend;
use super::kvcache::{ArenaStatus, CacheArena, CacheHandle, CacheLayout};
use crate::util::error::{Context, Result};
use std::cell::RefCell;
use std::sync::Arc;

/// Which execution backend to load.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// Pure-Rust reference executor (the offline default).
    Reference,
    /// Bitplane popcount executor over packed ternary weights
    /// ([`crate::quant`]); bit-identical to `Reference`.
    Packed,
    /// XLA/PJRT engine executing the AOT-lowered HLO.
    #[cfg(feature = "pjrt")]
    Pjrt,
}

impl BackendKind {
    /// Resolve a backend name ("" / "reference" -> Reference; "packed"
    /// -> Packed; "pjrt" -> Pjrt when the feature is compiled in, a
    /// clear error otherwise).
    pub fn from_name(name: &str) -> Result<Self> {
        match name {
            "" | "reference" => Ok(BackendKind::Reference),
            "packed" => Ok(BackendKind::Packed),
            #[cfg(feature = "pjrt")]
            "pjrt" => Ok(BackendKind::Pjrt),
            other => {
                // With the feature on, "pjrt" is matched above, so this
                // branch only fires for it on feature-less builds.
                if other == "pjrt" {
                    crate::bail!(
                        "backend 'pjrt' needs a build with --features pjrt \
                         (see rust/README.md for the build matrix)"
                    );
                }
                crate::bail!("unknown backend '{other}' (reference | packed | pjrt)")
            }
        }
    }

    /// Resolve from `PIM_LLM_BACKEND` (unset -> Reference).
    pub fn from_env() -> Result<Self> {
        let name = std::env::var("PIM_LLM_BACKEND").unwrap_or_default();
        Self::from_name(&name).context("resolving PIM_LLM_BACKEND")
    }

    /// Resolve the CLI `--backend` flag, falling back to the env var
    /// (then the reference default) when the flag was not given.
    pub fn resolve(flag: Option<&str>) -> Result<Self> {
        match flag {
            Some(name) => Self::from_name(name).context("resolving --backend"),
            None => Self::from_env(),
        }
    }

    /// Whether this backend can only run from real AOT artifacts.
    /// Synthetic artifacts carry weights but no HLO text, so only the
    /// PJRT engine needs the real thing — the host executors (reference,
    /// packed) both run from the synthetic fallback.
    pub fn requires_aot_artifacts(self) -> bool {
        match self {
            BackendKind::Reference | BackendKind::Packed => false,
            #[cfg(feature = "pjrt")]
            BackendKind::Pjrt => true,
        }
    }
}

/// Loaded model + execution backend + the shared KV-cache arena; one
/// `decode_step`/`decode_batch` per generated token.
///
/// The arena sits behind a `RefCell`: engine calls are already
/// single-threaded per engine (backends are not `Sync`; the threaded
/// serving front end replicates one engine per worker), and interior
/// mutability is what lets many sessions share one `&Engine` the way
/// they shared it before the paging refactor.
pub struct Engine {
    pub artifacts: Arc<Artifacts>,
    backend: Box<dyn Backend>,
    arena: RefCell<CacheArena>,
}

impl Engine {
    /// Load with the backend selected by `PIM_LLM_BACKEND` (reference by
    /// default).
    pub fn load(artifacts: Artifacts) -> Result<Self> {
        Self::load_with(artifacts, BackendKind::from_env()?)
    }

    /// Load with an explicit backend and the default arena geometry
    /// (default block length, [`super::kvcache::DEFAULT_ARENA_SESSIONS`]
    /// worst-case sessions of capacity).
    pub fn load_with(artifacts: Artifacts, kind: BackendKind) -> Result<Self> {
        Self::load_with_arena(artifacts, kind, 0, 0)
    }

    /// Load with an explicit backend AND arena geometry: `block_len`
    /// positions per cache block and `capacity_blocks` total blocks
    /// (either `0` selects its default). Small capacities are how the
    /// continuous-batching tests and benches create arena pressure.
    pub fn load_with_arena(
        artifacts: Artifacts,
        kind: BackendKind,
        block_len: usize,
        capacity_blocks: usize,
    ) -> Result<Self> {
        let artifacts = Arc::new(artifacts);
        let backend: Box<dyn Backend> = match kind {
            BackendKind::Reference => Box::new(
                super::reference::ReferenceBackend::new(Arc::clone(&artifacts))?,
            ),
            BackendKind::Packed => {
                Box::new(super::packed::PackedBackend::new(Arc::clone(&artifacts))?)
            }
            #[cfg(feature = "pjrt")]
            BackendKind::Pjrt => {
                Box::new(super::pjrt::PjrtBackend::new(Arc::clone(&artifacts))?)
            }
        };
        let layout = CacheLayout::with_block_len(&artifacts.manifest.model, block_len);
        let arena = if capacity_blocks == 0 {
            CacheArena::with_sessions(layout, 0)?
        } else {
            CacheArena::new(layout, capacity_blocks)?
        };
        Ok(Self {
            artifacts,
            backend,
            arena: RefCell::new(arena),
        })
    }

    /// Load from the default `artifacts/` directory with the env-var
    /// backend; see [`Engine::load_default_with`].
    pub fn load_default() -> Result<Self> {
        Self::load_default_with(BackendKind::from_env()?)
    }

    /// [`Engine::load_default_with`] with explicit arena geometry (both
    /// `0` = defaults); what the CLI's `--arena-blocks` flag maps to.
    pub fn load_default_with_arena(
        kind: BackendKind,
        block_len: usize,
        capacity_blocks: usize,
    ) -> Result<Self> {
        let dir = super::artifacts::default_dir();
        if dir.join("manifest.json").exists() {
            let artifacts = Artifacts::load(dir)
                .context("loading artifacts (run `make artifacts`)")?;
            Self::load_with_arena(artifacts, kind, block_len, capacity_blocks)
        } else if kind.requires_aot_artifacts() {
            crate::bail!(
                "backend {kind:?} requires real AOT artifacts at {} — run `make \
                 artifacts` first (only the host backends have a synthetic \
                 fallback)",
                dir.display()
            )
        } else {
            eprintln!(
                "note: no AOT artifacts at {} — using the built-in synthetic tiny \
                 model on the {kind:?} backend (run `make artifacts` for the real \
                 AOT decoder)",
                dir.display()
            );
            Self::load_with_arena(Artifacts::synthetic(0)?, kind, block_len, capacity_blocks)
        }
    }

    /// Load from the default `artifacts/` directory; if no AOT artifacts
    /// exist there, fall back to the in-memory synthetic tiny model so
    /// the functional path still runs offline. The fallback applies to
    /// both host executors (reference and packed) — PJRT needs the real
    /// HLO text, so selecting it without artifacts is a clear error
    /// rather than a confusing HLO-parse failure later.
    pub fn load_default_with(kind: BackendKind) -> Result<Self> {
        Self::load_default_with_arena(kind, 0, 0)
    }

    /// Open a fresh decode session; retire it with
    /// [`Engine::free_session`] (the decoders do this on drop).
    pub fn new_session(&self) -> Result<CacheHandle> {
        self.backend.new_session(&mut self.arena.borrow_mut())
    }

    /// Retire a session, returning its cache blocks to the arena.
    pub fn free_session(&self, handle: CacheHandle) -> Result<()> {
        self.backend.drop_session(&mut self.arena.borrow_mut(), handle)
    }

    /// Non-panicking session release for `Drop` impls: skips (leaving
    /// the blocks to the arena's owner) if the arena is mid-borrow,
    /// which can only happen while unwinding out of an engine call.
    pub(crate) fn release_session(&self, handle: CacheHandle) {
        if let Ok(mut arena) = self.arena.try_borrow_mut() {
            let _ = self.backend.drop_session(&mut arena, handle);
        }
    }

    /// Reserve worst-case cache capacity (`positions` total fed tokens)
    /// for a session up front — what the fixed-wave serving policies do
    /// at admission so an admitted session can never stall mid-decode.
    pub fn reserve_session(&self, handle: CacheHandle, positions: usize) -> Result<()> {
        self.backend
            .reserve_session(&mut self.arena.borrow_mut(), handle, positions)
    }

    /// Execute one decode step: feed token `token_id` at position `pos`
    /// into the session's cache state (updated in place); returns the
    /// logits.
    pub fn decode_step(
        &self,
        handle: CacheHandle,
        token_id: i32,
        pos: i32,
    ) -> Result<Vec<f32>> {
        self.backend
            .decode_step(&mut self.arena.borrow_mut(), handle, token_id, pos)
    }

    /// Execute one decode step for B independent sessions in a single
    /// backend call (session `handles[i]` feeds `tokens[i]` at
    /// `positions[i]`; ragged positions allowed). Guaranteed
    /// bit-identical to B separate [`Engine::decode_step`] calls — on
    /// the host backends each weight matrix is traversed once per call
    /// instead of once per session.
    pub fn decode_batch(
        &self,
        handles: &[CacheHandle],
        tokens: &[i32],
        positions: &[i32],
    ) -> Result<Vec<Vec<f32>>> {
        self.backend
            .decode_batch(&mut self.arena.borrow_mut(), handles, tokens, positions)
    }

    /// Current arena occupancy (total/free/used blocks), the signal the
    /// continuous-batching scheduler admits and preempts on.
    pub fn arena_status(&self) -> ArenaStatus {
        self.arena.borrow().status()
    }

    /// Cache blocks needed to back `positions` fed tokens.
    pub fn blocks_for_positions(&self, positions: usize) -> usize {
        self.arena.borrow().layout().blocks_for_positions(positions)
    }

    /// Cache blocks the session currently holds.
    pub fn session_blocks(&self, handle: CacheHandle) -> Result<usize> {
        self.arena.borrow().session_blocks(handle)
    }

    /// Whether decoding the session at `pos` would claim a cache block
    /// it does not yet hold (always false on backends whose caches are
    /// not arena blocks, e.g. PJRT) — the continuous scheduler's
    /// pressure signal.
    pub fn session_needs_block(&self, handle: CacheHandle, pos: usize) -> Result<bool> {
        self.backend
            .session_needs_block(&self.arena.borrow(), handle, pos)
    }

    /// Reassemble a session's cache as the contiguous
    /// `(n_layers, h, max_ctx, d_head)` K/V tensors — test/diagnostic
    /// surface for the paged-vs-contiguous equivalence suites.
    pub fn gather_session(&self, handle: CacheHandle) -> Result<(Vec<f32>, Vec<f32>)> {
        self.arena.borrow().gather_contiguous(handle)
    }

    pub fn vocab(&self) -> usize {
        self.artifacts.manifest.model.vocab
    }

    pub fn max_ctx(&self) -> usize {
        self.artifacts.manifest.model.max_ctx
    }

    pub fn platform(&self) -> String {
        self.backend.platform()
    }

    /// Short backend identifier: "reference", "packed" or "pjrt".
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine() -> Engine {
        Engine::load_with(Artifacts::synthetic(1).unwrap(), BackendKind::Reference)
            .expect("engine")
    }

    #[test]
    fn engine_loads_and_steps_offline() {
        let e = engine();
        assert_eq!(e.backend_name(), "reference");
        assert_eq!(e.platform(), "cpu");
        let s = e.new_session().unwrap();
        let logits = e.decode_step(s, 1, 0).unwrap();
        assert_eq!(logits.len(), e.vocab());
        assert!(logits.iter().all(|x| x.is_finite()));
        e.free_session(s).unwrap();
    }

    #[test]
    fn packed_engine_loads_and_matches_reference() {
        let reference = engine();
        let packed =
            Engine::load_with(Artifacts::synthetic(1).unwrap(), BackendKind::Packed)
                .expect("packed engine");
        assert_eq!(packed.backend_name(), "packed");
        let rs = reference.new_session().unwrap();
        let ps = packed.new_session().unwrap();
        assert_eq!(
            reference.decode_step(rs, 7, 0).unwrap(),
            packed.decode_step(ps, 7, 0).unwrap()
        );
    }

    #[test]
    fn backend_names_resolve() {
        assert_eq!(BackendKind::from_name("").unwrap(), BackendKind::Reference);
        assert_eq!(
            BackendKind::from_name("reference").unwrap(),
            BackendKind::Reference
        );
        assert_eq!(
            BackendKind::from_name("packed").unwrap(),
            BackendKind::Packed
        );
        assert!(BackendKind::from_name("tpu").is_err());
        #[cfg(not(feature = "pjrt"))]
        assert!(BackendKind::from_name("pjrt").is_err());
        // The flag wins over the env var; no flag falls through.
        assert_eq!(
            BackendKind::resolve(Some("packed")).unwrap(),
            BackendKind::Packed
        );
        assert!(BackendKind::resolve(Some("nope")).is_err());
        // AOT requirement: only PJRT insists on real artifacts.
        assert!(!BackendKind::Reference.requires_aot_artifacts());
        assert!(!BackendKind::Packed.requires_aot_artifacts());
    }

    #[test]
    fn decode_step_deterministic() {
        let e = engine();
        let s1 = e.new_session().unwrap();
        let s2 = e.new_session().unwrap();
        assert_eq!(
            e.decode_step(s1, 5, 0).unwrap(),
            e.decode_step(s2, 5, 0).unwrap()
        );
    }

    #[test]
    fn sessions_thread_state_and_free_releases_blocks() {
        // Feeding [1] then [2] must differ from feeding [2] fresh, and
        // retiring sessions must return their blocks to the pool.
        let e = engine();
        let full = e.arena_status().free_blocks;
        let s = e.new_session().unwrap();
        e.decode_step(s, 1, 0).unwrap();
        let continued = e.decode_step(s, 2, 1).unwrap();
        let fresh_s = e.new_session().unwrap();
        let fresh = e.decode_step(fresh_s, 2, 0).unwrap();
        assert_ne!(continued, fresh);
        assert!(e.arena_status().free_blocks < full);
        e.free_session(s).unwrap();
        e.free_session(fresh_s).unwrap();
        assert_eq!(e.arena_status().free_blocks, full);
        // Stale handle rejected.
        assert!(e.decode_step(s, 0, 0).is_err());
    }

    #[test]
    fn decode_batch_matches_individual_steps() {
        let e = engine();
        let sa = e.new_session().unwrap();
        let sb = e.new_session().unwrap();
        let a = e.decode_step(sa, 3, 0).unwrap();
        let b = e.decode_step(sb, 9, 0).unwrap();
        let ba = e.new_session().unwrap();
        let bb = e.new_session().unwrap();
        let out = e.decode_batch(&[ba, bb], &[3, 9], &[0, 0]).unwrap();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0], a);
        assert_eq!(out[1], b);
    }

    #[test]
    fn explicit_arena_geometry_is_respected() {
        let e = Engine::load_with_arena(
            Artifacts::synthetic(1).unwrap(),
            BackendKind::Reference,
            4,
            6,
        )
        .unwrap();
        let st = e.arena_status();
        assert_eq!(st.block_len, 4);
        assert_eq!(st.total_blocks, 6);
        assert_eq!(e.blocks_for_positions(0), 0);
        assert_eq!(e.blocks_for_positions(4), 1);
        assert_eq!(e.blocks_for_positions(5), 2);
        // Reservation claims worst-case blocks up front.
        let s = e.new_session().unwrap();
        e.reserve_session(s, 9).unwrap();
        assert_eq!(e.session_blocks(s).unwrap(), 3);
        assert_eq!(e.arena_status().free_blocks, 3);
    }

    #[test]
    fn decode_step_matches_golden_first_logits() {
        let e = engine();
        let g = e.artifacts.golden.clone();
        let s = e.new_session().unwrap();
        let logits = e.decode_step(s, g.prompt[0], 0).unwrap();
        for (got, want) in logits.iter().zip(g.first_logits_prefix.iter()) {
            assert!(
                (got - want).abs() <= 1e-4 * want.abs().max(1.0),
                "{got} vs {want}"
            );
        }
        let l2: f64 = logits
            .iter()
            .map(|&x| (x as f64) * (x as f64))
            .sum::<f64>()
            .sqrt();
        assert!((l2 - g.first_logits_l2).abs() / g.first_logits_l2 < 1e-4);
    }

    #[test]
    fn engines_agree_across_instances() {
        // Two engines from the same artifacts must agree bitwise.
        let e1 = engine();
        let e2 = engine();
        let s1 = e1.new_session().unwrap();
        let s2 = e2.new_session().unwrap();
        assert_eq!(
            e1.decode_step(s1, 42, 0).unwrap(),
            e2.decode_step(s2, 42, 0).unwrap()
        );
    }
}
