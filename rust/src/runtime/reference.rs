//! Pure-Rust reference executor for the 1-bit decode step — the default
//! runtime backend of the offline build.
//!
//! Numerics mirror `python/compile/kernels/ref.py` + `model.py` exactly;
//! the dense f32 kernels themselves (activation quantization, RMSNorm,
//! GELU, softmax, `bitlinear`, `bitlinear_batch`, attention) live in the
//! shared [`super::kernels`] module so the packed-bitplane backend
//! ([`super::packed`]) can reuse them verbatim — this file owns only the
//! manifest resolution and the decode-step orchestration:
//!
//! * `act_quant_int8`  — absmax per-tensor symmetric int8 quantization.
//! * `bitlinear`       — W1A8 projection: quantize → exact integer
//!   matmul on f32 carriers → rescale (what one PIM bank computes).
//! * attention         — W8A8 activation-to-activation matmuls (the
//!   attention-head op PIM-LLM keeps on the systolic array).
//! * RMSNorm / tanh-GELU / softmax in f32, like the paper's nonlinear
//!   functional units.
//!
//! KV caches are host `Vec<f32>` tensors of shape
//! `(n_layers, h, max_ctx, d_head)`, threaded through [`Caches::Host`].

use super::artifacts::Artifacts;
use super::backend::{Backend, Caches, StepOutput};
use super::kernels::{attention, bitlinear, bitlinear_batch, gelu, rms_norm};
use crate::util::error::{anyhow, ensure, Context, Result};
use std::sync::Arc;

/// Resolved parameter indices (into `manifest.params`) of one layer.
/// Shared with the packed backend, which resolves the same names and
/// then lowers the six projection matrices into bitplanes.
pub(crate) struct LayerParams {
    pub(crate) ln1_gamma: usize,
    pub(crate) wq: usize,
    pub(crate) wq_scale: usize,
    pub(crate) wk: usize,
    pub(crate) wk_scale: usize,
    pub(crate) wv: usize,
    pub(crate) wv_scale: usize,
    pub(crate) wx: usize,
    pub(crate) wx_scale: usize,
    pub(crate) ln2_gamma: usize,
    pub(crate) w_in: usize,
    pub(crate) w_in_scale: usize,
    pub(crate) w_out: usize,
    pub(crate) w_out_scale: usize,
}

/// The reference backend: interprets the manifest/weights directly.
pub struct ReferenceBackend {
    pub(crate) artifacts: Arc<Artifacts>,
    /// Per-layer parameter indices, resolved once at construction so the
    /// per-token path does no name lookups or allocation.
    pub(crate) layers: Vec<LayerParams>,
    pub(crate) embedding: usize,
    pub(crate) lnf_gamma: usize,
    pub(crate) w_head: usize,
    pub(crate) w_head_scale: usize,
}

impl ReferenceBackend {
    pub fn new(artifacts: Arc<Artifacts>) -> Result<Self> {
        // Resolve every parameter up front: a malformed manifest fails
        // here, not mid-decode, and decode_step indexes straight into
        // the manifest afterwards.
        let find = |name: &str| -> Result<usize> {
            artifacts
                .manifest
                .params
                .iter()
                .position(|p| p.name == name)
                .ok_or_else(|| anyhow!("manifest missing parameter '{name}'"))
        };
        let scalar = |name: &str| -> Result<usize> {
            let i = find(name)?;
            ensure!(
                artifacts.manifest.params[i].numel == 1,
                "parameter '{name}' is not a scalar"
            );
            Ok(i)
        };
        let mut layers = Vec::with_capacity(artifacts.manifest.model.n_layers);
        for layer in 0..artifacts.manifest.model.n_layers {
            let l = |name: &str| format!("layer{layer}.{name}");
            layers.push(LayerParams {
                ln1_gamma: find(&l("ln1_gamma"))?,
                wq: find(&l("wq"))?,
                wq_scale: scalar(&l("wq_scale"))?,
                wk: find(&l("wk"))?,
                wk_scale: scalar(&l("wk_scale"))?,
                wv: find(&l("wv"))?,
                wv_scale: scalar(&l("wv_scale"))?,
                wx: find(&l("wx"))?,
                wx_scale: scalar(&l("wx_scale"))?,
                ln2_gamma: find(&l("ln2_gamma"))?,
                w_in: find(&l("w_in"))?,
                w_in_scale: scalar(&l("w_in_scale"))?,
                w_out: find(&l("w_out"))?,
                w_out_scale: scalar(&l("w_out_scale"))?,
            });
        }
        let embedding = find("embedding")?;
        let lnf_gamma = find("lnf_gamma")?;
        let w_head = find("w_head")?;
        let w_head_scale = scalar("w_head_scale")?;
        Ok(Self {
            artifacts,
            layers,
            embedding,
            lnf_gamma,
            w_head,
            w_head_scale,
        })
    }

    /// Parameter tensor data by resolved index.
    pub(crate) fn data(&self, idx: usize) -> &[f32] {
        self.artifacts
            .param_data(&self.artifacts.manifest.params[idx])
    }

    /// Scalar parameter (shape validated at construction).
    pub(crate) fn scalar(&self, idx: usize) -> f32 {
        self.data(idx)[0]
    }
}

impl Backend for ReferenceBackend {
    fn name(&self) -> &'static str {
        "reference"
    }

    fn platform(&self) -> String {
        "cpu".to_string()
    }

    fn empty_caches(&self) -> Result<Caches> {
        let numel: usize = self.artifacts.cache_shape().iter().product();
        Ok(Caches::Host {
            k: vec![0.0; numel],
            v: vec![0.0; numel],
        })
    }

    fn decode_step(&self, caches: Caches, token_id: i32, pos: i32) -> Result<StepOutput> {
        let (mut kc, mut vc) = match caches {
            Caches::Host { k, v } => (k, v),
            #[cfg(feature = "pjrt")]
            Caches::Device { .. } => {
                crate::bail!("reference backend received device-resident caches")
            }
        };
        let m = self.artifacts.manifest.model.clone();
        let (d, h, max_ctx) = (m.d, m.h, m.max_ctx);
        let dh = d / h;
        ensure!(pos >= 0, "negative position {pos}");
        let pos = pos as usize;
        ensure!(pos < max_ctx, "position {pos} >= max_ctx {max_ctx}");
        let eps = m.eps as f32;

        // Embed (XLA clamps out-of-range gather indices; mirror that).
        let tok = (token_id.max(0) as usize).min(m.vocab - 1);
        let embedding = self.data(self.embedding);
        let mut x: Vec<f32> = embedding[tok * d..(tok + 1) * d].to_vec();

        for (layer, lp) in self.layers.iter().enumerate() {
            // --- attention sub-block (projections on PIM, W1A8) -------
            let xn = rms_norm(&x, self.data(lp.ln1_gamma), eps);
            let q = bitlinear(&xn, self.data(lp.wq), d, self.scalar(lp.wq_scale));
            let k = bitlinear(&xn, self.data(lp.wk), d, self.scalar(lp.wk_scale));
            let v = bitlinear(&xn, self.data(lp.wv), d, self.scalar(lp.wv_scale));

            // Write this token's K/V into the caches at `pos` (the
            // LPDDR-side concat of the paper; never touches RRAM).
            for head in 0..h {
                let base = ((layer * h + head) * max_ctx + pos) * dh;
                kc[base..base + dh].copy_from_slice(&k[head * dh..(head + 1) * dh]);
                vc[base..base + dh].copy_from_slice(&v[head * dh..(head + 1) * dh]);
            }

            let att = attention(&q, &kc, &vc, layer, pos, h, max_ctx, dh);
            let att = bitlinear(&att, self.data(lp.wx), d, self.scalar(lp.wx_scale));
            for (xi, ai) in x.iter_mut().zip(&att) {
                *xi += ai;
            }

            // --- feed-forward sub-block -------------------------------
            let xn = rms_norm(&x, self.data(lp.ln2_gamma), eps);
            let ff = bitlinear(&xn, self.data(lp.w_in), m.d_ff, self.scalar(lp.w_in_scale));
            let ff: Vec<f32> = ff.into_iter().map(gelu).collect();
            let ff = bitlinear(&ff, self.data(lp.w_out), d, self.scalar(lp.w_out_scale));
            for (xi, fi) in x.iter_mut().zip(&ff) {
                *xi += fi;
            }
        }

        let x = rms_norm(&x, self.data(self.lnf_gamma), eps);
        let logits = bitlinear(&x, self.data(self.w_head), m.vocab, self.scalar(self.w_head_scale));

        Ok(StepOutput {
            logits,
            caches: Caches::Host { k: kc, v: vc },
        })
    }

    /// The genuinely batched decode step: every weight matrix is
    /// traversed ONCE per call (via [`bitlinear_batch`]) and applied to
    /// all B per-sequence activations; only the attention sub-block —
    /// which reads per-sequence KV state, not weights — runs per
    /// sequence. Ragged positions are allowed: sequence `i` decodes at
    /// `positions[i]` against its own cache.
    ///
    /// Bit-for-bit equivalent to B sequential [`Backend::decode_step`]
    /// calls (enforced by `tests/batch_equivalence.rs`).
    fn decode_batch(
        &self,
        caches: Vec<Caches>,
        tokens: &[i32],
        positions: &[i32],
    ) -> Result<Vec<StepOutput>> {
        ensure!(
            caches.len() == tokens.len() && caches.len() == positions.len(),
            "decode_batch arity mismatch: {} caches, {} tokens, {} positions",
            caches.len(),
            tokens.len(),
            positions.len()
        );
        if caches.is_empty() {
            return Ok(Vec::new());
        }
        let m = self.artifacts.manifest.model.clone();
        let (d, h, max_ctx) = (m.d, m.h, m.max_ctx);
        let dh = d / h;
        let eps = m.eps as f32;

        let mut kcs = Vec::with_capacity(caches.len());
        let mut vcs = Vec::with_capacity(caches.len());
        for c in caches {
            match c {
                Caches::Host { k, v } => {
                    kcs.push(k);
                    vcs.push(v);
                }
                #[cfg(feature = "pjrt")]
                Caches::Device { .. } => {
                    crate::bail!("reference backend received device-resident caches")
                }
            }
        }
        let mut poss = Vec::with_capacity(positions.len());
        for &p in positions {
            ensure!(p >= 0, "negative position {p}");
            let p = p as usize;
            ensure!(p < max_ctx, "position {p} >= max_ctx {max_ctx}");
            poss.push(p);
        }

        // Embed every sequence's token (XLA-style clamped gather).
        let embedding = self.data(self.embedding);
        let mut xs: Vec<Vec<f32>> = tokens
            .iter()
            .map(|&t| {
                let tok = (t.max(0) as usize).min(m.vocab - 1);
                embedding[tok * d..(tok + 1) * d].to_vec()
            })
            .collect();

        for (layer, lp) in self.layers.iter().enumerate() {
            // --- attention sub-block (projections on PIM, W1A8) -------
            let xn: Vec<Vec<f32>> = xs
                .iter()
                .map(|x| rms_norm(x, self.data(lp.ln1_gamma), eps))
                .collect();
            let q = bitlinear_batch(&xn, self.data(lp.wq), d, self.scalar(lp.wq_scale));
            let k = bitlinear_batch(&xn, self.data(lp.wk), d, self.scalar(lp.wk_scale));
            let v = bitlinear_batch(&xn, self.data(lp.wv), d, self.scalar(lp.wv_scale));

            // Scatter each sequence's new K/V into its own cache at its
            // own (ragged) position.
            for (((kc, vc), &pos), (k_i, v_i)) in kcs
                .iter_mut()
                .zip(vcs.iter_mut())
                .zip(&poss)
                .zip(k.iter().zip(&v))
            {
                for head in 0..h {
                    let base = ((layer * h + head) * max_ctx + pos) * dh;
                    kc[base..base + dh].copy_from_slice(&k_i[head * dh..(head + 1) * dh]);
                    vc[base..base + dh].copy_from_slice(&v_i[head * dh..(head + 1) * dh]);
                }
            }

            // Attention reads per-sequence KV state, not weights — there
            // is nothing to amortize, so it runs per sequence.
            let att: Vec<Vec<f32>> = q
                .iter()
                .zip(kcs.iter().zip(&vcs))
                .zip(&poss)
                .map(|((q_i, (kc, vc)), &pos)| attention(q_i, kc, vc, layer, pos, h, max_ctx, dh))
                .collect();
            let att = bitlinear_batch(&att, self.data(lp.wx), d, self.scalar(lp.wx_scale));
            for (x, a) in xs.iter_mut().zip(&att) {
                for (xi, ai) in x.iter_mut().zip(a) {
                    *xi += ai;
                }
            }

            // --- feed-forward sub-block -------------------------------
            let xn: Vec<Vec<f32>> = xs
                .iter()
                .map(|x| rms_norm(x, self.data(lp.ln2_gamma), eps))
                .collect();
            let ff = bitlinear_batch(&xn, self.data(lp.w_in), m.d_ff, self.scalar(lp.w_in_scale));
            let ff: Vec<Vec<f32>> = ff
                .into_iter()
                .map(|f| f.into_iter().map(gelu).collect())
                .collect();
            let ff = bitlinear_batch(&ff, self.data(lp.w_out), d, self.scalar(lp.w_out_scale));
            for (x, f) in xs.iter_mut().zip(&ff) {
                for (xi, fi) in x.iter_mut().zip(f) {
                    *xi += fi;
                }
            }
        }

        let xs: Vec<Vec<f32>> = xs
            .iter()
            .map(|x| rms_norm(x, self.data(self.lnf_gamma), eps))
            .collect();
        let logits = bitlinear_batch(
            &xs,
            self.data(self.w_head),
            m.vocab,
            self.scalar(self.w_head_scale),
        );

        Ok(logits
            .into_iter()
            .zip(kcs.into_iter().zip(vcs))
            .map(|(lg, (kc, vc))| StepOutput {
                logits: lg,
                caches: Caches::Host { k: kc, v: vc },
            })
            .collect())
    }
}

/// Convenience: build the backend straight from artifacts.
pub fn load(artifacts: Arc<Artifacts>) -> Result<ReferenceBackend> {
    ReferenceBackend::new(artifacts).context("building reference backend")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn backend() -> ReferenceBackend {
        ReferenceBackend::new(Arc::new(Artifacts::synthetic(3).unwrap())).unwrap()
    }

    #[test]
    fn decode_step_is_deterministic_and_finite() {
        let b = backend();
        let vocab = b.artifacts.manifest.model.vocab;
        let o1 = b.decode_step(b.empty_caches().unwrap(), 5, 0).unwrap();
        let o2 = b.decode_step(b.empty_caches().unwrap(), 5, 0).unwrap();
        assert_eq!(o1.logits, o2.logits);
        assert_eq!(o1.logits.len(), vocab);
        assert!(o1.logits.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn caches_carry_state() {
        // Feeding [1] then [2] must differ from feeding [2] fresh.
        let b = backend();
        let s1 = b.decode_step(b.empty_caches().unwrap(), 1, 0).unwrap();
        let s2 = b.decode_step(s1.caches, 2, 1).unwrap();
        let fresh = b.decode_step(b.empty_caches().unwrap(), 2, 0).unwrap();
        assert_ne!(s2.logits, fresh.logits);
    }

    #[test]
    fn position_bounds_enforced() {
        let b = backend();
        let max_ctx = b.artifacts.manifest.model.max_ctx;
        let r = b.decode_step(b.empty_caches().unwrap(), 0, max_ctx as i32);
        assert!(r.is_err());
        let r = b.decode_step(b.empty_caches().unwrap(), 0, -1);
        assert!(r.is_err());
    }

    #[test]
    fn out_of_range_token_clamped_like_xla_gather() {
        let b = backend();
        let vocab = b.artifacts.manifest.model.vocab as i32;
        let o = b
            .decode_step(b.empty_caches().unwrap(), vocab + 500, 0)
            .unwrap();
        let edge = b
            .decode_step(b.empty_caches().unwrap(), vocab - 1, 0)
            .unwrap();
        assert_eq!(o.logits, edge.logits);
    }

    #[test]
    fn decode_batch_bitwise_matches_decode_step() {
        let b = backend();
        let tokens = [1i32, 9, 23, 4];
        let seq: Vec<StepOutput> = tokens
            .iter()
            .map(|&t| b.decode_step(b.empty_caches().unwrap(), t, 0).unwrap())
            .collect();
        let caches = tokens.iter().map(|_| b.empty_caches().unwrap()).collect();
        let batch = b.decode_batch(caches, &tokens, &[0, 0, 0, 0]).unwrap();
        for (s, bt) in seq.iter().zip(&batch) {
            assert_eq!(s.logits, bt.logits);
        }
    }

    #[test]
    fn decode_batch_allows_ragged_positions() {
        // Sequence A at pos 2 (two tokens already cached), sequence B
        // fresh at pos 0, decoded in ONE batch: each must match its own
        // sequential continuation exactly.
        let b = backend();
        let s1 = b.decode_step(b.empty_caches().unwrap(), 1, 0).unwrap();
        let s2 = b.decode_step(s1.caches, 2, 1).unwrap();
        let seq_a = b.decode_step(s2.caches, 3, 2).unwrap();
        let seq_b = b.decode_step(b.empty_caches().unwrap(), 7, 0).unwrap();

        let s1 = b.decode_step(b.empty_caches().unwrap(), 1, 0).unwrap();
        let s2 = b.decode_step(s1.caches, 2, 1).unwrap();
        let out = b
            .decode_batch(
                vec![s2.caches, b.empty_caches().unwrap()],
                &[3, 7],
                &[2, 0],
            )
            .unwrap();
        assert_eq!(out[0].logits, seq_a.logits);
        assert_eq!(out[1].logits, seq_b.logits);
    }

    #[test]
    fn decode_batch_rejects_arity_mismatch_and_bad_positions() {
        let b = backend();
        let r = b.decode_batch(vec![b.empty_caches().unwrap()], &[1, 2], &[0, 0]);
        assert!(r.is_err());
        let max_ctx = b.artifacts.manifest.model.max_ctx as i32;
        let r = b.decode_batch(vec![b.empty_caches().unwrap()], &[1], &[max_ctx]);
        assert!(r.is_err());
        let r = b.decode_batch(vec![b.empty_caches().unwrap()], &[1], &[-1]);
        assert!(r.is_err());
    }

    #[test]
    fn decode_batch_empty_is_empty() {
        let b = backend();
        assert!(b.decode_batch(Vec::new(), &[], &[]).unwrap().is_empty());
    }

    #[test]
    fn missing_parameter_rejected_at_load() {
        let mut a = Artifacts::synthetic(4).unwrap();
        let idx = a
            .manifest
            .params
            .iter()
            .position(|p| p.name == "layer1.wk")
            .unwrap();
        a.manifest.params[idx].name = "layer1.wk_gone".to_string();
        assert!(ReferenceBackend::new(Arc::new(a)).is_err());
    }
}
