//! Pure-Rust reference executor for the 1-bit decode step — the default
//! runtime backend of the offline build.
//!
//! Numerics mirror `python/compile/kernels/ref.py` + `model.py` exactly:
//!
//! * `act_quant_int8`  — absmax per-tensor symmetric int8 quantization.
//! * `bitlinear`       — W1A8 projection: quantize → exact integer
//!   matmul on f32 carriers → rescale (what one PIM bank computes).
//! * `qmatmul`         — W8A8 activation-to-activation matmul (the
//!   attention-head op PIM-LLM keeps on the systolic array).
//! * RMSNorm / tanh-GELU / softmax in f32, like the paper's nonlinear
//!   functional units.
//!
//! Quantized integer values are carried in f32; exact for |v| < 2^24,
//! and the largest magnitude here is bounded by k_max * 127 * 127 with
//! k <= 1024 for the AOT tiny model — inside the exact window (see the
//! derivation in ref.py's module docstring).
//!
//! KV caches are host `Vec<f32>` tensors of shape
//! `(n_layers, h, max_ctx, d_head)`, threaded through [`Caches::Host`].

use super::artifacts::Artifacts;
use super::backend::{Backend, Caches, StepOutput};
use crate::util::error::{anyhow, ensure, Context, Result};
use std::sync::Arc;

/// Absmax per-tensor symmetric int8 quantization (ref.py::act_quant_int8):
/// scale = 127 / max(|x|, eps); x_q = clip(round(x * scale), -128, 127).
fn act_quant_int8(x: &[f32]) -> (Vec<f32>, f32) {
    let absmax = x.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
    let scale = 127.0 / absmax.max(1e-5);
    let q = x
        .iter()
        .map(|&v| (v * scale).round().clamp(-128.0, 127.0))
        .collect();
    (q, scale)
}

/// RMSNorm (model.py::rms_norm): x * rsqrt(mean(x^2) + eps) * gamma.
fn rms_norm(x: &[f32], gamma: &[f32], eps: f32) -> Vec<f32> {
    let var = x.iter().map(|&v| v * v).sum::<f32>() / x.len() as f32;
    let r = 1.0 / (var + eps).sqrt();
    x.iter().zip(gamma).map(|(&v, &g)| v * r * g).collect()
}

/// Tanh-approximate GELU (jax.nn.gelu approximate=True).
fn gelu(x: f32) -> f32 {
    const SQRT_2_OVER_PI: f32 = 0.797_884_6;
    0.5 * x * (1.0 + (SQRT_2_OVER_PI * (x + 0.044_715 * x * x * x)).tanh())
}

/// Numerically-stable softmax in place over `x`.
fn softmax(x: &mut [f32]) {
    let max = x.iter().fold(f32::NEG_INFINITY, |a, &v| a.max(v));
    let mut sum = 0.0f32;
    for v in x.iter_mut() {
        *v = (*v - max).exp();
        sum += *v;
    }
    for v in x.iter_mut() {
        *v /= sum;
    }
}

/// W1A8 projection (ref.py::bitlinear_ref): `x` (len k) through the
/// ternary matrix `w` (k x n_out, row-major) with combined dequant
/// rescale. One PIM-bank MVM.
fn bitlinear(x: &[f32], w: &[f32], n_out: usize, w_scale: f32) -> Vec<f32> {
    let k = x.len();
    debug_assert_eq!(w.len(), k * n_out);
    let (x_q, x_scale) = act_quant_int8(x);
    let mut acc = vec![0.0f32; n_out];
    for (kk, &xv) in x_q.iter().enumerate() {
        if xv == 0.0 {
            continue; // ternary-friendly: skip zero activations
        }
        let row = &w[kk * n_out..(kk + 1) * n_out];
        for (a, &wv) in acc.iter_mut().zip(row) {
            *a += xv * wv;
        }
    }
    let rescale = w_scale / x_scale;
    for a in &mut acc {
        *a *= rescale;
    }
    acc
}

/// Batched W1A8 projection: the same numerics as [`bitlinear`] for each
/// of the B activation vectors in `xs`, but with ONE traversal of the
/// weight matrix `w` per call — each weight row is read once and applied
/// to every sequence while it is hot, instead of being re-streamed B
/// times. This is the software analogue of the paper's weight-stationary
/// PIM banks serving many users per programmed crossbar, and the whole
/// source of the batched path's throughput win.
///
/// Exactness: for every sequence `b` and output `j`, the accumulator
/// receives `x_q[b][kk] * w[kk][j]` for `kk` ascending — the identical
/// f32 operation sequence [`bitlinear`] performs — so the result is
/// bit-for-bit equal to B sequential calls. Column striping (below)
/// partitions `j`, never reorders `kk`, so thread count and stripe
/// boundaries cannot change a single bit of the output.
fn bitlinear_batch(xs: &[Vec<f32>], w: &[f32], n_out: usize, w_scale: f32) -> Vec<Vec<f32>> {
    let b = xs.len();
    if b == 0 {
        return Vec::new();
    }
    let k = xs[0].len();
    debug_assert!(xs.iter().all(|x| x.len() == k));
    debug_assert_eq!(w.len(), k * n_out);
    let quant: Vec<(Vec<f32>, f32)> = xs.iter().map(|x| act_quant_int8(x)).collect();

    // Column stripes: split the output dimension across threads once the
    // MAC count is large enough to amortize thread spawn. Each stripe
    // reads only its own columns of every row, so the weight matrix is
    // still traversed exactly once per call in aggregate.
    const PAR_MAC_THRESHOLD: usize = 1 << 21;
    let threads = if b * k * n_out >= PAR_MAC_THRESHOLD {
        crate::util::par::default_threads().min(n_out)
    } else {
        1
    };
    let chunk = n_out.div_ceil(threads);
    let stripes: Vec<(usize, usize)> = (0..threads)
        .map(|t| (t * chunk, ((t + 1) * chunk).min(n_out)))
        .filter(|&(j0, j1)| j0 < j1)
        .collect();

    let parts = crate::util::par::parallel_map_threads(&stripes, stripes.len(), |&(j0, j1)| {
        let width = j1 - j0;
        let mut acc = vec![0.0f32; b * width];
        for kk in 0..k {
            let row = &w[kk * n_out + j0..kk * n_out + j1];
            for (bi, (x_q, _)) in quant.iter().enumerate() {
                let xv = x_q[kk];
                if xv == 0.0 {
                    continue; // ternary-friendly: skip zero activations
                }
                let a = &mut acc[bi * width..(bi + 1) * width];
                for (aj, &wv) in a.iter_mut().zip(row) {
                    *aj += xv * wv;
                }
            }
        }
        acc
    });

    let mut out: Vec<Vec<f32>> = vec![vec![0.0f32; n_out]; b];
    for (stripe, part) in stripes.iter().zip(&parts) {
        let (j0, j1) = *stripe;
        let width = j1 - j0;
        for (bi, o) in out.iter_mut().enumerate() {
            o[j0..j1].copy_from_slice(&part[bi * width..(bi + 1) * width]);
        }
    }
    for (o, (_, x_scale)) in out.iter_mut().zip(&quant) {
        let rescale = w_scale / x_scale;
        for a in o.iter_mut() {
            *a *= rescale;
        }
    }
    out
}

/// Resolved parameter indices (into `manifest.params`) of one layer.
struct LayerParams {
    ln1_gamma: usize,
    wq: usize,
    wq_scale: usize,
    wk: usize,
    wk_scale: usize,
    wv: usize,
    wv_scale: usize,
    wx: usize,
    wx_scale: usize,
    ln2_gamma: usize,
    w_in: usize,
    w_in_scale: usize,
    w_out: usize,
    w_out_scale: usize,
}

/// The reference backend: interprets the manifest/weights directly.
pub struct ReferenceBackend {
    artifacts: Arc<Artifacts>,
    /// Per-layer parameter indices, resolved once at construction so the
    /// per-token path does no name lookups or allocation.
    layers: Vec<LayerParams>,
    embedding: usize,
    lnf_gamma: usize,
    w_head: usize,
    w_head_scale: usize,
}

impl ReferenceBackend {
    pub fn new(artifacts: Arc<Artifacts>) -> Result<Self> {
        // Resolve every parameter up front: a malformed manifest fails
        // here, not mid-decode, and decode_step indexes straight into
        // the manifest afterwards.
        let find = |name: &str| -> Result<usize> {
            artifacts
                .manifest
                .params
                .iter()
                .position(|p| p.name == name)
                .ok_or_else(|| anyhow!("manifest missing parameter '{name}'"))
        };
        let scalar = |name: &str| -> Result<usize> {
            let i = find(name)?;
            ensure!(
                artifacts.manifest.params[i].numel == 1,
                "parameter '{name}' is not a scalar"
            );
            Ok(i)
        };
        let mut layers = Vec::with_capacity(artifacts.manifest.model.n_layers);
        for layer in 0..artifacts.manifest.model.n_layers {
            let l = |name: &str| format!("layer{layer}.{name}");
            layers.push(LayerParams {
                ln1_gamma: find(&l("ln1_gamma"))?,
                wq: find(&l("wq"))?,
                wq_scale: scalar(&l("wq_scale"))?,
                wk: find(&l("wk"))?,
                wk_scale: scalar(&l("wk_scale"))?,
                wv: find(&l("wv"))?,
                wv_scale: scalar(&l("wv_scale"))?,
                wx: find(&l("wx"))?,
                wx_scale: scalar(&l("wx_scale"))?,
                ln2_gamma: find(&l("ln2_gamma"))?,
                w_in: find(&l("w_in"))?,
                w_in_scale: scalar(&l("w_in_scale"))?,
                w_out: find(&l("w_out"))?,
                w_out_scale: scalar(&l("w_out_scale"))?,
            });
        }
        let embedding = find("embedding")?;
        let lnf_gamma = find("lnf_gamma")?;
        let w_head = find("w_head")?;
        let w_head_scale = scalar("w_head_scale")?;
        Ok(Self {
            artifacts,
            layers,
            embedding,
            lnf_gamma,
            w_head,
            w_head_scale,
        })
    }

    /// Parameter tensor data by resolved index.
    fn data(&self, idx: usize) -> &[f32] {
        self.artifacts
            .param_data(&self.artifacts.manifest.params[idx])
    }

    /// Scalar parameter (shape validated at construction).
    fn scalar(&self, idx: usize) -> f32 {
        self.data(idx)[0]
    }

    /// Multi-head attention over the (already updated) caches of one
    /// layer — both matmuls through W8A8 qmatmul semantics, mirroring
    /// model.py::_attention.
    fn attention(
        &self,
        q: &[f32],
        k_cache: &[f32],
        v_cache: &[f32],
        layer: usize,
        pos: usize,
    ) -> Vec<f32> {
        let m = &self.artifacts.manifest.model;
        let (h, max_ctx) = (m.h, m.max_ctx);
        let dh = m.d / m.h;
        let valid = pos + 1; // causal: slots [0, pos]
        let mut out = vec![0.0f32; m.d];
        for head in 0..h {
            let base = (layer * h + head) * max_ctx * dh;
            let k_head = &k_cache[base..base + valid * dh];
            let v_head = &v_cache[base..base + valid * dh];
            let q_head = &q[head * dh..(head + 1) * dh];

            // Score = q . K^T, both operands int8-quantized (W8A8).
            let (q_q, q_s) = act_quant_int8(q_head);
            let (k_q, k_s) = act_quant_int8(k_head);
            let inv_scale = 1.0 / (q_s * k_s);
            let inv_sqrt_dh = 1.0 / (dh as f32).sqrt();
            let mut scores = vec![0.0f32; valid];
            for (t, s) in scores.iter_mut().enumerate() {
                let row = &k_q[t * dh..(t + 1) * dh];
                let mut acc = 0.0f32;
                for (a, b) in q_q.iter().zip(row) {
                    acc += a * b;
                }
                *s = acc * inv_scale * inv_sqrt_dh;
            }
            softmax(&mut scores);

            // Out = probs . V (W8A8 again).
            let (p_q, p_s) = act_quant_int8(&scores);
            let (v_q, v_s) = act_quant_int8(v_head);
            let inv_scale = 1.0 / (p_s * v_s);
            let o = &mut out[head * dh..(head + 1) * dh];
            for (t, &pv) in p_q.iter().enumerate() {
                if pv == 0.0 {
                    continue;
                }
                let row = &v_q[t * dh..(t + 1) * dh];
                for (oj, &vj) in o.iter_mut().zip(row) {
                    *oj += pv * vj;
                }
            }
            for oj in o.iter_mut() {
                *oj *= inv_scale;
            }
        }
        out
    }
}

impl Backend for ReferenceBackend {
    fn name(&self) -> &'static str {
        "reference"
    }

    fn platform(&self) -> String {
        "cpu".to_string()
    }

    fn empty_caches(&self) -> Result<Caches> {
        let numel: usize = self.artifacts.cache_shape().iter().product();
        Ok(Caches::Host {
            k: vec![0.0; numel],
            v: vec![0.0; numel],
        })
    }

    fn decode_step(&self, caches: Caches, token_id: i32, pos: i32) -> Result<StepOutput> {
        let (mut kc, mut vc) = match caches {
            Caches::Host { k, v } => (k, v),
            #[cfg(feature = "pjrt")]
            Caches::Device { .. } => {
                crate::bail!("reference backend received device-resident caches")
            }
        };
        let m = self.artifacts.manifest.model.clone();
        let (d, h, max_ctx) = (m.d, m.h, m.max_ctx);
        let dh = d / h;
        ensure!(pos >= 0, "negative position {pos}");
        let pos = pos as usize;
        ensure!(pos < max_ctx, "position {pos} >= max_ctx {max_ctx}");
        let eps = m.eps as f32;

        // Embed (XLA clamps out-of-range gather indices; mirror that).
        let tok = (token_id.max(0) as usize).min(m.vocab - 1);
        let embedding = self.data(self.embedding);
        let mut x: Vec<f32> = embedding[tok * d..(tok + 1) * d].to_vec();

        for (layer, lp) in self.layers.iter().enumerate() {
            // --- attention sub-block (projections on PIM, W1A8) -------
            let xn = rms_norm(&x, self.data(lp.ln1_gamma), eps);
            let q = bitlinear(&xn, self.data(lp.wq), d, self.scalar(lp.wq_scale));
            let k = bitlinear(&xn, self.data(lp.wk), d, self.scalar(lp.wk_scale));
            let v = bitlinear(&xn, self.data(lp.wv), d, self.scalar(lp.wv_scale));

            // Write this token's K/V into the caches at `pos` (the
            // LPDDR-side concat of the paper; never touches RRAM).
            for head in 0..h {
                let base = ((layer * h + head) * max_ctx + pos) * dh;
                kc[base..base + dh].copy_from_slice(&k[head * dh..(head + 1) * dh]);
                vc[base..base + dh].copy_from_slice(&v[head * dh..(head + 1) * dh]);
            }

            let att = self.attention(&q, &kc, &vc, layer, pos);
            let att = bitlinear(&att, self.data(lp.wx), d, self.scalar(lp.wx_scale));
            for (xi, ai) in x.iter_mut().zip(&att) {
                *xi += ai;
            }

            // --- feed-forward sub-block -------------------------------
            let xn = rms_norm(&x, self.data(lp.ln2_gamma), eps);
            let ff = bitlinear(&xn, self.data(lp.w_in), m.d_ff, self.scalar(lp.w_in_scale));
            let ff: Vec<f32> = ff.into_iter().map(gelu).collect();
            let ff = bitlinear(&ff, self.data(lp.w_out), d, self.scalar(lp.w_out_scale));
            for (xi, fi) in x.iter_mut().zip(&ff) {
                *xi += fi;
            }
        }

        let x = rms_norm(&x, self.data(self.lnf_gamma), eps);
        let logits = bitlinear(&x, self.data(self.w_head), m.vocab, self.scalar(self.w_head_scale));

        Ok(StepOutput {
            logits,
            caches: Caches::Host { k: kc, v: vc },
        })
    }

    /// The genuinely batched decode step: every weight matrix is
    /// traversed ONCE per call (via [`bitlinear_batch`]) and applied to
    /// all B per-sequence activations; only the attention sub-block —
    /// which reads per-sequence KV state, not weights — runs per
    /// sequence. Ragged positions are allowed: sequence `i` decodes at
    /// `positions[i]` against its own cache.
    ///
    /// Bit-for-bit equivalent to B sequential [`Backend::decode_step`]
    /// calls (enforced by `tests/batch_equivalence.rs`).
    fn decode_batch(
        &self,
        caches: Vec<Caches>,
        tokens: &[i32],
        positions: &[i32],
    ) -> Result<Vec<StepOutput>> {
        ensure!(
            caches.len() == tokens.len() && caches.len() == positions.len(),
            "decode_batch arity mismatch: {} caches, {} tokens, {} positions",
            caches.len(),
            tokens.len(),
            positions.len()
        );
        if caches.is_empty() {
            return Ok(Vec::new());
        }
        let m = self.artifacts.manifest.model.clone();
        let (d, h, max_ctx) = (m.d, m.h, m.max_ctx);
        let dh = d / h;
        let eps = m.eps as f32;

        let mut kcs = Vec::with_capacity(caches.len());
        let mut vcs = Vec::with_capacity(caches.len());
        for c in caches {
            match c {
                Caches::Host { k, v } => {
                    kcs.push(k);
                    vcs.push(v);
                }
                #[cfg(feature = "pjrt")]
                Caches::Device { .. } => {
                    crate::bail!("reference backend received device-resident caches")
                }
            }
        }
        let mut poss = Vec::with_capacity(positions.len());
        for &p in positions {
            ensure!(p >= 0, "negative position {p}");
            let p = p as usize;
            ensure!(p < max_ctx, "position {p} >= max_ctx {max_ctx}");
            poss.push(p);
        }

        // Embed every sequence's token (XLA-style clamped gather).
        let embedding = self.data(self.embedding);
        let mut xs: Vec<Vec<f32>> = tokens
            .iter()
            .map(|&t| {
                let tok = (t.max(0) as usize).min(m.vocab - 1);
                embedding[tok * d..(tok + 1) * d].to_vec()
            })
            .collect();

        for (layer, lp) in self.layers.iter().enumerate() {
            // --- attention sub-block (projections on PIM, W1A8) -------
            let xn: Vec<Vec<f32>> = xs
                .iter()
                .map(|x| rms_norm(x, self.data(lp.ln1_gamma), eps))
                .collect();
            let q = bitlinear_batch(&xn, self.data(lp.wq), d, self.scalar(lp.wq_scale));
            let k = bitlinear_batch(&xn, self.data(lp.wk), d, self.scalar(lp.wk_scale));
            let v = bitlinear_batch(&xn, self.data(lp.wv), d, self.scalar(lp.wv_scale));

            // Scatter each sequence's new K/V into its own cache at its
            // own (ragged) position.
            for (((kc, vc), &pos), (k_i, v_i)) in kcs
                .iter_mut()
                .zip(vcs.iter_mut())
                .zip(&poss)
                .zip(k.iter().zip(&v))
            {
                for head in 0..h {
                    let base = ((layer * h + head) * max_ctx + pos) * dh;
                    kc[base..base + dh].copy_from_slice(&k_i[head * dh..(head + 1) * dh]);
                    vc[base..base + dh].copy_from_slice(&v_i[head * dh..(head + 1) * dh]);
                }
            }

            // Attention reads per-sequence KV state, not weights — there
            // is nothing to amortize, so it runs per sequence.
            let att: Vec<Vec<f32>> = q
                .iter()
                .zip(kcs.iter().zip(&vcs))
                .zip(&poss)
                .map(|((q_i, (kc, vc)), &pos)| self.attention(q_i, kc, vc, layer, pos))
                .collect();
            let att = bitlinear_batch(&att, self.data(lp.wx), d, self.scalar(lp.wx_scale));
            for (x, a) in xs.iter_mut().zip(&att) {
                for (xi, ai) in x.iter_mut().zip(a) {
                    *xi += ai;
                }
            }

            // --- feed-forward sub-block -------------------------------
            let xn: Vec<Vec<f32>> = xs
                .iter()
                .map(|x| rms_norm(x, self.data(lp.ln2_gamma), eps))
                .collect();
            let ff = bitlinear_batch(&xn, self.data(lp.w_in), m.d_ff, self.scalar(lp.w_in_scale));
            let ff: Vec<Vec<f32>> = ff
                .into_iter()
                .map(|f| f.into_iter().map(gelu).collect())
                .collect();
            let ff = bitlinear_batch(&ff, self.data(lp.w_out), d, self.scalar(lp.w_out_scale));
            for (x, f) in xs.iter_mut().zip(&ff) {
                for (xi, fi) in x.iter_mut().zip(f) {
                    *xi += fi;
                }
            }
        }

        let xs: Vec<Vec<f32>> = xs
            .iter()
            .map(|x| rms_norm(x, self.data(self.lnf_gamma), eps))
            .collect();
        let logits = bitlinear_batch(
            &xs,
            self.data(self.w_head),
            m.vocab,
            self.scalar(self.w_head_scale),
        );

        Ok(logits
            .into_iter()
            .zip(kcs.into_iter().zip(vcs))
            .map(|(lg, (kc, vc))| StepOutput {
                logits: lg,
                caches: Caches::Host { k: kc, v: vc },
            })
            .collect())
    }
}

/// Convenience: build the backend straight from artifacts.
pub fn load(artifacts: Arc<Artifacts>) -> Result<ReferenceBackend> {
    ReferenceBackend::new(artifacts).context("building reference backend")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn backend() -> ReferenceBackend {
        ReferenceBackend::new(Arc::new(Artifacts::synthetic(3).unwrap())).unwrap()
    }

    #[test]
    fn act_quant_matches_ref_py_semantics() {
        let (q, s) = act_quant_int8(&[0.5, -1.0, 0.25]);
        assert_eq!(s, 127.0);
        assert_eq!(q, vec![64.0, -127.0, 32.0]);
        // All-zero input: eps floor keeps the scale finite.
        let (q0, s0) = act_quant_int8(&[0.0, 0.0]);
        assert!(s0.is_finite() && s0 > 0.0);
        assert_eq!(q0, vec![0.0, 0.0]);
    }

    #[test]
    fn softmax_normalizes() {
        let mut x = vec![1.0, 2.0, 3.0];
        softmax(&mut x);
        assert!((x.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(x[2] > x[1] && x[1] > x[0]);
    }

    #[test]
    fn bitlinear_identity_on_identity_matrix() {
        // w = I (ternary-legal), scale chosen so rescale undoes x's
        // quantization: y ~= x.
        let n = 4;
        let mut w = vec![0.0f32; n * n];
        for i in 0..n {
            w[i * n + i] = 1.0;
        }
        let x = vec![0.5, -0.25, 0.125, 1.0];
        let y = bitlinear(&x, &w, n, 1.0);
        for (a, b) in x.iter().zip(&y) {
            assert!((a - b).abs() < 0.01, "{a} vs {b}");
        }
    }

    #[test]
    fn decode_step_is_deterministic_and_finite() {
        let b = backend();
        let vocab = b.artifacts.manifest.model.vocab;
        let o1 = b.decode_step(b.empty_caches().unwrap(), 5, 0).unwrap();
        let o2 = b.decode_step(b.empty_caches().unwrap(), 5, 0).unwrap();
        assert_eq!(o1.logits, o2.logits);
        assert_eq!(o1.logits.len(), vocab);
        assert!(o1.logits.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn caches_carry_state() {
        // Feeding [1] then [2] must differ from feeding [2] fresh.
        let b = backend();
        let s1 = b.decode_step(b.empty_caches().unwrap(), 1, 0).unwrap();
        let s2 = b.decode_step(s1.caches, 2, 1).unwrap();
        let fresh = b.decode_step(b.empty_caches().unwrap(), 2, 0).unwrap();
        assert_ne!(s2.logits, fresh.logits);
    }

    #[test]
    fn position_bounds_enforced() {
        let b = backend();
        let max_ctx = b.artifacts.manifest.model.max_ctx;
        let r = b.decode_step(b.empty_caches().unwrap(), 0, max_ctx as i32);
        assert!(r.is_err());
        let r = b.decode_step(b.empty_caches().unwrap(), 0, -1);
        assert!(r.is_err());
    }

    #[test]
    fn out_of_range_token_clamped_like_xla_gather() {
        let b = backend();
        let vocab = b.artifacts.manifest.model.vocab as i32;
        let o = b
            .decode_step(b.empty_caches().unwrap(), vocab + 500, 0)
            .unwrap();
        let edge = b
            .decode_step(b.empty_caches().unwrap(), vocab - 1, 0)
            .unwrap();
        assert_eq!(o.logits, edge.logits);
    }

    #[test]
    fn bitlinear_batch_bitwise_matches_sequential() {
        // Random-ish inputs across shapes that exercise both the serial
        // stripe path and ragged widths; the batched kernel must agree
        // bit-for-bit with per-vector bitlinear.
        let mut rng = crate::util::rng::Rng::new(99);
        for (b_n, k, n_out) in [(1usize, 8usize, 5usize), (3, 16, 16), (8, 32, 7)] {
            let w: Vec<f32> = (0..k * n_out)
                .map(|_| rng.range(0, 3) as f32 - 1.0)
                .collect();
            let xs: Vec<Vec<f32>> = (0..b_n)
                .map(|_| (0..k).map(|_| rng.normal() as f32).collect())
                .collect();
            let batched = bitlinear_batch(&xs, &w, n_out, 0.37);
            for (x, y) in xs.iter().zip(&batched) {
                assert_eq!(&bitlinear(x, &w, n_out, 0.37), y);
            }
        }
    }

    #[test]
    fn decode_batch_bitwise_matches_decode_step() {
        let b = backend();
        let tokens = [1i32, 9, 23, 4];
        let seq: Vec<StepOutput> = tokens
            .iter()
            .map(|&t| b.decode_step(b.empty_caches().unwrap(), t, 0).unwrap())
            .collect();
        let caches = tokens.iter().map(|_| b.empty_caches().unwrap()).collect();
        let batch = b.decode_batch(caches, &tokens, &[0, 0, 0, 0]).unwrap();
        for (s, bt) in seq.iter().zip(&batch) {
            assert_eq!(s.logits, bt.logits);
        }
    }

    #[test]
    fn decode_batch_allows_ragged_positions() {
        // Sequence A at pos 2 (two tokens already cached), sequence B
        // fresh at pos 0, decoded in ONE batch: each must match its own
        // sequential continuation exactly.
        let b = backend();
        let s1 = b.decode_step(b.empty_caches().unwrap(), 1, 0).unwrap();
        let s2 = b.decode_step(s1.caches, 2, 1).unwrap();
        let seq_a = b.decode_step(s2.caches, 3, 2).unwrap();
        let seq_b = b.decode_step(b.empty_caches().unwrap(), 7, 0).unwrap();

        let s1 = b.decode_step(b.empty_caches().unwrap(), 1, 0).unwrap();
        let s2 = b.decode_step(s1.caches, 2, 1).unwrap();
        let out = b
            .decode_batch(
                vec![s2.caches, b.empty_caches().unwrap()],
                &[3, 7],
                &[2, 0],
            )
            .unwrap();
        assert_eq!(out[0].logits, seq_a.logits);
        assert_eq!(out[1].logits, seq_b.logits);
    }

    #[test]
    fn decode_batch_rejects_arity_mismatch_and_bad_positions() {
        let b = backend();
        let r = b.decode_batch(vec![b.empty_caches().unwrap()], &[1, 2], &[0, 0]);
        assert!(r.is_err());
        let max_ctx = b.artifacts.manifest.model.max_ctx as i32;
        let r = b.decode_batch(vec![b.empty_caches().unwrap()], &[1], &[max_ctx]);
        assert!(r.is_err());
        let r = b.decode_batch(vec![b.empty_caches().unwrap()], &[1], &[-1]);
        assert!(r.is_err());
    }

    #[test]
    fn decode_batch_empty_is_empty() {
        let b = backend();
        assert!(b.decode_batch(Vec::new(), &[], &[]).unwrap().is_empty());
    }

    #[test]
    fn missing_parameter_rejected_at_load() {
        let mut a = Artifacts::synthetic(4).unwrap();
        let idx = a
            .manifest
            .params
            .iter()
            .position(|p| p.name == "layer1.wk")
            .unwrap();
        a.manifest.params[idx].name = "layer1.wk_gone".to_string();
        assert!(ReferenceBackend::new(Arc::new(a)).is_err());
    }
}
