//! Pure-Rust reference executor for the 1-bit decode step — the default
//! runtime backend of the offline build.
//!
//! Numerics mirror `python/compile/kernels/ref.py` + `model.py` exactly;
//! the dense f32 kernels themselves (activation quantization, RMSNorm,
//! GELU, softmax, `bitlinear`, `bitlinear_batch`, attention) live in the
//! shared [`super::kernels`] module so the packed-bitplane backend
//! ([`super::packed`]) can reuse them verbatim — this file owns only the
//! manifest resolution and the decode-step orchestration:
//!
//! * `act_quant_int8`  — absmax per-tensor symmetric int8 quantization.
//! * `bitlinear`       — W1A8 projection: quantize → exact integer
//!   matmul on f32 carriers → rescale (what one PIM bank computes).
//! * attention         — W8A8 activation-to-activation matmuls (the
//!   attention-head op PIM-LLM keeps on the systolic array).
//! * RMSNorm / tanh-GELU / softmax in f32, like the paper's nonlinear
//!   functional units.
//!
//! KV caches live in the shared block-paged arena
//! ([`super::kvcache::CacheArena`]); a decode step writes the token's
//! K/V rows through the session's block table and attends through
//! [`super::kernels::attention_paged`]. The single-session
//! [`Backend::decode_step`] IS a batch of one — `bitlinear_batch` at
//! B=1 is bit-for-bit `bitlinear` (pinned by the kernel tests), so one
//! orchestration serves both entry points and single-vs-batched
//! equivalence holds by construction.
//!
//! [`ReferenceBackend::decode_step_contiguous`] keeps the pre-paging
//! contiguous path alive as the numeric ORACLE: the PR-2 decode-step
//! numerics verbatim over caller-owned `(n_layers, h, max_ctx, d_head)`
//! tensors. `tests/paged_equivalence.rs` holds the paged path — logits
//! AND cache contents — to bitwise equality against it on every shape
//! of workload, including evict→re-prefill cycles.

use super::artifacts::Artifacts;
use super::backend::Backend;
use super::kernels::{
    attention, attention_paged, attention_paged_q8, bitlinear, bitlinear_batch, gelu, rms_norm,
};
use super::kvcache::{ensure_distinct, ArenaLayout, CacheArena, CacheHandle, PagedKv};
use crate::obs::{Counter, Obs, SpanKind};
use crate::util::error::{anyhow, ensure, Context, Result};
use std::cell::RefCell;
use std::sync::Arc;

/// Resolved parameter indices (into `manifest.params`) of one layer.
/// Shared with the packed backend, which resolves the same names and
/// then lowers the six projection matrices into bitplanes.
pub(crate) struct LayerParams {
    pub(crate) ln1_gamma: usize,
    pub(crate) wq: usize,
    pub(crate) wq_scale: usize,
    pub(crate) wk: usize,
    pub(crate) wk_scale: usize,
    pub(crate) wv: usize,
    pub(crate) wv_scale: usize,
    pub(crate) wx: usize,
    pub(crate) wx_scale: usize,
    pub(crate) ln2_gamma: usize,
    pub(crate) w_in: usize,
    pub(crate) w_in_scale: usize,
    pub(crate) w_out: usize,
    pub(crate) w_out_scale: usize,
}

/// The reference backend: interprets the manifest/weights directly.
pub struct ReferenceBackend {
    pub(crate) artifacts: Arc<Artifacts>,
    /// Per-layer parameter indices, resolved once at construction so the
    /// per-token path does no name lookups or allocation.
    pub(crate) layers: Vec<LayerParams>,
    pub(crate) embedding: usize,
    pub(crate) lnf_gamma: usize,
    pub(crate) w_head: usize,
    pub(crate) w_head_scale: usize,
    /// The owning engine's observability bundle (kernel spans land in
    /// the same per-shard trace ring as the serving events). Installed
    /// once via [`Backend::install_obs`]; starts as a disabled
    /// placeholder so every record call is a relaxed load until the
    /// engine turns tracing on. `RefCell` because installation happens
    /// through `&self` at assembly time — never on a decode path.
    pub(crate) obs: RefCell<Arc<Obs>>,
}

impl ReferenceBackend {
    pub fn new(artifacts: Arc<Artifacts>) -> Result<Self> {
        // Resolve every parameter up front: a malformed manifest fails
        // here, not mid-decode, and decode_step indexes straight into
        // the manifest afterwards.
        let find = |name: &str| -> Result<usize> {
            artifacts
                .manifest
                .params
                .iter()
                .position(|p| p.name == name)
                .ok_or_else(|| anyhow!("manifest missing parameter '{name}'"))
        };
        let scalar = |name: &str| -> Result<usize> {
            let i = find(name)?;
            ensure!(
                artifacts.manifest.params[i].numel == 1,
                "parameter '{name}' is not a scalar"
            );
            Ok(i)
        };
        let mut layers = Vec::with_capacity(artifacts.manifest.model.n_layers);
        for layer in 0..artifacts.manifest.model.n_layers {
            let l = |name: &str| format!("layer{layer}.{name}");
            layers.push(LayerParams {
                ln1_gamma: find(&l("ln1_gamma"))?,
                wq: find(&l("wq"))?,
                wq_scale: scalar(&l("wq_scale"))?,
                wk: find(&l("wk"))?,
                wk_scale: scalar(&l("wk_scale"))?,
                wv: find(&l("wv"))?,
                wv_scale: scalar(&l("wv_scale"))?,
                wx: find(&l("wx"))?,
                wx_scale: scalar(&l("wx_scale"))?,
                ln2_gamma: find(&l("ln2_gamma"))?,
                w_in: find(&l("w_in"))?,
                w_in_scale: scalar(&l("w_in_scale"))?,
                w_out: find(&l("w_out"))?,
                w_out_scale: scalar(&l("w_out_scale"))?,
            });
        }
        let embedding = find("embedding")?;
        let lnf_gamma = find("lnf_gamma")?;
        let w_head = find("w_head")?;
        let w_head_scale = scalar("w_head_scale")?;
        Ok(Self {
            artifacts,
            layers,
            embedding,
            lnf_gamma,
            w_head,
            w_head_scale,
            obs: RefCell::new(Arc::new(Obs::new(0))),
        })
    }

    /// Parameter tensor data by resolved index.
    pub(crate) fn data(&self, idx: usize) -> &[f32] {
        self.artifacts
            .param_data(&self.artifacts.manifest.params[idx])
    }

    /// Scalar parameter (shape validated at construction).
    pub(crate) fn scalar(&self, idx: usize) -> f32 {
        self.data(idx)[0]
    }

    /// Validate positions and claim the cache blocks every session needs
    /// for this step — all allocation happens HERE, before any write, so
    /// an out-of-blocks error consumes nothing numerically (re-running
    /// the step after freeing capacity overwrites the same positions).
    /// Shared with the packed backend.
    ///
    /// Prefix sharing rides through transparently: `ensure_capacity`
    /// copy-on-writes a shared (prefix-adopted) block before this step's
    /// `write_kv` touches it, and the attention gather reads adopted
    /// blocks through the block table like any other — so both host
    /// backends serve shared prefixes with zero changes to their decode
    /// orchestration (`tests/prefix_equivalence.rs` pins the bitwise
    /// guarantee on each).
    pub(crate) fn prepare_step(
        arena: &mut CacheArena,
        handles: &[CacheHandle],
        positions: &[i32],
        max_ctx: usize,
    ) -> Result<Vec<usize>> {
        let mut poss = Vec::with_capacity(positions.len());
        for &p in positions {
            ensure!(p >= 0, "negative position {p}");
            let p = p as usize;
            ensure!(p < max_ctx, "position {p} >= max_ctx {max_ctx}");
            poss.push(p);
        }
        for (&h, &pos) in handles.iter().zip(&poss) {
            arena.ensure_capacity(h, pos)?;
        }
        Ok(poss)
    }

    /// Attention over one session's paged view, dispatched on the
    /// arena's storage layout — shared by both host backends so the
    /// layout decision lives in exactly one place. The f32 branch is
    /// the unchanged bit-exact gather; the int8 branch runs the
    /// i32-accumulating kernel and bumps the dequantized-blocks counter
    /// (one per block the window touched — a relaxed atomic add, so the
    /// f32 hot path and the packed backend's zero-allocation guarantee
    /// are untouched).
    pub(crate) fn attention_dispatch(
        q: &[f32],
        view: &PagedKv<'_>,
        layer: usize,
        pos: usize,
        obs: &Obs,
    ) -> Vec<f32> {
        match view.mode() {
            ArenaLayout::F32 => attention_paged(q, view, layer, pos),
            ArenaLayout::KvInt8 => {
                let blocks = (pos + 1).div_ceil(view.block_len()) as u64;
                obs.count(Counter::KvDequantBlocks, blocks);
                attention_paged_q8(q, view, layer, pos)
            }
        }
    }

    /// The pre-paging contiguous decode step, kept verbatim as the
    /// bitwise ORACLE for the paged path: `kc`/`vc` are caller-owned
    /// flattened `(n_layers, h, max_ctx, d_head)` tensors, updated in
    /// place exactly as PR 2's `Caches::Host` path updated them.
    /// `tests/paged_equivalence.rs` drives this against the arena path.
    pub fn decode_step_contiguous(
        &self,
        kc: &mut [f32],
        vc: &mut [f32],
        token_id: i32,
        pos: i32,
    ) -> Result<Vec<f32>> {
        let m = self.artifacts.manifest.model.clone();
        let (d, h, max_ctx) = (m.d, m.h, m.max_ctx);
        let dh = d / h;
        ensure!(pos >= 0, "negative position {pos}");
        let pos = pos as usize;
        ensure!(pos < max_ctx, "position {pos} >= max_ctx {max_ctx}");
        let eps = m.eps as f32;

        // Embed (XLA clamps out-of-range gather indices; mirror that).
        let tok = (token_id.max(0) as usize).min(m.vocab - 1);
        let embedding = self.data(self.embedding);
        let mut x: Vec<f32> = embedding[tok * d..(tok + 1) * d].to_vec();

        for (layer, lp) in self.layers.iter().enumerate() {
            // --- attention sub-block (projections on PIM, W1A8) -------
            let xn = rms_norm(&x, self.data(lp.ln1_gamma), eps);
            let q = bitlinear(&xn, self.data(lp.wq), d, self.scalar(lp.wq_scale));
            let k = bitlinear(&xn, self.data(lp.wk), d, self.scalar(lp.wk_scale));
            let v = bitlinear(&xn, self.data(lp.wv), d, self.scalar(lp.wv_scale));

            // Write this token's K/V into the caches at `pos` (the
            // LPDDR-side concat of the paper; never touches RRAM).
            for head in 0..h {
                let base = ((layer * h + head) * max_ctx + pos) * dh;
                kc[base..base + dh].copy_from_slice(&k[head * dh..(head + 1) * dh]);
                vc[base..base + dh].copy_from_slice(&v[head * dh..(head + 1) * dh]);
            }

            let att = attention(&q, kc, vc, layer, pos, h, max_ctx, dh);
            let att = bitlinear(&att, self.data(lp.wx), d, self.scalar(lp.wx_scale));
            for (xi, ai) in x.iter_mut().zip(&att) {
                *xi += ai;
            }

            // --- feed-forward sub-block -------------------------------
            let xn = rms_norm(&x, self.data(lp.ln2_gamma), eps);
            let ff = bitlinear(&xn, self.data(lp.w_in), m.d_ff, self.scalar(lp.w_in_scale));
            let ff: Vec<f32> = ff.into_iter().map(gelu).collect();
            let ff = bitlinear(&ff, self.data(lp.w_out), d, self.scalar(lp.w_out_scale));
            for (xi, fi) in x.iter_mut().zip(&ff) {
                *xi += fi;
            }
        }

        let x = rms_norm(&x, self.data(self.lnf_gamma), eps);
        Ok(bitlinear(&x, self.data(self.w_head), m.vocab, self.scalar(self.w_head_scale)))
    }
}

impl Backend for ReferenceBackend {
    fn name(&self) -> &'static str {
        "reference"
    }

    fn platform(&self) -> String {
        "cpu".to_string()
    }

    fn install_obs(&self, obs: Arc<Obs>) {
        *self.obs.borrow_mut() = obs;
    }

    /// A single step is a batch of one: `bitlinear_batch` at B=1 is
    /// bit-for-bit `bitlinear` (pinned by the kernel tests), so the one
    /// batched orchestration below serves both entry points.
    fn decode_step(
        &self,
        arena: &mut CacheArena,
        handle: CacheHandle,
        token_id: i32,
        pos: i32,
    ) -> Result<Vec<f32>> {
        let mut out = self.decode_batch(arena, &[handle], &[token_id], &[pos])?;
        Ok(out.pop().expect("one lane in, one lane out"))
    }

    /// The genuinely batched decode step: every weight matrix is
    /// traversed ONCE per call (via [`bitlinear_batch`]) and applied to
    /// all B per-session activations; only the attention sub-block —
    /// which reads per-session KV state through the block tables, not
    /// weights — runs per session. Ragged positions are allowed:
    /// session `i` decodes at `positions[i]` against its own table.
    ///
    /// Bit-for-bit equivalent to B sequential [`Backend::decode_step`]
    /// calls (enforced by `tests/batch_equivalence.rs`) and to the
    /// contiguous oracle (`tests/paged_equivalence.rs`).
    fn decode_batch(
        &self,
        arena: &mut CacheArena,
        handles: &[CacheHandle],
        tokens: &[i32],
        positions: &[i32],
    ) -> Result<Vec<Vec<f32>>> {
        ensure!(
            handles.len() == tokens.len() && handles.len() == positions.len(),
            "decode_batch arity mismatch: {} handles, {} tokens, {} positions",
            handles.len(),
            tokens.len(),
            positions.len()
        );
        if handles.is_empty() {
            return Ok(Vec::new());
        }
        ensure_distinct(handles)?;
        self.step_many(arena, handles, tokens, positions)
    }

    /// Feed `tokens` into ONE session at consecutive positions through
    /// the SAME one-traversal-per-weight orchestration as
    /// [`Backend::decode_batch`] — sound because position `p + 1`'s
    /// layer input depends only on its own previous-layer output, and
    /// its attention reads K/V rows `0..=p + 1`, all of which the
    /// per-layer scatter has already written by the time the per-lane
    /// attention pass runs. Gated to the f32 arena layout: on int8,
    /// writing a row requantizes EARLIER rows of its quantization group
    /// in place, so within one call a later span entry could rewrite
    /// codes an earlier entry's attention has yet to read — there the
    /// span falls back to the sequential default, which is always
    /// bit-exact.
    fn decode_span(
        &self,
        arena: &mut CacheArena,
        handle: CacheHandle,
        tokens: &[i32],
        start_pos: i32,
    ) -> Result<Vec<Vec<f32>>> {
        if tokens.is_empty() {
            return Ok(Vec::new());
        }
        if arena.mode() != ArenaLayout::F32 {
            return tokens
                .iter()
                .enumerate()
                .map(|(i, &t)| self.decode_step(arena, handle, t, start_pos + i as i32))
                .collect();
        }
        let handles = vec![handle; tokens.len()];
        let positions: Vec<i32> = (0..tokens.len() as i32).map(|i| start_pos + i).collect();
        self.step_many(arena, &handles, tokens, &positions)
    }
}

impl ReferenceBackend {
    /// The shared batched orchestration behind [`Backend::decode_batch`]
    /// (B distinct sessions, ragged positions) and
    /// [`Backend::decode_span`] (one session, consecutive positions):
    /// every weight matrix is traversed ONCE per call. Callers have
    /// already validated arity — and distinctness where it matters; span
    /// entries deliberately alias one handle, which is exactly why the
    /// check lives in the callers rather than here.
    fn step_many(
        &self,
        arena: &mut CacheArena,
        handles: &[CacheHandle],
        tokens: &[i32],
        positions: &[i32],
    ) -> Result<Vec<Vec<f32>>> {
        let m = self.artifacts.manifest.model.clone();
        let (d, h, max_ctx) = (m.d, m.h, m.max_ctx);
        let dh = d / h;
        let eps = m.eps as f32;
        let poss = Self::prepare_step(arena, handles, positions, max_ctx)?;
        // One borrow for the whole step (install only happens at
        // assembly); span records are relaxed-load no-ops while
        // tracing is off and allocation-free while it is on.
        let obs_guard = self.obs.borrow();
        let obs: &Obs = &obs_guard;

        // Embed every session's token (XLA-style clamped gather).
        let embedding = self.data(self.embedding);
        let mut xs: Vec<Vec<f32>> = tokens
            .iter()
            .map(|&t| {
                let tok = (t.max(0) as usize).min(m.vocab - 1);
                embedding[tok * d..(tok + 1) * d].to_vec()
            })
            .collect();

        for (layer, lp) in self.layers.iter().enumerate() {
            // --- attention sub-block (projections on PIM, W1A8) -------
            let xn: Vec<Vec<f32>> = xs
                .iter()
                .map(|x| rms_norm(x, self.data(lp.ln1_gamma), eps))
                .collect();
            let lid = layer as u64;
            obs.span_begin(SpanKind::KernelQ, lid);
            let q = bitlinear_batch(&xn, self.data(lp.wq), d, self.scalar(lp.wq_scale));
            obs.span_end(SpanKind::KernelQ, lid);
            obs.span_begin(SpanKind::KernelK, lid);
            let k = bitlinear_batch(&xn, self.data(lp.wk), d, self.scalar(lp.wk_scale));
            obs.span_end(SpanKind::KernelK, lid);
            obs.span_begin(SpanKind::KernelV, lid);
            let v = bitlinear_batch(&xn, self.data(lp.wv), d, self.scalar(lp.wv_scale));
            obs.span_end(SpanKind::KernelV, lid);

            // Scatter each session's new K/V through its block table at
            // its own (ragged) position.
            for (i, (&hd, &pos)) in handles.iter().zip(&poss).enumerate() {
                arena.write_kv(hd, layer, pos, &k[i], &v[i])?;
            }

            // Attention reads per-session KV state, not weights — there
            // is nothing to amortize, so it runs per session, gathering
            // through the block table.
            obs.span_begin(SpanKind::Attention, lid);
            let att = q
                .iter()
                .zip(handles.iter().zip(&poss))
                .map(|(q_i, (&hd, &pos))| {
                    Ok(Self::attention_dispatch(q_i, &arena.view(hd)?, layer, pos, obs))
                })
                .collect::<Result<Vec<_>>>()?;
            obs.span_end(SpanKind::Attention, lid);
            obs.span_begin(SpanKind::KernelO, lid);
            let att = bitlinear_batch(&att, self.data(lp.wx), d, self.scalar(lp.wx_scale));
            obs.span_end(SpanKind::KernelO, lid);
            for (x, a) in xs.iter_mut().zip(&att) {
                for (xi, ai) in x.iter_mut().zip(a) {
                    *xi += ai;
                }
            }

            // --- feed-forward sub-block -------------------------------
            let xn: Vec<Vec<f32>> = xs
                .iter()
                .map(|x| rms_norm(x, self.data(lp.ln2_gamma), eps))
                .collect();
            obs.span_begin(SpanKind::KernelFf1, lid);
            let ff = bitlinear_batch(&xn, self.data(lp.w_in), m.d_ff, self.scalar(lp.w_in_scale));
            obs.span_end(SpanKind::KernelFf1, lid);
            let ff: Vec<Vec<f32>> = ff
                .into_iter()
                .map(|f| f.into_iter().map(gelu).collect())
                .collect();
            obs.span_begin(SpanKind::KernelFf2, lid);
            let ff = bitlinear_batch(&ff, self.data(lp.w_out), d, self.scalar(lp.w_out_scale));
            obs.span_end(SpanKind::KernelFf2, lid);
            for (x, f) in xs.iter_mut().zip(&ff) {
                for (xi, fi) in x.iter_mut().zip(f) {
                    *xi += fi;
                }
            }
        }

        let xs: Vec<Vec<f32>> = xs
            .iter()
            .map(|x| rms_norm(x, self.data(self.lnf_gamma), eps))
            .collect();
        let hid = self.layers.len() as u64;
        obs.span_begin(SpanKind::KernelHead, hid);
        let logits = bitlinear_batch(
            &xs,
            self.data(self.w_head),
            m.vocab,
            self.scalar(self.w_head_scale),
        );
        obs.span_end(SpanKind::KernelHead, hid);
        Ok(logits)
    }
}

/// Convenience: build the backend straight from artifacts.
pub fn load(artifacts: Arc<Artifacts>) -> Result<ReferenceBackend> {
    ReferenceBackend::new(artifacts).context("building reference backend")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::kvcache::CacheLayout;

    fn backend() -> ReferenceBackend {
        ReferenceBackend::new(Arc::new(Artifacts::synthetic(3).unwrap())).unwrap()
    }

    fn arena_for(b: &ReferenceBackend) -> CacheArena {
        CacheArena::with_sessions(CacheLayout::from_model(&b.artifacts.manifest.model), 8)
            .unwrap()
    }

    #[test]
    fn decode_step_is_deterministic_and_finite() {
        let b = backend();
        let mut arena = arena_for(&b);
        let vocab = b.artifacts.manifest.model.vocab;
        let s1 = b.new_session(&mut arena).unwrap();
        let s2 = b.new_session(&mut arena).unwrap();
        let o1 = b.decode_step(&mut arena, s1, 5, 0).unwrap();
        let o2 = b.decode_step(&mut arena, s2, 5, 0).unwrap();
        assert_eq!(o1, o2);
        assert_eq!(o1.len(), vocab);
        assert!(o1.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn caches_carry_state() {
        // Feeding [1] then [2] must differ from feeding [2] fresh.
        let b = backend();
        let mut arena = arena_for(&b);
        let s = b.new_session(&mut arena).unwrap();
        b.decode_step(&mut arena, s, 1, 0).unwrap();
        let continued = b.decode_step(&mut arena, s, 2, 1).unwrap();
        let fresh_s = b.new_session(&mut arena).unwrap();
        let fresh = b.decode_step(&mut arena, fresh_s, 2, 0).unwrap();
        assert_ne!(continued, fresh);
    }

    #[test]
    fn position_bounds_enforced() {
        let b = backend();
        let mut arena = arena_for(&b);
        let max_ctx = b.artifacts.manifest.model.max_ctx;
        let s = b.new_session(&mut arena).unwrap();
        assert!(b.decode_step(&mut arena, s, 0, max_ctx as i32).is_err());
        assert!(b.decode_step(&mut arena, s, 0, -1).is_err());
    }

    #[test]
    fn out_of_range_token_clamped_like_xla_gather() {
        let b = backend();
        let mut arena = arena_for(&b);
        let vocab = b.artifacts.manifest.model.vocab as i32;
        let s1 = b.new_session(&mut arena).unwrap();
        let o = b.decode_step(&mut arena, s1, vocab + 500, 0).unwrap();
        let s2 = b.new_session(&mut arena).unwrap();
        let edge = b.decode_step(&mut arena, s2, vocab - 1, 0).unwrap();
        assert_eq!(o, edge);
    }

    #[test]
    fn decode_batch_bitwise_matches_decode_step() {
        let b = backend();
        let mut arena = arena_for(&b);
        let tokens = [1i32, 9, 23, 4];
        let seq: Vec<Vec<f32>> = tokens
            .iter()
            .map(|&t| {
                let s = b.new_session(&mut arena).unwrap();
                b.decode_step(&mut arena, s, t, 0).unwrap()
            })
            .collect();
        let handles: Vec<_> = tokens
            .iter()
            .map(|_| b.new_session(&mut arena).unwrap())
            .collect();
        let batch = b
            .decode_batch(&mut arena, &handles, &tokens, &[0, 0, 0, 0])
            .unwrap();
        assert_eq!(seq, batch);
    }

    #[test]
    fn decode_batch_allows_ragged_positions() {
        // Session A at pos 2 (two tokens already cached), session B
        // fresh at pos 0, decoded in ONE batch: each must match its own
        // sequential continuation exactly.
        let b = backend();
        let mut arena = arena_for(&b);
        let a1 = b.new_session(&mut arena).unwrap();
        b.decode_step(&mut arena, a1, 1, 0).unwrap();
        b.decode_step(&mut arena, a1, 2, 1).unwrap();
        let seq_a = b.decode_step(&mut arena, a1, 3, 2).unwrap();
        let b1 = b.new_session(&mut arena).unwrap();
        let seq_b = b.decode_step(&mut arena, b1, 7, 0).unwrap();

        let a2 = b.new_session(&mut arena).unwrap();
        b.decode_step(&mut arena, a2, 1, 0).unwrap();
        b.decode_step(&mut arena, a2, 2, 1).unwrap();
        let b2 = b.new_session(&mut arena).unwrap();
        let out = b
            .decode_batch(&mut arena, &[a2, b2], &[3, 7], &[2, 0])
            .unwrap();
        assert_eq!(out[0], seq_a);
        assert_eq!(out[1], seq_b);
    }

    #[test]
    fn decode_batch_rejects_bad_arguments() {
        let b = backend();
        let mut arena = arena_for(&b);
        let s = b.new_session(&mut arena).unwrap();
        // Arity mismatch.
        assert!(b.decode_batch(&mut arena, &[s], &[1, 2], &[0, 0]).is_err());
        // Out-of-range positions.
        let max_ctx = b.artifacts.manifest.model.max_ctx as i32;
        assert!(b.decode_batch(&mut arena, &[s], &[1], &[max_ctx]).is_err());
        assert!(b.decode_batch(&mut arena, &[s], &[1], &[-1]).is_err());
        // Duplicate session in one batch.
        assert!(b
            .decode_batch(&mut arena, &[s, s], &[1, 2], &[0, 1])
            .is_err());
        // Stale handle.
        b.drop_session(&mut arena, s).unwrap();
        assert!(b.decode_step(&mut arena, s, 1, 0).is_err());
    }

    #[test]
    fn decode_batch_empty_is_empty() {
        let b = backend();
        let mut arena = arena_for(&b);
        assert!(b.decode_batch(&mut arena, &[], &[], &[]).unwrap().is_empty());
    }

    #[test]
    fn contiguous_oracle_matches_paged_path() {
        // The in-module smoke version of tests/paged_equivalence.rs:
        // logits and gathered caches bitwise equal over a short run.
        let b = backend();
        let m = b.artifacts.manifest.model.clone();
        let mut arena = CacheArena::new(
            CacheLayout::with_block_len(&m, 3), // awkward block length
            16,
        )
        .unwrap();
        let s = b.new_session(&mut arena).unwrap();
        let numel = m.n_layers * m.h * m.max_ctx * (m.d / m.h);
        let (mut kc, mut vc) = (vec![0.0f32; numel], vec![0.0f32; numel]);
        for (pos, tok) in [5i32, 2, 9, 2, 7, 1, 1, 4].into_iter().enumerate() {
            let paged = b.decode_step(&mut arena, s, tok, pos as i32).unwrap();
            let oracle = b
                .decode_step_contiguous(&mut kc, &mut vc, tok, pos as i32)
                .unwrap();
            assert_eq!(paged, oracle, "pos {pos}");
        }
        assert_eq!(arena.gather_contiguous(s).unwrap(), (kc, vc));
    }

    #[test]
    fn reference_backend_is_send() {
        // The sharded serving engine moves one backend instance into
        // each worker thread as `Box<dyn Backend + Send>`; this compiles
        // only while the struct stays plain data over `Arc<Artifacts>`.
        fn assert_send<T: Send>() {}
        assert_send::<ReferenceBackend>();
    }

    #[test]
    fn missing_parameter_rejected_at_load() {
        let mut a = Artifacts::synthetic(4).unwrap();
        let idx = a
            .manifest
            .params
            .iter()
            .position(|p| p.name == "layer1.wk")
            .unwrap();
        a.manifest.params[idx].name = "layer1.wk_gone".to_string();
        assert!(ReferenceBackend::new(Arc::new(a)).is_err());
    }
}
