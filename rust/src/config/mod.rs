//! Architecture configuration: every physical parameter of the hybrid
//! PIM-LLM accelerator and of the TPU-LLM baseline, with 45 nm-class
//! defaults matching the paper's experimental setup (Synopsys DC @45 nm
//! for the TPU, MNSIM 2.0 with 256x256 RRAM crossbars and 45 nm 8-bit
//! ADCs for the PIM part).
//!
//! Everything is TOML-serializable so calibrated constants live in
//! `configs/calibrated_45nm.toml` and experiments are reproducible from a
//! checked-in file rather than magic numbers.

use crate::util::error::{Context, Result};
use crate::util::toml;
use std::path::Path;

/// Override helpers: apply a TOML key if present.
fn ov_f64(doc: &toml::Doc, table: &str, key: &str, slot: &mut f64) -> Result<()> {
    if let Ok(t) = doc.table(table) {
        if let Some(v) = t.get(key) {
            *slot = v.as_f64()?;
        }
    }
    Ok(())
}

fn ov_usize(doc: &toml::Doc, table: &str, key: &str, slot: &mut usize) -> Result<()> {
    if let Ok(t) = doc.table(table) {
        if let Some(v) = t.get(key) {
            *slot = v.as_usize()?;
        }
    }
    Ok(())
}

fn ov_bool(doc: &toml::Doc, table: &str, key: &str, slot: &mut bool) -> Result<()> {
    if let Ok(t) = doc.table(table) {
        if let Some(v) = t.get(key) {
            *slot = v.as_bool()?;
        }
    }
    Ok(())
}

/// Digital LLM-specific TPU (paper §III-A): 32x32 output-stationary
/// systolic array of 8-bit MACs at 100 MHz, 8 MB SRAM.
#[derive(Debug, Clone, PartialEq)]
pub struct TpuConfig {
    /// Systolic array rows (R).
    pub rows: usize,
    /// Systolic array columns (C).
    pub cols: usize,
    /// Operating frequency in Hz (paper: 100 MHz post-synthesis @45 nm).
    pub freq_hz: f64,
    /// On-chip SRAM capacity in bytes (paper: 8 MB, typical edge TPU).
    pub sram_bytes: usize,
    /// Energy per 8-bit MAC, joules (45 nm, incl. local register traffic).
    pub mac_energy_j: f64,
    /// Static/leakage power of the TPU complex, watts.
    pub static_power_w: f64,
    /// SRAM access energy per byte, joules.
    pub sram_energy_per_byte_j: f64,
}

impl Default for TpuConfig {
    fn default() -> Self {
        Self {
            rows: 32,
            cols: 32,
            freq_hz: 100e6,
            sram_bytes: 8 * 1024 * 1024,
            mac_energy_j: 0.53e-12,
            static_power_w: 0.4e-3,
            sram_energy_per_byte_j: 0.032e-12,
        }
    }
}

/// Analog PIM bank array (paper §III-B): RRAM crossbars with differential
/// device pairs, 8-bit DAC-less bit-serial inputs, shared 8-bit ADCs.
#[derive(Debug, Clone, PartialEq)]
pub struct PimConfig {
    /// Crossbar physical dimension (paper: 256x256 RRAM devices).
    pub crossbar_dim: usize,
    /// Devices per weight. 2 = differential pair encoding of {-1,0,1}
    /// (paper Fig. 3d), so a 256x256 crossbar stores 256x128 weights.
    pub devices_per_weight: usize,
    /// Crossbar analog read (MVM) latency per bit-serial input pulse, s.
    pub xbar_read_latency_s: f64,
    /// Input activation bit-width streamed bit-serially by the drivers.
    pub input_bits: usize,
    /// ADC resolution in bits (paper: 45 nm 8-bit folding ADC).
    pub adc_bits: usize,
    /// ADC conversion latency, seconds (2 GS/s class folding ADC).
    pub adc_latency_s: f64,
    /// Columns multiplexed onto one ADC.
    pub adc_share: usize,
    /// ADC energy per conversion, joules.
    pub adc_energy_j: f64,
    /// Driver (DAC-equivalent) energy per input bit pulse, joules.
    pub dac_energy_j: f64,
    /// Crossbar energy per effective MAC (device pair read), joules.
    pub xbar_mac_energy_j: f64,
    /// Per-token fixed controller/peripheral energy, joules (PIM
    /// controller, global buffer, instruction sequencing).
    pub fixed_token_energy_j: f64,
    /// PEs per tile (paper Fig. 3c: network of PEs per tile).
    pub pes_per_tile: usize,
    /// Crossbars per PE.
    pub xbars_per_pe: usize,
    /// RRAM write energy per device, joules (why attention never goes on
    /// PIM; used by the ablation).
    pub write_energy_per_device_j: f64,
    /// RRAM write latency per row, seconds.
    pub write_latency_per_row_s: f64,
    /// RRAM endurance, program/erase cycles (ablation: device lifetime if
    /// K/V were written each token).
    pub endurance_cycles: f64,
}

impl Default for PimConfig {
    fn default() -> Self {
        Self {
            crossbar_dim: 256,
            devices_per_weight: 2,
            xbar_read_latency_s: 10e-9,
            input_bits: 8,
            adc_bits: 8,
            adc_latency_s: 0.5e-9,
            adc_share: 8,
            adc_energy_j: 3.2e-12,
            dac_energy_j: 0.4e-12,
            xbar_mac_energy_j: 0.54e-12,
            fixed_token_energy_j: 124e-6,
            pes_per_tile: 4,
            xbars_per_pe: 8,
            write_energy_per_device_j: 10e-12,
            write_latency_per_row_s: 100e-9,
            endurance_cycles: 1e8,
        }
    }
}

/// Network-on-chip connecting PIM tiles to each other and to the TPU
/// complex (paper Fig. 3b). Calibrated so that partial-sum/activation
/// routing reproduces the paper's communication fractions (36.3% for
/// OPT-6.7B @ l=128, 10.7% for GPT2-350M @ l=128).
#[derive(Debug, Clone, PartialEq)]
pub struct NocConfig {
    /// Effective serialized time to collect one crossbar's output vector
    /// over the NoC, seconds. Total comm per token ~= n_crossbars * this.
    pub per_xbar_collect_s: f64,
    /// NoC energy per byte moved, joules.
    pub energy_per_byte_j: f64,
    /// Bytes of digitized partial sums produced per crossbar per token.
    pub bytes_per_xbar: usize,
}

impl Default for NocConfig {
    fn default() -> Self {
        Self {
            // 46 ns per crossbar reproduces comm = 9.4 ms/token for
            // OPT-6.7B (204k crossbars) and 0.50 ms for GPT2-350M.
            per_xbar_collect_s: 46e-9,
            energy_per_byte_j: 0.04e-12,
            bytes_per_xbar: 128,
        }
    }
}

/// PIM tile input/output buffer model (paper Fig. 3c). Calibrated to the
/// paper's buffer fractions (14.7% GPT2-350M, 3.5% OPT-6.7B @ l=128).
#[derive(Debug, Clone, PartialEq)]
pub struct BufferConfig {
    /// Fixed buffer fill+drain time per decoder layer per token, seconds.
    /// Dominated by (de)serialization into tile-local SRAM at fixed port
    /// width, roughly model-size independent per layer.
    pub per_layer_s: f64,
    /// Buffer access energy per byte, joules.
    pub energy_per_byte_j: f64,
}

impl Default for BufferConfig {
    fn default() -> Self {
        Self {
            per_layer_s: 28e-6,
            energy_per_byte_j: 0.02e-12,
        }
    }
}

/// LPDDR memory channel (paper: data preloaded into LPDDR; KV cache and
/// activations stream through it).
#[derive(Debug, Clone, PartialEq)]
pub struct LpddrConfig {
    /// Sustained bandwidth, bytes/second (LPDDR4-3200 x32 class).
    pub bandwidth_bytes_per_s: f64,
    /// Access energy per byte, joules (edge LPDDR4 class).
    pub energy_per_byte_j: f64,
    /// Whether the TPU-LLM baseline must stream all weights from LPDDR
    /// every token (true for models larger than SRAM).
    pub charge_weight_streaming: bool,
}

impl Default for LpddrConfig {
    fn default() -> Self {
        Self {
            bandwidth_bytes_per_s: 25.6e9,
            energy_per_byte_j: 0.24e-12,
            charge_weight_streaming: true,
        }
    }
}

/// Digital peripheral circuitry of the PIM part (decoders, mux trees,
/// sequencers). The paper reports its latency share as < 0.01%.
#[derive(Debug, Clone, PartialEq)]
pub struct PeripheralConfig {
    /// Fixed peripheral latency per decoder layer, seconds.
    pub per_layer_s: f64,
    /// Peripheral energy per layer, joules.
    pub energy_per_layer_j: f64,
}

impl Default for PeripheralConfig {
    fn default() -> Self {
        Self {
            per_layer_s: 1e-9,
            energy_per_layer_j: 3.2e-6,
        }
    }
}

/// Complete architecture description used by the coordinator and all
/// substrates.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ArchConfig {
    pub tpu: TpuConfig,
    pub pim: PimConfig,
    pub noc: NocConfig,
    pub buffer: BufferConfig,
    pub lpddr: LpddrConfig,
    pub peripheral: PeripheralConfig,
}

impl ArchConfig {
    /// The paper's evaluated configuration (45 nm, 32x32 array @100 MHz,
    /// 256x256 crossbars, 8-bit ADCs).
    pub fn paper_45nm() -> Self {
        Self::default()
    }

    /// Load a calibrated configuration from TOML. Starts from the paper
    /// defaults and overrides any key present in the file, so calibration
    /// TOMLs may be partial.
    pub fn from_toml_file<P: AsRef<Path>>(path: P) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref()).with_context(|| {
            format!("reading arch config {}", path.as_ref().display())
        })?;
        Self::from_toml_str(&text)
    }

    /// Parse from TOML text (paper defaults + overrides).
    pub fn from_toml_str(text: &str) -> Result<Self> {
        let doc = toml::parse(text).context("parsing arch config TOML")?;
        let mut c = Self::paper_45nm();
        {
            let t = &mut c.tpu;
            ov_usize(&doc, "tpu", "rows", &mut t.rows)?;
            ov_usize(&doc, "tpu", "cols", &mut t.cols)?;
            ov_f64(&doc, "tpu", "freq_hz", &mut t.freq_hz)?;
            ov_usize(&doc, "tpu", "sram_bytes", &mut t.sram_bytes)?;
            ov_f64(&doc, "tpu", "mac_energy_j", &mut t.mac_energy_j)?;
            ov_f64(&doc, "tpu", "static_power_w", &mut t.static_power_w)?;
            ov_f64(&doc, "tpu", "sram_energy_per_byte_j", &mut t.sram_energy_per_byte_j)?;
        }
        {
            let p = &mut c.pim;
            ov_usize(&doc, "pim", "crossbar_dim", &mut p.crossbar_dim)?;
            ov_usize(&doc, "pim", "devices_per_weight", &mut p.devices_per_weight)?;
            ov_f64(&doc, "pim", "xbar_read_latency_s", &mut p.xbar_read_latency_s)?;
            ov_usize(&doc, "pim", "input_bits", &mut p.input_bits)?;
            ov_usize(&doc, "pim", "adc_bits", &mut p.adc_bits)?;
            ov_f64(&doc, "pim", "adc_latency_s", &mut p.adc_latency_s)?;
            ov_usize(&doc, "pim", "adc_share", &mut p.adc_share)?;
            ov_f64(&doc, "pim", "adc_energy_j", &mut p.adc_energy_j)?;
            ov_f64(&doc, "pim", "dac_energy_j", &mut p.dac_energy_j)?;
            ov_f64(&doc, "pim", "xbar_mac_energy_j", &mut p.xbar_mac_energy_j)?;
            ov_f64(&doc, "pim", "fixed_token_energy_j", &mut p.fixed_token_energy_j)?;
            ov_usize(&doc, "pim", "pes_per_tile", &mut p.pes_per_tile)?;
            ov_usize(&doc, "pim", "xbars_per_pe", &mut p.xbars_per_pe)?;
            ov_f64(&doc, "pim", "write_energy_per_device_j", &mut p.write_energy_per_device_j)?;
            ov_f64(&doc, "pim", "write_latency_per_row_s", &mut p.write_latency_per_row_s)?;
            ov_f64(&doc, "pim", "endurance_cycles", &mut p.endurance_cycles)?;
        }
        {
            let n = &mut c.noc;
            ov_f64(&doc, "noc", "per_xbar_collect_s", &mut n.per_xbar_collect_s)?;
            ov_f64(&doc, "noc", "energy_per_byte_j", &mut n.energy_per_byte_j)?;
            ov_usize(&doc, "noc", "bytes_per_xbar", &mut n.bytes_per_xbar)?;
        }
        {
            let b = &mut c.buffer;
            ov_f64(&doc, "buffer", "per_layer_s", &mut b.per_layer_s)?;
            ov_f64(&doc, "buffer", "energy_per_byte_j", &mut b.energy_per_byte_j)?;
        }
        {
            let l = &mut c.lpddr;
            ov_f64(&doc, "lpddr", "bandwidth_bytes_per_s", &mut l.bandwidth_bytes_per_s)?;
            ov_f64(&doc, "lpddr", "energy_per_byte_j", &mut l.energy_per_byte_j)?;
            ov_bool(&doc, "lpddr", "charge_weight_streaming", &mut l.charge_weight_streaming)?;
        }
        {
            let p = &mut c.peripheral;
            ov_f64(&doc, "peripheral", "per_layer_s", &mut p.per_layer_s)?;
            ov_f64(&doc, "peripheral", "energy_per_layer_j", &mut p.energy_per_layer_j)?;
        }
        Ok(c)
    }

    /// Serialize (e.g. after calibration) to TOML.
    pub fn to_toml_file<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        let text = self.to_toml_string();
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path.as_ref(), text).with_context(|| {
            format!("writing arch config {}", path.as_ref().display())
        })?;
        Ok(())
    }

    /// TOML text of the full configuration (deterministic key order).
    pub fn to_toml_string(&self) -> String {
        use toml::Value::{Bool, Num};
        let mut d = toml::Doc::default();
        let t = &self.tpu;
        d.set("tpu", "rows", Num(t.rows as f64));
        d.set("tpu", "cols", Num(t.cols as f64));
        d.set("tpu", "freq_hz", Num(t.freq_hz));
        d.set("tpu", "sram_bytes", Num(t.sram_bytes as f64));
        d.set("tpu", "mac_energy_j", Num(t.mac_energy_j));
        d.set("tpu", "static_power_w", Num(t.static_power_w));
        d.set("tpu", "sram_energy_per_byte_j", Num(t.sram_energy_per_byte_j));
        let p = &self.pim;
        d.set("pim", "crossbar_dim", Num(p.crossbar_dim as f64));
        d.set("pim", "devices_per_weight", Num(p.devices_per_weight as f64));
        d.set("pim", "xbar_read_latency_s", Num(p.xbar_read_latency_s));
        d.set("pim", "input_bits", Num(p.input_bits as f64));
        d.set("pim", "adc_bits", Num(p.adc_bits as f64));
        d.set("pim", "adc_latency_s", Num(p.adc_latency_s));
        d.set("pim", "adc_share", Num(p.adc_share as f64));
        d.set("pim", "adc_energy_j", Num(p.adc_energy_j));
        d.set("pim", "dac_energy_j", Num(p.dac_energy_j));
        d.set("pim", "xbar_mac_energy_j", Num(p.xbar_mac_energy_j));
        d.set("pim", "fixed_token_energy_j", Num(p.fixed_token_energy_j));
        d.set("pim", "pes_per_tile", Num(p.pes_per_tile as f64));
        d.set("pim", "xbars_per_pe", Num(p.xbars_per_pe as f64));
        d.set("pim", "write_energy_per_device_j", Num(p.write_energy_per_device_j));
        d.set("pim", "write_latency_per_row_s", Num(p.write_latency_per_row_s));
        d.set("pim", "endurance_cycles", Num(p.endurance_cycles));
        let n = &self.noc;
        d.set("noc", "per_xbar_collect_s", Num(n.per_xbar_collect_s));
        d.set("noc", "energy_per_byte_j", Num(n.energy_per_byte_j));
        d.set("noc", "bytes_per_xbar", Num(n.bytes_per_xbar as f64));
        let b = &self.buffer;
        d.set("buffer", "per_layer_s", Num(b.per_layer_s));
        d.set("buffer", "energy_per_byte_j", Num(b.energy_per_byte_j));
        let l = &self.lpddr;
        d.set("lpddr", "bandwidth_bytes_per_s", Num(l.bandwidth_bytes_per_s));
        d.set("lpddr", "energy_per_byte_j", Num(l.energy_per_byte_j));
        d.set("lpddr", "charge_weight_streaming", Bool(l.charge_weight_streaming));
        let pe = &self.peripheral;
        d.set("peripheral", "per_layer_s", Num(pe.per_layer_s));
        d.set("peripheral", "energy_per_layer_j", Num(pe.energy_per_layer_j));
        d.to_string()
    }

    /// Effective weights stored per crossbar (differential pairs halve
    /// the column count).
    pub fn weights_per_crossbar(&self) -> usize {
        self.pim.crossbar_dim * (self.pim.crossbar_dim / self.pim.devices_per_weight)
    }

    /// Clock period of the TPU, seconds.
    pub fn tpu_cycle_s(&self) -> f64 {
        1.0 / self.tpu.freq_hz
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_hardware() {
        let c = ArchConfig::paper_45nm();
        assert_eq!(c.tpu.rows, 32);
        assert_eq!(c.tpu.cols, 32);
        assert_eq!(c.tpu.freq_hz, 100e6);
        assert_eq!(c.tpu.sram_bytes, 8 * 1024 * 1024);
        assert_eq!(c.pim.crossbar_dim, 256);
        assert_eq!(c.pim.adc_bits, 8);
    }

    #[test]
    fn weights_per_crossbar_uses_differential_pairs() {
        let c = ArchConfig::paper_45nm();
        // 256 rows x 128 weight columns
        assert_eq!(c.weights_per_crossbar(), 256 * 128);
    }

    #[test]
    fn toml_roundtrip() {
        let c = ArchConfig::paper_45nm();
        let back = ArchConfig::from_toml_str(&c.to_toml_string()).unwrap();
        assert_eq!(c, back);
    }

    #[test]
    fn toml_file_roundtrip_and_partial_override() {
        let c = ArchConfig::paper_45nm();
        let path = std::env::temp_dir().join(format!(
            "pimllm-arch-{}.toml",
            std::process::id()
        ));
        c.to_toml_file(&path).unwrap();
        let back = ArchConfig::from_toml_file(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(c, back);
        // Partial file only overrides the named key.
        let partial = ArchConfig::from_toml_str("[tpu]\nrows = 64\n").unwrap();
        assert_eq!(partial.tpu.rows, 64);
        assert_eq!(partial.tpu.cols, c.tpu.cols);
        assert_eq!(partial.pim, c.pim);
    }

    #[test]
    fn cycle_time_is_10ns_at_100mhz() {
        let c = ArchConfig::paper_45nm();
        assert!((c.tpu_cycle_s() - 10e-9).abs() < 1e-15);
    }
}
