//! Scoped-thread data-parallel map — the rayon replacement for the
//! figure sweeps (7 models x 6 contexts x 2 architectures each calling
//! the simulator).
//!
//! Work-stealing is overkill for these uniform sweeps; a shared atomic
//! index over the input slice balances fine and keeps results in input
//! order.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of worker threads (physical parallelism, capped).
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(32)
}

/// Parallel map preserving input order. `f` must be `Sync` (called from
/// many threads); items are processed exactly once.
pub fn parallel_map<T, U, F>(items: &[T], f: F) -> Vec<U>
where
    T: Sync,
    U: Send,
    F: Fn(&T) -> U + Sync,
{
    parallel_map_threads(items, default_threads(), f)
}

/// Parallel map with an explicit thread count.
///
/// # Panic propagation
///
/// If the closure panics on any item, the panic is caught in the worker,
/// the other workers stop claiming new items, and the ORIGINAL panic
/// payload is re-raised on the calling thread after all workers have
/// joined — the caller never observes partial results. (Catching inside
/// the worker, rather than letting `thread::scope` re-panic on join,
/// also guarantees the already-written `Some` slots are dropped normally
/// during unwinding instead of leaking through a raw-pointer write.)
pub fn parallel_map_threads<T, U, F>(items: &[T], threads: usize, f: F) -> Vec<U>
where
    T: Sync,
    U: Send,
    F: Fn(&T) -> U + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.clamp(1, n);
    if threads == 1 {
        return items.iter().map(|t| f(t)).collect();
    }

    let next = AtomicUsize::new(0);
    let poisoned = AtomicBool::new(false);
    let payload: Mutex<Option<Box<dyn std::any::Any + Send>>> = Mutex::new(None);
    let mut out: Vec<Option<U>> = (0..n).map(|_| None).collect();
    let out_ptr = SendPtr(out.as_mut_ptr());

    std::thread::scope(|scope| {
        for _ in 0..threads {
            let next = &next;
            let f = &f;
            let out_ptr = out_ptr;
            let poisoned = &poisoned;
            let payload = &payload;
            scope.spawn(move || {
                // Bind the wrapper itself so edition-2021 disjoint capture
                // moves the Send wrapper, not the raw-pointer field.
                let slots = out_ptr;
                loop {
                    if poisoned.load(Ordering::Relaxed) {
                        break; // another worker panicked; stop early
                    }
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    // AssertUnwindSafe: on Err we never touch the closure
                    // or the output again — the payload is re-thrown.
                    match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        f(&items[i])
                    })) {
                        Ok(v) => {
                            // SAFETY: each index i is claimed exactly once
                            // via the atomic counter, so no two threads
                            // write the same slot; the vector outlives the
                            // scope.
                            unsafe {
                                *slots.0.add(i) = Some(v);
                            }
                        }
                        Err(p) => {
                            poisoned.store(true, Ordering::Relaxed);
                            let mut guard =
                                payload.lock().unwrap_or_else(|e| e.into_inner());
                            // Keep the FIRST panic if several race.
                            if guard.is_none() {
                                *guard = Some(p);
                            }
                            break;
                        }
                    }
                }
            });
        }
    });

    let first_panic = payload.into_inner().unwrap_or_else(|e| e.into_inner());
    if let Some(p) = first_panic {
        std::panic::resume_unwind(p);
    }
    out.into_iter().map(|v| v.expect("slot filled")).collect()
}

/// Raw-pointer wrapper that is Copy + Send for the scoped workers.
struct SendPtr<U>(*mut Option<U>);
impl<U> Clone for SendPtr<U> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<U> Copy for SendPtr<U> {}
// SAFETY: disjoint-index writes only, synchronized by thread::scope join.
unsafe impl<U: Send> Send for SendPtr<U> {}
unsafe impl<U: Send> Sync for SendPtr<U> {}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn preserves_order() {
        let items: Vec<usize> = (0..1000).collect();
        let out = parallel_map(&items, |&x| x * 2);
        assert_eq!(out, (0..1000).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn each_item_processed_once() {
        let items: Vec<usize> = (0..500).collect();
        let count = AtomicU64::new(0);
        let out = parallel_map(&items, |&x| {
            count.fetch_add(1, Ordering::Relaxed);
            x
        });
        assert_eq!(out.len(), 500);
        assert_eq!(count.load(Ordering::Relaxed), 500);
    }

    #[test]
    fn empty_and_singleton() {
        let empty: Vec<u32> = vec![];
        assert!(parallel_map(&empty, |&x| x).is_empty());
        assert_eq!(parallel_map(&[7u32], |&x| x + 1), vec![8]);
    }

    #[test]
    fn explicit_thread_counts_agree() {
        let items: Vec<u64> = (0..256).collect();
        let a = parallel_map_threads(&items, 1, |&x| x * x);
        let b = parallel_map_threads(&items, 8, |&x| x * x);
        assert_eq!(a, b);
    }

    #[test]
    fn worker_panic_propagates_not_partial_results() {
        let items: Vec<usize> = (0..64).collect();
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            parallel_map_threads(&items, 4, |&x| {
                if x == 13 {
                    panic!("boom at {x}");
                }
                x * 2
            })
        }));
        let payload = result.expect_err("panic must propagate to the caller");
        let msg = payload
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        assert!(msg.contains("boom at 13"), "original payload kept: {msg}");
    }

    #[test]
    fn single_thread_path_panics_too() {
        let items = vec![1u32];
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            parallel_map_threads(&items, 1, |_| -> u32 { panic!("serial boom") })
        }));
        assert!(result.is_err());
    }

    #[test]
    fn parallelism_actually_happens() {
        // With 4 threads and sleepy work, wall time << serial time.
        let items: Vec<u32> = (0..8).collect();
        let t0 = std::time::Instant::now();
        parallel_map_threads(&items, 8, |_| {
            std::thread::sleep(std::time::Duration::from_millis(30))
        });
        assert!(t0.elapsed().as_millis() < 8 * 30);
    }
}
