//! Read-only memory-mapped files — the zero-copy substrate under the
//! packed-artifact loader (`crate::quant::artifact`), kept in-crate like
//! every other substrate (see the dependency-policy note in Cargo.toml).
//!
//! Two layers:
//!
//! * [`Mapping`] — a whole file mapped `PROT_READ`/`MAP_PRIVATE` through
//!   a direct `extern "C"` binding to the unix `mmap`/`munmap` pair (no
//!   libc crate). Only compiled into a working constructor on 64-bit
//!   unix; elsewhere [`Mapping::of_file`] returns a clear error and the
//!   callers fall back to buffered reads.
//! * [`FileBytes`] — the loader-facing entry: "give me this file's
//!   bytes, mapped if the platform can, read into memory otherwise".
//!   Consumers that only need `&[u8]` never see the difference; the
//!   artifact loader additionally asks for the [`Mapping`] so it can
//!   keep plane sections as pointers into the map (`Arc`-shared, so N
//!   engines/shards in one process — and N processes via the kernel
//!   page cache — share one physical copy).
//!
//! Safety argument for the `unsafe` here: the region is mapped
//! `PROT_READ` + `MAP_PRIVATE`, so no one can write through it and
//! writes elsewhere cannot move it; it stays valid until `munmap`, which
//! only `Drop` calls; and `Mapping` is therefore `Send + Sync` the same
//! way `&[u8]` is. A truncation of the underlying file by another
//! process could SIGBUS any mmap consumer — the standard, documented
//! mmap caveat; artifacts are immutable build products, and the buffered
//! fallback exists for anyone who cannot accept it.

use crate::util::error::{bail, Context, Result};
use std::path::Path;
use std::sync::Arc;

#[cfg(all(unix, target_pointer_width = "64"))]
mod sys {
    use core::ffi::c_void;
    // POSIX values shared by Linux and the BSD/mac family.
    pub const PROT_READ: i32 = 1;
    pub const MAP_PRIVATE: i32 = 2;
    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> i32;
    }
}

/// A whole file mapped read-only. Dereferences to `&[u8]`; unmapped on
/// drop. Construct through [`Mapping::of_file`] (64-bit unix) or accept
/// either backing via [`FileBytes::open`].
pub struct Mapping {
    ptr: std::ptr::NonNull<u8>,
    len: usize,
}

// SAFETY: the mapping is PROT_READ + MAP_PRIVATE — immutable shared
// bytes, exactly the aliasing contract of &[u8] — and stays valid until
// Drop unmaps it.
unsafe impl Send for Mapping {}
unsafe impl Sync for Mapping {}

impl Mapping {
    /// Map `path` read-only in its entirety. Errors on open/stat/mmap
    /// failure, on an empty file (zero-length mmap is EINVAL), and on
    /// targets without the mmap binding (non-unix or 32-bit pointers).
    #[cfg(all(unix, target_pointer_width = "64"))]
    pub fn of_file(path: &Path) -> Result<Self> {
        use std::os::unix::io::AsRawFd;
        let file = std::fs::File::open(path)
            .with_context(|| format!("opening {} for mmap", path.display()))?;
        let len = file
            .metadata()
            .with_context(|| format!("stat {}", path.display()))?
            .len() as usize;
        if len == 0 {
            bail!("mmap {}: file is empty", path.display());
        }
        // SAFETY: fd is a live file descriptor for the duration of the
        // call; addr = null lets the kernel place the mapping; the
        // result is checked against MAP_FAILED before use. The fd may
        // be closed after mmap returns — the mapping persists.
        let ptr = unsafe {
            sys::mmap(
                std::ptr::null_mut(),
                len,
                sys::PROT_READ,
                sys::MAP_PRIVATE,
                file.as_raw_fd(),
                0,
            )
        };
        if ptr as usize == usize::MAX {
            // MAP_FAILED
            bail!(
                "mmap {} ({} bytes): {}",
                path.display(),
                len,
                std::io::Error::last_os_error()
            );
        }
        let ptr = std::ptr::NonNull::new(ptr as *mut u8)
            .ok_or_else(|| crate::anyhow!("mmap returned null"))?;
        Ok(Self { ptr, len })
    }

    /// Stub for targets without the direct binding: always an error, so
    /// [`FileBytes::open`] falls through to the buffered read.
    #[cfg(not(all(unix, target_pointer_width = "64")))]
    pub fn of_file(path: &Path) -> Result<Self> {
        bail!(
            "mmap unavailable on this target (need 64-bit unix): {}",
            path.display()
        )
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

impl std::ops::Deref for Mapping {
    type Target = [u8];
    fn deref(&self) -> &[u8] {
        // SAFETY: ptr/len describe the live PROT_READ mapping.
        unsafe { std::slice::from_raw_parts(self.ptr.as_ptr(), self.len) }
    }
}

impl Drop for Mapping {
    fn drop(&mut self) {
        #[cfg(all(unix, target_pointer_width = "64"))]
        // SAFETY: exactly the pointer/length pair mmap returned; after
        // this the struct is gone, so no dangling access is possible.
        unsafe {
            sys::munmap(self.ptr.as_ptr() as *mut core::ffi::c_void, self.len);
        }
    }
}

impl std::fmt::Debug for Mapping {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Mapping({} bytes)", self.len)
    }
}

/// A file's bytes, zero-copy when the platform allows it.
#[derive(Debug)]
pub enum FileBytes {
    /// Mapped pages (64-bit unix): shared, lazily faulted, evictable.
    Mapped(Arc<Mapping>),
    /// Buffered fallback: the whole file read into memory.
    Buffered(Vec<u8>),
}

impl FileBytes {
    /// Open `path`, preferring mmap; any mmap failure (platform, empty
    /// file, exotic filesystem) falls back to an ordinary buffered read,
    /// so the only hard error is the file being unreadable.
    pub fn open(path: &Path) -> Result<Self> {
        if let Ok(m) = Mapping::of_file(path) {
            return Ok(FileBytes::Mapped(Arc::new(m)));
        }
        Ok(FileBytes::Buffered(std::fs::read(path).with_context(
            || format!("reading {}", path.display()),
        )?))
    }

    /// The file contents, whichever backing holds them.
    pub fn bytes(&self) -> &[u8] {
        match self {
            FileBytes::Mapped(m) => m,
            FileBytes::Buffered(v) => v,
        }
    }

    /// The mapping behind the bytes, when zero-copy consumers can use
    /// it (None for the buffered fallback).
    pub fn mapping(&self) -> Option<&Arc<Mapping>> {
        match self {
            FileBytes::Mapped(m) => Some(m),
            FileBytes::Buffered(_) => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("pimllm-mmap-{}-{name}", std::process::id()))
    }

    #[test]
    fn mapped_bytes_match_read_bytes() {
        let p = tmp("basic");
        let data: Vec<u8> = (0..4099u32).map(|i| (i % 251) as u8).collect();
        std::fs::write(&p, &data).unwrap();
        let fb = FileBytes::open(&p).unwrap();
        assert_eq!(fb.bytes(), &data[..]);
        #[cfg(all(unix, target_pointer_width = "64"))]
        {
            let m = fb.mapping().expect("64-bit unix should mmap");
            assert_eq!(m.len(), data.len());
            assert!(!m.is_empty());
            // The Arc'd mapping outlives the FileBytes wrapper.
            let keep = Arc::clone(m);
            drop(fb);
            assert_eq!(&keep[..16], &data[..16]);
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn missing_file_is_an_error_not_a_panic() {
        assert!(FileBytes::open(Path::new("/nonexistent/pimllm.tpk")).is_err());
        assert!(Mapping::of_file(Path::new("/nonexistent/pimllm.tpk")).is_err());
    }

    #[test]
    fn empty_file_falls_back_to_buffered() {
        let p = tmp("empty");
        std::fs::write(&p, []).unwrap();
        let fb = FileBytes::open(&p).unwrap();
        assert!(fb.bytes().is_empty());
        assert!(fb.mapping().is_none(), "empty files cannot be mapped");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn mapping_is_send_and_sync() {
        fn assert_both<T: Send + Sync>() {}
        assert_both::<Mapping>();
        assert_both::<FileBytes>();
    }
}
