//! SplitMix64 PRNG — deterministic, seedable, dependency-free. Used for
//! synthetic workloads, serving-trace generation and the in-crate
//! property tests (the offline build has no `rand`/`proptest`).

/// SplitMix64 (Steele, Lea, Flood 2014): passes BigCrush, one u64 of
/// state, trivially seedable.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next raw u64.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, n) (n > 0), via Lemire's multiply-shift with
    /// rejection to kill modulo bias.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (n as u128);
            let lo = m as u64;
            if lo >= n || lo >= (u64::MAX - n + 1) % n {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform usize in [lo, hi] inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as usize
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Bernoulli(p).
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn range_inclusive() {
        let mut r = Rng::new(3);
        let mut hit_lo = false;
        let mut hit_hi = false;
        for _ in 0..2000 {
            let v = r.range(5, 8);
            assert!((5..=8).contains(&v));
            hit_lo |= v == 5;
            hit_hi |= v == 8;
        }
        assert!(hit_lo && hit_hi);
    }

    #[test]
    fn f64_unit_interval_mean() {
        let mut r = Rng::new(11);
        let n = 10_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "{mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "{mean}");
        assert!((var - 1.0).abs() < 0.1, "{var}");
    }

    #[test]
    #[should_panic(expected = "below(0)")]
    fn below_zero_panics() {
        Rng::new(0).below(0);
    }
}
