//! Minimal JSON parser + serializer.
//!
//! Covers the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null); used to read the AOT `manifest.json` /
//! `golden.json` and to emit structured results. Object key order is
//! preserved (Vec of pairs) so round-trips are stable.

use crate::util::error::{anyhow, bail, Result};
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    // ------------------------------------------------------- accessors
    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(pairs) => pairs
                .iter()
                .find(|(k, _)| k == key)
                .map(|(_, v)| v)
                .ok_or_else(|| anyhow!("missing key '{key}'")),
            _ => bail!("not an object (looking up '{key}')"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 {
            bail!("not a non-negative integer: {f}");
        }
        Ok(f as usize)
    }

    pub fn as_i64(&self) -> Result<i64> {
        let f = self.as_f64()?;
        if f.fract() != 0.0 {
            bail!("not an integer: {f}");
        }
        Ok(f as i64)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    /// Array of numbers -> Vec<f64>.
    pub fn as_f64_vec(&self) -> Result<Vec<f64>> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }

    /// Array of integers -> Vec<i64>.
    pub fn as_i64_vec(&self) -> Result<Vec<i64>> {
        self.as_arr()?.iter().map(|v| v.as_i64()).collect()
    }

    // ------------------------------------------------------ serializer
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c if c.is_ascii() => out.push(c),
            c => {
                // Escape all non-ASCII as \u sequences so emitted JSON is
                // pure ASCII. A \u escape carries one UTF-16 code unit, so
                // codepoints above U+FFFF MUST be written as a surrogate
                // pair (a single 5-hex-digit escape would be invalid JSON).
                let mut units = [0u16; 2];
                for &unit in c.encode_utf16(&mut units).iter() {
                    let _ = write!(out, "\\u{unit:04x}");
                }
            }
        }
    }
    out.push('"');
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Json> {
    let mut p = Parser {
        bytes: text.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        bail!("trailing characters at byte {}", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Result<u8> {
        let b = self.peek().ok_or_else(|| anyhow!("unexpected end of input"))?;
        self.pos += 1;
        Ok(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        let got = self.bump()?;
        if got != b {
            bail!(
                "expected '{}' at byte {}, got '{}'",
                b as char,
                self.pos - 1,
                got as char
            );
        }
        Ok(())
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos);
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek().ok_or_else(|| anyhow!("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            pairs.push((key, val));
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b'}' => break,
                c => bail!("expected ',' or '}}', got '{}'", c as char),
            }
        }
        Ok(Json::Obj(pairs))
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b']' => break,
                c => bail!("expected ',' or ']', got '{}'", c as char),
            }
        }
        Ok(Json::Arr(items))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump()? {
                b'"' => return Ok(s),
                b'\\' => match self.bump()? {
                    b'"' => s.push('"'),
                    b'\\' => s.push('\\'),
                    b'/' => s.push('/'),
                    b'n' => s.push('\n'),
                    b't' => s.push('\t'),
                    b'r' => s.push('\r'),
                    b'b' => s.push('\u{8}'),
                    b'f' => s.push('\u{c}'),
                    b'u' => {
                        let code = self.hex4()?;
                        let c = match code {
                            // High surrogate: a low surrogate escape MUST
                            // follow; together they encode one codepoint
                            // above U+FFFF.
                            0xD800..=0xDBFF => {
                                if self.bump()? != b'\\' || self.bump()? != b'u' {
                                    bail!("unpaired high surrogate \\u{code:04x}");
                                }
                                let lo = self.hex4()?;
                                if !(0xDC00..=0xDFFF).contains(&lo) {
                                    bail!(
                                        "high surrogate \\u{code:04x} followed by \
                                         non-surrogate \\u{lo:04x}"
                                    );
                                }
                                let combined =
                                    0x10000 + ((code - 0xD800) << 10) + (lo - 0xDC00);
                                char::from_u32(combined)
                                    .ok_or_else(|| anyhow!("bad codepoint {combined}"))?
                            }
                            0xDC00..=0xDFFF => {
                                bail!("unpaired low surrogate \\u{code:04x}")
                            }
                            _ => char::from_u32(code)
                                .ok_or_else(|| anyhow!("bad codepoint {code}"))?,
                        };
                        s.push(c);
                    }
                    c => bail!("bad escape '\\{}'", c as char),
                },
                c if c < 0x20 => bail!("control character in string"),
                c => {
                    // Re-decode UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(c);
                        let end = start + len;
                        if end > self.bytes.len() {
                            bail!("truncated UTF-8");
                        }
                        s.push_str(
                            std::str::from_utf8(&self.bytes[start..end])
                                .map_err(|e| anyhow!("bad UTF-8: {e}"))?,
                        );
                        self.pos = end;
                    }
                }
            }
        }
    }

    /// Four hex digits of a \u escape.
    fn hex4(&mut self) -> Result<u32> {
        let mut code = 0u32;
        for _ in 0..4 {
            let c = self.bump()? as char;
            code = code * 16
                + c.to_digit(16)
                    .ok_or_else(|| anyhow!("bad \\u escape"))?;
        }
        Ok(code)
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        ) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| anyhow!("invalid number '{text}' at byte {start}"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("42").unwrap().as_i64().unwrap(), 42);
        assert_eq!(parse("-1.5e2").unwrap().as_f64().unwrap(), -150.0);
        assert_eq!(parse("true").unwrap().as_bool().unwrap(), true);
        assert_eq!(parse("\"hi\"").unwrap().as_str().unwrap(), "hi");
        assert_eq!(parse("null").unwrap(), Json::Null);
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": {"e": false}}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str()
                .unwrap(),
            "c"
        );
        assert!(!v.get("d").unwrap().get("e").unwrap().as_bool().unwrap());
    }

    #[test]
    fn string_escapes_roundtrip() {
        let original = Json::Str("a\"b\\c\nd\te\u{1f600}".to_string());
        let text = original.to_string();
        assert_eq!(parse(&text).unwrap(), original);
    }

    #[test]
    fn non_bmp_serialized_as_surrogate_pair() {
        // U+1F600 is the UTF-16 pair D83D/DE00; a single 5-hex-digit
        // escape would be invalid JSON (\u carries one 16-bit code unit).
        let text = Json::Str("\u{1f600}".to_string()).to_string();
        assert_eq!(text, r#""\ud83d\ude00""#);
        // BMP non-ASCII uses a single escape.
        assert_eq!(
            Json::Str("\u{e9}".to_string()).to_string(),
            r#""\u00e9""#
        );
    }

    #[test]
    fn surrogate_pair_escapes_decode() {
        let v = parse(r#""\ud83d\ude00""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "\u{1f600}");
        // Highest codepoint: U+10FFFF = DBFF/DFFF.
        let v = parse(r#""\udbff\udfff""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "\u{10ffff}");
    }

    #[test]
    fn unpaired_surrogates_rejected() {
        // Lone high surrogate (end of string, or followed by non-escape).
        assert!(parse(r#""\ud800""#).is_err());
        assert!(parse(r#""\ud83dx""#).is_err());
        // High surrogate followed by a non-low-surrogate escape.
        assert!(parse(r#""\ud83dA""#).is_err());
        // Lone low surrogate.
        assert!(parse(r#""\ude00""#).is_err());
    }

    #[test]
    fn object_roundtrip_preserves_order() {
        let text = r#"{"z":1,"a":2,"m":[true,null]}"#;
        let v = parse(text).unwrap();
        assert_eq!(v.to_string(), text);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("012x").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("1 2").is_err());
    }

    #[test]
    fn parses_real_manifest_fragment() {
        let text = r#"{"model": {"vocab": 256, "d": 256, "eps": 1e-05},
                       "params": [{"name": "layer0.wq", "shape": [256, 256],
                                   "offset": 256, "numel": 65536}]}"#;
        let v = parse(text).unwrap();
        assert_eq!(v.get("model").unwrap().get("d").unwrap().as_usize().unwrap(), 256);
        let p = &v.get("params").unwrap().as_arr().unwrap()[0];
        assert_eq!(p.get("numel").unwrap().as_usize().unwrap(), 65536);
        assert!((v.get("model").unwrap().get("eps").unwrap().as_f64().unwrap() - 1e-5).abs() < 1e-12);
    }

    #[test]
    fn as_usize_rejects_negative_and_fractional() {
        assert!(parse("-1").unwrap().as_usize().is_err());
        assert!(parse("1.5").unwrap().as_usize().is_err());
    }
}
