//! In-crate substrates for facilities the offline build cannot pull from
//! crates.io (see the dependency-policy note in Cargo.toml):
//!
//! * [`error`] — `anyhow`-equivalent error type, `Result` alias,
//!   `anyhow!`/`bail!`/`ensure!` macros and a `Context` extension trait.
//! * [`json`]  — JSON parser/serializer (manifest.json, golden.json).
//! * [`toml`]  — minimal TOML (tables, numbers, strings, bools) for the
//!   architecture configs.
//! * [`rng`]   — SplitMix64 PRNG for synthetic workloads and the
//!   in-crate property tests.
//! * [`par`]   — scoped-thread data-parallel map (rayon-equivalent for
//!   the figure sweeps).
//! * [`cli`]   — tiny flag parser for the `repro` binary and examples.
//! * [`bench`] — measurement harness used by `rust/benches/*`
//!   (harness = false): warmup, repeats, mean/stddev, table output.
//! * [`mmap`]  — read-only memory-mapped files (direct unix binding,
//!   buffered fallback) for zero-copy `.tpk` packed-artifact loading.
//! * [`testalloc`] — (tests only) counting global allocator backing the
//!   zero-allocation assertions in the packed-kernel tests.

pub mod bench;
pub mod cli;
pub mod error;
pub mod json;
pub mod mmap;
pub mod par;
pub mod rng;
pub mod toml;

#[cfg(test)]
pub mod testalloc;
