//! Measurement harness for `rust/benches/*` (harness = false; the
//! offline build has no criterion). Provides warmup + repeated timing
//! with mean/stddev/min, throughput helpers and a fixed-width report —
//! enough to run the paper-figure benches and the perf-pass loop.

use std::time::Instant;

/// One benchmark measurement.
#[derive(Debug, Clone, PartialEq)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub stddev_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

impl Measurement {
    pub fn per_sec(&self) -> f64 {
        1.0 / self.mean_s
    }
}

/// Bench runner: fixed warmup + adaptive iteration count targeting
/// ~`target_s` of total measurement time, capped by `max_iters`.
pub struct Bench {
    pub warmup: usize,
    pub target_s: f64,
    pub min_iters: usize,
    pub max_iters: usize,
    results: Vec<Measurement>,
}

impl Default for Bench {
    fn default() -> Self {
        Self {
            warmup: 2,
            target_s: 0.5,
            min_iters: 5,
            max_iters: 200,
            results: Vec::new(),
        }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Self {
            warmup: 1,
            target_s: 0.1,
            min_iters: 3,
            max_iters: 30,
            ..Default::default()
        }
    }

    /// Time `f`, returning (and recording) the measurement. The closure
    /// should return something observable to avoid dead-code elimination
    /// (use [`black_box`]).
    pub fn run<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> Measurement {
        for _ in 0..self.warmup {
            black_box(f());
        }
        // Pilot to size the iteration count.
        let t0 = Instant::now();
        black_box(f());
        let pilot = t0.elapsed().as_secs_f64().max(1e-9);
        let iters = ((self.target_s / pilot) as usize)
            .clamp(self.min_iters, self.max_iters);

        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t = Instant::now();
            black_box(f());
            samples.push(t.elapsed().as_secs_f64());
        }
        let mean = samples.iter().sum::<f64>() / iters as f64;
        let var = samples
            .iter()
            .map(|s| (s - mean) * (s - mean))
            .sum::<f64>()
            / iters as f64;
        let m = Measurement {
            name: name.to_string(),
            iters,
            mean_s: mean,
            stddev_s: var.sqrt(),
            min_s: samples.iter().cloned().fold(f64::INFINITY, f64::min),
            max_s: samples.iter().cloned().fold(0.0, f64::max),
        };
        println!(
            "bench {:<44} {:>10} {:>9} ±{:<9} (n={})",
            m.name,
            fmt_time(m.mean_s),
            format!("min {}", fmt_time(m.min_s)),
            fmt_time(m.stddev_s),
            m.iters
        );
        self.results.push(m.clone());
        m
    }

    pub fn results(&self) -> &[Measurement] {
        &self.results
    }
}

/// Opaque value sink (std::hint::black_box wrapper, kept local so bench
/// code reads uniformly).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Human time formatting.
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3}us", s * 1e6)
    } else {
        format!("{:.1}ns", s * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let mut b = Bench::quick();
        let m = b.run("spin", || {
            let mut x = 0u64;
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert!(m.mean_s > 0.0);
        assert!(m.min_s <= m.mean_s && m.mean_s <= m.max_s + 1e-12);
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn iteration_bounds_respected() {
        let mut b = Bench::quick();
        let m = b.run("sleepy", || std::thread::sleep(std::time::Duration::from_millis(20)));
        assert!(m.iters >= b.min_iters.min(3));
        assert!(m.iters <= b.max_iters);
    }

    #[test]
    fn fmt_time_units() {
        assert_eq!(fmt_time(2.5), "2.500s");
        assert_eq!(fmt_time(0.0025), "2.500ms");
        assert_eq!(fmt_time(2.5e-6), "2.500us");
        assert_eq!(fmt_time(2.5e-9), "2.5ns");
    }
}
