//! Tiny CLI flag parser for the `repro` binary and the examples
//! (offline build — no clap). Supports `--key value`, `--key=value`,
//! bare `--flag` booleans and one positional subcommand.

use crate::util::error::{anyhow, bail, Result};
use std::collections::BTreeMap;

/// Parsed command line: subcommand + flags.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse `std::env::args()` (skipping argv[0]).
    pub fn from_env() -> Result<Self> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn parse<I: IntoIterator<Item = String>>(items: I) -> Result<Self> {
        let mut out = Args::default();
        let mut it = items.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if name.is_empty() {
                    bail!("empty flag");
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(name.to_string(), v);
                } else {
                    out.flags.insert(name.to_string(), "true".to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(tok);
            } else {
                bail!("unexpected positional argument '{tok}'");
            }
        }
        Ok(out)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key} expects an integer, got '{v}'")),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key} expects a number, got '{v}'")),
        }
    }

    pub fn flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    /// The `--backend` runtime-executor selector shared by `repro
    /// serve`/`repro validate` and the examples. `None` (flag absent)
    /// lets `BackendKind::resolve` fall back to the `PIM_LLM_BACKEND`
    /// env var, then the reference default.
    pub fn backend(&self) -> Option<&str> {
        self.get("backend")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("simulate --model OPT-6.7B --context 128 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("simulate"));
        assert_eq!(a.get("model"), Some("OPT-6.7B"));
        assert_eq!(a.usize_or("context", 0).unwrap(), 128);
        assert!(a.flag("verbose"));
    }

    #[test]
    fn equals_syntax() {
        let a = parse("sweep --figure=fig5");
        assert_eq!(a.get("figure"), Some("fig5"));
    }

    #[test]
    fn backend_flag_threads_through() {
        let a = parse("serve --backend packed --requests 4");
        assert_eq!(a.backend(), Some("packed"));
        assert_eq!(parse("serve --backend=pjrt").backend(), Some("pjrt"));
        assert_eq!(parse("validate").backend(), None);
    }

    #[test]
    fn defaults_apply() {
        let a = parse("simulate");
        assert_eq!(a.usize_or("context", 128).unwrap(), 128);
        assert_eq!(a.str_or("model", "OPT-6.7B"), "OPT-6.7B");
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn bad_numbers_error() {
        let a = parse("x --n abc");
        assert!(a.usize_or("n", 1).is_err());
    }

    #[test]
    fn double_positional_rejected() {
        assert!(Args::parse(["a".to_string(), "b".to_string()]).is_err());
    }
}
