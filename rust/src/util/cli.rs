//! Tiny CLI flag parser for the `repro` binary and the examples
//! (offline build — no clap). Supports `--key value`, `--key=value`,
//! bare `--flag` booleans and one positional subcommand.

use crate::util::error::{anyhow, bail, Result};
use std::collections::BTreeMap;

/// Parsed command line: subcommand + flags.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse `std::env::args()` (skipping argv[0]).
    pub fn from_env() -> Result<Self> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn parse<I: IntoIterator<Item = String>>(items: I) -> Result<Self> {
        let mut out = Args::default();
        let mut it = items.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if name.is_empty() {
                    bail!("empty flag");
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(name.to_string(), v);
                } else {
                    out.flags.insert(name.to_string(), "true".to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(tok);
            } else {
                bail!("unexpected positional argument '{tok}'");
            }
        }
        Ok(out)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key} expects an integer, got '{v}'")),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key} expects a number, got '{v}'")),
        }
    }

    /// Boolean flag: absent is `false`, bare `--key` is `true`, and an
    /// explicit value must be a recognized spelling. Anything else —
    /// `--verbose on`, `--verbose ture` — is an error, not a silent
    /// `false`: the caller typed SOMETHING and the run must not quietly
    /// proceed as if they hadn't.
    pub fn flag(&self, key: &str) -> Result<bool> {
        match self.get(key) {
            None => Ok(false),
            Some("true") | Some("1") | Some("yes") => Ok(true),
            Some("false") | Some("0") | Some("no") => Ok(false),
            Some(v) => bail!(
                "--{key} expects a boolean (true/1/yes or false/0/no), got '{v}'"
            ),
        }
    }

    /// Reject any flag not in `known` (deliberately NOT paths or
    /// subcommands — those are positional). Every `repro` subcommand
    /// and example calls this after parsing so a typo like
    /// `--prefil-chunk 8` fails loudly with the valid list instead of
    /// silently running with the default.
    pub fn expect_known(&self, known: &[&str]) -> Result<()> {
        for key in self.flags.keys() {
            if !known.contains(&key.as_str()) {
                bail!(
                    "unknown flag --{key} (valid flags: {})",
                    known
                        .iter()
                        .map(|k| format!("--{k}"))
                        .collect::<Vec<_>>()
                        .join(", ")
                );
            }
        }
        Ok(())
    }

    /// The `--backend` runtime-executor selector shared by `repro
    /// serve`/`repro validate` and the examples. `None` (flag absent)
    /// lets `BackendKind::resolve` fall back to the `PIM_LLM_BACKEND`
    /// env var, then the reference default.
    pub fn backend(&self) -> Option<&str> {
        self.get("backend")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("simulate --model OPT-6.7B --context 128 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("simulate"));
        assert_eq!(a.get("model"), Some("OPT-6.7B"));
        assert_eq!(a.usize_or("context", 0).unwrap(), 128);
        assert!(a.flag("verbose").unwrap());
    }

    #[test]
    fn equals_syntax() {
        let a = parse("sweep --figure=fig5");
        assert_eq!(a.get("figure"), Some("fig5"));
    }

    #[test]
    fn backend_flag_threads_through() {
        let a = parse("serve --backend packed --requests 4");
        assert_eq!(a.backend(), Some("packed"));
        assert_eq!(parse("serve --backend=pjrt").backend(), Some("pjrt"));
        assert_eq!(parse("validate").backend(), None);
    }

    #[test]
    fn defaults_apply() {
        let a = parse("simulate");
        assert_eq!(a.usize_or("context", 128).unwrap(), 128);
        assert_eq!(a.str_or("model", "OPT-6.7B"), "OPT-6.7B");
        assert!(!a.flag("verbose").unwrap());
    }

    #[test]
    fn boolean_flags_accept_both_spellings_and_reject_garbage() {
        for (input, want) in [
            ("x --verbose", true),
            ("x --verbose true", true),
            ("x --verbose=1", true),
            ("x --verbose yes", true),
            ("x --verbose false", false),
            ("x --verbose=0", false),
            ("x --verbose no", false),
            ("x", false),
        ] {
            assert_eq!(parse(input).flag("verbose").unwrap(), want, "{input}");
        }
        // Regression: `--verbose on` used to parse as a silent `false`.
        let err = parse("x --verbose on").flag("verbose").unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("--verbose"), "{msg}");
        assert!(msg.contains("expects a boolean"), "{msg}");
        assert!(msg.contains("'on'"), "{msg}");
    }

    #[test]
    fn unknown_flags_are_rejected_with_the_valid_list() {
        let a = parse("serve --requests 4 --prefil-chunk 8");
        let err = a
            .expect_known(&["requests", "prefill-chunk", "backend"])
            .unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("unknown flag --prefil-chunk"), "{msg}");
        assert!(msg.contains("--prefill-chunk"), "{msg}");
        assert!(msg.contains("--backend"), "{msg}");
        // The full known set passes, including flags not supplied.
        parse("serve --requests 4")
            .expect_known(&["requests", "prefill-chunk", "backend"])
            .unwrap();
    }

    #[test]
    fn bad_numbers_error() {
        let a = parse("x --n abc");
        assert!(a.usize_or("n", 1).is_err());
    }

    #[test]
    fn double_positional_rejected() {
        assert!(Args::parse(["a".to_string(), "b".to_string()]).is_err());
    }
}
