//! Thread-local heap-allocation counter for zero-allocation assertions.
//!
//! Registered as the crate's `#[global_allocator]` **only under
//! `cfg(test)`** (see lib.rs), so release binaries and benches keep the
//! stock system allocator. The counter is per-thread: unit tests run on
//! many threads concurrently, and a process-global counter would make
//! "this region allocated nothing" impossible to assert. Deallocations
//! are deliberately not counted — a zero-alloc invariant is about new
//! heap traffic, and frees of pre-warmed scratch would be a bug anyway.
//!
//! Usage in a test:
//!
//! ```ignore
//! let before = thread_allocs();
//! hot_path(&mut warm_scratch, &mut out);
//! assert_eq!(thread_allocs() - before, 0);
//! ```

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

thread_local! {
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

/// Heap allocations made by the current thread since it started (only
/// meaningful when [`CountingAlloc`] is the registered global
/// allocator; otherwise constant 0).
pub fn thread_allocs() -> u64 {
    ALLOCS.with(|c| c.get())
}

/// A `GlobalAlloc` that forwards to [`System`] and bumps the calling
/// thread's allocation counter on every `alloc`/`realloc`.
pub struct CountingAlloc;

fn bump() {
    // try_with: during thread-local teardown the allocator can still be
    // invoked; silently skip counting rather than abort.
    let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
}

// SAFETY: pure pass-through to `System`, which upholds the GlobalAlloc
// contract; the counter side effect touches no allocator state.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        bump();
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        bump();
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        bump();
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_sees_allocations_and_is_quiet_without_them() {
        let base = thread_allocs();
        let v: Vec<u64> = Vec::with_capacity(64);
        assert!(thread_allocs() > base, "Vec::with_capacity must count");
        drop(v);
        let mut buf = [0u64; 8];
        let before = thread_allocs();
        for (i, w) in buf.iter_mut().enumerate() {
            *w = (i as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15);
        }
        let checksum: u64 = buf.iter().fold(0, |a, &b| a ^ b);
        assert_ne!(checksum, 1);
        assert_eq!(thread_allocs() - before, 0, "stack work must not count");
    }

    #[test]
    fn counter_is_thread_local() {
        let base = thread_allocs();
        std::thread::spawn(|| {
            let _v: Vec<u8> = vec![0; 4096];
        })
        .join()
        .unwrap();
        assert_eq!(
            thread_allocs(),
            base,
            "another thread's allocations must not leak into this counter"
        );
    }
}
