//! In-crate error substrate — the `anyhow` replacement for the offline
//! build (see the dependency-policy note in Cargo.toml).
//!
//! Provides the same surface the rest of the crate uses:
//!
//! * [`Error`] — a message-chain error (outermost context first, like
//!   `anyhow::Error`'s "Caused by" chain).
//! * [`Result`] — alias defaulting the error type to [`Error`].
//! * [`crate::anyhow!`] / [`crate::bail!`] / [`crate::ensure!`] — macro
//!   equivalents, re-exported here so call sites can
//!   `use crate::util::error::{anyhow, bail, ensure}`.
//! * [`Context`] — `.context(..)` / `.with_context(..)` extension for
//!   `Result` and `Option`.

use std::fmt;

/// Chain-of-messages error. The first frame is the outermost context;
/// the last is the root cause.
pub struct Error {
    frames: Vec<String>,
}

/// Crate-wide result alias (error type defaults to [`Error`]).
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// New root error from a message.
    pub fn new(msg: impl Into<String>) -> Self {
        Error {
            frames: vec![msg.into()],
        }
    }

    /// Wrap with an outer context message (becomes the new headline).
    pub fn context(mut self, msg: impl Into<String>) -> Self {
        self.frames.insert(0, msg.into());
        self
    }

    /// The messages, outermost first.
    pub fn frames(&self) -> &[String] {
        &self.frames
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.frames.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut first = true;
        for frame in &self.frames {
            if !first {
                write!(f, ": ")?;
            }
            write!(f, "{frame}")?;
            first = false;
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    // `fn main() -> Result<()>` prints the Debug form on error; render
    // the anyhow-style "Caused by" chain so failures stay readable.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.frames.split_first() {
            None => write!(f, "unknown error"),
            Some((head, rest)) => {
                write!(f, "{head}")?;
                if !rest.is_empty() {
                    write!(f, "\n\nCaused by:")?;
                    for frame in rest {
                        write!(f, "\n    {frame}")?;
                    }
                }
                Ok(())
            }
        }
    }
}

impl std::error::Error for Error {}

impl From<String> for Error {
    fn from(msg: String) -> Self {
        Error::new(msg)
    }
}

impl From<&str> for Error {
    fn from(msg: &str) -> Self {
        Error::new(msg)
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::new(e.to_string())
    }
}

impl From<std::fmt::Error> for Error {
    fn from(e: std::fmt::Error) -> Self {
        Error::new(e.to_string())
    }
}

/// `.context(..)` / `.with_context(..)` for fallible values, mirroring
/// `anyhow::Context`.
pub trait Context<T> {
    /// Attach a fixed context message.
    fn context<S: Into<String>>(self, msg: S) -> Result<T>;
    /// Attach a lazily-built context message.
    fn with_context<S: Into<String>, F: FnOnce() -> S>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<S: Into<String>>(self, msg: S) -> Result<T> {
        self.map_err(|e| e.into().context(msg))
    }

    fn with_context<S: Into<String>, F: FnOnce() -> S>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<S: Into<String>>(self, msg: S) -> Result<T> {
        self.ok_or_else(|| Error::new(msg))
    }

    fn with_context<S: Into<String>, F: FnOnce() -> S>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::new(f()))
    }
}

/// Build an [`Error`] from a format string: `anyhow!("bad {x}")`.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::util::error::Error::new(format!($($arg)*))
    };
}

/// Early-return an `Err` built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::util::error::Error::new(format!($($arg)*)).into())
    };
}

/// `ensure!(cond, "msg {x}")` — bail unless `cond` holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

// Make the crate-root macros importable from this module, so call sites
// read `use crate::util::error::{anyhow, bail, ensure, Context, Result}`.
pub use crate::{anyhow, bail, ensure};

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<u32> {
        bail!("root cause {}", 42);
    }

    #[test]
    fn bail_and_display() {
        let e = fails().unwrap_err();
        assert_eq!(e.to_string(), "root cause 42");
        assert_eq!(e.root_cause(), "root cause 42");
    }

    #[test]
    fn context_chains_outermost_first() {
        let e = fails().context("loading artifacts").unwrap_err();
        assert_eq!(e.frames().len(), 2);
        assert_eq!(e.frames()[0], "loading artifacts");
        assert_eq!(e.to_string(), "loading artifacts: root cause 42");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by:"), "{dbg}");
        assert!(dbg.contains("root cause 42"), "{dbg}");
    }

    #[test]
    fn with_context_is_lazy() {
        let ok: Result<u32> = Ok(7);
        let mut called = false;
        let v = ok
            .with_context(|| {
                called = true;
                "never"
            })
            .unwrap();
        assert_eq!(v, 7);
        assert!(!called, "context closure must not run on Ok");
    }

    #[test]
    fn io_errors_convert() {
        let r = std::fs::read_to_string("/nonexistent/definitely/missing")
            .with_context(|| "reading config".to_string());
        let e = r.unwrap_err();
        assert_eq!(e.frames()[0], "reading config");
        assert!(e.frames().len() == 2);
    }

    #[test]
    fn option_context() {
        let none: Option<u32> = None;
        assert!(none.context("missing").is_err());
        assert_eq!(Some(3u32).context("missing").unwrap(), 3);
    }

    #[test]
    fn ensure_macro() {
        fn check(x: u32) -> Result<()> {
            ensure!(x < 10, "x too big: {x}");
            Ok(())
        }
        assert!(check(3).is_ok());
        assert_eq!(check(11).unwrap_err().to_string(), "x too big: 11");
    }

    #[test]
    fn anyhow_macro_builds_error() {
        let e = anyhow!("value {} out of range", 7);
        assert_eq!(e.to_string(), "value 7 out of range");
    }
}
