//! Minimal TOML parser + emitter for the architecture config files.
//!
//! Supports the subset the configs use: `[table]` headers (one level of
//! nesting), `key = value` with numbers (int/float/scientific), strings,
//! and booleans; `#` comments. Emits deterministic, pretty output.

use crate::util::error::{anyhow, bail, Result};
use std::collections::BTreeMap;

/// A TOML scalar value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Num(f64),
    Str(String),
    Bool(bool),
}

impl Value {
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 {
            bail!("not a non-negative integer: {f}");
        }
        Ok(f as usize)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }
}

/// Parsed document: table name -> (key -> value). Root keys live under
/// the "" table.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Doc {
    pub tables: BTreeMap<String, BTreeMap<String, Value>>,
}

impl Doc {
    pub fn table(&self, name: &str) -> Result<&BTreeMap<String, Value>> {
        self.tables
            .get(name)
            .ok_or_else(|| anyhow!("missing table [{name}]"))
    }

    pub fn get(&self, table: &str, key: &str) -> Result<&Value> {
        self.table(table)?
            .get(key)
            .ok_or_else(|| anyhow!("missing {table}.{key}"))
    }

    pub fn f64(&self, table: &str, key: &str) -> Result<f64> {
        self.get(table, key)?.as_f64()
    }

    pub fn usize(&self, table: &str, key: &str) -> Result<usize> {
        self.get(table, key)?.as_usize()
    }

    pub fn bool(&self, table: &str, key: &str) -> Result<bool> {
        self.get(table, key)?.as_bool()
    }

    pub fn set(&mut self, table: &str, key: &str, v: Value) {
        self.tables
            .entry(table.to_string())
            .or_default()
            .insert(key.to_string(), v);
    }

    /// Pretty-print (tables sorted, keys sorted — deterministic).
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        if let Some(root) = self.tables.get("") {
            for (k, v) in root {
                out.push_str(&format!("{k} = {}\n", emit(v)));
            }
        }
        for (name, table) in &self.tables {
            if name.is_empty() {
                continue;
            }
            if !out.is_empty() {
                out.push('\n');
            }
            out.push_str(&format!("[{name}]\n"));
            for (k, v) in table {
                out.push_str(&format!("{k} = {}\n", emit(v)));
            }
        }
        out
    }
}

fn emit(v: &Value) -> String {
    match v {
        Value::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 9e15 && *n == n.trunc() && n.abs() < 1e7 {
                format!("{}", *n as i64)
            } else {
                format!("{n:e}")
            }
        }
        Value::Str(s) => format!("\"{}\"", s.replace('\\', "\\\\").replace('"', "\\\"")),
        Value::Bool(b) => b.to_string(),
    }
}

/// Parse a TOML document (subset; see module docs).
pub fn parse(text: &str) -> Result<Doc> {
    let mut doc = Doc::default();
    let mut current = String::new();
    doc.tables.insert(String::new(), BTreeMap::new());
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[') {
            let name = name
                .strip_suffix(']')
                .ok_or_else(|| anyhow!("line {}: bad table header", lineno + 1))?
                .trim();
            if name.is_empty() {
                bail!("line {}: empty table name", lineno + 1);
            }
            current = name.to_string();
            doc.tables.entry(current.clone()).or_default();
            continue;
        }
        let (key, val) = line
            .split_once('=')
            .ok_or_else(|| anyhow!("line {}: expected key = value", lineno + 1))?;
        let key = key.trim();
        if key.is_empty() {
            bail!("line {}: empty key", lineno + 1);
        }
        let value = parse_value(val.trim())
            .map_err(|e| anyhow!("line {}: {e}", lineno + 1))?;
        doc.tables
            .get_mut(&current)
            .unwrap()
            .insert(key.to_string(), value);
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value> {
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or_else(|| anyhow!("unterminated string"))?;
        return Ok(Value::Str(inner.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    // TOML allows underscores in numbers.
    let cleaned: String = s.chars().filter(|&c| c != '_').collect();
    cleaned
        .parse::<f64>()
        .map(Value::Num)
        .map_err(|_| anyhow!("invalid value '{s}'"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# top-level comment
title = "pim-llm"  # inline comment

[tpu]
rows = 32
freq_hz = 1e8
mac_energy_j = 1.33e-12
enabled = true

[pim]
crossbar_dim = 256
"#;

    #[test]
    fn parses_sample() {
        let d = parse(SAMPLE).unwrap();
        assert_eq!(d.get("", "title").unwrap(), &Value::Str("pim-llm".into()));
        assert_eq!(d.usize("tpu", "rows").unwrap(), 32);
        assert_eq!(d.f64("tpu", "freq_hz").unwrap(), 1e8);
        assert!((d.f64("tpu", "mac_energy_j").unwrap() - 1.33e-12).abs() < 1e-20);
        assert!(d.bool("tpu", "enabled").unwrap());
        assert_eq!(d.usize("pim", "crossbar_dim").unwrap(), 256);
    }

    #[test]
    fn roundtrip() {
        let d = parse(SAMPLE).unwrap();
        let text = d.to_string();
        let d2 = parse(&text).unwrap();
        assert_eq!(d, d2);
    }

    #[test]
    fn underscored_numbers() {
        let d = parse("x = 8_388_608").unwrap();
        assert_eq!(d.usize("", "x").unwrap(), 8_388_608);
    }

    #[test]
    fn errors_are_located() {
        let err = parse("[tpu]\nrows 32").unwrap_err().to_string();
        assert!(err.contains("line 2"), "{err}");
        assert!(parse("[]").is_err());
        assert!(parse("k = \"open").is_err());
    }

    #[test]
    fn missing_lookups_fail() {
        let d = parse("[a]\nb = 1").unwrap();
        assert!(d.f64("a", "c").is_err());
        assert!(d.f64("z", "b").is_err());
    }
}
