//! DRAM/SRAM read-address trace generation — the paper's "dataflow
//! generator produces read address traces to retrieve inputs and
//! weights from LPDDR, routing them to the input and weight SRAMs based
//! on the OS dataflow algorithm" (§III-A), in the style of SCALE-Sim's
//! trace mode.
//!
//! Traces are generated lazily per fold; tests check the structural
//! invariants (coverage, ordering, double-buffer phase alternation)
//! without materializing multi-GB traces for real models.

use super::dataflow::Dataflow;

/// One address-trace entry: which operand, element coordinates, and the
/// cycle at which the fetch must complete.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceEntry {
    pub operand: Operand,
    /// Row index into the operand matrix.
    pub row: usize,
    /// Column index into the operand matrix.
    pub col: usize,
    /// Deadline cycle (fold-local).
    pub cycle: u64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Operand {
    /// Ifmap / activation matrix (M x K).
    Input,
    /// Filter / weight matrix (K x N).
    Weight,
}

/// One output-stationary fold's fetch trace for an (M x K).(K x N) GEMM
/// on an R x C array: output tile (fm, fn), streaming K elements into
/// each valid row/column with the wavefront skew.
pub fn os_fold_trace(
    m: usize,
    k: usize,
    n: usize,
    r: usize,
    c: usize,
    fm: usize,
    fn_: usize,
) -> Vec<TraceEntry> {
    let valid_rows = (m - fm * r).min(r);
    let valid_cols = (n - fn_ * c).min(c);
    let mut trace = Vec::with_capacity(k * (valid_rows + valid_cols));
    for kk in 0..k {
        // Input row i consumes A[fm*r + i, kk] at cycle i + kk.
        for i in 0..valid_rows {
            trace.push(TraceEntry {
                operand: Operand::Input,
                row: fm * r + i,
                col: kk,
                cycle: (i + kk) as u64,
            });
        }
        // Weight column j consumes B[kk, fn*c + j] at cycle j + kk.
        for j in 0..valid_cols {
            trace.push(TraceEntry {
                operand: Operand::Weight,
                row: kk,
                col: fn_ * c + j,
                cycle: (j + kk) as u64,
            });
        }
    }
    trace
}

/// Summary of a full-GEMM trace under OS: bytes fetched per operand and
/// the double-buffer high-water mark (bytes in flight while the next
/// fold prefetches during the current fold's drain).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceSummary {
    pub input_bytes: u64,
    pub weight_bytes: u64,
    pub folds: u64,
    /// Peak bytes resident in the (double-buffered) operand SRAMs.
    pub sram_high_water_bytes: u64,
}

/// Structural trace summary for the whole GEMM (int8 operands).
pub fn os_trace_summary(m: usize, k: usize, n: usize, r: usize, c: usize) -> TraceSummary {
    let folds_m = m.div_ceil(r) as u64;
    let folds_n = n.div_ceil(c) as u64;
    let folds = folds_m * folds_n;
    // Each fold streams its rows/cols of depth K once.
    let input_bytes = folds_n * (m as u64 * k as u64);
    let weight_bytes = folds_m * (k as u64 * n as u64);
    // Double buffering: one fold's working set live while the next
    // prefetches — two folds of (r + c) * k operand bytes.
    let fold_bytes = ((r + c) * k) as u64;
    TraceSummary {
        input_bytes,
        weight_bytes,
        folds,
        sram_high_water_bytes: 2 * fold_bytes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TpuConfig;
    use crate::systolic::sram_traffic;

    #[test]
    fn fold_trace_covers_exact_elements() {
        let t = os_fold_trace(5, 7, 3, 4, 4, 0, 0);
        // valid rows = 4, valid cols = 3; per k step: 4 inputs + 3 weights.
        assert_eq!(t.len(), 7 * (4 + 3));
        // Every input coordinate in range and unique per (row, k).
        let mut seen = std::collections::HashSet::new();
        for e in &t {
            match e.operand {
                Operand::Input => {
                    assert!(e.row < 5 && e.col < 7);
                    assert!(seen.insert((0, e.row, e.col)));
                }
                Operand::Weight => {
                    assert!(e.row < 7 && e.col < 3);
                    assert!(seen.insert((1, e.row, e.col)));
                }
            }
        }
    }

    #[test]
    fn edge_fold_is_ragged() {
        // Second m-fold of m=5 on r=4 has 1 valid row.
        let t = os_fold_trace(5, 6, 3, 4, 4, 1, 0);
        let inputs = t.iter().filter(|e| e.operand == Operand::Input).count();
        assert_eq!(inputs, 6); // 1 row x 6 k-steps
        assert!(t.iter().all(|e| e.operand != Operand::Input || e.row == 4));
    }

    #[test]
    fn deadlines_respect_wavefront_skew() {
        let t = os_fold_trace(4, 8, 4, 4, 4, 0, 0);
        for e in &t {
            let expected = match e.operand {
                Operand::Input => (e.row + e.col) as u64,
                Operand::Weight => (e.col + e.row) as u64,
            };
            assert_eq!(e.cycle, expected);
        }
        // Latest deadline < fold cycle count (k + r + c - 2).
        let max_cycle = t.iter().map(|e| e.cycle).max().unwrap();
        assert!(max_cycle <= (8 + 4 + 4 - 2) as u64);
    }

    #[test]
    fn summary_matches_sram_traffic_model() {
        // The trace summary and the coordinator's sram_traffic() must
        // agree on total bytes (they model the same fetch schedule).
        let tpu = TpuConfig::default();
        for (m, k, n) in [(100, 64, 1), (4096, 4096, 1), (33, 17, 9)] {
            let s = os_trace_summary(m, k, n, tpu.rows, tpu.cols);
            let (reads, _w) =
                sram_traffic(m, k, n, tpu.rows, tpu.cols, Dataflow::OutputStationary);
            assert_eq!(s.input_bytes + s.weight_bytes, reads, "({m},{k},{n})");
        }
    }

    #[test]
    fn high_water_fits_paper_sram() {
        // The paper's 8 MB SRAM must hold the double-buffered working
        // set of the largest Table II op (OPT-6.7B FF: 16384 x 4096).
        let tpu = TpuConfig::default();
        let s = os_trace_summary(16384, 4096, 1, tpu.rows, tpu.cols);
        assert!(
            s.sram_high_water_bytes < tpu.sram_bytes as u64,
            "{} bytes",
            s.sram_high_water_bytes
        );
    }
}
