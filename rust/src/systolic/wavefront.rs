//! Cycle-accurate wavefront stepper for the systolic array.
//!
//! Marches the skewed wavefront through the R x C PE grid cycle by cycle
//! and counts both elapsed cycles and executed MACs. It exists to
//! *validate* the closed-form models in [`super::dataflow`]: property
//! tests assert `simulate_gemm(..).cycles == gemm_cycles(..)` across
//! random shapes, and that executed MACs equal exactly M*K*N (work
//! conservation).
//!
//! Schedules (0-indexed cycles within a fold):
//!
//! * **OS**  — PE(i,j) performs its k-th MAC at cycle `i + j + k`:
//!   operand A row i is skewed by i, operand B column j by j, both
//!   streamed for K cycles.
//! * **WS**  — the fold's weight tile loads row-by-row for R cycles, then
//!   input row m meets PE(i,j) at `R + m + i + j`.
//! * **IS**  — input tile loads column-by-column for C cycles, then
//!   weight column nn meets PE(i,j) at `C + nn + i + j`.
//!
//! Folds execute back-to-back with no overlap, matching the analytical
//! model (and SCALE-Sim's non-overlapped analytical mode).

use super::dataflow::Dataflow;

/// Result of a cycle-accurate simulation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WavefrontResult {
    pub cycles: u64,
    pub macs: u64,
    /// Number of (fold) passes over the array.
    pub folds: u64,
}

/// Step one fold: PEs in the valid (rows x cols) sub-grid execute one MAC
/// per scheduled cycle. Returns (fold cycles, fold macs).
fn step_fold(
    valid_rows: usize,
    valid_cols: usize,
    depth: usize,   // streamed reduction length within the fold
    preload: usize, // cycles spent loading the stationary tile
    r: usize,
    c: usize,
) -> (u64, u64) {
    // Last MAC fires at preload + (depth-1) + (r-1) + (c-1); +1 for count.
    // We *march* it to keep the simulator honest rather than trusting the
    // formula we are trying to validate.
    let mut macs: u64 = 0;
    let mut last_active: u64 = 0;
    let horizon = preload + depth + r + c; // safe upper bound
    for t in 0..horizon as u64 {
        let mut any = false;
        for i in 0..valid_rows {
            for j in 0..valid_cols {
                // k-index scheduled at this PE this cycle:
                let offset = preload as i64 + i as i64 + j as i64;
                let k = t as i64 - offset;
                if k >= 0 && (k as usize) < depth {
                    macs += 1;
                    any = true;
                }
            }
        }
        if any {
            last_active = t;
        }
    }
    // Full pipeline occupancy of the fold includes the skew across the
    // WHOLE array (drain through inactive edge PEs still takes wall
    // cycles in the rigid schedule), so the fold time is formula-shaped
    // even for ragged tiles — matching SCALE-Sim.
    let fold_cycles = (preload + depth + r + c - 2) as u64;
    debug_assert!(last_active < fold_cycles + 1);
    (fold_cycles, macs)
}

/// Cycle-accurate GEMM simulation. Panics on degenerate shapes.
pub fn simulate_gemm(
    m: usize,
    k: usize,
    n: usize,
    r: usize,
    c: usize,
    df: Dataflow,
) -> WavefrontResult {
    assert!(m > 0 && k > 0 && n > 0 && r > 0 && c > 0, "degenerate GEMM");
    let mut total_cycles = 0u64;
    let mut total_macs = 0u64;
    let mut folds = 0u64;

    match df {
        Dataflow::OutputStationary => {
            // Fold grid: output tiles of (R x C) over (M x N); each PE
            // owns one output and accumulates across the full K stream.
            for fm in 0..m.div_ceil(r) {
                for fn_ in 0..n.div_ceil(c) {
                    let vr = (m - fm * r).min(r);
                    let vc = (n - fn_ * c).min(c);
                    let (cy, mc) = step_fold(vr, vc, k, 0, r, c);
                    total_cycles += cy;
                    total_macs += mc;
                    folds += 1;
                }
            }
        }
        Dataflow::WeightStationary => {
            // Stationary tile: (R x C) over the (K x N) weight matrix;
            // M input rows stream per fold after an R-cycle preload.
            for fk in 0..k.div_ceil(r) {
                for fn_ in 0..n.div_ceil(c) {
                    let vr = (k - fk * r).min(r);
                    let vc = (n - fn_ * c).min(c);
                    // Each streamed input row m contributes one MAC per
                    // valid (k, n) PE — depth is M here.
                    let (cy, mc) = step_fold(vr, vc, m, r, r, c);
                    total_cycles += cy;
                    total_macs += mc;
                    folds += 1;
                }
            }
        }
        Dataflow::InputStationary => {
            // Stationary tile: (R x C) over the (M x K) input matrix;
            // N weight columns stream per fold after a C-cycle preload.
            for fm in 0..m.div_ceil(r) {
                for fk in 0..k.div_ceil(c) {
                    let vr = (m - fm * r).min(r);
                    let vc = (k - fk * c).min(c);
                    let (cy, mc) = step_fold(vr, vc, n, c, r, c);
                    total_cycles += cy;
                    total_macs += mc;
                    folds += 1;
                }
            }
        }
    }

    WavefrontResult {
        cycles: total_cycles,
        macs: total_macs,
        folds,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::systolic::dataflow::gemm_cycles;

    #[test]
    fn os_single_fold_matches_formula() {
        let w = simulate_gemm(4, 7, 3, 4, 4, Dataflow::OutputStationary);
        assert_eq!(w.cycles, gemm_cycles(4, 7, 3, 4, 4, Dataflow::OutputStationary));
        assert_eq!(w.macs, 4 * 7 * 3);
        assert_eq!(w.folds, 1);
    }

    #[test]
    fn os_multi_fold_conserves_work() {
        let w = simulate_gemm(9, 5, 10, 4, 4, Dataflow::OutputStationary);
        assert_eq!(w.macs, 9 * 5 * 10);
        assert_eq!(w.folds, 3 * 3);
        assert_eq!(
            w.cycles,
            gemm_cycles(9, 5, 10, 4, 4, Dataflow::OutputStationary)
        );
    }

    #[test]
    fn ws_matches_formula_and_work() {
        let w = simulate_gemm(6, 9, 5, 4, 4, Dataflow::WeightStationary);
        assert_eq!(w.macs, 6 * 9 * 5);
        assert_eq!(
            w.cycles,
            gemm_cycles(6, 9, 5, 4, 4, Dataflow::WeightStationary)
        );
    }

    #[test]
    fn is_matches_formula_and_work() {
        let w = simulate_gemm(5, 6, 7, 4, 4, Dataflow::InputStationary);
        assert_eq!(w.macs, 5 * 6 * 7);
        assert_eq!(
            w.cycles,
            gemm_cycles(5, 6, 7, 4, 4, Dataflow::InputStationary)
        );
    }

    #[test]
    fn mvm_shape_all_dataflows_conserve_work() {
        for df in Dataflow::ALL {
            let w = simulate_gemm(33, 17, 1, 8, 8, df);
            assert_eq!(w.macs, 33 * 17, "{df:?}");
            assert_eq!(w.cycles, gemm_cycles(33, 17, 1, 8, 8, df), "{df:?}");
        }
    }
}
