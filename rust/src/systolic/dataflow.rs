//! Analytical cycle models for the three classic systolic dataflows,
//! SCALE-Sim style (paper Fig. 4 compares them and selects OS).
//!
//! GEMM convention: ifmap (M x K) . filter (K x N) -> output (M x N) on
//! an R x C PE array. Decoder inference makes everything an MVM (N = 1
//! or M = 1), which is exactly the regime where dataflow choice matters:
//! OS keeps partial sums pinned and only pays the skew once per fold,
//! WS burns cycles re-loading weights for folds that then do almost no
//! work, IS similarly re-streams weights.
//!
//! Formulas (validated cycle-by-cycle by `wavefront` property tests):
//!
//! * OS: folds = ceil(M/R) * ceil(N/C); per fold the K-deep accumulation
//!   plus the 2-D skew fill/drain: `T = folds * (K + R + C - 2)`.
//! * WS: folds = ceil(K/R) * ceil(N/C); per fold R cycles to pre-load the
//!   weight tile, then M input rows stream through with skew:
//!   `T = folds * (R + M + R + C - 2)`.
//! * IS: folds = ceil(M/R) * ceil(K/C); per fold C cycles to pre-load the
//!   input tile, then N weight columns stream through with skew:
//!   `T = folds * (C + N + R + C - 2)`.


/// Systolic-array dataflow (paper Fig. 4).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dataflow {
    /// Partial sums stationary in PEs (the paper's choice).
    OutputStationary,
    /// Weights pre-loaded per fold, inputs stream.
    WeightStationary,
    /// Inputs pre-loaded per fold, weights stream.
    InputStationary,
}

impl Dataflow {
    pub const ALL: [Dataflow; 3] = [
        Dataflow::OutputStationary,
        Dataflow::WeightStationary,
        Dataflow::InputStationary,
    ];

    pub fn short_name(&self) -> &'static str {
        match self {
            Dataflow::OutputStationary => "OS",
            Dataflow::WeightStationary => "WS",
            Dataflow::InputStationary => "IS",
        }
    }
}

/// Cycles for an (M x K).(K x N) GEMM on an R x C array.
pub fn gemm_cycles(m: usize, k: usize, n: usize, r: usize, c: usize, df: Dataflow) -> u64 {
    assert!(m > 0 && k > 0 && n > 0 && r > 0 && c > 0, "degenerate GEMM");
    let (m64, k64, n64) = (m as u64, k as u64, n as u64);
    let (r64, c64) = (r as u64, c as u64);
    match df {
        Dataflow::OutputStationary => {
            let folds = m64.div_ceil(r64) * n64.div_ceil(c64);
            folds * (k64 + r64 + c64 - 2)
        }
        Dataflow::WeightStationary => {
            let folds = k64.div_ceil(r64) * n64.div_ceil(c64);
            folds * (r64 + m64 + r64 + c64 - 2)
        }
        Dataflow::InputStationary => {
            let folds = m64.div_ceil(r64) * k64.div_ceil(c64);
            folds * (c64 + n64 + r64 + c64 - 2)
        }
    }
}

/// Cycles for a full decode step (all ops) under one dataflow — the
/// quantity plotted per model in paper Fig. 4.
pub fn decode_step_cycles(
    model: &crate::models::LlmConfig,
    l: usize,
    r: usize,
    c: usize,
    df: Dataflow,
) -> u64 {
    crate::workload::decode_ops(model, l)
        .iter()
        .map(|op| gemm_cycles(op.m, op.k, op.n, r, c, df))
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::by_name;

    #[test]
    fn os_formula_spot_checks() {
        // ceil(128/32)*ceil(1/32)*(64+62) = 4*126
        assert_eq!(
            gemm_cycles(128, 64, 1, 32, 32, Dataflow::OutputStationary),
            4 * 126
        );
        // square fold: ceil(64/32)*ceil(64/32)*(64+62)
        assert_eq!(
            gemm_cycles(64, 64, 64, 32, 32, Dataflow::OutputStationary),
            4 * 126
        );
    }

    #[test]
    fn ws_pays_weight_reload_for_mvm() {
        // MVM M=1: WS folds over K, each fold mostly pipeline overhead.
        let os = gemm_cycles(1, 4096, 4096, 32, 32, Dataflow::OutputStationary);
        let ws = gemm_cycles(1, 4096, 4096, 32, 32, Dataflow::WeightStationary);
        assert!(ws > os, "ws={ws} os={os}");
    }

    #[test]
    fn os_wins_for_decoder_workloads() {
        // Fig. 4's conclusion: OS < WS and OS < IS for decode steps.
        for name in ["GPT2-355M", "OPT-1.3B", "OPT-6.7B"] {
            let m = by_name(name).unwrap();
            let os = decode_step_cycles(&m, 1024, 32, 32, Dataflow::OutputStationary);
            let ws = decode_step_cycles(&m, 1024, 32, 32, Dataflow::WeightStationary);
            let is = decode_step_cycles(&m, 1024, 32, 32, Dataflow::InputStationary);
            assert!(os < ws, "{name}: os={os} ws={ws}");
            assert!(os < is, "{name}: os={os} is={is}");
        }
    }

    #[test]
    fn cycles_monotone_in_each_dim() {
        for df in Dataflow::ALL {
            let base = gemm_cycles(100, 100, 100, 32, 32, df);
            assert!(gemm_cycles(200, 100, 100, 32, 32, df) >= base);
            assert!(gemm_cycles(100, 200, 100, 32, 32, df) >= base);
            assert!(gemm_cycles(100, 100, 200, 32, 32, df) >= base);
        }
    }

    #[test]
    #[should_panic(expected = "degenerate")]
    fn zero_dim_panics() {
        gemm_cycles(0, 1, 1, 32, 32, Dataflow::OutputStationary);
    }
}
